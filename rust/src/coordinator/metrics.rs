//! Service metrics: queue depth, batch occupancy, latency percentiles,
//! failure/backpressure counters, and the resilience (retry/failover)
//! counters exported by `runtime::resilient`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, PoisonError};

/// Counters and latency samples for one [`KdeService`] instance.
///
/// The failure-path counters (`rejected`, `timeouts`, `error_replies`,
/// `worker_panics`, `worker_respawns`) record the serving contracts of the
/// failure model (docs/ARCHITECTURE.md): every admitted request gets
/// exactly one reply — an answer, a `Timeout`, or a typed error — and a
/// crashed worker is respawned rather than silently shrinking the pool.
///
/// [`KdeService`]: crate::coordinator::batcher::KdeService
#[derive(Default)]
pub struct ServiceMetrics {
    /// Requests admitted into the bounded queue.
    pub enqueued: AtomicU64,
    /// Requests answered with an `Ok` value.
    pub completed: AtomicU64,
    /// Requests refused at the bounded queue (`Overloaded` backpressure).
    pub rejected: AtomicU64,
    /// Requests whose deadline expired before execution (`Timeout` reply).
    pub timeouts: AtomicU64,
    /// Requests answered with a typed error other than `Timeout`.
    pub error_replies: AtomicU64,
    /// Panics caught at a worker's isolation boundary.
    pub worker_panics: AtomicU64,
    /// Worker threads respawned after dying.
    pub worker_respawns: AtomicU64,
    /// Batches dispatched to the worker pool.
    pub batches: AtomicU64,
    /// Total queries across dispatched batches.
    pub batched_queries: AtomicU64,
    latencies_us: Mutex<Vec<f64>>,
}

impl ServiceMetrics {
    /// Fresh zeroed metrics.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one dispatched batch of `size` queries.
    pub fn record_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_queries.fetch_add(size as u64, Ordering::Relaxed);
    }

    /// Record one completed request and its end-to-end latency.
    pub fn record_latency_us(&self, us: f64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        // A poisoned sample buffer (panicking pusher) still holds valid
        // samples; recover the guard instead of cascading the panic.
        self.latencies_us
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .push(us);
    }

    /// Mean queries per batch (batch occupancy; 64 is the AOT optimum).
    pub fn mean_batch_occupancy(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batched_queries.load(Ordering::Relaxed) as f64 / b as f64
    }

    /// Latency percentile in microseconds over all completed requests.
    pub fn latency_percentile_us(&self, p: f64) -> f64 {
        let l = self
            .latencies_us
            .lock()
            .unwrap_or_else(PoisonError::into_inner);
        if l.is_empty() {
            return 0.0;
        }
        crate::util::stats::percentile(&l, p)
    }

    /// One-line human-readable snapshot.
    pub fn summary(&self) -> String {
        format!(
            "enqueued={} completed={} rejected={} timeouts={} errors={} batches={} \
             occupancy={:.1} p50={:.0}us p95={:.0}us p99={:.0}us",
            self.enqueued.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.timeouts.load(Ordering::Relaxed),
            self.error_replies.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_occupancy(),
            self.latency_percentile_us(50.0),
            self.latency_percentile_us(95.0),
            self.latency_percentile_us(99.0),
        )
    }
}

/// Retry/degradation counters for a `ResilientBackend`
/// (`runtime::resilient`): how many primary attempts failed, how many were
/// retried, whether the wrapper failed over, and how many calls the
/// fallback has absorbed since.
#[derive(Default)]
pub struct ResilienceMetrics {
    /// Primary-backend attempts that returned an error (or panicked).
    pub primary_errors: AtomicU64,
    /// Retries issued against the primary after a transient error.
    pub retries: AtomicU64,
    /// Permanent degradations to the fallback backend (0 or 1 per wrapper).
    pub failovers: AtomicU64,
    /// Calls served by the fallback backend after failover.
    pub fallback_calls: AtomicU64,
}

impl ResilienceMetrics {
    /// Fresh zeroed counters behind an `Arc` (shared with the wrapper).
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// One-line human-readable snapshot.
    pub fn summary(&self) -> String {
        format!(
            "primary_errors={} retries={} failovers={} fallback_calls={}",
            self.primary_errors.load(Ordering::Relaxed),
            self.retries.load(Ordering::Relaxed),
            self.failovers.load(Ordering::Relaxed),
            self.fallback_calls.load(Ordering::Relaxed),
        )
    }
}

/// Occupancy and scheduling counters for the persistent sharded worker
/// pool (`runtime::pool::WorkerPool`).
///
/// `busy`/`queued` are gauges (current in-flight and queued task counts);
/// `busy_max`/`queued_max` are their high-water marks since pool start.
/// `steals` counts tasks a worker took LIFO from another worker's shard,
/// `inline_runs` counts tasks executed on the submitting thread because a
/// shard queue was at its bound (or the submitter was itself a pool
/// worker), and `task_panics` counts panics contained at the worker
/// isolation boundary (the pool thread survives; `run_scoped` re-raises
/// the payload on the caller so the typed `BackendError::Panicked` path
/// still fires).
#[derive(Default)]
pub struct PoolMetrics {
    /// Tasks currently executing on pool workers (gauge).
    pub busy: AtomicU64,
    /// High-water mark of `busy`.
    pub busy_max: AtomicU64,
    /// Tasks currently sitting in shard queues (gauge).
    pub queued: AtomicU64,
    /// High-water mark of `queued`.
    pub queued_max: AtomicU64,
    /// Tasks taken LIFO from another worker's shard.
    pub steals: AtomicU64,
    /// Tasks submitted to the pool (queued + inline).
    pub submitted: AtomicU64,
    /// Tasks run on the submitting thread (queue bound hit, or the
    /// submitter was a pool worker — the nested-submit deadlock guard).
    pub inline_runs: AtomicU64,
    /// Tasks that finished (on a worker or inline), panicked or not.
    pub completed: AtomicU64,
    /// Panics contained at the worker boundary.
    pub task_panics: AtomicU64,
}

impl PoolMetrics {
    /// Fresh zeroed counters behind an `Arc` (shared with the pool).
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// Current in-flight task count.
    pub fn busy(&self) -> u64 {
        self.busy.load(Ordering::Relaxed)
    }

    /// Current queued task count across all shards.
    pub fn queued_depth(&self) -> u64 {
        self.queued.load(Ordering::Relaxed)
    }

    /// Tasks stolen LIFO from a sibling shard since pool start.
    pub fn steals(&self) -> u64 {
        self.steals.load(Ordering::Relaxed)
    }

    /// Bump a gauge and fold its new value into the high-water mark.
    pub(crate) fn gauge_inc(gauge: &AtomicU64, max: &AtomicU64) {
        let now = gauge.fetch_add(1, Ordering::Relaxed) + 1;
        max.fetch_max(now, Ordering::Relaxed);
    }

    /// One-line human-readable snapshot.
    pub fn summary(&self) -> String {
        format!(
            "busy={} queued={} busy_max={} queued_max={} steals={} \
             submitted={} inline={} panics={}",
            self.busy.load(Ordering::Relaxed),
            self.queued.load(Ordering::Relaxed),
            self.busy_max.load(Ordering::Relaxed),
            self.queued_max.load(Ordering::Relaxed),
            self.steals.load(Ordering::Relaxed),
            self.submitted.load(Ordering::Relaxed),
            self.inline_runs.load(Ordering::Relaxed),
            self.task_panics.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn occupancy_math() {
        let m = ServiceMetrics::new();
        m.record_batch(64);
        m.record_batch(32);
        assert!((m.mean_batch_occupancy() - 48.0).abs() < 1e-12);
    }

    #[test]
    fn latency_percentiles() {
        let m = ServiceMetrics::new();
        for i in 1..=100 {
            m.record_latency_us(i as f64);
        }
        assert_eq!(m.completed.load(Ordering::Relaxed), 100);
        assert!((m.latency_percentile_us(50.0) - 50.0).abs() <= 1.0);
        assert!(m.latency_percentile_us(95.0) >= 94.0);
    }

    #[test]
    fn summaries_include_failure_counters() {
        let m = ServiceMetrics::new();
        m.rejected.fetch_add(3, Ordering::Relaxed);
        m.timeouts.fetch_add(2, Ordering::Relaxed);
        let s = m.summary();
        assert!(s.contains("rejected=3"), "got: {s}");
        assert!(s.contains("timeouts=2"), "got: {s}");
        let r = ResilienceMetrics::new();
        r.retries.fetch_add(5, Ordering::Relaxed);
        assert!(r.summary().contains("retries=5"));
    }
}
