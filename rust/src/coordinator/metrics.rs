//! Service metrics: queue depth, batch occupancy, latency percentiles.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

#[derive(Default)]
pub struct ServiceMetrics {
    pub enqueued: AtomicU64,
    pub completed: AtomicU64,
    pub batches: AtomicU64,
    pub batched_queries: AtomicU64,
    latencies_us: Mutex<Vec<f64>>,
}

impl ServiceMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_queries.fetch_add(size as u64, Ordering::Relaxed);
    }

    pub fn record_latency_us(&self, us: f64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.latencies_us.lock().unwrap().push(us);
    }

    /// Mean queries per batch (batch occupancy; 64 is the AOT optimum).
    pub fn mean_batch_occupancy(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batched_queries.load(Ordering::Relaxed) as f64 / b as f64
    }

    pub fn latency_percentile_us(&self, p: f64) -> f64 {
        let l = self.latencies_us.lock().unwrap();
        if l.is_empty() {
            return 0.0;
        }
        crate::util::stats::percentile(&l, p)
    }

    pub fn summary(&self) -> String {
        format!(
            "enqueued={} completed={} batches={} occupancy={:.1} p50={:.0}us p95={:.0}us p99={:.0}us",
            self.enqueued.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_occupancy(),
            self.latency_percentile_us(50.0),
            self.latency_percentile_us(95.0),
            self.latency_percentile_us(99.0),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn occupancy_math() {
        let m = ServiceMetrics::new();
        m.record_batch(64);
        m.record_batch(32);
        assert!((m.mean_batch_occupancy() - 48.0).abs() < 1e-12);
    }

    #[test]
    fn latency_percentiles() {
        let m = ServiceMetrics::new();
        for i in 1..=100 {
            m.record_latency_us(i as f64);
        }
        assert_eq!(m.completed.load(Ordering::Relaxed), 100);
        assert!((m.latency_percentile_us(50.0) - 50.0).abs() <= 1.0);
        assert!(m.latency_percentile_us(95.0) >= 94.0);
    }
}
