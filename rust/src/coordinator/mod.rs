//! The KDE query coordinator: the serving-layer system around the paper's
//! oracle.
//!
//! Architecture (vLLM-router-style, thread + channel based — tokio is not
//! available in the offline registry, DESIGN.md §3):
//!
//! ```text
//!   clients ──> router (mpsc) ──> dynamic batcher ──> worker pool
//!                                   |  flush at B=64 or deadline     \
//!                                   v                                v
//!                            per-shard queues                 KernelBackend
//!                                                          (CPU or PJRT AOT)
//! ```
//!
//! Requests are single KDE queries (`shard`, `point`); the batcher packs up
//! to `max_batch` of them into one `Kde::query_batch` dispatch — exactly
//! the shape the AOT artifact wants (B = 64 queries per execution) — and
//! fans results back out through per-request channels. Shards are
//! `Arc<dyn Kde>` oracles (`start_with_oracles`): raw datasets served
//! exactly (`start`), sampling/HBE estimators, or multi-level-tree nodes.
//!
//! The serving path implements the failure model of docs/ARCHITECTURE.md
//! §"Failure model": a bounded ingress queue that rejects with
//! `Overloaded` under backpressure, per-request deadlines answered with
//! `Timeout`, panic isolation at the worker boundary with typed error
//! replies, and worker respawn. Production code in this tree must not
//! `unwrap`/`expect` — failures travel as typed
//! [`BackendError`](crate::runtime::BackendError)s (the clippy gate below
//! is part of CI's `-D warnings` leg).
//!
//! The module also hosts the offline pipeline's level-fusion planners
//! ([`plan_level_fusion`] and its cross-level extension
//! [`plan_level_fusion_adaptive`], which admits segments largest-first so
//! the frontier walk engine's mixed-level rounds share submissions): the
//! same B = 64 packing discipline, applied to whole tree levels instead of
//! request queues.
#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod batcher;
pub mod metrics;

pub use batcher::{
    plan_level_fusion, plan_level_fusion_adaptive, run_double_buffered,
    try_run_double_buffered, BatcherConfig, FuseJob, FuseSubmission, KdeService, OverlapEpoch,
    OverlapSession, QueryRequest,
};
pub use metrics::{PoolMetrics, ResilienceMetrics, ServiceMetrics};
