//! The KDE query coordinator: the serving-layer system around the paper's
//! oracle.
//!
//! Architecture (vLLM-router-style, thread + channel based — tokio is not
//! available in the offline registry, DESIGN.md §3):
//!
//! ```text
//!   clients ──> router (mpsc) ──> dynamic batcher ──> worker pool
//!                                   |  flush at B=64 or deadline     \
//!                                   v                                v
//!                            per-shard queues                 KernelBackend
//!                                                          (CPU or PJRT AOT)
//! ```
//!
//! Requests are single KDE queries (`shard`, `point`); the batcher packs up
//! to `max_batch` of them into one `Kde::query_batch` dispatch — exactly
//! the shape the AOT artifact wants (B = 64 queries per execution) — and
//! fans results back out through per-request channels. Shards are
//! `Arc<dyn Kde>` oracles (`start_with_oracles`): raw datasets served
//! exactly (`start`), sampling/HBE estimators, or multi-level-tree nodes.
//!
//! The module also hosts the offline pipeline's level-fusion planners
//! ([`plan_level_fusion`] and its cross-level extension
//! [`plan_level_fusion_adaptive`], which admits segments largest-first so
//! the frontier walk engine's mixed-level rounds share submissions): the
//! same B = 64 packing discipline, applied to whole tree levels instead of
//! request queues.

pub mod batcher;
pub mod metrics;

pub use batcher::{
    plan_level_fusion, plan_level_fusion_adaptive, BatcherConfig, FuseJob, FuseSubmission,
    KdeService, QueryRequest,
};
pub use metrics::ServiceMetrics;
