//! Dynamic batcher + worker pool for KDE queries.
//!
//! One router thread drains the ingress queue, groups requests per shard,
//! and flushes a batch when it reaches `max_batch` or when the oldest
//! request exceeds `max_wait`. Worker threads execute batches through the
//! shard's `Kde::query_batch` (one oracle/backend dispatch per batch — the
//! AOT artifact's native shape) and deliver results to per-request
//! response channels.
//!
//! A shard is any `Arc<dyn Kde>` — a raw dataset served exactly (the
//! [`KdeService::start`] convenience wraps each `(kernel, dataset)` in a
//! `NaiveKde`), a sampling/HBE estimator, or a multi-level-tree node —
//! so the serving layer batches over the same oracle abstraction the
//! algorithms use.
//!
//! ## Failure hardening
//!
//! The service enforces the failure model of docs/ARCHITECTURE.md
//! §"Failure model": the ingress queue is **bounded** (`queue_cap`) and a
//! full queue rejects with [`BackendError::Overloaded`] instead of
//! buffering without bound; requests may carry a **deadline**, and an
//! expired request is dropped from the batch plan and answered with
//! [`BackendError::Timeout`]; a panicking shard oracle is caught at the
//! worker's isolation boundary, every in-flight client of the batch gets
//! a typed error reply (never a hang), and a worker that dies anyway is
//! respawned by the router. Every reply channel carries
//! `Result<f64, BackendError>`; the panicking `submit`/`query` entry
//! points remain as thin wrappers over `try_submit`/`try_query`.
//!
//! This module also hosts [`plan_level_fusion`], the static planner behind
//! the batched tree pipeline's level fusion: it packs the cache-miss query
//! groups of *several* tree nodes at one level into padded fused
//! submissions shaped like the AOT artifact (B = 64 query rows, M = 1024
//! packed data rows), which `MultiLevelKde::query_points_multi` then
//! executes through one `KernelBackend::sums_ranged` dispatch each.
//! [`plan_level_fusion_adaptive`] is its cross-level extension: identical
//! invariants, but segments are admitted largest-first so that groups from
//! *different tree levels* (the frontier-batched walk engine's shape, with
//! per-level row counts far below B) share padded submissions instead of
//! closing one at every level boundary.

use std::sync::OnceLock;
use std::time::{Duration, Instant};

use crate::coordinator::metrics::ServiceMetrics;
use crate::kde::estimators::NaiveKde;
use crate::kde::{Kde, KdeCounters};
use crate::kernel::{Dataset, Kernel};
use crate::runtime::backend::KernelBackend;
use crate::runtime::error::{catch_panic, BackendError};
use crate::runtime::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::runtime::sync::mpsc::{self, Receiver, SyncSender};
use crate::runtime::sync::{self, Arc, Mutex, PoisonError};

/// One fusable query group handed to [`plan_level_fusion`]: `rows`
/// cache-miss query rows that all attend to the same `seg_rows`-row data
/// segment (one tree node's data slice or sample buffer).
#[derive(Clone, Copy, Debug)]
pub struct FuseJob {
    /// Number of query rows in this group.
    pub rows: usize,
    /// Number of data rows in the group's segment.
    pub seg_rows: usize,
}

/// One planned fused submission: which job rows it carries and which jobs'
/// segments get packed (each segment once) into its shared data buffer.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FuseSubmission {
    /// `(job index, row index within that job)` in submission row order.
    pub rows: Vec<(usize, usize)>,
    /// Distinct job indices whose segments are packed, in pack order. A
    /// row's `(lo, hi)` data range is its job's segment offset within this
    /// pack.
    pub segments: Vec<usize>,
}

/// Pack one level's fusable query groups into fused submissions.
///
/// Greedy and deterministic: jobs are consumed in order; a submission is
/// closed when it reaches `max_rows` query rows, or when admitting a *new*
/// segment would push its packed data past `max_data_rows` (a single
/// segment larger than `max_data_rows` is still admitted alone — the
/// backend tiles internally). Rows never split across submissions, so a
/// fused row's sum keeps the exact accumulation order of an unfused
/// per-node dispatch; a job whose rows span several submissions has its
/// segment re-packed into each.
///
/// `max_rows` and `max_data_rows` are normally the AOT shapes
/// (`AOT_B` = 64, `AOT_M` = 1024), making the CPU backends' per-submission
/// `calls()` counter line up with the PJRT executions a real artifact run
/// would pay — the backend-uniform accounting the fusion tests assert on.
pub fn plan_level_fusion(
    jobs: &[FuseJob],
    max_rows: usize,
    max_data_rows: usize,
) -> Vec<FuseSubmission> {
    let order: Vec<usize> = (0..jobs.len()).collect();
    plan_greedy(jobs, &order, max_rows, max_data_rows)
}

/// Cross-level variant of [`plan_level_fusion`] — the adaptive planner the
/// frontier-batched walk engine runs on.
///
/// Same packing rules and invariants (rows never split, segments packed
/// once per submission, row/data caps, oversize-alone), but jobs are
/// admitted in order of **decreasing segment size** (ties by job index,
/// deterministic) instead of input order. When the jobs of one
/// `query_points_multi` call come from *several tree levels* — the
/// frontier walk engine's shape, where W < B walkers sit at different
/// depths of interleaved descents — input order alternates large
/// (shallow-node) and small (deep-node) segments, and the in-order greedy
/// closes a submission at nearly every boundary. Sorting clusters the
/// small deep-level segments so they share padded submissions: in the
/// tiny-walker regime (per-level row counts below B = 64) a whole mixed-
/// level frontier round packs into O(ceil(rows / B) + ceil(data / M))
/// submissions instead of one per level.
///
/// Values are unaffected by the ordering: every row accumulates its own
/// segment range with its own f64 accumulator, so fused answers stay
/// bit-identical to [`plan_level_fusion`]'s regardless of which rows
/// share a submission.
pub fn plan_level_fusion_adaptive(
    jobs: &[FuseJob],
    max_rows: usize,
    max_data_rows: usize,
) -> Vec<FuseSubmission> {
    let mut order: Vec<usize> = (0..jobs.len()).collect();
    order.sort_by_key(|&j| (std::cmp::Reverse(jobs[j].seg_rows), j));
    plan_greedy(jobs, &order, max_rows, max_data_rows)
}

/// Greedy packing core shared by the in-order and adaptive planners:
/// consume jobs in `order`, close a submission at `max_rows` query rows or
/// when admitting a new segment would exceed `max_data_rows` (an oversize
/// segment is still admitted alone — the backend tiles internally).
fn plan_greedy(
    jobs: &[FuseJob],
    order: &[usize],
    max_rows: usize,
    max_data_rows: usize,
) -> Vec<FuseSubmission> {
    assert!(max_rows >= 1 && max_data_rows >= 1);
    let mut subs: Vec<FuseSubmission> = Vec::new();
    let mut cur = FuseSubmission::default();
    let mut cur_data = 0usize;
    for &j in order {
        let job = &jobs[j];
        for r in 0..job.rows {
            if cur.rows.len() == max_rows {
                subs.push(std::mem::take(&mut cur));
                cur_data = 0;
            }
            if !cur.segments.contains(&j) {
                if !cur.rows.is_empty() && cur_data + job.seg_rows > max_data_rows {
                    subs.push(std::mem::take(&mut cur));
                    cur_data = 0;
                }
                cur.segments.push(j);
                cur_data += job.seg_rows;
            }
            cur.rows.push((j, r));
        }
    }
    if !cur.rows.is_empty() {
        subs.push(cur);
    }
    subs
}

/// Fallible double-buffered pack/execute submission queue — the overlap
/// engine behind [`run_double_buffered`], with a typed failure channel.
///
/// Semantics on success are identical to [`run_double_buffered`]: `pack`
/// runs on a dedicated packer thread feeding a bounded channel of
/// capacity 1, `execute` runs on the **calling** thread in plan order.
/// Failure semantics:
///
/// * A panic inside `pack` is caught on the packer thread and surfaces as
///   `Err(BackendError::Panicked)`; the packer stops after reporting it.
/// * The first `Err` returned by `execute` aborts the run; pending packed
///   submissions are discarded.
/// * In both cases the channel endpoints drop on the way out, so the
///   packer thread can never stay blocked on a full channel — the scope
///   join completes and the caller gets the error instead of a hang
///   (pinned in `tests/faults.rs`).
pub fn try_run_double_buffered<T, P, R, F, G>(
    items: Vec<T>,
    overlap: bool,
    pack: F,
    mut execute: G,
) -> Result<Vec<R>, BackendError>
where
    T: Send,
    P: Send,
    F: Fn(T) -> P + Sync,
    G: FnMut(P) -> Result<R, BackendError>,
{
    if !overlap || items.len() < 2 {
        let mut out = Vec::with_capacity(items.len());
        for t in items {
            let p = catch_panic(|| pack(t))?;
            out.push(catch_panic(|| execute(p)).and_then(|r| r)?);
        }
        return Ok(out);
    }
    let expected = items.len();
    std::thread::scope(|s| {
        let (tx, rx) = mpsc::sync_channel::<Result<P, BackendError>>(1);
        let pack_ref = &pack;
        s.spawn(move || {
            for t in items {
                let packed = catch_panic(|| pack_ref(t));
                let failed = packed.is_err();
                // A send error means the executor hung up (error abort);
                // stop packing rather than panic. After reporting a pack
                // failure there is nothing sound left to pack either.
                if tx.send(packed).is_err() || failed {
                    return;
                }
            }
        });
        let mut out = Vec::with_capacity(expected);
        let mut failure: Option<BackendError> = None;
        for packed in rx.iter() {
            let ran = packed.and_then(|p| catch_panic(|| execute(p)).and_then(|r| r));
            match ran {
                Ok(r) => out.push(r),
                Err(e) => {
                    failure = Some(e);
                    break;
                }
            }
        }
        // `rx` drops when this closure returns — before the scope joins —
        // so a packer blocked mid-`send` wakes with a send error and
        // exits; the join cannot hang.
        match failure {
            None => Ok(out),
            Some(e) => Err(e),
        }
    })
}

/// Double-buffered pack/execute submission queue: overlap the *packing*
/// of fused submission `r + 1` (query gather + data-segment concatenation
/// — the planner's memcpy-bound tail) with the *backend execution* of
/// submission `r` (the compute-bound head).
///
/// `pack` runs on a dedicated packer thread feeding a bounded channel of
/// capacity 1, so at any moment at most two packed submissions exist —
/// one executing, one buffered (plus one in flight inside `pack`): the
/// classic double buffer, with bounded memory no matter how long the
/// plan is. `execute` runs on the **calling** thread, in plan order, so
/// everything the executor touches (`&mut` result tables, memo-cache
/// commits, dispatch counters) behaves exactly as in the sequential
/// loop: same submissions, same order, same values — overlap changes
/// wall-clock only. With `overlap` false (the sequential fallback, see
/// `MultiLevelKde::set_overlap`) or fewer than two items, no thread is
/// spawned and the loop runs inline.
///
/// Scoped threads make borrowed data (`&[f32]` views into oracle
/// buffers) safe to pack on the worker without cloning. This entry
/// panics if packing panics; fallible callers use
/// [`try_run_double_buffered`], which this is a thin wrapper over.
pub fn run_double_buffered<T, P, R, F, G>(
    items: Vec<T>,
    overlap: bool,
    pack: F,
    mut execute: G,
) -> Vec<R>
where
    T: Send,
    P: Send,
    F: Fn(T) -> P + Sync,
    G: FnMut(P) -> R,
{
    match try_run_double_buffered(items, overlap, pack, |p| Ok(execute(p))) {
        Ok(out) => out,
        Err(e) => panic!("overlap pipeline failed: {e}"),
    }
}

/// Persistent cross-round overlap pipeline: the packer thread behind
/// [`try_run_double_buffered`], kept alive **across successive**
/// `query_points_multi` rounds instead of being spawned and joined per
/// call.
///
/// Why: a batched tree descent issues one fused round per level, and the
/// per-call scoped pipeline pays a packer-thread spawn + join on every
/// round. A descent over L levels — or a long walk/edge batch issuing
/// hundreds of rounds — re-pays that startup L times for pipelines that
/// individually last microseconds. The session keeps ONE warm packer
/// thread; round r+1's packing starts on it the moment round r's caller
/// hands over its plan, so packing overlaps execution across round
/// boundaries, not just within one call.
///
/// Execution semantics are *identical* to `try_run_double_buffered` with
/// `overlap = true`: `pack` runs off-thread feeding a bounded channel of
/// capacity 1, `execute` runs on the calling thread in plan order, pack
/// panics surface as `Err(BackendError::Panicked)`, the first execute
/// error aborts the round, and no path hangs. Same submissions, same
/// order, same memo commits, same dispatch counts — the session changes
/// wall-clock only (property-pinned in this module's tests and
/// `tests/fusion.rs`). The session thread survives pack panics: the
/// round reports its typed error and the next round reuses the thread.
///
/// Concurrency: one round runs on the session thread at a time; a
/// concurrent caller (two threads querying one `MultiLevelKde`) falls
/// back to the per-call scoped pipeline — again semantics-identical —
/// and the `fallbacks` counter records it.
pub struct OverlapSession {
    /// Lazily spawned worker; `None` inside means thread spawn failed and
    /// every round falls back to the per-call pipeline.
    inner: OnceLock<Option<SessionHandle>>,
    /// Serializes rounds on the session thread (try-lock; contended
    /// callers fall back).
    busy: Mutex<()>,
    rounds: AtomicU64,
    epochs: AtomicU64,
    fallbacks: AtomicU64,
}

struct SessionHandle {
    tx: SyncSender<SessionJob>,
    worker: sync::thread::JoinHandle<()>,
}

/// One round's erased pack loop plus the caller-release signal.
struct SessionJob {
    payload: Option<Box<dyn FnOnce() + Send>>,
    done: Option<SyncSender<()>>,
}

impl SessionJob {
    fn run(mut self) {
        if let Some(f) = self.payload.take() {
            f();
        }
        // Drop signals `done` — strictly after the payload (and every
        // lifetime-erased borrow inside it) has been dropped.
    }
}

impl Drop for SessionJob {
    fn drop(&mut self) {
        // Order matters for the lifetime-erasure soundness argument:
        // erased borrows drop FIRST (whether the job ran or not), and
        // only then is the blocked caller released.
        self.payload.take();
        if let Some(done) = self.done.take() {
            let _ = done.send(());
        }
    }
}

/// Blocks (in `Drop`, so on unwind paths too) until the session thread
/// has finished with — and dropped — everything borrowed by the round.
struct DoneGuard(Receiver<()>);

impl Drop for DoneGuard {
    fn drop(&mut self) {
        let _ = self.0.recv();
    }
}

fn spawn_session_worker() -> Option<SessionHandle> {
    let (tx, rx) = mpsc::sync_channel::<SessionJob>(1);
    sync::thread::spawn_named("kde-overlap", move || {
        while let Ok(job) = rx.recv() {
            // Pack panics are already caught inside the job; this
            // outer guard keeps the session thread alive against
            // anything else, so one bad round never degrades the
            // session for the rounds after it.
            let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| job.run()));
        }
    })
    .ok()
    .map(|worker| SessionHandle { tx, worker })
}

impl Default for OverlapSession {
    fn default() -> Self {
        Self::new()
    }
}

impl OverlapSession {
    /// New session; the worker thread spawns lazily on first use.
    pub fn new() -> Self {
        OverlapSession {
            inner: OnceLock::new(),
            busy: Mutex::new(()),
            rounds: AtomicU64::new(0),
            epochs: AtomicU64::new(0),
            fallbacks: AtomicU64::new(0),
        }
    }

    /// Open an epoch: warm the packer thread ahead of a multi-round batch
    /// (a whole `sample_batch_with_streams` descent, an edge batch) so
    /// even the first round reuses it. The guard is a scope marker — the
    /// session outlives it; `epochs()` counts openings.
    pub fn epoch(&self) -> OverlapEpoch<'_> {
        self.epochs.fetch_add(1, Ordering::Relaxed);
        let _ = self.inner.get_or_init(spawn_session_worker);
        OverlapEpoch { _session: self }
    }

    /// Rounds run on the persistent packer thread since creation.
    pub fn rounds(&self) -> u64 {
        self.rounds.load(Ordering::Relaxed)
    }

    /// Epoch handles opened via [`OverlapSession::epoch`].
    pub fn epochs(&self) -> u64 {
        self.epochs.load(Ordering::Relaxed)
    }

    /// Rounds that fell back to the per-call scoped pipeline (concurrent
    /// caller or failed thread spawn). Semantics are identical either
    /// way; this only records which substrate ran the round.
    pub fn fallbacks(&self) -> u64 {
        self.fallbacks.load(Ordering::Relaxed)
    }

    /// Whether the persistent worker thread has been spawned.
    pub fn started(&self) -> bool {
        matches!(self.inner.get(), Some(Some(_)))
    }

    /// Run one round through the persistent pipeline. Single-item (or
    /// empty) rounds run inline exactly like `try_run_double_buffered`'s
    /// sequential arm; contended or spawn-failed sessions fall back to
    /// the per-call scoped pipeline. All routes: identical submissions,
    /// order, memo commits, and dispatch counts.
    pub fn try_run<T, P, R, F, G>(
        &self,
        items: Vec<T>,
        pack: F,
        mut execute: G,
    ) -> Result<Vec<R>, BackendError>
    where
        T: Send,
        P: Send,
        F: Fn(T) -> P + Sync,
        G: FnMut(P) -> Result<R, BackendError>,
    {
        if items.len() < 2 {
            return try_run_double_buffered(items, false, pack, execute);
        }
        let _busy = match self.busy.try_lock() {
            Ok(g) => g,
            Err(_) => {
                self.fallbacks.fetch_add(1, Ordering::Relaxed);
                return try_run_double_buffered(items, true, pack, execute);
            }
        };
        let handle = match self.inner.get_or_init(spawn_session_worker) {
            Some(h) => h,
            None => {
                self.fallbacks.fetch_add(1, Ordering::Relaxed);
                return try_run_double_buffered(items, true, pack, execute);
            }
        };
        let expected = items.len();
        // Declaration order is load-bearing: locals drop in reverse, so
        // `rx_packed` (declared after `_done`) closes BEFORE the guard
        // blocks — a packer stuck mid-send wakes with a send error, drops
        // its borrows, and only then is the caller released.
        let (done_tx, done_rx) = mpsc::sync_channel::<()>(1);
        let _done = DoneGuard(done_rx);
        let (tx_packed, rx_packed) = mpsc::sync_channel::<Result<P, BackendError>>(1);
        let pack_ref = &pack;
        let body: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
            let mut it = items.into_iter();
            for t in &mut it {
                let packed = catch_panic(|| pack_ref(t));
                let failed = packed.is_err();
                // Send error = executor hung up (error abort); after a
                // pack failure there is nothing sound left to pack.
                if tx_packed.send(packed).is_err() || failed {
                    break;
                }
            }
            // Unconsumed items (early abort) drop here, on the session
            // thread, before SessionJob's Drop releases the caller.
            drop(it);
        });
        // SAFETY: every borrow erased here outlives this call frame, the
        // session thread drops the payload (executed or not) strictly
        // before signalling `done` (SessionJob's Drop order), and this
        // frame cannot return — even unwinding — before `DoneGuard`
        // receives that signal. No erased borrow is ever reachable after
        // this function returns.
        let body: Box<dyn FnOnce() + Send> = unsafe {
            std::mem::transmute::<Box<dyn FnOnce() + Send + '_>, Box<dyn FnOnce() + Send>>(body)
        };
        let job = SessionJob {
            payload: Some(body),
            done: Some(done_tx),
        };
        if let Err(send_failed) = handle.tx.send(job) {
            // Session thread gone (cannot happen while the session is
            // alive; defensive). The returned job drops here, on the
            // caller — erased borrows are still valid — then we report a
            // retryable fault rather than running a half-consumed plan.
            drop(send_failed);
            self.fallbacks.fetch_add(1, Ordering::Relaxed);
            return Err(BackendError::transient_failure(
                "overlap session worker unavailable",
            ));
        }
        self.rounds.fetch_add(1, Ordering::Relaxed);
        let mut out = Vec::with_capacity(expected);
        let mut failure: Option<BackendError> = None;
        for packed in rx_packed.iter() {
            let ran = packed.and_then(|p| catch_panic(|| execute(p)).and_then(|r| r));
            match ran {
                Ok(r) => out.push(r),
                Err(e) => {
                    failure = Some(e);
                    break;
                }
            }
        }
        drop(rx_packed);
        match failure {
            None => Ok(out),
            Some(e) => Err(e),
        }
    }
}

impl Drop for OverlapSession {
    fn drop(&mut self) {
        if let Some(Some(handle)) = self.inner.take() {
            // Closing the job channel ends the worker loop; join so no
            // detached thread outlives the session.
            drop(handle.tx);
            let _ = handle.worker.join();
        }
    }
}

/// Scope marker returned by [`OverlapSession::epoch`]; see there.
pub struct OverlapEpoch<'a> {
    _session: &'a OverlapSession,
}

/// One KDE query in flight.
pub struct QueryRequest {
    /// Target shard index.
    pub shard: usize,
    /// The query point (must match the shard's `dim()`).
    pub point: Vec<f32>,
    /// Per-request reply channel: the answer or a typed error.
    pub respond: SyncSender<Result<f64, BackendError>>,
    /// When the request was admitted (end-to-end latency accounting).
    pub enqueued_at: Instant,
    /// Optional deadline: once passed, the request is dropped from the
    /// batch plan and answered with [`BackendError::Timeout`].
    pub deadline: Option<Instant>,
}

/// Router/worker-pool tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Max queries per dispatched batch (64 = AOT_B).
    pub max_batch: usize,
    /// Max time the oldest pending request waits before a flush.
    pub max_wait: Duration,
    /// Worker threads executing batches.
    pub workers: usize,
    /// Bound on the ingress channel AND each shard's pending queue.
    /// Admission past either bound is refused with
    /// [`BackendError::Overloaded`] (backpressure, not unbounded memory).
    pub queue_cap: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 64, // = AOT_B
            max_wait: Duration::from_micros(500),
            workers: 2,
            queue_cap: 1024,
        }
    }
}

enum Control {
    Request(QueryRequest),
    Shutdown,
}

/// Handle to a running KDE query service.
pub struct KdeService {
    ingress: SyncSender<Control>,
    router: Option<std::thread::JoinHandle<()>>,
    /// Shared service metrics (counters + latency percentiles).
    pub metrics: Arc<ServiceMetrics>,
    shards_len: usize,
}

impl KdeService {
    /// Spawn the router + workers over exact-scan shards: each `(kernel,
    /// dataset)` pair is served through a `NaiveKde` oracle over the
    /// shared backend.
    pub fn start(
        shards: Vec<(Kernel, Arc<Dataset>)>,
        backend: Arc<dyn KernelBackend>,
        cfg: BatcherConfig,
    ) -> Self {
        let counters = KdeCounters::new();
        let oracles: Vec<Arc<dyn Kde>> = shards
            .into_iter()
            .map(|(kernel, data)| {
                let n = data.n;
                Arc::new(NaiveKde::new(
                    data,
                    kernel,
                    0,
                    n,
                    backend.clone(),
                    counters.clone(),
                )) as Arc<dyn Kde>
            })
            .collect();
        Self::start_with_oracles(oracles, cfg)
    }

    /// Spawn the router + workers over arbitrary KDE oracles (estimators,
    /// tree nodes, ...): worker flushes call `query_batch` on the shard.
    pub fn start_with_oracles(shards: Vec<Arc<dyn Kde>>, cfg: BatcherConfig) -> Self {
        assert!(!shards.is_empty());
        let metrics = Arc::new(ServiceMetrics::new());
        let shards_len = shards.len();
        let (tx, rx) = mpsc::sync_channel::<Control>(cfg.queue_cap.max(1));
        let m = metrics.clone();
        let router = std::thread::spawn(move || {
            run_router(rx, shards, cfg, m);
        });
        KdeService { ingress: tx, router: Some(router), metrics, shards_len }
    }

    /// Fallible async submit: returns a receiver for the typed reply, or
    /// [`BackendError::UnknownShard`] / [`BackendError::Overloaded`] /
    /// a permanent error if the service has stopped.
    pub fn try_submit(
        &self,
        shard: usize,
        point: Vec<f32>,
    ) -> Result<Receiver<Result<f64, BackendError>>, BackendError> {
        self.enqueue(shard, point, None)
    }

    /// [`try_submit`](Self::try_submit) with a deadline `timeout` from
    /// now: if the request is still waiting (in the pending queue or a
    /// worker's inbox) when the deadline passes, it is dropped from the
    /// batch plan and answered with [`BackendError::Timeout`].
    pub fn try_submit_deadline(
        &self,
        shard: usize,
        point: Vec<f32>,
        timeout: Duration,
    ) -> Result<Receiver<Result<f64, BackendError>>, BackendError> {
        self.enqueue(shard, point, Some(Instant::now() + timeout))
    }

    fn enqueue(
        &self,
        shard: usize,
        point: Vec<f32>,
        deadline: Option<Instant>,
    ) -> Result<Receiver<Result<f64, BackendError>>, BackendError> {
        if shard >= self.shards_len {
            return Err(BackendError::UnknownShard { shard, shards: self.shards_len });
        }
        let (tx, rx) = mpsc::sync_channel(1);
        let req = QueryRequest {
            shard,
            point,
            respond: tx,
            enqueued_at: Instant::now(),
            deadline,
        };
        match self.ingress.try_send(Control::Request(req)) {
            Ok(()) => {
                self.metrics.enqueued.fetch_add(1, Ordering::Relaxed);
                Ok(rx)
            }
            Err(mpsc::TrySendError::Full(_)) => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(BackendError::Overloaded)
            }
            Err(mpsc::TrySendError::Disconnected(_)) => {
                Err(BackendError::permanent_failure("service stopped"))
            }
        }
    }

    /// Async submit: returns a receiver for the typed reply. Panics where
    /// [`try_submit`](Self::try_submit) would return an error.
    pub fn submit(&self, shard: usize, point: Vec<f32>) -> Receiver<Result<f64, BackendError>> {
        match self.try_submit(shard, point) {
            Ok(rx) => rx,
            Err(e) => panic!("KDE service submit failed: {e}"),
        }
    }

    /// Fallible blocking query: the answer, or the typed error the
    /// service replied with. A dropped reply channel (a worker dying
    /// between respawns) surfaces as [`BackendError::Panicked`], never a
    /// panic or a hang.
    pub fn try_query(&self, shard: usize, point: Vec<f32>) -> Result<f64, BackendError> {
        let rx = self.try_submit(shard, point)?;
        match rx.recv() {
            Ok(reply) => reply,
            Err(_) => Err(BackendError::Panicked {
                message: "service dropped request (worker died before replying)".to_string(),
            }),
        }
    }

    /// Fallible blocking query with a deadline: combines
    /// [`try_submit_deadline`](Self::try_submit_deadline) with a
    /// client-side wait bounded at `timeout` plus a generous grace period
    /// (the service answers expired requests with `Timeout` itself; the
    /// client-side bound is a belt-and-braces guarantee against hangs).
    pub fn try_query_deadline(
        &self,
        shard: usize,
        point: Vec<f32>,
        timeout: Duration,
    ) -> Result<f64, BackendError> {
        let rx = self.try_submit_deadline(shard, point, timeout)?;
        match rx.recv_timeout(timeout.saturating_add(Duration::from_secs(30))) {
            Ok(reply) => reply,
            Err(mpsc::RecvTimeoutError::Timeout) => Err(BackendError::Timeout),
            Err(mpsc::RecvTimeoutError::Disconnected) => Err(BackendError::Panicked {
                message: "service dropped request (worker died before replying)".to_string(),
            }),
        }
    }

    /// Fallible batch query: submits every point, then collects every
    /// reply. The first error (submission or reply) is returned.
    pub fn try_query_batch(
        &self,
        shard: usize,
        points: &[Vec<f32>],
    ) -> Result<Vec<f64>, BackendError> {
        let mut rxs = Vec::with_capacity(points.len());
        for p in points {
            rxs.push(self.try_submit(shard, p.clone())?);
        }
        let mut out = Vec::with_capacity(rxs.len());
        for rx in rxs {
            match rx.recv() {
                Ok(reply) => out.push(reply?),
                Err(_) => {
                    return Err(BackendError::Panicked {
                        message: "service dropped request (worker died before replying)"
                            .to_string(),
                    })
                }
            }
        }
        Ok(out)
    }

    /// Blocking query. Panics where [`try_query`](Self::try_query) would
    /// return an error.
    pub fn query(&self, shard: usize, point: Vec<f32>) -> f64 {
        match self.try_query(shard, point) {
            Ok(v) => v,
            Err(e) => panic!("KDE service query failed: {e}"),
        }
    }

    /// Blocking batch query. Panics where
    /// [`try_query_batch`](Self::try_query_batch) would return an error.
    pub fn query_batch(&self, shard: usize, points: &[Vec<f32>]) -> Vec<f64> {
        match self.try_query_batch(shard, points) {
            Ok(v) => v,
            Err(e) => panic!("KDE service batch query failed: {e}"),
        }
    }

    /// Stop the router and workers; pending admitted requests are flushed
    /// first.
    pub fn shutdown(mut self) {
        let _ = self.ingress.send(Control::Shutdown);
        if let Some(h) = self.router.take() {
            let _ = h.join();
        }
    }
}

impl Drop for KdeService {
    fn drop(&mut self) {
        let _ = self.ingress.send(Control::Shutdown);
        if let Some(h) = self.router.take() {
            let _ = h.join();
        }
    }
}

type SharedBatchRx = Arc<Mutex<Receiver<Vec<QueryRequest>>>>;

/// Spawn one batch-executing worker over the shared batch channel. The
/// worker loop is panic-isolated per batch (`execute_batch` catches the
/// oracle's panic and replies typed errors), so a worker death is
/// exceptional — the router still watches for it and respawns.
fn spawn_worker(
    batch_rx: &SharedBatchRx,
    shards: &Arc<Vec<Arc<dyn Kde>>>,
    metrics: &Arc<ServiceMetrics>,
    stop: &Arc<AtomicBool>,
) -> std::thread::JoinHandle<()> {
    let rx = batch_rx.clone();
    let sh = shards.clone();
    let m = metrics.clone();
    let stop_flag = stop.clone();
    std::thread::spawn(move || loop {
        let batch = {
            // A poisoned lock means a sibling worker panicked while
            // *holding the receiver* (between recv and unlock); the
            // channel itself is still consistent — recover and serve.
            let guard = rx.lock().unwrap_or_else(PoisonError::into_inner);
            match guard.recv_timeout(Duration::from_millis(20)) {
                Ok(b) => b,
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    if stop_flag.load(Ordering::Relaxed) {
                        return;
                    }
                    continue;
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => return,
            }
        };
        execute_batch(batch, sh.as_slice(), &m);
    })
}

fn run_router(
    rx: Receiver<Control>,
    shards: Vec<Arc<dyn Kde>>,
    cfg: BatcherConfig,
    metrics: Arc<ServiceMetrics>,
) {
    let shards = Arc::new(shards);
    // Worker pool: batches travel over a crossbeam-free mpsc + mutex'd rx.
    let (batch_tx, batch_rx) = mpsc::channel::<Vec<QueryRequest>>();
    let batch_rx: SharedBatchRx = Arc::new(Mutex::new(batch_rx));
    let stop = Arc::new(AtomicBool::new(false));
    let mut workers = Vec::new();
    for _ in 0..cfg.workers.max(1) {
        workers.push(spawn_worker(&batch_rx, &shards, &metrics, &stop));
    }

    // Pending per-shard queues. `pending_since[s]` is when the oldest
    // *currently pending* request entered the pending queue (NOT its
    // client enqueue time: while workers are busy, requests age in the
    // ingress channel, and flushing on client-side age would degrade every
    // flush to a single-request batch under backlog — the bug the
    // `batching actually batches` tests pin down).
    let mut pending: Vec<Vec<QueryRequest>> = (0..shards.len()).map(|_| Vec::new()).collect();
    let mut pending_since: Vec<Option<Instant>> = vec![None; shards.len()];
    let queue_cap = cfg.queue_cap.max(1);
    let mut running = true;
    while running {
        // Wait for at least one request (or shutdown), with a deadline if
        // something is pending.
        let timeout = if pending.iter().any(|q| !q.is_empty()) {
            cfg.max_wait
        } else {
            Duration::from_millis(50)
        };
        let mut absorb = |ctl: Control,
                          pending: &mut Vec<Vec<QueryRequest>>,
                          pending_since: &mut Vec<Option<Instant>>,
                          running: &mut bool| {
            match ctl {
                Control::Request(req) => {
                    let s = req.shard;
                    // The bounded ingress channel throttles the client
                    // side; this bounds the router's own buffer so a slow
                    // worker pool cannot grow pending without limit
                    // either. Past the cap, the request is answered
                    // `Overloaded` instead of queued.
                    if pending[s].len() >= queue_cap {
                        metrics.rejected.fetch_add(1, Ordering::Relaxed);
                        let _ = req.respond.send(Err(BackendError::Overloaded));
                        return;
                    }
                    if pending_since[s].is_none() {
                        pending_since[s] = Some(Instant::now());
                    }
                    pending[s].push(req);
                }
                Control::Shutdown => *running = false,
            }
        };
        match rx.recv_timeout(timeout) {
            Ok(ctl) => absorb(ctl, &mut pending, &mut pending_since, &mut running),
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => running = false,
        }
        // Greedily drain everything already waiting in the ingress channel
        // so a backlog becomes one large batch, not many singletons.
        while let Ok(ctl) = rx.try_recv() {
            absorb(ctl, &mut pending, &mut pending_since, &mut running);
        }
        // Flush policy: size or pending-age. Requests whose deadline
        // already passed are answered `Timeout` here instead of occupying
        // batch slots (workers re-check at execution time for requests
        // that expire later, while queued behind a slow batch).
        for s in 0..pending.len() {
            let flush = pending[s].len() >= cfg.max_batch
                || (!pending[s].is_empty()
                    && pending_since[s]
                        .map(|t| t.elapsed() >= cfg.max_wait)
                        .unwrap_or(false));
            if flush {
                let take = pending[s].len().min(cfg.max_batch);
                let drained: Vec<QueryRequest> = pending[s].drain(..take).collect();
                pending_since[s] = if pending[s].is_empty() {
                    None
                } else {
                    Some(Instant::now())
                };
                let now = Instant::now();
                let mut batch = Vec::with_capacity(drained.len());
                for req in drained {
                    if req.deadline.is_some_and(|dl| dl <= now) {
                        metrics.timeouts.fetch_add(1, Ordering::Relaxed);
                        let _ = req.respond.send(Err(BackendError::Timeout));
                    } else {
                        batch.push(req);
                    }
                }
                if !batch.is_empty() {
                    metrics.record_batch(batch.len());
                    let _ = batch_tx.send(batch);
                }
            }
        }
        // Respawn any worker that died despite per-batch isolation, so
        // the pool never silently shrinks to zero.
        for w in workers.iter_mut() {
            if w.is_finished() {
                let old = std::mem::replace(w, spawn_worker(&batch_rx, &shards, &metrics, &stop));
                if old.join().is_err() {
                    metrics.worker_panics.fetch_add(1, Ordering::Relaxed);
                }
                metrics.worker_respawns.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
    // Drain everything left, then stop workers (execute_batch re-checks
    // deadlines, so late requests still get Timeout over an answer).
    for s in 0..pending.len() {
        while !pending[s].is_empty() {
            let take = pending[s].len().min(cfg.max_batch);
            let batch: Vec<QueryRequest> = pending[s].drain(..take).collect();
            metrics.record_batch(batch.len());
            let _ = batch_tx.send(batch);
        }
    }
    drop(batch_tx);
    stop.store(true, Ordering::Relaxed);
    for w in workers {
        let _ = w.join();
    }
}

fn execute_batch(batch: Vec<QueryRequest>, shards: &[Arc<dyn Kde>], metrics: &ServiceMetrics) {
    if batch.is_empty() {
        return;
    }
    // Deadline re-check at execution time: a batch can age in the worker
    // queue behind a slow predecessor, and an expired request must get
    // `Timeout`, not a late answer.
    let now = Instant::now();
    let mut live = Vec::with_capacity(batch.len());
    for req in batch {
        if req.deadline.is_some_and(|dl| dl <= now) {
            metrics.timeouts.fetch_add(1, Ordering::Relaxed);
            let _ = req.respond.send(Err(BackendError::Timeout));
        } else {
            live.push(req);
        }
    }
    let Some(first) = live.first() else {
        return;
    };
    let shard = &shards[first.shard];
    let d = shard.dim();
    let mut queries = Vec::with_capacity(live.len() * d);
    let mut runnable = Vec::with_capacity(live.len());
    for req in live {
        if req.point.len() == d {
            queries.extend_from_slice(&req.point);
            runnable.push(req);
        } else {
            metrics.error_replies.fetch_add(1, Ordering::Relaxed);
            let _ = req.respond.send(Err(BackendError::permanent_failure(format!(
                "query dim {} does not match shard dim {d}",
                req.point.len()
            ))));
        }
    }
    if runnable.is_empty() {
        return;
    }
    match catch_panic(|| shard.query_batch(&queries)) {
        Ok(sums) if sums.len() == runnable.len() => {
            for (req, &ans) in runnable.iter().zip(&sums) {
                // Record BEFORE responding: once `send` lands the client
                // may check the completed counter, and recording after
                // would race it.
                metrics.record_latency_us(req.enqueued_at.elapsed().as_micros() as f64);
                let _ = req.respond.send(Ok(ans));
            }
        }
        Ok(sums) => {
            let err = BackendError::permanent_failure(format!(
                "oracle returned {} answers for {} queries",
                sums.len(),
                runnable.len()
            ));
            for req in &runnable {
                metrics.error_replies.fetch_add(1, Ordering::Relaxed);
                let _ = req.respond.send(Err(err.clone()));
            }
        }
        Err(e) => {
            // Panic isolation boundary: the worker thread survives and
            // every in-flight client of this batch gets a typed reply
            // instead of a dropped channel.
            metrics.worker_panics.fetch_add(1, Ordering::Relaxed);
            for req in &runnable {
                metrics.error_replies.fetch_add(1, Ordering::Relaxed);
                let _ = req.respond.send(Err(e.clone()));
            }
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::kernel::dataset::gaussian_mixture;
    use crate::runtime::backend::CpuBackend;
    use crate::util::rng::Rng;

    fn service(n: usize, cfg: BatcherConfig) -> (KdeService, Arc<Dataset>) {
        let mut rng = Rng::new(261);
        let ds = Arc::new(gaussian_mixture(n, 4, 2, 1.0, 0.5, &mut rng));
        let svc = KdeService::start(
            vec![(Kernel::Laplacian, ds.clone())],
            CpuBackend::new(),
            cfg,
        );
        (svc, ds)
    }

    fn exact(ds: &Dataset, y: &[f32]) -> f64 {
        (0..ds.n)
            .map(|j| Kernel::Laplacian.eval(ds.point(j), y) as f64)
            .sum()
    }

    #[test]
    fn single_query_matches_naive() {
        let (svc, ds) = service(64, BatcherConfig::default());
        let y = ds.point(5).to_vec();
        let got = svc.query(0, y.clone());
        let want = exact(&ds, &y);
        assert!((got - want).abs() < 1e-6 * (1.0 + want));
        svc.shutdown();
    }

    #[test]
    fn no_request_dropped_or_misrouted_under_load() {
        let (svc, ds) = service(48, BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_micros(200),
            workers: 3,
            ..BatcherConfig::default()
        });
        let mut rxs = Vec::new();
        for i in 0..200 {
            let y = ds.point(i % 48).to_vec();
            rxs.push((i % 48, svc.submit(0, y)));
        }
        for (idx, rx) in rxs {
            let got = rx
                .recv_timeout(Duration::from_secs(10))
                .expect("dropped")
                .expect("error reply");
            let want = exact(&ds, ds.point(idx));
            assert!(
                (got - want).abs() < 1e-6 * (1.0 + want),
                "request for point {idx} got wrong answer"
            );
        }
        assert_eq!(
            svc.metrics.completed.load(Ordering::Relaxed),
            200,
            "all requests completed"
        );
        svc.shutdown();
    }

    #[test]
    fn batching_actually_batches() {
        let (svc, ds) = service(32, BatcherConfig {
            max_batch: 16,
            max_wait: Duration::from_millis(20),
            workers: 1,
            ..BatcherConfig::default()
        });
        let mut rxs = Vec::new();
        for i in 0..64 {
            rxs.push(svc.submit(0, ds.point(i % 32).to_vec()));
        }
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
        }
        let occ = svc.metrics.mean_batch_occupancy();
        assert!(occ > 2.0, "mean occupancy {occ} — batcher not batching");
        svc.shutdown();
    }

    #[test]
    fn multi_shard_routing() {
        let mut rng = Rng::new(263);
        let ds1 = Arc::new(gaussian_mixture(16, 3, 1, 0.0, 0.3, &mut rng));
        let ds2 = Arc::new(gaussian_mixture(40, 3, 1, 5.0, 0.3, &mut rng));
        let svc = KdeService::start(
            vec![
                (Kernel::Gaussian, ds1.clone()),
                (Kernel::Gaussian, ds2.clone()),
            ],
            CpuBackend::new(),
            BatcherConfig::default(),
        );
        let y = ds1.point(0).to_vec();
        let a = svc.query(0, y.clone());
        let b = svc.query(1, y.clone());
        let want1: f64 = (0..16)
            .map(|j| Kernel::Gaussian.eval(ds1.point(j), &y) as f64)
            .sum();
        let want2: f64 = (0..40)
            .map(|j| Kernel::Gaussian.eval(ds2.point(j), &y) as f64)
            .sum();
        assert!((a - want1).abs() < 1e-6 * (1.0 + want1));
        assert!((b - want2).abs() < 1e-6 * (1.0 + want2));
        svc.shutdown();
    }

    #[test]
    fn oracle_shards_serve_estimators() {
        // start_with_oracles: shards are arbitrary Kde oracles — here a
        // NaiveKde over a subrange, i.e. a multi-level-tree node.
        let mut rng = Rng::new(265);
        let ds = Arc::new(gaussian_mixture(80, 4, 2, 1.0, 0.5, &mut rng));
        let counters = crate::kde::KdeCounters::new();
        let oracle: Arc<dyn crate::kde::Kde> = Arc::new(crate::kde::estimators::NaiveKde::new(
            ds.clone(),
            Kernel::Laplacian,
            10,
            60,
            CpuBackend::new(),
            counters,
        ));
        let svc = KdeService::start_with_oracles(vec![oracle], BatcherConfig::default());
        let y = ds.point(2).to_vec();
        let got = svc.query(0, y.clone());
        let want: f64 = (10..60)
            .map(|j| Kernel::Laplacian.eval(ds.point(j), &y) as f64)
            .sum();
        assert!((got - want).abs() < 1e-6 * (1.0 + want), "{got} vs {want}");
        svc.shutdown();
    }

    #[test]
    fn unknown_shard_is_typed_error() {
        let (svc, _) = service(8, BatcherConfig::default());
        match svc.try_submit(3, vec![0.0; 4]) {
            Err(BackendError::UnknownShard { shard: 3, shards: 1 }) => {}
            Err(e) => panic!("want UnknownShard, got {e:?}"),
            Ok(_) => panic!("unknown shard must be rejected"),
        }
        match svc.try_query(9, vec![0.0; 4]) {
            Err(BackendError::UnknownShard { shard: 9, shards: 1 }) => {}
            other => panic!("want UnknownShard, got {other:?}"),
        }
        svc.shutdown();
    }

    #[test]
    fn expired_deadline_gets_timeout_reply() {
        let (svc, ds) = service(16, BatcherConfig::default());
        // A zero deadline is already expired when the router flushes it.
        for i in 0..8 {
            let got =
                svc.try_query_deadline(0, ds.point(i).to_vec(), Duration::ZERO);
            assert_eq!(got, Err(BackendError::Timeout), "request {i}");
        }
        assert!(svc.metrics.timeouts.load(Ordering::Relaxed) >= 8);
        // The service keeps serving normal requests afterwards.
        let y = ds.point(0).to_vec();
        let got = svc.try_query(0, y.clone()).expect("service still healthy");
        let want = exact(&ds, &y);
        assert!((got - want).abs() < 1e-6 * (1.0 + want));
        svc.shutdown();
    }

    /// A Kde oracle that panics on every batch — the chaos stand-in for a
    /// shard whose backend blows up at execution time.
    struct PanickingKde {
        dim: usize,
    }

    impl Kde for PanickingKde {
        fn query(&self, _y: &[f32]) -> f64 {
            panic!("oracle exploded")
        }
        fn query_batch(&self, _ys: &[f32]) -> Vec<f64> {
            panic!("oracle exploded")
        }
        fn subset_len(&self) -> usize {
            1
        }
        fn dim(&self) -> usize {
            self.dim
        }
    }

    #[test]
    fn worker_panic_becomes_typed_reply_and_service_survives() {
        let mut rng = Rng::new(267);
        let ds = Arc::new(gaussian_mixture(24, 3, 2, 1.0, 0.5, &mut rng));
        let counters = crate::kde::KdeCounters::new();
        let healthy: Arc<dyn Kde> = Arc::new(NaiveKde::new(
            ds.clone(),
            Kernel::Laplacian,
            0,
            24,
            CpuBackend::new(),
            counters,
        ));
        let broken: Arc<dyn Kde> = Arc::new(PanickingKde { dim: 3 });
        let svc =
            KdeService::start_with_oracles(vec![healthy, broken], BatcherConfig::default());
        // Batches on the broken shard reply with Panicked — no hang, no
        // process abort.
        for _ in 0..3 {
            match svc.try_query(1, vec![0.0; 3]) {
                Err(BackendError::Panicked { message }) => {
                    assert!(message.contains("oracle exploded"), "got: {message}")
                }
                other => panic!("want Panicked, got {other:?}"),
            }
        }
        assert!(svc.metrics.worker_panics.load(Ordering::Relaxed) >= 3);
        // The healthy shard still answers on the same worker pool.
        let y = ds.point(1).to_vec();
        let got = svc.try_query(0, y.clone()).expect("healthy shard serves");
        let want: f64 = (0..24)
            .map(|j| Kernel::Laplacian.eval(ds.point(j), &y) as f64)
            .sum();
        assert!((got - want).abs() < 1e-6 * (1.0 + want));
        svc.shutdown();
    }

    fn job(rows: usize, seg_rows: usize) -> FuseJob {
        FuseJob { rows, seg_rows }
    }

    /// Planner invariants: every (job, row) appears exactly once, rows
    /// never split, every submission packs each of its rows' segments
    /// exactly once, and the row/data caps hold (single oversize segment
    /// excepted).
    fn check_plan(jobs: &[FuseJob], max_rows: usize, max_data: usize) -> Vec<FuseSubmission> {
        let plan = plan_level_fusion(jobs, max_rows, max_data);
        verify_plan(plan, jobs, max_rows, max_data)
    }

    /// Same invariants for the adaptive (segment-size-sorted) planner.
    fn check_plan_adaptive(
        jobs: &[FuseJob],
        max_rows: usize,
        max_data: usize,
    ) -> Vec<FuseSubmission> {
        let plan = plan_level_fusion_adaptive(jobs, max_rows, max_data);
        verify_plan(plan, jobs, max_rows, max_data)
    }

    fn verify_plan(
        plan: Vec<FuseSubmission>,
        jobs: &[FuseJob],
        max_rows: usize,
        max_data: usize,
    ) -> Vec<FuseSubmission> {
        let mut seen = std::collections::HashSet::new();
        for sub in &plan {
            assert!(!sub.rows.is_empty());
            assert!(sub.rows.len() <= max_rows);
            let data: usize = sub.segments.iter().map(|&j| jobs[j].seg_rows).sum();
            assert!(
                data <= max_data || sub.segments.len() == 1,
                "data {data} over budget with {} segments",
                sub.segments.len()
            );
            let mut uniq = sub.segments.clone();
            uniq.dedup();
            uniq.sort_unstable();
            uniq.dedup();
            assert_eq!(uniq.len(), sub.segments.len(), "duplicate segment in pack");
            for &(j, r) in &sub.rows {
                assert!(r < jobs[j].rows);
                assert!(sub.segments.contains(&j), "row without its segment");
                assert!(seen.insert((j, r)), "row ({j}, {r}) planned twice");
            }
        }
        let total: usize = jobs.iter().map(|j| j.rows).sum();
        assert_eq!(seen.len(), total, "rows dropped by the plan");
        plan
    }

    #[test]
    fn fusion_planner_single_small_job_is_one_submission() {
        let plan = check_plan(&[job(5, 100)], 64, 1024);
        assert_eq!(plan.len(), 1);
        assert_eq!(plan[0].segments, vec![0]);
    }

    #[test]
    fn fusion_planner_splits_rows_at_max_and_repacks_segment() {
        // 130 rows at B=64 -> 64 + 64 + 2, each carrying the segment.
        let plan = check_plan(&[job(130, 100)], 64, 1024);
        assert_eq!(plan.len(), 3);
        assert_eq!(plan[0].rows.len(), 64);
        assert_eq!(plan[1].rows.len(), 64);
        assert_eq!(plan[2].rows.len(), 2);
        for sub in &plan {
            assert_eq!(sub.segments, vec![0], "split rows re-pack the segment");
        }
    }

    #[test]
    fn fusion_planner_packs_many_small_segments_per_submission() {
        // 16 nodes x 2 rows x 128-row segments: 8 segments fit the M=1024
        // data budget, 32 rows fit the B=64 row budget -> 2 submissions.
        let jobs: Vec<FuseJob> = (0..16).map(|_| job(2, 128)).collect();
        let plan = check_plan(&jobs, 64, 1024);
        assert_eq!(plan.len(), 2);
        assert_eq!(plan[0].segments.len(), 8);
        assert_eq!(plan[1].segments.len(), 8);
    }

    #[test]
    fn fusion_planner_oversize_segment_goes_alone() {
        let plan = check_plan(&[job(3, 5000), job(2, 100)], 64, 1024);
        assert_eq!(plan.len(), 2);
        assert_eq!(plan[0].segments, vec![0], "oversize segment isolated");
        assert_eq!(plan[1].segments, vec![1]);
    }

    #[test]
    fn fusion_planner_skips_empty_jobs_and_empty_input() {
        assert!(plan_level_fusion(&[], 64, 1024).is_empty());
        let plan = check_plan(&[job(0, 50), job(1, 50), job(0, 9)], 64, 1024);
        assert_eq!(plan.len(), 1);
        assert_eq!(plan[0].rows, vec![(1, 0)]);
        assert_eq!(plan[0].segments, vec![1]);
    }

    #[test]
    fn fusion_planner_ragged_property() {
        // Random ragged job mixes keep all invariants.
        crate::util::prop::forall(12, |rng, _| {
            let jobs: Vec<FuseJob> = (0..1 + rng.below(20))
                .map(|_| job(rng.below(100), 1 + rng.below(2000)))
                .collect();
            check_plan(&jobs, 64, 1024);
        });
    }

    #[test]
    fn adaptive_planner_packs_mixed_level_jobs_tighter() {
        // A frontier-walk shape: small deep-level segments interleaved
        // with large shallow-level ones. In-order greedy closes a
        // submission at nearly every large/small boundary; the adaptive
        // planner clusters the small segments into shared submissions.
        let jobs: Vec<FuseJob> = vec![
            job(2, 1000),
            job(2, 30),
            job(2, 1000),
            job(2, 30),
            job(2, 1000),
            job(2, 30),
            job(2, 1000),
            job(2, 30),
        ];
        // In-order: 1000 + 30 > 1024 closes at every boundary -> 8 subs.
        let in_order = check_plan(&jobs, 64, 1024);
        assert_eq!(in_order.len(), 8);
        // Adaptive: the four 1000-row segments go alone, the four 30-row
        // segments share one submission.
        let adaptive = check_plan_adaptive(&jobs, 64, 1024);
        assert_eq!(adaptive.len(), 5);
    }

    #[test]
    fn adaptive_planner_tiny_walker_regime_is_one_submission() {
        // Per-level row counts far below B across many levels: everything
        // fits one padded submission when the data budget allows.
        let jobs: Vec<FuseJob> = (0..10).map(|l| job(2, 1 << (9 - l).min(6))).collect();
        let plan = check_plan_adaptive(&jobs, 64, 1024);
        assert_eq!(plan.len(), 1, "tiny mixed-level frontier packs into one");
        assert_eq!(plan[0].rows.len(), 20);
    }

    #[test]
    fn adaptive_planner_ragged_property() {
        // Random ragged job mixes keep every invariant under the sorted
        // admission order too (rows never lost/split, caps hold).
        crate::util::prop::forall(12, |rng, _| {
            let jobs: Vec<FuseJob> = (0..1 + rng.below(20))
                .map(|_| job(rng.below(100), 1 + rng.below(2000)))
                .collect();
            check_plan_adaptive(&jobs, 64, 1024);
        });
    }

    #[test]
    fn double_buffered_queue_preserves_order_and_values() {
        // Overlapped and sequential runs must produce the same results in
        // the same order; the executor must observe plan order even
        // though packing runs ahead on another thread.
        let items: Vec<usize> = (0..57).collect();
        let run = |overlap: bool| {
            let mut seen = Vec::new();
            let out = run_double_buffered(
                items.clone(),
                overlap,
                |t| t * 10 + 1,
                |p| {
                    seen.push(p);
                    p + 1
                },
            );
            (out, seen)
        };
        let (seq_out, seq_seen) = run(false);
        let (ovl_out, ovl_seen) = run(true);
        assert_eq!(seq_out, ovl_out);
        assert_eq!(seq_seen, ovl_seen);
        assert_eq!(ovl_out, (0..57).map(|t| t * 10 + 2).collect::<Vec<_>>());
    }

    #[test]
    fn double_buffered_queue_edge_sizes() {
        // Empty and single-item inputs take the inline path either way.
        for overlap in [false, true] {
            let empty: Vec<u64> = Vec::new();
            assert!(run_double_buffered(empty, overlap, |t| t, |p: u64| p).is_empty());
            let one = run_double_buffered(vec![41u64], overlap, |t| t + 1, |p| p);
            assert_eq!(one, vec![42]);
        }
    }

    #[test]
    fn double_buffered_queue_executes_on_calling_thread() {
        // The executor closure mutates caller-local state without any
        // synchronization — only sound because execute runs inline on the
        // calling thread (the contract MultiLevelKde's cache commits and
        // resolution maps rely on).
        let caller = std::thread::current().id();
        let mut executed_on = Vec::new();
        let _ = run_double_buffered(
            (0..8).collect::<Vec<usize>>(),
            true,
            |t| t,
            |p| {
                executed_on.push(std::thread::current().id());
                p
            },
        );
        assert!(executed_on.iter().all(|&id| id == caller));
    }

    #[test]
    fn try_double_buffered_packer_panic_is_typed_and_does_not_hang() {
        for overlap in [false, true] {
            let got = try_run_double_buffered(
                (0..32).collect::<Vec<usize>>(),
                overlap,
                |t| {
                    if t == 3 {
                        panic!("pack exploded at {t}")
                    }
                    t
                },
                |p| Ok::<usize, BackendError>(p),
            );
            match got {
                Err(BackendError::Panicked { message }) => {
                    assert!(message.contains("pack exploded"), "got: {message}")
                }
                other => panic!("overlap={overlap}: want Panicked, got {other:?}"),
            }
        }
    }

    #[test]
    fn try_double_buffered_execute_error_aborts_cleanly() {
        for overlap in [false, true] {
            let mut executed = 0usize;
            let got = try_run_double_buffered(
                (0..32).collect::<Vec<usize>>(),
                overlap,
                |t| t,
                |p| {
                    if p == 5 {
                        return Err(BackendError::transient_failure("execute refused"));
                    }
                    executed += 1;
                    Ok(p)
                },
            );
            assert!(got.is_err(), "overlap={overlap}");
            assert_eq!(executed, 5, "execution stops at the first error");
        }
    }

    #[test]
    fn session_preserves_order_values_and_reuses_one_thread() {
        // The persistent session must behave exactly like the per-call
        // pipeline — same pack results, same execute order — while running
        // every round on ONE warm packer thread.
        let session = OverlapSession::new();
        assert!(!session.started(), "worker spawns lazily");
        for round in 0..20u64 {
            let items: Vec<usize> = (0..37).collect();
            let mut seen = Vec::new();
            let out = session
                .try_run(
                    items,
                    |t| t * 10 + 1,
                    |p| {
                        seen.push(p);
                        Ok::<usize, BackendError>(p + 1)
                    },
                )
                .unwrap();
            assert_eq!(out, (0..37).map(|t| t * 10 + 2).collect::<Vec<_>>());
            assert_eq!(seen, (0..37).map(|t| t * 10 + 1).collect::<Vec<_>>());
            assert_eq!(session.rounds(), round + 1, "every round on the session");
        }
        assert!(session.started());
        assert_eq!(session.fallbacks(), 0);
    }

    #[test]
    fn session_executes_on_calling_thread() {
        // Same contract as the per-call pipeline: execute runs inline on
        // the caller (MultiLevelKde's memo commits rely on it).
        let session = OverlapSession::new();
        let caller = std::thread::current().id();
        let mut executed_on = Vec::new();
        let mut packed_on = std::collections::HashSet::new();
        let packed_on_ref = std::sync::Mutex::new(&mut packed_on);
        session
            .try_run(
                (0..8).collect::<Vec<usize>>(),
                |t| {
                    packed_on_ref.lock().unwrap().insert(std::thread::current().id());
                    t
                },
                |p| {
                    executed_on.push(std::thread::current().id());
                    Ok::<usize, BackendError>(p)
                },
            )
            .unwrap();
        assert!(executed_on.iter().all(|&id| id == caller));
        assert!(
            !packed_on.contains(&caller),
            "multi-item rounds pack on the session thread"
        );
    }

    #[test]
    fn session_pack_panic_is_typed_and_session_survives() {
        let session = OverlapSession::new();
        let got = session.try_run(
            (0..32).collect::<Vec<usize>>(),
            |t| {
                if t == 3 {
                    panic!("pack exploded at {t}")
                }
                t
            },
            |p| Ok::<usize, BackendError>(p),
        );
        match got {
            Err(BackendError::Panicked { message }) => {
                assert!(message.contains("pack exploded"), "got: {message}")
            }
            other => panic!("want Panicked, got {other:?}"),
        }
        // The session thread must survive the panicking round.
        let out = session
            .try_run(
                (0..5).collect::<Vec<usize>>(),
                |t| t,
                |p| Ok::<usize, BackendError>(p),
            )
            .unwrap();
        assert_eq!(out, vec![0, 1, 2, 3, 4]);
        assert_eq!(session.rounds(), 2, "both rounds ran on the session");
    }

    #[test]
    fn session_execute_error_aborts_cleanly() {
        let session = OverlapSession::new();
        let mut executed = 0usize;
        let got = session.try_run(
            (0..32).collect::<Vec<usize>>(),
            |t| t,
            |p| {
                if p == 5 {
                    return Err(BackendError::transient_failure("execute refused"));
                }
                executed += 1;
                Ok(p)
            },
        );
        assert!(got.is_err());
        assert_eq!(executed, 5, "execution stops at the first error");
        // Next round is healthy.
        assert!(session
            .try_run((0..4).collect::<Vec<usize>>(), |t| t, |p| Ok::<
                usize,
                BackendError,
            >(p))
            .is_ok());
    }

    #[test]
    fn session_concurrent_rounds_fall_back_not_deadlock() {
        // Two threads sharing one session: whichever loses the try-lock
        // must run the per-call pipeline with identical results.
        let session = Arc::new(OverlapSession::new());
        let barrier = Arc::new(std::sync::Barrier::new(2));
        let mut handles = Vec::new();
        for _ in 0..2 {
            let s = Arc::clone(&session);
            let b = Arc::clone(&barrier);
            handles.push(std::thread::spawn(move || {
                b.wait();
                let mut outs = Vec::new();
                for _ in 0..50 {
                    let out = s
                        .try_run(
                            (0..9).collect::<Vec<usize>>(),
                            |t| t * 3,
                            |p| Ok::<usize, BackendError>(p + 1),
                        )
                        .unwrap();
                    outs.push(out);
                }
                outs
            }));
        }
        for h in handles {
            for out in h.join().unwrap() {
                assert_eq!(out, (0..9).map(|t| t * 3 + 1).collect::<Vec<_>>());
            }
        }
        assert_eq!(
            session.rounds() + session.fallbacks(),
            100,
            "every multi-item round accounted for"
        );
    }

    #[test]
    fn property_session_matches_per_call_pipeline_on_random_plans() {
        // Satellite property: random submission plans produce identical
        // pack outputs, execute order, and results whether they run on the
        // persistent session, the per-call overlapped pipeline, or the
        // sequential fallback — and single-item rounds stay inline.
        let session = OverlapSession::new();
        crate::util::prop::forall(24, |rng, _| {
            let len = rng.below(40);
            let items: Vec<u64> = (0..len).map(|_| rng.next_u64() >> 32).collect();
            let mul = 1 + rng.next_u64() % 1000;
            let run_session = {
                let mut seen = Vec::new();
                let out = session
                    .try_run(items.clone(), |t| t.wrapping_mul(mul), |p| {
                        seen.push(p);
                        Ok::<u64, BackendError>(p ^ 0xABCD)
                    })
                    .unwrap();
                (out, seen)
            };
            for overlap in [false, true] {
                let mut seen = Vec::new();
                let out = try_run_double_buffered(
                    items.clone(),
                    overlap,
                    |t| t.wrapping_mul(mul),
                    |p| {
                        seen.push(p);
                        Ok::<u64, BackendError>(p ^ 0xABCD)
                    },
                )
                .unwrap();
                assert_eq!(run_session.0, out, "overlap={overlap}");
                assert_eq!(run_session.1, seen, "overlap={overlap}");
            }
        });
    }

    #[test]
    fn property_random_loads_all_answered() {
        crate::util::prop::forall(6, |rng, _| {
            let n = 8 + rng.below(32);
            let mut r2 = Rng::new(rng.next_u64());
            let ds = Arc::new(gaussian_mixture(n, 3, 2, 1.0, 0.5, &mut r2));
            let svc = KdeService::start(
                vec![(Kernel::Laplacian, ds.clone())],
                CpuBackend::new(),
                BatcherConfig {
                    max_batch: 1 + rng.below(16),
                    max_wait: Duration::from_micros(100 + rng.below(500) as u64),
                    workers: 1 + rng.below(3),
                    ..BatcherConfig::default()
                },
            );
            let reqs = 1 + rng.below(60);
            let mut rxs = Vec::new();
            for i in 0..reqs {
                rxs.push((i % n, svc.submit(0, ds.point(i % n).to_vec())));
            }
            for (idx, rx) in rxs {
                let got = rx
                    .recv_timeout(Duration::from_secs(10))
                    .expect("dropped")
                    .expect("error reply");
                let want: f64 = (0..n)
                    .map(|j| Kernel::Laplacian.eval(ds.point(j), ds.point(idx)) as f64)
                    .sum();
                assert!((got - want).abs() < 1e-6 * (1.0 + want));
            }
            svc.shutdown();
        });
    }
}

// Model-check suite for the overlap-session handoff, run only by the
// loom CI leg (`RUSTFLAGS="--cfg loom" cargo test --release --lib loom_`).
// The two properties loom pins exhaustively are exactly the ones the
// SAFETY comment in `try_run` relies on: the erased payload drops on the
// session thread strictly BEFORE the caller is released, and a full
// epoch round-trip (spawn, pack handoff, execute, drop-join) can never
// deadlock or reorder under any interleaving.
#[cfg(all(loom, test))]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod loom_tests {
    use super::*;

    /// SessionJob's Drop-order contract: in every interleaving, by the
    /// time `done` is observable on the caller the payload (and every
    /// erased borrow inside it) has already been dropped on the worker.
    #[test]
    fn loom_session_job_drops_payload_before_done() {
        loom::model(|| {
            let dropped = Arc::new(AtomicBool::new(false));
            struct SetOnDrop(Arc<AtomicBool>);
            impl Drop for SetOnDrop {
                fn drop(&mut self) {
                    self.0.store(true, Ordering::Release);
                }
            }
            let guard = SetOnDrop(Arc::clone(&dropped));
            let (done_tx, done_rx) = mpsc::sync_channel::<()>(1);
            let job = SessionJob {
                payload: Some(Box::new(move || {
                    // `guard` drops when this closure is consumed.
                    let _hold = &guard;
                })),
                done: Some(done_tx),
            };
            let t = sync::thread::spawn(move || job.run());
            done_rx.recv().unwrap();
            assert!(
                dropped.load(Ordering::Acquire),
                "payload must drop before the done signal"
            );
            t.join().unwrap();
        });
    }

    /// Full epoch handoff: lazy worker spawn, pipelined pack/execute over
    /// the bounded channel, result order, and the Drop join — explored
    /// across every caller/worker interleaving.
    #[test]
    fn loom_session_epoch_handoff() {
        loom::model(|| {
            let session = OverlapSession::new();
            let data = [10u64, 20, 30];
            let out = {
                let _epoch = session.epoch();
                session
                    .try_run(
                        vec![0usize, 1, 2],
                        |i| data[i],
                        |p| Ok::<u64, BackendError>(p + 1),
                    )
                    .unwrap()
            };
            assert_eq!(out, vec![11, 21, 31]);
            assert_eq!(session.rounds(), 1);
            assert_eq!(session.fallbacks(), 0);
            // `session` drops here: the model also verifies the
            // close-channel + join shutdown cannot hang.
        });
    }
}
