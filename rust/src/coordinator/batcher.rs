//! Dynamic batcher + worker pool for KDE queries.
//!
//! One router thread drains the ingress queue, groups requests per shard,
//! and flushes a batch when it reaches `max_batch` or when the oldest
//! request exceeds `max_wait`. Worker threads execute batches through the
//! shard's `Kde::query_batch` (one oracle/backend dispatch per batch — the
//! AOT artifact's native shape) and deliver results to per-request
//! response channels.
//!
//! A shard is any `Arc<dyn Kde>` — a raw dataset served exactly (the
//! [`KdeService::start`] convenience wraps each `(kernel, dataset)` in a
//! `NaiveKde`), a sampling/HBE estimator, or a multi-level-tree node —
//! so the serving layer batches over the same oracle abstraction the
//! algorithms use.
//!
//! This module also hosts [`plan_level_fusion`], the static planner behind
//! the batched tree pipeline's level fusion: it packs the cache-miss query
//! groups of *several* tree nodes at one level into padded fused
//! submissions shaped like the AOT artifact (B = 64 query rows, M = 1024
//! packed data rows), which `MultiLevelKde::query_points_multi` then
//! executes through one `KernelBackend::sums_ranged` dispatch each.
//! [`plan_level_fusion_adaptive`] is its cross-level extension: identical
//! invariants, but segments are admitted largest-first so that groups from
//! *different tree levels* (the frontier-batched walk engine's shape, with
//! per-level row counts far below B) share padded submissions instead of
//! closing one at every level boundary.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, Sender, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::metrics::ServiceMetrics;
use crate::kde::estimators::NaiveKde;
use crate::kde::{Kde, KdeCounters};
use crate::kernel::{Dataset, Kernel};
use crate::runtime::backend::KernelBackend;

/// One fusable query group handed to [`plan_level_fusion`]: `rows`
/// cache-miss query rows that all attend to the same `seg_rows`-row data
/// segment (one tree node's data slice or sample buffer).
#[derive(Clone, Copy, Debug)]
pub struct FuseJob {
    /// Number of query rows in this group.
    pub rows: usize,
    /// Number of data rows in the group's segment.
    pub seg_rows: usize,
}

/// One planned fused submission: which job rows it carries and which jobs'
/// segments get packed (each segment once) into its shared data buffer.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FuseSubmission {
    /// `(job index, row index within that job)` in submission row order.
    pub rows: Vec<(usize, usize)>,
    /// Distinct job indices whose segments are packed, in pack order. A
    /// row's `(lo, hi)` data range is its job's segment offset within this
    /// pack.
    pub segments: Vec<usize>,
}

/// Pack one level's fusable query groups into fused submissions.
///
/// Greedy and deterministic: jobs are consumed in order; a submission is
/// closed when it reaches `max_rows` query rows, or when admitting a *new*
/// segment would push its packed data past `max_data_rows` (a single
/// segment larger than `max_data_rows` is still admitted alone — the
/// backend tiles internally). Rows never split across submissions, so a
/// fused row's sum keeps the exact accumulation order of an unfused
/// per-node dispatch; a job whose rows span several submissions has its
/// segment re-packed into each.
///
/// `max_rows` and `max_data_rows` are normally the AOT shapes
/// (`AOT_B` = 64, `AOT_M` = 1024), making the CPU backends' per-submission
/// `calls()` counter line up with the PJRT executions a real artifact run
/// would pay — the backend-uniform accounting the fusion tests assert on.
pub fn plan_level_fusion(
    jobs: &[FuseJob],
    max_rows: usize,
    max_data_rows: usize,
) -> Vec<FuseSubmission> {
    let order: Vec<usize> = (0..jobs.len()).collect();
    plan_greedy(jobs, &order, max_rows, max_data_rows)
}

/// Cross-level variant of [`plan_level_fusion`] — the adaptive planner the
/// frontier-batched walk engine runs on.
///
/// Same packing rules and invariants (rows never split, segments packed
/// once per submission, row/data caps, oversize-alone), but jobs are
/// admitted in order of **decreasing segment size** (ties by job index,
/// deterministic) instead of input order. When the jobs of one
/// `query_points_multi` call come from *several tree levels* — the
/// frontier walk engine's shape, where W < B walkers sit at different
/// depths of interleaved descents — input order alternates large
/// (shallow-node) and small (deep-node) segments, and the in-order greedy
/// closes a submission at nearly every boundary. Sorting clusters the
/// small deep-level segments so they share padded submissions: in the
/// tiny-walker regime (per-level row counts below B = 64) a whole mixed-
/// level frontier round packs into O(ceil(rows / B) + ceil(data / M))
/// submissions instead of one per level.
///
/// Values are unaffected by the ordering: every row accumulates its own
/// segment range with its own f64 accumulator, so fused answers stay
/// bit-identical to [`plan_level_fusion`]'s regardless of which rows
/// share a submission.
pub fn plan_level_fusion_adaptive(
    jobs: &[FuseJob],
    max_rows: usize,
    max_data_rows: usize,
) -> Vec<FuseSubmission> {
    let mut order: Vec<usize> = (0..jobs.len()).collect();
    order.sort_by_key(|&j| (std::cmp::Reverse(jobs[j].seg_rows), j));
    plan_greedy(jobs, &order, max_rows, max_data_rows)
}

/// Greedy packing core shared by the in-order and adaptive planners:
/// consume jobs in `order`, close a submission at `max_rows` query rows or
/// when admitting a new segment would exceed `max_data_rows` (an oversize
/// segment is still admitted alone — the backend tiles internally).
fn plan_greedy(
    jobs: &[FuseJob],
    order: &[usize],
    max_rows: usize,
    max_data_rows: usize,
) -> Vec<FuseSubmission> {
    assert!(max_rows >= 1 && max_data_rows >= 1);
    let mut subs: Vec<FuseSubmission> = Vec::new();
    let mut cur = FuseSubmission::default();
    let mut cur_data = 0usize;
    for &j in order {
        let job = &jobs[j];
        for r in 0..job.rows {
            if cur.rows.len() == max_rows {
                subs.push(std::mem::take(&mut cur));
                cur_data = 0;
            }
            if !cur.segments.contains(&j) {
                if !cur.rows.is_empty() && cur_data + job.seg_rows > max_data_rows {
                    subs.push(std::mem::take(&mut cur));
                    cur_data = 0;
                }
                cur.segments.push(j);
                cur_data += job.seg_rows;
            }
            cur.rows.push((j, r));
        }
    }
    if !cur.rows.is_empty() {
        subs.push(cur);
    }
    subs
}

/// Double-buffered pack/execute submission queue: overlap the *packing*
/// of fused submission `r + 1` (query gather + data-segment concatenation
/// — the planner's memcpy-bound tail) with the *backend execution* of
/// submission `r` (the compute-bound head).
///
/// `pack` runs on a dedicated packer thread feeding a bounded channel of
/// capacity 1, so at any moment at most two packed submissions exist —
/// one executing, one buffered (plus one in flight inside `pack`): the
/// classic double buffer, with bounded memory no matter how long the
/// plan is. `execute` runs on the **calling** thread, in plan order, so
/// everything the executor touches (`&mut` result tables, memo-cache
/// commits, dispatch counters) behaves exactly as in the sequential
/// loop: same submissions, same order, same values — overlap changes
/// wall-clock only. With `overlap` false (the sequential fallback, see
/// `MultiLevelKde::set_overlap`) or fewer than two items, no thread is
/// spawned and the loop runs inline.
///
/// Scoped threads make borrowed data (`&[f32]` views into oracle
/// buffers) safe to pack on the worker without cloning.
pub fn run_double_buffered<T, P, R, F, G>(
    items: Vec<T>,
    overlap: bool,
    pack: F,
    mut execute: G,
) -> Vec<R>
where
    T: Send,
    P: Send,
    F: Fn(T) -> P + Sync,
    G: FnMut(P) -> R,
{
    if !overlap || items.len() < 2 {
        return items.into_iter().map(|t| execute(pack(t))).collect();
    }
    let expected = items.len();
    std::thread::scope(|s| {
        let (tx, rx) = mpsc::sync_channel::<P>(1);
        let pack_ref = &pack;
        s.spawn(move || {
            for t in items {
                // A send error means the executor hung up (it cannot in
                // the current callers, which drain the channel fully);
                // stop packing rather than panic.
                if tx.send(pack_ref(t)).is_err() {
                    return;
                }
            }
        });
        let mut out = Vec::with_capacity(expected);
        for p in rx {
            out.push(execute(p));
        }
        out
    })
}

/// One KDE query in flight.
pub struct QueryRequest {
    pub shard: usize,
    pub point: Vec<f32>,
    pub respond: SyncSender<f64>,
    pub enqueued_at: Instant,
}

#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
    pub workers: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 64, // = AOT_B
            max_wait: Duration::from_micros(500),
            workers: 2,
        }
    }
}

enum Control {
    Request(QueryRequest),
    Shutdown,
}

/// Handle to a running KDE query service.
pub struct KdeService {
    ingress: Sender<Control>,
    router: Option<std::thread::JoinHandle<()>>,
    pub metrics: Arc<ServiceMetrics>,
    shards_len: usize,
}

impl KdeService {
    /// Spawn the router + workers over exact-scan shards: each `(kernel,
    /// dataset)` pair is served through a `NaiveKde` oracle over the
    /// shared backend.
    pub fn start(
        shards: Vec<(Kernel, Arc<Dataset>)>,
        backend: Arc<dyn KernelBackend>,
        cfg: BatcherConfig,
    ) -> Self {
        let counters = KdeCounters::new();
        let oracles: Vec<Arc<dyn Kde>> = shards
            .into_iter()
            .map(|(kernel, data)| {
                let n = data.n;
                Arc::new(NaiveKde::new(
                    data,
                    kernel,
                    0,
                    n,
                    backend.clone(),
                    counters.clone(),
                )) as Arc<dyn Kde>
            })
            .collect();
        Self::start_with_oracles(oracles, cfg)
    }

    /// Spawn the router + workers over arbitrary KDE oracles (estimators,
    /// tree nodes, ...): worker flushes call `query_batch` on the shard.
    pub fn start_with_oracles(shards: Vec<Arc<dyn Kde>>, cfg: BatcherConfig) -> Self {
        assert!(!shards.is_empty());
        let metrics = Arc::new(ServiceMetrics::new());
        let shards_len = shards.len();
        let (tx, rx) = mpsc::channel::<Control>();
        let m = metrics.clone();
        let router = std::thread::spawn(move || {
            run_router(rx, shards, cfg, m);
        });
        KdeService { ingress: tx, router: Some(router), metrics, shards_len }
    }

    /// Async submit: returns a receiver for the answer.
    pub fn submit(&self, shard: usize, point: Vec<f32>) -> Receiver<f64> {
        assert!(shard < self.shards_len, "unknown shard {shard}");
        let (tx, rx) = mpsc::sync_channel(1);
        self.metrics.enqueued.fetch_add(1, Ordering::Relaxed);
        self.ingress
            .send(Control::Request(QueryRequest {
                shard,
                point,
                respond: tx,
                enqueued_at: Instant::now(),
            }))
            .expect("service stopped");
        rx
    }

    /// Blocking query.
    pub fn query(&self, shard: usize, point: Vec<f32>) -> f64 {
        self.submit(shard, point).recv().expect("service dropped request")
    }

    pub fn shutdown(mut self) {
        let _ = self.ingress.send(Control::Shutdown);
        if let Some(h) = self.router.take() {
            let _ = h.join();
        }
    }
}

impl Drop for KdeService {
    fn drop(&mut self) {
        let _ = self.ingress.send(Control::Shutdown);
        if let Some(h) = self.router.take() {
            let _ = h.join();
        }
    }
}

fn run_router(
    rx: Receiver<Control>,
    shards: Vec<Arc<dyn Kde>>,
    cfg: BatcherConfig,
    metrics: Arc<ServiceMetrics>,
) {
    let shards = Arc::new(shards);
    // Worker pool: batches travel over a crossbeam-free mpsc + mutex'd rx.
    let (batch_tx, batch_rx) = mpsc::channel::<Vec<QueryRequest>>();
    let batch_rx = Arc::new(std::sync::Mutex::new(batch_rx));
    let stop = Arc::new(AtomicBool::new(false));
    let mut workers = Vec::new();
    for _ in 0..cfg.workers.max(1) {
        let rx = batch_rx.clone();
        let sh = shards.clone();
        let m = metrics.clone();
        let stop_flag = stop.clone();
        workers.push(std::thread::spawn(move || loop {
            let batch = {
                let guard = rx.lock().unwrap();
                match guard.recv_timeout(Duration::from_millis(20)) {
                    Ok(b) => b,
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        if stop_flag.load(Ordering::Relaxed) {
                            return;
                        }
                        continue;
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => return,
                }
            };
            execute_batch(batch, sh.as_slice(), &m);
        }));
    }

    // Pending per-shard queues. `pending_since[s]` is when the oldest
    // *currently pending* request entered the pending queue (NOT its
    // client enqueue time: while workers are busy, requests age in the
    // ingress channel, and flushing on client-side age would degrade every
    // flush to a single-request batch under backlog — the bug the
    // `batching actually batches` tests pin down).
    let mut pending: Vec<Vec<QueryRequest>> = (0..shards.len()).map(|_| Vec::new()).collect();
    let mut pending_since: Vec<Option<Instant>> = vec![None; shards.len()];
    let mut running = true;
    while running {
        // Wait for at least one request (or shutdown), with a deadline if
        // something is pending.
        let timeout = if pending.iter().any(|q| !q.is_empty()) {
            cfg.max_wait
        } else {
            Duration::from_millis(50)
        };
        let mut absorb = |ctl: Control,
                          pending: &mut Vec<Vec<QueryRequest>>,
                          pending_since: &mut Vec<Option<Instant>>,
                          running: &mut bool| {
            match ctl {
                Control::Request(req) => {
                    let s = req.shard;
                    if pending_since[s].is_none() {
                        pending_since[s] = Some(Instant::now());
                    }
                    pending[s].push(req);
                }
                Control::Shutdown => *running = false,
            }
        };
        match rx.recv_timeout(timeout) {
            Ok(ctl) => absorb(ctl, &mut pending, &mut pending_since, &mut running),
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => running = false,
        }
        // Greedily drain everything already waiting in the ingress channel
        // so a backlog becomes one large batch, not many singletons.
        while let Ok(ctl) = rx.try_recv() {
            absorb(ctl, &mut pending, &mut pending_since, &mut running);
        }
        // Flush policy: size or pending-age.
        for s in 0..pending.len() {
            let flush = pending[s].len() >= cfg.max_batch
                || (!pending[s].is_empty()
                    && pending_since[s]
                        .map(|t| t.elapsed() >= cfg.max_wait)
                        .unwrap_or(false));
            if flush {
                let take = pending[s].len().min(cfg.max_batch);
                let batch: Vec<QueryRequest> = pending[s].drain(..take).collect();
                pending_since[s] = if pending[s].is_empty() {
                    None
                } else {
                    Some(Instant::now())
                };
                metrics.record_batch(batch.len());
                let _ = batch_tx.send(batch);
            }
        }
    }
    // Drain everything left, then stop workers.
    for s in 0..pending.len() {
        while !pending[s].is_empty() {
            let take = pending[s].len().min(cfg.max_batch);
            let batch: Vec<QueryRequest> = pending[s].drain(..take).collect();
            metrics.record_batch(batch.len());
            let _ = batch_tx.send(batch);
        }
    }
    drop(batch_tx);
    stop.store(true, Ordering::Relaxed);
    for w in workers {
        let _ = w.join();
    }
}

fn execute_batch(batch: Vec<QueryRequest>, shards: &[Arc<dyn Kde>], metrics: &ServiceMetrics) {
    if batch.is_empty() {
        return;
    }
    let shard = &shards[batch[0].shard];
    let d = shard.dim();
    let mut queries = Vec::with_capacity(batch.len() * d);
    for req in &batch {
        assert_eq!(req.point.len(), d, "query dim mismatch");
        queries.extend_from_slice(&req.point);
    }
    let sums = shard.query_batch(&queries);
    for (req, &ans) in batch.iter().zip(&sums) {
        // Record BEFORE responding: once `send` lands the client may check
        // the completed counter, and recording after would race it.
        metrics.record_latency_us(req.enqueued_at.elapsed().as_micros() as f64);
        let _ = req.respond.send(ans);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::dataset::gaussian_mixture;
    use crate::runtime::backend::CpuBackend;
    use crate::util::rng::Rng;

    fn service(n: usize, cfg: BatcherConfig) -> (KdeService, Arc<Dataset>) {
        let mut rng = Rng::new(261);
        let ds = Arc::new(gaussian_mixture(n, 4, 2, 1.0, 0.5, &mut rng));
        let svc = KdeService::start(
            vec![(Kernel::Laplacian, ds.clone())],
            CpuBackend::new(),
            cfg,
        );
        (svc, ds)
    }

    fn exact(ds: &Dataset, y: &[f32]) -> f64 {
        (0..ds.n)
            .map(|j| Kernel::Laplacian.eval(ds.point(j), y) as f64)
            .sum()
    }

    #[test]
    fn single_query_matches_naive() {
        let (svc, ds) = service(64, BatcherConfig::default());
        let y = ds.point(5).to_vec();
        let got = svc.query(0, y.clone());
        let want = exact(&ds, &y);
        assert!((got - want).abs() < 1e-6 * (1.0 + want));
        svc.shutdown();
    }

    #[test]
    fn no_request_dropped_or_misrouted_under_load() {
        let (svc, ds) = service(48, BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_micros(200),
            workers: 3,
        });
        let mut rxs = Vec::new();
        for i in 0..200 {
            let y = ds.point(i % 48).to_vec();
            rxs.push((i % 48, svc.submit(0, y)));
        }
        for (idx, rx) in rxs {
            let got = rx.recv_timeout(Duration::from_secs(10)).expect("dropped");
            let want = exact(&ds, ds.point(idx));
            assert!(
                (got - want).abs() < 1e-6 * (1.0 + want),
                "request for point {idx} got wrong answer"
            );
        }
        assert_eq!(
            svc.metrics.completed.load(Ordering::Relaxed),
            200,
            "all requests completed"
        );
        svc.shutdown();
    }

    #[test]
    fn batching_actually_batches() {
        let (svc, ds) = service(32, BatcherConfig {
            max_batch: 16,
            max_wait: Duration::from_millis(20),
            workers: 1,
        });
        let mut rxs = Vec::new();
        for i in 0..64 {
            rxs.push(svc.submit(0, ds.point(i % 32).to_vec()));
        }
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(10)).unwrap();
        }
        let occ = svc.metrics.mean_batch_occupancy();
        assert!(occ > 2.0, "mean occupancy {occ} — batcher not batching");
        svc.shutdown();
    }

    #[test]
    fn multi_shard_routing() {
        let mut rng = Rng::new(263);
        let ds1 = Arc::new(gaussian_mixture(16, 3, 1, 0.0, 0.3, &mut rng));
        let ds2 = Arc::new(gaussian_mixture(40, 3, 1, 5.0, 0.3, &mut rng));
        let svc = KdeService::start(
            vec![
                (Kernel::Gaussian, ds1.clone()),
                (Kernel::Gaussian, ds2.clone()),
            ],
            CpuBackend::new(),
            BatcherConfig::default(),
        );
        let y = ds1.point(0).to_vec();
        let a = svc.query(0, y.clone());
        let b = svc.query(1, y.clone());
        let want1: f64 = (0..16)
            .map(|j| Kernel::Gaussian.eval(ds1.point(j), &y) as f64)
            .sum();
        let want2: f64 = (0..40)
            .map(|j| Kernel::Gaussian.eval(ds2.point(j), &y) as f64)
            .sum();
        assert!((a - want1).abs() < 1e-6 * (1.0 + want1));
        assert!((b - want2).abs() < 1e-6 * (1.0 + want2));
        svc.shutdown();
    }

    #[test]
    fn oracle_shards_serve_estimators() {
        // start_with_oracles: shards are arbitrary Kde oracles — here a
        // NaiveKde over a subrange, i.e. a multi-level-tree node.
        let mut rng = Rng::new(265);
        let ds = Arc::new(gaussian_mixture(80, 4, 2, 1.0, 0.5, &mut rng));
        let counters = crate::kde::KdeCounters::new();
        let oracle: Arc<dyn crate::kde::Kde> = Arc::new(crate::kde::estimators::NaiveKde::new(
            ds.clone(),
            Kernel::Laplacian,
            10,
            60,
            CpuBackend::new(),
            counters,
        ));
        let svc = KdeService::start_with_oracles(vec![oracle], BatcherConfig::default());
        let y = ds.point(2).to_vec();
        let got = svc.query(0, y.clone());
        let want: f64 = (10..60)
            .map(|j| Kernel::Laplacian.eval(ds.point(j), &y) as f64)
            .sum();
        assert!((got - want).abs() < 1e-6 * (1.0 + want), "{got} vs {want}");
        svc.shutdown();
    }

    #[test]
    #[should_panic(expected = "unknown shard")]
    fn unknown_shard_rejected() {
        let (svc, _) = service(8, BatcherConfig::default());
        let _ = svc.submit(3, vec![0.0; 4]);
    }

    fn job(rows: usize, seg_rows: usize) -> FuseJob {
        FuseJob { rows, seg_rows }
    }

    /// Planner invariants: every (job, row) appears exactly once, rows
    /// never split, every submission packs each of its rows' segments
    /// exactly once, and the row/data caps hold (single oversize segment
    /// excepted).
    fn check_plan(jobs: &[FuseJob], max_rows: usize, max_data: usize) -> Vec<FuseSubmission> {
        let plan = plan_level_fusion(jobs, max_rows, max_data);
        verify_plan(plan, jobs, max_rows, max_data)
    }

    /// Same invariants for the adaptive (segment-size-sorted) planner.
    fn check_plan_adaptive(
        jobs: &[FuseJob],
        max_rows: usize,
        max_data: usize,
    ) -> Vec<FuseSubmission> {
        let plan = plan_level_fusion_adaptive(jobs, max_rows, max_data);
        verify_plan(plan, jobs, max_rows, max_data)
    }

    fn verify_plan(
        plan: Vec<FuseSubmission>,
        jobs: &[FuseJob],
        max_rows: usize,
        max_data: usize,
    ) -> Vec<FuseSubmission> {
        let mut seen = std::collections::HashSet::new();
        for sub in &plan {
            assert!(!sub.rows.is_empty());
            assert!(sub.rows.len() <= max_rows);
            let data: usize = sub.segments.iter().map(|&j| jobs[j].seg_rows).sum();
            assert!(
                data <= max_data || sub.segments.len() == 1,
                "data {data} over budget with {} segments",
                sub.segments.len()
            );
            let mut uniq = sub.segments.clone();
            uniq.dedup();
            uniq.sort_unstable();
            uniq.dedup();
            assert_eq!(uniq.len(), sub.segments.len(), "duplicate segment in pack");
            for &(j, r) in &sub.rows {
                assert!(r < jobs[j].rows);
                assert!(sub.segments.contains(&j), "row without its segment");
                assert!(seen.insert((j, r)), "row ({j}, {r}) planned twice");
            }
        }
        let total: usize = jobs.iter().map(|j| j.rows).sum();
        assert_eq!(seen.len(), total, "rows dropped by the plan");
        plan
    }

    #[test]
    fn fusion_planner_single_small_job_is_one_submission() {
        let plan = check_plan(&[job(5, 100)], 64, 1024);
        assert_eq!(plan.len(), 1);
        assert_eq!(plan[0].segments, vec![0]);
    }

    #[test]
    fn fusion_planner_splits_rows_at_max_and_repacks_segment() {
        // 130 rows at B=64 -> 64 + 64 + 2, each carrying the segment.
        let plan = check_plan(&[job(130, 100)], 64, 1024);
        assert_eq!(plan.len(), 3);
        assert_eq!(plan[0].rows.len(), 64);
        assert_eq!(plan[1].rows.len(), 64);
        assert_eq!(plan[2].rows.len(), 2);
        for sub in &plan {
            assert_eq!(sub.segments, vec![0], "split rows re-pack the segment");
        }
    }

    #[test]
    fn fusion_planner_packs_many_small_segments_per_submission() {
        // 16 nodes x 2 rows x 128-row segments: 8 segments fit the M=1024
        // data budget, 32 rows fit the B=64 row budget -> 2 submissions.
        let jobs: Vec<FuseJob> = (0..16).map(|_| job(2, 128)).collect();
        let plan = check_plan(&jobs, 64, 1024);
        assert_eq!(plan.len(), 2);
        assert_eq!(plan[0].segments.len(), 8);
        assert_eq!(plan[1].segments.len(), 8);
    }

    #[test]
    fn fusion_planner_oversize_segment_goes_alone() {
        let plan = check_plan(&[job(3, 5000), job(2, 100)], 64, 1024);
        assert_eq!(plan.len(), 2);
        assert_eq!(plan[0].segments, vec![0], "oversize segment isolated");
        assert_eq!(plan[1].segments, vec![1]);
    }

    #[test]
    fn fusion_planner_skips_empty_jobs_and_empty_input() {
        assert!(plan_level_fusion(&[], 64, 1024).is_empty());
        let plan = check_plan(&[job(0, 50), job(1, 50), job(0, 9)], 64, 1024);
        assert_eq!(plan.len(), 1);
        assert_eq!(plan[0].rows, vec![(1, 0)]);
        assert_eq!(plan[0].segments, vec![1]);
    }

    #[test]
    fn fusion_planner_ragged_property() {
        // Random ragged job mixes keep all invariants.
        crate::util::prop::forall(12, |rng, _| {
            let jobs: Vec<FuseJob> = (0..1 + rng.below(20))
                .map(|_| job(rng.below(100), 1 + rng.below(2000)))
                .collect();
            check_plan(&jobs, 64, 1024);
        });
    }

    #[test]
    fn adaptive_planner_packs_mixed_level_jobs_tighter() {
        // A frontier-walk shape: small deep-level segments interleaved
        // with large shallow-level ones. In-order greedy closes a
        // submission at nearly every large/small boundary; the adaptive
        // planner clusters the small segments into shared submissions.
        let jobs: Vec<FuseJob> = vec![
            job(2, 1000),
            job(2, 30),
            job(2, 1000),
            job(2, 30),
            job(2, 1000),
            job(2, 30),
            job(2, 1000),
            job(2, 30),
        ];
        // In-order: 1000 + 30 > 1024 closes at every boundary -> 8 subs.
        let in_order = check_plan(&jobs, 64, 1024);
        assert_eq!(in_order.len(), 8);
        // Adaptive: the four 1000-row segments go alone, the four 30-row
        // segments share one submission.
        let adaptive = check_plan_adaptive(&jobs, 64, 1024);
        assert_eq!(adaptive.len(), 5);
    }

    #[test]
    fn adaptive_planner_tiny_walker_regime_is_one_submission() {
        // Per-level row counts far below B across many levels: everything
        // fits one padded submission when the data budget allows.
        let jobs: Vec<FuseJob> = (0..10).map(|l| job(2, 1 << (9 - l).min(6))).collect();
        let plan = check_plan_adaptive(&jobs, 64, 1024);
        assert_eq!(plan.len(), 1, "tiny mixed-level frontier packs into one");
        assert_eq!(plan[0].rows.len(), 20);
    }

    #[test]
    fn adaptive_planner_ragged_property() {
        // Random ragged job mixes keep every invariant under the sorted
        // admission order too (rows never lost/split, caps hold).
        crate::util::prop::forall(12, |rng, _| {
            let jobs: Vec<FuseJob> = (0..1 + rng.below(20))
                .map(|_| job(rng.below(100), 1 + rng.below(2000)))
                .collect();
            check_plan_adaptive(&jobs, 64, 1024);
        });
    }

    #[test]
    fn double_buffered_queue_preserves_order_and_values() {
        // Overlapped and sequential runs must produce the same results in
        // the same order; the executor must observe plan order even
        // though packing runs ahead on another thread.
        let items: Vec<usize> = (0..57).collect();
        let run = |overlap: bool| {
            let mut seen = Vec::new();
            let out = run_double_buffered(
                items.clone(),
                overlap,
                |t| t * 10 + 1,
                |p| {
                    seen.push(p);
                    p + 1
                },
            );
            (out, seen)
        };
        let (seq_out, seq_seen) = run(false);
        let (ovl_out, ovl_seen) = run(true);
        assert_eq!(seq_out, ovl_out);
        assert_eq!(seq_seen, ovl_seen);
        assert_eq!(ovl_out, (0..57).map(|t| t * 10 + 2).collect::<Vec<_>>());
    }

    #[test]
    fn double_buffered_queue_edge_sizes() {
        // Empty and single-item inputs take the inline path either way.
        for overlap in [false, true] {
            let empty: Vec<u64> = Vec::new();
            assert!(run_double_buffered(empty, overlap, |t| t, |p: u64| p).is_empty());
            let one = run_double_buffered(vec![41u64], overlap, |t| t + 1, |p| p);
            assert_eq!(one, vec![42]);
        }
    }

    #[test]
    fn double_buffered_queue_executes_on_calling_thread() {
        // The executor closure mutates caller-local state without any
        // synchronization — only sound because execute runs inline on the
        // calling thread (the contract MultiLevelKde's cache commits and
        // resolution maps rely on).
        let caller = std::thread::current().id();
        let mut executed_on = Vec::new();
        let _ = run_double_buffered(
            (0..8).collect::<Vec<usize>>(),
            true,
            |t| t,
            |p| {
                executed_on.push(std::thread::current().id());
                p
            },
        );
        assert!(executed_on.iter().all(|&id| id == caller));
    }

    #[test]
    fn property_random_loads_all_answered() {
        crate::util::prop::forall(6, |rng, _| {
            let n = 8 + rng.below(32);
            let mut r2 = Rng::new(rng.next_u64());
            let ds = Arc::new(gaussian_mixture(n, 3, 2, 1.0, 0.5, &mut r2));
            let svc = KdeService::start(
                vec![(Kernel::Laplacian, ds.clone())],
                CpuBackend::new(),
                BatcherConfig {
                    max_batch: 1 + rng.below(16),
                    max_wait: Duration::from_micros(100 + rng.below(500) as u64),
                    workers: 1 + rng.below(3),
                },
            );
            let reqs = 1 + rng.below(60);
            let mut rxs = Vec::new();
            for i in 0..reqs {
                rxs.push((i % n, svc.submit(0, ds.point(i % n).to_vec())));
            }
            for (idx, rx) in rxs {
                let got = rx.recv_timeout(Duration::from_secs(10)).expect("dropped");
                let want: f64 = (0..n)
                    .map(|j| Kernel::Laplacian.eval(ds.point(j), ds.point(idx)) as f64)
                    .sum();
                assert!((got - want).abs() < 1e-6 * (1.0 + want));
            }
            svc.shutdown();
        });
    }
}
