//! # kde-matrix
//!
//! Sub-quadratic algorithms for kernel matrices via Kernel Density
//! Estimation — a reproduction of Bakshi, Indyk, Kacham, Silwal & Zhou
//! (2022) as a three-layer Rust + JAX + Pallas system.
//!
//! * **Layer 1/2 (build time)** — `python/compile/` authors the tiled
//!   pairwise-kernel Pallas kernel and the batched KDE compute graphs, and
//!   AOT-lowers them to HLO text (`make artifacts`).
//! * **Layer 3 (this crate)** — the paper's algorithms over black-box KDE
//!   oracles, a PJRT runtime that executes the artifacts, and a batching
//!   query coordinator. Python never runs on the request path.
//!
//! Map from the paper to modules:
//!
//! | Paper | Module |
//! |---|---|
//! | Def. 1.1 KDE oracle, Alg 4.1 multi-level KDE | [`kde`] |
//! | Alg 4.3/4.5/4.6 vertex sampling | [`sampling::vertex`] |
//! | Alg 4.11/4.13 neighbor & edge sampling | [`sampling::neighbor`], [`sampling::edge`] |
//! | Alg 4.16 random walks | [`sampling::walk`] |
//! | §5.2 row-norm sampling | [`sampling::rownorm`] |
//! | Thm 5.3 spectral sparsification | [`apps::sparsify`] |
//! | §5.1.1 Laplacian solver | [`apps::solver`] |
//! | Cor 5.14 low-rank approximation | [`apps::lra`] |
//! | Thm 5.17 spectrum in EMD | [`apps::spectrum`] |
//! | Thm 5.22 top eigenvalue | [`apps::eigen_top`] |
//! | Thm 6.9 local clustering | [`apps::cluster_local`] |
//! | §6.2 spectral clustering | [`apps::cluster_spectral`] |
//! | Thm 6.15 arboricity | [`apps::arboricity`] |
//! | Thm 6.17 weighted triangles | [`apps::triangles`] |
// Every unsafe block in the crate carries a written `// SAFETY:` contract
// (docs/ARCHITECTURE.md §Verification matrix); the clippy gate below is
// enforced by CI's `-D warnings` legs.
#![deny(clippy::undocumented_unsafe_blocks)]

pub mod apps;
pub mod coordinator;
pub mod graph;
pub mod kde;
pub mod kernel;
pub mod linalg;
pub mod runtime;
pub mod sampling;
pub mod server;
pub mod util;
