//! Max-flow (Dinic) and exact weighted densest-subgraph via Goldberg's
//! binary-search reduction.
//!
//! The paper's arboricity (§6.3) is `max_U w(E(G_U)) / |U|` — the weighted
//! densest-subgraph density. Algorithm 6.14 subsamples edges and then
//! computes the arboricity of the subsample *exactly*; this module is that
//! exact offline solver (the paper cites [Cha00]'s LP; we use the
//! equivalent flow formulation, which is self-contained).

/// Dinic's max-flow on a capacity network with f64 capacities.
pub struct Dinic {
    n: usize,
    // adjacency: per node, list of edge ids
    adj: Vec<Vec<usize>>,
    // edges stored as (to, cap); reverse edge is id ^ 1
    to: Vec<usize>,
    cap: Vec<f64>,
    level: Vec<i32>,
    iter: Vec<usize>,
}

impl Dinic {
    pub fn new(n: usize) -> Self {
        Dinic {
            n,
            adj: vec![Vec::new(); n],
            to: Vec::new(),
            cap: Vec::new(),
            level: vec![-1; n],
            iter: vec![0; n],
        }
    }

    /// Add a directed edge u -> v with capacity c (and residual v -> u, 0).
    pub fn add_edge(&mut self, u: usize, v: usize, c: f64) {
        debug_assert!(c >= 0.0);
        let id = self.to.len();
        self.to.push(v);
        self.cap.push(c);
        self.adj[u].push(id);
        self.to.push(u);
        self.cap.push(0.0);
        self.adj[v].push(id + 1);
    }

    /// Add an undirected edge with capacity c in both directions.
    pub fn add_undirected(&mut self, u: usize, v: usize, c: f64) {
        let id = self.to.len();
        self.to.push(v);
        self.cap.push(c);
        self.adj[u].push(id);
        self.to.push(u);
        self.cap.push(c);
        self.adj[v].push(id + 1);
    }

    fn bfs(&mut self, s: usize, t: usize) -> bool {
        self.level.fill(-1);
        let mut q = std::collections::VecDeque::new();
        self.level[s] = 0;
        q.push_back(s);
        while let Some(u) = q.pop_front() {
            for &e in &self.adj[u] {
                if self.cap[e] > 1e-12 && self.level[self.to[e]] < 0 {
                    self.level[self.to[e]] = self.level[u] + 1;
                    q.push_back(self.to[e]);
                }
            }
        }
        self.level[t] >= 0
    }

    fn dfs(&mut self, u: usize, t: usize, f: f64) -> f64 {
        if u == t {
            return f;
        }
        while self.iter[u] < self.adj[u].len() {
            let e = self.adj[u][self.iter[u]];
            let v = self.to[e];
            if self.cap[e] > 1e-12 && self.level[v] == self.level[u] + 1 {
                let d = self.dfs(v, t, f.min(self.cap[e]));
                if d > 1e-12 {
                    self.cap[e] -= d;
                    self.cap[e ^ 1] += d;
                    return d;
                }
            }
            self.iter[u] += 1;
        }
        0.0
    }

    /// Compute max flow from s to t; consumes residual capacities.
    pub fn max_flow(&mut self, s: usize, t: usize) -> f64 {
        assert!(s < self.n && t < self.n && s != t);
        let mut flow = 0.0;
        while self.bfs(s, t) {
            self.iter.fill(0);
            loop {
                let f = self.dfs(s, t, f64::INFINITY);
                if f <= 1e-12 {
                    break;
                }
                flow += f;
            }
        }
        flow
    }

    /// After max_flow, the min-cut source side = nodes reachable from s in
    /// the residual graph.
    pub fn min_cut_source_side(&self, s: usize) -> Vec<bool> {
        let mut seen = vec![false; self.n];
        let mut q = std::collections::VecDeque::new();
        seen[s] = true;
        q.push_back(s);
        while let Some(u) = q.pop_front() {
            for &e in &self.adj[u] {
                let v = self.to[e];
                if self.cap[e] > 1e-9 && !seen[v] {
                    seen[v] = true;
                    q.push_back(v);
                }
            }
        }
        seen
    }
}

/// Exact weighted densest subgraph (max_U w(E(U))/|U|) via Goldberg's
/// binary-search-on-guess flow construction, weighted variant:
///
///   source -> v   capacity  W          (W = total edge weight)
///   v -> sink     capacity  W + 2g - deg_w(v)
///   u <-> v       capacity  w(u, v)
///
/// `exists U with density > g` iff min-cut < n*W. Binary search g to
/// relative precision, then extract the optimal set from the final cut.
///
/// Returns `(density, membership)`.
pub fn densest_subgraph(
    n: usize,
    edges: &[(u32, u32, f64)],
    precision: f64,
) -> (f64, Vec<bool>) {
    assert!(n > 0);
    if edges.is_empty() {
        let mut set = vec![false; n];
        set[0] = true;
        return (0.0, set);
    }
    let w_total: f64 = edges.iter().map(|e| e.2).sum();
    let mut deg = vec![0.0f64; n];
    for &(u, v, w) in edges {
        deg[u as usize] += w;
        deg[v as usize] += w;
    }
    let (mut lo, mut hi) = (0.0f64, w_total);
    let mut best_set: Option<Vec<bool>> = None;
    let s = n;
    let t = n + 1;
    // Fixed iteration count: precision halves each round.
    let iters = ((w_total / precision).log2().ceil() as usize).clamp(1, 64);
    for _ in 0..iters {
        let g = 0.5 * (lo + hi);
        let mut net = Dinic::new(n + 2);
        for v in 0..n {
            net.add_edge(s, v, w_total);
            net.add_edge(v, t, w_total + 2.0 * g - deg[v]);
        }
        for &(u, v, w) in edges {
            net.add_undirected(u as usize, v as usize, w);
        }
        let flow = net.max_flow(s, t);
        // If cut < n*W some U has density > g.
        if flow < n as f64 * w_total - 1e-9 {
            let side = net.min_cut_source_side(s);
            let sel: Vec<bool> = (0..n).map(|v| side[v]).collect();
            if sel.iter().any(|&b| b) {
                best_set = Some(sel);
            }
            lo = g;
        } else {
            hi = g;
        }
    }
    let set = best_set.unwrap_or_else(|| {
        // Density never exceeded 0+eps; the densest set is any single
        // maximum-degree... fall back to the full vertex set.
        vec![true; n]
    });
    // Report the exact density of the extracted set (better than returning
    // the binary-search midpoint).
    let size = set.iter().filter(|&&b| b).count().max(1);
    let mut w_in = 0.0;
    for &(u, v, w) in edges {
        if set[u as usize] && set[v as usize] {
            w_in += w;
        }
    }
    (w_in / size as f64, set)
}

/// Charikar's greedy peeling 2-approximation (used as a cross-check and as
/// a fast path for very large samples).
pub fn densest_subgraph_greedy(n: usize, edges: &[(u32, u32, f64)]) -> (f64, Vec<bool>) {
    let mut adj: Vec<Vec<(u32, f64)>> = vec![Vec::new(); n];
    for &(u, v, w) in edges {
        adj[u as usize].push((v, w));
        adj[v as usize].push((u, w));
    }
    let mut deg: Vec<f64> = (0..n)
        .map(|v| adj[v].iter().map(|&(_, w)| w).sum())
        .collect();
    let mut alive = vec![true; n];
    let mut alive_count = n;
    let mut total_w: f64 = edges.iter().map(|e| e.2).sum();
    let mut best_density = total_w / n as f64;
    let mut removal_order = Vec::with_capacity(n);
    // O(n^2) peeling — fine at sample sizes (m = O(n log n)).
    for _ in 0..n {
        // find min-degree alive vertex
        let mut vmin = usize::MAX;
        let mut dmin = f64::INFINITY;
        for v in 0..n {
            if alive[v] && deg[v] < dmin {
                dmin = deg[v];
                vmin = v;
            }
        }
        if vmin == usize::MAX {
            break;
        }
        alive[vmin] = false;
        alive_count -= 1;
        removal_order.push(vmin);
        for &(u, w) in &adj[vmin] {
            if alive[u as usize] {
                deg[u as usize] -= w;
                total_w -= w;
            }
        }
        if alive_count > 0 {
            best_density = best_density.max(total_w / alive_count as f64);
        }
    }
    // Reconstruct the best prefix set.
    let mut set = vec![true; n];
    let mut alive_count = n;
    let mut total_w: f64 = edges.iter().map(|e| e.2).sum();
    let mut best = (total_w / n as f64, set.clone());
    let mut deg: Vec<f64> = (0..n)
        .map(|v| adj[v].iter().map(|&(_, w)| w).sum())
        .collect();
    for &v in &removal_order {
        set[v] = false;
        alive_count -= 1;
        for &(u, w) in &adj[v] {
            if set[u as usize] {
                deg[u as usize] -= w;
                total_w -= w;
            }
        }
        if alive_count > 0 {
            let d = total_w / alive_count as f64;
            if d > best.0 {
                best = (d, set.clone());
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn dinic_known_small() {
        // s=0, t=3; edges 0->1 (3), 0->2 (2), 1->2 (5), 1->3 (2), 2->3 (3)
        let mut d = Dinic::new(4);
        d.add_edge(0, 1, 3.0);
        d.add_edge(0, 2, 2.0);
        d.add_edge(1, 2, 5.0);
        d.add_edge(1, 3, 2.0);
        d.add_edge(2, 3, 3.0);
        assert!((d.max_flow(0, 3) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn dinic_disconnected_zero() {
        let mut d = Dinic::new(4);
        d.add_edge(0, 1, 10.0);
        d.add_edge(2, 3, 10.0);
        assert_eq!(d.max_flow(0, 3), 0.0);
    }

    #[test]
    fn dinic_min_cut_matches_flow() {
        forall(12, |rng, _| {
            let n = 4 + rng.below(6);
            let mut caps = Vec::new();
            for u in 0..n {
                for v in 0..n {
                    if u != v && rng.bernoulli(0.4) {
                        caps.push((u, v, 0.5 + rng.f64() * 2.0));
                    }
                }
            }
            let mut d = Dinic::new(n);
            for &(u, v, c) in &caps {
                d.add_edge(u, v, c);
            }
            let flow = d.max_flow(0, n - 1);
            let side = d.min_cut_source_side(0);
            assert!(side[0] && !side[n - 1]);
            // cut capacity == flow (max-flow min-cut theorem)
            let cut: f64 = caps
                .iter()
                .filter(|&&(u, v, _)| side[u] && !side[v])
                .map(|&(_, _, c)| c)
                .sum();
            assert!((cut - flow).abs() < 1e-6, "cut {cut} vs flow {flow}");
        });
    }

    fn brute_force_densest(n: usize, edges: &[(u32, u32, f64)]) -> f64 {
        let mut best = 0.0f64;
        for mask in 1u32..(1 << n) {
            let size = mask.count_ones() as f64;
            let mut w = 0.0;
            for &(u, v, ww) in edges {
                if mask & (1 << u) != 0 && mask & (1 << v) != 0 {
                    w += ww;
                }
            }
            best = best.max(w / size);
        }
        best
    }

    #[test]
    fn densest_matches_brute_force() {
        forall(16, |rng, _| {
            let n = 3 + rng.below(6); // <= 8 for brute force
            let mut edges = Vec::new();
            for u in 0..n {
                for v in (u + 1)..n {
                    if rng.bernoulli(0.6) {
                        edges.push((u as u32, v as u32, 0.2 + rng.f64()));
                    }
                }
            }
            if edges.is_empty() {
                return;
            }
            let want = brute_force_densest(n, &edges);
            let (got, set) = densest_subgraph(n, &edges, 1e-6);
            assert!(
                (got - want).abs() < 1e-4 * (1.0 + want),
                "flow {got} vs brute {want}"
            );
            assert!(set.iter().any(|&b| b));
        });
    }

    #[test]
    fn densest_planted_clique() {
        // sparse background + dense planted subgraph on {0..4}
        let mut edges = Vec::new();
        for u in 0..5u32 {
            for v in (u + 1)..5 {
                edges.push((u, v, 1.0));
            }
        }
        for v in 5..12u32 {
            edges.push((0, v, 0.01));
        }
        let (d, set) = densest_subgraph(12, &edges, 1e-6);
        // clique density = 10 edges / 5 nodes = 2.0
        assert!((d - 2.0).abs() < 1e-3, "density {d}");
        for v in 0..5 {
            assert!(set[v], "clique vertex {v} excluded");
        }
        for v in 5..12 {
            assert!(!set[v], "background vertex {v} included");
        }
    }

    #[test]
    fn greedy_within_factor_two() {
        forall(12, |rng, _| {
            let n = 4 + rng.below(5);
            let mut edges = Vec::new();
            for u in 0..n {
                for v in (u + 1)..n {
                    if rng.bernoulli(0.5) {
                        edges.push((u as u32, v as u32, 0.2 + rng.f64()));
                    }
                }
            }
            if edges.is_empty() {
                return;
            }
            let opt = brute_force_densest(n, &edges);
            let (greedy, _) = densest_subgraph_greedy(n, &edges);
            assert!(greedy <= opt + 1e-9);
            assert!(greedy >= 0.5 * opt - 1e-9, "greedy {greedy} vs opt {opt}");
        });
    }
}
