//! Weighted-graph substrate: edge-list graph with CSR adjacency, Laplacian
//! operations (quadratic forms, matvecs, dense materialization for tests),
//! conductance, exact kernel-graph construction, and the flow machinery
//! behind exact densest-subgraph (arboricity) computation.

pub mod flow;

use crate::kernel::{Dataset, Kernel};
use crate::linalg::eigen::SymOp;
use crate::linalg::mat::Mat;

/// An undirected weighted graph stored as a deduplicated edge list
/// (parallel edges merged by weight) plus a CSR adjacency built on demand.
#[derive(Clone, Debug)]
pub struct WGraph {
    pub n: usize,
    /// Unique undirected edges `(u, v, w)` with `u < v`, `w > 0`.
    pub edges: Vec<(u32, u32, f64)>,
    csr_offsets: Vec<usize>,
    csr_neighbors: Vec<(u32, f64)>,
}

impl WGraph {
    /// Build from possibly-repeated undirected edges; parallel edges are
    /// merged by summing weights, self-loops dropped.
    pub fn from_edges(n: usize, raw: impl IntoIterator<Item = (usize, usize, f64)>) -> Self {
        let mut map: crate::util::fxhash::FxHashMap<(u32, u32), f64> =
            crate::util::fxhash::FxHashMap::default();
        for (a, b, w) in raw {
            if a == b || w == 0.0 {
                continue;
            }
            assert!(a < n && b < n, "edge endpoint out of range");
            let key = if a < b { (a as u32, b as u32) } else { (b as u32, a as u32) };
            *map.entry(key).or_insert(0.0) += w;
        }
        let mut edges: Vec<(u32, u32, f64)> =
            map.into_iter().map(|((a, b), w)| (a, b, w)).collect();
        edges.sort_unstable_by_key(|e| (e.0, e.1));
        let mut g = WGraph { n, edges, csr_offsets: Vec::new(), csr_neighbors: Vec::new() };
        g.build_csr();
        g
    }

    /// Materialize the complete kernel graph (O(n^2 d); baseline oracle).
    pub fn complete_kernel_graph(ds: &Dataset, k: Kernel) -> Self {
        let mut edges = Vec::with_capacity(ds.n * (ds.n - 1) / 2);
        for i in 0..ds.n {
            for j in (i + 1)..ds.n {
                edges.push((i, j, ds.kernel(k, i, j) as f64));
            }
        }
        WGraph::from_edges(ds.n, edges)
    }

    fn build_csr(&mut self) {
        let mut deg = vec![0usize; self.n];
        for &(u, v, _) in &self.edges {
            deg[u as usize] += 1;
            deg[v as usize] += 1;
        }
        let mut offsets = vec![0usize; self.n + 1];
        for i in 0..self.n {
            offsets[i + 1] = offsets[i] + deg[i];
        }
        let mut cursor = offsets.clone();
        let mut neighbors = vec![(0u32, 0.0f64); offsets[self.n]];
        for &(u, v, w) in &self.edges {
            neighbors[cursor[u as usize]] = (v, w);
            cursor[u as usize] += 1;
            neighbors[cursor[v as usize]] = (u, w);
            cursor[v as usize] += 1;
        }
        self.csr_offsets = offsets;
        self.csr_neighbors = neighbors;
    }

    /// Neighbors of `v` as `(other, weight)`.
    #[inline]
    pub fn neighbors(&self, v: usize) -> &[(u32, f64)] {
        &self.csr_neighbors[self.csr_offsets[v]..self.csr_offsets[v + 1]]
    }

    /// Weighted degree.
    pub fn degree(&self, v: usize) -> f64 {
        self.neighbors(v).iter().map(|&(_, w)| w).sum()
    }

    pub fn degrees(&self) -> Vec<f64> {
        (0..self.n).map(|v| self.degree(v)).collect()
    }

    /// Total edge weight.
    pub fn total_weight(&self) -> f64 {
        self.edges.iter().map(|e| e.2).sum()
    }

    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    /// Laplacian quadratic form `x^T L x = sum_e w_e (x_u - x_v)^2`.
    pub fn laplacian_quadratic(&self, x: &[f64]) -> f64 {
        assert_eq!(x.len(), self.n);
        self.edges
            .iter()
            .map(|&(u, v, w)| {
                let d = x[u as usize] - x[v as usize];
                w * d * d
            })
            .sum()
    }

    /// `L x` without materializing L.
    pub fn laplacian_matvec(&self, x: &[f64], out: &mut [f64]) {
        assert_eq!(x.len(), self.n);
        assert_eq!(out.len(), self.n);
        out.fill(0.0);
        for &(u, v, w) in &self.edges {
            let (u, v) = (u as usize, v as usize);
            let d = x[u] - x[v];
            out[u] += w * d;
            out[v] -= w * d;
        }
    }

    /// Dense Laplacian `D - A` (tests / small baselines).
    pub fn laplacian_dense(&self) -> Mat {
        let mut l = Mat::zeros(self.n, self.n);
        for &(u, v, w) in &self.edges {
            let (u, v) = (u as usize, v as usize);
            l[(u, v)] -= w;
            l[(v, u)] -= w;
            l[(u, u)] += w;
            l[(v, v)] += w;
        }
        l
    }

    /// Dense *normalized* Laplacian `I - D^{-1/2} A D^{-1/2}`.
    pub fn normalized_laplacian_dense(&self) -> Mat {
        let deg = self.degrees();
        let mut l = Mat::identity(self.n);
        for &(u, v, w) in &self.edges {
            let (u, v) = (u as usize, v as usize);
            let s = w / (deg[u] * deg[v]).sqrt();
            l[(u, v)] -= s;
            l[(v, u)] -= s;
        }
        l
    }

    /// Conductance of a vertex subset (Definition 6.2).
    pub fn conductance(&self, in_set: &[bool]) -> f64 {
        assert_eq!(in_set.len(), self.n);
        let mut cut = 0.0;
        let mut vol_s = 0.0;
        let mut vol_c = 0.0;
        for &(u, v, w) in &self.edges {
            let (a, b) = (in_set[u as usize], in_set[v as usize]);
            if a != b {
                cut += w;
            }
            // each edge contributes w to the degree of both endpoints
            if a {
                vol_s += w;
            } else {
                vol_c += w;
            }
            if b {
                vol_s += w;
            } else {
                vol_c += w;
            }
        }
        let denom = vol_s.min(vol_c);
        if denom <= 0.0 {
            return f64::INFINITY;
        }
        cut / denom
    }

    /// Density `w(E(G_U)) / |U|` of the induced subgraph on `U` (§6.3).
    pub fn subgraph_density(&self, in_set: &[bool]) -> f64 {
        let size = in_set.iter().filter(|&&b| b).count();
        if size == 0 {
            return 0.0;
        }
        let mut w_in = 0.0;
        for &(u, v, w) in &self.edges {
            if in_set[u as usize] && in_set[v as usize] {
                w_in += w;
            }
        }
        w_in / size as f64
    }

    /// Exact total weight of triangles, weight = product of edge weights
    /// (Definition 6.16). O(n * m) over CSR — baseline for Theorem 6.17.
    pub fn exact_triangle_weight(&self) -> f64 {
        // adjacency lookup map for membership tests
        let mut wmap: crate::util::fxhash::FxHashMap<(u32, u32), f64> =
            crate::util::fxhash::FxHashMap::default();
        wmap.reserve(self.edges.len());
        for &(u, v, w) in &self.edges {
            wmap.insert((u, v), w);
        }
        let mut total = 0.0;
        for &(u, v, w_uv) in &self.edges {
            // iterate the smaller adjacency of u, count x > v to count each
            // triangle once via its smallest vertex ordering u < v < x
            for &(x, w_ux) in self.neighbors(u as usize) {
                if x > v {
                    if let Some(&w_vx) = wmap.get(&(v.min(x), v.max(x))) {
                        total += w_uv * w_ux * w_vx;
                    }
                }
            }
        }
        total
    }
}

/// Laplacian-as-operator adapter for the CG solver and eigensolvers.
pub struct LaplacianOp<'a>(pub &'a WGraph);

impl SymOp for LaplacianOp<'_> {
    fn dim(&self) -> usize {
        self.0.n
    }
    fn apply(&self, x: &[f64], out: &mut [f64]) {
        self.0.laplacian_matvec(x, out);
    }
}

/// `c*I - normalized Laplacian` operator: top eigenvectors of this are the
/// bottom eigenvectors of the normalized Laplacian (spectral embedding).
pub struct ShiftedNormLaplacianOp<'a> {
    pub g: &'a WGraph,
    pub shift: f64,
    inv_sqrt_deg: Vec<f64>,
}

impl<'a> ShiftedNormLaplacianOp<'a> {
    pub fn new(g: &'a WGraph, shift: f64) -> Self {
        let inv_sqrt_deg = g
            .degrees()
            .iter()
            .map(|&d| if d > 0.0 { 1.0 / d.sqrt() } else { 0.0 })
            .collect();
        ShiftedNormLaplacianOp { g, shift, inv_sqrt_deg }
    }
}

impl SymOp for ShiftedNormLaplacianOp<'_> {
    fn dim(&self) -> usize {
        self.g.n
    }
    fn apply(&self, x: &[f64], out: &mut [f64]) {
        // out = shift*x - (x - D^{-1/2} A D^{-1/2} x)
        //     = (shift-1)*x + D^{-1/2} A D^{-1/2} x
        out.fill(0.0);
        for &(u, v, w) in &self.g.edges {
            let (u, v) = (u as usize, v as usize);
            let s = w * self.inv_sqrt_deg[u] * self.inv_sqrt_deg[v];
            out[u] += s * x[v];
            out[v] += s * x[u];
        }
        for i in 0..x.len() {
            out[i] += (self.shift - 1.0) * x[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn random_graph(rng: &mut Rng, n: usize, p: f64) -> WGraph {
        let mut edges = Vec::new();
        for i in 0..n {
            for j in (i + 1)..n {
                if rng.bernoulli(p) {
                    edges.push((i, j, 0.1 + rng.f64()));
                }
            }
        }
        // ensure connectivity-ish: path backbone
        for i in 0..n - 1 {
            edges.push((i, i + 1, 0.05));
        }
        WGraph::from_edges(n, edges)
    }

    #[test]
    fn parallel_edges_merge() {
        let g = WGraph::from_edges(3, vec![(0, 1, 1.0), (1, 0, 2.0), (1, 2, 0.5)]);
        assert_eq!(g.num_edges(), 2);
        let w01 = g
            .edges
            .iter()
            .find(|e| (e.0, e.1) == (0, 1))
            .unwrap()
            .2;
        assert_eq!(w01, 3.0);
    }

    #[test]
    fn self_loops_dropped() {
        let g = WGraph::from_edges(2, vec![(0, 0, 5.0), (0, 1, 1.0)]);
        assert_eq!(g.num_edges(), 1);
    }

    #[test]
    fn degrees_and_total_weight_consistent() {
        forall(16, |rng, _| {
            let n = 3 + rng.below(12);
            let g = random_graph(rng, n, 0.4);
            let degs = g.degrees();
            let sum_deg: f64 = degs.iter().sum();
            assert!(
                (sum_deg - 2.0 * g.total_weight()).abs() < 1e-9,
                "handshake lemma"
            );
        });
    }

    #[test]
    fn laplacian_quadratic_matches_dense() {
        forall(12, |rng, _| {
            let n = 3 + rng.below(10);
            let g = random_graph(rng, n, 0.5);
            let l = g.laplacian_dense();
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let want = crate::linalg::dot(&x, &l.matvec(&x));
            let got = g.laplacian_quadratic(&x);
            assert!((got - want).abs() < 1e-8 * (1.0 + want.abs()));
        });
    }

    #[test]
    fn laplacian_matvec_matches_dense() {
        forall(12, |rng, _| {
            let n = 3 + rng.below(10);
            let g = random_graph(rng, n, 0.5);
            let l = g.laplacian_dense();
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let want = l.matvec(&x);
            let mut got = vec![0.0; n];
            g.laplacian_matvec(&x, &mut got);
            for i in 0..n {
                assert!((got[i] - want[i]).abs() < 1e-9);
            }
        });
    }

    #[test]
    fn laplacian_annihilates_ones() {
        let mut rng = Rng::new(3);
        let g = random_graph(&mut rng, 8, 0.5);
        let ones = vec![1.0; 8];
        let mut out = vec![0.0; 8];
        g.laplacian_matvec(&ones, &mut out);
        for v in out {
            assert!(v.abs() < 1e-12);
        }
    }

    #[test]
    fn normalized_laplacian_psd_with_spectrum_in_0_2() {
        let mut rng = Rng::new(4);
        let g = random_graph(&mut rng, 10, 0.6);
        let nl = g.normalized_laplacian_dense();
        let (vals, _) = crate::linalg::jacobi_eigen(&nl, 60);
        for &v in &vals {
            assert!(v > -1e-9 && v < 2.0 + 1e-9, "eigenvalue {v}");
        }
        // smallest eigenvalue is 0
        assert!(vals.last().unwrap().abs() < 1e-8);
    }

    #[test]
    fn conductance_known_barbell() {
        // Two triangles joined by one weak edge.
        let mut edges = vec![
            (0, 1, 1.0),
            (1, 2, 1.0),
            (0, 2, 1.0),
            (3, 4, 1.0),
            (4, 5, 1.0),
            (3, 5, 1.0),
            (2, 3, 0.1),
        ];
        edges.dedup();
        let g = WGraph::from_edges(6, edges);
        let mut in_set = vec![false; 6];
        in_set[0] = true;
        in_set[1] = true;
        in_set[2] = true;
        let phi = g.conductance(&in_set);
        // cut = 0.1, vol(S) = 6*1 + 0.1 = 6.1
        assert!((phi - 0.1 / 6.1).abs() < 1e-9, "phi {phi}");
    }

    #[test]
    fn exact_triangle_weight_known() {
        // Single triangle with weights 2, 3, 4 -> product 24.
        let g = WGraph::from_edges(3, vec![(0, 1, 2.0), (1, 2, 3.0), (0, 2, 4.0)]);
        assert!((g.exact_triangle_weight() - 24.0).abs() < 1e-9);
        // Adding a disconnected edge changes nothing.
        let g2 = WGraph::from_edges(
            5,
            vec![(0, 1, 2.0), (1, 2, 3.0), (0, 2, 4.0), (3, 4, 9.0)],
        );
        assert!((g2.exact_triangle_weight() - 24.0).abs() < 1e-9);
    }

    #[test]
    fn exact_triangle_weight_vs_brute_force() {
        forall(8, |rng, _| {
            let n = 4 + rng.below(8);
            let g = random_graph(rng, n, 0.5);
            let mut want = 0.0;
            let mut wmat = vec![vec![0.0f64; n]; n];
            for &(u, v, w) in &g.edges {
                wmat[u as usize][v as usize] = w;
                wmat[v as usize][u as usize] = w;
            }
            for a in 0..n {
                for b in (a + 1)..n {
                    for c in (b + 1)..n {
                        want += wmat[a][b] * wmat[b][c] * wmat[a][c];
                    }
                }
            }
            let got = g.exact_triangle_weight();
            assert!((got - want).abs() < 1e-8 * (1.0 + want), "{got} vs {want}");
        });
    }

    #[test]
    fn shifted_norm_laplacian_op_matches_dense() {
        let mut rng = Rng::new(5);
        let g = random_graph(&mut rng, 9, 0.5);
        let op = ShiftedNormLaplacianOp::new(&g, 2.0);
        let nl = g.normalized_laplacian_dense();
        let x: Vec<f64> = (0..9).map(|_| rng.normal()).collect();
        let mut got = vec![0.0; 9];
        op.apply(&x, &mut got);
        let lx = nl.matvec(&x);
        for i in 0..9 {
            let want = 2.0 * x[i] - lx[i];
            assert!((got[i] - want).abs() < 1e-9);
        }
    }

    #[test]
    fn complete_kernel_graph_edge_count() {
        let mut rng = Rng::new(6);
        let ds = crate::kernel::dataset::gaussian_mixture(12, 3, 2, 1.0, 0.4, &mut rng);
        let g = WGraph::complete_kernel_graph(&ds, Kernel::Gaussian);
        assert_eq!(g.num_edges(), 12 * 11 / 2);
        // weights match kernel evals
        for &(u, v, w) in g.edges.iter().take(10) {
            let want = ds.kernel(Kernel::Gaussian, u as usize, v as usize) as f64;
            assert!((w - want).abs() < 1e-9);
        }
    }
}
