//! The paper's §4 algorithmic building blocks, each a thin layer over the
//! multi-level KDE oracle:
//!
//! * [`vertex`]   — Algorithms 4.3 / 4.5 / 4.6: approximate degrees +
//!   degree-proportional vertex sampling.
//! * [`neighbor`] — Algorithm 4.11: weighted neighbor sampling by KDE tree
//!   descent, with exact descent-probability recovery.
//! * [`edge`]     — Algorithm 4.13: weighted edge sampling.
//! * [`walk`]     — Algorithm 4.16: random walks on the kernel graph.
//! * [`rownorm`]  — §5.2: squared-row-norm sampling via the `cX` trick.
//!
//! A [`Primitives`] bundle wires them together for the applications.

#![warn(missing_docs)]
#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod edge;
pub mod neighbor;
pub mod rownorm;
pub mod vertex;
pub mod walk;

pub use edge::{EdgeSample, EdgeSampler};
pub use neighbor::{NeighborSample, NeighborSampler};
pub use rownorm::RowNormSampler;
pub use vertex::{DegreeSampler, PrefixSampler};
pub use walk::RandomWalker;

use std::sync::Arc;

use crate::kde::multilevel::MultiLevelKde;
use crate::kde::{KdeConfig, KdeCounters};
use crate::kernel::{Dataset, Kernel};
use crate::runtime::backend::KernelBackend;

/// Ready-to-use bundle of all §4 primitives over one kernel graph.
pub struct Primitives {
    /// The multi-level KDE tree every primitive descends.
    pub tree: Arc<MultiLevelKde>,
    /// Degree-proportional vertex sampler (Algorithm 4.6).
    pub degrees: Arc<DegreeSampler>,
    /// Weighted neighbor sampler (Algorithm 4.11).
    pub neighbors: Arc<NeighborSampler>,
    /// Weighted edge sampler (Algorithm 4.13), sequential and
    /// frontier-batched entries.
    pub edges: EdgeSampler,
    /// Random walker (Algorithm 4.16), sequential and frontier-batched
    /// entries.
    pub walker: RandomWalker,
    /// Shared logical-KDE-query accounting (cache misses only).
    pub counters: Arc<KdeCounters>,
}

impl Primitives {
    /// Build the tree and every sampler over one `(dataset, kernel)`
    /// pair; all primitives share the tree's memo cache and counters.
    pub fn build(
        ds: Arc<Dataset>,
        kernel: Kernel,
        cfg: &KdeConfig,
        backend: Arc<dyn KernelBackend>,
    ) -> Self {
        let counters = KdeCounters::new();
        let tree = Arc::new(MultiLevelKde::build(
            ds,
            kernel,
            cfg,
            backend,
            counters.clone(),
        ));
        let degrees = Arc::new(DegreeSampler::build(&tree));
        let neighbors = Arc::new(NeighborSampler::new(tree.clone()));
        let edges = EdgeSampler::new(degrees.clone(), neighbors.clone());
        let walker = RandomWalker::new(neighbors.clone());
        Primitives { tree, degrees, neighbors, edges, walker, counters }
    }

    /// Number of vertices of the kernel graph (= dataset points).
    pub fn n(&self) -> usize {
        self.tree.ds.n
    }

    /// Logical KDE queries issued so far (cache misses only).
    pub fn kde_queries(&self) -> u64 {
        self.counters.queries()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::kernel::dataset::gaussian_mixture;
    use crate::runtime::backend::CpuBackend;
    use crate::util::rng::Rng;

    #[test]
    fn primitives_bundle_smoke() {
        let mut rng = Rng::new(151);
        let ds = Arc::new(gaussian_mixture(32, 3, 2, 1.0, 0.5, &mut rng));
        let p = Primitives::build(
            ds,
            Kernel::Laplacian,
            &KdeConfig::exact(),
            CpuBackend::new(),
        );
        assert_eq!(p.n(), 32);
        assert!(p.kde_queries() >= 32, "degree build must issue n queries");
        let (u, pu) = p.degrees.sample(&mut rng);
        assert!(u < 32 && pu > 0.0);
        let e = p.edges.sample(&mut rng).unwrap();
        assert_ne!(e.u, e.v);
        let end = p.walker.walk(0, 5, &mut rng);
        assert!(end < 32);
    }
}
