//! Weighted edge sampling: Algorithm 4.13 / Theorem 4.14.
//!
//! An edge `(u, v)` is drawn by composing degree sampling (Alg 4.6) with
//! neighbor sampling (Alg 4.11); the resulting edge probability is
//! `p_u q_{uv} + p_v q_{vu} ~ 2 k(u,v) / W` — proportional to its weight.
//!
//! **Frontier-batched evaluation shape.** [`EdgeSampler::sample_batch`]
//! / [`EdgeSampler::sample_one_sided_batch`] draw many edges at once:
//! every edge owns a stream forked off the caller's RNG in draw order,
//! the degree draws consume those streams up front
//! ([`DegreeSampler::sample_batch`] — a pure prefix-tree walk, no backend
//! traffic), and all the neighbor descents then advance in level-order
//! lock-step on the *same* streams
//! ([`NeighborSampler::sample_batch_with_streams`]), each descent round's
//! cache misses coalescing into fused padded backend submissions. A batch
//! of `m` edges therefore costs O(log n) backend dispatches total instead
//! of the sequential O(m log n) — the evaluation shape Theorems 6.15
//! (arboricity) and 6.17 (triangles) assume — while edge `k` is
//! bit-identical to `sample(&mut fork_k)` on the k-th forked stream
//! (pinned in `tests/fusion.rs`).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::sampling::neighbor::NeighborSampler;
use crate::sampling::vertex::DegreeSampler;
use crate::util::rng::Rng;

/// Algorithm 4.13 edge sampler: degree sampling composed with neighbor
/// sampling over one shared multi-level KDE tree.
pub struct EdgeSampler {
    /// Degree-proportional vertex sampler (Algorithm 4.6).
    pub degrees: Arc<DegreeSampler>,
    /// Weighted neighbor sampler (Algorithm 4.11).
    pub neighbors: Arc<NeighborSampler>,
    /// Reverse-probe fusion on/off (on by default): resolve a two-sided
    /// batch's reverse probabilities through the single-round
    /// [`NeighborSampler::neighbor_prob_batch_fused`] probe instead of a
    /// second per-level sweep.
    probe_fuse: AtomicBool,
}

/// One sampled edge with its exact (memoized-oracle) sampling probability.
#[derive(Clone, Copy, Debug)]
pub struct EdgeSample {
    /// Degree-sampled source vertex.
    pub u: usize,
    /// Neighbor-sampled endpoint (never equals `u`).
    pub v: usize,
    /// `p_u * q_uv + p_v * q_vu` — the two-sided edge sampling probability
    /// (Algorithm 5.1 steps (c)-(d)). One-sided draws report `p_u * q_uv`.
    pub prob: f64,
}

impl EdgeSampler {
    /// Compose a degree sampler and a neighbor sampler into an edge
    /// sampler (they must share the same underlying tree).
    pub fn new(degrees: Arc<DegreeSampler>, neighbors: Arc<NeighborSampler>) -> Self {
        EdgeSampler { degrees, neighbors, probe_fuse: AtomicBool::new(true) }
    }

    /// Enable/disable reverse-probe fusion (on by default). When on, a
    /// two-sided batch resolves every edge's reverse probability `q_vu`
    /// through [`NeighborSampler::neighbor_prob_batch_fused`] — ONE extra
    /// `query_points_multi` round per batch instead of the per-level
    /// sweep's O(log n) — so a batch costs `L_forward + 1` rounds rather
    /// than `L_forward + L_reverse` (the >= 1.5x per-batch round drop
    /// pinned in `tests/fusion.rs`). Reported edges and probabilities are
    /// bit-identical on/off; off is the two-sweep shape for A/Bs.
    pub fn set_probe_fusion(&self, enabled: bool) {
        self.probe_fuse.store(enabled, Ordering::Relaxed);
    }

    /// Whether reverse-probe fusion is enabled.
    pub fn probe_fusion(&self) -> bool {
        self.probe_fuse.load(Ordering::Relaxed)
    }

    /// Algorithm 4.13: vertex by degree, then neighbor by edge weight.
    /// `prob` is the exact two-sided probability of producing `{u, v}`.
    pub fn sample(&self, rng: &mut Rng) -> Option<EdgeSample> {
        let (u, p_u) = self.degrees.sample(rng);
        let ns = self.neighbors.sample(u, rng)?;
        let v = ns.neighbor;
        let q_uv = ns.prob;
        let p_v = self.degrees.prob(v);
        let q_vu = self.neighbors.neighbor_prob(v, u);
        Some(EdgeSample { u, v, prob: p_u * q_uv + p_v * q_vu })
    }

    /// One-sided fast path: just `(u, v)` with the forward probability
    /// (used where only proportionality matters, e.g. the one-sided bound
    /// inside Algorithm 6.14's upper-bound sampling).
    pub fn sample_one_sided(&self, rng: &mut Rng) -> Option<EdgeSample> {
        let (u, p_u) = self.degrees.sample(rng);
        let ns = self.neighbors.sample(u, rng)?;
        Some(EdgeSample { u, v: ns.neighbor, prob: p_u * ns.prob })
    }

    /// Frontier-batched [`Self::sample`]: draw `count` weighted edges with
    /// O(log n) backend dispatches total instead of one descent at a time.
    ///
    /// Edge `k` draws from the `k`-th stream forked off `rng` — first its
    /// degree sample, then its neighbor descent on the *same* stream — so
    /// the result equals calling [`Self::sample`] sequentially with those
    /// forks, bit for bit (deterministic memoized oracles; the reverse
    /// probabilities `q_vu` are RNG-free descents resolved by one batched
    /// probe). The descents advance in level-order lock-step and every
    /// level's cache misses are coalesced into fused padded submissions
    /// (`MultiLevelKde::query_points_multi`).
    ///
    /// # Example
    ///
    /// ```
    /// use std::sync::Arc;
    /// use kde_matrix::kde::{KdeConfig, KdeCounters, MultiLevelKde};
    /// use kde_matrix::kernel::{dataset::gaussian_mixture, Kernel};
    /// use kde_matrix::runtime::CpuBackend;
    /// use kde_matrix::sampling::{DegreeSampler, EdgeSampler, NeighborSampler};
    /// use kde_matrix::util::rng::Rng;
    ///
    /// let mut rng = Rng::new(7);
    /// let ds = Arc::new(gaussian_mixture(32, 3, 2, 1.0, 0.5, &mut rng));
    /// let tree = Arc::new(MultiLevelKde::build(
    ///     ds, Kernel::Laplacian, &KdeConfig::exact(), CpuBackend::new(), KdeCounters::new(),
    /// ));
    /// let edges = EdgeSampler::new(
    ///     Arc::new(DegreeSampler::build(&tree)),
    ///     Arc::new(NeighborSampler::new(tree.clone())),
    /// );
    /// // A batch replays the sequential draws on the same forked streams.
    /// let batch = edges.sample_batch(4, &mut Rng::new(11));
    /// let mut seed = Rng::new(11);
    /// for b in batch {
    ///     let mut fork = seed.fork();
    ///     let want = edges.sample(&mut fork).unwrap();
    ///     let got = b.unwrap();
    ///     assert_eq!((got.u, got.v), (want.u, want.v));
    ///     assert_eq!(got.prob.to_bits(), want.prob.to_bits());
    /// }
    /// ```
    pub fn sample_batch(&self, count: usize, rng: &mut Rng) -> Vec<Option<EdgeSample>> {
        self.batch_impl(count, rng, true)
    }

    /// Frontier-batched [`Self::sample_one_sided`]: same engine, stream
    /// discipline and bit-identity contract as [`Self::sample_batch`],
    /// but each edge reports only the forward probability `p_u * q_uv`
    /// (no reverse-probability probe at all — the cheapest batch shape
    /// when only proportionality matters).
    pub fn sample_one_sided_batch(&self, count: usize, rng: &mut Rng) -> Vec<Option<EdgeSample>> {
        self.batch_impl(count, rng, false)
    }

    /// Shared frontier-batch body: fork the per-edge streams, degree-draw
    /// from each, run every descent in lock-step on the same streams, and
    /// (two-sided only) resolve all reverse probabilities in one batched
    /// RNG-free probe.
    fn batch_impl(&self, count: usize, rng: &mut Rng, two_sided: bool) -> Vec<Option<EdgeSample>> {
        let mut rngs: Vec<Rng> = (0..count).map(|_| rng.fork()).collect();
        let degree = self.degrees.sample_batch(&mut rngs);
        let sources: Vec<usize> = degree.iter().map(|&(u, _)| u).collect();
        let samples = self.neighbors.sample_batch_with_streams(&sources, &mut rngs);
        let mut out: Vec<Option<EdgeSample>> = vec![None; count];
        if two_sided {
            // Reverse descent probabilities q_{vu}: deterministic, so one
            // batched probe resolves every kept edge's factor.
            let mut pairs = Vec::with_capacity(count);
            let mut keep = Vec::with_capacity(count);
            for (k, s) in samples.iter().enumerate() {
                if let Some(s) = s {
                    pairs.push((s.neighbor, sources[k]));
                    keep.push(k);
                }
            }
            let q_vu = if self.probe_fuse.load(Ordering::Relaxed) {
                self.neighbors.neighbor_prob_batch_fused(&pairs)
            } else {
                self.neighbors.neighbor_prob_batch(&pairs)
            };
            for (ki, &k) in keep.iter().enumerate() {
                let (u, p_u) = degree[k];
                let s = match samples[k] {
                    Some(s) => s,
                    // `keep` holds exactly the Some indices collected above.
                    None => unreachable!("kept samples are Some"),
                };
                let v = s.neighbor;
                let p_v = self.degrees.prob(v);
                out[k] = Some(EdgeSample { u, v, prob: p_u * s.prob + p_v * q_vu[ki] });
            }
        } else {
            for (k, s) in samples.iter().enumerate() {
                if let Some(s) = s {
                    let (u, p_u) = degree[k];
                    out[k] = Some(EdgeSample { u, v: s.neighbor, prob: p_u * s.prob });
                }
            }
        }
        out
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::kde::multilevel::MultiLevelKde;
    use crate::kde::{KdeConfig, KdeCounters};
    use crate::kernel::dataset::gaussian_mixture;
    use crate::kernel::Kernel;
    use crate::runtime::backend::CpuBackend;

    fn build(n: usize, seed: u64) -> EdgeSampler {
        let mut rng = Rng::new(seed);
        let ds = Arc::new(gaussian_mixture(n, 3, 2, 1.0, 0.5, &mut rng));
        let tree = Arc::new(MultiLevelKde::build(
            ds,
            Kernel::Laplacian,
            &KdeConfig::exact(),
            CpuBackend::new(),
            KdeCounters::new(),
        ));
        let deg = Arc::new(DegreeSampler::build(&tree));
        EdgeSampler::new(deg, Arc::new(NeighborSampler::new(tree)))
    }

    #[test]
    fn batches_replay_sequential_forked_streams() {
        // The frontier-batch contract: edge k of a batch equals the
        // sequential draw on the k-th stream forked off the same rng —
        // bit for bit, including the reported probability — for both the
        // two-sided and one-sided entries.
        let s = build(40, 211);
        for two_sided in [true, false] {
            let got = if two_sided {
                s.sample_batch(23, &mut Rng::new(213))
            } else {
                s.sample_one_sided_batch(23, &mut Rng::new(213))
            };
            let mut seed = Rng::new(213);
            for (k, g) in got.iter().enumerate() {
                let mut fork = seed.fork();
                let seq = if two_sided {
                    s.sample(&mut fork)
                } else {
                    s.sample_one_sided(&mut fork)
                };
                let want = seq.expect("n > 1 always samples");
                let g = g.expect("batched edge must sample too");
                assert_eq!((g.u, g.v), (want.u, want.v), "edge {k} diverged");
                assert_eq!(g.prob.to_bits(), want.prob.to_bits(), "edge {k} prob");
            }
        }
    }

    #[test]
    fn probe_fusion_is_bit_identical_and_saves_rounds() {
        // Two-sided batches must report bit-identical edges with the
        // reverse probe fused (one extra round) or per-level (a second
        // sweep), and fusion must cut the per-batch round count.
        let fused = build(48, 217);
        let sweep = build(48, 217);
        sweep.set_probe_fusion(false);
        assert!(fused.probe_fusion() && !sweep.probe_fusion());
        let base_fused = fused.neighbors.tree.multi_calls();
        let base_sweep = sweep.neighbors.tree.multi_calls();
        let a = fused.sample_batch(31, &mut Rng::new(219));
        let rounds_fused = fused.neighbors.tree.multi_calls() - base_fused;
        let b = sweep.sample_batch(31, &mut Rng::new(219));
        let rounds_sweep = sweep.neighbors.tree.multi_calls() - base_sweep;
        for (k, (x, y)) in a.iter().zip(&b).enumerate() {
            let (x, y) = (x.expect("sampled"), y.expect("sampled"));
            assert_eq!((x.u, x.v), (y.u, y.v), "edge {k} diverged");
            assert_eq!(x.prob.to_bits(), y.prob.to_bits(), "edge {k} prob");
        }
        assert!(
            rounds_sweep as f64 >= 1.5 * rounds_fused as f64,
            "probe fusion should drop rounds >= 1.5x: fused {rounds_fused}, sweep {rounds_sweep}"
        );
    }

    #[test]
    fn single_edge_batch_and_empty_batch() {
        let s = build(24, 215);
        assert!(s.sample_batch(0, &mut Rng::new(1)).is_empty());
        let got = s.sample_batch(1, &mut Rng::new(3));
        let mut seed = Rng::new(3);
        let mut fork = seed.fork();
        let want = s.sample(&mut fork).unwrap();
        let g = got[0].unwrap();
        assert_eq!((g.u, g.v, g.prob.to_bits()), (want.u, want.v, want.prob.to_bits()));
    }

    #[test]
    fn edge_distribution_proportional_to_weight() {
        let s = build(16, 111);
        let ds = &s.neighbors.tree.ds;
        let mut rng = Rng::new(113);
        let trials = 60_000;
        let mut counts = std::collections::HashMap::new();
        for _ in 0..trials {
            let e = s.sample(&mut rng).unwrap();
            let key = (e.u.min(e.v), e.u.max(e.v));
            *counts.entry(key).or_insert(0f64) += 1.0;
        }
        let mut empirical = Vec::new();
        let mut want = Vec::new();
        for i in 0..16 {
            for j in (i + 1)..16 {
                empirical.push(*counts.get(&(i, j)).unwrap_or(&0.0));
                want.push(Kernel::Laplacian.eval(ds.point(i), ds.point(j)) as f64);
            }
        }
        let tv = crate::util::stats::tv_distance(&empirical, &want);
        assert!(tv < 0.04, "edge TV {tv}");
    }

    #[test]
    fn reported_prob_matches_empirical_frequency() {
        let s = build(12, 115);
        let mut rng = Rng::new(117);
        // Collect reported probabilities once (deterministic under exact
        // oracle), then compare against empirical frequency.
        let trials = 80_000;
        let mut counts = std::collections::HashMap::new();
        let mut probs = std::collections::HashMap::new();
        for _ in 0..trials {
            let e = s.sample(&mut rng).unwrap();
            let key = (e.u.min(e.v), e.u.max(e.v));
            *counts.entry(key).or_insert(0f64) += 1.0;
            probs.insert(key, e.prob);
        }
        for (key, &p) in &probs {
            let freq = counts[key] / trials as f64;
            assert!(
                (freq - p).abs() < 0.01 + 0.25 * p,
                "edge {key:?}: freq {freq} vs prob {p}"
            );
        }
        // Probabilities over all edges sum to ~1.
        let mut total = 0.0;
        for i in 0..12 {
            for j in (i + 1)..12 {
                let q_uv = s.neighbors.neighbor_prob(i, j);
                let q_vu = s.neighbors.neighbor_prob(j, i);
                total += s.degrees.prob(i) * q_uv + s.degrees.prob(j) * q_vu;
            }
        }
        assert!((total - 1.0).abs() < 1e-9, "edge probs sum {total}");
    }
}
