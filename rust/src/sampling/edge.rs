//! Weighted edge sampling: Algorithm 4.13 / Theorem 4.14.
//!
//! An edge `(u, v)` is drawn by composing degree sampling (Alg 4.6) with
//! neighbor sampling (Alg 4.11); the resulting edge probability is
//! `p_u q_{uv} + p_v q_{vu} ~ 2 k(u,v) / W` — proportional to its weight.

use std::sync::Arc;

use crate::sampling::neighbor::NeighborSampler;
use crate::sampling::vertex::DegreeSampler;
use crate::util::rng::Rng;

pub struct EdgeSampler {
    pub degrees: Arc<DegreeSampler>,
    pub neighbors: Arc<NeighborSampler>,
}

/// One sampled edge with its exact (memoized-oracle) sampling probability.
#[derive(Clone, Copy, Debug)]
pub struct EdgeSample {
    pub u: usize,
    pub v: usize,
    /// `p_u * q_uv + p_v * q_vu` — the two-sided edge sampling probability
    /// (Algorithm 5.1 steps (c)-(d)).
    pub prob: f64,
}

impl EdgeSampler {
    pub fn new(degrees: Arc<DegreeSampler>, neighbors: Arc<NeighborSampler>) -> Self {
        EdgeSampler { degrees, neighbors }
    }

    /// Algorithm 4.13: vertex by degree, then neighbor by edge weight.
    /// `prob` is the exact two-sided probability of producing `{u, v}`.
    pub fn sample(&self, rng: &mut Rng) -> Option<EdgeSample> {
        let (u, p_u) = self.degrees.sample(rng);
        let ns = self.neighbors.sample(u, rng)?;
        let v = ns.neighbor;
        let q_uv = ns.prob;
        let p_v = self.degrees.prob(v);
        let q_vu = self.neighbors.neighbor_prob(v, u);
        Some(EdgeSample { u, v, prob: p_u * q_uv + p_v * q_vu })
    }

    /// One-sided fast path: just `(u, v)` with the forward probability
    /// (used where only proportionality matters, e.g. arboricity).
    pub fn sample_one_sided(&self, rng: &mut Rng) -> Option<EdgeSample> {
        let (u, p_u) = self.degrees.sample(rng);
        let ns = self.neighbors.sample(u, rng)?;
        Some(EdgeSample { u, v: ns.neighbor, prob: p_u * ns.prob })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kde::multilevel::MultiLevelKde;
    use crate::kde::{KdeConfig, KdeCounters};
    use crate::kernel::dataset::gaussian_mixture;
    use crate::kernel::Kernel;
    use crate::runtime::backend::CpuBackend;

    fn build(n: usize, seed: u64) -> EdgeSampler {
        let mut rng = Rng::new(seed);
        let ds = Arc::new(gaussian_mixture(n, 3, 2, 1.0, 0.5, &mut rng));
        let tree = Arc::new(MultiLevelKde::build(
            ds,
            Kernel::Laplacian,
            &KdeConfig::exact(),
            CpuBackend::new(),
            KdeCounters::new(),
        ));
        let deg = Arc::new(DegreeSampler::build(&tree));
        EdgeSampler::new(deg, Arc::new(NeighborSampler::new(tree)))
    }

    #[test]
    fn edge_distribution_proportional_to_weight() {
        let s = build(16, 111);
        let ds = &s.neighbors.tree.ds;
        let mut rng = Rng::new(113);
        let trials = 60_000;
        let mut counts = std::collections::HashMap::new();
        for _ in 0..trials {
            let e = s.sample(&mut rng).unwrap();
            let key = (e.u.min(e.v), e.u.max(e.v));
            *counts.entry(key).or_insert(0f64) += 1.0;
        }
        let mut empirical = Vec::new();
        let mut want = Vec::new();
        for i in 0..16 {
            for j in (i + 1)..16 {
                empirical.push(*counts.get(&(i, j)).unwrap_or(&0.0));
                want.push(Kernel::Laplacian.eval(ds.point(i), ds.point(j)) as f64);
            }
        }
        let tv = crate::util::stats::tv_distance(&empirical, &want);
        assert!(tv < 0.04, "edge TV {tv}");
    }

    #[test]
    fn reported_prob_matches_empirical_frequency() {
        let s = build(12, 115);
        let mut rng = Rng::new(117);
        // Collect reported probabilities once (deterministic under exact
        // oracle), then compare against empirical frequency.
        let trials = 80_000;
        let mut counts = std::collections::HashMap::new();
        let mut probs = std::collections::HashMap::new();
        for _ in 0..trials {
            let e = s.sample(&mut rng).unwrap();
            let key = (e.u.min(e.v), e.u.max(e.v));
            *counts.entry(key).or_insert(0f64) += 1.0;
            probs.insert(key, e.prob);
        }
        for (key, &p) in &probs {
            let freq = counts[key] / trials as f64;
            assert!(
                (freq - p).abs() < 0.01 + 0.25 * p,
                "edge {key:?}: freq {freq} vs prob {p}"
            );
        }
        // Probabilities over all edges sum to ~1.
        let mut total = 0.0;
        for i in 0..12 {
            for j in (i + 1)..12 {
                let q_uv = s.neighbors.neighbor_prob(i, j);
                let q_vu = s.neighbors.neighbor_prob(j, i);
                total += s.degrees.prob(i) * q_uv + s.degrees.prob(j) * q_vu;
            }
        }
        assert!((total - 1.0).abs() < 1e-9, "edge probs sum {total}");
    }
}
