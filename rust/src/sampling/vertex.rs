//! Weighted vertex (degree) sampling: Algorithms 4.3, 4.5 and 4.6.
//!
//! Algorithm 4.3 computes `p_i ~ deg(x_i)` with one KDE query per vertex
//! (subtracting the self term `k(x_i, x_i) = 1`), **once**; afterwards
//! every sample costs O(log n) via the prefix-sum tree of Algorithm 4.5.

use std::sync::Arc;

use crate::kde::multilevel::MultiLevelKde;
use crate::util::rng::Rng;

/// Algorithm 4.5: sample an index proportional to a positive array, via
/// binary descent on prefix sums (O(log n) per sample after O(n) build).
#[derive(Clone, Debug)]
pub struct PrefixSampler {
    /// prefix[i] = sum of weights[0..i]; prefix[n] = total.
    prefix: Vec<f64>,
}

impl PrefixSampler {
    /// Build the prefix-sum tree over nonnegative `weights` (at least one
    /// must be positive).
    pub fn new(weights: &[f64]) -> Self {
        assert!(!weights.is_empty());
        assert!(weights.iter().all(|&w| w >= 0.0), "negative weight");
        let mut prefix = Vec::with_capacity(weights.len() + 1);
        prefix.push(0.0);
        let mut acc = 0.0;
        for &w in weights {
            acc += w;
            prefix.push(acc);
        }
        assert!(acc > 0.0, "all-zero weights");
        PrefixSampler { prefix }
    }

    /// Sum of all weights.
    pub fn total(&self) -> f64 {
        match self.prefix.last() {
            Some(&t) => t,
            // `new` always pushes the leading 0.0, so prefix is nonempty.
            None => unreachable!("prefix always holds the leading 0.0"),
        }
    }

    /// Weight of index `i`.
    pub fn weight(&self, i: usize) -> f64 {
        self.prefix[i + 1] - self.prefix[i]
    }

    /// Probability of sampling index `i`.
    pub fn prob(&self, i: usize) -> f64 {
        self.weight(i) / self.total()
    }

    /// Draw one index (binary search = the Algorithm 4.5 tree descent).
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let target = rng.f64() * self.total();
        // partition_point: first i with prefix[i+1] > target
        let idx = self
            .prefix
            .partition_point(|&p| p <= target)
            .saturating_sub(1);
        idx.min(self.prefix.len() - 2)
    }
}

/// Algorithm 4.3 + 4.6: approximate-degree array + degree-proportional
/// vertex sampling over the kernel graph.
pub struct DegreeSampler {
    /// Approximate degree of every vertex (self term removed, floored at a
    /// tiny positive value).
    pub degrees: Vec<f64>,
    sampler: PrefixSampler,
    /// KDE queries spent building the degree array (exactly n).
    pub build_queries: u64,
}

impl DegreeSampler {
    /// Run Algorithm 4.3 against the multi-level KDE's root oracle: n KDE
    /// queries, executed once — batched through `query_points`, so the
    /// whole degree array costs `ceil(n / 64)` fused backend submissions
    /// (the AOT B=64 batch shape) instead of n singleton dispatches.
    pub fn build(tree: &Arc<MultiLevelKde>) -> Self {
        let n = tree.ds.n;
        let before = tree.counters.queries();
        let idx: Vec<usize> = (0..n).collect();
        let raw = tree.query_points(tree.root(), &idx);
        let degrees: Vec<f64> = raw
            .into_iter()
            // Root answers include the self term k(x_i, x_i) = 1: subtract.
            // Estimates can dip <= 0 under sampling noise; floor at a tiny
            // positive value so the distribution stays well-defined.
            .map(|v| (v - 1.0).max(1e-12))
            .collect();
        let build_queries = tree.counters.queries() - before;
        let sampler = PrefixSampler::new(&degrees);
        DegreeSampler { degrees, sampler, build_queries }
    }

    /// Build directly from an exact degree array (test / baseline path).
    pub fn from_degrees(degrees: Vec<f64>) -> Self {
        let sampler = PrefixSampler::new(&degrees);
        DegreeSampler { degrees, sampler, build_queries: 0 }
    }

    /// Sample a vertex; returns `(index, sampling probability)`.
    pub fn sample(&self, rng: &mut Rng) -> (usize, f64) {
        let i = self.sampler.sample(rng);
        (i, self.sampler.prob(i))
    }

    /// Batched [`Self::sample`] over caller-owned per-draw streams: draw
    /// `k` comes from `rngs[k]`, exactly as `sample(&mut rngs[k])` would.
    /// Degree sampling is a pure prefix-tree walk — zero KDE queries and
    /// zero backend dispatches per draw — so this batch entry exists for
    /// the *stream discipline*, not for fusion: the frontier-batched edge
    /// engine ([`EdgeSampler::sample_batch`](crate::sampling::EdgeSampler::sample_batch))
    /// draws every edge's source vertex from that edge's own forked
    /// stream, then continues the same stream into the neighbor descent,
    /// which is what makes a batched edge replay its sequential draw bit
    /// for bit.
    pub fn sample_batch(&self, rngs: &mut [Rng]) -> Vec<(usize, f64)> {
        rngs.iter_mut().map(|r| self.sample(r)).collect()
    }

    /// Probability this sampler assigns to vertex `i`.
    pub fn prob(&self, i: usize) -> f64 {
        self.sampler.prob(i)
    }

    /// Total degree mass (the normalizer of the sampling distribution).
    pub fn total(&self) -> f64 {
        self.sampler.total()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::kde::{KdeConfig, KdeCounters};
    use crate::kernel::dataset::gaussian_mixture;
    use crate::kernel::Kernel;
    use crate::runtime::backend::CpuBackend;
    use crate::util::prop::forall;

    #[test]
    fn prefix_sampler_matches_exact_categorical() {
        forall(8, |rng, _| {
            let n = 2 + rng.below(12);
            let weights: Vec<f64> = (0..n).map(|_| rng.f64() + 0.01).collect();
            let s = PrefixSampler::new(&weights);
            let total: f64 = weights.iter().sum();
            let trials = 30_000;
            let mut counts = vec![0usize; n];
            for _ in 0..trials {
                counts[s.sample(rng)] += 1;
            }
            for i in 0..n {
                let want = weights[i] / total;
                let got = counts[i] as f64 / trials as f64;
                assert!(
                    (got - want).abs() < 0.02 + 0.15 * want,
                    "idx {i}: got {got}, want {want}"
                );
            }
        });
    }

    #[test]
    fn prefix_sampler_skips_zero_weights() {
        let mut rng = Rng::new(71);
        let s = PrefixSampler::new(&[0.0, 1.0, 0.0, 2.0, 0.0]);
        for _ in 0..2_000 {
            let i = s.sample(&mut rng);
            assert!(i == 1 || i == 3, "sampled zero-weight index {i}");
        }
    }

    #[test]
    fn prefix_probs_sum_to_one() {
        let s = PrefixSampler::new(&[0.5, 1.5, 3.0]);
        let total: f64 = (0..3).map(|i| s.prob(i)).sum();
        assert!((total - 1.0).abs() < 1e-12);
        assert!((s.prob(2) - 0.6).abs() < 1e-12);
    }

    fn build_tree(n: usize, seed: u64, cfg: KdeConfig) -> Arc<MultiLevelKde> {
        let mut rng = Rng::new(seed);
        let ds = Arc::new(gaussian_mixture(n, 4, 2, 1.0, 0.5, &mut rng));
        Arc::new(MultiLevelKde::build(
            ds,
            Kernel::Laplacian,
            &cfg,
            CpuBackend::new(),
            KdeCounters::new(),
        ))
    }

    #[test]
    fn degrees_exact_with_naive_oracle() {
        let tree = build_tree(40, 73, KdeConfig::exact());
        let sampler = DegreeSampler::build(&tree);
        for i in 0..40 {
            let want = tree.ds.exact_degree(Kernel::Laplacian, i);
            let got = sampler.degrees[i];
            assert!(
                (got - want).abs() < 1e-6 * (1.0 + want),
                "deg {i}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn build_uses_exactly_n_queries() {
        let tree = build_tree(33, 75, KdeConfig::exact());
        let sampler = DegreeSampler::build(&tree);
        assert_eq!(sampler.build_queries, 33, "Theorem 4.9: n queries upfront");
    }

    #[test]
    fn sample_batch_replays_sequential_per_stream() {
        let tree = build_tree(48, 81, KdeConfig::exact());
        let sampler = DegreeSampler::build(&tree);
        let mut seed = crate::util::rng::Rng::new(83);
        let mut batch_rngs: Vec<_> = (0..17).map(|_| seed.fork()).collect();
        let mut seq_rngs = batch_rngs.clone();
        let got = sampler.sample_batch(&mut batch_rngs);
        for (k, (u, p)) in got.into_iter().enumerate() {
            let (wu, wp) = sampler.sample(&mut seq_rngs[k]);
            assert_eq!(u, wu, "draw {k} diverged");
            assert_eq!(p.to_bits(), wp.to_bits(), "draw {k} prob");
        }
    }

    #[test]
    fn degree_sampling_close_to_true_distribution() {
        // Theorem 4.9: TV distance O(eps) from the true degree distribution.
        let tree = build_tree(64, 77, KdeConfig::exact());
        let sampler = DegreeSampler::build(&tree);
        let mut rng = Rng::new(79);
        let trials = 60_000;
        let mut counts = vec![0f64; 64];
        for _ in 0..trials {
            counts[sampler.sample(&mut rng).0] += 1.0;
        }
        let true_deg: Vec<f64> = (0..64)
            .map(|i| tree.ds.exact_degree(Kernel::Laplacian, i))
            .collect();
        let tv = crate::util::stats::tv_distance(&counts, &true_deg);
        assert!(tv < 0.03, "TV distance {tv}");
    }
}
