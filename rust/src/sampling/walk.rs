//! Random walks on the kernel graph: Algorithm 4.16 / Theorem 4.15.
//!
//! A T-step walk is T sequential neighbor samples; each step costs
//! O(log n) KDE queries (cache-cold) and the endpoint distribution is
//! within O(T eps) TV of the true walk distribution.
//!
//! Two evaluation shapes:
//!
//! * **Sequential** ([`RandomWalker::walk`] / [`RandomWalker::trajectory`]):
//!   one descent at a time, each resolved through the memoized tree —
//!   O(log n) dispatches per cache-cold step. Both paths advance through
//!   one shared step function, so an `exact()` walker's trajectory applies
//!   the same Theorem 4.12 rejection correction its endpoints do.
//! * **Frontier-batched** ([`RandomWalker::walk_batch`] /
//!   [`RandomWalker::trajectory_batch`]): all W walkers advance in
//!   lockstep rounds. Every round groups the frontier's walkers by their
//!   current descent node and resolves the *whole* round's child answers
//!   in one [`MultiLevelKde`](crate::kde::multilevel::MultiLevelKde)
//!   `query_points_multi` call, so the misses of every node the frontier
//!   touches — across tree levels, once walkers desync through ragged
//!   leaf-finish depths or exact-mode rejections — coalesce into shared
//!   fused `sums_ranged` submissions (planned by
//!   `plan_level_fusion_adaptive`, which packs mixed-level segments
//!   largest-first). A W-walker, T-step batch therefore costs
//!   O(T · log n · ceil(distinct_sources / B)) backend executions instead
//!   of the sequential O(W · T · log n), and cache warm-up drives late
//!   rounds toward zero dispatches (pinned in `tests/fusion.rs`).
//!
//! Each frontier walker draws from its own RNG stream forked off the
//! caller's `rng` in `starts` order, so a batch reproduces — **bit for
//! bit** — the endpoints the sequential walker produces from the same
//! forked streams (oracle answers are deterministic and memoized), while
//! the *distribution* is identical to walking with any stream.

use std::sync::Arc;

use crate::sampling::neighbor::NeighborSampler;
use crate::util::rng::Rng;

/// Rejection proposals an exact-mode step attempts before falling back to
/// the plain descent sample (Theorem 4.12's `O(1)` expected rounds).
const EXACT_PROPOSALS: usize = 16;

/// Algorithm 4.16 random walker (see the module docs for the sequential
/// and frontier-batched evaluation shapes).
pub struct RandomWalker {
    /// The neighbor sampler each step draws from.
    pub neighbors: Arc<NeighborSampler>,
    /// If true, apply Theorem 4.12's rejection correction at every step.
    pub exact_steps: bool,
}

/// One walker's in-flight state in the frontier engine: which vertex it
/// stands on, how many steps remain, and where its current descent is.
struct Frontier {
    /// Current vertex (the descent source).
    pos: usize,
    /// Walk steps still to take (including the one in flight).
    steps_left: usize,
    /// Current node of the in-flight descent.
    node: usize,
    /// Accumulated branch probability of the in-flight descent.
    prob: f64,
    /// Accept-tested proposals spent on the in-flight step (exact mode).
    proposals_used: usize,
    /// This walker's private stream (forked from the caller's in order).
    rng: Rng,
    /// Recorded trajectory (`Some` only for `trajectory_batch`).
    path: Option<Vec<usize>>,
}

impl RandomWalker {
    /// Plain walker: every step is one Algorithm 4.11 neighbor sample.
    pub fn new(neighbors: Arc<NeighborSampler>) -> Self {
        RandomWalker { neighbors, exact_steps: false }
    }

    /// Exact-mode walker: every step applies Theorem 4.12's rejection
    /// correction against true kernel weights.
    pub fn exact(neighbors: Arc<NeighborSampler>) -> Self {
        RandomWalker { neighbors, exact_steps: true }
    }

    /// One walk step from `v`: the exact (rejection-corrected) or plain
    /// neighbor sample, shared by `walk` AND `trajectory` so both honor
    /// `exact_steps`. A `None` from the sampler (degenerate n <= 1, or an
    /// all-zero-mass leaf) leaves the walker in place.
    fn step(&self, v: usize, rng: &mut Rng) -> usize {
        if self.exact_steps {
            match self.neighbors.sample_exact(v, rng, EXACT_PROPOSALS) {
                Some((j, _)) => j,
                None => v,
            }
        } else {
            match self.neighbors.sample(v, rng) {
                Some(s) => s.neighbor,
                None => v,
            }
        }
    }

    /// Run a `t`-step walk from `start`; returns the endpoint.
    pub fn walk(&self, start: usize, t: usize, rng: &mut Rng) -> usize {
        let mut v = start;
        for _ in 0..t {
            v = self.step(v, rng);
        }
        v
    }

    /// Run a walk and return the full trajectory including the start.
    /// Routes through the same step function as [`walk`](Self::walk), so
    /// an `exact()` walker records rejection-corrected positions.
    pub fn trajectory(&self, start: usize, t: usize, rng: &mut Rng) -> Vec<usize> {
        let mut path = Vec::with_capacity(t + 1);
        let mut v = start;
        path.push(v);
        for _ in 0..t {
            v = self.step(v, rng);
            path.push(v);
        }
        path
    }

    /// Frontier-batched [`walk`](Self::walk): advance all `starts.len()`
    /// walkers in lockstep, resolving every round's neighbor-descent
    /// queries through one fused multi-group tree call. Returns the
    /// endpoints in `starts` order.
    ///
    /// Walker `k` draws from the `k`-th stream forked off `rng`, so the
    /// result equals calling `walk(starts[k], t, &mut fork_k)`
    /// sequentially with those forks — bit for bit, since oracle answers
    /// are deterministic and memoized — while the whole batch's backend
    /// dispatches collapse into O(per-round submissions) instead of one
    /// descent at a time.
    pub fn walk_batch(&self, starts: &[usize], t: usize, rng: &mut Rng) -> Vec<usize> {
        self.run_frontier(starts, t, rng, false)
            .into_iter()
            .map(|(end, _)| end)
            .collect()
    }

    /// Frontier-batched [`trajectory`](Self::trajectory): full paths
    /// (start included) for all walkers, same engine and RNG semantics as
    /// [`walk_batch`](Self::walk_batch).
    pub fn trajectory_batch(&self, starts: &[usize], t: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
        self.run_frontier(starts, t, rng, true)
            .into_iter()
            .map(|(_, path)| match path {
                Some(p) => p,
                // `record = true` above makes the engine keep every path.
                None => unreachable!("recording was requested"),
            })
            .collect()
    }

    /// The frontier engine: one entry per walker, advanced round by round.
    /// Each round touches every active walker's current descent node once;
    /// all of the round's child-mass (and exact-mode denominator) queries
    /// resolve through ONE `query_points_multi` call whose misses the
    /// adaptive planner packs into shared padded submissions across
    /// whatever mix of tree levels the frontier occupies.
    fn run_frontier(
        &self,
        starts: &[usize],
        t: usize,
        rng: &mut Rng,
        record: bool,
    ) -> Vec<(usize, Option<Vec<usize>>)> {
        let ns = &self.neighbors;
        let tree = &ns.tree;
        let root = tree.root();
        let mut ws: Vec<Frontier> = starts
            .iter()
            .map(|&s| Frontier {
                pos: s,
                steps_left: t,
                node: root,
                prob: 1.0,
                proposals_used: 0,
                rng: rng.fork(),
                path: if record {
                    let mut p = Vec::with_capacity(t + 1);
                    p.push(s);
                    Some(p)
                } else {
                    None
                },
            })
            .collect();
        let root_node = tree.node(root);
        if root_node.hi - root_node.lo <= 1 {
            // Degenerate n <= 1: every sampler call returns None, so every
            // step stays put (mirrors the sequential paths).
            for w in &mut ws {
                if let Some(p) = &mut w.path {
                    for _ in 0..t {
                        p.push(w.pos);
                    }
                }
            }
            return ws.into_iter().map(|w| (w.pos, w.path)).collect();
        }
        let finish = ns.finish_size();
        let mut active: Vec<usize> = if t > 0 { (0..ws.len()).collect() } else { Vec::new() };
        while !active.is_empty() {
            // Group the frontier by descent node (deterministic order).
            active.sort_by_key(|&w| (ws[w].node, w));
            let mut runs: Vec<(usize, usize, usize)> = Vec::new();
            let mut a0 = 0usize;
            while a0 < active.len() {
                let id = ws[active[a0]].node;
                let mut a1 = a0;
                while a1 < active.len() && ws[active[a1]].node == id {
                    a1 += 1;
                }
                runs.push((id, a0, a1));
                a0 = a1;
            }
            // Collect the WHOLE round's query groups — both children of
            // every internal run, plus the root-mass denominators exact
            // mode needs — and resolve them in one fused multi call.
            let mut qgroups: Vec<(usize, Vec<usize>)> = Vec::new();
            for &(id, a0, a1) in &runs {
                let srcs: Vec<usize> = active[a0..a1].iter().map(|&w| ws[w].pos).collect();
                if self.exact_steps && id == root {
                    qgroups.push((root, srcs.clone()));
                }
                let node = tree.node(id);
                if node.hi - node.lo > finish {
                    let (l, r) = node.children();
                    qgroups.push((l, srcs.clone()));
                    qgroups.push((r, srcs));
                }
            }
            let refs: Vec<(usize, &[usize])> =
                qgroups.iter().map(|(id, v)| (*id, v.as_slice())).collect();
            let answers = tree.query_points_multi(&refs);
            // Advance every walker one level (or finish its step).
            let mut next: Vec<usize> = Vec::with_capacity(active.len());
            let mut qi = 0usize;
            for &(id, a0, a1) in &runs {
                if self.exact_steps && id == root {
                    // Denominator group: consumed from the cache at accept
                    // time; resolving it here kept the round fused.
                    qi += 1;
                }
                let node = tree.node(id);
                if node.hi - node.lo <= finish {
                    for &wi in &active[a0..a1] {
                        let (pos, prob) = (ws[wi].pos, ws[wi].prob);
                        match ns.leaf_finish(id, pos, &mut ws[wi].rng) {
                            Some((j, p)) => {
                                let prop = prob * p;
                                self.resolve_proposal(&mut ws[wi], j, prop, root, wi, &mut next);
                            }
                            None => Self::complete_step(&mut ws[wi], None, root, wi, &mut next),
                        }
                    }
                } else {
                    let (l, r) = node.children();
                    let (raw_l, raw_r) = (&answers[qi], &answers[qi + 1]);
                    qi += 2;
                    for (gi, &wi) in active[a0..a1].iter().enumerate() {
                        let i = ws[wi].pos;
                        let a = ns.side_mass_value(l, i, raw_l[gi]);
                        let b = ns.side_mass_value(r, i, raw_r[gi]);
                        match ns.branch(l, r, i, a, b, &mut ws[wi].rng) {
                            Some((nid, p)) => {
                                ws[wi].node = nid;
                                ws[wi].prob *= p;
                                next.push(wi);
                            }
                            None => Self::complete_step(&mut ws[wi], None, root, wi, &mut next),
                        }
                    }
                }
            }
            active = next;
        }
        ws.into_iter().map(|w| (w.pos, w.path)).collect()
    }

    /// A completed descent proposed neighbor `j` with full descent
    /// probability `prob`. Plain mode takes the step; exact mode runs
    /// Theorem 4.12's accept test (the same draws, in the same stream
    /// order, as the sequential `sample_exact`), restarting the descent on
    /// rejection and falling back to an unconditional proposal after
    /// [`EXACT_PROPOSALS`] rejections.
    fn resolve_proposal(
        &self,
        w: &mut Frontier,
        j: usize,
        prob: f64,
        root: usize,
        wi: usize,
        next: &mut Vec<usize>,
    ) {
        if !self.exact_steps {
            Self::complete_step(w, Some(j), root, wi, next);
            return;
        }
        if w.proposals_used < EXACT_PROPOSALS {
            w.proposals_used += 1;
            let tree = &self.neighbors.tree;
            let i = w.pos;
            // Same normalizer as the sequential path: the memoized root
            // answer (a cache hit — the round that started this step
            // resolved it through the fused call), minus the self-term.
            let denom = (tree.query_point(root, i) - 1.0).max(1e-12);
            let true_w = tree.kernel.eval(tree.ds.point(i), tree.ds.point(j)) as f64;
            let ratio = (true_w / denom) / (2.0 * prob);
            if w.rng.f64() < ratio.min(1.0) {
                Self::complete_step(w, Some(j), root, wi, next);
            } else {
                // Rejected: restart the descent for the same step.
                w.node = root;
                w.prob = 1.0;
                next.push(wi);
            }
        } else {
            // Fallback proposal after EXACT_PROPOSALS rejections: taken
            // unconditionally, no accept draw (mirrors `sample_exact`).
            Self::complete_step(w, Some(j), root, wi, next);
        }
    }

    /// Finish walker `wi`'s current step at `to` (or in place on `None`),
    /// record the trajectory point, and re-arm the next step's descent.
    fn complete_step(
        w: &mut Frontier,
        to: Option<usize>,
        root: usize,
        wi: usize,
        next: &mut Vec<usize>,
    ) {
        if let Some(j) = to {
            w.pos = j;
        }
        if let Some(p) = &mut w.path {
            p.push(w.pos);
        }
        w.steps_left -= 1;
        if w.steps_left > 0 {
            w.node = root;
            w.prob = 1.0;
            w.proposals_used = 0;
            next.push(wi);
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::kde::multilevel::MultiLevelKde;
    use crate::kde::{KdeConfig, KdeCounters};
    use crate::kernel::dataset::gaussian_mixture;
    use crate::kernel::Kernel;
    use crate::linalg::Mat;
    use crate::runtime::backend::CpuBackend;

    fn build(n: usize, seed: u64) -> (RandomWalker, Arc<crate::kernel::Dataset>) {
        build_cfg(n, seed, KdeConfig::exact())
    }

    fn build_cfg(
        n: usize,
        seed: u64,
        cfg: KdeConfig,
    ) -> (RandomWalker, Arc<crate::kernel::Dataset>) {
        let mut rng = Rng::new(seed);
        let ds = Arc::new(gaussian_mixture(n, 3, 2, 1.2, 0.5, &mut rng));
        let tree = Arc::new(MultiLevelKde::build(
            ds.clone(),
            Kernel::Laplacian,
            &cfg,
            CpuBackend::new(),
            KdeCounters::new(),
        ));
        (RandomWalker::new(Arc::new(NeighborSampler::new(tree))), ds)
    }

    /// Exact t-step endpoint distribution via dense transition matrix.
    fn exact_walk_dist(ds: &crate::kernel::Dataset, start: usize, t: usize) -> Vec<f64> {
        let n = ds.n;
        let mut m = Mat::zeros(n, n); // column-stochastic M = A D^{-1}
        for j in 0..n {
            let deg = ds.exact_degree(Kernel::Laplacian, j);
            for i in 0..n {
                if i != j {
                    m[(i, j)] =
                        Kernel::Laplacian.eval(ds.point(i), ds.point(j)) as f64 / deg;
                }
            }
        }
        let mut p = vec![0.0; n];
        p[start] = 1.0;
        for _ in 0..t {
            p = m.matvec(&p);
        }
        p
    }

    #[test]
    fn trajectory_has_no_self_steps_and_right_length() {
        let (w, _) = build(20, 121);
        let mut rng = Rng::new(123);
        let path = w.trajectory(4, 10, &mut rng);
        assert_eq!(path.len(), 11);
        for i in 0..10 {
            assert_ne!(path[i], path[i + 1], "self step at {i}");
        }
    }

    #[test]
    fn endpoint_distribution_matches_exact_markov_chain() {
        let (w, ds) = build(12, 125);
        let start = 2;
        let t = 3;
        let want = exact_walk_dist(&ds, start, t);
        let mut rng = Rng::new(127);
        let trials = 60_000;
        let mut counts = vec![0f64; ds.n];
        for _ in 0..trials {
            counts[w.walk(start, t, &mut rng)] += 1.0;
        }
        let tv = crate::util::stats::tv_distance(&counts, &want);
        assert!(tv < 0.03, "walk endpoint TV {tv}");
    }

    #[test]
    fn zero_step_walk_stays_put() {
        let (w, _) = build(8, 129);
        let mut rng = Rng::new(131);
        assert_eq!(w.walk(5, 0, &mut rng), 5);
    }

    #[test]
    fn exact_trajectory_last_matches_exact_walk_same_seed() {
        // The satellite regression: `trajectory` must route through the
        // SAME step function as `walk`, so from identical rng streams an
        // exact() walker's trajectory endpoint equals its walk endpoint.
        // (Before the fix, trajectory silently recorded approximate steps.)
        let (plain, _) = build(31, 133);
        let exact = RandomWalker::exact(plain.neighbors.clone());
        for seed in [1u64, 7, 991] {
            let path = exact.trajectory(3, 12, &mut Rng::new(seed));
            let end = exact.walk(3, 12, &mut Rng::new(seed));
            assert_eq!(*path.last().unwrap(), end, "seed {seed}");
            let ppath = plain.trajectory(3, 12, &mut Rng::new(seed));
            let pend = plain.walk(3, 12, &mut Rng::new(seed));
            assert_eq!(*ppath.last().unwrap(), pend, "plain seed {seed}");
        }
    }

    #[test]
    fn exact_trajectory_replays_sample_exact() {
        // An exact walker's trajectory is exactly the sequence of
        // `sample_exact` outcomes from the same stream.
        let (plain, _) = build(29, 135);
        let exact = RandomWalker::exact(plain.neighbors.clone());
        let got = exact.trajectory(5, 15, &mut Rng::new(777));
        let mut rng = Rng::new(777);
        let mut v = 5usize;
        let mut want = vec![v];
        for _ in 0..15 {
            if let Some((j, _)) = exact.neighbors.sample_exact(v, &mut rng, 16) {
                v = j;
            }
            want.push(v);
        }
        assert_eq!(got, want, "trajectory must apply the rejection correction");
    }

    #[test]
    fn exact_and_plain_trajectories_diverge() {
        // The rejection correction consumes accept draws (ratio ~ 1/2 with
        // the c = 2 slack), so from the same seed the exact and plain
        // streams diverge essentially immediately; identical 20-step
        // trajectories would mean exact_steps is being ignored.
        let (plain, _) = build(31, 137);
        let exact = RandomWalker::exact(plain.neighbors.clone());
        let a = exact.trajectory(0, 20, &mut Rng::new(42));
        let b = plain.trajectory(0, 20, &mut Rng::new(42));
        assert_ne!(a, b, "exact trajectory ignored the rejection correction");
    }

    #[test]
    fn walk_batch_matches_sequential_forked_streams() {
        // The frontier engine's contract: walker k's endpoint equals the
        // sequential walk driven by the k-th stream forked off the same
        // rng — bit for bit (deterministic memoized oracles).
        let (w, _) = build(60, 139);
        let starts: Vec<usize> = (0..37).map(|k| (k * 13) % 60).collect();
        let t = 9;
        let got = w.walk_batch(&starts, t, &mut Rng::new(5151));
        let mut seq_rng = Rng::new(5151);
        let forks: Vec<Rng> = starts.iter().map(|_| seq_rng.fork()).collect();
        for (k, mut fork) in forks.into_iter().enumerate() {
            let want = w.walk(starts[k], t, &mut fork);
            assert_eq!(got[k], want, "walker {k} diverged from its stream");
        }
    }

    #[test]
    fn exact_walk_batch_matches_sequential_forked_streams() {
        // Same contract in exact mode: the frontier's rejection rounds
        // consume the per-walker streams exactly like `sample_exact`.
        let (plain, _) = build_cfg(
            48,
            141,
            KdeConfig {
                kind: crate::kde::EstimatorKind::Sampling { eps: 0.4, tau: 0.2 },
                leaf_cutoff: 8,
                seed: 0x33,
            },
        );
        let w = RandomWalker::exact(plain.neighbors.clone());
        let starts: Vec<usize> = (0..21).map(|k| (k * 5) % 48).collect();
        let t = 6;
        let got = w.walk_batch(&starts, t, &mut Rng::new(616));
        let mut seq_rng = Rng::new(616);
        let forks: Vec<Rng> = starts.iter().map(|_| seq_rng.fork()).collect();
        for (k, mut fork) in forks.into_iter().enumerate() {
            let want = w.walk(starts[k], t, &mut fork);
            assert_eq!(got[k], want, "exact walker {k} diverged from its stream");
        }
    }

    #[test]
    fn trajectory_batch_matches_sequential_and_walk_batch() {
        let (w, _) = build(40, 143);
        let starts = [0usize, 17, 17, 39, 5];
        let t = 7;
        let paths = w.trajectory_batch(&starts, t, &mut Rng::new(808));
        let ends = w.walk_batch(&starts, t, &mut Rng::new(808));
        let mut seq_rng = Rng::new(808);
        let forks: Vec<Rng> = starts.iter().map(|_| seq_rng.fork()).collect();
        for (k, mut fork) in forks.into_iter().enumerate() {
            let want = w.trajectory(starts[k], t, &mut fork);
            assert_eq!(paths[k], want, "walker {k} path diverged");
            assert_eq!(paths[k].len(), t + 1);
            assert_eq!(paths[k][0], starts[k]);
            assert_eq!(*paths[k].last().unwrap(), ends[k]);
        }
    }

    #[test]
    fn walk_batch_edges() {
        let (w, _) = build(16, 145);
        // Zero steps: endpoints are the starts, trajectories length 1.
        let starts = [3usize, 9];
        assert_eq!(w.walk_batch(&starts, 0, &mut Rng::new(1)), vec![3, 9]);
        let paths = w.trajectory_batch(&starts, 0, &mut Rng::new(1));
        assert_eq!(paths, vec![vec![3], vec![9]]);
        // Empty batch.
        assert!(w.walk_batch(&[], 5, &mut Rng::new(2)).is_empty());
        // Single walker (W = 1) still works through the frontier.
        let got = w.walk_batch(&[7], 4, &mut Rng::new(3));
        let mut seq = Rng::new(3);
        let mut fork = seq.fork();
        assert_eq!(got[0], w.walk(7, 4, &mut fork));
    }

    #[test]
    fn walk_batch_endpoint_distribution_matches_markov_chain() {
        // Statistical sanity on top of the bit-level stream equivalence.
        let (w, ds) = build(12, 147);
        let (start, t) = (4usize, 3usize);
        let want = exact_walk_dist(&ds, start, t);
        let mut rng = Rng::new(149);
        let trials = 60_000usize;
        let mut counts = vec![0f64; ds.n];
        let batch = 2_000;
        for _ in 0..trials / batch {
            let starts = vec![start; batch];
            for end in w.walk_batch(&starts, t, &mut rng) {
                counts[end] += 1.0;
            }
        }
        let tv = crate::util::stats::tv_distance(&counts, &want);
        assert!(tv < 0.03, "batched walk endpoint TV {tv}");
    }
}
