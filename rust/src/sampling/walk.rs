//! Random walks on the kernel graph: Algorithm 4.16 / Theorem 4.15.
//!
//! A T-step walk is T sequential neighbor samples; each step costs
//! O(log n) KDE queries (cache-cold) and the endpoint distribution is
//! within O(T eps) TV of the true walk distribution.

use std::sync::Arc;

use crate::sampling::neighbor::NeighborSampler;
use crate::util::rng::Rng;

pub struct RandomWalker {
    pub neighbors: Arc<NeighborSampler>,
    /// If true, apply Theorem 4.12's rejection correction at every step.
    pub exact_steps: bool,
}

impl RandomWalker {
    pub fn new(neighbors: Arc<NeighborSampler>) -> Self {
        RandomWalker { neighbors, exact_steps: false }
    }

    pub fn exact(neighbors: Arc<NeighborSampler>) -> Self {
        RandomWalker { neighbors, exact_steps: true }
    }

    /// Run a `t`-step walk from `start`; returns the endpoint.
    pub fn walk(&self, start: usize, t: usize, rng: &mut Rng) -> usize {
        let mut v = start;
        for _ in 0..t {
            v = if self.exact_steps {
                match self.neighbors.sample_exact(v, rng, 16) {
                    Some((j, _)) => j,
                    None => v,
                }
            } else {
                match self.neighbors.sample(v, rng) {
                    Some(s) => s.neighbor,
                    None => v,
                }
            };
        }
        v
    }

    /// Run a walk and return the full trajectory including the start.
    pub fn trajectory(&self, start: usize, t: usize, rng: &mut Rng) -> Vec<usize> {
        let mut path = Vec::with_capacity(t + 1);
        let mut v = start;
        path.push(v);
        for _ in 0..t {
            if let Some(s) = self.neighbors.sample(v, rng) {
                v = s.neighbor;
            }
            path.push(v);
        }
        path
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kde::multilevel::MultiLevelKde;
    use crate::kde::{KdeConfig, KdeCounters};
    use crate::kernel::dataset::gaussian_mixture;
    use crate::kernel::Kernel;
    use crate::linalg::Mat;
    use crate::runtime::backend::CpuBackend;

    fn build(n: usize, seed: u64) -> (RandomWalker, Arc<crate::kernel::Dataset>) {
        let mut rng = Rng::new(seed);
        let ds = Arc::new(gaussian_mixture(n, 3, 2, 1.2, 0.5, &mut rng));
        let tree = Arc::new(MultiLevelKde::build(
            ds.clone(),
            Kernel::Laplacian,
            &KdeConfig::exact(),
            CpuBackend::new(),
            KdeCounters::new(),
        ));
        (RandomWalker::new(Arc::new(NeighborSampler::new(tree))), ds)
    }

    /// Exact t-step endpoint distribution via dense transition matrix.
    fn exact_walk_dist(ds: &crate::kernel::Dataset, start: usize, t: usize) -> Vec<f64> {
        let n = ds.n;
        let mut m = Mat::zeros(n, n); // column-stochastic M = A D^{-1}
        for j in 0..n {
            let deg = ds.exact_degree(Kernel::Laplacian, j);
            for i in 0..n {
                if i != j {
                    m[(i, j)] =
                        Kernel::Laplacian.eval(ds.point(i), ds.point(j)) as f64 / deg;
                }
            }
        }
        let mut p = vec![0.0; n];
        p[start] = 1.0;
        for _ in 0..t {
            p = m.matvec(&p);
        }
        p
    }

    #[test]
    fn trajectory_has_no_self_steps_and_right_length() {
        let (w, _) = build(20, 121);
        let mut rng = Rng::new(123);
        let path = w.trajectory(4, 10, &mut rng);
        assert_eq!(path.len(), 11);
        for i in 0..10 {
            assert_ne!(path[i], path[i + 1], "self step at {i}");
        }
    }

    #[test]
    fn endpoint_distribution_matches_exact_markov_chain() {
        let (w, ds) = build(12, 125);
        let start = 2;
        let t = 3;
        let want = exact_walk_dist(&ds, start, t);
        let mut rng = Rng::new(127);
        let trials = 60_000;
        let mut counts = vec![0f64; ds.n];
        for _ in 0..trials {
            counts[w.walk(start, t, &mut rng)] += 1.0;
        }
        let tv = crate::util::stats::tv_distance(&counts, &want);
        assert!(tv < 0.03, "walk endpoint TV {tv}");
    }

    #[test]
    fn zero_step_walk_stays_put() {
        let (w, _) = build(8, 129);
        let mut rng = Rng::new(131);
        assert_eq!(w.walk(5, 0, &mut rng), 5);
    }
}
