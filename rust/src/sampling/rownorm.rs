//! Squared-row-norm importance sampling on the kernel matrix (§5.2).
//!
//! For kernels with `k(x,y)^2 = k(cx, cy)` (Laplacian, exponential,
//! Gaussian), the squared l2 norm of row i of K equals the degree of
//! vertex i in the kernel graph of the *scaled* dataset `cX`, **plus** the
//! self term `k(x_i,x_i)^2 = 1` — so n KDE queries on `cX` give every
//! row norm, and the prefix sampler gives row samples forever after.

use std::sync::Arc;

use crate::kde::multilevel::MultiLevelKde;
use crate::kde::{KdeConfig, KdeCounters};
use crate::kernel::{Dataset, Kernel};
use crate::runtime::backend::KernelBackend;
use crate::sampling::vertex::PrefixSampler;

/// §5.2 squared-row-norm sampler over the kernel matrix (the `cX` trick).
pub struct RowNormSampler {
    /// Estimated squared row norms of K (including the diagonal term).
    pub row_norms_sq: Vec<f64>,
    sampler: PrefixSampler,
    /// KDE queries spent building the row-norm array (exactly n).
    pub build_queries: u64,
}

impl RowNormSampler {
    /// Build via n KDE queries against the scaled dataset `cX`.
    pub fn build(
        ds: &Arc<Dataset>,
        kernel: Kernel,
        cfg: &KdeConfig,
        backend: Arc<dyn KernelBackend>,
        counters: Arc<KdeCounters>,
    ) -> Self {
        // A real precondition (§5.2 needs the cX trick), not an internal
        // invariant: fail loudly with the requirement spelled out.
        let Some(c) = kernel.square_scale() else {
            panic!("kernel does not satisfy k^2(x,y) = k(cx,cy)");
        };
        let scaled = Arc::new(ds.scaled(c));
        let tree = MultiLevelKde::build(scaled, kernel, cfg, backend, counters.clone());
        let before = counters.queries();
        let n = ds.n;
        // Root queries on cX at (c x_i) = sum_j k(x_i, x_j)^2, including
        // the j = i self term (= 1), which IS part of the row norm. One
        // batched dispatch for all n rows.
        let idx: Vec<usize> = (0..n).collect();
        let row_norms_sq: Vec<f64> = tree
            .query_points(tree.root(), &idx)
            .into_iter()
            .map(|v| v.max(1e-12))
            .collect();
        let build_queries = counters.queries() - before;
        let sampler = PrefixSampler::new(&row_norms_sq);
        RowNormSampler { row_norms_sq, sampler, build_queries }
    }

    /// Sample a row index with probability ~ ||K_i||_2^2; returns
    /// `(row, probability)`.
    pub fn sample(&self, rng: &mut crate::util::rng::Rng) -> (usize, f64) {
        let i = self.sampler.sample(rng);
        (i, self.sampler.prob(i))
    }

    /// Probability this sampler assigns to row `i`.
    pub fn prob(&self, i: usize) -> f64 {
        self.sampler.prob(i)
    }

    /// Estimated ||K||_F^2 (sum of the row-norm estimates).
    pub fn frob_norm_sq(&self) -> f64 {
        self.sampler.total()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::kernel::dataset::gaussian_mixture;
    use crate::runtime::backend::CpuBackend;
    use crate::util::rng::Rng;

    fn exact_row_norm_sq(ds: &Dataset, k: Kernel, i: usize) -> f64 {
        (0..ds.n)
            .map(|j| {
                let v = k.eval(ds.point(i), ds.point(j)) as f64;
                v * v
            })
            .sum()
    }

    #[test]
    fn exact_oracle_matches_true_row_norms() {
        let mut rng = Rng::new(141);
        let ds = Arc::new(gaussian_mixture(40, 4, 2, 1.0, 0.5, &mut rng));
        for k in [Kernel::Laplacian, Kernel::Gaussian, Kernel::Exponential] {
            let rn = RowNormSampler::build(
                &ds,
                k,
                &KdeConfig::exact(),
                CpuBackend::new(),
                KdeCounters::new(),
            );
            for i in 0..ds.n {
                let want = exact_row_norm_sq(&ds, k, i);
                let got = rn.row_norms_sq[i];
                assert!(
                    (got - want).abs() < 1e-4 * (1.0 + want),
                    "{:?} row {i}: {got} vs {want}",
                    k
                );
            }
            assert_eq!(rn.build_queries, 40, "n queries upfront");
        }
    }

    #[test]
    fn sampling_frequencies_match_row_norms() {
        let mut rng = Rng::new(143);
        let ds = Arc::new(gaussian_mixture(24, 3, 2, 1.5, 0.4, &mut rng));
        let rn = RowNormSampler::build(
            &ds,
            Kernel::Gaussian,
            &KdeConfig::exact(),
            CpuBackend::new(),
            KdeCounters::new(),
        );
        let trials = 40_000;
        let mut counts = vec![0f64; 24];
        for _ in 0..trials {
            counts[rn.sample(&mut rng).0] += 1.0;
        }
        let want: Vec<f64> = (0..24)
            .map(|i| exact_row_norm_sq(&ds, Kernel::Gaussian, i))
            .collect();
        let tv = crate::util::stats::tv_distance(&counts, &want);
        assert!(tv < 0.03, "row-norm sampling TV {tv}");
    }

    #[test]
    #[should_panic(expected = "does not satisfy")]
    fn rational_quadratic_rejected() {
        let mut rng = Rng::new(145);
        let ds = Arc::new(gaussian_mixture(8, 2, 1, 0.0, 0.5, &mut rng));
        let _ = RowNormSampler::build(
            &ds,
            Kernel::RationalQuadratic,
            &KdeConfig::exact(),
            CpuBackend::new(),
            KdeCounters::new(),
        );
    }
}
