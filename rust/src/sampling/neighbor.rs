//! Weighted neighbor edge sampling: Algorithm 4.11 / Theorem 4.12.
//!
//! Given a vertex `x_i`, sample a neighbor `v` with `Pr[v = x_k] ~
//! k(x_i, x_k)` by descending the multi-level KDE tree: at each internal
//! node query the two children's KDE oracles at `x_i` (subtracting the
//! self-term when `i` falls inside a child's range) and branch
//! proportionally. O(log n) KDE queries per sample; answers are memoized
//! inside the tree so the *probability* of any concrete descent is a
//! well-defined deterministic quantity — `neighbor_prob` recomputes it
//! exactly, which Algorithm 5.1 (sparsification) requires.
//!
//! Two evaluation-shape refinements over the verbatim algorithm (both
//! distribution-preserving):
//!
//! * **Leaf finish.** Once the descent reaches a node of size <=
//!   `leaf_cutoff`, every oracle in that subtree is exact (the tree builds
//!   naive oracles below the cutoff), so continuing the binary descent
//!   telescopes to the categorical distribution `Pr[j] = k(x_i, x_j) /
//!   mass(range)`. The sampler therefore finishes such nodes in one
//!   categorical draw over the directly rescanned kernel values — the
//!   normalizer is that exact rescan (not the memoized oracle answer), so
//!   reported probability equals actual draw probability under any
//!   backend, the leaf step costs zero oracle dispatches, and the descent
//!   depth the batched path synchronizes over shrinks by
//!   log2(leaf_cutoff) levels. `neighbor_prob` applies the same single
//!   factor, keeping reported probabilities bit-identical.
//! * **Level-order batching with level fusion.**
//!   [`NeighborSampler::sample_batch`] runs many descents in lock-step:
//!   per level it groups walkers by node and resolves *every* group's two
//!   child answers in one [`MultiLevelKde::query_points_multi`] call,
//!   which coalesces all the level's cache misses across nodes into fused
//!   padded backend submissions (B = 64 rows, one packed data segment per
//!   node) — O(1) dispatches per level instead of one per (node, side),
//!   so a whole sampling round costs O(log n) backend executions
//!   (asserted in tests/fusion.rs). Each walker draws from its own forked
//!   RNG stream, so a batched round produces *exactly* the samples the
//!   sequential path produces from the same forked streams (verified in
//!   tests/batched_pipeline.rs). The frontier-batched walk engine
//!   (`RandomWalker::walk_batch`) drives the same descent primitives
//!   (`branch`, `leaf_finish`, `side_mass_value`) with *persistent*
//!   per-walker streams across T steps, coalescing every round's queries
//!   across whatever mix of tree levels its walkers occupy.

use std::sync::Arc;

use crate::kde::multilevel::MultiLevelKde;
use crate::util::rng::Rng;

/// Algorithm 4.11 neighbor sampler over a multi-level KDE tree (see the
/// module docs for the descent and its two batched evaluation shapes).
pub struct NeighborSampler {
    /// The multi-level KDE tree whose node oracles drive the descent.
    pub tree: Arc<MultiLevelKde>,
}

/// Outcome of one neighbor-sampling descent.
#[derive(Clone, Copy, Debug)]
pub struct NeighborSample {
    /// Sampled neighbor index (never equals the source).
    pub neighbor: usize,
    /// Exact probability the descent produced this neighbor (product of
    /// branch probabilities under the memoized KDE answers).
    pub prob: f64,
}

impl NeighborSampler {
    /// Wrap a multi-level KDE tree as a neighbor sampler.
    pub fn new(tree: Arc<MultiLevelKde>) -> Self {
        NeighborSampler { tree }
    }

    /// Node size at which the descent switches to the categorical finish.
    /// `pub(crate)` so the frontier-batched walk engine
    /// (`RandomWalker::walk_batch`) can drive the same descent primitives
    /// level by level.
    pub(crate) fn finish_size(&self) -> usize {
        self.tree.leaf_cutoff().max(1)
    }

    /// Self-exclude and clamp a raw node answer for source `i`.
    pub(crate) fn side_mass_value(&self, id: usize, i: usize, raw: f64) -> f64 {
        let n = self.tree.node(id);
        let mut v = raw;
        if n.lo <= i && i < n.hi {
            v -= 1.0; // remove k(x_i, x_i)
        }
        v.max(0.0)
    }

    /// Mass of node `id`'s subset as seen from source `i`, self-excluded.
    fn side_mass(&self, id: usize, i: usize) -> f64 {
        self.side_mass_value(id, i, self.tree.query_point(id, i))
    }

    /// One branching step shared by the sequential, batched and frontier
    /// descents: child masses `a`/`b` -> (chosen child, branch
    /// probability). `None` only if both subtrees are empty of candidates.
    pub(crate) fn branch(
        &self,
        l: usize,
        r: usize,
        i: usize,
        a: f64,
        b: f64,
        rng: &mut Rng,
    ) -> Option<(usize, f64)> {
        let total = a + b;
        if total <= 0.0 {
            // All mass vanished under estimation noise: fall back to a
            // size-proportional branch, excluding the source leaf.
            let nl = self.tree.node(l);
            let nr = self.tree.node(r);
            let sl = (nl.hi - nl.lo - usize::from(nl.lo <= i && i < nl.hi)) as f64;
            let sr = (nr.hi - nr.lo - usize::from(nr.lo <= i && i < nr.hi)) as f64;
            let denom = sl + sr;
            if denom <= 0.0 {
                return None;
            }
            if rng.f64() * denom < sl {
                Some((l, sl / denom))
            } else {
                Some((r, sr / denom))
            }
        } else if rng.f64() * total < a {
            Some((l, a / total))
        } else {
            Some((r, b / total))
        }
    }

    /// Exact self-excluded kernel mass of a cutoff-sized node's range,
    /// rescanned with `Kernel::eval` in index order. The categorical
    /// finish normalizes by THIS sum (not the memoized oracle answer) so
    /// the reported probability equals the actual draw probability even
    /// under an approximate backend (tiled fast-exp, PJRT) — and the leaf
    /// step needs no oracle dispatch at all. `leaf_finish` and
    /// `leaf_prob_factor` share it, keeping their factors bit-identical.
    fn leaf_mass(&self, id: usize, i: usize) -> f64 {
        let node = self.tree.node(id);
        let ds = &self.tree.ds;
        let kernel = self.tree.kernel;
        let mut s = 0.0f64;
        for j in node.lo..node.hi {
            if j != i {
                s += kernel.eval(ds.point(i), ds.point(j)) as f64;
            }
        }
        s
    }

    /// Categorical finish at a cutoff-sized node: draw `j` in the node's
    /// range (excluding `i`) with `Pr[j] = k(x_i, x_j) / mass`, returning
    /// `(j, that factor)`. The node's subtree oracles are exact, so this
    /// equals the distribution of descending the remaining levels.
    pub(crate) fn leaf_finish(&self, id: usize, i: usize, rng: &mut Rng) -> Option<(usize, f64)> {
        let node = self.tree.node(id);
        let mass = self.leaf_mass(id, i);
        if mass <= 0.0 {
            // Degenerate mass: uniform over the range excluding the source
            // (mirrors the size-proportional internal fallback).
            let cnt = node.hi - node.lo - usize::from(node.lo <= i && i < node.hi);
            if cnt == 0 {
                return None;
            }
            let mut pick = (rng.f64() * cnt as f64) as usize;
            if pick >= cnt {
                pick = cnt - 1;
            }
            let mut seen = 0usize;
            for j in node.lo..node.hi {
                if j == i {
                    continue;
                }
                if seen == pick {
                    return Some((j, 1.0 / cnt as f64));
                }
                seen += 1;
            }
            return None;
        }
        let ds = &self.tree.ds;
        let kernel = self.tree.kernel;
        let target = rng.f64() * mass;
        let mut acc = 0.0f64;
        let mut last: Option<(usize, f64)> = None;
        for j in node.lo..node.hi {
            if j == i {
                continue;
            }
            let k = kernel.eval(ds.point(i), ds.point(j)) as f64;
            if k > 0.0 {
                // mass > 0 guarantees at least one positive weight (mass
                // sums these same evaluations), so tracking only positive
                // candidates keeps reported probs > 0.
                last = Some((j, k));
            }
            acc += k;
            if target < acc {
                return Some((j, k / mass));
            }
        }
        // target < mass and acc reaches mass on the final element, so this
        // is pure float-edge insurance: settle on the last positive
        // candidate with its true factor.
        last.map(|(j, k)| (j, k / mass))
    }

    /// Probability factor the categorical finish assigns to target `j`
    /// (the exact counterpart of `leaf_finish`'s reported factor).
    fn leaf_prob_factor(&self, id: usize, i: usize, j: usize) -> f64 {
        let node = self.tree.node(id);
        debug_assert!(node.lo <= j && j < node.hi && j != i);
        let mass = self.leaf_mass(id, i);
        if mass <= 0.0 {
            let cnt = node.hi - node.lo - usize::from(node.lo <= i && i < node.hi);
            if cnt == 0 {
                return 0.0;
            }
            return 1.0 / cnt as f64;
        }
        self.tree.kernel.eval(self.tree.ds.point(i), self.tree.ds.point(j)) as f64 / mass
    }

    /// Algorithm 4.11. Returns the sampled neighbor and its exact descent
    /// probability. Returns `None` only in the degenerate n = 1 case.
    pub fn sample(&self, i: usize, rng: &mut Rng) -> Option<NeighborSample> {
        let mut id = self.tree.root();
        if self.tree.node(id).hi - self.tree.node(id).lo <= 1 {
            return None;
        }
        let finish = self.finish_size();
        let mut prob = 1.0f64;
        loop {
            let node = self.tree.node(id);
            if node.hi - node.lo <= finish {
                let (j, p) = self.leaf_finish(id, i, rng)?;
                return Some(NeighborSample { neighbor: j, prob: prob * p });
            }
            let (l, r) = node.children();
            let a = self.side_mass(l, i);
            let b = self.side_mass(r, i);
            let (next, p) = self.branch(l, r, i, a, b, rng)?;
            prob *= p;
            id = next;
        }
    }

    /// Group the level's sorted walkers into per-node `(id, g0, g1)` runs.
    fn level_groups(active: &[(usize, usize, f64)]) -> Vec<(usize, usize, usize)> {
        let mut bounds = Vec::new();
        let mut g0 = 0usize;
        while g0 < active.len() {
            let id = active[g0].1;
            let mut g1 = g0;
            while g1 < active.len() && active[g1].1 == id {
                g1 += 1;
            }
            bounds.push((id, g0, g1));
            g0 = g1;
        }
        bounds
    }

    /// Collect both children's query groups for every internal-node run
    /// and resolve the WHOLE level through one
    /// [`MultiLevelKde::query_points_multi`] call (the level-fused
    /// dispatch). Returns the per-group answers, two consecutive entries
    /// (left, right) per internal group in `bounds` order.
    fn level_answers(
        &self,
        bounds: &[(usize, usize, usize)],
        active: &[(usize, usize, f64)],
        source_of: impl Fn(usize) -> usize,
        finish: usize,
    ) -> Vec<Vec<f64>> {
        let mut qgroups: Vec<(usize, Vec<usize>)> = Vec::new();
        for &(id, g0, g1) in bounds {
            let node = self.tree.node(id);
            if node.hi - node.lo > finish {
                let srcs: Vec<usize> =
                    active[g0..g1].iter().map(|&(w, _, _)| source_of(w)).collect();
                let (l, r) = node.children();
                qgroups.push((l, srcs.clone()));
                qgroups.push((r, srcs));
            }
        }
        let refs: Vec<(usize, &[usize])> =
            qgroups.iter().map(|(id, v)| (*id, v.as_slice())).collect();
        self.tree.query_points_multi(&refs)
    }

    /// Batched Algorithm 4.11: run one descent per entry of `sources` in
    /// level-order lock-step, grouping walkers by node and resolving every
    /// level's child answers in ONE fused multi-group call — O(1) backend
    /// dispatches per level instead of one per (node, side).
    ///
    /// Each walker draws from its own stream forked off `rng` in source
    /// order, so the result is *identical* to calling [`Self::sample`]
    /// sequentially with the same forked streams (deterministic oracles),
    /// while issuing a small fraction of the backend dispatches.
    pub fn sample_batch(&self, sources: &[usize], rng: &mut Rng) -> Vec<Option<NeighborSample>> {
        let mut rngs: Vec<Rng> = sources.iter().map(|_| rng.fork()).collect();
        self.sample_batch_with_streams(sources, &mut rngs)
    }

    /// [`Self::sample_batch`] with caller-owned per-walker streams: walker
    /// `k` draws from `rngs[k]`, exactly as `sample(sources[k], &mut
    /// rngs[k])` would, so the batch is bit-identical to those sequential
    /// calls while the descents advance in fused lock-step. This is the
    /// entry the frontier-batched edge engine
    /// ([`EdgeSampler::sample_batch`](crate::sampling::EdgeSampler::sample_batch))
    /// uses: each edge's stream has already consumed its degree draw, and
    /// the descent must continue on that same stream for the batched edge
    /// to replay the sequential one.
    pub fn sample_batch_with_streams(
        &self,
        sources: &[usize],
        rngs: &mut [Rng],
    ) -> Vec<Option<NeighborSample>> {
        assert_eq!(sources.len(), rngs.len(), "one stream per walker");
        let n = sources.len();
        let mut out: Vec<Option<NeighborSample>> = vec![None; n];
        // One overlap epoch per batch descent: every level's fused round
        // reuses the tree's persistent packer pipeline (cross-round
        // overlap) instead of spawning a packer per round.
        let _epoch = self.tree.overlap_epoch();
        let root = self.tree.root();
        if self.tree.node(root).hi - self.tree.node(root).lo <= 1 {
            return out;
        }
        let finish = self.finish_size();
        // (walker, node, accumulated probability)
        let mut active: Vec<(usize, usize, f64)> = (0..n).map(|w| (w, root, 1.0f64)).collect();
        while !active.is_empty() {
            // Group by node id; deterministic order so HBE-style stateful
            // oracles see a reproducible first-query order.
            active.sort_by_key(|&(w, id, _)| (id, w));
            let bounds = Self::level_groups(&active);
            let answers = self.level_answers(&bounds, &active, |w| sources[w], finish);
            let mut next: Vec<(usize, usize, f64)> = Vec::with_capacity(active.len());
            let mut qi = 0usize;
            for &(id, g0, g1) in &bounds {
                let group = &active[g0..g1];
                let node = self.tree.node(id);
                if node.hi - node.lo <= finish {
                    // The categorical finish rescans the (cutoff-sized)
                    // range directly — no oracle dispatch needed.
                    for &(w, _, prob) in group {
                        out[w] = self
                            .leaf_finish(id, sources[w], &mut rngs[w])
                            .map(|(j, p)| NeighborSample { neighbor: j, prob: prob * p });
                    }
                } else {
                    let (l, r) = node.children();
                    let (raw_l, raw_r) = (&answers[qi], &answers[qi + 1]);
                    qi += 2;
                    for (gi, &(w, _, prob)) in group.iter().enumerate() {
                        let i = sources[w];
                        let a = self.side_mass_value(l, i, raw_l[gi]);
                        let b = self.side_mass_value(r, i, raw_r[gi]);
                        match self.branch(l, r, i, a, b, &mut rngs[w]) {
                            Some((nid, p)) => next.push((w, nid, prob * p)),
                            None => out[w] = None,
                        }
                    }
                }
            }
            active = next;
        }
        out
    }

    /// Deterministic probability that `sample(i)` returns `j` (the product
    /// of branch probabilities along the root-to-j path, under the same
    /// memoized KDE answers the sampler used). Algorithm 5.1 step (c)/(d).
    pub fn neighbor_prob(&self, i: usize, j: usize) -> f64 {
        assert_ne!(i, j, "a vertex is not its own neighbor");
        let finish = self.finish_size();
        let mut id = self.tree.root();
        let mut prob = 1.0f64;
        loop {
            let node = self.tree.node(id);
            if node.hi - node.lo <= finish {
                return prob * self.leaf_prob_factor(id, i, j);
            }
            let (l, r) = node.children();
            let a = self.side_mass(l, i);
            let b = self.side_mass(r, i);
            let total = a + b;
            let nl = self.tree.node(l);
            let goes_left = nl.lo <= j && j < nl.hi;
            if total <= 0.0 {
                let nr = self.tree.node(r);
                let sl = (nl.hi - nl.lo - usize::from(nl.lo <= i && i < nl.hi)) as f64;
                let sr = (nr.hi - nr.lo - usize::from(nr.lo <= i && i < nr.hi)) as f64;
                let denom = sl + sr;
                if denom <= 0.0 {
                    return 0.0;
                }
                prob *= if goes_left { sl / denom } else { sr / denom };
            } else {
                prob *= if goes_left { a / total } else { b / total };
            }
            id = if goes_left { l } else { r };
        }
    }

    /// Batched [`Self::neighbor_prob`] over `(source, target)` pairs, with
    /// the same level-order grouping and level fusion as `sample_batch`
    /// (the descents are deterministic — no RNG — so this is purely a
    /// dispatch-shape win).
    pub fn neighbor_prob_batch(&self, pairs: &[(usize, usize)]) -> Vec<f64> {
        let n = pairs.len();
        let mut out = vec![0.0f64; n];
        if n == 0 {
            return out;
        }
        let _epoch = self.tree.overlap_epoch();
        let finish = self.finish_size();
        let root = self.tree.root();
        let mut active: Vec<(usize, usize, f64)> = (0..n)
            .map(|w| {
                let (i, j) = pairs[w];
                assert_ne!(i, j, "a vertex is not its own neighbor");
                (w, root, 1.0f64)
            })
            .collect();
        while !active.is_empty() {
            active.sort_by_key(|&(w, id, _)| (id, w));
            let bounds = Self::level_groups(&active);
            let answers = self.level_answers(&bounds, &active, |w| pairs[w].0, finish);
            let mut next: Vec<(usize, usize, f64)> = Vec::with_capacity(active.len());
            let mut qi = 0usize;
            for &(id, g0, g1) in &bounds {
                let group = &active[g0..g1];
                let node = self.tree.node(id);
                if node.hi - node.lo <= finish {
                    for &(w, _, prob) in group {
                        let (i, j) = pairs[w];
                        out[w] = prob * self.leaf_prob_factor(id, i, j);
                    }
                } else {
                    let (l, r) = node.children();
                    let (raw_l, raw_r) = (&answers[qi], &answers[qi + 1]);
                    qi += 2;
                    let nl = self.tree.node(l);
                    let nr = self.tree.node(r);
                    for (gi, &(w, _, prob)) in group.iter().enumerate() {
                        let (i, j) = pairs[w];
                        let a = self.side_mass_value(l, i, raw_l[gi]);
                        let b = self.side_mass_value(r, i, raw_r[gi]);
                        let total = a + b;
                        let goes_left = nl.lo <= j && j < nl.hi;
                        let factor = if total <= 0.0 {
                            let sl =
                                (nl.hi - nl.lo - usize::from(nl.lo <= i && i < nl.hi)) as f64;
                            let sr =
                                (nr.hi - nr.lo - usize::from(nr.lo <= i && i < nr.hi)) as f64;
                            let denom = sl + sr;
                            if denom <= 0.0 {
                                out[w] = 0.0;
                                continue;
                            }
                            if goes_left {
                                sl / denom
                            } else {
                                sr / denom
                            }
                        } else if goes_left {
                            a / total
                        } else {
                            b / total
                        };
                        next.push((w, if goes_left { l } else { r }, prob * factor));
                    }
                }
            }
            active = next;
        }
        out
    }

    /// Single-round [`Self::neighbor_prob_batch`]: because the reverse
    /// descent's branching is fully determined by the *target* (`goes_left
    /// = nl.lo <= j && j < nl.hi` does not depend on any KDE answer), every
    /// pair's root-to-cutoff path is known up front — so ALL (child node,
    /// source) probe groups across every level of every pair collapse into
    /// ONE [`MultiLevelKde::query_points_multi`] round (the adaptive
    /// planner packs the mixed-level segments; [`MultiLevelKde::multi_calls`]
    /// ticks once instead of once per level). Probes are grouped per level
    /// in `(node, pair)` order — the same first-query order
    /// `neighbor_prob_batch` produces — and each pair's factors multiply in
    /// root-to-leaf order, so returned probabilities are bit-identical to
    /// the per-level path's on the same tree (shared memo answers) and to a
    /// twin tree's from the same seed (pinned in `tests/fusion.rs`).
    pub fn neighbor_prob_batch_fused(&self, pairs: &[(usize, usize)]) -> Vec<f64> {
        let n = pairs.len();
        let mut out = vec![0.0f64; n];
        if n == 0 {
            return out;
        }
        let _epoch = self.tree.overlap_epoch();
        let finish = self.finish_size();
        let root = self.tree.root();
        // Pass 1: walk every pair's (deterministic) path root -> cutoff
        // node, recording the (left, right, goes_left) probe triple per
        // internal level and the final cutoff node.
        let mut paths: Vec<Vec<(usize, usize, bool)>> = Vec::with_capacity(n);
        let mut leaves: Vec<usize> = Vec::with_capacity(n);
        for &(i, j) in pairs {
            assert_ne!(i, j, "a vertex is not its own neighbor");
            let mut id = root;
            let mut path: Vec<(usize, usize, bool)> = Vec::new();
            loop {
                let node = self.tree.node(id);
                if node.hi - node.lo <= finish {
                    break;
                }
                let (l, r) = node.children();
                let nl = self.tree.node(l);
                let goes_left = nl.lo <= j && j < nl.hi;
                path.push((l, r, goes_left));
                id = if goes_left { l } else { r };
            }
            paths.push(path);
            leaves.push(id);
        }
        // Pass 2: gather every level's probe groups — walkers grouped by
        // their current node in (node, pair) order, exactly the grouping
        // `neighbor_prob_batch` would issue level by level — and resolve
        // them all in ONE fused multi-group round. `slot[w][lvl]` remembers
        // where pair w's level-`lvl` (left, right) answers landed.
        let max_depth = paths.iter().map(|p| p.len()).max().unwrap_or(0);
        let mut qgroups: Vec<(usize, Vec<usize>)> = Vec::new();
        let mut slot: Vec<Vec<(usize, usize)>> =
            paths.iter().map(|p| Vec::with_capacity(p.len())).collect();
        for lvl in 0..max_depth {
            let mut at: Vec<(usize, usize)> = Vec::new();
            for (w, path) in paths.iter().enumerate() {
                if lvl < path.len() {
                    let parent = if lvl == 0 {
                        root
                    } else {
                        let (pl, pr, pg) = path[lvl - 1];
                        if pg {
                            pl
                        } else {
                            pr
                        }
                    };
                    at.push((parent, w));
                }
            }
            at.sort_unstable();
            let mut g0 = 0usize;
            while g0 < at.len() {
                let id = at[g0].0;
                let mut g1 = g0;
                while g1 < at.len() && at[g1].0 == id {
                    g1 += 1;
                }
                let qi = qgroups.len();
                for (row, &(_, w)) in at[g0..g1].iter().enumerate() {
                    slot[w].push((qi, row));
                }
                let srcs: Vec<usize> =
                    at[g0..g1].iter().map(|&(_, w)| pairs[w].0).collect();
                let (l, r, _) = paths[at[g0].1][lvl];
                qgroups.push((l, srcs.clone()));
                qgroups.push((r, srcs));
                g0 = g1;
            }
        }
        let refs: Vec<(usize, &[usize])> =
            qgroups.iter().map(|(id, v)| (*id, v.as_slice())).collect();
        let answers = self.tree.query_points_multi(&refs);
        // Pass 3: per pair, multiply factors in root-to-leaf order —
        // the exact operation sequence of `neighbor_prob`.
        'pairs: for (w, &(i, j)) in pairs.iter().enumerate() {
            let mut prob = 1.0f64;
            for (lvl, &(l, r, goes_left)) in paths[w].iter().enumerate() {
                let (qi, row) = slot[w][lvl];
                let a = self.side_mass_value(l, i, answers[qi][row]);
                let b = self.side_mass_value(r, i, answers[qi + 1][row]);
                let total = a + b;
                if total <= 0.0 {
                    let nl = self.tree.node(l);
                    let nr = self.tree.node(r);
                    let sl = (nl.hi - nl.lo - usize::from(nl.lo <= i && i < nl.hi)) as f64;
                    let sr = (nr.hi - nr.lo - usize::from(nr.lo <= i && i < nr.hi)) as f64;
                    let denom = sl + sr;
                    if denom <= 0.0 {
                        out[w] = 0.0;
                        continue 'pairs;
                    }
                    prob *= if goes_left { sl / denom } else { sr / denom };
                } else {
                    prob *= if goes_left { a / total } else { b / total };
                }
            }
            out[w] = prob * self.leaf_prob_factor(leaves[w], i, j);
        }
        out
    }

    /// Theorem 4.12's exact mode: rejection-sample against true kernel
    /// weights to remove the estimator's TV error. The proposal is the tree
    /// descent; accept with ratio true/(c * proposal). Also returns the
    /// number of kernel evaluations spent (expected O(1/tau)).
    pub fn sample_exact(
        &self,
        i: usize,
        rng: &mut Rng,
        max_rounds: usize,
    ) -> Option<(usize, u64)> {
        let ds = &self.tree.ds;
        let kernel = self.tree.kernel;
        // True neighbor mass of i (one extra linear pass amortized over
        // many samples would be ideal; here we take the root KDE answer
        // as the normalizer since it is cached).
        let denom = (self.tree.query_point(self.tree.root(), i) - 1.0).max(1e-12);
        let mut evals = 0u64;
        for _ in 0..max_rounds {
            let s = self.sample(i, rng)?;
            let true_w = kernel.eval(ds.point(i), ds.point(s.neighbor)) as f64;
            evals += 1;
            let target = true_w / denom;
            // Accept w.p. min(1, target / (c * proposal)); c=2 slack keeps
            // the ratio <= 1 w.h.p. under (1 ± eps) estimates.
            let ratio = target / (2.0 * s.prob);
            if rng.f64() < ratio.min(1.0) {
                return Some((s.neighbor, evals));
            }
        }
        // Fall back to the proposal sample after max_rounds.
        self.sample(i, rng).map(|s| (s.neighbor, evals))
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::kde::{KdeConfig, KdeCounters};
    use crate::kernel::dataset::gaussian_mixture;
    use crate::kernel::Kernel;
    use crate::runtime::backend::CpuBackend;

    fn build(n: usize, seed: u64, cfg: KdeConfig) -> NeighborSampler {
        let mut rng = Rng::new(seed);
        let ds = Arc::new(gaussian_mixture(n, 4, 2, 1.5, 0.5, &mut rng));
        let tree = Arc::new(MultiLevelKde::build(
            ds,
            Kernel::Laplacian,
            &cfg,
            CpuBackend::new(),
            KdeCounters::new(),
        ));
        NeighborSampler::new(tree)
    }

    #[test]
    fn never_samples_self() {
        let s = build(31, 81, KdeConfig::exact());
        let mut rng = Rng::new(83);
        for i in [0usize, 7, 30] {
            for _ in 0..200 {
                let got = s.sample(i, &mut rng).unwrap();
                assert_ne!(got.neighbor, i);
            }
        }
    }

    #[test]
    fn exact_tree_matches_true_edge_distribution() {
        let s = build(32, 85, KdeConfig::exact());
        let ds = &s.tree.ds;
        let i = 5;
        let mut rng = Rng::new(87);
        let trials = 40_000;
        let mut counts = vec![0f64; 32];
        for _ in 0..trials {
            counts[s.sample(i, &mut rng).unwrap().neighbor] += 1.0;
        }
        let mut want: Vec<f64> = (0..32)
            .map(|j| {
                if j == i {
                    0.0
                } else {
                    Kernel::Laplacian.eval(ds.point(i), ds.point(j)) as f64
                }
            })
            .collect();
        // TV distance between empirical and true neighbor distribution.
        counts[i] = 1e-300;
        want[i] = 1e-300;
        let tv = crate::util::stats::tv_distance(&counts, &want);
        assert!(tv < 0.03, "TV {tv}");
    }

    #[test]
    fn reported_prob_matches_neighbor_prob() {
        let s = build(24, 89, KdeConfig::exact());
        let mut rng = Rng::new(91);
        for _ in 0..100 {
            let i = rng.below(24);
            let got = s.sample(i, &mut rng).unwrap();
            let recomputed = s.neighbor_prob(i, got.neighbor);
            assert!(
                (got.prob - recomputed).abs() < 1e-12 * (1.0 + got.prob),
                "prob mismatch: {} vs {recomputed}",
                got.prob
            );
        }
    }

    #[test]
    fn neighbor_probs_sum_to_one() {
        let s = build(20, 93, KdeConfig::exact());
        for i in [0usize, 9, 19] {
            let total: f64 = (0..20)
                .filter(|&j| j != i)
                .map(|j| s.neighbor_prob(i, j))
                .sum();
            assert!((total - 1.0).abs() < 1e-9, "source {i}: sum {total}");
        }
    }

    #[test]
    fn probs_consistent_under_sampling_estimator() {
        // Even with a noisy estimator, memoization must make sample() and
        // neighbor_prob() agree exactly.
        let cfg = KdeConfig {
            kind: crate::kde::EstimatorKind::Sampling { eps: 0.5, tau: 0.3 },
            ..Default::default()
        };
        let s = build(64, 95, cfg);
        let mut rng = Rng::new(97);
        for _ in 0..50 {
            let i = rng.below(64);
            let got = s.sample(i, &mut rng).unwrap();
            let recomputed = s.neighbor_prob(i, got.neighbor);
            assert!(
                (got.prob - recomputed).abs() < 1e-12 * (1.0 + got.prob),
                "memoized probs must be identical"
            );
        }
    }

    #[test]
    fn sampling_estimator_close_in_tv() {
        // Theorem 4.12: TV distance O(eps) with eps' = eps / log n.
        let cfg = KdeConfig {
            kind: crate::kde::EstimatorKind::Sampling { eps: 0.12, tau: 0.1 },
            leaf_cutoff: 8,
            seed: 0xAB,
        };
        let s = build(64, 99, cfg);
        let ds = &s.tree.ds;
        let i = 11;
        let mut rng = Rng::new(101);
        let trials = 30_000;
        let mut counts = vec![0f64; 64];
        for _ in 0..trials {
            counts[s.sample(i, &mut rng).unwrap().neighbor] += 1.0;
        }
        let mut want: Vec<f64> = (0..64)
            .map(|j| {
                if j == i {
                    1e-300
                } else {
                    Kernel::Laplacian.eval(ds.point(i), ds.point(j)) as f64
                }
            })
            .collect();
        counts[i] = 1e-300;
        let tv = crate::util::stats::tv_distance(&counts, &want);
        want[i] = 0.0;
        assert!(tv < 0.25, "TV {tv} too large for eps=0.12 sampling oracle");
    }

    #[test]
    fn exact_mode_reduces_tv() {
        let cfg = KdeConfig {
            kind: crate::kde::EstimatorKind::Sampling { eps: 0.4, tau: 0.1 },
            leaf_cutoff: 4,
            seed: 0xCD,
        };
        let s = build(48, 103, cfg);
        let ds = &s.tree.ds;
        let i = 3;
        let mut rng = Rng::new(105);
        let trials = 20_000;
        let mut counts = vec![0f64; 48];
        for _ in 0..trials {
            let (j, _) = s.sample_exact(i, &mut rng, 32).unwrap();
            counts[j] += 1.0;
        }
        let mut want: Vec<f64> = (0..48)
            .map(|j| {
                if j == i {
                    1e-300
                } else {
                    Kernel::Laplacian.eval(ds.point(i), ds.point(j)) as f64
                }
            })
            .collect();
        counts[i] = 1e-300;
        let tv_exact = crate::util::stats::tv_distance(&counts, &want);
        want[i] = 0.0;
        assert!(tv_exact < 0.08, "rejection-corrected TV {tv_exact}");
    }

    #[test]
    fn leaf_finish_covers_whole_range_from_root() {
        // n <= leaf_cutoff: the descent is a single categorical draw and
        // must still match the true edge distribution and never self-step.
        let s = build(12, 107, KdeConfig::exact());
        assert!(12 <= s.finish_size() + 4, "setup: root should leaf-finish soon");
        let ds = &s.tree.ds;
        let i = 4;
        let mut rng = Rng::new(109);
        let trials = 30_000;
        let mut counts = vec![0f64; 12];
        for _ in 0..trials {
            let got = s.sample(i, &mut rng).unwrap();
            assert_ne!(got.neighbor, i);
            counts[got.neighbor] += 1.0;
        }
        let mut want: Vec<f64> = (0..12)
            .map(|j| {
                if j == i {
                    1e-300
                } else {
                    Kernel::Laplacian.eval(ds.point(i), ds.point(j)) as f64
                }
            })
            .collect();
        counts[i] = 1e-300;
        let tv = crate::util::stats::tv_distance(&counts, &want);
        want[i] = 0.0;
        assert!(tv < 0.03, "leaf-finish TV {tv}");
    }

    #[test]
    fn sample_batch_with_streams_replays_sequential_per_stream() {
        // The caller-owned-streams contract: walker k's batched draw is
        // bit-identical to `sample(sources[k], &mut rngs[k])`.
        let s = build(48, 119, KdeConfig::exact());
        let sources: Vec<usize> = (0..29).map(|k| (k * 11) % 48).collect();
        let mut seed = Rng::new(121);
        let mut batch_rngs: Vec<Rng> = sources.iter().map(|_| seed.fork()).collect();
        let mut seq_rngs = batch_rngs.clone();
        let got = s.sample_batch_with_streams(&sources, &mut batch_rngs);
        for (k, &src) in sources.iter().enumerate() {
            let want = s.sample(src, &mut seq_rngs[k]).expect("n > 1 samples");
            let g = got[k].expect("batched walker must sample too");
            assert_eq!(g.neighbor, want.neighbor, "walker {k} diverged");
            assert_eq!(g.prob.to_bits(), want.prob.to_bits(), "walker {k} prob");
        }
    }

    #[test]
    fn prob_batch_fused_matches_per_level_and_sequential() {
        // The single-round fused probe must report bit-identical
        // probabilities to the per-level batch and the sequential recompute
        // on the same tree, while ticking the round counter exactly once.
        let s = build(48, 123, KdeConfig::exact());
        let pairs: Vec<(usize, usize)> = (0..48)
            .flat_map(|i| [(i, (i + 5) % 48), (i, (i + 23) % 48)])
            .filter(|&(i, j)| i != j)
            .collect();
        let before = s.tree.multi_calls();
        let fused = s.neighbor_prob_batch_fused(&pairs);
        assert_eq!(s.tree.multi_calls() - before, 1, "fused probe is one round");
        let per_level = s.neighbor_prob_batch(&pairs);
        for (w, &(i, j)) in pairs.iter().enumerate() {
            assert_eq!(fused[w].to_bits(), per_level[w].to_bits(), "pair ({i},{j})");
            assert_eq!(fused[w].to_bits(), s.neighbor_prob(i, j).to_bits(), "pair ({i},{j}) seq");
        }
    }

    #[test]
    fn prob_batch_matches_sequential_probs() {
        let s = build(40, 111, KdeConfig::exact());
        let pairs: Vec<(usize, usize)> = (0..40)
            .flat_map(|i| [(i, (i + 7) % 40), (i, (i + 19) % 40)])
            .filter(|&(i, j)| i != j)
            .collect();
        let batched = s.neighbor_prob_batch(&pairs);
        for (w, &(i, j)) in pairs.iter().enumerate() {
            let seq = s.neighbor_prob(i, j);
            assert_eq!(
                batched[w].to_bits(),
                seq.to_bits(),
                "pair ({i},{j}): batched {} vs sequential {seq}",
                batched[w]
            );
        }
    }
}
