//! Weighted neighbor edge sampling: Algorithm 4.11 / Theorem 4.12.
//!
//! Given a vertex `x_i`, sample a neighbor `v` with `Pr[v = x_k] ~
//! k(x_i, x_k)` by descending the multi-level KDE tree: at each internal
//! node query the two children's KDE oracles at `x_i` (subtracting the
//! self-term when `i` falls inside a child's range) and branch
//! proportionally. O(log n) KDE queries per sample; answers are memoized
//! inside the tree so the *probability* of any concrete descent is a
//! well-defined deterministic quantity — `neighbor_prob` recomputes it
//! exactly, which Algorithm 5.1 (sparsification) requires.

use std::sync::Arc;

use crate::kde::multilevel::MultiLevelKde;
use crate::util::rng::Rng;

pub struct NeighborSampler {
    pub tree: Arc<MultiLevelKde>,
}

/// Outcome of one neighbor-sampling descent.
#[derive(Clone, Copy, Debug)]
pub struct NeighborSample {
    /// Sampled neighbor index (never equals the source).
    pub neighbor: usize,
    /// Exact probability the descent produced this neighbor (product of
    /// branch probabilities under the memoized KDE answers).
    pub prob: f64,
}

impl NeighborSampler {
    pub fn new(tree: Arc<MultiLevelKde>) -> Self {
        NeighborSampler { tree }
    }

    /// Mass of node `id`'s subset as seen from source `i`, self-excluded.
    fn side_mass(&self, id: usize, i: usize) -> f64 {
        let n = self.tree.node(id);
        let mut v = self.tree.query_point(id, i);
        if n.lo <= i && i < n.hi {
            v -= 1.0; // remove k(x_i, x_i)
        }
        v.max(0.0)
    }

    /// Algorithm 4.11. Returns the sampled neighbor and its exact descent
    /// probability. Returns `None` only in the degenerate n = 1 case.
    pub fn sample(&self, i: usize, rng: &mut Rng) -> Option<NeighborSample> {
        let mut id = self.tree.root();
        if self.tree.node(id).hi - self.tree.node(id).lo <= 1 {
            return None;
        }
        let mut prob = 1.0f64;
        loop {
            let node = self.tree.node(id);
            let (Some(l), Some(r)) = (node.left, node.right) else {
                debug_assert_ne!(node.lo, i, "descended into the source leaf");
                return Some(NeighborSample { neighbor: node.lo, prob });
            };
            let a = self.side_mass(l, i);
            let b = self.side_mass(r, i);
            let total = a + b;
            let (next, p) = if total <= 0.0 {
                // All mass vanished under estimation noise: fall back to a
                // size-proportional branch, excluding the source leaf.
                let nl = self.tree.node(l);
                let nr = self.tree.node(r);
                let sl = (nl.hi - nl.lo - usize::from(nl.lo <= i && i < nl.hi)) as f64;
                let sr = (nr.hi - nr.lo - usize::from(nr.lo <= i && i < nr.hi)) as f64;
                if sl + sr <= 0.0 {
                    return None;
                }
                if rng.f64() * (sl + sr) < sl {
                    (l, sl / (sl + sr))
                } else {
                    (r, sr / (sl + sr))
                }
            } else if rng.f64() * total < a {
                (l, a / total)
            } else {
                (r, b / total)
            };
            prob *= p;
            id = next;
        }
    }

    /// Deterministic probability that `sample(i)` returns `j` (the product
    /// of branch probabilities along the root-to-j path, under the same
    /// memoized KDE answers the sampler used). Algorithm 5.1 step (c)/(d).
    pub fn neighbor_prob(&self, i: usize, j: usize) -> f64 {
        assert_ne!(i, j, "a vertex is not its own neighbor");
        let mut id = self.tree.root();
        let mut prob = 1.0f64;
        loop {
            let node = self.tree.node(id);
            let (Some(l), Some(r)) = (node.left, node.right) else {
                debug_assert_eq!(node.lo, j);
                return prob;
            };
            let a = self.side_mass(l, i);
            let b = self.side_mass(r, i);
            let total = a + b;
            let nl = self.tree.node(l);
            let goes_left = nl.lo <= j && j < nl.hi;
            if total <= 0.0 {
                let nr = self.tree.node(r);
                let sl = (nl.hi - nl.lo - usize::from(nl.lo <= i && i < nl.hi)) as f64;
                let sr = (nr.hi - nr.lo - usize::from(nr.lo <= i && i < nr.hi)) as f64;
                let denom = sl + sr;
                if denom <= 0.0 {
                    return 0.0;
                }
                prob *= if goes_left { sl / denom } else { sr / denom };
            } else {
                prob *= if goes_left { a / total } else { b / total };
            }
            id = if goes_left { l } else { r };
        }
    }

    /// Theorem 4.12's exact mode: rejection-sample against true kernel
    /// weights to remove the estimator's TV error. The proposal is the tree
    /// descent; accept with ratio true/(c * proposal). Also returns the
    /// number of kernel evaluations spent (expected O(1/tau)).
    pub fn sample_exact(
        &self,
        i: usize,
        rng: &mut Rng,
        max_rounds: usize,
    ) -> Option<(usize, u64)> {
        let ds = &self.tree.ds;
        let kernel = self.tree.kernel;
        // True neighbor mass of i (one extra linear pass amortized over
        // many samples would be ideal; here we take the root KDE answer
        // as the normalizer since it is cached).
        let denom = (self.tree.query_point(self.tree.root(), i) - 1.0).max(1e-12);
        let mut evals = 0u64;
        for _ in 0..max_rounds {
            let s = self.sample(i, rng)?;
            let true_w = kernel.eval(ds.point(i), ds.point(s.neighbor)) as f64;
            evals += 1;
            let target = true_w / denom;
            // Accept w.p. min(1, target / (c * proposal)); c=2 slack keeps
            // the ratio <= 1 w.h.p. under (1 ± eps) estimates.
            let ratio = target / (2.0 * s.prob);
            if rng.f64() < ratio.min(1.0) {
                return Some((s.neighbor, evals));
            }
        }
        // Fall back to the proposal sample after max_rounds.
        self.sample(i, rng).map(|s| (s.neighbor, evals))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kde::{KdeConfig, KdeCounters};
    use crate::kernel::dataset::gaussian_mixture;
    use crate::kernel::Kernel;
    use crate::runtime::backend::CpuBackend;

    fn build(n: usize, seed: u64, cfg: KdeConfig) -> NeighborSampler {
        let mut rng = Rng::new(seed);
        let ds = Arc::new(gaussian_mixture(n, 4, 2, 1.5, 0.5, &mut rng));
        let tree = Arc::new(MultiLevelKde::build(
            ds,
            Kernel::Laplacian,
            &cfg,
            CpuBackend::new(),
            KdeCounters::new(),
        ));
        NeighborSampler::new(tree)
    }

    #[test]
    fn never_samples_self() {
        let s = build(31, 81, KdeConfig::exact());
        let mut rng = Rng::new(83);
        for i in [0usize, 7, 30] {
            for _ in 0..200 {
                let got = s.sample(i, &mut rng).unwrap();
                assert_ne!(got.neighbor, i);
            }
        }
    }

    #[test]
    fn exact_tree_matches_true_edge_distribution() {
        let s = build(32, 85, KdeConfig::exact());
        let ds = &s.tree.ds;
        let i = 5;
        let mut rng = Rng::new(87);
        let trials = 40_000;
        let mut counts = vec![0f64; 32];
        for _ in 0..trials {
            counts[s.sample(i, &mut rng).unwrap().neighbor] += 1.0;
        }
        let mut want: Vec<f64> = (0..32)
            .map(|j| {
                if j == i {
                    0.0
                } else {
                    Kernel::Laplacian.eval(ds.point(i), ds.point(j)) as f64
                }
            })
            .collect();
        // TV distance between empirical and true neighbor distribution.
        counts[i] = 1e-300;
        want[i] = 1e-300;
        let tv = crate::util::stats::tv_distance(&counts, &want);
        assert!(tv < 0.03, "TV {tv}");
    }

    #[test]
    fn reported_prob_matches_neighbor_prob() {
        let s = build(24, 89, KdeConfig::exact());
        let mut rng = Rng::new(91);
        for _ in 0..100 {
            let i = rng.below(24);
            let got = s.sample(i, &mut rng).unwrap();
            let recomputed = s.neighbor_prob(i, got.neighbor);
            assert!(
                (got.prob - recomputed).abs() < 1e-12 * (1.0 + got.prob),
                "prob mismatch: {} vs {recomputed}",
                got.prob
            );
        }
    }

    #[test]
    fn neighbor_probs_sum_to_one() {
        let s = build(20, 93, KdeConfig::exact());
        for i in [0usize, 9, 19] {
            let total: f64 = (0..20)
                .filter(|&j| j != i)
                .map(|j| s.neighbor_prob(i, j))
                .sum();
            assert!((total - 1.0).abs() < 1e-9, "source {i}: sum {total}");
        }
    }

    #[test]
    fn probs_consistent_under_sampling_estimator() {
        // Even with a noisy estimator, memoization must make sample() and
        // neighbor_prob() agree exactly.
        let cfg = KdeConfig {
            kind: crate::kde::EstimatorKind::Sampling { eps: 0.5, tau: 0.3 },
            ..Default::default()
        };
        let s = build(64, 95, cfg);
        let mut rng = Rng::new(97);
        for _ in 0..50 {
            let i = rng.below(64);
            let got = s.sample(i, &mut rng).unwrap();
            let recomputed = s.neighbor_prob(i, got.neighbor);
            assert!(
                (got.prob - recomputed).abs() < 1e-12 * (1.0 + got.prob),
                "memoized probs must be identical"
            );
        }
    }

    #[test]
    fn sampling_estimator_close_in_tv() {
        // Theorem 4.12: TV distance O(eps) with eps' = eps / log n.
        let cfg = KdeConfig {
            kind: crate::kde::EstimatorKind::Sampling { eps: 0.12, tau: 0.1 },
            leaf_cutoff: 8,
            seed: 0xAB,
        };
        let s = build(64, 99, cfg);
        let ds = &s.tree.ds;
        let i = 11;
        let mut rng = Rng::new(101);
        let trials = 30_000;
        let mut counts = vec![0f64; 64];
        for _ in 0..trials {
            counts[s.sample(i, &mut rng).unwrap().neighbor] += 1.0;
        }
        let mut want: Vec<f64> = (0..64)
            .map(|j| {
                if j == i {
                    1e-300
                } else {
                    Kernel::Laplacian.eval(ds.point(i), ds.point(j)) as f64
                }
            })
            .collect();
        counts[i] = 1e-300;
        let tv = crate::util::stats::tv_distance(&counts, &want);
        want[i] = 0.0;
        assert!(tv < 0.25, "TV {tv} too large for eps=0.12 sampling oracle");
    }

    #[test]
    fn exact_mode_reduces_tv() {
        let cfg = KdeConfig {
            kind: crate::kde::EstimatorKind::Sampling { eps: 0.4, tau: 0.1 },
            leaf_cutoff: 4,
            seed: 0xCD,
        };
        let s = build(48, 103, cfg);
        let ds = &s.tree.ds;
        let i = 3;
        let mut rng = Rng::new(105);
        let trials = 20_000;
        let mut counts = vec![0f64; 48];
        for _ in 0..trials {
            let (j, _) = s.sample_exact(i, &mut rng, 32).unwrap();
            counts[j] += 1.0;
        }
        let mut want: Vec<f64> = (0..48)
            .map(|j| {
                if j == i {
                    1e-300
                } else {
                    Kernel::Laplacian.eval(ds.point(i), ds.point(j)) as f64
                }
            })
            .collect();
        counts[i] = 1e-300;
        let tv_exact = crate::util::stats::tv_distance(&counts, &want);
        want[i] = 0.0;
        assert!(tv_exact < 0.08, "rejection-corrected TV {tv_exact}");
    }
}
