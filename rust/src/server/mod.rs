//! KDE-as-a-service: the cross-request coalescing server.
//!
//! The coordinator (`coordinator::KdeService`) batches *one* caller's
//! raw-point queries per shard; this module is the production serving
//! shape above it for **many concurrent clients against shared named
//! datasets**:
//!
//! ```text
//!   clients ──> KdeServer (bounded mpsc) ──> RequestStore ──────────────┐
//!      │              router thread          per-dataset runs,          │
//!      │                                     flush @ B=64 or max_wait   │
//!      │                                                                v
//!      │          OracleRegistry: name -> Arc<MultiLevelKde>   ONE fused
//!      │          (built once, shared memo cache)              query_points_multi
//!      │                                                       per dataset per flush
//!      └───────<── per-request reply channels <────────────────────────┘
//!                  Result<ServerReply, BackendError>
//! ```
//!
//! * **Registry** ([`OracleRegistry`]): named datasets are built once
//!   into `Arc<MultiLevelKde>` trees and shared across every client —
//!   the paper's amortize-preprocessing-across-queries serving shape.
//! * **Coalescing** ([`RequestStore`]): concurrent clients' point
//!   queries accumulate per dataset and flush — at `max_batch` pending
//!   or `max_wait` age — into **one**
//!   [`MultiLevelKde::try_query_points_multi`] call per dataset, which
//!   packs all cache misses into fused padded `sums_ranged` submissions
//!   (B = 64 rows). Dispatches per query fall from 1 (solo cold query)
//!   to `ceil(misses / 64) / flushed` — the coalescing win the serving
//!   bench gates in CI.
//! * **Versioned runs**: the router keys pending runs by
//!   `(name, version)` — the version [`RegisteredDataset::version`]
//!   carries and [`OracleRegistry::update`] bumps — so a dataset
//!   replacement mid-flight never mixes requests across builds: requests
//!   that resolved version `v` flush as their own batch against version
//!   `v`'s tree, and new requests flush against the fresh build instead
//!   of a stale first-writer entry.
//! * **Determinism**: the store keeps a stable pack order (arrival
//!   order within a dataset, first-arrival order across datasets), each
//!   row of a fused submission accumulates its own segment range
//!   independently, and every neighbor-sample request carries its own
//!   seed evaluated through a private RNG stream
//!   ([`NeighborSampler::sample_batch_with_streams`]) — so a coalesced
//!   answer is **bit-identical** to the same request served solo, the
//!   same discipline `walk_batch`/`sample_batch` pin
//!   (`tests/serving.rs`).
//! * **Failure model** (shared with the coordinator,
//!   docs/ARCHITECTURE.md §"Failure model"): bounded ingress + per-
//!   dataset pending caps reject with [`BackendError::Overloaded`];
//!   per-request deadlines answer [`BackendError::Timeout`] (checked at
//!   flush); unregistered names answer the typed
//!   [`BackendError::UnknownDataset`]; oracle panics are caught at the
//!   flush boundary and every in-flight request of the flush gets a
//!   typed reply. Every admitted request gets exactly one reply.
//!
//! Flushes execute inline on the router thread: parallelism lives
//! *inside* the backend (`TiledBackend` worker threads, PJRT), where it
//! does not reorder replies; the bounded ingress channel provides
//! backpressure while a flush runs.
//!
//! [`MultiLevelKde::try_query_points_multi`]: crate::kde::MultiLevelKde::try_query_points_multi
//! [`NeighborSampler::sample_batch_with_streams`]: crate::sampling::NeighborSampler::sample_batch_with_streams
#![warn(missing_docs)]
#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod registry;
pub mod store;

pub use registry::{OracleRegistry, RegisteredDataset};
pub use store::RequestStore;

use std::collections::HashMap;
use std::time::{Duration, Instant};

use crate::coordinator::metrics::ServiceMetrics;
use crate::runtime::error::{catch_panic, BackendError};
use crate::runtime::sync::atomic::Ordering;
use crate::runtime::sync::mpsc::{self, Receiver, SyncSender};
use crate::runtime::sync::Arc;
use crate::sampling::NeighborSample;
use crate::util::rng::Rng;

/// Server tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    /// Pending-count flush watermark per dataset (64 = the AOT batch
    /// shape). A trigger, not a cap: a flush drains everything pending.
    pub max_batch: usize,
    /// Age flush watermark: the oldest pending request of any dataset
    /// waits at most this long before a flush. `Duration::ZERO` flushes
    /// every router iteration (the solo/low-latency setting).
    pub max_wait: Duration,
    /// Bound on the ingress channel AND each dataset's pending run.
    /// Admission past either bound is refused with
    /// [`BackendError::Overloaded`].
    pub queue_cap: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 64, // = AOT_B
            max_wait: Duration::from_micros(500),
            queue_cap: 1024,
        }
    }
}

/// A successful server reply.
#[derive(Clone, Copy, Debug)]
pub enum ServerReply {
    /// Memoized KDE density of a dataset point against the whole dataset
    /// (the tree root's answer, self-term included — same contract as
    /// [`MultiLevelKde::query_point`](crate::kde::MultiLevelKde::query_point)).
    Density(f64),
    /// A weighted neighbor sample drawn from the request's own seeded
    /// stream (`None` only for degenerate single-point datasets).
    Neighbor(Option<NeighborSample>),
}

/// What a request asks for.
enum RequestKind {
    /// Density of dataset point `point` (tree-root query).
    Density { point: usize },
    /// Neighbor sample from `source` using stream `Rng::new(seed)`.
    Neighbor { source: usize, seed: u64 },
}

/// One admitted request waiting in the store.
struct Pending {
    kind: RequestKind,
    respond: SyncSender<Result<ServerReply, BackendError>>,
    enqueued_at: Instant,
    deadline: Option<Instant>,
}

struct Ingress {
    dataset: Arc<RegisteredDataset>,
    req: Pending,
}

enum Control {
    Request(Ingress),
    Shutdown,
}

/// Handle to a running coalescing KDE server; see the module docs.
pub struct KdeServer {
    registry: Arc<OracleRegistry>,
    ingress: SyncSender<Control>,
    router: Option<std::thread::JoinHandle<()>>,
    /// Shared serving metrics (admission/flush/latency counters; a
    /// "batch" here is one dataset's flushed run).
    pub metrics: Arc<ServiceMetrics>,
}

impl KdeServer {
    /// Spawn the router over a registry. The registry stays shared:
    /// datasets may be registered before or after the server starts, and
    /// other servers (or offline pipelines) may use it concurrently.
    pub fn start(registry: Arc<OracleRegistry>, cfg: ServerConfig) -> Self {
        let metrics = Arc::new(ServiceMetrics::new());
        let (tx, rx) = mpsc::sync_channel::<Control>(cfg.queue_cap.max(1));
        let m = metrics.clone();
        let router = std::thread::spawn(move || run_router(rx, cfg, m));
        KdeServer { registry, ingress: tx, router: Some(router), metrics }
    }

    /// The registry this server resolves dataset names through.
    pub fn registry(&self) -> &Arc<OracleRegistry> {
        &self.registry
    }

    /// Fallible async density query for dataset point `point` of
    /// `dataset`: returns the reply receiver, or — synchronously —
    /// [`BackendError::UnknownDataset`], an out-of-range error, or
    /// [`BackendError::Overloaded`].
    pub fn try_submit_density(
        &self,
        dataset: &str,
        point: usize,
    ) -> Result<Receiver<Result<ServerReply, BackendError>>, BackendError> {
        self.enqueue(dataset, RequestKind::Density { point }, None)
    }

    /// [`try_submit_density`](Self::try_submit_density) with a deadline
    /// `timeout` from now: a request still pending when it expires is
    /// dropped from the flush and answered [`BackendError::Timeout`].
    pub fn try_submit_density_deadline(
        &self,
        dataset: &str,
        point: usize,
        timeout: Duration,
    ) -> Result<Receiver<Result<ServerReply, BackendError>>, BackendError> {
        self.enqueue(
            dataset,
            RequestKind::Density { point },
            Some(Instant::now() + timeout),
        )
    }

    /// Fallible async neighbor-sample request: draw a weighted neighbor
    /// of `source` (Algorithm 4.11) using the request's own stream
    /// `Rng::new(seed)` — bit-identical to a solo
    /// `NeighborSampler::sample(source, &mut Rng::new(seed))` on the
    /// same tree, however the request gets coalesced.
    pub fn try_submit_neighbor(
        &self,
        dataset: &str,
        source: usize,
        seed: u64,
    ) -> Result<Receiver<Result<ServerReply, BackendError>>, BackendError> {
        self.enqueue(dataset, RequestKind::Neighbor { source, seed }, None)
    }

    /// Blocking [`try_submit_density`](Self::try_submit_density): the
    /// density, or the typed error the server replied with.
    pub fn try_query_density(&self, dataset: &str, point: usize) -> Result<f64, BackendError> {
        match self.try_submit_density(dataset, point)?.recv() {
            Ok(Ok(ServerReply::Density(v))) => Ok(v),
            Ok(Ok(_)) => Err(BackendError::permanent_failure(
                "server sent a non-density reply to a density request",
            )),
            Ok(Err(e)) => Err(e),
            Err(_) => Err(dropped_reply()),
        }
    }

    /// Blocking [`try_submit_neighbor`](Self::try_submit_neighbor).
    pub fn try_sample_neighbor(
        &self,
        dataset: &str,
        source: usize,
        seed: u64,
    ) -> Result<Option<NeighborSample>, BackendError> {
        match self.try_submit_neighbor(dataset, source, seed)?.recv() {
            Ok(Ok(ServerReply::Neighbor(s))) => Ok(s),
            Ok(Ok(_)) => Err(BackendError::permanent_failure(
                "server sent a non-neighbor reply to a neighbor request",
            )),
            Ok(Err(e)) => Err(e),
            Err(_) => Err(dropped_reply()),
        }
    }

    fn enqueue(
        &self,
        dataset: &str,
        kind: RequestKind,
        deadline: Option<Instant>,
    ) -> Result<Receiver<Result<ServerReply, BackendError>>, BackendError> {
        let entry = self.registry.get(dataset)?;
        let n = entry.len();
        let idx = match kind {
            RequestKind::Density { point } => point,
            RequestKind::Neighbor { source, .. } => source,
        };
        if idx >= n {
            return Err(BackendError::permanent_failure(format!(
                "point index {idx} out of range for dataset {dataset:?} (n = {n})"
            )));
        }
        let (tx, rx) = mpsc::sync_channel(1);
        let req = Pending { kind, respond: tx, enqueued_at: Instant::now(), deadline };
        match self.ingress.try_send(Control::Request(Ingress { dataset: entry, req })) {
            Ok(()) => {
                self.metrics.enqueued.fetch_add(1, Ordering::Relaxed);
                Ok(rx)
            }
            Err(mpsc::TrySendError::Full(_)) => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(BackendError::Overloaded)
            }
            Err(mpsc::TrySendError::Disconnected(_)) => {
                Err(BackendError::permanent_failure("server stopped"))
            }
        }
    }

    /// Stop the router; pending admitted requests are flushed first.
    pub fn shutdown(mut self) {
        let _ = self.ingress.send(Control::Shutdown);
        if let Some(h) = self.router.take() {
            let _ = h.join();
        }
    }
}

impl Drop for KdeServer {
    fn drop(&mut self) {
        let _ = self.ingress.send(Control::Shutdown);
        if let Some(h) = self.router.take() {
            let _ = h.join();
        }
    }
}

fn dropped_reply() -> BackendError {
    BackendError::Panicked {
        message: "server dropped request (router died before replying)".to_string(),
    }
}

fn run_router(rx: Receiver<Control>, cfg: ServerConfig, metrics: Arc<ServiceMetrics>) {
    let mut store: RequestStore<Pending> = RequestStore::new(cfg.max_batch, cfg.max_wait);
    let mut datasets: HashMap<String, Arc<RegisteredDataset>> = HashMap::new();
    let queue_cap = cfg.queue_cap.max(1);
    let mut running = true;
    while running {
        // Wait for at least one request (or shutdown); while something is
        // pending, wake exactly at the store's next age watermark.
        let timeout = store
            .next_flush_at()
            .map(|at| at.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(ctl) => absorb(ctl, &mut store, &mut datasets, &mut running, queue_cap, &metrics),
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => running = false,
        }
        // Greedily drain the ingress backlog so it becomes one large
        // coalesced flush, not many singletons.
        while let Ok(ctl) = rx.try_recv() {
            absorb(ctl, &mut store, &mut datasets, &mut running, queue_cap, &metrics);
        }
        if store.ready(Instant::now()) || (!running && !store.is_empty()) {
            for (name, batch) in store.drain() {
                if let Some(ds) = datasets.get(&name) {
                    flush_dataset(ds, batch, &metrics);
                }
            }
        }
    }
    // Shutdown: flush whatever is still pending so every admitted request
    // gets its one reply.
    for (name, batch) in store.drain() {
        if let Some(ds) = datasets.get(&name) {
            flush_dataset(ds, batch, &metrics);
        }
    }
}

/// Admit one control message into the router's store (or begin
/// shutdown). Past the per-dataset pending cap the request is refused
/// with a typed `Overloaded` reply instead of buffering without bound
/// behind a slow flush.
fn absorb(
    ctl: Control,
    store: &mut RequestStore<Pending>,
    datasets: &mut HashMap<String, Arc<RegisteredDataset>>,
    running: &mut bool,
    queue_cap: usize,
    metrics: &ServiceMetrics,
) {
    match ctl {
        Control::Request(ing) => {
            // Key the run by (name, version), not name alone: a registry
            // `update` mid-flight must not reroute requests that resolved
            // the old entry (they flush as their own batch against their
            // own tree), and — the converse hazard — requests resolving
            // the NEW entry must not be flushed against a stale tree a
            // first-writer-wins `or_insert` pinned under the bare name.
            let key = format!("{}@{}", ing.dataset.name(), ing.dataset.version());
            if store.key_len(&key) >= queue_cap {
                metrics.rejected.fetch_add(1, Ordering::Relaxed);
                let _ = ing.req.respond.send(Err(BackendError::Overloaded));
                return;
            }
            datasets.insert(key.clone(), ing.dataset);
            store.push(&key, ing.req, Instant::now());
        }
        Control::Shutdown => *running = false,
    }
}

/// Flush one dataset's pending run: deadline-check, then resolve every
/// density request through ONE fused `try_query_points_multi` call and
/// every neighbor request through one `sample_batch_with_streams` call
/// (per-request seeded streams, arrival order), replying per client.
fn flush_dataset(ds: &Arc<RegisteredDataset>, batch: Vec<Pending>, metrics: &ServiceMetrics) {
    // Deadline check at flush time: expired requests are dropped from the
    // fused plan and answered Timeout, never answered late.
    let now = Instant::now();
    let mut live: Vec<Pending> = Vec::with_capacity(batch.len());
    for req in batch {
        if req.deadline.is_some_and(|dl| dl <= now) {
            metrics.timeouts.fetch_add(1, Ordering::Relaxed);
            let _ = req.respond.send(Err(BackendError::Timeout));
        } else {
            live.push(req);
        }
    }
    if live.is_empty() {
        return;
    }
    metrics.record_batch(live.len());

    // Split by kind, preserving arrival order within each kind (the
    // stable pack order the bit-identity contract rides on).
    let mut density: Vec<&Pending> = Vec::new();
    let mut points: Vec<usize> = Vec::new();
    let mut neighbor: Vec<&Pending> = Vec::new();
    let mut sources: Vec<usize> = Vec::new();
    let mut streams: Vec<Rng> = Vec::new();
    for req in &live {
        match req.kind {
            RequestKind::Density { point } => {
                density.push(req);
                points.push(point);
            }
            RequestKind::Neighbor { source, seed } => {
                neighbor.push(req);
                sources.push(source);
                streams.push(Rng::new(seed));
            }
        }
    }

    if !points.is_empty() {
        // ONE fused submission chain for the whole flush's density
        // queries: all points as one root group; the tree dedups repeats
        // and cache hits, then packs the misses into ceil(misses / 64)
        // fused dispatches.
        let groups = [(ds.tree.root(), points.as_slice())];
        let run = catch_panic(|| ds.tree.try_query_points_multi(&groups)).and_then(|r| r);
        match run {
            Ok(mut per_group) => {
                let vals = per_group.pop().unwrap_or_default();
                if vals.len() == points.len() {
                    for (req, &v) in density.iter().zip(&vals) {
                        metrics.record_latency_us(req.enqueued_at.elapsed().as_micros() as f64);
                        let _ = req.respond.send(Ok(ServerReply::Density(v)));
                    }
                } else {
                    let err = BackendError::permanent_failure(format!(
                        "oracle returned {} answers for {} density queries",
                        vals.len(),
                        points.len()
                    ));
                    reply_error(&density, &err, metrics);
                }
            }
            Err(e) => {
                if matches!(e, BackendError::Panicked { .. }) {
                    metrics.worker_panics.fetch_add(1, Ordering::Relaxed);
                }
                reply_error(&density, &e, metrics);
            }
        }
    }

    if !sources.is_empty() {
        // One lock-step descent batch for the flush's neighbor requests;
        // each request draws only from its own stream, so the answers
        // equal solo `sample(source, &mut Rng::new(seed))` calls bit for
        // bit regardless of who else shared the flush.
        let run = catch_panic(|| ds.sampler.sample_batch_with_streams(&sources, &mut streams));
        match run {
            Ok(samples) => {
                for (req, &s) in neighbor.iter().zip(&samples) {
                    metrics.record_latency_us(req.enqueued_at.elapsed().as_micros() as f64);
                    let _ = req.respond.send(Ok(ServerReply::Neighbor(s)));
                }
            }
            Err(e) => {
                if matches!(e, BackendError::Panicked { .. }) {
                    metrics.worker_panics.fetch_add(1, Ordering::Relaxed);
                }
                reply_error(&neighbor, &e, metrics);
            }
        }
    }
}

fn reply_error(reqs: &[&Pending], err: &BackendError, metrics: &ServiceMetrics) {
    for req in reqs {
        metrics.error_replies.fetch_add(1, Ordering::Relaxed);
        let _ = req.respond.send(Err(err.clone()));
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::kde::KdeConfig;
    use crate::kernel::dataset::gaussian_mixture;
    use crate::kernel::Kernel;
    use crate::runtime::backend::CpuBackend;

    fn serve(seed: u64, cfg: ServerConfig) -> (KdeServer, Arc<RegisteredDataset>) {
        let reg = OracleRegistry::new(CpuBackend::new());
        let mut rng = Rng::new(seed);
        let ds = Arc::new(gaussian_mixture(48, 3, 2, 1.0, 0.5, &mut rng));
        let entry = reg.register("web", ds, Kernel::Laplacian, &KdeConfig::exact());
        (KdeServer::start(reg, cfg), entry)
    }

    #[test]
    fn density_reply_matches_direct_tree_query() {
        let cfg = ServerConfig { max_wait: Duration::ZERO, ..ServerConfig::default() };
        let (srv, entry) = serve(21, cfg);
        for i in [0usize, 7, 31] {
            let got = srv.try_query_density("web", i).unwrap();
            let want = entry.tree.query_point(entry.tree.root(), i);
            assert_eq!(got.to_bits(), want.to_bits());
        }
    }

    #[test]
    fn neighbor_reply_matches_solo_sample_on_same_stream() {
        let cfg = ServerConfig { max_wait: Duration::ZERO, ..ServerConfig::default() };
        let (srv, entry) = serve(23, cfg);
        let got = srv.try_sample_neighbor("web", 5, 0xFEED).unwrap().unwrap();
        let want = entry.sampler.sample(5, &mut Rng::new(0xFEED)).unwrap();
        assert_eq!(got.neighbor, want.neighbor);
        assert_eq!(got.prob.to_bits(), want.prob.to_bits());
    }

    #[test]
    fn unknown_dataset_and_bad_index_fail_synchronously() {
        let (srv, _) = serve(25, ServerConfig::default());
        match srv.try_submit_density("nope", 0) {
            Err(BackendError::UnknownDataset { name }) => assert_eq!(name, "nope"),
            other => panic!("want UnknownDataset, got {:?}", other.map(|_| ())),
        }
        assert!(srv.try_submit_density("web", 48).is_err(), "out-of-range index");
    }

    #[test]
    fn update_routes_new_requests_to_the_fresh_tree() {
        let cfg = ServerConfig { max_wait: Duration::ZERO, ..ServerConfig::default() };
        let (srv, v0) = serve(29, cfg);
        assert_eq!(
            srv.try_query_density("web", 3).unwrap().to_bits(),
            v0.tree.query_point(v0.tree.root(), 3).to_bits()
        );
        // Replace the dataset through the registry's version bump. Without
        // (name, version) run keys the router's first-writer dataset map
        // would keep flushing "web" against the retired v0 tree.
        let mut rng = Rng::new(31);
        let fresh = Arc::new(gaussian_mixture(48, 3, 2, 1.0, 0.5, &mut rng));
        let v1 = srv
            .registry()
            .update("web", fresh, Kernel::Laplacian, &KdeConfig::exact());
        assert_eq!(v1.version(), 1);
        let got = srv.try_query_density("web", 3).unwrap();
        let want = v1.tree.query_point(v1.tree.root(), 3);
        assert_eq!(got.to_bits(), want.to_bits());
        assert!(got != v0.tree.query_point(v0.tree.root(), 3), "stale tree answered");
        srv.shutdown();
    }

    #[test]
    fn shutdown_flushes_pending_requests() {
        let cfg = ServerConfig { max_wait: Duration::from_secs(3600), ..Default::default() };
        let (srv, entry) = serve(27, cfg);
        // With an hour-long age watermark these can only be answered by
        // the shutdown flush.
        let rx0 = srv.try_submit_density("web", 1).unwrap();
        let rx1 = srv.try_submit_density("web", 2).unwrap();
        srv.shutdown();
        let v0 = rx0.recv().unwrap().unwrap();
        let v1 = rx1.recv().unwrap().unwrap();
        let (want0, want1) = (
            entry.tree.query_point(entry.tree.root(), 1),
            entry.tree.query_point(entry.tree.root(), 2),
        );
        match (v0, v1) {
            (ServerReply::Density(a), ServerReply::Density(b)) => {
                assert_eq!(a.to_bits(), want0.to_bits());
                assert_eq!(b.to_bits(), want1.to_bits());
            }
            other => panic!("want density replies, got {other:?}"),
        }
    }
}
