//! Cross-request coalescing store: per-dataset pending queues with a
//! batch-size / age watermark, the buffer between the server's mpsc
//! ingress and its fused flushes.
//!
//! The store is deliberately dumb and fully deterministic: items are kept
//! **in arrival order** within each key, and keys keep the order of their
//! *first* arrival across the store's whole lifetime — the stable pack
//! order that lets a coalesced flush reproduce solo answers bit for bit
//! (each request's position in the fused submission is a function of the
//! arrival sequence alone, never of timing). It is generic over the item
//! type so the flush policy is unit-testable without building trees.
//!
//! Flush policy ([`RequestStore::ready`]): flush when any key's pending
//! count reaches `max_batch` (the artifact's native B = 64 shape is
//! full), or when the **oldest currently-pending** item of any key has
//! aged past `max_wait` (the latency watermark; measured from when the
//! item entered the store, exactly like the coordinator batcher's
//! `pending_since` — not from client enqueue time, which would degrade a
//! backlog to singleton flushes). `max_batch` is a *trigger*, not a cap:
//! a drain hands back everything pending, and the fused evaluation
//! downstream packs any count into `ceil(count / 64)` submissions.
//!
//! Concurrency: the store itself holds no sync primitives — the router
//! owns it single-threaded. The `loom_tests` module below model-checks
//! the one concurrent shape it participates in (shared behind a facade
//! `Mutex`, producers racing a drainer) on the loom CI leg; see
//! `runtime::sync` for the facade.

use std::collections::HashMap;
use std::time::{Duration, Instant};

/// One key's pending run.
struct StoreGroup<T> {
    key: String,
    items: Vec<T>,
    /// When the oldest *currently pending* item entered the store
    /// (`None` while empty).
    oldest: Option<Instant>,
}

/// Per-key coalescing buffer with a size/age flush watermark; see the
/// module docs.
pub struct RequestStore<T> {
    groups: Vec<StoreGroup<T>>,
    index: HashMap<String, usize>,
    max_batch: usize,
    max_wait: Duration,
}

impl<T> RequestStore<T> {
    /// Empty store flushing at `max_batch` pending items per key or
    /// `max_wait` age of the oldest pending item.
    pub fn new(max_batch: usize, max_wait: Duration) -> Self {
        RequestStore {
            groups: Vec::new(),
            index: HashMap::new(),
            max_batch: max_batch.max(1),
            max_wait,
        }
    }

    /// Append one item under `key` (arriving `now`), preserving arrival
    /// order within the key and first-arrival order across keys.
    pub fn push(&mut self, key: &str, item: T, now: Instant) {
        let gi = match self.index.get(key) {
            Some(&gi) => gi,
            None => {
                let gi = self.groups.len();
                self.groups.push(StoreGroup {
                    key: key.to_string(),
                    items: Vec::new(),
                    oldest: None,
                });
                self.index.insert(key.to_string(), gi);
                gi
            }
        };
        let g = &mut self.groups[gi];
        if g.oldest.is_none() {
            g.oldest = Some(now);
        }
        g.items.push(item);
    }

    /// Total pending items across all keys.
    pub fn len(&self) -> usize {
        self.groups.iter().map(|g| g.items.len()).sum()
    }

    /// Whether nothing is pending.
    pub fn is_empty(&self) -> bool {
        self.groups.iter().all(|g| g.items.is_empty())
    }

    /// Pending items under `key` (0 for unknown keys).
    pub fn key_len(&self, key: &str) -> usize {
        self.index
            .get(key)
            .map(|&gi| self.groups[gi].items.len())
            .unwrap_or(0)
    }

    /// Whether the watermark has tripped: some key is at `max_batch`, or
    /// some key's oldest pending item is at least `max_wait` old.
    pub fn ready(&self, now: Instant) -> bool {
        self.groups.iter().any(|g| {
            g.items.len() >= self.max_batch
                || (!g.items.is_empty()
                    && g.oldest
                        .map(|t| now.saturating_duration_since(t) >= self.max_wait)
                        .unwrap_or(false))
        })
    }

    /// Earliest instant at which [`ready`](Self::ready) will trip on age
    /// alone (`None` while empty). A key already at `max_batch` reports
    /// its own `oldest` arrival — i.e. a time already in the past.
    pub fn next_flush_at(&self) -> Option<Instant> {
        self.groups
            .iter()
            .filter(|g| !g.items.is_empty())
            .filter_map(|g| {
                g.oldest.map(|t| {
                    if g.items.len() >= self.max_batch {
                        t
                    } else {
                        t + self.max_wait
                    }
                })
            })
            .min()
    }

    /// Take everything pending: one `(key, items)` run per non-empty key,
    /// keys in first-arrival order, items in arrival order. Keys stay
    /// known (so the cross-flush pack order never reshuffles) but their
    /// ages reset.
    pub fn drain(&mut self) -> Vec<(String, Vec<T>)> {
        let mut out = Vec::new();
        for g in &mut self.groups {
            g.oldest = None;
            if !g.items.is_empty() {
                out.push((g.key.clone(), std::mem::take(&mut g.items)));
            }
        }
        out
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn arrival_order_is_preserved_within_and_across_keys() {
        let t0 = Instant::now();
        let mut s: RequestStore<u32> = RequestStore::new(64, Duration::from_millis(1));
        s.push("b", 1, t0);
        s.push("a", 2, t0);
        s.push("b", 3, t0);
        s.push("a", 4, t0);
        assert_eq!(s.len(), 4);
        assert_eq!(s.key_len("b"), 2);
        let drained = s.drain();
        // Keys in FIRST-arrival order ("b" before "a"), items in arrival
        // order within each key — the stable pack order.
        assert_eq!(
            drained,
            vec![("b".to_string(), vec![1, 3]), ("a".to_string(), vec![2, 4])]
        );
        assert!(s.is_empty());
        // A later round keeps the same key order even if "a" now fills
        // first.
        s.push("a", 5, t0);
        s.push("b", 6, t0);
        assert_eq!(
            s.drain(),
            vec![("b".to_string(), vec![6]), ("a".to_string(), vec![5])]
        );
    }

    #[test]
    fn batch_watermark_trips_ready_immediately() {
        let t0 = Instant::now();
        let mut s: RequestStore<u32> = RequestStore::new(3, Duration::from_secs(3600));
        s.push("k", 0, t0);
        s.push("k", 1, t0);
        assert!(!s.ready(t0), "below both watermarks");
        s.push("k", 2, t0);
        assert!(s.ready(t0), "max_batch reached");
        // next_flush_at reports a non-future instant for a full key.
        assert!(s.next_flush_at().unwrap() <= t0);
    }

    #[test]
    fn age_watermark_trips_ready_after_max_wait() {
        let t0 = Instant::now();
        let wait = Duration::from_millis(10);
        let mut s: RequestStore<u32> = RequestStore::new(64, wait);
        s.push("k", 0, t0);
        assert!(!s.ready(t0));
        assert_eq!(s.next_flush_at().unwrap(), t0 + wait);
        assert!(s.ready(t0 + wait), "oldest item aged past max_wait");
        // Draining resets the age: a fresh push starts a fresh clock.
        s.drain();
        s.push("k", 1, t0 + wait);
        assert!(!s.ready(t0 + wait));
        assert_eq!(s.next_flush_at().unwrap(), t0 + wait + wait);
    }

    #[test]
    fn empty_store_never_flushes() {
        let s: RequestStore<u32> = RequestStore::new(1, Duration::ZERO);
        assert!(s.is_empty());
        assert!(!s.ready(Instant::now()));
        assert_eq!(s.next_flush_at(), None);
        assert_eq!(s.key_len("missing"), 0);
    }

    #[test]
    fn property_flush_order_matches_naive_model() {
        // Random push sequences with interleaved drains: the store's flush
        // output must equal a naive model that tracks first-arrival key
        // order and per-key arrival order — for any max_batch/max_wait, so
        // the coalesced pack order is a pure function of the arrival
        // sequence (never timing), mirroring the cross-round overlap
        // planner's determinism contract.
        crate::util::prop::forall(24, |rng, _| {
            let t0 = Instant::now();
            let max_batch = 1 + rng.below(8);
            let mut s: RequestStore<u64> =
                RequestStore::new(max_batch, Duration::from_millis(rng.below(20) as u64));
            // Naive model: keys in first-arrival order over the store's
            // whole lifetime, per-key items in arrival order.
            let mut key_order: Vec<String> = Vec::new();
            let mut pending: std::collections::HashMap<String, Vec<u64>> =
                std::collections::HashMap::new();
            for step in 0..2 + rng.below(6) {
                let pushes = rng.below(30);
                for p in 0..pushes {
                    let key = format!("k{}", rng.below(5));
                    let item = rng.next_u64();
                    s.push(&key, item, t0 + Duration::from_millis(p as u64));
                    if !key_order.contains(&key) {
                        key_order.push(key.clone());
                    }
                    pending.entry(key).or_default().push(item);
                }
                let keys: Vec<String> = key_order
                    .iter()
                    .filter(|k| pending.get(*k).map(|v| !v.is_empty()).unwrap_or(false))
                    .cloned()
                    .collect();
                let want: Vec<(String, Vec<u64>)> = keys
                    .into_iter()
                    .map(|k| {
                        let v = std::mem::take(pending.get_mut(&k).unwrap());
                        (k, v)
                    })
                    .collect();
                let total: usize = want.iter().map(|(_, v)| v.len()).sum();
                assert_eq!(s.len(), total, "step {step}: pending count");
                if want.iter().any(|(_, v)| v.len() >= max_batch) {
                    assert!(s.ready(t0 + Duration::from_secs(1)), "step {step}");
                }
                assert_eq!(s.drain(), want, "step {step}: flush order diverged");
                assert!(s.is_empty());
            }
        });
    }

    #[test]
    fn zero_max_wait_flushes_anything_pending() {
        let t0 = Instant::now();
        let mut s: RequestStore<u32> = RequestStore::new(64, Duration::ZERO);
        s.push("k", 7, t0);
        assert!(s.ready(t0), "zero max_wait: any pending item is flushable");
        assert_eq!(s.drain(), vec![("k".to_string(), vec![7])]);
    }
}

// Model-check suite, run only by the loom CI leg
// (`RUSTFLAGS="--cfg loom" cargo test --release --lib loom_`). The store
// is deterministic single-threaded; what loom pins is the flush
// bookkeeping under the one concurrent shape the server exposes it to —
// a facade Mutex shared between producer threads and a drainer.
#[cfg(all(loom, test))]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod loom_tests {
    use super::*;
    use crate::runtime::sync::{self, Arc, Mutex, PoisonError};

    /// Racing producers on distinct keys: nothing is lost, per-key
    /// arrival order survives, and the drain empties the store — in
    /// every interleaving.
    #[test]
    fn loom_concurrent_push_and_drain_loses_nothing() {
        loom::model(|| {
            let t0 = Instant::now();
            let store = Arc::new(Mutex::new(RequestStore::<u32>::new(64, Duration::ZERO)));
            let s2 = Arc::clone(&store);
            let t = sync::thread::spawn(move || {
                let mut g = s2.lock().unwrap_or_else(PoisonError::into_inner);
                g.push("a", 1, t0);
                g.push("a", 2, t0);
            });
            store
                .lock()
                .unwrap_or_else(PoisonError::into_inner)
                .push("b", 3, t0);
            t.join().unwrap();
            let mut g = store.lock().unwrap_or_else(PoisonError::into_inner);
            let mut drained = g.drain();
            drained.sort_by(|x, y| x.0.cmp(&y.0));
            let want = vec![("a".to_string(), vec![1, 2]), ("b".to_string(), vec![3])];
            assert_eq!(drained, want);
            assert!(g.is_empty(), "drain empties the store");
        });
    }

    /// A drainer racing a producer on ONE key: across any number of
    /// mid-stream drains, every push is handed out exactly once and the
    /// key's arrival order is preserved end to end.
    #[test]
    fn loom_drain_interleaved_with_push_preserves_order() {
        loom::model(|| {
            let t0 = Instant::now();
            let store = Arc::new(Mutex::new(RequestStore::<u32>::new(1, Duration::ZERO)));
            let s2 = Arc::clone(&store);
            let t = sync::thread::spawn(move || {
                for v in 0..2u32 {
                    s2.lock().unwrap_or_else(PoisonError::into_inner).push("k", v, t0);
                }
            });
            let mut got = Vec::new();
            for _ in 0..2 {
                let drained = store.lock().unwrap_or_else(PoisonError::into_inner).drain();
                for (_, vs) in drained {
                    got.extend(vs);
                }
            }
            t.join().unwrap();
            for (_, vs) in store.lock().unwrap_or_else(PoisonError::into_inner).drain() {
                got.extend(vs);
            }
            assert_eq!(got, vec![0, 1], "each push drains exactly once, in order");
        });
    }
}
