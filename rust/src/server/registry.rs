//! Per-dataset oracle registry: named datasets, each built **once** into a
//! shared [`MultiLevelKde`] + [`NeighborSampler`] pair that every client
//! of the serving layer queries through.
//!
//! The registry is the server-side answer to the paper's amortization
//! argument (Definition 1.1): preprocessing — building the multi-level
//! tree and its node estimators — is paid once per dataset, after which
//! every query is sub-linear. Registration is **idempotent and
//! first-writer-wins** (the same discipline as the tree's memo cache):
//! concurrent `register` calls for one name may build twice, but exactly
//! one build is kept and every caller gets that one, so all clients share
//! one memo cache and one set of estimators. Lookups of unregistered
//! names fail with the typed
//! [`BackendError::UnknownDataset`] — a *permanent* error (retrying
//! cannot make a dataset appear).

use std::collections::HashMap;
use std::sync::{Arc, PoisonError, RwLock};

use crate::kde::multilevel::MultiLevelKde;
use crate::kde::{KdeConfig, KdeCounters};
use crate::kernel::{Dataset, Kernel};
use crate::runtime::backend::KernelBackend;
use crate::runtime::error::BackendError;
use crate::sampling::NeighborSampler;

/// One registered dataset: the tree built over it, the neighbor sampler
/// wrapping that tree, and the dataset's own KDE-query accounting. All
/// clients resolving this name share this one instance (one memo cache,
/// one estimator build).
pub struct RegisteredDataset {
    name: String,
    /// The multi-level KDE tree built once over the dataset.
    pub tree: Arc<MultiLevelKde>,
    /// Neighbor sampler (Algorithm 4.11) over [`tree`](Self::tree) —
    /// serves the server's neighbor-sample requests.
    pub sampler: NeighborSampler,
    /// Logical KDE queries (memo-cache misses) charged to this dataset.
    pub counters: Arc<KdeCounters>,
}

impl RegisteredDataset {
    /// The name this dataset was registered under.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Number of points in the registered dataset.
    pub fn len(&self) -> usize {
        self.tree.ds.n
    }

    /// Whether the registered dataset is empty.
    pub fn is_empty(&self) -> bool {
        self.tree.ds.n == 0
    }
}

/// Named-dataset oracle registry shared by every client of a
/// [`KdeServer`](crate::server::KdeServer); see the module docs.
pub struct OracleRegistry {
    backend: Arc<dyn KernelBackend>,
    entries: RwLock<HashMap<String, Arc<RegisteredDataset>>>,
}

impl OracleRegistry {
    /// Empty registry over one shared execution backend (every registered
    /// dataset's tree dispatches through it, so its `calls()` counter is
    /// the server-wide dispatch count).
    pub fn new(backend: Arc<dyn KernelBackend>) -> Arc<Self> {
        Arc::new(OracleRegistry { backend, entries: RwLock::new(HashMap::new()) })
    }

    /// The shared execution backend the registry builds trees over.
    pub fn backend(&self) -> &Arc<dyn KernelBackend> {
        &self.backend
    }

    /// Register `ds` under `name`, building the multi-level tree once.
    ///
    /// Idempotent: if `name` is already registered the existing entry is
    /// returned untouched (the new build, if any raced in, is discarded).
    /// Under concurrent registration of the same name, every caller gets
    /// the same single surviving entry — first writer wins, like the
    /// tree's memo cache.
    pub fn register(
        &self,
        name: &str,
        ds: Arc<Dataset>,
        kernel: Kernel,
        cfg: &KdeConfig,
    ) -> Arc<RegisteredDataset> {
        if let Ok(existing) = self.get(name) {
            return existing;
        }
        // Build outside the lock: tree construction is the expensive part
        // and must not serialize lookups of other datasets.
        let counters = KdeCounters::new();
        let tree = Arc::new(MultiLevelKde::build(
            ds,
            kernel,
            cfg,
            self.backend.clone(),
            counters.clone(),
        ));
        let entry = Arc::new(RegisteredDataset {
            name: name.to_string(),
            sampler: NeighborSampler::new(tree.clone()),
            tree,
            counters,
        });
        let mut map = self.entries.write().unwrap_or_else(PoisonError::into_inner);
        map.entry(name.to_string()).or_insert(entry).clone()
    }

    /// Look up a registered dataset by name; unregistered names fail with
    /// the typed (permanent) [`BackendError::UnknownDataset`].
    pub fn get(&self, name: &str) -> Result<Arc<RegisteredDataset>, BackendError> {
        let map = self.entries.read().unwrap_or_else(PoisonError::into_inner);
        map.get(name)
            .cloned()
            .ok_or_else(|| BackendError::UnknownDataset { name: name.to_string() })
    }

    /// Registered dataset names, sorted.
    pub fn names(&self) -> Vec<String> {
        let map = self.entries.read().unwrap_or_else(PoisonError::into_inner);
        let mut names: Vec<String> = map.keys().cloned().collect();
        names.sort();
        names
    }

    /// Number of registered datasets.
    pub fn len(&self) -> usize {
        self.entries
            .read()
            .unwrap_or_else(PoisonError::into_inner)
            .len()
    }

    /// Whether the registry has no datasets.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::kernel::dataset::gaussian_mixture;
    use crate::runtime::backend::CpuBackend;
    use crate::util::rng::Rng;

    fn small_ds(seed: u64) -> Arc<Dataset> {
        let mut rng = Rng::new(seed);
        Arc::new(gaussian_mixture(32, 3, 2, 1.0, 0.5, &mut rng))
    }

    #[test]
    fn register_is_idempotent_and_shared() {
        let reg = OracleRegistry::new(CpuBackend::new());
        let a = reg.register("web", small_ds(1), Kernel::Laplacian, &KdeConfig::exact());
        let b = reg.register("web", small_ds(2), Kernel::Gaussian, &KdeConfig::exact());
        // Second registration under the same name is discarded: both
        // handles are the SAME entry (shared memo cache).
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(reg.len(), 1);
        assert_eq!(a.name(), "web");
        assert_eq!(a.len(), 32);
    }

    #[test]
    fn unknown_dataset_is_a_typed_permanent_error() {
        let reg = OracleRegistry::new(CpuBackend::new());
        match reg.get("nope") {
            Err(BackendError::UnknownDataset { name }) => {
                assert_eq!(name, "nope");
            }
            other => panic!("want UnknownDataset, got {:?}", other.map(|_| ())),
        }
        assert!(!BackendError::UnknownDataset { name: "nope".into() }.transient());
    }

    #[test]
    fn names_are_sorted() {
        let reg = OracleRegistry::new(CpuBackend::new());
        reg.register("zeta", small_ds(3), Kernel::Laplacian, &KdeConfig::exact());
        reg.register("alpha", small_ds(4), Kernel::Laplacian, &KdeConfig::exact());
        assert_eq!(reg.names(), vec!["alpha".to_string(), "zeta".to_string()]);
    }

    #[test]
    fn concurrent_registration_converges_to_one_entry() {
        let reg = OracleRegistry::new(CpuBackend::new());
        let handles: Vec<Arc<RegisteredDataset>> = std::thread::scope(|s| {
            (0..8u64)
                .map(|t| {
                    let reg = &reg;
                    s.spawn(move || {
                        reg.register(
                            "shared",
                            small_ds(100 + t),
                            Kernel::Laplacian,
                            &KdeConfig::exact(),
                        )
                    })
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect()
        });
        assert_eq!(reg.len(), 1);
        let first = &handles[0];
        for h in &handles[1..] {
            assert!(Arc::ptr_eq(first, h), "all racers share one surviving build");
        }
        // And the survivor answers queries consistently for everyone.
        let v = first.tree.query_point(first.tree.root(), 3);
        for h in &handles {
            assert_eq!(v.to_bits(), h.tree.query_point(h.tree.root(), 3).to_bits());
        }
    }
}
