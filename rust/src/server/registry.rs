//! Per-dataset oracle registry: named datasets, each built **once** into a
//! shared [`MultiLevelKde`] + [`NeighborSampler`] pair that every client
//! of the serving layer queries through.
//!
//! The registry is the server-side answer to the paper's amortization
//! argument (Definition 1.1): preprocessing — building the multi-level
//! tree and its node estimators — is paid once per dataset, after which
//! every query is sub-linear. Registration is **idempotent and
//! first-writer-wins** (the same discipline as the tree's memo cache):
//! concurrent `register` calls for one name may build twice, but exactly
//! one build is kept and every caller gets that one, so all clients share
//! one memo cache and one set of estimators. Lookups of unregistered
//! names fail with the typed
//! [`BackendError::UnknownDataset`] — a *permanent* error (retrying
//! cannot make a dataset appear).

use std::collections::HashMap;
use std::sync::{Arc, PoisonError, RwLock};

use crate::kde::multilevel::MultiLevelKde;
use crate::kde::{KdeConfig, KdeCounters};
use crate::kernel::{Dataset, Kernel};
use crate::runtime::backend::KernelBackend;
use crate::runtime::error::BackendError;
use crate::sampling::NeighborSampler;

/// One registered dataset: the tree built over it, the neighbor sampler
/// wrapping that tree, and the dataset's own KDE-query accounting. All
/// clients resolving this name share this one instance (one memo cache,
/// one estimator build).
pub struct RegisteredDataset {
    name: String,
    /// Monotone dataset version: 0 at first registration, bumped by each
    /// [`OracleRegistry::update`]. The server keys its coalescing store by
    /// `(name, version)`, so requests that resolved an older entry flush
    /// against *that* entry's tree — never a newer build they did not ask
    /// for.
    version: u64,
    /// The multi-level KDE tree built once over the dataset.
    pub tree: Arc<MultiLevelKde>,
    /// Neighbor sampler (Algorithm 4.11) over [`tree`](Self::tree) —
    /// serves the server's neighbor-sample requests.
    pub sampler: NeighborSampler,
    /// Logical KDE queries (memo-cache misses) charged to this dataset.
    pub counters: Arc<KdeCounters>,
}

impl RegisteredDataset {
    /// The name this dataset was registered under.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// The entry's dataset version (see the field docs).
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Number of points in the registered dataset.
    pub fn len(&self) -> usize {
        self.tree.ds.n
    }

    /// Whether the registered dataset is empty.
    pub fn is_empty(&self) -> bool {
        self.tree.ds.n == 0
    }
}

/// Named-dataset oracle registry shared by every client of a
/// [`KdeServer`](crate::server::KdeServer); see the module docs.
pub struct OracleRegistry {
    backend: Arc<dyn KernelBackend>,
    entries: RwLock<HashMap<String, Arc<RegisteredDataset>>>,
}

impl OracleRegistry {
    /// Empty registry over one shared execution backend (every registered
    /// dataset's tree dispatches through it, so its `calls()` counter is
    /// the server-wide dispatch count).
    pub fn new(backend: Arc<dyn KernelBackend>) -> Arc<Self> {
        Arc::new(OracleRegistry { backend, entries: RwLock::new(HashMap::new()) })
    }

    /// The shared execution backend the registry builds trees over.
    pub fn backend(&self) -> &Arc<dyn KernelBackend> {
        &self.backend
    }

    /// Register `ds` under `name`, building the multi-level tree once.
    ///
    /// Idempotent: if `name` is already registered the existing entry is
    /// returned untouched (the new build, if any raced in, is discarded).
    /// Under concurrent registration of the same name, every caller gets
    /// the same single surviving entry — first writer wins, like the
    /// tree's memo cache.
    pub fn register(
        &self,
        name: &str,
        ds: Arc<Dataset>,
        kernel: Kernel,
        cfg: &KdeConfig,
    ) -> Arc<RegisteredDataset> {
        if let Ok(existing) = self.get(name) {
            return existing;
        }
        // Build outside the lock: tree construction is the expensive part
        // and must not serialize lookups of other datasets.
        let entry = self.build_entry(name, ds, kernel, cfg, 0);
        let mut map = self.entries.write().unwrap_or_else(PoisonError::into_inner);
        map.entry(name.to_string()).or_insert(entry).clone()
    }

    /// Strict [`register`](Self::register): fails with the typed permanent
    /// [`BackendError::AlreadyRegistered`] when `name` is taken, instead
    /// of silently handing back the existing (possibly different) build.
    /// This is the entry point for callers that would otherwise mutate a
    /// served dataset in place — the registry makes replacement explicit
    /// ([`update`](Self::update)) so in-flight coalesced requests can
    /// never be flushed against a tree they did not resolve.
    pub fn try_register(
        &self,
        name: &str,
        ds: Arc<Dataset>,
        kernel: Kernel,
        cfg: &KdeConfig,
    ) -> Result<Arc<RegisteredDataset>, BackendError> {
        let already = || BackendError::AlreadyRegistered { name: name.to_string() };
        if self.get(name).is_ok() {
            return Err(already());
        }
        let entry = self.build_entry(name, ds, kernel, cfg, 0);
        let mut map = self.entries.write().unwrap_or_else(PoisonError::into_inner);
        if map.contains_key(name) {
            return Err(already());
        }
        map.insert(name.to_string(), entry.clone());
        Ok(entry)
    }

    /// Replace (or create) the entry under `name` with a fresh build over
    /// `ds`, bumping the dataset version. Existing handles to the old
    /// entry stay fully usable — their tree is immutable and their
    /// version identifies them — while new lookups resolve the fresh
    /// build. The server's request store keys by `(name, version)`, so a
    /// request coalesced against version `v` is flushed against version
    /// `v`'s tree even if an update lands mid-flight.
    pub fn update(
        &self,
        name: &str,
        ds: Arc<Dataset>,
        kernel: Kernel,
        cfg: &KdeConfig,
    ) -> Arc<RegisteredDataset> {
        // Build outside the lock; stamp the version under it so racing
        // updates serialize into distinct versions.
        let counters = KdeCounters::new();
        let tree = Arc::new(MultiLevelKde::build(
            ds,
            kernel,
            cfg,
            self.backend.clone(),
            counters.clone(),
        ));
        let mut map = self.entries.write().unwrap_or_else(PoisonError::into_inner);
        let version = map.get(name).map(|e| e.version + 1).unwrap_or(0);
        let entry = Arc::new(RegisteredDataset {
            name: name.to_string(),
            version,
            sampler: NeighborSampler::new(tree.clone()),
            tree,
            counters,
        });
        map.insert(name.to_string(), entry.clone());
        entry
    }

    /// Build a complete entry (tree + sampler + counters) for `name`.
    fn build_entry(
        &self,
        name: &str,
        ds: Arc<Dataset>,
        kernel: Kernel,
        cfg: &KdeConfig,
        version: u64,
    ) -> Arc<RegisteredDataset> {
        let counters = KdeCounters::new();
        let tree = Arc::new(MultiLevelKde::build(
            ds,
            kernel,
            cfg,
            self.backend.clone(),
            counters.clone(),
        ));
        Arc::new(RegisteredDataset {
            name: name.to_string(),
            version,
            sampler: NeighborSampler::new(tree.clone()),
            tree,
            counters,
        })
    }

    /// Look up a registered dataset by name; unregistered names fail with
    /// the typed (permanent) [`BackendError::UnknownDataset`].
    pub fn get(&self, name: &str) -> Result<Arc<RegisteredDataset>, BackendError> {
        let map = self.entries.read().unwrap_or_else(PoisonError::into_inner);
        map.get(name)
            .cloned()
            .ok_or_else(|| BackendError::UnknownDataset { name: name.to_string() })
    }

    /// Registered dataset names, sorted.
    pub fn names(&self) -> Vec<String> {
        let map = self.entries.read().unwrap_or_else(PoisonError::into_inner);
        let mut names: Vec<String> = map.keys().cloned().collect();
        names.sort();
        names
    }

    /// Number of registered datasets.
    pub fn len(&self) -> usize {
        self.entries
            .read()
            .unwrap_or_else(PoisonError::into_inner)
            .len()
    }

    /// Whether the registry has no datasets.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::kernel::dataset::gaussian_mixture;
    use crate::runtime::backend::CpuBackend;
    use crate::util::rng::Rng;

    fn small_ds(seed: u64) -> Arc<Dataset> {
        let mut rng = Rng::new(seed);
        Arc::new(gaussian_mixture(32, 3, 2, 1.0, 0.5, &mut rng))
    }

    #[test]
    fn register_is_idempotent_and_shared() {
        let reg = OracleRegistry::new(CpuBackend::new());
        let a = reg.register("web", small_ds(1), Kernel::Laplacian, &KdeConfig::exact());
        let b = reg.register("web", small_ds(2), Kernel::Gaussian, &KdeConfig::exact());
        // Second registration under the same name is discarded: both
        // handles are the SAME entry (shared memo cache).
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(reg.len(), 1);
        assert_eq!(a.name(), "web");
        assert_eq!(a.len(), 32);
    }

    #[test]
    fn unknown_dataset_is_a_typed_permanent_error() {
        let reg = OracleRegistry::new(CpuBackend::new());
        match reg.get("nope") {
            Err(BackendError::UnknownDataset { name }) => {
                assert_eq!(name, "nope");
            }
            other => panic!("want UnknownDataset, got {:?}", other.map(|_| ())),
        }
        assert!(!BackendError::UnknownDataset { name: "nope".into() }.transient());
    }

    #[test]
    fn try_register_conflicts_are_typed_and_permanent() {
        let reg = OracleRegistry::new(CpuBackend::new());
        let a = reg
            .try_register("web", small_ds(21), Kernel::Laplacian, &KdeConfig::exact())
            .unwrap();
        assert_eq!(a.version(), 0);
        match reg.try_register("web", small_ds(22), Kernel::Gaussian, &KdeConfig::exact()) {
            Err(BackendError::AlreadyRegistered { name }) => {
                assert_eq!(name, "web");
                assert!(!BackendError::AlreadyRegistered { name }.transient());
            }
            other => panic!("want AlreadyRegistered, got {:?}", other.map(|_| ())),
        }
        // The original entry is untouched by the failed attempt.
        assert!(Arc::ptr_eq(&a, &reg.get("web").unwrap()));
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn update_version_bumps_and_serves_the_fresh_tree() {
        let reg = OracleRegistry::new(CpuBackend::new());
        let v0 = reg.register("web", small_ds(31), Kernel::Laplacian, &KdeConfig::exact());
        assert_eq!(v0.version(), 0);
        let old_answer = v0.tree.query_point(v0.tree.root(), 3);
        let v1 = reg.update("web", small_ds(32), Kernel::Laplacian, &KdeConfig::exact());
        assert_eq!(v1.version(), 1);
        assert!(!Arc::ptr_eq(&v0, &v1), "update must replace, not alias");
        // New lookups resolve the fresh build ...
        assert!(Arc::ptr_eq(&v1, &reg.get("web").unwrap()));
        assert_eq!(reg.len(), 1, "still one name");
        // ... while the old handle keeps answering from its own tree.
        assert_eq!(
            old_answer.to_bits(),
            v0.tree.query_point(v0.tree.root(), 3).to_bits()
        );
        // Different dataset -> different answers (seeds 31 vs 32).
        let new_answer = v1.tree.query_point(v1.tree.root(), 3);
        assert!(old_answer != new_answer, "fresh dataset must serve fresh values");
        // update on an unregistered name creates version 0.
        let fresh = reg.update("logs", small_ds(33), Kernel::Laplacian, &KdeConfig::exact());
        assert_eq!(fresh.version(), 0);
        assert_eq!(reg.len(), 2);
    }

    #[test]
    fn names_are_sorted() {
        let reg = OracleRegistry::new(CpuBackend::new());
        reg.register("zeta", small_ds(3), Kernel::Laplacian, &KdeConfig::exact());
        reg.register("alpha", small_ds(4), Kernel::Laplacian, &KdeConfig::exact());
        assert_eq!(reg.names(), vec!["alpha".to_string(), "zeta".to_string()]);
    }

    #[test]
    fn concurrent_registration_converges_to_one_entry() {
        let reg = OracleRegistry::new(CpuBackend::new());
        let handles: Vec<Arc<RegisteredDataset>> = std::thread::scope(|s| {
            (0..8u64)
                .map(|t| {
                    let reg = &reg;
                    s.spawn(move || {
                        reg.register(
                            "shared",
                            small_ds(100 + t),
                            Kernel::Laplacian,
                            &KdeConfig::exact(),
                        )
                    })
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect()
        });
        assert_eq!(reg.len(), 1);
        let first = &handles[0];
        for h in &handles[1..] {
            assert!(Arc::ptr_eq(first, h), "all racers share one surviving build");
        }
        // And the survivor answers queries consistently for everyone.
        let v = first.tree.query_point(first.tree.root(), 3);
        for h in &handles {
            assert_eq!(v.to_bits(), h.tree.query_point(h.tree.root(), 3).to_bits());
        }
    }
}
