//! Multi-level KDE (Algorithm 4.1): a binary tree over contiguous index
//! ranges of the dataset, each node holding an independent KDE oracle over
//! its range. The tree is the engine behind Algorithm 4.11's weighted
//! neighbor sampling descent and everything built on it.
//!
//! Per the technical overview (§2), KDE answers must be **consistent**
//! between the sampling descent and the later probability computation
//! (`neighbor_prob`) — so per-(node, query-point) answers are memoized.
//! Cache misses are what the query counter counts; cache hits are free,
//! matching the paper's accounting where a degree array is "computed once".

use std::cell::RefCell;
use std::sync::Arc;

use crate::util::fxhash::FxHashMap;

use crate::kde::{EstimatorKind, Kde, KdeConfig, KdeCounters, NaiveKde, SamplingKde};
use crate::kde::hbe::HbeKde;
use crate::kernel::{Dataset, Kernel};
use crate::runtime::backend::KernelBackend;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub struct Node {
    pub lo: usize,
    pub hi: usize,
    pub left: Option<usize>,
    pub right: Option<usize>,
}

pub struct MultiLevelKde {
    pub ds: Arc<Dataset>,
    pub kernel: Kernel,
    nodes: Vec<Node>,
    oracles: Vec<Box<dyn Kde>>,
    cache: RefCell<FxHashMap<(u32, u32), f64>>,
    pub counters: Arc<KdeCounters>,
}

// Queries go through a RefCell cache; the structure is used single-threaded
// (the coordinator owns per-shard instances behind a Mutex).
unsafe impl Sync for MultiLevelKde {}

impl MultiLevelKde {
    /// Build the tree with the configured estimator at every node
    /// (Lemma 4.2: construction cost is one level's cost times O(log n)).
    pub fn build(
        ds: Arc<Dataset>,
        kernel: Kernel,
        cfg: &KdeConfig,
        backend: Arc<dyn KernelBackend>,
        counters: Arc<KdeCounters>,
    ) -> Self {
        let mut rng = Rng::new(cfg.seed);
        let mut nodes = Vec::new();
        let mut oracles: Vec<Box<dyn Kde>> = Vec::new();
        Self::build_rec(
            &ds, kernel, cfg, &backend, &counters, &mut rng, 0, ds.n, &mut nodes, &mut oracles,
        );
        MultiLevelKde {
            ds,
            kernel,
            nodes,
            oracles,
            cache: RefCell::new(FxHashMap::default()),
            counters,
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn build_rec(
        ds: &Arc<Dataset>,
        kernel: Kernel,
        cfg: &KdeConfig,
        backend: &Arc<dyn KernelBackend>,
        counters: &Arc<KdeCounters>,
        rng: &mut Rng,
        lo: usize,
        hi: usize,
        nodes: &mut Vec<Node>,
        oracles: &mut Vec<Box<dyn Kde>>,
    ) -> usize {
        let id = nodes.len();
        nodes.push(Node { lo, hi, left: None, right: None });
        let len = hi - lo;
        let oracle: Box<dyn Kde> = if len <= cfg.leaf_cutoff {
            Box::new(NaiveKde::new(
                ds.clone(),
                kernel,
                lo,
                hi,
                backend.clone(),
                counters.clone(),
            ))
        } else {
            match cfg.kind {
                EstimatorKind::Naive => Box::new(NaiveKde::new(
                    ds.clone(),
                    kernel,
                    lo,
                    hi,
                    backend.clone(),
                    counters.clone(),
                )),
                EstimatorKind::Sampling { .. } => Box::new(SamplingKde::new(
                    ds.clone(),
                    kernel,
                    lo,
                    hi,
                    cfg,
                    backend.clone(),
                    counters.clone(),
                    rng,
                )),
                EstimatorKind::Hbe { tables, width } => Box::new(HbeKde::new(
                    ds.clone(),
                    kernel,
                    lo,
                    hi,
                    tables,
                    width,
                    counters.clone(),
                    rng,
                )),
                EstimatorKind::PartitionTree { eps } => {
                    Box::new(crate::kde::ptree::PartitionTreeKde::new(
                        ds.clone(),
                        kernel,
                        lo,
                        hi,
                        eps,
                        counters.clone(),
                    ))
                }
            }
        };
        oracles.push(oracle);
        if len > 1 {
            let mid = lo + len / 2;
            let l = Self::build_rec(
                ds, kernel, cfg, backend, counters, rng, lo, mid, nodes, oracles,
            );
            let r = Self::build_rec(
                ds, kernel, cfg, backend, counters, rng, mid, hi, nodes, oracles,
            );
            nodes[id].left = Some(l);
            nodes[id].right = Some(r);
        }
        id
    }

    pub fn root(&self) -> usize {
        0
    }

    pub fn node(&self, id: usize) -> Node {
        self.nodes[id]
    }

    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Memoized KDE answer for dataset point `i` against node `id`'s
    /// subset. Includes `k(x_i, x_i)` if `i` lies inside the node's range —
    /// callers subtract 1.0 in that case (Alg 4.3 / 4.11).
    pub fn query_point(&self, id: usize, i: usize) -> f64 {
        let key = (id as u32, i as u32);
        if let Some(&v) = self.cache.borrow().get(&key) {
            return v;
        }
        let v = self.oracles[id].query(self.ds.point(i));
        self.cache.borrow_mut().insert(key, v);
        v
    }

    /// Un-memoized query for an arbitrary vector (serving path).
    pub fn query_vec(&self, id: usize, y: &[f32]) -> f64 {
        self.oracles[id].query(y)
    }

    /// Clear the per-point memo table (experiment hygiene between runs).
    pub fn clear_cache(&self) {
        self.cache.borrow_mut().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::dataset::gaussian_mixture;
    use crate::runtime::backend::CpuBackend;

    fn build_exact(n: usize, seed: u64) -> (Arc<Dataset>, MultiLevelKde) {
        let mut rng = Rng::new(seed);
        let ds = Arc::new(gaussian_mixture(n, 4, 2, 1.0, 0.5, &mut rng));
        let tree = MultiLevelKde::build(
            ds.clone(),
            Kernel::Laplacian,
            &KdeConfig::exact(),
            CpuBackend::new(),
            KdeCounters::new(),
        );
        (ds, tree)
    }

    #[test]
    fn tree_covers_all_ranges() {
        let (_, tree) = build_exact(37, 61); // non-power-of-two
        // Every internal node's children partition it.
        for id in 0..tree.num_nodes() {
            let n = tree.node(id);
            if let (Some(l), Some(r)) = (n.left, n.right) {
                let (nl, nr) = (tree.node(l), tree.node(r));
                assert_eq!(nl.lo, n.lo);
                assert_eq!(nl.hi, nr.lo);
                assert_eq!(nr.hi, n.hi);
            } else {
                assert_eq!(n.hi - n.lo, 1, "leaf must be a single point");
            }
        }
        let root = tree.node(tree.root());
        assert_eq!((root.lo, root.hi), (0, 37));
    }

    #[test]
    fn node_count_is_2n_minus_1() {
        let (_, tree) = build_exact(32, 63);
        assert_eq!(tree.num_nodes(), 2 * 32 - 1);
    }

    #[test]
    fn exact_tree_children_sum_to_parent() {
        let (ds, tree) = build_exact(24, 65);
        for id in 0..tree.num_nodes() {
            let n = tree.node(id);
            if let (Some(l), Some(r)) = (n.left, n.right) {
                for q in [0usize, 7, 23] {
                    let parent = tree.query_point(id, q);
                    let sum = tree.query_point(l, q) + tree.query_point(r, q);
                    assert!(
                        (parent - sum).abs() < 1e-6 * (1.0 + parent),
                        "node {id} point {q}: {parent} vs {sum}"
                    );
                    let _ = &ds;
                }
            }
        }
    }

    #[test]
    fn cache_memoizes_and_counts_misses_only() {
        let (_, tree) = build_exact(16, 67);
        let before = tree.counters.queries();
        let a = tree.query_point(0, 3);
        let mid = tree.counters.queries();
        let b = tree.query_point(0, 3);
        let after = tree.counters.queries();
        assert_eq!(a, b);
        assert_eq!(mid, before + 1);
        assert_eq!(after, mid, "cache hit must not count as a query");
    }

    #[test]
    fn query_point_matches_exact_range_sum() {
        let (ds, tree) = build_exact(20, 69);
        for id in [0usize, 1, 2] {
            let n = tree.node(id);
            let q = 5;
            let got = tree.query_point(id, q);
            let want: f64 = (n.lo..n.hi)
                .map(|j| Kernel::Laplacian.eval(ds.point(j), ds.point(q)) as f64)
                .sum();
            assert!((got - want).abs() < 1e-6 * (1.0 + want));
        }
    }
}
