//! Multi-level KDE (Algorithm 4.1): a binary tree over contiguous index
//! ranges of the dataset, each node holding an independent KDE oracle over
//! its range. The tree is the engine behind Algorithm 4.11's weighted
//! neighbor sampling descent and everything built on it.
//!
//! Per the technical overview (§2), KDE answers must be **consistent**
//! between the sampling descent and the later probability computation
//! (`neighbor_prob`) — so per-(node, query-point) answers are memoized.
//! Cache misses are what the query counter counts; cache hits are free,
//! matching the paper's accounting where a degree array is "computed once".
//!
//! The memo table is sharded across `CACHE_SHARDS` mutexes, which makes
//! the structure safely `Sync` (no `unsafe impl`) and keeps contention low
//! when the coordinator or the batched pipeline queries it from several
//! threads. Concurrent misses of the same key may compute twice, but the
//! first insert wins and every caller observes that single value — the
//! consistency property Algorithm 5.1 needs survives races.
//!
//! [`MultiLevelKde::query_points`] is the per-node batched entry point: it
//! dedups its index list against the cache and resolves the misses with
//! fused backend submissions instead of one dispatch per point.
//! [`MultiLevelKde::query_points_multi`] is the *level-fused* entry the
//! level-order walkers use: it coalesces the cache misses of **several
//! nodes'** query groups into shared padded submissions (planned by
//! [`plan_level_fusion_adaptive`](crate::coordinator::batcher::plan_level_fusion_adaptive),
//! which admits segments largest-first so that groups from *different
//! tree levels* — the frontier-batched walk engine's shape — share
//! submissions too; executed by `KernelBackend::sums_ranged` — one
//! dispatch per B=64-row submission, each node's data packed as one
//! segment with per-row ranges). That is what makes a whole sparsifier round cost O(log n)
//! backend executions instead of one per tree node touched (pinned by
//! `tests/fusion.rs`); oracles without a [`FusedView`] (HBE, partition
//! tree) fall back to their own `query_batch`, one dispatch per group.
//! When a fused plan spans several submissions, packing and execution are
//! pipelined through the double-buffered submission queue
//! ([`try_run_double_buffered`]): submission r + 1's rows and data
//! segments are gathered on a packer thread while the backend runs
//! submission r — same submissions, same order, same values; wall-clock
//! only ([`MultiLevelKde::set_overlap`] is the sequential fallback
//! switch). Dispatch failures (and packer panics) surface through
//! [`MultiLevelKde::try_query_points_multi`] as typed
//! [`BackendError`](crate::runtime::BackendError)s; the infallible
//! entry points are thin panicking wrappers.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, PoisonError};

use crate::util::fxhash::FxHashMap;

use crate::coordinator::batcher::{
    plan_level_fusion_adaptive, try_run_double_buffered, FuseJob, FuseSubmission, OverlapEpoch,
    OverlapSession,
};
use crate::kde::hbe::HbeKde;
use crate::kde::{
    BufferKde, EstimatorKind, FusedView, Kde, KdeConfig, KdeCounters, NaiveKde, SamplingKde,
};
use crate::kernel::{Dataset, Kernel};
use crate::runtime::backend::KernelBackend;
use crate::runtime::error::{catch_panic, BackendError};
use crate::runtime::pjrt::{AOT_B, AOT_M};
use crate::util::rng::Rng;

/// Number of independent mutex-protected cache shards.
const CACHE_SHARDS: usize = 16;

/// One tree node: a contiguous index range `[lo, hi)` of the dataset.
#[derive(Clone, Copy, Debug)]
pub struct Node {
    /// First dataset index of the node's range.
    pub lo: usize,
    /// One past the last dataset index of the node's range.
    pub hi: usize,
    /// Left child id (`None` for single-point leaves).
    pub left: Option<usize>,
    /// Right child id (`None` for single-point leaves).
    pub right: Option<usize>,
}

impl Node {
    /// Both child ids of an internal node.
    ///
    /// # Panics
    ///
    /// Panics on a single-point leaf. The samplers only descend while
    /// `hi - lo > 1`, and `build_rec` splits every range of two or more
    /// points, so on every descent path both children exist.
    pub fn children(&self) -> (usize, usize) {
        match (self.left, self.right) {
            (Some(l), Some(r)) => (l, r),
            _ => unreachable!("children() called on a single-point leaf"),
        }
    }
}

/// Sharded (node, point) -> (stamp, answer) memo table; safely `Sync`.
///
/// Each entry is stamped with the edit version it was computed under
/// (`MultiLevelKde::stamp`: the node's version plus the query point's
/// version; always 0 for statically built trees). A lookup only hits when
/// the stored stamp equals the current one, so entries invalidated by a
/// dynamic edit — everything keyed by a node on the edited slot's ancestor
/// path, plus everything queried *by* the edited point — simply stop
/// matching and are lazily overwritten on the next miss. Versions only
/// grow, so a stale entry can never validate again.
struct ShardedCache {
    shards: Vec<Mutex<FxHashMap<(u32, u32), (u64, f64)>>>,
}

impl ShardedCache {
    fn new() -> Self {
        ShardedCache {
            shards: (0..CACHE_SHARDS)
                .map(|_| Mutex::new(FxHashMap::default()))
                .collect(),
        }
    }

    #[inline]
    fn shard(&self, key: (u32, u32)) -> &Mutex<FxHashMap<(u32, u32), (u64, f64)>> {
        let h = key.0 as usize ^ (key.1 as usize).wrapping_mul(0x9E37_79B9);
        &self.shards[h % CACHE_SHARDS]
    }

    #[inline]
    fn get(&self, key: (u32, u32), stamp: u64) -> Option<f64> {
        // Poison recovery: a panicked writer leaves at worst a missing
        // entry, never a torn one ((u64, f64) inserts are single-step).
        self.shard(key)
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .get(&key)
            .and_then(|&(s, v)| if s == stamp { Some(v) } else { None })
    }

    /// Insert unless a same-stamp entry is present; returns the value that
    /// ended up cached (the first same-stamp writer's), which the caller
    /// must report for consistency. A staler-stamp entry is overwritten.
    #[inline]
    fn insert_or_get(&self, key: (u32, u32), stamp: u64, v: f64) -> f64 {
        let mut shard = self
            .shard(key)
            .lock()
            .unwrap_or_else(PoisonError::into_inner);
        match shard.get(&key) {
            Some(&(s, cached)) if s == stamp => cached,
            _ => {
                shard.insert(key, (stamp, v));
                v
            }
        }
    }

    fn clear(&self) {
        for s in &self.shards {
            s.lock().unwrap_or_else(PoisonError::into_inner).clear();
        }
    }
}

/// The multi-level KDE structure (Algorithm 4.1); see the module docs.
pub struct MultiLevelKde {
    /// The dataset the tree is built over.
    pub ds: Arc<Dataset>,
    /// Kernel shared by every node oracle.
    pub kernel: Kernel,
    nodes: Vec<Node>,
    oracles: Vec<Box<dyn Kde>>,
    cache: ShardedCache,
    leaf_cutoff: usize,
    /// The backend fused submissions dispatch through (the same one the
    /// node oracles were built over).
    backend: Arc<dyn KernelBackend>,
    /// Level fusion on/off (on by default; the off switch exists for
    /// fused-vs-unfused parity tests and dispatch-count A/Bs).
    fuse: AtomicBool,
    /// Overlapped pack/execute pipelining of fused submissions (on by
    /// default; off is the strictly sequential fallback).
    overlap: AtomicBool,
    /// Cross-round reuse of the persistent overlap pipeline (on by
    /// default; off spawns a fresh per-call packer as before).
    cross_round: AtomicBool,
    /// The persistent packer pipeline shared across successive
    /// `query_points_multi` rounds (lazy; see [`OverlapSession`]).
    session: OverlapSession,
    /// `query_points_multi` rounds issued (the samplers' per-batch round
    /// accounting; probe fusion is measured as a drop in this counter).
    multi_calls: AtomicU64,
    /// Shared KDE-query accounting (cache misses only).
    pub counters: Arc<KdeCounters>,
    /// Per-node RNG snapshots recorded at [`build_dynamic`]
    /// (`Self::build_dynamic`) time, *before* the node's oracle consumed
    /// any draws. A path rebuild replays the snapshot so the rebuilt
    /// oracle's sample indices are exactly what a fresh same-seed build
    /// over the current dataset would draw — the bit-identity contract
    /// `tests/dynamic.rs` pins. Empty for statically built trees.
    rng_snaps: Vec<Rng>,
    /// Per-node edit versions (bumped along the edited slot's ancestor
    /// path). Empty for static trees (stamp 0 everywhere).
    node_versions: Vec<u64>,
    /// Per-slot edit versions (bumped for the edited slot itself, whose
    /// coordinates changed for *every* node it queries). Empty for static
    /// trees.
    point_versions: Vec<u64>,
    /// The build config, retained so path rebuilds can reconstruct
    /// oracles. `None` marks a statically built tree (no edits allowed).
    dyn_cfg: Option<KdeConfig>,
    /// Edits applied (`insert` + `delete`).
    edit_count: u64,
    /// Node oracles rebuilt across all edits — the dispatch-count contract
    /// pins this at O(log n) per edit.
    edit_rebuilds: u64,
}

impl MultiLevelKde {
    /// Build the tree with the configured estimator at every node
    /// (Lemma 4.2: construction cost is one level's cost times O(log n)).
    pub fn build(
        ds: Arc<Dataset>,
        kernel: Kernel,
        cfg: &KdeConfig,
        backend: Arc<dyn KernelBackend>,
        counters: Arc<KdeCounters>,
    ) -> Self {
        let mut rng = Rng::new(cfg.seed);
        let mut nodes = Vec::new();
        let mut oracles: Vec<Box<dyn Kde>> = Vec::new();
        Self::build_rec(
            &ds, kernel, cfg, &backend, &counters, &mut rng, 0, ds.n, &mut nodes, &mut oracles,
        );
        MultiLevelKde {
            ds,
            kernel,
            nodes,
            oracles,
            cache: ShardedCache::new(),
            leaf_cutoff: cfg.leaf_cutoff,
            backend,
            fuse: AtomicBool::new(true),
            overlap: AtomicBool::new(true),
            cross_round: AtomicBool::new(true),
            session: OverlapSession::new(),
            multi_calls: AtomicU64::new(0),
            counters,
            rng_snaps: Vec::new(),
            node_versions: Vec::new(),
            point_versions: Vec::new(),
            dyn_cfg: None,
            edit_count: 0,
            edit_rebuilds: 0,
        }
    }

    /// Build a *dynamic* tree: same shape and semantics as
    /// [`build`](Self::build), but every oracle owns its scan buffer
    /// (gathered copies, never borrows of the shared dataset) and the
    /// tree records a per-node RNG snapshot, so
    /// [`insert`](Self::insert) / [`delete`](Self::delete) can rebuild
    /// exactly the O(log n) oracles on an edited slot's ancestor path
    /// while leaving every other node's cached sums and samples intact.
    ///
    /// Restrictions (asserted):
    /// * `kind` must be `Naive` or `Sampling` — the estimator families
    ///   whose construction draws depend only on the range *shape*, which
    ///   is what makes a path rebuild reproduce a fresh build bit for bit.
    /// * `kernel` must not be `RationalQuadratic`: deletes rely on the
    ///   far-sentinel tombstone ([`Dataset::TOMBSTONE_COORD`]) carrying
    ///   exactly zero kernel mass, and `1/(1+d^2)` never underflows.
    pub fn build_dynamic(
        ds: Arc<Dataset>,
        kernel: Kernel,
        cfg: &KdeConfig,
        backend: Arc<dyn KernelBackend>,
        counters: Arc<KdeCounters>,
    ) -> Self {
        assert!(
            kernel != Kernel::RationalQuadratic,
            "dynamic trees need a kernel that underflows at the tombstone sentinel"
        );
        assert!(
            matches!(cfg.kind, EstimatorKind::Naive | EstimatorKind::Sampling { .. }),
            "dynamic trees support Naive and Sampling estimators only"
        );
        let mut rng = Rng::new(cfg.seed);
        let mut nodes = Vec::new();
        let mut oracles: Vec<Box<dyn Kde>> = Vec::new();
        let mut snaps: Vec<Rng> = Vec::new();
        Self::build_dyn_rec(
            &ds, kernel, cfg, &backend, &counters, &mut rng, 0, ds.n, &mut nodes, &mut oracles,
            &mut snaps,
        );
        let n_nodes = nodes.len();
        let n_points = ds.n;
        MultiLevelKde {
            ds,
            kernel,
            nodes,
            oracles,
            cache: ShardedCache::new(),
            leaf_cutoff: cfg.leaf_cutoff,
            backend,
            fuse: AtomicBool::new(true),
            overlap: AtomicBool::new(true),
            cross_round: AtomicBool::new(true),
            session: OverlapSession::new(),
            multi_calls: AtomicU64::new(0),
            counters,
            rng_snaps: snaps,
            node_versions: vec![0; n_nodes],
            point_versions: vec![0; n_points],
            dyn_cfg: Some(*cfg),
            edit_count: 0,
            edit_rebuilds: 0,
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn build_rec(
        ds: &Arc<Dataset>,
        kernel: Kernel,
        cfg: &KdeConfig,
        backend: &Arc<dyn KernelBackend>,
        counters: &Arc<KdeCounters>,
        rng: &mut Rng,
        lo: usize,
        hi: usize,
        nodes: &mut Vec<Node>,
        oracles: &mut Vec<Box<dyn Kde>>,
    ) -> usize {
        let id = nodes.len();
        nodes.push(Node { lo, hi, left: None, right: None });
        let len = hi - lo;
        let oracle: Box<dyn Kde> = if len <= cfg.leaf_cutoff {
            Box::new(NaiveKde::new(
                ds.clone(),
                kernel,
                lo,
                hi,
                backend.clone(),
                counters.clone(),
            ))
        } else {
            match cfg.kind {
                EstimatorKind::Naive => Box::new(NaiveKde::new(
                    ds.clone(),
                    kernel,
                    lo,
                    hi,
                    backend.clone(),
                    counters.clone(),
                )),
                EstimatorKind::Sampling { .. } => Box::new(SamplingKde::new(
                    ds.clone(),
                    kernel,
                    lo,
                    hi,
                    cfg,
                    backend.clone(),
                    counters.clone(),
                    rng,
                )),
                EstimatorKind::Hbe { tables, width } => Box::new(HbeKde::new(
                    ds.clone(),
                    kernel,
                    lo,
                    hi,
                    tables,
                    width,
                    counters.clone(),
                    rng,
                )),
                EstimatorKind::PartitionTree { eps } => {
                    Box::new(crate::kde::ptree::PartitionTreeKde::new(
                        ds.clone(),
                        kernel,
                        lo,
                        hi,
                        eps,
                        counters.clone(),
                    ))
                }
            }
        };
        oracles.push(oracle);
        if len > 1 {
            let mid = lo + len / 2;
            let l = Self::build_rec(
                ds, kernel, cfg, backend, counters, rng, lo, mid, nodes, oracles,
            );
            let r = Self::build_rec(
                ds, kernel, cfg, backend, counters, rng, mid, hi, nodes, oracles,
            );
            nodes[id].left = Some(l);
            nodes[id].right = Some(r);
        }
        id
    }

    /// Dynamic-tree oracle factory: leaves and `Naive` nodes get an
    /// owned-buffer exact scan ([`BufferKde`] — numerically identical to
    /// the static tree's [`NaiveKde`], but holding no dataset `Arc`);
    /// `Sampling` nodes gather their subsample into an owned buffer as
    /// before. Shared by the initial build and every path rebuild.
    fn dyn_oracle(
        ds: &Arc<Dataset>,
        kernel: Kernel,
        cfg: &KdeConfig,
        backend: &Arc<dyn KernelBackend>,
        counters: &Arc<KdeCounters>,
        rng: &mut Rng,
        lo: usize,
        hi: usize,
    ) -> Box<dyn Kde> {
        let len = hi - lo;
        if len <= cfg.leaf_cutoff || matches!(cfg.kind, EstimatorKind::Naive) {
            Box::new(BufferKde::gather(
                ds,
                kernel,
                lo,
                hi,
                backend.clone(),
                counters.clone(),
            ))
        } else {
            Box::new(SamplingKde::new(
                ds.clone(),
                kernel,
                lo,
                hi,
                cfg,
                backend.clone(),
                counters.clone(),
                rng,
            ))
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn build_dyn_rec(
        ds: &Arc<Dataset>,
        kernel: Kernel,
        cfg: &KdeConfig,
        backend: &Arc<dyn KernelBackend>,
        counters: &Arc<KdeCounters>,
        rng: &mut Rng,
        lo: usize,
        hi: usize,
        nodes: &mut Vec<Node>,
        oracles: &mut Vec<Box<dyn Kde>>,
        snaps: &mut Vec<Rng>,
    ) -> usize {
        let id = nodes.len();
        nodes.push(Node { lo, hi, left: None, right: None });
        // Snapshot BEFORE the oracle consumes draws: a rebuild replays
        // exactly the draw stream a fresh build would see at this node
        // (construction draw counts depend only on the range shape, never
        // on coordinates, so the stream stays aligned across edits).
        snaps.push(rng.clone());
        oracles.push(Self::dyn_oracle(ds, kernel, cfg, backend, counters, rng, lo, hi));
        let len = hi - lo;
        if len > 1 {
            let mid = lo + len / 2;
            let l = Self::build_dyn_rec(
                ds, kernel, cfg, backend, counters, rng, lo, mid, nodes, oracles, snaps,
            );
            let r = Self::build_dyn_rec(
                ds, kernel, cfg, backend, counters, rng, mid, hi, nodes, oracles, snaps,
            );
            nodes[id].left = Some(l);
            nodes[id].right = Some(r);
        }
        id
    }

    /// Whether this tree was built with [`build_dynamic`]
    /// (`Self::build_dynamic`) and accepts edits.
    pub fn is_dynamic(&self) -> bool {
        self.dyn_cfg.is_some()
    }

    /// `(edits, oracle_rebuilds)`: edits applied so far and the total
    /// node-oracle rebuilds they cost. The dispatch-count contract pinned
    /// by `tests/dynamic.rs`: `oracle_rebuilds <= edits * (log2(n) + 1)`.
    pub fn edit_stats(&self) -> (u64, u64) {
        (self.edit_count, self.edit_rebuilds)
    }

    /// Insert a point into a tombstoned slot (copy-on-write on the shared
    /// dataset), rebuilding only the slot's ancestor-path oracles. Returns
    /// the slot written, or `None` when no free slot exists — dynamic
    /// trees index a fixed `[0, n)` slot space, so grow by building over a
    /// dataset with spare (deleted) capacity.
    ///
    /// # Panics
    ///
    /// Panics on a statically built tree.
    pub fn insert(&mut self, row: &[f32]) -> Option<usize> {
        assert!(self.is_dynamic(), "insert on a static tree: use build_dynamic");
        let slot = Arc::make_mut(&mut self.ds).insert_reuse(row)?;
        self.rebuild_path(slot);
        Some(slot)
    }

    /// Tombstone-delete `slot` (copy-on-write on the shared dataset),
    /// rebuilding only the slot's ancestor-path oracles. Returns `false`
    /// if the slot was already dead.
    ///
    /// # Panics
    ///
    /// Panics on a statically built tree.
    pub fn delete(&mut self, slot: usize) -> bool {
        assert!(self.is_dynamic(), "delete on a static tree: use build_dynamic");
        if !Arc::make_mut(&mut self.ds).delete(slot) {
            return false;
        }
        self.rebuild_path(slot);
        true
    }

    /// Rebuild the oracles on `slot`'s root-to-leaf ancestor path from
    /// their recorded RNG snapshots and bump the stamps that invalidate
    /// exactly the affected memo entries: the path nodes' versions (their
    /// subset data changed for every query point) and the slot's point
    /// version (its coordinates changed for every node).
    fn rebuild_path(&mut self, slot: usize) {
        let cfg = match self.dyn_cfg {
            Some(c) => c,
            None => return,
        };
        let mut id = 0usize;
        loop {
            let node = self.nodes[id];
            let mut rng = self.rng_snaps[id].clone();
            self.oracles[id] = Self::dyn_oracle(
                &self.ds,
                self.kernel,
                &cfg,
                &self.backend,
                &self.counters,
                &mut rng,
                node.lo,
                node.hi,
            );
            self.node_versions[id] += 1;
            self.edit_rebuilds += 1;
            if node.hi - node.lo <= 1 {
                break;
            }
            let mid = node.lo + (node.hi - node.lo) / 2;
            let next = if slot < mid { node.left } else { node.right };
            match next {
                Some(c) => id = c,
                None => break,
            }
        }
        self.point_versions[slot] += 1;
        self.edit_count += 1;
    }

    /// The stamp a (node, point) memo entry must carry to be valid now.
    /// Versions only grow, so any edit touching either coordinate of the
    /// key strictly increases the stamp and the stale entry never hits
    /// again. Statically built trees have empty version vectors: stamp 0
    /// everywhere, the pre-dynamic behavior unchanged.
    #[inline]
    fn stamp(&self, id: usize, i: usize) -> u64 {
        if self.node_versions.is_empty() {
            return 0;
        }
        self.node_versions[id] + self.point_versions[i]
    }

    /// Id of the root node (covers the whole dataset).
    pub fn root(&self) -> usize {
        0
    }

    /// The node with id `id`.
    pub fn node(&self, id: usize) -> Node {
        self.nodes[id]
    }

    /// Total number of tree nodes (`2n - 1` for a full binary split).
    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Enable/disable level fusion (on by default). Off routes every query
    /// group through its node oracle's `query_batch` — one backend dispatch
    /// per (node, level) group, the pre-fusion evaluation shape — kept for
    /// fused-vs-unfused parity tests and dispatch-count A/Bs. Answers are
    /// bit-identical either way on `CpuBackend` and single-threaded
    /// `TiledBackend`; multi-threaded `TiledBackend` matches except for
    /// miss groups small enough that the *unfused* dispatch would take its
    /// data-split shape (`b < threads`), which regroups f64 additions —
    /// the same last-ULP caveat that path already carries unfused. Either
    /// way the memo cache keeps every caller consistent (first writer
    /// wins).
    pub fn set_fusion(&self, enabled: bool) {
        self.fuse.store(enabled, Ordering::Relaxed);
    }

    /// Whether level fusion is enabled.
    pub fn fusion(&self) -> bool {
        self.fuse.load(Ordering::Relaxed)
    }

    /// Enable/disable the overlapped submission pipeline (on by default).
    /// When on, a fused plan with two or more submissions runs through
    /// the double-buffered pack/execute queue
    /// ([`run_double_buffered`](crate::coordinator::batcher::run_double_buffered)):
    /// a packer thread gathers submission `r + 1`'s query rows and data
    /// segments while the backend executes submission `r` on the calling
    /// thread. Execution order, dispatch counts and every value are
    /// unchanged — the backend still sees the same submissions in the
    /// same order, and cache commits still happen on the calling thread —
    /// so answers are bit-identical with overlap on or off (pinned in
    /// `tests/fusion.rs`); off is the strictly sequential fallback for
    /// A/Bs and single-threaded environments.
    pub fn set_overlap(&self, enabled: bool) {
        self.overlap.store(enabled, Ordering::Relaxed);
    }

    /// Whether the overlapped submission pipeline is enabled.
    pub fn overlap(&self) -> bool {
        self.overlap.load(Ordering::Relaxed)
    }

    /// Enable/disable cross-round overlap (on by default; requires
    /// [`set_overlap`](Self::set_overlap) on to matter). When on, fused
    /// plans run through a persistent [`OverlapSession`] packer thread
    /// that is reused across *successive* `query_points_multi` rounds —
    /// a whole descent's L rounds (or a walk batch's hundreds) share one
    /// warm pipeline instead of paying a packer spawn + join per round.
    /// Submissions, execution order, dispatch counts, memo commits and
    /// every value are identical on/off (property-pinned in
    /// `tests/fusion.rs`); off is the per-call pipeline for A/Bs.
    pub fn set_cross_round(&self, enabled: bool) {
        self.cross_round.store(enabled, Ordering::Relaxed);
    }

    /// Whether cross-round overlap is enabled.
    pub fn cross_round(&self) -> bool {
        self.cross_round.load(Ordering::Relaxed)
    }

    /// Open a cross-round overlap epoch: warms the session's packer
    /// thread ahead of a multi-round batch so even its first round reuses
    /// the pipeline. The samplers hold one epoch per batch descent
    /// (`NeighborSampler::sample_batch_with_streams`, the probe batches).
    pub fn overlap_epoch(&self) -> OverlapEpoch<'_> {
        self.session.epoch()
    }

    /// `(epochs, rounds, fallbacks)` counters of the persistent overlap
    /// session — how many batch epochs were opened, how many fused rounds
    /// ran on the persistent packer thread, and how many fell back to the
    /// per-call pipeline (contention / spawn failure).
    pub fn overlap_stats(&self) -> (u64, u64, u64) {
        (
            self.session.epochs(),
            self.session.rounds(),
            self.session.fallbacks(),
        )
    }

    /// Total `query_points_multi` rounds issued against this tree (both
    /// fused and unfused; one per call). The samplers' per-batch round
    /// accounting — `EdgeSampler`'s reverse-probe fusion is pinned as a
    /// >= 1.5x drop in this counter per batch (`tests/fusion.rs`).
    pub fn multi_calls(&self) -> u64 {
        self.multi_calls.load(Ordering::Relaxed)
    }

    /// The config's leaf cutoff: ranges of at most this size carry exact
    /// (naive) oracles, which is what lets the samplers finish a descent
    /// categorically once a subtree this small is reached.
    pub fn leaf_cutoff(&self) -> usize {
        self.leaf_cutoff
    }

    /// Memoized KDE answer for dataset point `i` against node `id`'s
    /// subset. Includes `k(x_i, x_i)` if `i` lies inside the node's range —
    /// callers subtract 1.0 in that case (Alg 4.3 / 4.11).
    pub fn query_point(&self, id: usize, i: usize) -> f64 {
        let key = (id as u32, i as u32);
        let stamp = self.stamp(id, i);
        if let Some(v) = self.cache.get(key, stamp) {
            return v;
        }
        let v = self.oracles[id].query(self.ds.point(i));
        self.cache.insert_or_get(key, stamp, v)
    }

    /// Batched [`query_point`](Self::query_point): answers for every index
    /// in `idx` against node `id`, deduping repeats and cache hits so only
    /// the misses hit the backend (in at most `ceil(misses / 64)` fused
    /// submissions for fusable oracles, one `query_batch` otherwise).
    /// Returned values are the memoized ones — later `query_point` calls
    /// observe exactly these answers.
    ///
    /// # Example
    ///
    /// ```
    /// use std::sync::Arc;
    /// use kde_matrix::kde::{KdeConfig, KdeCounters, MultiLevelKde};
    /// use kde_matrix::kernel::{dataset::gaussian_mixture, Kernel};
    /// use kde_matrix::runtime::CpuBackend;
    /// use kde_matrix::util::rng::Rng;
    ///
    /// let mut rng = Rng::new(11);
    /// let ds = Arc::new(gaussian_mixture(24, 3, 2, 1.0, 0.5, &mut rng));
    /// let tree = MultiLevelKde::build(
    ///     ds, Kernel::Laplacian, &KdeConfig::exact(), CpuBackend::new(), KdeCounters::new(),
    /// );
    /// // Batched node answers dedup repeats and memoize: later single-point
    /// // queries observe exactly the same values, bit for bit.
    /// let vals = tree.query_points(tree.root(), &[3, 7, 3]);
    /// assert_eq!(vals[0].to_bits(), vals[2].to_bits());
    /// assert_eq!(vals[1].to_bits(), tree.query_point(tree.root(), 7).to_bits());
    /// ```
    pub fn query_points(&self, id: usize, idx: &[usize]) -> Vec<f64> {
        match self.query_points_multi(&[(id, idx)]).pop() {
            Some(vals) => vals,
            None => unreachable!("one group in, one group out"),
        }
    }

    /// Level-fused [`query_points`](Self::query_points) over several
    /// `(node, indices)` groups at once — the entry point the level-order
    /// walkers (`NeighborSampler::sample_batch` / `neighbor_prob_batch`)
    /// use. Per group, repeats and cache hits are deduped exactly like
    /// `query_points`; the remaining cache misses of every group whose
    /// oracle exposes a [`FusedView`] are coalesced into shared padded
    /// submissions (B = 64 rows, each node's data packed as one segment,
    /// per-row ranges) and executed through one
    /// `KernelBackend::sums_ranged` dispatch each. Groups without a fused
    /// view — and every group while [`set_fusion`](Self::set_fusion) is
    /// off — go through their oracle's `query_batch` in input order.
    ///
    /// Answers equal the unfused path's bit for bit (same per-row
    /// accumulation order, same scale application) on every backend whose
    /// unfused dispatch also walks rows in order — see
    /// [`set_fusion`](Self::set_fusion) for the one multi-threaded-tiled
    /// caveat — and are memoized identically either way, so consistency
    /// across the sampling descent and later probability recomputation
    /// survives fusion.
    pub fn query_points_multi(&self, groups: &[(usize, &[usize])]) -> Vec<Vec<f64>> {
        match self.try_query_points_multi(groups) {
            Ok(v) => v,
            Err(e) => panic!("multi-level KDE dispatch failed: {e}"),
        }
    }

    /// Fallible [`query_points_multi`](Self::query_points_multi): the same
    /// dedup + fused-plan evaluation, but backend dispatch failures
    /// (`KernelBackend::try_sums_ranged`), panicking oracles, and packer
    /// panics in the overlapped queue surface as typed
    /// [`BackendError`]s instead of unwinding. On error, every answer
    /// committed before the failing submission stays memoized (first
    /// writer wins as usual), so a retry — or a failover rerun through a
    /// [`ResilientBackend`](crate::runtime::ResilientBackend)-wrapped
    /// tree — only pays for the uncommitted remainder.
    pub fn try_query_points_multi(
        &self,
        groups: &[(usize, &[usize])],
    ) -> Result<Vec<Vec<f64>>, BackendError> {
        // One round per call — the samplers' per-batch round accounting.
        self.multi_calls.fetch_add(1, Ordering::Relaxed);
        // Pass 1: per-group dedup + cache probe. One shard lookup per
        // DISTINCT index; answers resolve through local maps so the final
        // readback is lock-free (and immune to a racing clear_cache
        // between fill and readback).
        let mut resolved: Vec<FxHashMap<u32, Option<f64>>> = Vec::with_capacity(groups.len());
        let mut missing: Vec<Vec<usize>> = Vec::with_capacity(groups.len());
        for &(id, idx) in groups {
            let mut res: FxHashMap<u32, Option<f64>> = FxHashMap::default();
            let mut miss: Vec<usize> = Vec::new();
            for &i in idx {
                let k = i as u32;
                res.entry(k).or_insert_with(|| {
                    let cached = self.cache.get((id as u32, k), self.stamp(id, i));
                    if cached.is_none() {
                        miss.push(i);
                    }
                    cached
                });
            }
            resolved.push(res);
            missing.push(miss);
        }
        // Pass 2: resolve misses. Groups with a FusedView are deferred to
        // the shared fused plan; the rest run their oracle's native batch
        // in input order (HBE-style stateful oracles keep a reproducible
        // first-query order).
        let d = self.ds.d;
        let fuse = self.fuse.load(Ordering::Relaxed);
        let mut fused: Vec<(usize, FusedView<'_>)> = Vec::new();
        for (gi, &(id, _)) in groups.iter().enumerate() {
            if missing[gi].is_empty() {
                continue;
            }
            let view = if fuse { self.oracles[id].fused_view() } else { None };
            match view {
                Some(v) => fused.push((gi, v)),
                None => {
                    let miss = &missing[gi];
                    let mut ys = Vec::with_capacity(miss.len() * d);
                    for &i in miss {
                        ys.extend_from_slice(self.ds.point(i));
                    }
                    // The oracle records its own query count. A panicking
                    // oracle (chaos tests, poisoned estimator state)
                    // becomes a typed error instead of unwinding through
                    // the sampling descent.
                    let vals = catch_panic(|| self.oracles[id].query_batch(&ys))?;
                    self.commit(id, miss, &vals, &mut resolved[gi]);
                }
            }
        }
        if !fused.is_empty() {
            let jobs: Vec<FuseJob> = fused
                .iter()
                .map(|&(gi, v)| FuseJob { rows: missing[gi].len(), seg_rows: v.data.len() / d })
                .collect();
            // Fused misses bypass the oracles, so record their query count
            // here (exactly what the oracles' query_batch would record).
            self.counters.record_queries(jobs.iter().map(|j| j.rows as u64).sum());
            let plan = plan_level_fusion_adaptive(&jobs, AOT_B, AOT_M);

            /// A fused submission's shared data buffer: borrowed straight
            /// from the oracle's view when the submission carries one
            /// segment (e.g. each chunk of the root degree scan), owned
            /// when several segments were concatenated.
            enum PackedData<'v> {
                Borrowed(&'v [f32]),
                Owned(Vec<f32>),
            }
            /// One packed submission, ready for `sums_ranged`.
            struct PackedSub<'v> {
                rows: Vec<(usize, usize)>,
                queries: Vec<f32>,
                ranges: Vec<(usize, usize)>,
                data: PackedData<'v>,
            }
            let fused_ref = &fused;
            let missing_ref = &missing;
            let resolved_ref = &mut resolved;
            let overlap = self.overlap.load(Ordering::Relaxed);
            let cross_round = overlap && self.cross_round.load(Ordering::Relaxed);
            // Pack stage: gather one submission's query rows and data
            // segments (each segment once, remembering its row range).
            // Runs on the packer thread when overlap is on — the per-call
            // scoped packer, or the persistent session packer when
            // cross-round reuse is on.
            let pack = |sub: FuseSubmission| {
                let mut seg_range: FxHashMap<usize, (usize, usize)> = FxHashMap::default();
                let data = if sub.segments.len() == 1 {
                    let fj = sub.segments[0];
                    let (_, view) = fused_ref[fj];
                    seg_range.insert(fj, (0, view.data.len() / d));
                    PackedData::Borrowed(view.data)
                } else {
                    let mut packed: Vec<f32> = Vec::new();
                    for &fj in &sub.segments {
                        let (_, view) = fused_ref[fj];
                        let lo = packed.len() / d;
                        packed.extend_from_slice(view.data);
                        seg_range.insert(fj, (lo, packed.len() / d));
                    }
                    PackedData::Owned(packed)
                };
                let mut queries: Vec<f32> = Vec::with_capacity(sub.rows.len() * d);
                let mut ranges: Vec<(usize, usize)> = Vec::with_capacity(sub.rows.len());
                for &(fj, r) in &sub.rows {
                    let (gi, _) = fused_ref[fj];
                    queries.extend_from_slice(self.ds.point(missing_ref[gi][r]));
                    ranges.push(seg_range[&fj]);
                }
                PackedSub { rows: sub.rows, queries, ranges, data }
            };
            // Execute stage: one backend dispatch + cache commit per
            // submission, always on the calling thread and in plan
            // order (so dispatch counting, memoization and answers
            // are identical with or without overlap, per-call or
            // cross-round).
            let execute = |p: PackedSub<'_>| {
                let data: &[f32] = match &p.data {
                    PackedData::Borrowed(b) => *b,
                    PackedData::Owned(v) => v.as_slice(),
                };
                let raw = self
                    .backend
                    .try_sums_ranged(self.kernel, &p.queries, data, d, &p.ranges)?;
                for (&(fj, r), &v) in p.rows.iter().zip(&raw) {
                    let (gi, view) = fused_ref[fj];
                    let id = groups[gi].0;
                    let i = missing_ref[gi][r];
                    // First writer wins under concurrent misses;
                    // report what actually ended up cached
                    // (consistency).
                    let stored = self.cache.insert_or_get(
                        (id as u32, i as u32),
                        self.stamp(id, i),
                        v * view.scale,
                    );
                    resolved_ref[gi].insert(i as u32, Some(stored));
                }
                Ok(())
            };
            if cross_round {
                self.session.try_run(plan, pack, execute)?;
            } else {
                try_run_double_buffered(plan, overlap, pack, execute)?;
            }
        }
        // Pass 3: readback in input order.
        Ok(groups
            .iter()
            .enumerate()
            .map(|(gi, &(_, idx))| {
                idx.iter()
                    .map(|&i| match resolved[gi][&(i as u32)] {
                        Some(v) => v,
                        None => unreachable!("every index resolved above"),
                    })
                    .collect()
            })
            .collect())
    }

    /// Memoize `vals` for `miss` against node `id` and mirror the stored
    /// (first-writer) values into the local resolution map.
    fn commit(
        &self,
        id: usize,
        miss: &[usize],
        vals: &[f64],
        resolved: &mut FxHashMap<u32, Option<f64>>,
    ) {
        for (&i, &v) in miss.iter().zip(vals) {
            let stored = self.cache.insert_or_get((id as u32, i as u32), self.stamp(id, i), v);
            resolved.insert(i as u32, Some(stored));
        }
    }

    /// Un-memoized query for an arbitrary vector (serving path).
    pub fn query_vec(&self, id: usize, y: &[f32]) -> f64 {
        self.oracles[id].query(y)
    }

    /// Clear the per-point memo table (experiment hygiene between runs).
    pub fn clear_cache(&self) {
        self.cache.clear();
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::kernel::dataset::gaussian_mixture;
    use crate::runtime::backend::CpuBackend;

    fn build_exact(n: usize, seed: u64) -> (Arc<Dataset>, MultiLevelKde) {
        let mut rng = Rng::new(seed);
        let ds = Arc::new(gaussian_mixture(n, 4, 2, 1.0, 0.5, &mut rng));
        let tree = MultiLevelKde::build(
            ds.clone(),
            Kernel::Laplacian,
            &KdeConfig::exact(),
            CpuBackend::new(),
            KdeCounters::new(),
        );
        (ds, tree)
    }

    #[test]
    fn tree_covers_all_ranges() {
        let (_, tree) = build_exact(37, 61); // non-power-of-two
        // Every internal node's children partition it.
        for id in 0..tree.num_nodes() {
            let n = tree.node(id);
            if let (Some(l), Some(r)) = (n.left, n.right) {
                let (nl, nr) = (tree.node(l), tree.node(r));
                assert_eq!(nl.lo, n.lo);
                assert_eq!(nl.hi, nr.lo);
                assert_eq!(nr.hi, n.hi);
            } else {
                assert_eq!(n.hi - n.lo, 1, "leaf must be a single point");
            }
        }
        let root = tree.node(tree.root());
        assert_eq!((root.lo, root.hi), (0, 37));
    }

    #[test]
    fn node_count_is_2n_minus_1() {
        let (_, tree) = build_exact(32, 63);
        assert_eq!(tree.num_nodes(), 2 * 32 - 1);
    }

    #[test]
    fn exact_tree_children_sum_to_parent() {
        let (ds, tree) = build_exact(24, 65);
        for id in 0..tree.num_nodes() {
            let n = tree.node(id);
            if let (Some(l), Some(r)) = (n.left, n.right) {
                for q in [0usize, 7, 23] {
                    let parent = tree.query_point(id, q);
                    let sum = tree.query_point(l, q) + tree.query_point(r, q);
                    assert!(
                        (parent - sum).abs() < 1e-6 * (1.0 + parent),
                        "node {id} point {q}: {parent} vs {sum}"
                    );
                    let _ = &ds;
                }
            }
        }
    }

    #[test]
    fn cache_memoizes_and_counts_misses_only() {
        let (_, tree) = build_exact(16, 67);
        let before = tree.counters.queries();
        let a = tree.query_point(0, 3);
        let mid = tree.counters.queries();
        let b = tree.query_point(0, 3);
        let after = tree.counters.queries();
        assert_eq!(a, b);
        assert_eq!(mid, before + 1);
        assert_eq!(after, mid, "cache hit must not count as a query");
    }

    #[test]
    fn query_point_matches_exact_range_sum() {
        let (ds, tree) = build_exact(20, 69);
        for id in [0usize, 1, 2] {
            let n = tree.node(id);
            let q = 5;
            let got = tree.query_point(id, q);
            let want: f64 = (n.lo..n.hi)
                .map(|j| Kernel::Laplacian.eval(ds.point(j), ds.point(q)) as f64)
                .sum();
            assert!((got - want).abs() < 1e-6 * (1.0 + want));
        }
    }

    #[test]
    fn query_points_dedups_and_matches_query_point() {
        let (_, tree) = build_exact(40, 71);
        // Warm one entry through the single-point path first.
        let warm = tree.query_point(1, 5);
        let before = tree.counters.queries();
        let idx = [5usize, 9, 9, 17, 5, 33];
        let got = tree.query_points(1, &idx);
        // 3 distinct cold points -> exactly 3 new queries, 1 backend batch.
        assert_eq!(tree.counters.queries(), before + 3);
        assert_eq!(got[0].to_bits(), warm.to_bits());
        assert_eq!(got[1].to_bits(), got[2].to_bits());
        assert_eq!(got[0].to_bits(), got[4].to_bits());
        for (pos, &i) in idx.iter().enumerate() {
            assert_eq!(got[pos].to_bits(), tree.query_point(1, i).to_bits());
        }
    }

    #[test]
    fn fused_and_unfused_node_answers_are_bit_identical() {
        // Twin trees (identical build), one with fusion disabled: every
        // node's batched answers must agree bit for bit.
        let (_, fused) = build_exact(40, 75);
        let (_, plain) = build_exact(40, 75);
        assert!(fused.fusion(), "fusion defaults on");
        plain.set_fusion(false);
        let idx: Vec<usize> = (0..40).chain([3, 9, 9]).collect();
        for id in 0..fused.num_nodes() {
            let a = fused.query_points(id, &idx);
            let b = plain.query_points(id, &idx);
            for (pos, (x, y)) in a.iter().zip(&b).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "node {id} pos {pos}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn query_points_multi_fuses_a_level_into_one_submission() {
        // Two sibling nodes' groups, both small: the planner packs them
        // into ONE fused backend dispatch; answers match per-node queries.
        let mut rng = Rng::new(77);
        let ds = Arc::new(gaussian_mixture(64, 4, 2, 1.0, 0.5, &mut rng));
        let be = CpuBackend::new();
        let tree = MultiLevelKde::build(
            ds,
            Kernel::Laplacian,
            &KdeConfig::exact(),
            be.clone(),
            KdeCounters::new(),
        );
        let (l, r) = {
            let root = tree.node(tree.root());
            (root.left.unwrap(), root.right.unwrap())
        };
        let idx: Vec<usize> = (0..20).collect();
        let before = be.calls();
        let answers = tree.query_points_multi(&[(l, &idx), (r, &idx)]);
        assert_eq!(be.calls() - before, 1, "two sibling groups fuse into one dispatch");
        // Parity against the single-point memoized path.
        for (gi, id) in [l, r].into_iter().enumerate() {
            for (pos, &i) in idx.iter().enumerate() {
                assert_eq!(answers[gi][pos].to_bits(), tree.query_point(id, i).to_bits());
            }
        }
    }

    #[test]
    fn single_group_fusion_does_not_regress_dispatch_count() {
        // A <= 64-miss single-node group costs exactly one backend call
        // (what the unfused query_batch path paid), and an all-warm group
        // or an empty index list costs zero.
        let mut rng = Rng::new(79);
        let ds = Arc::new(gaussian_mixture(96, 4, 2, 1.0, 0.5, &mut rng));
        let be = CpuBackend::new();
        let tree = MultiLevelKde::build(
            ds,
            Kernel::Laplacian,
            &KdeConfig::exact(),
            be.clone(),
            KdeCounters::new(),
        );
        let idx: Vec<usize> = (0..50).collect();
        let before = be.calls();
        tree.query_points(1, &idx);
        assert_eq!(be.calls() - before, 1, "one fused submission for <= 64 misses");
        let before = be.calls();
        let warm = tree.query_points(1, &idx);
        assert_eq!(be.calls() - before, 0, "warm cache dispatches nothing");
        assert_eq!(warm.len(), idx.len());
        let before = be.calls();
        assert!(tree.query_points(1, &[]).is_empty());
        assert!(tree.query_points_multi(&[]).is_empty());
        let empty: [usize; 0] = [];
        let multi = tree.query_points_multi(&[(1, &empty[..]), (2, &empty[..])]);
        assert_eq!(multi, vec![Vec::<f64>::new(), Vec::<f64>::new()]);
        assert_eq!(be.calls() - before, 0, "empty miss sets dispatch nothing");
    }

    #[test]
    fn sampling_tree_fusion_is_bit_identical_too() {
        // SamplingKde nodes fuse through their gathered subsample buffers
        // with the |S|/|R| scale; fused and unfused must still agree
        // bit for bit (same scale multiplication on both paths).
        let cfg = KdeConfig {
            kind: crate::kde::EstimatorKind::Sampling { eps: 0.4, tau: 0.15 },
            leaf_cutoff: 8,
            seed: 0x91,
        };
        let build = |seed| {
            let mut rng = Rng::new(seed);
            let ds = Arc::new(gaussian_mixture(72, 4, 2, 1.0, 0.5, &mut rng));
            MultiLevelKde::build(
                ds,
                Kernel::Gaussian,
                &cfg,
                CpuBackend::new(),
                KdeCounters::new(),
            )
        };
        let fused = build(81);
        let plain = build(81);
        plain.set_fusion(false);
        let idx: Vec<usize> = (0..72).step_by(3).collect();
        for id in 0..fused.num_nodes() {
            let a = fused.query_points(id, &idx);
            let b = plain.query_points(id, &idx);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.to_bits(), y.to_bits(), "node {id}");
            }
        }
    }

    #[test]
    fn overlap_pipeline_is_bit_identical_and_dispatch_neutral() {
        // Twin trees, one with the overlapped submission queue disabled:
        // identical answers (bit for bit) and identical dispatch counts —
        // overlap changes wall-clock only, never the evaluation.
        let mk = |overlap: bool| {
            let mut rng = Rng::new(85);
            let ds = Arc::new(gaussian_mixture(96, 4, 2, 1.0, 0.5, &mut rng));
            let be = CpuBackend::new();
            let tree = MultiLevelKde::build(
                ds,
                Kernel::Laplacian,
                &KdeConfig::exact(),
                be.clone(),
                KdeCounters::new(),
            );
            tree.set_overlap(overlap);
            (tree, be)
        };
        let (ovl, be_o) = mk(true);
        let (seq, be_s) = mk(false);
        assert!(ovl.overlap(), "overlap defaults on");
        assert!(!seq.overlap());
        let idx: Vec<usize> = (0..96).collect();
        // A multi-group call whose fused plan spans several submissions
        // (96 misses per node > B = 64 rows).
        let groups = [(1usize, &idx[..]), (2usize, &idx[..])];
        let a = ovl.query_points_multi(&groups);
        let b = seq.query_points_multi(&groups);
        assert_eq!(be_o.calls(), be_s.calls(), "overlap must not change dispatches");
        for (gi, (ga, gb)) in a.iter().zip(&b).enumerate() {
            for (pos, (x, y)) in ga.iter().zip(gb).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "group {gi} pos {pos}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn dynamic_build_matches_static_build_bit_for_bit() {
        // BufferKde owns copies of the same bytes NaiveKde borrows, so on
        // an all-live dataset the dynamic tree is the static tree.
        let mut rng = Rng::new(87);
        let ds = Arc::new(gaussian_mixture(48, 4, 2, 1.0, 0.5, &mut rng));
        let stat = MultiLevelKde::build(
            ds.clone(),
            Kernel::Laplacian,
            &KdeConfig::exact(),
            CpuBackend::new(),
            KdeCounters::new(),
        );
        let dynm = MultiLevelKde::build_dynamic(
            ds,
            Kernel::Laplacian,
            &KdeConfig::exact(),
            CpuBackend::new(),
            KdeCounters::new(),
        );
        assert!(dynm.is_dynamic() && !stat.is_dynamic());
        assert_eq!(stat.num_nodes(), dynm.num_nodes());
        let idx: Vec<usize> = (0..48).collect();
        for id in [0usize, 1, 2, 5, 40] {
            let a = stat.query_points(id, &idx);
            let b = dynm.query_points(id, &idx);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.to_bits(), y.to_bits(), "node {id}");
            }
        }
    }

    #[test]
    fn edits_invalidate_the_path_and_only_the_path() {
        let mut rng = Rng::new(89);
        let ds = Arc::new(gaussian_mixture(64, 4, 2, 1.0, 0.5, &mut rng));
        let mut tree = MultiLevelKde::build_dynamic(
            ds.clone(),
            Kernel::Laplacian,
            &KdeConfig::exact(),
            CpuBackend::new(),
            KdeCounters::new(),
        );
        let root = tree.root();
        let (l, r) = tree.node(root).children();
        // Slot 60 lives under the right child; query point 3 lives on the
        // left. Warm both subtree answers for point 3.
        let before_l = tree.query_point(l, 3);
        let before_r = tree.query_point(r, 3);
        let before_root = tree.query_point(root, 3);
        let warm = tree.counters.queries();
        let victim = ds.point(60).to_vec();
        assert!(tree.delete(60));
        // Left subtree untouched: still a cache hit (no new KDE query).
        assert_eq!(tree.query_point(l, 3).to_bits(), before_l.to_bits());
        assert_eq!(tree.counters.queries(), warm, "off-path entry must stay cached");
        // Right subtree and root were on the path: recomputed, and the
        // deleted point's mass is gone (exact oracles).
        let after_r = tree.query_point(r, 3);
        let after_root = tree.query_point(root, 3);
        assert!(tree.counters.queries() > warm);
        let k = Kernel::Laplacian.eval(&victim, ds.point(3)) as f64;
        assert!((before_r - after_r - k).abs() < 1e-9 * (1.0 + k), "{before_r} -> {after_r}");
        assert!((before_root - after_root - k).abs() < 1e-9 * (1.0 + k));
        // Re-inserting different coordinates into the freed slot shifts
        // the answers again and reuses slot 60.
        assert_eq!(tree.insert(&[0.5, 0.5, 0.5, 0.5]), Some(60));
        assert_eq!(tree.insert(&[0.5; 4]), None, "no second free slot");
        let (edits, rebuilds) = tree.edit_stats();
        assert_eq!(edits, 2);
        // Path length for n = 64 is log2(64) + 1 = 7 nodes.
        assert_eq!(rebuilds, 2 * 7, "each edit rebuilds exactly the ancestor path");
    }

    #[test]
    fn dynamic_edits_rebuild_o_log_n_oracles() {
        let mut rng = Rng::new(91);
        let n = 200; // non-power-of-two
        let ds = Arc::new(gaussian_mixture(n, 3, 2, 1.0, 0.5, &mut rng));
        let cfg = KdeConfig {
            kind: EstimatorKind::Sampling { eps: 0.5, tau: 0.2 },
            leaf_cutoff: 8,
            seed: 0xD1,
        };
        let mut tree = MultiLevelKde::build_dynamic(
            ds,
            Kernel::Gaussian,
            &cfg,
            CpuBackend::new(),
            KdeCounters::new(),
        );
        for s in 0..40usize {
            assert!(tree.delete((s * 37) % n));
        }
        let (edits, rebuilds) = tree.edit_stats();
        assert_eq!(edits, 40);
        // Unbalanced splits round up, so allow ceil(log2 n) + 1 per edit.
        let bound = edits * ((n as f64).log2().ceil() as u64 + 1);
        assert!(rebuilds <= bound, "rebuilds {rebuilds} > O(log n) bound {bound}");
    }

    #[test]
    fn tree_is_safely_shareable_across_threads() {
        // The sharded cache replaced the old `unsafe impl Sync`; verify the
        // auto-derived bound holds and that concurrent mixed hit/miss
        // traffic stays consistent with the exact answer.
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<MultiLevelKde>();

        let (ds, tree) = build_exact(64, 73);
        let tree = Arc::new(tree);
        std::thread::scope(|s| {
            for t in 0..4usize {
                let tr = tree.clone();
                s.spawn(move || {
                    for k in 0..64usize {
                        let i = (k * 7 + t) % 64;
                        let _ = tr.query_point(0, i);
                        let _ = tr.query_points(2, &[i, (i + 1) % 64]);
                    }
                });
            }
        });
        for i in (0..64).step_by(11) {
            let want: f64 = (0..64)
                .map(|j| Kernel::Laplacian.eval(ds.point(j), ds.point(i)) as f64)
                .sum();
            let got = tree.query_point(0, i);
            assert!((got - want).abs() < 1e-6 * (1.0 + want));
        }
    }
}
