//! Multi-level KDE (Algorithm 4.1): a binary tree over contiguous index
//! ranges of the dataset, each node holding an independent KDE oracle over
//! its range. The tree is the engine behind Algorithm 4.11's weighted
//! neighbor sampling descent and everything built on it.
//!
//! Per the technical overview (§2), KDE answers must be **consistent**
//! between the sampling descent and the later probability computation
//! (`neighbor_prob`) — so per-(node, query-point) answers are memoized.
//! Cache misses are what the query counter counts; cache hits are free,
//! matching the paper's accounting where a degree array is "computed once".
//!
//! The memo table is sharded across [`CACHE_SHARDS`] mutexes, which makes
//! the structure safely `Sync` (no `unsafe impl`) and keeps contention low
//! when the coordinator or the batched pipeline queries it from several
//! threads. Concurrent misses of the same key may compute twice, but the
//! first insert wins and every caller observes that single value — the
//! consistency property Algorithm 5.1 needs survives races.
//!
//! [`MultiLevelKde::query_points`] is the batched entry point: it dedups
//! its index list against the cache and issues one `query_batch` to the
//! node's oracle for all misses — one backend dispatch per (node, batch)
//! instead of one per point, which is what makes a `t`-descent sampling
//! round cost O(log n) backend calls (see `sampling::neighbor`).

use std::sync::{Arc, Mutex};

use crate::util::fxhash::FxHashMap;

use crate::kde::hbe::HbeKde;
use crate::kde::{EstimatorKind, Kde, KdeConfig, KdeCounters, NaiveKde, SamplingKde};
use crate::kernel::{Dataset, Kernel};
use crate::runtime::backend::KernelBackend;
use crate::util::rng::Rng;

/// Number of independent mutex-protected cache shards.
const CACHE_SHARDS: usize = 16;

#[derive(Clone, Copy, Debug)]
pub struct Node {
    pub lo: usize,
    pub hi: usize,
    pub left: Option<usize>,
    pub right: Option<usize>,
}

/// Sharded (node, point) -> answer memo table; safely `Sync`.
struct ShardedCache {
    shards: Vec<Mutex<FxHashMap<(u32, u32), f64>>>,
}

impl ShardedCache {
    fn new() -> Self {
        ShardedCache {
            shards: (0..CACHE_SHARDS)
                .map(|_| Mutex::new(FxHashMap::default()))
                .collect(),
        }
    }

    #[inline]
    fn shard(&self, key: (u32, u32)) -> &Mutex<FxHashMap<(u32, u32), f64>> {
        let h = key.0 as usize ^ (key.1 as usize).wrapping_mul(0x9E37_79B9);
        &self.shards[h % CACHE_SHARDS]
    }

    #[inline]
    fn get(&self, key: (u32, u32)) -> Option<f64> {
        self.shard(key).lock().unwrap().get(&key).copied()
    }

    /// Insert unless present; returns the value that ended up cached (the
    /// first writer's), which the caller must report for consistency.
    #[inline]
    fn insert_or_get(&self, key: (u32, u32), v: f64) -> f64 {
        *self.shard(key).lock().unwrap().entry(key).or_insert(v)
    }

    fn clear(&self) {
        for s in &self.shards {
            s.lock().unwrap().clear();
        }
    }
}

pub struct MultiLevelKde {
    pub ds: Arc<Dataset>,
    pub kernel: Kernel,
    nodes: Vec<Node>,
    oracles: Vec<Box<dyn Kde>>,
    cache: ShardedCache,
    leaf_cutoff: usize,
    pub counters: Arc<KdeCounters>,
}

impl MultiLevelKde {
    /// Build the tree with the configured estimator at every node
    /// (Lemma 4.2: construction cost is one level's cost times O(log n)).
    pub fn build(
        ds: Arc<Dataset>,
        kernel: Kernel,
        cfg: &KdeConfig,
        backend: Arc<dyn KernelBackend>,
        counters: Arc<KdeCounters>,
    ) -> Self {
        let mut rng = Rng::new(cfg.seed);
        let mut nodes = Vec::new();
        let mut oracles: Vec<Box<dyn Kde>> = Vec::new();
        Self::build_rec(
            &ds, kernel, cfg, &backend, &counters, &mut rng, 0, ds.n, &mut nodes, &mut oracles,
        );
        MultiLevelKde {
            ds,
            kernel,
            nodes,
            oracles,
            cache: ShardedCache::new(),
            leaf_cutoff: cfg.leaf_cutoff,
            counters,
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn build_rec(
        ds: &Arc<Dataset>,
        kernel: Kernel,
        cfg: &KdeConfig,
        backend: &Arc<dyn KernelBackend>,
        counters: &Arc<KdeCounters>,
        rng: &mut Rng,
        lo: usize,
        hi: usize,
        nodes: &mut Vec<Node>,
        oracles: &mut Vec<Box<dyn Kde>>,
    ) -> usize {
        let id = nodes.len();
        nodes.push(Node { lo, hi, left: None, right: None });
        let len = hi - lo;
        let oracle: Box<dyn Kde> = if len <= cfg.leaf_cutoff {
            Box::new(NaiveKde::new(
                ds.clone(),
                kernel,
                lo,
                hi,
                backend.clone(),
                counters.clone(),
            ))
        } else {
            match cfg.kind {
                EstimatorKind::Naive => Box::new(NaiveKde::new(
                    ds.clone(),
                    kernel,
                    lo,
                    hi,
                    backend.clone(),
                    counters.clone(),
                )),
                EstimatorKind::Sampling { .. } => Box::new(SamplingKde::new(
                    ds.clone(),
                    kernel,
                    lo,
                    hi,
                    cfg,
                    backend.clone(),
                    counters.clone(),
                    rng,
                )),
                EstimatorKind::Hbe { tables, width } => Box::new(HbeKde::new(
                    ds.clone(),
                    kernel,
                    lo,
                    hi,
                    tables,
                    width,
                    counters.clone(),
                    rng,
                )),
                EstimatorKind::PartitionTree { eps } => {
                    Box::new(crate::kde::ptree::PartitionTreeKde::new(
                        ds.clone(),
                        kernel,
                        lo,
                        hi,
                        eps,
                        counters.clone(),
                    ))
                }
            }
        };
        oracles.push(oracle);
        if len > 1 {
            let mid = lo + len / 2;
            let l = Self::build_rec(
                ds, kernel, cfg, backend, counters, rng, lo, mid, nodes, oracles,
            );
            let r = Self::build_rec(
                ds, kernel, cfg, backend, counters, rng, mid, hi, nodes, oracles,
            );
            nodes[id].left = Some(l);
            nodes[id].right = Some(r);
        }
        id
    }

    pub fn root(&self) -> usize {
        0
    }

    pub fn node(&self, id: usize) -> Node {
        self.nodes[id]
    }

    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// The config's leaf cutoff: ranges of at most this size carry exact
    /// (naive) oracles, which is what lets the samplers finish a descent
    /// categorically once a subtree this small is reached.
    pub fn leaf_cutoff(&self) -> usize {
        self.leaf_cutoff
    }

    /// Memoized KDE answer for dataset point `i` against node `id`'s
    /// subset. Includes `k(x_i, x_i)` if `i` lies inside the node's range —
    /// callers subtract 1.0 in that case (Alg 4.3 / 4.11).
    pub fn query_point(&self, id: usize, i: usize) -> f64 {
        let key = (id as u32, i as u32);
        if let Some(v) = self.cache.get(key) {
            return v;
        }
        let v = self.oracles[id].query(self.ds.point(i));
        self.cache.insert_or_get(key, v)
    }

    /// Batched [`query_point`](Self::query_point): answers for every index
    /// in `idx` against node `id`, deduping repeats and cache hits so the
    /// misses cost ONE oracle `query_batch` (one backend dispatch for the
    /// backend-based estimators). Returned values are the memoized ones —
    /// later `query_point` calls observe exactly these answers.
    pub fn query_points(&self, id: usize, idx: &[usize]) -> Vec<f64> {
        // One shard lookup per DISTINCT index; answers resolve through a
        // local map so the final pass is lock-free (and immune to a racing
        // clear_cache between fill and readback).
        let mut resolved: FxHashMap<u32, Option<f64>> = FxHashMap::default();
        let mut missing: Vec<usize> = Vec::new();
        for &i in idx {
            let k = i as u32;
            resolved.entry(k).or_insert_with(|| {
                let cached = self.cache.get((id as u32, k));
                if cached.is_none() {
                    missing.push(i);
                }
                cached
            });
        }
        if !missing.is_empty() {
            let d = self.ds.d;
            let mut ys = Vec::with_capacity(missing.len() * d);
            for &i in &missing {
                ys.extend_from_slice(self.ds.point(i));
            }
            let vals = self.oracles[id].query_batch(&ys);
            for (&i, &v) in missing.iter().zip(&vals) {
                // First writer wins under concurrent misses; report what
                // actually ended up cached so callers stay consistent.
                let stored = self.cache.insert_or_get((id as u32, i as u32), v);
                resolved.insert(i as u32, Some(stored));
            }
        }
        idx.iter()
            .map(|&i| resolved[&(i as u32)].expect("every index resolved above"))
            .collect()
    }

    /// Un-memoized query for an arbitrary vector (serving path).
    pub fn query_vec(&self, id: usize, y: &[f32]) -> f64 {
        self.oracles[id].query(y)
    }

    /// Clear the per-point memo table (experiment hygiene between runs).
    pub fn clear_cache(&self) {
        self.cache.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::dataset::gaussian_mixture;
    use crate::runtime::backend::CpuBackend;

    fn build_exact(n: usize, seed: u64) -> (Arc<Dataset>, MultiLevelKde) {
        let mut rng = Rng::new(seed);
        let ds = Arc::new(gaussian_mixture(n, 4, 2, 1.0, 0.5, &mut rng));
        let tree = MultiLevelKde::build(
            ds.clone(),
            Kernel::Laplacian,
            &KdeConfig::exact(),
            CpuBackend::new(),
            KdeCounters::new(),
        );
        (ds, tree)
    }

    #[test]
    fn tree_covers_all_ranges() {
        let (_, tree) = build_exact(37, 61); // non-power-of-two
        // Every internal node's children partition it.
        for id in 0..tree.num_nodes() {
            let n = tree.node(id);
            if let (Some(l), Some(r)) = (n.left, n.right) {
                let (nl, nr) = (tree.node(l), tree.node(r));
                assert_eq!(nl.lo, n.lo);
                assert_eq!(nl.hi, nr.lo);
                assert_eq!(nr.hi, n.hi);
            } else {
                assert_eq!(n.hi - n.lo, 1, "leaf must be a single point");
            }
        }
        let root = tree.node(tree.root());
        assert_eq!((root.lo, root.hi), (0, 37));
    }

    #[test]
    fn node_count_is_2n_minus_1() {
        let (_, tree) = build_exact(32, 63);
        assert_eq!(tree.num_nodes(), 2 * 32 - 1);
    }

    #[test]
    fn exact_tree_children_sum_to_parent() {
        let (ds, tree) = build_exact(24, 65);
        for id in 0..tree.num_nodes() {
            let n = tree.node(id);
            if let (Some(l), Some(r)) = (n.left, n.right) {
                for q in [0usize, 7, 23] {
                    let parent = tree.query_point(id, q);
                    let sum = tree.query_point(l, q) + tree.query_point(r, q);
                    assert!(
                        (parent - sum).abs() < 1e-6 * (1.0 + parent),
                        "node {id} point {q}: {parent} vs {sum}"
                    );
                    let _ = &ds;
                }
            }
        }
    }

    #[test]
    fn cache_memoizes_and_counts_misses_only() {
        let (_, tree) = build_exact(16, 67);
        let before = tree.counters.queries();
        let a = tree.query_point(0, 3);
        let mid = tree.counters.queries();
        let b = tree.query_point(0, 3);
        let after = tree.counters.queries();
        assert_eq!(a, b);
        assert_eq!(mid, before + 1);
        assert_eq!(after, mid, "cache hit must not count as a query");
    }

    #[test]
    fn query_point_matches_exact_range_sum() {
        let (ds, tree) = build_exact(20, 69);
        for id in [0usize, 1, 2] {
            let n = tree.node(id);
            let q = 5;
            let got = tree.query_point(id, q);
            let want: f64 = (n.lo..n.hi)
                .map(|j| Kernel::Laplacian.eval(ds.point(j), ds.point(q)) as f64)
                .sum();
            assert!((got - want).abs() < 1e-6 * (1.0 + want));
        }
    }

    #[test]
    fn query_points_dedups_and_matches_query_point() {
        let (_, tree) = build_exact(40, 71);
        // Warm one entry through the single-point path first.
        let warm = tree.query_point(1, 5);
        let before = tree.counters.queries();
        let idx = [5usize, 9, 9, 17, 5, 33];
        let got = tree.query_points(1, &idx);
        // 3 distinct cold points -> exactly 3 new queries, 1 backend batch.
        assert_eq!(tree.counters.queries(), before + 3);
        assert_eq!(got[0].to_bits(), warm.to_bits());
        assert_eq!(got[1].to_bits(), got[2].to_bits());
        assert_eq!(got[0].to_bits(), got[4].to_bits());
        for (pos, &i) in idx.iter().enumerate() {
            assert_eq!(got[pos].to_bits(), tree.query_point(1, i).to_bits());
        }
    }

    #[test]
    fn tree_is_safely_shareable_across_threads() {
        // The sharded cache replaced the old `unsafe impl Sync`; verify the
        // auto-derived bound holds and that concurrent mixed hit/miss
        // traffic stays consistent with the exact answer.
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<MultiLevelKde>();

        let (ds, tree) = build_exact(64, 73);
        let tree = Arc::new(tree);
        std::thread::scope(|s| {
            for t in 0..4usize {
                let tr = tree.clone();
                s.spawn(move || {
                    for k in 0..64usize {
                        let i = (k * 7 + t) % 64;
                        let _ = tr.query_point(0, i);
                        let _ = tr.query_points(2, &[i, (i + 1) % 64]);
                    }
                });
            }
        });
        for i in (0..64).step_by(11) {
            let want: f64 = (0..64)
                .map(|j| Kernel::Laplacian.eval(ds.point(j), ds.point(i)) as f64)
                .sum();
            let got = tree.query_point(0, i);
            assert!((got - want).abs() < 1e-6 * (1.0 + want));
        }
    }
}
