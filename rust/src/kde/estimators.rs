//! Naive and sampling KDE estimators over contiguous index ranges of a
//! dataset.

use std::sync::Arc;

use crate::kde::{FusedView, Kde, KdeConfig, KdeCounters};
use crate::kernel::{Dataset, Kernel};
use crate::runtime::backend::KernelBackend;
use crate::util::rng::Rng;

/// Exact KDE over `ds[lo..hi)`: a full scan per query. `eps = 0`.
pub struct NaiveKde {
    ds: Arc<Dataset>,
    kernel: Kernel,
    lo: usize,
    hi: usize,
    backend: Arc<dyn KernelBackend>,
    counters: Arc<KdeCounters>,
}

impl NaiveKde {
    /// Exact oracle over `ds[lo..hi)` dispatching through `backend`.
    pub fn new(
        ds: Arc<Dataset>,
        kernel: Kernel,
        lo: usize,
        hi: usize,
        backend: Arc<dyn KernelBackend>,
        counters: Arc<KdeCounters>,
    ) -> Self {
        assert!(lo < hi && hi <= ds.n);
        NaiveKde { ds, kernel, lo, hi, backend, counters }
    }
}

impl Kde for NaiveKde {
    fn query(&self, y: &[f32]) -> f64 {
        self.counters.record_query();
        let d = self.ds.d;
        let data = &self.ds.flat()[self.lo * d..self.hi * d];
        self.backend.sums(self.kernel, y, data, d)[0]
    }

    /// Native batch: one backend `sums` dispatch for the whole query set.
    /// Each output equals the corresponding single `query` exactly (the
    /// backend computes rows independently).
    fn query_batch(&self, ys: &[f32]) -> Vec<f64> {
        let d = self.ds.d;
        assert!(ys.len() % d == 0);
        self.counters.record_queries((ys.len() / d) as u64);
        let data = &self.ds.flat()[self.lo * d..self.hi * d];
        self.backend.sums(self.kernel, ys, data, d)
    }

    /// Fusable: one backend scan over the node's dataset slice, scale 1.
    fn fused_view(&self) -> Option<FusedView<'_>> {
        let d = self.ds.d;
        Some(FusedView {
            data: &self.ds.flat()[self.lo * d..self.hi * d],
            scale: 1.0,
        })
    }

    fn subset_len(&self) -> usize {
        self.hi - self.lo
    }

    fn dim(&self) -> usize {
        self.ds.d
    }
}

/// Exact KDE over an *owned* copy of `ds[lo..hi)`, gathered once at
/// construction.
///
/// Numerically identical to [`NaiveKde`] over the same range (both issue
/// one backend `sums` scan over the same bytes with scale 1), but it holds
/// no `Arc<Dataset>` — which is what the dynamic tree needs: after a
/// copy-on-write dataset edit (`Arc::make_mut`), borrowing oracles would
/// silently keep reading the pre-edit buffer their own `Arc` pins alive,
/// while owned-buffer oracles are explicitly rebuilt along the edited
/// slot's ancestor path and nowhere else.
pub struct BufferKde {
    kernel: Kernel,
    d: usize,
    /// Gathered range coordinates, row-major `(hi - lo) x d`.
    data: Vec<f32>,
    backend: Arc<dyn KernelBackend>,
    counters: Arc<KdeCounters>,
}

impl BufferKde {
    /// Copy `ds[lo..hi)` into an owned buffer; queries scan only the copy.
    pub fn gather(
        ds: &Dataset,
        kernel: Kernel,
        lo: usize,
        hi: usize,
        backend: Arc<dyn KernelBackend>,
        counters: Arc<KdeCounters>,
    ) -> Self {
        assert!(lo < hi && hi <= ds.n);
        let d = ds.d;
        let data = ds.flat()[lo * d..hi * d].to_vec();
        BufferKde { kernel, d, data, backend, counters }
    }
}

impl Kde for BufferKde {
    fn query(&self, y: &[f32]) -> f64 {
        self.counters.record_query();
        self.backend.sums(self.kernel, y, &self.data, self.d)[0]
    }

    /// Native batch: one backend `sums` dispatch over the owned buffer.
    fn query_batch(&self, ys: &[f32]) -> Vec<f64> {
        assert!(ys.len() % self.d == 0);
        self.counters.record_queries((ys.len() / self.d) as u64);
        self.backend.sums(self.kernel, ys, &self.data, self.d)
    }

    /// Fusable: one backend scan over the owned buffer, scale 1.
    fn fused_view(&self) -> Option<FusedView<'_>> {
        Some(FusedView { data: &self.data, scale: 1.0 })
    }

    fn subset_len(&self) -> usize {
        self.data.len() / self.d
    }

    fn dim(&self) -> usize {
        self.d
    }
}

/// Uniform-sampling KDE (§3.1): a fixed random subsample `R` of the range,
/// drawn once at construction; `query(y) = |S|/|R| * sum_{x in R} k(x, y)`.
///
/// For kernels with all values `>= tau` this is a `(1 ± eps)` estimator
/// with `|R| = O(1/(tau eps^2))` (exponent `p = 1` in Table 1's terms).
/// The subsample is gathered into a contiguous buffer so each query is one
/// backend call (and one PJRT tile execution on the artifact path).
pub struct SamplingKde {
    kernel: Kernel,
    d: usize,
    /// Gathered sample coordinates, row-major `s x d`.
    sample: Vec<f32>,
    /// Range size |S| that the estimate scales up to.
    len: usize,
    /// `|S| / |R|`, the constant every raw backend sum is scaled by.
    /// Precomputed so the per-query path and the fused level path apply
    /// the *same* f64 multiplication and stay bit-identical.
    scale: f64,
    backend: Arc<dyn KernelBackend>,
    counters: Arc<KdeCounters>,
}

impl SamplingKde {
    /// Draw the subsample of `ds[lo..hi)` once; queries then scan only it.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        ds: Arc<Dataset>,
        kernel: Kernel,
        lo: usize,
        hi: usize,
        cfg: &KdeConfig,
        backend: Arc<dyn KernelBackend>,
        counters: Arc<KdeCounters>,
        rng: &mut Rng,
    ) -> Self {
        assert!(lo < hi && hi <= ds.n);
        let len = hi - lo;
        let s = cfg.sample_size(len);
        let idx = rng.sample_indices(len, s);
        let d = ds.d;
        let mut sample = Vec::with_capacity(s * d);
        for &i in &idx {
            sample.extend_from_slice(ds.point(lo + i));
        }
        let scale = len as f64 / s as f64;
        SamplingKde { kernel, d, sample, len, scale, backend, counters }
    }
}

impl Kde for SamplingKde {
    fn query(&self, y: &[f32]) -> f64 {
        self.counters.record_query();
        let raw = self.backend.sums(self.kernel, y, &self.sample, self.d)[0];
        raw * self.scale
    }

    /// Native batch: the fixed subsample is shared by every query, so the
    /// whole batch is one backend `sums` dispatch over it.
    fn query_batch(&self, ys: &[f32]) -> Vec<f64> {
        assert!(ys.len() % self.d == 0);
        self.counters.record_queries((ys.len() / self.d) as u64);
        let raw = self.backend.sums(self.kernel, ys, &self.sample, self.d);
        raw.into_iter().map(|v| v * self.scale).collect()
    }

    /// Fusable: one backend scan over the gathered subsample, scaled by
    /// `|S| / |R|`.
    fn fused_view(&self) -> Option<FusedView<'_>> {
        Some(FusedView { data: &self.sample, scale: self.scale })
    }

    fn subset_len(&self) -> usize {
        self.len
    }

    fn dim(&self) -> usize {
        self.d
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::kernel::dataset::gaussian_mixture;
    use crate::runtime::backend::CpuBackend;
    use crate::util::prop::forall;

    fn setup(n: usize, seed: u64) -> (Arc<Dataset>, Arc<CpuBackend>, Arc<KdeCounters>, Rng) {
        let mut rng = Rng::new(seed);
        let ds = Arc::new(gaussian_mixture(n, 6, 3, 1.0, 0.6, &mut rng));
        (ds, CpuBackend::new(), KdeCounters::new(), rng)
    }

    fn exact_range_sum(ds: &Dataset, k: Kernel, lo: usize, hi: usize, y: &[f32]) -> f64 {
        (lo..hi).map(|j| k.eval(ds.point(j), y) as f64).sum()
    }

    #[test]
    fn naive_is_exact() {
        let (ds, be, ctr, mut rng) = setup(64, 41);
        let k = Kernel::Laplacian;
        let kde = NaiveKde::new(ds.clone(), k, 8, 40, be, ctr.clone());
        for _ in 0..10 {
            let q = rng.below(ds.n);
            let got = kde.query(ds.point(q));
            let want = exact_range_sum(&ds, k, 8, 40, ds.point(q));
            assert!((got - want).abs() < 1e-6 * (1.0 + want));
        }
        assert_eq!(ctr.queries(), 10);
        assert_eq!(kde.subset_len(), 32);
    }

    #[test]
    fn sampling_full_size_is_exact() {
        // When the sample covers the whole range, estimate is exact.
        let (ds, be, ctr, mut rng) = setup(48, 43);
        let cfg = KdeConfig {
            kind: crate::kde::EstimatorKind::Sampling { eps: 0.01, tau: 0.9 },
            ..Default::default()
        };
        // sample_size = 4/(0.9*1e-4) >> 48 -> clamped to 48.
        let kde = SamplingKde::new(
            ds.clone(),
            Kernel::Gaussian,
            0,
            48,
            &cfg,
            be,
            ctr,
            &mut rng,
        );
        let y = ds.point(0).to_vec();
        let got = kde.query(&y);
        let want = exact_range_sum(&ds, Kernel::Gaussian, 0, 48, &y);
        assert!((got - want).abs() < 1e-6 * (1.0 + want));
    }

    #[test]
    fn sampling_concentrates() {
        // Tight dataset (all kernel values near 1) -> tiny relative error.
        forall(8, |rng, case| {
            let ds = Arc::new(gaussian_mixture(512, 4, 1, 0.0, 0.15, rng));
            let tau = ds.tau(Kernel::Laplacian);
            assert!(tau > 0.05, "setup: tau too small ({tau})");
            let cfg = KdeConfig {
                kind: crate::kde::EstimatorKind::Sampling { eps: 0.2, tau: 0.2 },
                ..Default::default()
            };
            let kde = SamplingKde::new(
                ds.clone(),
                Kernel::Laplacian,
                0,
                512,
                &cfg,
                CpuBackend::new(),
                KdeCounters::new(),
                rng,
            );
            let q = rng.below(512);
            let got = kde.query(ds.point(q));
            let want = exact_range_sum(&ds, Kernel::Laplacian, 0, 512, ds.point(q));
            let rel = (got - want).abs() / want;
            assert!(rel < 0.25, "case {case}: rel err {rel}");
        });
    }

    #[test]
    fn buffer_kde_is_bit_identical_to_naive() {
        let (ds, be, ctr, mut rng) = setup(80, 49);
        for k in [Kernel::Laplacian, Kernel::Gaussian] {
            let naive = NaiveKde::new(ds.clone(), k, 8, 72, be.clone(), ctr.clone());
            let buf = BufferKde::gather(&ds, k, 8, 72, be.clone(), ctr.clone());
            assert_eq!(buf.subset_len(), naive.subset_len());
            assert_eq!(buf.dim(), naive.dim());
            let mut ys = Vec::new();
            for _ in 0..5 {
                ys.extend_from_slice(ds.point(rng.below(ds.n)));
            }
            let a = naive.query_batch(&ys);
            let b = buf.query_batch(&ys);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.to_bits(), y.to_bits(), "{k:?}");
            }
            assert_eq!(
                naive.query(ds.point(0)).to_bits(),
                buf.query(ds.point(0)).to_bits()
            );
            let (fa, fb) = (naive.fused_view().unwrap(), buf.fused_view().unwrap());
            assert_eq!(fa.data, fb.data);
            assert_eq!(fa.scale.to_bits(), fb.scale.to_bits());
        }
    }

    #[test]
    fn query_batch_matches_query_exactly() {
        // Backends compute batch rows independently, so the native batch
        // paths must reproduce the per-query answers bit-for-bit.
        let (ds, be, ctr, mut rng) = setup(96, 45);
        let naive = NaiveKde::new(ds.clone(), Kernel::Gaussian, 4, 90, be.clone(), ctr.clone());
        let cfg = KdeConfig {
            kind: crate::kde::EstimatorKind::Sampling { eps: 0.3, tau: 0.1 },
            ..Default::default()
        };
        let sampling = SamplingKde::new(
            ds.clone(),
            Kernel::Gaussian,
            0,
            96,
            &cfg,
            be,
            ctr.clone(),
            &mut rng,
        );
        let idx = [0usize, 7, 41, 95, 7];
        let mut ys = Vec::new();
        for &i in &idx {
            ys.extend_from_slice(ds.point(i));
        }
        let before = ctr.queries();
        let batch_n = naive.query_batch(&ys);
        assert_eq!(ctr.queries(), before + idx.len() as u64, "batch counts b queries");
        let batch_s = sampling.query_batch(&ys);
        for (pos, &i) in idx.iter().enumerate() {
            assert_eq!(batch_n[pos].to_bits(), naive.query(ds.point(i)).to_bits());
            assert_eq!(batch_s[pos].to_bits(), sampling.query(ds.point(i)).to_bits());
        }
        assert_eq!(naive.dim(), ds.d);
        assert_eq!(sampling.dim(), ds.d);
    }

    #[test]
    fn sampling_unbiased_over_redraws() {
        let (ds, be, _, mut rng) = setup(256, 47);
        let k = Kernel::Gaussian;
        let y = ds.point(3).to_vec();
        let want = exact_range_sum(&ds, k, 0, 256, &y);
        let cfg = KdeConfig {
            kind: crate::kde::EstimatorKind::Sampling { eps: 0.8, tau: 0.2 },
            ..Default::default()
        };
        let trials = 200;
        let mut acc = 0.0;
        for _ in 0..trials {
            let kde = SamplingKde::new(
                ds.clone(),
                k,
                0,
                256,
                &cfg,
                be.clone(),
                KdeCounters::new(),
                &mut rng,
            );
            acc += kde.query(&y);
        }
        let mean = acc / trials as f64;
        assert!(
            (mean - want).abs() < 0.05 * want,
            "mean {mean} vs exact {want}"
        );
    }
}
