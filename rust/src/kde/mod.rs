//! Kernel Density Estimation oracles (Definition 1.1) and the multi-level
//! KDE structure (Algorithm 4.1).
//!
//! The paper treats KDE strictly as a black box: `query(y)` returns a value
//! in `[(1-eps) z, (1+eps) z]` for `z = sum_{x in S} k(x, y)` over the
//! structure's subset `S`, assuming all kernel values >= tau. Three
//! realizations live here:
//!
//! * [`NaiveKde`]    — exact scan; the test oracle and the `eps = 0` point.
//! * [`SamplingKde`] — uniform-subsample estimator; the paper's §3.1
//!   "simple random sampling" baseline achieving exponent `p = 1` for any
//!   bounded kernel. This is the default estimator in experiments.
//! * [`HbeKde`]      — hashing-based estimator for the Laplacian kernel
//!   (BIW19-style L1 random-grid LSH with importance-weighted collisions).
//!
//! All estimators route their bulk evaluations through a
//! [`KernelBackend`](crate::runtime::backend::KernelBackend) so the same
//! code runs on the pure-Rust path and the PJRT artifact path. Estimators
//! whose query is a single contiguous backend scan additionally expose a
//! [`FusedView`], which lets the multi-level tree coalesce several nodes'
//! query groups into one fused backend dispatch per level (see
//! [`MultiLevelKde::query_points_multi`] and `docs/ARCHITECTURE.md`).
#![warn(missing_docs)]
#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod estimators;
pub mod hbe;
pub mod multilevel;
pub mod ptree;

pub use estimators::{BufferKde, NaiveKde, SamplingKde};
pub use hbe::HbeKde;
pub use multilevel::MultiLevelKde;
pub use ptree::PartitionTreeKde;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Shared query accounting (the paper's "number of KDE queries" metric).
#[derive(Default, Debug)]
pub struct KdeCounters {
    queries: AtomicU64,
}

impl KdeCounters {
    /// Fresh zeroed counters behind an `Arc` (shared across oracles).
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }
    /// Record one KDE query.
    pub fn record_query(&self) {
        self.queries.fetch_add(1, Ordering::Relaxed);
    }
    /// Record `n` queries at once (the batched path).
    pub fn record_queries(&self, n: u64) {
        self.queries.fetch_add(n, Ordering::Relaxed);
    }
    /// KDE queries recorded so far.
    pub fn queries(&self) -> u64 {
        self.queries.load(Ordering::Relaxed)
    }
    /// Zero the counter (experiment hygiene between runs).
    pub fn reset(&self) {
        self.queries.store(0, Ordering::Relaxed);
    }
}

/// How a fusable oracle evaluates a query: one backend `sums` scan over a
/// fixed row-major buffer, times a constant scale. Exposing the buffer
/// lets the multi-level tree pack several oracles' scans into one fused
/// `sums_ranged` dispatch (the buffer becomes one data segment of the
/// packed submission) while reproducing `query_batch` bit for bit:
/// `answer = scale * sum_{x in data} k(x, y)`.
#[derive(Clone, Copy, Debug)]
pub struct FusedView<'a> {
    /// The oracle's scan buffer, `rows x dim` row-major (a dataset range
    /// for [`NaiveKde`], the gathered subsample for [`SamplingKde`]).
    pub data: &'a [f32],
    /// Constant the raw backend sum is multiplied by (1.0 for exact scans,
    /// `|S| / |R|` for the sampling estimator).
    pub scale: f64,
}

/// A KDE oracle over some subset of the dataset.
pub trait Kde: Send + Sync {
    /// Approximate `sum_{x in S} k(x, y)`. NOTE: if `y` is itself a member
    /// of `S`, its self-term `k(y,y) = 1` **is included** — callers
    /// subtract it (Algorithm 4.3 line (a)).
    fn query(&self, y: &[f32]) -> f64;

    /// Batched query: `ys` is `b x dim()` row-major; returns the `b`
    /// per-query answers, each identical in distribution (and, for
    /// deterministic estimators, in value) to `query` on that row. The
    /// default implementation loops `query`; estimators backed by a
    /// [`KernelBackend`](crate::runtime::backend::KernelBackend) override
    /// it with a single backend dispatch — the primitive the level-order
    /// batched tree evaluation and the coordinator's batcher are built on.
    ///
    /// # Example
    ///
    /// ```
    /// use std::sync::Arc;
    /// use kde_matrix::kde::{Kde, KdeCounters, NaiveKde};
    /// use kde_matrix::kernel::{dataset::gaussian_mixture, Kernel};
    /// use kde_matrix::runtime::CpuBackend;
    /// use kde_matrix::util::rng::Rng;
    ///
    /// let mut rng = Rng::new(7);
    /// let ds = Arc::new(gaussian_mixture(32, 3, 2, 1.0, 0.5, &mut rng));
    /// let kde = NaiveKde::new(
    ///     ds.clone(), Kernel::Laplacian, 0, 32, CpuBackend::new(), KdeCounters::new(),
    /// );
    /// // Two query points, packed row-major.
    /// let mut ys = Vec::new();
    /// ys.extend_from_slice(ds.point(0));
    /// ys.extend_from_slice(ds.point(5));
    /// let sums = kde.query_batch(&ys);
    /// assert_eq!(sums.len(), 2);
    /// // Batch rows reproduce single queries exactly (deterministic oracle),
    /// // and a member point's answer includes its own self-term k(y, y) = 1.
    /// assert_eq!(sums[0].to_bits(), kde.query(ds.point(0)).to_bits());
    /// assert!(sums[0] >= 1.0);
    /// ```
    fn query_batch(&self, ys: &[f32]) -> Vec<f64> {
        let d = self.dim();
        assert!(d > 0 && ys.len() % d == 0, "query batch not a multiple of dim");
        ys.chunks_exact(d).map(|y| self.query(y)).collect()
    }

    /// The oracle's [`FusedView`], when its `query_batch` is exactly one
    /// backend `sums` scan times a scale — `None` (the default) for
    /// estimators with a different evaluation shape (hash probes, tree
    /// pruning), which the fused pipeline then serves through
    /// [`query_batch`](Self::query_batch) as before.
    fn fused_view(&self) -> Option<FusedView<'_>> {
        None
    }

    /// |S|, the subset size this oracle covers.
    fn subset_len(&self) -> usize;

    /// Feature dimension of the query points this oracle accepts.
    fn dim(&self) -> usize;
}

/// Which estimator the factories instantiate.
#[derive(Clone, Copy, Debug)]
pub enum EstimatorKind {
    /// Exact scan (`eps = 0`): [`NaiveKde`].
    Naive,
    /// Uniform sampling with the §3.1 sample size `O(1/(tau eps^2))`.
    Sampling { eps: f64, tau: f64 },
    /// Laplacian-kernel HBE; `tables` hash tables of width `width`.
    Hbe { tables: usize, width: f32 },
    /// Deterministic space-partition-tree estimator with certified
    /// per-query relative error `eps` (§3.1's practical tree family).
    PartitionTree { eps: f64 },
}

/// Configuration shared by the sampling primitives.
#[derive(Clone, Copy, Debug)]
pub struct KdeConfig {
    /// Estimator family instantiated at every (non-leaf) tree node.
    pub kind: EstimatorKind,
    /// Ranges of at most this many points get exact (naive) estimators in
    /// the multi-level tree — the bottom levels are where accuracy matters
    /// most for edge sampling and exactness there is cheaper than sampling.
    pub leaf_cutoff: usize,
    /// Seed for estimator-construction randomness (subsamples, hashes).
    pub seed: u64,
}

impl Default for KdeConfig {
    fn default() -> Self {
        KdeConfig {
            kind: EstimatorKind::Sampling { eps: 0.25, tau: 0.05 },
            leaf_cutoff: 16,
            seed: 0x5EED,
        }
    }
}

impl KdeConfig {
    /// Exact (naive) estimators everywhere — the `eps = 0` test oracle.
    pub fn exact() -> Self {
        KdeConfig { kind: EstimatorKind::Naive, leaf_cutoff: 16, seed: 0x5EED }
    }

    /// Sample size the sampling estimator uses for a subset of size `len`.
    pub fn sample_size(&self, len: usize) -> usize {
        match self.kind {
            EstimatorKind::Naive => len,
            EstimatorKind::Sampling { eps, tau } => {
                let s = (4.0 / (tau * eps * eps)).ceil() as usize;
                s.clamp(1, len)
            }
            EstimatorKind::Hbe { .. } => len,
            EstimatorKind::PartitionTree { .. } => len,
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_reset() {
        let c = KdeCounters::new();
        c.record_query();
        c.record_query();
        assert_eq!(c.queries(), 2);
        c.reset();
        assert_eq!(c.queries(), 0);
    }

    #[test]
    fn sample_size_clamps() {
        let cfg = KdeConfig {
            kind: EstimatorKind::Sampling { eps: 0.5, tau: 0.1 },
            ..Default::default()
        };
        // 4/(0.1*0.25) = 160
        assert_eq!(cfg.sample_size(1000), 160);
        assert_eq!(cfg.sample_size(50), 50);
        let exact = KdeConfig::exact();
        assert_eq!(exact.sample_size(77), 77);
    }
}
