//! Hashing-Based Estimator for the Laplacian kernel (BIW19-style).
//!
//! LSH family: per hash table, a random-grid hash over R^d with per-table
//! width `w` and per-dimension uniform offsets. For this family the
//! collision probability of two points is exactly
//! `p(x, y) = prod_j max(0, 1 - |x_j - y_j| / w)`.
//!
//! The estimator samples a uniform point `Z` from the query's bucket and
//! returns `|bucket| * k(Z, y) / p(Z, y)`, which is unbiased for the mass
//! of all points with positive collision probability:
//! `E = sum_x E[1{x in bucket}] * k(x,y)/p(x,y) = sum_{x: p>0} k(x, y)`.
//!
//! Points with some coordinate gap >= w are invisible to one table; with
//! `w` a small multiple of the (pre-scaled) typical distance their kernel
//! mass is exponentially small, and averaging over tables controls the
//! variance — this is the practical trade documented in DESIGN.md §3
//! (paper Table 1 lists the theoretical tau^0.5 variant).

use std::collections::HashMap;
use std::sync::{Arc, Mutex, PoisonError};

use crate::kde::{Kde, KdeCounters};
use crate::kernel::{Dataset, Kernel};
use crate::util::rng::Rng;

struct Table {
    offsets: Vec<f32>,
    buckets: HashMap<Vec<i32>, Vec<usize>>,
}

/// Hashing-based estimator over `ds[lo..hi)`; see the module docs.
pub struct HbeKde {
    ds: Arc<Dataset>,
    lo: usize,
    hi: usize,
    width: f32,
    tables: Vec<Table>,
    counters: Arc<KdeCounters>,
    /// Per-query bucket sampling randomness; a Mutex (not RefCell) so the
    /// estimator is safely `Sync` — concurrent queries serialize only on
    /// the cheap RNG draw, not the hash probes.
    rng: Mutex<Rng>,
    evals: std::sync::atomic::AtomicU64,
}

impl HbeKde {
    /// Build `num_tables` random-grid hash tables of width `width` over
    /// `ds[lo..hi)` (Laplacian kernel only).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        ds: Arc<Dataset>,
        kernel: Kernel,
        lo: usize,
        hi: usize,
        num_tables: usize,
        width: f32,
        counters: Arc<KdeCounters>,
        rng: &mut Rng,
    ) -> Self {
        assert_eq!(
            kernel,
            Kernel::Laplacian,
            "HBE here implements the L1 (Laplacian) scheme only"
        );
        assert!(lo < hi && hi <= ds.n && width > 0.0);
        let d = ds.d;
        let mut tables = Vec::with_capacity(num_tables);
        for _ in 0..num_tables {
            let offsets: Vec<f32> = (0..d).map(|_| (rng.f64() as f32) * width).collect();
            let mut buckets: HashMap<Vec<i32>, Vec<usize>> = HashMap::new();
            for i in lo..hi {
                let key = Self::hash_key(ds.point(i), &offsets, width);
                buckets.entry(key).or_default().push(i);
            }
            tables.push(Table { offsets, buckets });
        }
        HbeKde {
            ds,
            lo,
            hi,
            width,
            tables,
            counters,
            rng: Mutex::new(rng.fork()),
            evals: std::sync::atomic::AtomicU64::new(0),
        }
    }

    fn hash_key(x: &[f32], offsets: &[f32], w: f32) -> Vec<i32> {
        x.iter()
            .zip(offsets)
            .map(|(v, o)| ((v + o) / w).floor() as i32)
            .collect()
    }

    fn collision_prob(&self, x: &[f32], y: &[f32]) -> f64 {
        let mut p = 1.0f64;
        for j in 0..x.len() {
            let frac = 1.0 - ((x[j] - y[j]).abs() / self.width) as f64;
            if frac <= 0.0 {
                return 0.0;
            }
            p *= frac;
        }
        p
    }

    /// Exact kernel evaluations spent so far (#tables per query).
    pub fn kernel_evals(&self) -> u64 {
        self.evals.load(std::sync::atomic::Ordering::Relaxed)
    }
}

impl Kde for HbeKde {
    fn query(&self, y: &[f32]) -> f64 {
        self.counters.record_query();
        let mut acc = 0.0f64;
        for t in &self.tables {
            let key = Self::hash_key(y, &t.offsets, self.width);
            let Some(bucket) = t.buckets.get(&key) else { continue };
            if bucket.is_empty() {
                continue;
            }
            // Lock only for the draw itself; the hash probes and kernel
            // evals (the actual work) run outside the critical section.
            let z = {
                // Poison recovery: the RNG state is a plain counter, valid
                // after any panic elsewhere.
                let mut rng = self.rng.lock().unwrap_or_else(PoisonError::into_inner);
                bucket[rng.below(bucket.len())]
            };
            let zx = self.ds.point(z);
            let p = self.collision_prob(zx, y);
            if p <= 0.0 {
                continue;
            }
            self.evals
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let k = Kernel::Laplacian.eval(zx, y) as f64;
            acc += bucket.len() as f64 * k / p;
        }
        acc / self.tables.len() as f64
    }

    /// Native batch: the HBE cost model is per-query hash probes (no
    /// backend dispatch to amortize), so the batch is a sequential loop —
    /// it exists so HBE-backed trees slot into the batched pipeline.
    fn query_batch(&self, ys: &[f32]) -> Vec<f64> {
        let d = self.ds.d;
        assert!(ys.len() % d == 0);
        ys.chunks_exact(d).map(|y| self.query(y)).collect()
    }

    fn subset_len(&self) -> usize {
        self.hi - self.lo
    }

    fn dim(&self) -> usize {
        self.ds.d
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::kernel::dataset::gaussian_mixture;

    fn exact_sum(ds: &Dataset, y: &[f32]) -> f64 {
        (0..ds.n)
            .map(|j| Kernel::Laplacian.eval(ds.point(j), y) as f64)
            .sum()
    }

    #[test]
    fn hbe_close_to_exact_on_scaled_data() {
        let mut rng = Rng::new(51);
        // Tight single blob, coordinates O(0.3): width 4.0 covers all pairs.
        let ds = Arc::new(gaussian_mixture(400, 4, 1, 0.0, 0.3, &mut rng));
        let kde = HbeKde::new(
            ds.clone(),
            Kernel::Laplacian,
            0,
            400,
            60,
            4.0,
            KdeCounters::new(),
            &mut rng,
        );
        let mut worst: f64 = 0.0;
        for q in [0usize, 17, 99, 321] {
            let got = kde.query(ds.point(q));
            let want = exact_sum(&ds, ds.point(q));
            worst = worst.max((got - want).abs() / want);
        }
        assert!(worst < 0.2, "worst rel err {worst}");
    }

    #[test]
    fn hbe_unbiased_when_width_covers_everything() {
        let mut rng = Rng::new(53);
        let ds = Arc::new(gaussian_mixture(128, 3, 1, 0.0, 0.2, &mut rng));
        let want = exact_sum(&ds, ds.point(5));
        let trials = 60;
        let mut acc = 0.0;
        for t in 0..trials {
            let mut r = Rng::new(1000 + t);
            let kde = HbeKde::new(
                ds.clone(),
                Kernel::Laplacian,
                0,
                128,
                8,
                6.0,
                KdeCounters::new(),
                &mut r,
            );
            acc += kde.query(ds.point(5));
        }
        let mean = acc / trials as f64;
        assert!(
            (mean - want).abs() < 0.08 * want,
            "mean {mean} vs exact {want}"
        );
    }

    #[test]
    fn hbe_query_cost_sublinear() {
        // Kernel evaluations per query = #tables, independent of n.
        let mut rng = Rng::new(57);
        let ds = Arc::new(gaussian_mixture(1000, 3, 1, 0.0, 0.3, &mut rng));
        let kde = HbeKde::new(
            ds.clone(),
            Kernel::Laplacian,
            0,
            1000,
            20,
            4.0,
            KdeCounters::new(),
            &mut rng,
        );
        kde.query(ds.point(0));
        assert!(kde.kernel_evals() <= 20, "evals {}", kde.kernel_evals());
    }
}
