//! Space-partition-tree KDE (§3.1's "practical algorithms based on space
//! partition trees" [GM01, GM03, MXB15]): a KD-tree whose nodes carry
//! bounding boxes; a query descends with per-node kernel bounds and
//! *prunes* whole subtrees whose kernel-mass uncertainty is below the
//! accuracy budget, falling back to exact evaluation at small leaves.
//!
//! For a box `B` and query `y`, every kernel in Table 1 is monotone in the
//! relevant distance, so
//! `|B| * k(d_max(y, B)) <= mass(B) <= |B| * k(d_min(y, B))`,
//! where `d_min`/`d_max` are the min/max distance from `y` to the box.
//! When `hi - lo <= 2 * eps_abs * |B| / |X|`, the midpoint is used and the
//! subtree skipped. This estimator is *deterministic* and its error is
//! certified per query — a different trade than sampling/HBE, matching the
//! paper's remark that any practical KDE structure slots in as the black
//! box.

use std::sync::Arc;

use crate::kde::{Kde, KdeCounters};
use crate::kernel::{Dataset, Kernel};

struct PNode {
    lo: usize,
    hi: usize,
    bbox_min: Vec<f32>,
    bbox_max: Vec<f32>,
    left: Option<usize>,
    right: Option<usize>,
}

/// Deterministic space-partition-tree estimator; see the module docs.
pub struct PartitionTreeKde {
    ds: Arc<Dataset>,
    kernel: Kernel,
    /// Permutation of [range_lo, range_hi) grouped by tree leaves.
    perm: Vec<usize>,
    nodes: Vec<PNode>,
    /// Per-point relative accuracy target.
    pub eps: f64,
    leaf_size: usize,
    counters: Arc<KdeCounters>,
    evals: std::sync::atomic::AtomicU64,
    range_len: usize,
}

impl PartitionTreeKde {
    /// KD-tree with bounding boxes over `ds[lo..hi)`, per-query relative
    /// accuracy target `eps` (0 = exact).
    pub fn new(
        ds: Arc<Dataset>,
        kernel: Kernel,
        lo: usize,
        hi: usize,
        eps: f64,
        counters: Arc<KdeCounters>,
    ) -> Self {
        assert!(lo < hi && hi <= ds.n);
        let mut perm: Vec<usize> = (lo..hi).collect();
        let mut nodes = Vec::new();
        let leaf_size = 16;
        let len = hi - lo;
        Self::build(&ds, &mut perm, 0, len, leaf_size, &mut nodes, 0);
        PartitionTreeKde {
            ds,
            kernel,
            perm,
            nodes,
            eps,
            leaf_size,
            counters,
            evals: std::sync::atomic::AtomicU64::new(0),
            range_len: len,
        }
    }

    fn build(
        ds: &Dataset,
        perm: &mut [usize],
        lo: usize,
        hi: usize,
        leaf_size: usize,
        nodes: &mut Vec<PNode>,
        depth: usize,
    ) -> usize {
        let d = ds.d;
        let mut bbox_min = vec![f32::INFINITY; d];
        let mut bbox_max = vec![f32::NEG_INFINITY; d];
        for &i in &perm[lo..hi] {
            let p = ds.point(i);
            for c in 0..d {
                bbox_min[c] = bbox_min[c].min(p[c]);
                bbox_max[c] = bbox_max[c].max(p[c]);
            }
        }
        let id = nodes.len();
        nodes.push(PNode { lo, hi, bbox_min, bbox_max, left: None, right: None });
        if hi - lo > leaf_size {
            // Split on the widest dimension at the median.
            let (mut axis, mut width) = (0usize, -1.0f32);
            for c in 0..d {
                let w = nodes[id].bbox_max[c] - nodes[id].bbox_min[c];
                if w > width {
                    width = w;
                    axis = c;
                }
            }
            let mid = (lo + hi) / 2;
            perm[lo..hi].select_nth_unstable_by(mid - lo, |&a, &b| {
                // Coordinates are finite by construction (dataset
                // generators never emit NaN); Equal is a safe total-order
                // fallback that at worst skews one median pick.
                ds.point(a)[axis]
                    .partial_cmp(&ds.point(b)[axis])
                    .unwrap_or(std::cmp::Ordering::Equal)
            });
            let l = Self::build(ds, perm, lo, mid, leaf_size, nodes, depth + 1);
            let r = Self::build(ds, perm, mid, hi, leaf_size, nodes, depth + 1);
            nodes[id].left = Some(l);
            nodes[id].right = Some(r);
        }
        id
    }

    /// Min / max distance (in the kernel's own metric space) from `y` to
    /// the node's bounding box: (L1 or L2^2 components per dimension).
    fn box_dists(&self, node: &PNode, y: &[f32]) -> (f64, f64) {
        let mut dmin = 0.0f64;
        let mut dmax = 0.0f64;
        let l1 = self.kernel == Kernel::Laplacian;
        for c in 0..y.len() {
            let (bmin, bmax) = (node.bbox_min[c], node.bbox_max[c]);
            let below = (bmin - y[c]).max(0.0) as f64;
            let above = (y[c] - bmax).max(0.0) as f64;
            let near = below.max(above);
            let far = ((y[c] - bmin).abs().max((y[c] - bmax).abs())) as f64;
            if l1 {
                dmin += near;
                dmax += far;
            } else {
                dmin += near * near;
                dmax += far * far;
            }
        }
        (dmin, dmax)
    }

    fn kernel_of_dist(&self, dist: f64) -> f64 {
        match self.kernel {
            Kernel::Laplacian => (-dist).exp(),
            Kernel::Gaussian => (-dist).exp(), // dist is already squared
            Kernel::Exponential => (-dist.max(0.0).sqrt()).exp(),
            Kernel::RationalQuadratic => 1.0 / (1.0 + dist),
        }
    }

    fn query_rec(&self, id: usize, y: &[f32], budget_per_point: f64) -> f64 {
        let node = &self.nodes[id];
        let size = (node.hi - node.lo) as f64;
        let (dmin, dmax) = self.box_dists(node, y);
        let hi = self.kernel_of_dist(dmin);
        let lo = self.kernel_of_dist(dmax);
        if hi - lo <= 2.0 * budget_per_point {
            return size * 0.5 * (hi + lo);
        }
        match (node.left, node.right) {
            (Some(l), Some(r)) => {
                self.query_rec(l, y, budget_per_point) + self.query_rec(r, y, budget_per_point)
            }
            _ => {
                // Exact leaf evaluation.
                self.evals.fetch_add(
                    (node.hi - node.lo) as u64,
                    std::sync::atomic::Ordering::Relaxed,
                );
                self.perm[node.lo..node.hi]
                    .iter()
                    .map(|&i| self.kernel.eval(self.ds.point(i), y) as f64)
                    .sum()
            }
        }
    }

    /// Exact leaf kernel evaluations spent so far.
    pub fn kernel_evals(&self) -> u64 {
        self.evals.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Ranges of at most this size are evaluated exactly.
    pub fn leaf_size(&self) -> usize {
        self.leaf_size
    }
}

impl Kde for PartitionTreeKde {
    fn query(&self, y: &[f32]) -> f64 {
        self.counters.record_query();
        if self.eps <= 0.0 {
            return self.query_rec(0, y, 0.0);
        }
        // Two-pass adaptive budget: the per-point error budget must scale
        // with the *true* mean kernel value (eps * Z / |X|), which is
        // unknown upfront. Pass 1 uses a crude root-bound budget to get a
        // first estimate Z1; pass 2 re-runs with the properly calibrated
        // budget eps * Z1 / (2 |X|), making the total error certified
        // <= ~eps * Z.
        let root = &self.nodes[0];
        let (dmin, dmax) = self.box_dists(root, y);
        let crude = 0.5 * (self.kernel_of_dist(dmin) + self.kernel_of_dist(dmax));
        let z1 = self.query_rec(0, y, self.eps * crude.max(1e-12));
        let budget = self.eps * (z1 / self.range_len as f64).max(1e-12) * 0.5;
        self.query_rec(0, y, budget)
    }

    /// Native batch: each query's adaptive pruning budget depends on its
    /// own two-pass calibration, so the batch is a per-query loop (the
    /// structure is already `Sync`; there is no backend dispatch to fuse).
    fn query_batch(&self, ys: &[f32]) -> Vec<f64> {
        let d = self.ds.d;
        assert!(ys.len() % d == 0);
        ys.chunks_exact(d).map(|y| self.query(y)).collect()
    }

    fn subset_len(&self) -> usize {
        self.range_len
    }

    fn dim(&self) -> usize {
        self.ds.d
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::kernel::dataset::gaussian_mixture;
    use crate::util::rng::Rng;

    fn exact(ds: &Dataset, k: Kernel, y: &[f32]) -> f64 {
        (0..ds.n).map(|j| k.eval(ds.point(j), y) as f64).sum()
    }

    #[test]
    fn ptree_matches_exact_within_eps() {
        let mut rng = Rng::new(1301);
        let ds = Arc::new(gaussian_mixture(512, 6, 4, 1.5, 0.5, &mut rng));
        for k in crate::kernel::ALL_KERNELS {
            let tree = PartitionTreeKde::new(
                ds.clone(),
                k,
                0,
                512,
                0.05,
                KdeCounters::new(),
            );
            let mut worst: f64 = 0.0;
            for q in (0..512).step_by(37) {
                let got = tree.query(ds.point(q));
                let want = exact(&ds, k, ds.point(q));
                worst = worst.max((got - want).abs() / want);
            }
            assert!(worst < 0.15, "{:?} ptree worst rel err {worst}", k);
        }
    }

    #[test]
    fn ptree_prunes_far_mass() {
        // Two far-apart blobs: querying inside one should not evaluate the
        // other blob's points exactly.
        let mut rng = Rng::new(1303);
        let ds = Arc::new(gaussian_mixture(1024, 4, 2, 25.0, 0.3, &mut rng));
        let tree = PartitionTreeKde::new(
            ds.clone(),
            Kernel::Gaussian,
            0,
            1024,
            0.1,
            KdeCounters::new(),
        );
        let _ = tree.query(ds.point(0));
        let evals = tree.kernel_evals();
        // Two certified passes over an unprunable own-blob (512 points)
        // cost <= 1024; the far blob (512 more points per pass) must have
        // been pruned away.
        assert!(
            evals <= 1100,
            "pruning ineffective: {evals} exact evals for n = 1024 (2048 = no pruning)"
        );
    }

    #[test]
    fn ptree_zero_eps_is_exact() {
        let mut rng = Rng::new(1305);
        let ds = Arc::new(gaussian_mixture(256, 4, 2, 1.0, 0.5, &mut rng));
        let tree = PartitionTreeKde::new(
            ds.clone(),
            Kernel::Laplacian,
            0,
            256,
            0.0,
            KdeCounters::new(),
        );
        for q in [0usize, 99, 255] {
            let got = tree.query(ds.point(q));
            let want = exact(&ds, Kernel::Laplacian, ds.point(q));
            assert!(
                (got - want).abs() < 1e-9 * (1.0 + want),
                "eps=0 must be exact: {got} vs {want}"
            );
        }
    }

    #[test]
    fn ptree_respects_subranges() {
        let mut rng = Rng::new(1307);
        let ds = Arc::new(gaussian_mixture(128, 4, 2, 1.0, 0.5, &mut rng));
        let tree = PartitionTreeKde::new(
            ds.clone(),
            Kernel::Laplacian,
            32,
            96,
            0.02,
            KdeCounters::new(),
        );
        assert_eq!(tree.subset_len(), 64);
        let y = ds.point(5);
        let got = tree.query(y);
        let want: f64 = (32..96)
            .map(|j| Kernel::Laplacian.eval(ds.point(j), y) as f64)
            .sum();
        assert!((got - want).abs() < 0.1 * want, "{got} vs {want}");
    }
}
