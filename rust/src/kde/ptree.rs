//! Space-partition-tree KDE (§3.1's "practical algorithms based on space
//! partition trees" [GM01, GM03, MXB15]): a KD-tree whose nodes carry
//! bounding boxes; a query descends with per-node kernel bounds and
//! *prunes* whole subtrees whose kernel-mass uncertainty is below the
//! accuracy budget, falling back to exact evaluation at small leaves.
//!
//! For a box `B` and query `y`, every kernel in Table 1 is monotone in the
//! relevant distance, so
//! `|B| * k(d_max(y, B)) <= mass(B) <= |B| * k(d_min(y, B))`,
//! where `d_min`/`d_max` are the min/max distance from `y` to the box.
//! When `hi - lo <= 2 * eps_abs * |B| / |X|`, the midpoint is used and the
//! subtree skipped. This estimator is *deterministic* and its error is
//! certified per query — a different trade than sampling/HBE, matching the
//! paper's remark that any practical KDE structure slots in as the black
//! box.

use std::sync::Arc;

use crate::kde::{Kde, KdeCounters};
use crate::kernel::{Dataset, Kernel};
use crate::util::fxhash::FxHashMap;

struct PNode {
    lo: usize,
    hi: usize,
    bbox_min: Vec<f32>,
    bbox_max: Vec<f32>,
    left: Option<usize>,
    right: Option<usize>,
}

/// Deterministic space-partition-tree estimator; see the module docs.
///
/// The tree is *dynamic*: [`insert_point`](Self::insert_point) attaches a
/// staged dataset slot by descending to the least-expanding leaf (growing
/// the ancestor bounding boxes on the way down, so every pruning bound
/// stays conservative), and [`delete_point`](Self::delete_point)
/// tombstones a point and decrements the live counts up its leaf-to-root
/// path. Either edit touches exactly one root-to-leaf path — O(log n)
/// nodes, pinned by the [`edit_stats`](Self::edit_stats) contract — and
/// queries certify their error against live counts, skipping dead mass.
pub struct PartitionTreeKde {
    ds: Arc<Dataset>,
    kernel: Kernel,
    /// Permutation of [range_lo, range_hi) grouped by tree leaves.
    perm: Vec<usize>,
    nodes: Vec<PNode>,
    /// Per-point relative accuracy target.
    pub eps: f64,
    leaf_size: usize,
    counters: Arc<KdeCounters>,
    evals: std::sync::atomic::AtomicU64,
    /// Parent of each node (`None` for the root) — the upward path edits
    /// walk when adjusting live counts.
    parents: Vec<Option<usize>>,
    /// Leaf currently holding each tracked dataset index (build residents
    /// and inserted points alike).
    leaf_of: FxHashMap<usize, usize>,
    /// Live points under each node (range residents + spill − tombstones).
    live_count: Vec<usize>,
    /// Dataset indices attached after construction, per leaf.
    spill: Vec<Vec<usize>>,
    /// Tree-local tombstones, indexed by dataset slot.
    dead: Vec<bool>,
    edits: u64,
    edit_touched: u64,
}

impl PartitionTreeKde {
    /// KD-tree with bounding boxes over `ds[lo..hi)`, per-query relative
    /// accuracy target `eps` (0 = exact).
    pub fn new(
        ds: Arc<Dataset>,
        kernel: Kernel,
        lo: usize,
        hi: usize,
        eps: f64,
        counters: Arc<KdeCounters>,
    ) -> Self {
        assert!(lo < hi && hi <= ds.n);
        let mut perm: Vec<usize> = (lo..hi).collect();
        let mut nodes = Vec::new();
        let leaf_size = 16;
        let len = hi - lo;
        Self::build(&ds, &mut perm, 0, len, leaf_size, &mut nodes, 0);
        let mut parents = vec![None; nodes.len()];
        for (id, n) in nodes.iter().enumerate() {
            if let Some(l) = n.left {
                parents[l] = Some(id);
            }
            if let Some(r) = n.right {
                parents[r] = Some(id);
            }
        }
        let mut leaf_of = FxHashMap::default();
        for (id, n) in nodes.iter().enumerate() {
            if n.left.is_none() {
                for &i in &perm[n.lo..n.hi] {
                    leaf_of.insert(i, id);
                }
            }
        }
        let live_count: Vec<usize> = nodes.iter().map(|n| n.hi - n.lo).collect();
        let spill = vec![Vec::new(); nodes.len()];
        let dead = vec![false; ds.n];
        PartitionTreeKde {
            ds,
            kernel,
            perm,
            nodes,
            eps,
            leaf_size,
            counters,
            evals: std::sync::atomic::AtomicU64::new(0),
            parents,
            leaf_of,
            live_count,
            spill,
            dead,
            edits: 0,
            edit_touched: 0,
        }
    }

    /// Attach dataset slot `i` (already staged in the shared dataset) to
    /// the tree. An untracked index descends to the leaf whose bounding
    /// box expands least (L1 expansion, ties left), growing every ancestor
    /// box on the way down; a tombstoned tracked index is revived in place
    /// along its recorded leaf-to-root path. Touches O(log n) nodes either
    /// way. Returns `false` (no-op) if `i` is already live.
    pub fn insert_point(&mut self, i: usize) -> bool {
        assert!(i < self.ds.n);
        if let Some(&leaf) = self.leaf_of.get(&i) {
            if !self.dead[i] {
                return false;
            }
            // Revive: boxes never shrank, so they still contain the point.
            self.dead[i] = false;
            self.bump_path(leaf, 1);
            return true;
        }
        let y = self.ds.point(i).to_vec();
        let mut id = 0usize;
        let mut touched = 0u64;
        loop {
            let node = &mut self.nodes[id];
            for c in 0..y.len() {
                node.bbox_min[c] = node.bbox_min[c].min(y[c]);
                node.bbox_max[c] = node.bbox_max[c].max(y[c]);
            }
            self.live_count[id] += 1;
            touched += 1;
            let (l, r) = match (self.nodes[id].left, self.nodes[id].right) {
                (Some(l), Some(r)) => (l, r),
                _ => break,
            };
            id = if self.expansion(l, &y) <= self.expansion(r, &y) { l } else { r };
        }
        self.spill[id].push(i);
        self.leaf_of.insert(i, id);
        self.edits += 1;
        self.edit_touched += touched;
        true
    }

    /// Tombstone tracked point `i`, decrementing live counts up its
    /// leaf-to-root path (O(log n) nodes). Bounding boxes are left as-is —
    /// stale-large boxes only widen the certified interval, never break
    /// it. Returns `false` if `i` is untracked or already dead.
    pub fn delete_point(&mut self, i: usize) -> bool {
        let leaf = match self.leaf_of.get(&i) {
            Some(&l) => l,
            None => return false,
        };
        if self.dead[i] {
            return false;
        }
        self.dead[i] = true;
        self.bump_path(leaf, -1);
        true
    }

    /// Walk `leaf` up to the root adjusting live counts by `delta`,
    /// charging the touched-node contract.
    fn bump_path(&mut self, leaf: usize, delta: isize) {
        let mut id = Some(leaf);
        let mut touched = 0u64;
        while let Some(cur) = id {
            self.live_count[cur] = (self.live_count[cur] as isize + delta) as usize;
            touched += 1;
            id = self.parents[cur];
        }
        self.edits += 1;
        self.edit_touched += touched;
    }

    /// L1 bounding-box expansion adding `y` to node `id` would cost.
    fn expansion(&self, id: usize, y: &[f32]) -> f64 {
        let n = &self.nodes[id];
        let mut e = 0.0f64;
        for c in 0..y.len() {
            e += (n.bbox_min[c] - y[c]).max(0.0) as f64 + (y[c] - n.bbox_max[c]).max(0.0) as f64;
        }
        e
    }

    /// `(edits, nodes_touched)`: point edits applied and the total tree
    /// nodes they adjusted — the per-edit O(log n) contract (each edit
    /// touches exactly one root-to-leaf path).
    pub fn edit_stats(&self) -> (u64, u64) {
        (self.edits, self.edit_touched)
    }

    /// Live (non-tombstoned) points currently tracked.
    pub fn live_len(&self) -> usize {
        self.live_count[0]
    }

    fn build(
        ds: &Dataset,
        perm: &mut [usize],
        lo: usize,
        hi: usize,
        leaf_size: usize,
        nodes: &mut Vec<PNode>,
        depth: usize,
    ) -> usize {
        let d = ds.d;
        let mut bbox_min = vec![f32::INFINITY; d];
        let mut bbox_max = vec![f32::NEG_INFINITY; d];
        for &i in &perm[lo..hi] {
            let p = ds.point(i);
            for c in 0..d {
                bbox_min[c] = bbox_min[c].min(p[c]);
                bbox_max[c] = bbox_max[c].max(p[c]);
            }
        }
        let id = nodes.len();
        nodes.push(PNode { lo, hi, bbox_min, bbox_max, left: None, right: None });
        if hi - lo > leaf_size {
            // Split on the widest dimension at the median.
            let (mut axis, mut width) = (0usize, -1.0f32);
            for c in 0..d {
                let w = nodes[id].bbox_max[c] - nodes[id].bbox_min[c];
                if w > width {
                    width = w;
                    axis = c;
                }
            }
            let mid = (lo + hi) / 2;
            perm[lo..hi].select_nth_unstable_by(mid - lo, |&a, &b| {
                // Coordinates are finite by construction (dataset
                // generators never emit NaN); Equal is a safe total-order
                // fallback that at worst skews one median pick.
                ds.point(a)[axis]
                    .partial_cmp(&ds.point(b)[axis])
                    .unwrap_or(std::cmp::Ordering::Equal)
            });
            let l = Self::build(ds, perm, lo, mid, leaf_size, nodes, depth + 1);
            let r = Self::build(ds, perm, mid, hi, leaf_size, nodes, depth + 1);
            nodes[id].left = Some(l);
            nodes[id].right = Some(r);
        }
        id
    }

    /// Min / max distance (in the kernel's own metric space) from `y` to
    /// the node's bounding box: (L1 or L2^2 components per dimension).
    fn box_dists(&self, node: &PNode, y: &[f32]) -> (f64, f64) {
        let mut dmin = 0.0f64;
        let mut dmax = 0.0f64;
        let l1 = self.kernel == Kernel::Laplacian;
        for c in 0..y.len() {
            let (bmin, bmax) = (node.bbox_min[c], node.bbox_max[c]);
            let below = (bmin - y[c]).max(0.0) as f64;
            let above = (y[c] - bmax).max(0.0) as f64;
            let near = below.max(above);
            let far = ((y[c] - bmin).abs().max((y[c] - bmax).abs())) as f64;
            if l1 {
                dmin += near;
                dmax += far;
            } else {
                dmin += near * near;
                dmax += far * far;
            }
        }
        (dmin, dmax)
    }

    fn kernel_of_dist(&self, dist: f64) -> f64 {
        match self.kernel {
            Kernel::Laplacian => (-dist).exp(),
            Kernel::Gaussian => (-dist).exp(), // dist is already squared
            Kernel::Exponential => (-dist.max(0.0).sqrt()).exp(),
            Kernel::RationalQuadratic => 1.0 / (1.0 + dist),
        }
    }

    fn query_rec(&self, id: usize, y: &[f32], budget_per_point: f64) -> f64 {
        let node = &self.nodes[id];
        // Live count, not range length: dead mass is skipped and inserted
        // (spill) mass counted, so the certified interval brackets the
        // true live sum.
        let size = self.live_count[id] as f64;
        if size == 0.0 {
            return 0.0;
        }
        let (dmin, dmax) = self.box_dists(node, y);
        let hi = self.kernel_of_dist(dmin);
        let lo = self.kernel_of_dist(dmax);
        if hi - lo <= 2.0 * budget_per_point {
            return size * 0.5 * (hi + lo);
        }
        match (node.left, node.right) {
            (Some(l), Some(r)) => {
                self.query_rec(l, y, budget_per_point) + self.query_rec(r, y, budget_per_point)
            }
            _ => {
                // Exact leaf evaluation over live residents + live spill.
                self.evals.fetch_add(
                    self.live_count[id] as u64,
                    std::sync::atomic::Ordering::Relaxed,
                );
                self.perm[node.lo..node.hi]
                    .iter()
                    .chain(self.spill[id].iter())
                    .filter(|&&i| !self.dead[i])
                    .map(|&i| self.kernel.eval(self.ds.point(i), y) as f64)
                    .sum()
            }
        }
    }

    /// Exact leaf kernel evaluations spent so far.
    pub fn kernel_evals(&self) -> u64 {
        self.evals.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Ranges of at most this size are evaluated exactly.
    pub fn leaf_size(&self) -> usize {
        self.leaf_size
    }
}

impl Kde for PartitionTreeKde {
    fn query(&self, y: &[f32]) -> f64 {
        self.counters.record_query();
        if self.live_count[0] == 0 {
            return 0.0;
        }
        if self.eps <= 0.0 {
            return self.query_rec(0, y, 0.0);
        }
        // Two-pass adaptive budget: the per-point error budget must scale
        // with the *true* mean kernel value (eps * Z / |X|), which is
        // unknown upfront. Pass 1 uses a crude root-bound budget to get a
        // first estimate Z1; pass 2 re-runs with the properly calibrated
        // budget eps * Z1 / (2 |X|), making the total error certified
        // <= ~eps * Z. |X| is the current live count.
        let root = &self.nodes[0];
        let (dmin, dmax) = self.box_dists(root, y);
        let crude = 0.5 * (self.kernel_of_dist(dmin) + self.kernel_of_dist(dmax));
        let z1 = self.query_rec(0, y, self.eps * crude.max(1e-12));
        let budget = self.eps * (z1 / self.live_count[0] as f64).max(1e-12) * 0.5;
        self.query_rec(0, y, budget)
    }

    /// Native batch: each query's adaptive pruning budget depends on its
    /// own two-pass calibration, so the batch is a per-query loop (the
    /// structure is already `Sync`; there is no backend dispatch to fuse).
    fn query_batch(&self, ys: &[f32]) -> Vec<f64> {
        let d = self.ds.d;
        assert!(ys.len() % d == 0);
        ys.chunks_exact(d).map(|y| self.query(y)).collect()
    }

    fn subset_len(&self) -> usize {
        self.live_count[0]
    }

    fn dim(&self) -> usize {
        self.ds.d
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::kernel::dataset::gaussian_mixture;
    use crate::util::rng::Rng;

    fn exact(ds: &Dataset, k: Kernel, y: &[f32]) -> f64 {
        (0..ds.n).map(|j| k.eval(ds.point(j), y) as f64).sum()
    }

    #[test]
    fn ptree_matches_exact_within_eps() {
        let mut rng = Rng::new(1301);
        let ds = Arc::new(gaussian_mixture(512, 6, 4, 1.5, 0.5, &mut rng));
        for k in crate::kernel::ALL_KERNELS {
            let tree = PartitionTreeKde::new(
                ds.clone(),
                k,
                0,
                512,
                0.05,
                KdeCounters::new(),
            );
            let mut worst: f64 = 0.0;
            for q in (0..512).step_by(37) {
                let got = tree.query(ds.point(q));
                let want = exact(&ds, k, ds.point(q));
                worst = worst.max((got - want).abs() / want);
            }
            assert!(worst < 0.15, "{:?} ptree worst rel err {worst}", k);
        }
    }

    #[test]
    fn ptree_prunes_far_mass() {
        // Two far-apart blobs: querying inside one should not evaluate the
        // other blob's points exactly.
        let mut rng = Rng::new(1303);
        let ds = Arc::new(gaussian_mixture(1024, 4, 2, 25.0, 0.3, &mut rng));
        let tree = PartitionTreeKde::new(
            ds.clone(),
            Kernel::Gaussian,
            0,
            1024,
            0.1,
            KdeCounters::new(),
        );
        let _ = tree.query(ds.point(0));
        let evals = tree.kernel_evals();
        // Two certified passes over an unprunable own-blob (512 points)
        // cost <= 1024; the far blob (512 more points per pass) must have
        // been pruned away.
        assert!(
            evals <= 1100,
            "pruning ineffective: {evals} exact evals for n = 1024 (2048 = no pruning)"
        );
    }

    #[test]
    fn ptree_zero_eps_is_exact() {
        let mut rng = Rng::new(1305);
        let ds = Arc::new(gaussian_mixture(256, 4, 2, 1.0, 0.5, &mut rng));
        let tree = PartitionTreeKde::new(
            ds.clone(),
            Kernel::Laplacian,
            0,
            256,
            0.0,
            KdeCounters::new(),
        );
        for q in [0usize, 99, 255] {
            let got = tree.query(ds.point(q));
            let want = exact(&ds, Kernel::Laplacian, ds.point(q));
            assert!(
                (got - want).abs() < 1e-9 * (1.0 + want),
                "eps=0 must be exact: {got} vs {want}"
            );
        }
    }

    #[test]
    fn dynamic_edits_match_exact_live_sum() {
        let mut rng = Rng::new(1309);
        // Build over the first 512 slots; the remaining 88 are staged in
        // the dataset and attached afterwards through insert_point.
        let ds = Arc::new(gaussian_mixture(600, 4, 2, 1.5, 0.5, &mut rng));
        let mut tree = PartitionTreeKde::new(
            ds.clone(),
            Kernel::Gaussian,
            0,
            512,
            0.05,
            KdeCounters::new(),
        );
        for i in 512..600 {
            assert!(tree.insert_point(i), "attach staged slot {i}");
        }
        for i in (0..600).step_by(7) {
            assert!(tree.delete_point(i), "delete {i}");
        }
        let live: Vec<usize> = (0..600).filter(|i| i % 7 != 0).collect();
        assert_eq!(tree.live_len(), live.len());
        assert_eq!(tree.subset_len(), live.len());
        let mut worst: f64 = 0.0;
        for &q in &[1usize, 52, 299, 599] {
            let got = tree.query(ds.point(q));
            let want: f64 = live
                .iter()
                .map(|&j| Kernel::Gaussian.eval(ds.point(j), ds.point(q)) as f64)
                .sum();
            worst = worst.max((got - want).abs() / want);
        }
        assert!(worst < 0.15, "dynamic ptree worst rel err {worst}");
        // Touched-node contract: each edit walks one root-to-leaf path.
        let (edits, touched) = tree.edit_stats();
        assert_eq!(edits, 88 + 86);
        let height = (512f64 / 16.0).log2().ceil() as u64 + 2; // splits + root/leaf
        assert!(
            touched <= edits * height,
            "touched {touched} > O(log n) bound {}",
            edits * height
        );
    }

    #[test]
    fn dynamic_delete_then_revive_is_idempotent() {
        let mut rng = Rng::new(1311);
        let ds = Arc::new(gaussian_mixture(128, 3, 2, 1.0, 0.5, &mut rng));
        let mut tree = PartitionTreeKde::new(
            ds.clone(),
            Kernel::Laplacian,
            0,
            128,
            0.0,
            KdeCounters::new(),
        );
        let before = tree.query(ds.point(5));
        assert!(tree.delete_point(9));
        assert!(!tree.delete_point(9), "double delete is a no-op");
        assert!(tree.insert_point(9), "revive in place");
        assert!(!tree.insert_point(9), "already live");
        assert_eq!(tree.live_len(), 128);
        let after = tree.query(ds.point(5));
        assert!(
            (before - after).abs() < 1e-9 * (1.0 + before),
            "revive must restore the exact answer: {before} vs {after}"
        );
        // Deleting everything yields exactly zero mass.
        for i in 0..128 {
            tree.delete_point(i);
        }
        assert_eq!(tree.live_len(), 0);
        assert_eq!(tree.query(ds.point(5)), 0.0);
    }

    #[test]
    fn ptree_respects_subranges() {
        let mut rng = Rng::new(1307);
        let ds = Arc::new(gaussian_mixture(128, 4, 2, 1.0, 0.5, &mut rng));
        let tree = PartitionTreeKde::new(
            ds.clone(),
            Kernel::Laplacian,
            32,
            96,
            0.02,
            KdeCounters::new(),
        );
        assert_eq!(tree.subset_len(), 64);
        let y = ds.point(5);
        let got = tree.query(y);
        let want: f64 = (32..96)
            .map(|j| Kernel::Laplacian.eval(ds.point(j), y) as f64)
            .sum();
        assert!((got - want).abs() < 0.1 * want, "{got} vs {want}");
    }
}
