//! `kdem` — CLI launcher for the kernel-matrix algorithm suite.
//!
//! Every subcommand runs one of the paper's algorithms on a synthetic
//! workload with explicit cost accounting, so the paper's tables can be
//! regenerated from the shell. `kdem reproduce <experiment>` drives the
//! per-figure harnesses (see EXPERIMENTS.md).

use std::collections::HashMap;
use std::sync::Arc;

use kde_matrix::apps;
use kde_matrix::graph::WGraph;
use kde_matrix::kde::{EstimatorKind, KdeConfig};
use kde_matrix::kernel::{dataset, Kernel};
use kde_matrix::runtime::backend::{CpuBackend, KernelBackend};
use kde_matrix::runtime::pjrt::PjrtBackend;
use kde_matrix::runtime::simd::SimdMode;
use kde_matrix::runtime::tiled::TiledBackend;
use kde_matrix::sampling::Primitives;
use kde_matrix::util::rng::Rng;

/// Minimal flag parser: `--key value` pairs after the subcommand.
struct Args {
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Self {
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            if let Some(key) = argv[i].strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                i += 1;
            }
        }
        Args { flags }
    }

    fn usize(&self, key: &str, default: usize) -> usize {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    fn f64(&self, key: &str, default: f64) -> f64 {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn bool(&self, key: &str) -> bool {
        self.bool_or(key, false)
    }

    /// Boolean flag with an explicit default: absent -> `default`,
    /// `--key` / `--key true` -> true, `--key false` -> false. Used by
    /// the default-on `--batched` flags so `--batched false` selects the
    /// sequential path.
    fn bool_or(&self, key: &str, default: bool) -> bool {
        self.flags.get(key).map(|v| v == "true").unwrap_or(default)
    }
}

/// `--simd {auto,avx2,neon,scalar}` — explicit microkernel ISA for the
/// tiled backend (A/B benchmarking). An unsupported explicit request is a
/// hard error rather than a silent fallback, so measurements mean what
/// they claim.
fn simd_mode_from_args(a: &Args) -> SimdMode {
    let name = a.str("simd", "auto");
    match SimdMode::from_name(&name) {
        Some(mode) => mode,
        None => {
            eprintln!("unknown --simd mode `{name}` (expected auto|avx2|neon|scalar)");
            std::process::exit(2);
        }
    }
}

fn tiled_backend(threads: usize, mode: SimdMode) -> Arc<dyn KernelBackend> {
    match TiledBackend::with_simd(threads, mode) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("--simd {}: {e}", mode.name());
            std::process::exit(2);
        }
    }
}

/// A non-default `--simd` on a backend that has no microkernel vtable
/// would silently measure the wrong thing; keep the no-silent-fallback
/// contract by refusing it.
fn reject_explicit_simd(a: &Args, mode: SimdMode, backend: &str) {
    if mode != SimdMode::Auto && a.flags.contains_key("simd") {
        eprintln!(
            "--simd {} only applies to the tiled backend (got --backend {backend})",
            mode.name()
        );
        std::process::exit(2);
    }
}

fn backend_from_args(a: &Args) -> Arc<dyn KernelBackend> {
    let mode = simd_mode_from_args(a);
    match a.str("backend", "tiled").as_str() {
        "pjrt" => {
            let dir = a.str("artifacts", "artifacts");
            match PjrtBackend::new(dir) {
                Ok(b) => {
                    reject_explicit_simd(a, mode, "pjrt");
                    b
                }
                Err(e) => {
                    eprintln!("PJRT backend unavailable ({e}); falling back to tiled CPU");
                    tiled_backend(TiledBackend::default_threads(), mode)
                }
            }
        }
        "cpu" | "scalar" => {
            reject_explicit_simd(a, mode, "cpu");
            CpuBackend::new()
        }
        "tiled1" => tiled_backend(1, mode),
        _ => tiled_backend(TiledBackend::default_threads(), mode),
    }
}

fn config_from_args(a: &Args) -> KdeConfig {
    let kind = match a.str("estimator", "sampling").as_str() {
        "naive" | "exact" => EstimatorKind::Naive,
        "hbe" => EstimatorKind::Hbe {
            tables: a.usize("hbe-tables", 32),
            width: a.f64("hbe-width", 4.0) as f32,
        },
        _ => EstimatorKind::Sampling {
            eps: a.f64("eps", 0.25),
            tau: a.f64("tau", 0.05),
        },
    };
    KdeConfig {
        kind,
        leaf_cutoff: a.usize("leaf-cutoff", 16),
        seed: a.usize("seed", 0x5EED) as u64,
    }
}

fn make_dataset(a: &Args, rng: &mut Rng) -> Arc<kde_matrix::kernel::Dataset> {
    let n = a.usize("n", 1024);
    let d = a.usize("d", 16);
    let kernel = Kernel::from_name(&a.str("kernel", "laplacian")).expect("unknown kernel");
    let ds = match a.str("data", "mixture").as_str() {
        "nested" => dataset::nested(n, rng).scaled(3.0),
        "rings" => dataset::rings(n, rng).scaled(6.0),
        "heavy" => dataset::heavy_tailed_mixture(n, d, a.usize("clusters", 10), rng)
            .with_median_bandwidth(kernel, rng),
        "clusterable" => dataset::clusterable(n, d, a.usize("clusters", 3), rng),
        _ => dataset::gaussian_mixture(n, d, a.usize("clusters", 10), 2.0, 0.5, rng)
            .with_median_bandwidth(kernel, rng),
    };
    Arc::new(ds)
}

fn cmd_info() {
    println!("kdem — sub-quadratic kernel-matrix algorithms via KDE");
    println!("(Bakshi, Indyk, Kacham, Silwal, Zhou 2022; three-layer Rust+JAX+Pallas)");
    println!();
    println!("subcommands:");
    println!("  info                         this message");
    println!("  check-runtime                load artifacts, verify PJRT vs CPU parity");
    println!("  sparsify   --n --t [--batched]  spectral sparsification (Thm 5.3)");
    println!("  resparsify --n --t --t2      two-stage: Alg 5.1 + eff.-resistance stage (§5.1)");
    println!("  solve      --n --t           Laplacian solve on the sparsifier (§5.1.1)");
    println!("  lra        --n --rank        low-rank approximation (Cor 5.14)");
    println!("  eigen      --n --t           top eigenvalue (Thm 5.22)");
    println!("  spectrum   --n               EMD spectrum (Thm 5.17)");
    println!("  cluster    --data nested     spectral clustering on sparsifier (§6.2)");
    println!("  local      --n               local clustering (Thm 6.9)");
    println!("  arboricity --n --m [--batched false]  arboricity estimation (Thm 6.15;");
    println!("                               frontier-batched edge draws by default)");
    println!("  triangles  --n [--batched false]      weighted triangle total (Thm 6.17;");
    println!("                               frontier-batched descents by default)");
    println!();
    println!("common flags: --kernel laplacian|gaussian|exponential|rational_quadratic");
    println!("              --estimator sampling|naive|hbe  --backend tiled|tiled1|cpu|pjrt");
    println!("              --simd auto|avx2|neon|scalar (tiled microkernel ISA override)");
    println!("              --n <points> --d <dims> --seed <u64>");
}

fn cmd_check_runtime(a: &Args) {
    let dir = a.str("artifacts", "artifacts");
    let pjrt = match PjrtBackend::new(&dir) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("FAIL: {e}");
            std::process::exit(1);
        }
    };
    let cpu = CpuBackend::new();
    let mut rng = Rng::new(7);
    let d = 8;
    let queries: Vec<f32> = (0..5 * d).map(|_| rng.normal() as f32).collect();
    let data: Vec<f32> = (0..300 * d).map(|_| rng.normal() as f32).collect();
    for k in kde_matrix::kernel::ALL_KERNELS {
        let a_s = pjrt.sums(k, &queries, &data, d);
        let b_s = cpu.sums(k, &queries, &data, d);
        let mut worst = 0.0f64;
        for (x, y) in a_s.iter().zip(&b_s) {
            worst = worst.max((x - y).abs() / (1.0 + y.abs()));
        }
        let verdict = if worst < 1e-4 { "OK" } else { "FAIL" };
        println!("{:<22} parity rel-err {:.2e}  {}", k.name(), worst, verdict);
        if worst >= 1e-4 {
            std::process::exit(1);
        }
    }
    println!("runtime OK ({} PJRT executions)", pjrt.executions());
}

fn cmd_sparsify(a: &Args) {
    let mut rng = Rng::new(a.usize("seed", 1) as u64);
    let ds = make_dataset(a, &mut rng);
    let kernel = Kernel::from_name(&a.str("kernel", "laplacian")).unwrap();
    let prims = Primitives::build(ds.clone(), kernel, &config_from_args(a), backend_from_args(a));
    let t = a.usize("t", 20 * ds.n);
    let r = if a.bool("batched") {
        apps::sparsify::sparsify_batched(&prims, t, &mut rng)
    } else {
        apps::sparsify::sparsify(&prims, t, &mut rng)
    };
    let complete_edges = ds.n * (ds.n - 1) / 2;
    println!(
        "n={} samples={} distinct_edges={} reduction={:.1}x kde_queries={} kernel_evals={}",
        ds.n,
        r.samples,
        r.distinct_edges,
        complete_edges as f64 / r.distinct_edges as f64,
        r.kde_queries,
        r.kernel_evals
    );
    if a.bool("check") {
        let err = apps::sparsify::spectral_error(&ds, kernel, &r.graph, 30, &mut rng);
        println!("spectral_error={err:.4}");
    }
}

fn cmd_resparsify(a: &Args) {
    let mut rng = Rng::new(a.usize("seed", 1) as u64);
    let ds = make_dataset(a, &mut rng);
    let kernel = Kernel::from_name(&a.str("kernel", "laplacian")).unwrap();
    let prims = Primitives::build(ds.clone(), kernel, &config_from_args(a), backend_from_args(a));
    let t = a.usize("t", 20 * ds.n);
    let stage1 = apps::sparsify::sparsify(&prims, t, &mut rng);
    let t2 = a.usize("t2", 4 * ds.n);
    let stage2 = apps::resparsify::resparsify(&stage1.graph, t2, a.usize("jl", 24), &mut rng);
    println!(
        "n={} stage1_edges={} stage2_edges={} total_reduction={:.1}x",
        ds.n,
        stage1.distinct_edges,
        stage2.num_edges(),
        (ds.n * (ds.n - 1) / 2) as f64 / stage2.num_edges().max(1) as f64
    );
    if a.bool("check") {
        let err1 = apps::sparsify::spectral_error(&ds, kernel, &stage1.graph, 20, &mut rng);
        let err2 = apps::sparsify::spectral_error(&ds, kernel, &stage2, 20, &mut rng);
        println!("spectral_error stage1={err1:.4} stage2={err2:.4}");
    }
}

fn cmd_solve(a: &Args) {
    let mut rng = Rng::new(a.usize("seed", 1) as u64);
    let ds = make_dataset(a, &mut rng);
    let kernel = Kernel::from_name(&a.str("kernel", "laplacian")).unwrap();
    let prims = Primitives::build(ds.clone(), kernel, &config_from_args(a), backend_from_args(a));
    let t = a.usize("t", 20 * ds.n);
    let sp = apps::sparsify::sparsify(&prims, t, &mut rng);
    let mut b: Vec<f64> = (0..ds.n).map(|_| rng.normal()).collect();
    let m = b.iter().sum::<f64>() / ds.n as f64;
    for v in b.iter_mut() {
        *v -= m;
    }
    let res = apps::solver::solve_laplacian(&sp.graph, &b, 1e-8, 5_000);
    println!(
        "n={} sparsifier_edges={} cg_iters={} residual={:.2e} converged={}",
        ds.n, sp.distinct_edges, res.iters, res.residual, res.converged
    );
}

fn cmd_lra(a: &Args) {
    let mut rng = Rng::new(a.usize("seed", 1) as u64);
    let ds = make_dataset(a, &mut rng);
    let kernel = Kernel::from_name(&a.str("kernel", "laplacian")).unwrap();
    let rank = a.usize("rank", 10);
    let r = apps::lra::lra_kde(
        &ds,
        kernel,
        rank,
        a.usize("rows-factor", 25),
        &config_from_args(a),
        backend_from_args(a),
        &mut rng,
    );
    println!(
        "n={} rank_requested={} rank_achieved={} sampled_rows={} peak_block_rows={} \
         kde_queries={} kernel_evals={} floats_stored={}",
        ds.n,
        rank,
        r.rank,
        r.sampled_rows,
        r.peak_block_rows,
        r.kde_queries,
        r.kernel_evals,
        r.floats_stored
    );
    if a.bool("check") {
        let kmat = apps::lra::materialize_kernel_matrix(&ds, kernel);
        let err = apps::lra::lra_error(&kmat, &r.v);
        println!(
            "frob_err={:.4e} rel={:.4}",
            err.sqrt(),
            (err / kmat.frob_norm_sq()).sqrt()
        );
    }
}

fn cmd_eigen(a: &Args) {
    let mut rng = Rng::new(a.usize("seed", 1) as u64);
    let ds = make_dataset(a, &mut rng);
    let kernel = Kernel::from_name(&a.str("kernel", "laplacian")).unwrap();
    let t = a.usize("t", 256);
    let r = apps::eigen_top::eigen_top_direct(&ds, kernel, t, 300, &mut rng);
    println!("n={} t={} lambda_est={:.4}", ds.n, r.submatrix_size, r.lambda);
    if a.bool("check") {
        let exact = apps::eigen_top::exact_top_eigenvalue(&ds, kernel, &mut rng);
        println!(
            "lambda_exact={:.4} rel_err={:.4}",
            exact,
            (r.lambda - exact).abs() / exact
        );
    }
}

fn cmd_spectrum(a: &Args) {
    let mut rng = Rng::new(a.usize("seed", 1) as u64);
    let ds = make_dataset(a, &mut rng);
    let kernel = Kernel::from_name(&a.str("kernel", "laplacian")).unwrap();
    let prims = Primitives::build(ds.clone(), kernel, &config_from_args(a), backend_from_args(a));
    let params = apps::spectrum::SpectrumParams {
        vertices: a.usize("vertices", 24),
        reps: a.usize("reps", 200),
        ..Default::default()
    };
    let r = apps::spectrum::approximate_spectrum(&prims, &params, &mut rng);
    println!(
        "n={} walks={} kde_queries={} moments={:?}",
        ds.n,
        r.walks,
        r.kde_queries,
        r.moments.iter().map(|m| (m * 1e4).round() / 1e4).collect::<Vec<_>>()
    );
    if a.bool("check") {
        let exact = apps::spectrum::exact_spectrum(&ds, kernel);
        let emd = kde_matrix::util::stats::emd_1d(&r.eigenvalues, &exact);
        println!("emd={emd:.4}");
    }
}

fn cmd_cluster(a: &Args) {
    let mut rng = Rng::new(a.usize("seed", 1) as u64);
    let ds = make_dataset(a, &mut rng);
    let kernel = Kernel::from_name(&a.str("kernel", "gaussian")).unwrap();
    let prims = Primitives::build(ds.clone(), kernel, &config_from_args(a), backend_from_args(a));
    let t = a.usize("t", 40 * ds.n);
    let sp = apps::sparsify::sparsify(&prims, t, &mut rng);
    let k = a.usize("k", 2);
    let labels = apps::cluster_spectral::spectral_cluster(&sp.graph, k, &mut rng);
    if let Some(truth) = &ds.labels {
        let acc = apps::cluster_spectral::clustering_accuracy(&labels, truth, k);
        println!(
            "n={} sparsifier_edges={} accuracy={:.4} kde_queries={}",
            ds.n, sp.distinct_edges, acc, sp.kde_queries
        );
    } else {
        println!("n={} sparsifier_edges={} (no ground-truth labels)", ds.n, sp.distinct_edges);
    }
}

fn cmd_local(a: &Args) {
    let mut rng = Rng::new(a.usize("seed", 1) as u64);
    let n = a.usize("n", 256);
    let ds = Arc::new(dataset::clusterable(n, a.usize("d", 8), a.usize("clusters", 3), &mut rng));
    let kernel = Kernel::from_name(&a.str("kernel", "laplacian")).unwrap();
    let prims = Primitives::build(ds.clone(), kernel, &config_from_args(a), backend_from_args(a));
    let params = apps::cluster_local::LocalClusterParams::for_n(n);
    let labels = ds.labels.as_ref().unwrap();
    let trials = a.usize("trials", 20);
    let mut correct = 0;
    for _ in 0..trials {
        let u = rng.below(n);
        let mut w = rng.below(n);
        while w == u {
            w = rng.below(n);
        }
        let out = apps::cluster_local::same_cluster(&prims, u, w, &params, &mut rng);
        if out.same_cluster == (labels[u] == labels[w]) {
            correct += 1;
        }
    }
    println!(
        "n={} trials={} correct={} walk_len={} samples_per_dist={}",
        n, trials, correct, params.walk_len, params.samples
    );
}

fn cmd_arboricity(a: &Args) {
    let mut rng = Rng::new(a.usize("seed", 1) as u64);
    let ds = make_dataset(a, &mut rng);
    let kernel = Kernel::from_name(&a.str("kernel", "laplacian")).unwrap();
    let prims = Primitives::build(ds.clone(), kernel, &config_from_args(a), backend_from_args(a));
    let m = a.usize("m", 20 * ds.n);
    let batched = a.bool_or("batched", true);
    let r = if batched {
        apps::arboricity::arboricity_estimate_batched(&prims, m, !a.bool("greedy"), &mut rng)
    } else {
        apps::arboricity::arboricity_estimate(&prims, m, !a.bool("greedy"), &mut rng)
    };
    println!(
        "n={} m={} batched={} density_est={:.4} sample_edges={} kde_queries={}",
        ds.n, m, batched, r.density, r.subsampled_graph_edges, r.kde_queries
    );
    if a.bool("check") {
        let g = WGraph::complete_kernel_graph(&ds, kernel);
        let exact = apps::arboricity::arboricity_exact(&g);
        println!(
            "density_exact={:.4} rel_err={:.4}",
            exact,
            (r.density - exact).abs() / exact
        );
    }
}

fn cmd_triangles(a: &Args) {
    let mut rng = Rng::new(a.usize("seed", 1) as u64);
    let ds = make_dataset(a, &mut rng);
    let kernel = Kernel::from_name(&a.str("kernel", "laplacian")).unwrap();
    let prims = Primitives::build(ds.clone(), kernel, &config_from_args(a), backend_from_args(a));
    let params = apps::triangles::TriangleParams {
        edge_pool: a.usize("pool", 512),
        reps: a.usize("reps", 32),
    };
    let batched = a.bool_or("batched", true);
    let r = if batched {
        apps::triangles::triangle_weight_estimate_batched(&prims, &params, &mut rng)
    } else {
        apps::triangles::triangle_weight_estimate(&prims, &params, &mut rng)
    };
    println!(
        "n={} batched={} estimate={:.4e} kde_queries={} kernel_evals={}",
        ds.n, batched, r.estimate, r.kde_queries, r.kernel_evals
    );
    if a.bool("check") {
        let g = WGraph::complete_kernel_graph(&ds, kernel);
        let exact = g.exact_triangle_weight();
        println!(
            "exact={:.4e} rel_err={:.4}",
            exact,
            (r.estimate - exact).abs() / exact
        );
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(|s| s.as_str()).unwrap_or("info");
    let args = Args::parse(&argv[argv.len().min(1)..]);
    match cmd {
        "info" | "--help" | "-h" => cmd_info(),
        "check-runtime" => cmd_check_runtime(&args),
        "sparsify" => cmd_sparsify(&args),
        "resparsify" => cmd_resparsify(&args),
        "solve" => cmd_solve(&args),
        "lra" => cmd_lra(&args),
        "eigen" => cmd_eigen(&args),
        "spectrum" => cmd_spectrum(&args),
        "cluster" => cmd_cluster(&args),
        "local" => cmd_local(&args),
        "arboricity" => cmd_arboricity(&args),
        "triangles" => cmd_triangles(&args),
        other => {
            eprintln!("unknown subcommand: {other}");
            cmd_info();
            std::process::exit(2);
        }
    }
}
