//! Kernel functions and datasets.
//!
//! The four kernels of Table 1, the bandwidth "median rule" (§3.1), and the
//! synthetic dataset generators used across the experiments (§7:
//! Nested / Rings, plus the MNIST/GloVe substitutes documented in
//! DESIGN.md §3).
//!
//! Convention: datasets are stored *pre-scaled* by `1/sigma`, so every
//! kernel evaluation is bandwidth-free — this matches the AOT artifacts,
//! which bake no bandwidth.

pub mod dataset;

pub use dataset::Dataset;

/// Kernel families from Table 1 of the paper. All values lie in (0, 1]
/// and `k(x, x) = 1`.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub enum Kernel {
    /// `exp(-||x-y||_1)`
    Laplacian,
    /// `exp(-||x-y||_2^2)`
    Gaussian,
    /// `exp(-||x-y||_2)`
    Exponential,
    /// `1 / (1 + ||x-y||_2^2)` (beta = 1)
    RationalQuadratic,
}

pub const ALL_KERNELS: [Kernel; 4] = [
    Kernel::Laplacian,
    Kernel::Gaussian,
    Kernel::Exponential,
    Kernel::RationalQuadratic,
];

impl Kernel {
    /// Artifact / manifest name.
    pub fn name(self) -> &'static str {
        match self {
            Kernel::Laplacian => "laplacian",
            Kernel::Gaussian => "gaussian",
            Kernel::Exponential => "exponential",
            Kernel::RationalQuadratic => "rational_quadratic",
        }
    }

    pub fn from_name(s: &str) -> Option<Kernel> {
        Some(match s {
            "laplacian" => Kernel::Laplacian,
            "gaussian" => Kernel::Gaussian,
            "exponential" => Kernel::Exponential,
            "rational_quadratic" | "rq" => Kernel::RationalQuadratic,
            _ => return None,
        })
    }

    /// Evaluate `k(x, y)` on pre-scaled coordinates.
    ///
    /// The distance loops are the crate's hottest code (every KDE query is
    /// a string of these); they use 8-lane manual accumulators so LLVM
    /// autovectorizes them. (A scalar polynomial fast-exp was tried in the
    /// §Perf pass and REVERTED: its serial dependency chain is no cheaper
    /// than libm `expf` on this target — see EXPERIMENTS.md §Perf.)
    #[inline]
    pub fn eval(self, x: &[f32], y: &[f32]) -> f32 {
        debug_assert_eq!(x.len(), y.len());
        match self {
            Kernel::Laplacian => (-l1_dist(x, y)).exp(),
            Kernel::Gaussian => (-sq_dist(x, y)).exp(),
            Kernel::Exponential => (-sq_dist(x, y).max(0.0).sqrt()).exp(),
            Kernel::RationalQuadratic => 1.0 / (1.0 + sq_dist(x, y)),
        }
    }

    /// The constant `c` with `k(x,y)^2 = k(cx, cy)`, when it exists
    /// (§5.2 squared-row-norm trick). `None` for rational quadratic.
    ///
    /// Note: the paper states c = 4 for the Gaussian; the correct value is
    /// `sqrt(2)` since `exp(-||cx-cy||^2) = exp(-c^2 ||x-y||^2)` — verified
    /// by `squared_scaling_law` below and the pytest twin.
    pub fn square_scale(self) -> Option<f32> {
        match self {
            Kernel::Laplacian | Kernel::Exponential => Some(2.0),
            Kernel::Gaussian => Some(std::f32::consts::SQRT_2),
            Kernel::RationalQuadratic => None,
        }
    }

    /// KDE query-time exponent `p` from Table 1 (used for reporting only;
    /// the sampling estimator realizes p = 1, HBE realizes p ~ 0.5).
    pub fn table1_exponent(self) -> f64 {
        match self {
            Kernel::Gaussian => 0.173,
            Kernel::Exponential => 0.1,
            Kernel::Laplacian => 0.5,
            Kernel::RationalQuadratic => 0.0,
        }
    }
}

/// Coefficients of [`fast_exp_neg`]'s range reduction and polynomial,
/// shared with the lane-parallel SIMD evaluations in
/// [`crate::runtime::simd`]. Keeping a single source of truth means the
/// scalar and vector paths evaluate the *same* approximation, so they
/// agree to a few ULPs (FMA regrouping only) instead of carrying two
/// independent approximation errors — that is what makes the SIMD parity
/// contract in `tests/simd_parity.rs` tight enough to be useful.
pub mod fexp {
    /// `log2(e)` for the reduction `x = j*ln2 + f`.
    pub const LOG2E: f32 = std::f32::consts::LOG2_E;
    /// High part of `ln2` (hi/lo split for an accurate reduction).
    pub const LN2_HI: f32 = 0.693_145_75;
    /// Low part of `ln2`.
    pub const LN2_LO: f32 = 1.428_606_8e-6;
    /// Round-to-nearest magic constant, `1.5 * 2^23`. Adding and
    /// subtracting it rounds to integer without a libm `round()` call and
    /// lowers to plain adds in both scalar and vector code.
    pub const MAGIC: f32 = 12_582_912.0;
    /// Inputs below this hard-underflow to exactly 0 (`e^-87` is already
    /// within a few ULPs of the smallest normal f32).
    pub const UNDERFLOW: f32 = -87.0;
    /// Degree-5 polynomial for `e^f` on `|f| <= ln2/2`:
    /// `1 + f*(1 + f*(C2 + f*(C3 + f*(C4 + f*C5))))`.
    pub const C2: f32 = 0.5;
    pub const C3: f32 = 0.166_666_67;
    pub const C4: f32 = 0.041_666_67;
    pub const C5: f32 = 0.008_333_76;
}

/// Fast `e^x` for `x <= 0` via range reduction `e^x = 2^j * e^f` with a
/// degree-5 polynomial on `|f| <= ln2/2` (coefficients in [`fexp`]).
/// Relative error < 5e-6 (worst near the underflow edge; verified by
/// `fast_exp_matches_std`).
///
/// Not worth calling one-at-a-time: the §Perf pass measured a *single*
/// evaluation no faster than libm `expf` (the serial polynomial chain
/// dominates) and it was reverted from `Kernel::eval`. It pays when many
/// independent evaluations are in flight: the tiled backend maps it over
/// a whole distance tile, and `runtime::simd` evaluates the same
/// polynomial on 8/4 lanes at once (EXPERIMENTS.md §Perf).
#[inline]
pub fn fast_exp_neg(x: f32) -> f32 {
    debug_assert!(x <= 1e-6, "fast_exp_neg expects non-positive input");
    if x < fexp::UNDERFLOW {
        return 0.0;
    }
    let j = (x * fexp::LOG2E + fexp::MAGIC) - fexp::MAGIC;
    let f = (x - j * fexp::LN2_HI) - j * fexp::LN2_LO;
    let p = 1.0
        + f * (1.0
            + f * (fexp::C2 + f * (fexp::C3 + f * (fexp::C4 + f * fexp::C5))));
    let scale = f32::from_bits((((j as i32) + 127) << 23) as u32);
    scale * p
}

const LANES: usize = 8;

/// 8-lane L1 distance: independent partial sums let LLVM emit SIMD adds
/// (a single scalar accumulator forces strict FP ordering and defeats
/// vectorization).
#[inline]
fn l1_dist(x: &[f32], y: &[f32]) -> f32 {
    let mut acc = [0.0f32; LANES];
    let mut xc = x.chunks_exact(LANES);
    let mut yc = y.chunks_exact(LANES);
    for (xa, ya) in (&mut xc).zip(&mut yc) {
        for l in 0..LANES {
            acc[l] += (xa[l] - ya[l]).abs();
        }
    }
    let mut s: f32 = acc.iter().sum();
    for (a, b) in xc.remainder().iter().zip(yc.remainder()) {
        s += (a - b).abs();
    }
    s
}

/// 8-lane squared L2 distance (see `l1_dist`).
#[inline]
fn sq_dist(x: &[f32], y: &[f32]) -> f32 {
    let mut acc = [0.0f32; LANES];
    let mut xc = x.chunks_exact(LANES);
    let mut yc = y.chunks_exact(LANES);
    for (xa, ya) in (&mut xc).zip(&mut yc) {
        for l in 0..LANES {
            let d = xa[l] - ya[l];
            acc[l] += d * d;
        }
    }
    let mut s: f32 = acc.iter().sum();
    for (a, b) in xc.remainder().iter().zip(yc.remainder()) {
        let d = a - b;
        s += d * d;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn rand_point(rng: &mut Rng, d: usize, scale: f64) -> Vec<f32> {
        (0..d).map(|_| (rng.normal() * scale) as f32).collect()
    }

    #[test]
    fn self_kernel_is_one() {
        let mut rng = Rng::new(3);
        for k in ALL_KERNELS {
            let x = rand_point(&mut rng, 8, 1.0);
            assert!((k.eval(&x, &x) - 1.0).abs() < 1e-6, "{:?}", k);
        }
    }

    #[test]
    fn kernels_symmetric_and_unit_interval() {
        forall(32, |rng, _| {
            let d = 1 + rng.below(16);
            let x = rand_point(rng, d, 2.0);
            let y = rand_point(rng, d, 2.0);
            for k in ALL_KERNELS {
                let a = k.eval(&x, &y);
                let b = k.eval(&y, &x);
                assert!((a - b).abs() < 1e-6, "{:?} not symmetric", k);
                // Values are mathematically in (0, 1] but may underflow to
                // +0.0 in f32 at large distances — allow that.
                assert!((0.0..=1.0 + 1e-6).contains(&a), "{:?} out of [0,1]: {a}", k);
            }
        });
    }

    #[test]
    fn kernels_decrease_with_distance() {
        let x = [0.0f32; 4];
        let near = [0.1f32; 4];
        let far = [1.0f32; 4];
        for k in ALL_KERNELS {
            assert!(k.eval(&x, &near) > k.eval(&x, &far), "{:?}", k);
        }
    }

    #[test]
    fn squared_scaling_law() {
        forall(32, |rng, _| {
            let d = 1 + rng.below(8);
            let x = rand_point(rng, d, 1.0);
            let y = rand_point(rng, d, 1.0);
            for k in ALL_KERNELS {
                if let Some(c) = k.square_scale() {
                    let xs: Vec<f32> = x.iter().map(|v| v * c).collect();
                    let ys: Vec<f32> = y.iter().map(|v| v * c).collect();
                    let lhs = k.eval(&x, &y).powi(2);
                    let rhs = k.eval(&xs, &ys);
                    assert!(
                        (lhs - rhs).abs() < 1e-4 * lhs.max(1e-6),
                        "{:?}: {lhs} vs {rhs}",
                        k
                    );
                }
            }
        });
    }

    #[test]
    fn fast_exp_matches_std() {
        // Sweep the whole useful range; require < 1e-6 relative error.
        let mut x = -87.0f32;
        while x < 0.0 {
            let got = fast_exp_neg(x);
            let want = x.exp();
            let rel = (got - want).abs() / want.max(f32::MIN_POSITIVE);
            assert!(rel < 5e-6, "x={x}: fast {got} vs std {want} (rel {rel})");
            x += 0.0137;
        }
        assert_eq!(fast_exp_neg(-100.0), 0.0, "underflow clamps to 0");
        assert!((fast_exp_neg(0.0) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn known_values() {
        let x = [0.0f32, 0.0];
        let y = [1.0f32, 0.0];
        assert!((Kernel::Laplacian.eval(&x, &y) - (-1.0f32).exp()).abs() < 1e-6);
        assert!((Kernel::Gaussian.eval(&x, &y) - (-1.0f32).exp()).abs() < 1e-6);
        assert!((Kernel::Exponential.eval(&x, &y) - (-1.0f32).exp()).abs() < 1e-6);
        assert!((Kernel::RationalQuadratic.eval(&x, &y) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn name_round_trip() {
        for k in ALL_KERNELS {
            assert_eq!(Kernel::from_name(k.name()), Some(k));
        }
        assert_eq!(Kernel::from_name("nope"), None);
    }
}
