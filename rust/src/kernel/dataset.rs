//! Row-major f32 datasets + synthetic generators for the paper's
//! experiments.
//!
//! Generators:
//! * `gaussian_mixture`  — MNIST substitute (well-clustered, fast spectral
//!   decay; DESIGN.md §3).
//! * `heavy_tailed_mixture` — GloVe substitute (spread row norms).
//! * `nested`            — §7 "Nested": points at the origin + a circle.
//! * `rings`             — §7 "Rings": two interlocked tori in 3-D.
//! * `clusterable`       — k well-separated blobs for local clustering
//!   (Definition 6.4-style instances).

use crate::kernel::Kernel;
use crate::util::rng::Rng;

/// A dataset of `n` points in `R^d`, stored row-major, already scaled by
/// `1/sigma` (bandwidth folded into the coordinates).
///
/// Storage is mutable: rows can be appended ([`Dataset::push_row`] /
/// [`Dataset::insert`]) and tombstone-deleted ([`Dataset::delete`]) in
/// place, with [`Dataset::compact`] reclaiming dead rows. The f32-rows /
/// f64-accumulation contract is unchanged: mutation only rewrites rows,
/// never the scan layout, so every backend path keeps streaming the same
/// contiguous `n x d` buffer.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub n: usize,
    pub d: usize,
    data: Vec<f32>,
    /// Optional ground-truth labels (for clustering experiments).
    pub labels: Option<Vec<usize>>,
    /// Tombstone flags, one per slot (`true` = dead).
    dead: Vec<bool>,
    /// Dead slots available for reuse (LIFO).
    free: Vec<usize>,
    /// Number of `true` entries in `dead`.
    dead_count: usize,
}

impl Dataset {
    /// Every coordinate of a tombstoned row is overwritten with this
    /// far-sentinel value. All live points in this repo's workloads sit at
    /// O(10) coordinates, so a tombstone is at L1/L2 distance >= ~3e4 from
    /// any live point or query — far past the f32 exp underflow threshold —
    /// and the Laplacian / Gaussian / Exponential kernels evaluate to
    /// *exactly* +0.0 against it. Dead rows therefore contribute exactly
    /// zero mass to any backend scan that still covers their slot.
    ///
    /// The RationalQuadratic kernel (`1/(1+d^2)`) never underflows, so the
    /// dynamic layers that rely on this sentinel reject it up front.
    pub const TOMBSTONE_COORD: f32 = 3.0e4;

    /// Build from per-point rows. Panics if `rows` is empty or the rows
    /// have unequal lengths.
    pub fn from_rows(rows: Vec<Vec<f32>>) -> Self {
        assert!(!rows.is_empty());
        let d = rows[0].len();
        assert!(rows.iter().all(|r| r.len() == d));
        let n = rows.len();
        let mut data = Vec::with_capacity(n * d);
        for r in &rows {
            data.extend_from_slice(r);
        }
        Self::from_flat(n, d, data)
    }

    /// Build from a row-major flat buffer. Panics unless
    /// `data.len() == n * d`.
    pub fn from_flat(n: usize, d: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), n * d);
        Dataset {
            n,
            d,
            data,
            labels: None,
            dead: vec![false; n],
            free: Vec::new(),
            dead_count: 0,
        }
    }

    #[inline]
    pub fn point(&self, i: usize) -> &[f32] {
        &self.data[i * self.d..(i + 1) * self.d]
    }

    pub fn flat(&self) -> &[f32] {
        &self.data
    }

    /// Kernel evaluation between two stored points.
    #[inline]
    pub fn kernel(&self, k: Kernel, i: usize, j: usize) -> f32 {
        k.eval(self.point(i), self.point(j))
    }

    /// Weighted degree `sum_{j != i} k(x_i, x_j)` computed exactly (O(nd);
    /// baseline / test oracle).
    pub fn exact_degree(&self, k: Kernel, i: usize) -> f64 {
        let mut s = 0.0f64;
        for j in 0..self.n {
            if j != i {
                s += self.kernel(k, i, j) as f64;
            }
        }
        s
    }

    /// The minimum off-diagonal kernel value = the paper's `tau`
    /// (Parameterization 1.2). O(n^2 d) — experiment-setup helper.
    pub fn tau(&self, k: Kernel) -> f64 {
        let mut t = f64::INFINITY;
        for i in 0..self.n {
            for j in (i + 1)..self.n {
                t = t.min(self.kernel(k, i, j) as f64);
            }
        }
        t
    }

    /// Scale all coordinates by `c` (returns a new dataset). Used for the
    /// squared-kernel row-norm trick (§5.2) and for bandwidth folding.
    /// Defined on compacted datasets: the result is fully live (scaling a
    /// tombstone row would shrink the far sentinel).
    pub fn scaled(&self, c: f32) -> Dataset {
        let mut ds = Dataset::from_flat(
            self.n,
            self.d,
            self.data.iter().map(|v| v * c).collect(),
        );
        ds.labels = self.labels.clone();
        ds
    }

    /// Restrict to a subset of indices (Alg 5.18's principal submatrix).
    /// The result is fully live; pick live indices (or [`Dataset::compact`]
    /// first) when subsetting a mutated dataset.
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        let mut data = Vec::with_capacity(idx.len() * self.d);
        for &i in idx {
            data.extend_from_slice(self.point(i));
        }
        let mut ds = Dataset::from_flat(idx.len(), self.d, data);
        ds.labels = self
            .labels
            .as_ref()
            .map(|l| idx.iter().map(|&i| l[i]).collect());
        ds
    }

    // -- Mutable storage (append / tombstone-delete / compaction) ----------

    /// Whether slot `i` holds a live point (`false` once tombstoned).
    #[inline]
    pub fn live(&self, i: usize) -> bool {
        !self.dead[i]
    }

    /// Number of live (non-tombstoned) points; `n` counts slots.
    #[inline]
    pub fn live_len(&self) -> usize {
        self.n - self.dead_count
    }

    /// Append a new row at slot `n`, growing the buffer. Returns the new
    /// slot index. Ground-truth labels (a static-experiment artifact) are
    /// dropped on append since the new point has none.
    pub fn push_row(&mut self, row: &[f32]) -> usize {
        assert_eq!(row.len(), self.d);
        let slot = self.n;
        self.data.extend_from_slice(row);
        self.dead.push(false);
        self.n += 1;
        self.labels = None;
        slot
    }

    /// Tombstone-delete slot `i`: the row is overwritten with
    /// [`Dataset::TOMBSTONE_COORD`] so backend scans that still cover the
    /// slot see exactly zero kernel mass, and the slot is queued for reuse.
    /// Returns `false` (and does nothing) if the slot was already dead.
    pub fn delete(&mut self, i: usize) -> bool {
        assert!(i < self.n);
        if self.dead[i] {
            return false;
        }
        self.dead[i] = true;
        self.dead_count += 1;
        for c in &mut self.data[i * self.d..(i + 1) * self.d] {
            *c = Self::TOMBSTONE_COORD;
        }
        self.free.push(i);
        true
    }

    /// Insert a point, reusing the most recently tombstoned slot if one
    /// exists, else appending. Returns the slot written.
    ///
    /// ```
    /// use kde_matrix::kernel::Dataset;
    /// let mut ds = Dataset::from_rows(vec![vec![0.0, 0.0], vec![1.0, 1.0]]);
    /// assert!(ds.delete(0));
    /// assert_eq!(ds.live_len(), 1);
    /// let slot = ds.insert(&[2.0, 2.0]);
    /// assert_eq!(slot, 0); // the tombstoned slot is reused in place
    /// assert_eq!(ds.point(0), &[2.0, 2.0]);
    /// assert_eq!((ds.n, ds.live_len()), (2, 2));
    /// assert_eq!(ds.insert(&[3.0, 3.0]), 2); // no free slot -> append
    /// ```
    pub fn insert(&mut self, row: &[f32]) -> usize {
        assert_eq!(row.len(), self.d);
        match self.free.pop() {
            Some(slot) => {
                self.revive_slot(slot, row);
                slot
            }
            None => self.push_row(row),
        }
    }

    /// Insert only if a tombstoned slot can be reused (no buffer growth, so
    /// index trees built over `[0, n)` stay valid). Returns `None` when no
    /// free slot exists.
    pub fn insert_reuse(&mut self, row: &[f32]) -> Option<usize> {
        assert_eq!(row.len(), self.d);
        let slot = self.free.pop()?;
        self.revive_slot(slot, row);
        Some(slot)
    }

    fn revive_slot(&mut self, slot: usize, row: &[f32]) {
        self.data[slot * self.d..(slot + 1) * self.d].copy_from_slice(row);
        self.dead[slot] = false;
        self.dead_count -= 1;
    }

    /// Drop all tombstoned rows, renumbering the survivors to `[0,
    /// live_len)` in original order. Labels are filtered alongside. Returns
    /// the *old* slot index of each survivor (`ret[new] = old`).
    pub fn compact(&mut self) -> Vec<usize> {
        let mut survivors = Vec::with_capacity(self.live_len());
        let mut data = Vec::with_capacity(self.live_len() * self.d);
        for i in 0..self.n {
            if !self.dead[i] {
                survivors.push(i);
                data.extend_from_slice(self.point(i));
            }
        }
        self.labels = self
            .labels
            .take()
            .map(|l| survivors.iter().map(|&i| l[i]).collect());
        self.n = survivors.len();
        self.data = data;
        self.dead = vec![false; self.n];
        self.free.clear();
        self.dead_count = 0;
        survivors
    }

    /// Median-rule bandwidth (§3.1): median pairwise distance over a sample
    /// of pairs, under the metric the kernel uses (L1 for Laplacian,
    /// L2 or L2^2 otherwise).
    pub fn median_rule_sigma(&self, k: Kernel, rng: &mut Rng) -> f64 {
        let pairs = 2_000.min(self.n * (self.n - 1) / 2).max(1);
        let mut dists = Vec::with_capacity(pairs);
        for _ in 0..pairs {
            let i = rng.below(self.n);
            let mut j = rng.below(self.n);
            while j == i {
                j = rng.below(self.n);
            }
            let (a, b) = (self.point(i), self.point(j));
            let dist = match k {
                Kernel::Laplacian => a
                    .iter()
                    .zip(b)
                    .map(|(x, y)| (x - y).abs() as f64)
                    .sum::<f64>(),
                Kernel::Gaussian | Kernel::RationalQuadratic => a
                    .iter()
                    .zip(b)
                    .map(|(x, y)| ((x - y) * (x - y)) as f64)
                    .sum::<f64>(),
                Kernel::Exponential => a
                    .iter()
                    .zip(b)
                    .map(|(x, y)| ((x - y) * (x - y)) as f64)
                    .sum::<f64>()
                    .sqrt(),
            };
            dists.push(dist);
        }
        crate::util::stats::percentile(&dists, 50.0).max(1e-9)
    }

    /// Fold bandwidth in: returns the dataset scaled so that using the
    /// bandwidth-free kernels reproduces `k_sigma`. For Gaussian /
    /// rational-quadratic the scale applies to squared distances, so the
    /// coordinate scale is `1/sqrt(sigma)` of the *squared* median; for L1 /
    /// L2 kernels it is `1/sigma`.
    pub fn with_median_bandwidth(&self, k: Kernel, rng: &mut Rng) -> Dataset {
        let med = self.median_rule_sigma(k, rng);
        let scale = match k {
            Kernel::Gaussian | Kernel::RationalQuadratic => (1.0 / med).sqrt(),
            Kernel::Laplacian | Kernel::Exponential => 1.0 / med,
        };
        self.scaled(scale as f32)
    }
}

// ---------------------------------------------------------------------------
// Synthetic generators
// ---------------------------------------------------------------------------

/// `k` isotropic Gaussian blobs in `R^d` (MNIST substitute).
pub fn gaussian_mixture(
    n: usize,
    d: usize,
    k: usize,
    sep: f64,
    spread: f64,
    rng: &mut Rng,
) -> Dataset {
    let centers: Vec<Vec<f64>> = (0..k)
        .map(|_| (0..d).map(|_| rng.normal() * sep).collect())
        .collect();
    let mut data = Vec::with_capacity(n * d);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let c = i % k;
        labels.push(c);
        for j in 0..d {
            data.push((centers[c][j] + rng.normal() * spread) as f32);
        }
    }
    let mut ds = Dataset::from_flat(n, d, data);
    ds.labels = Some(labels);
    ds
}

/// Heavy-tailed mixture (GloVe substitute): blob draws multiplied by a
/// per-point log-normal radius so row norms are spread out.
pub fn heavy_tailed_mixture(n: usize, d: usize, k: usize, rng: &mut Rng) -> Dataset {
    let base = gaussian_mixture(n, d, k, 1.5, 0.6, rng);
    let mut data = Vec::with_capacity(n * d);
    for i in 0..n {
        let r = (rng.normal() * 0.5).exp() as f32;
        for v in base.point(i) {
            data.push(v * r);
        }
    }
    let mut ds = Dataset::from_flat(n, d, data);
    ds.labels = base.labels;
    ds
}

/// §7 "Nested": half the points at the origin (jittered), half on the unit
/// circle. Two clusters, one inside the other's convex hull.
pub fn nested(n: usize, rng: &mut Rng) -> Dataset {
    let mut data = Vec::with_capacity(n * 2);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        if i % 2 == 0 {
            data.push((rng.normal() * 0.05) as f32);
            data.push((rng.normal() * 0.05) as f32);
            labels.push(0);
        } else {
            let theta = rng.f64() * std::f64::consts::TAU;
            data.push(theta.cos() as f32);
            data.push(theta.sin() as f32);
            labels.push(1);
        }
    }
    let mut ds = Dataset::from_flat(n, 2, data);
    ds.labels = Some(labels);
    ds
}

/// §7 "Rings": two interlocked tori in 3-D. Paper: small radius 5, large
/// radius 100 — we keep the 1:20 ratio at unit scale (r = 0.05, R = 1).
pub fn rings(n: usize, rng: &mut Rng) -> Dataset {
    let (r, big_r) = (0.05f64, 1.0f64);
    let mut data = Vec::with_capacity(n * 3);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let u = rng.f64() * std::f64::consts::TAU;
        let v = rng.f64() * std::f64::consts::TAU;
        let (x, y, z);
        if i % 2 == 0 {
            // Torus 1 in the xy-plane centered at origin.
            x = (big_r + r * v.cos()) * u.cos();
            y = (big_r + r * v.cos()) * u.sin();
            z = r * v.sin();
            labels.push(0);
        } else {
            // Torus 2 in the xz-plane, shifted so it threads torus 1.
            x = big_r + (big_r + r * v.cos()) * u.cos();
            y = r * v.sin();
            z = (big_r + r * v.cos()) * u.sin();
            labels.push(1);
        }
        data.push(x as f32);
        data.push(y as f32);
        data.push(z as f32);
    }
    let mut ds = Dataset::from_flat(n, 3, data);
    ds.labels = Some(labels);
    ds
}

/// `k` well-separated tight blobs: a `(k, phi_in, phi_out)`-clusterable
/// kernel graph instance for the local-clustering experiments (Def. 6.4).
pub fn clusterable(n: usize, d: usize, k: usize, rng: &mut Rng) -> Dataset {
    gaussian_mixture(n, d, k, 4.0, 0.25, rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::Kernel;

    #[test]
    fn from_rows_layout() {
        let ds = Dataset::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(ds.n, 2);
        assert_eq!(ds.d, 2);
        assert_eq!(ds.point(1), &[3.0, 4.0]);
    }

    #[test]
    fn exact_degree_matches_brute() {
        let mut rng = Rng::new(5);
        let ds = gaussian_mixture(20, 4, 2, 1.0, 0.5, &mut rng);
        let k = Kernel::Laplacian;
        for i in 0..ds.n {
            let mut want = 0.0f64;
            for j in 0..ds.n {
                if j != i {
                    want += k.eval(ds.point(i), ds.point(j)) as f64;
                }
            }
            assert!((ds.exact_degree(k, i) - want).abs() < 1e-9);
        }
    }

    #[test]
    fn subset_preserves_points_and_labels() {
        let mut rng = Rng::new(6);
        let ds = gaussian_mixture(10, 3, 2, 1.0, 0.3, &mut rng);
        let sub = ds.subset(&[7, 1, 4]);
        assert_eq!(sub.n, 3);
        assert_eq!(sub.point(0), ds.point(7));
        assert_eq!(sub.point(2), ds.point(4));
        assert_eq!(
            sub.labels.as_ref().unwrap()[1],
            ds.labels.as_ref().unwrap()[1]
        );
    }

    #[test]
    fn scaled_scales() {
        let ds = Dataset::from_rows(vec![vec![2.0, -4.0]]);
        let s = ds.scaled(0.5);
        assert_eq!(s.point(0), &[1.0, -2.0]);
    }

    #[test]
    fn nested_has_two_radii() {
        let mut rng = Rng::new(7);
        let ds = nested(100, &mut rng);
        let labels = ds.labels.as_ref().unwrap();
        for i in 0..ds.n {
            let p = ds.point(i);
            let r = (p[0] * p[0] + p[1] * p[1]).sqrt();
            if labels[i] == 0 {
                assert!(r < 0.5, "origin cluster point too far: {r}");
            } else {
                assert!((r - 1.0).abs() < 0.01, "circle point off circle: {r}");
            }
        }
    }

    #[test]
    fn rings_points_on_tori() {
        let mut rng = Rng::new(8);
        let ds = rings(200, &mut rng);
        let labels = ds.labels.as_ref().unwrap();
        for i in 0..ds.n {
            let p = ds.point(i);
            if labels[i] == 0 {
                // distance from the unit circle in the xy-plane ~ r = 0.05
                let rho = ((p[0] * p[0] + p[1] * p[1]).sqrt() - 1.0).abs();
                let dist = ((rho * rho + p[2] * p[2]) as f64).sqrt();
                assert!((dist - 0.05).abs() < 1e-3, "torus1 dist {dist}");
            }
        }
    }

    #[test]
    fn median_bandwidth_gives_order_one_kernel_values() {
        let mut rng = Rng::new(9);
        let ds = gaussian_mixture(200, 8, 3, 2.0, 1.0, &mut rng);
        for k in [Kernel::Laplacian, Kernel::Gaussian, Kernel::Exponential] {
            let scaled = ds.with_median_bandwidth(k, &mut rng);
            // The median pair should now have kernel value ~ exp(-1).
            let mut vals = Vec::new();
            for t in 0..500 {
                let i = (t * 7) % scaled.n;
                let j = (t * 13 + 1) % scaled.n;
                if i != j {
                    vals.push(scaled.kernel(k, i, j) as f64);
                }
            }
            let med = crate::util::stats::percentile(&vals, 50.0);
            assert!(
                (0.15..0.65).contains(&med),
                "{:?}: median kernel value {med} not O(1)",
                k
            );
        }
    }

    #[test]
    fn mutation_edge_cases_table() {
        // (name, d, n, duplicate_rows): built, deleted down to empty, then
        // refilled — the shapes the scale regime exposes (d=1, n=1,
        // duplicate points, empty-after-deletes).
        let cases: [(&str, usize, usize, bool); 4] = [
            ("n1_d1", 1, 1, false),
            ("n1_d3", 3, 1, false),
            ("d1", 1, 5, false),
            ("duplicates", 2, 4, true),
        ];
        for (name, d, n, dup) in cases {
            let rows: Vec<Vec<f32>> = (0..n)
                .map(|i| vec![if dup { 1.0 } else { i as f32 }; d])
                .collect();
            let mut ds = Dataset::from_rows(rows.clone());
            assert_eq!((ds.n, ds.d, ds.live_len()), (n, d, n), "{name}");
            // Delete everything; a second delete of the same slot is a
            // no-op returning false.
            for i in 0..n {
                assert!(ds.delete(i), "{name}: delete({i})");
                assert!(!ds.delete(i), "{name}: double delete({i})");
            }
            assert_eq!(ds.live_len(), 0, "{name}: empty after deletes");
            assert_eq!(ds.n, n, "{name}: slots retained");
            // Tombstones carry exactly zero kernel mass for the decaying
            // kernels (the far-sentinel contract).
            for i in 0..n {
                for k in [Kernel::Laplacian, Kernel::Gaussian, Kernel::Exponential] {
                    assert_eq!(
                        k.eval(ds.point(i), &rows[0]),
                        0.0,
                        "{name}: tombstone {i} leaks mass under {k:?}"
                    );
                }
            }
            // Refill: every insert reuses a tombstoned slot (no growth).
            for r in &rows {
                let s = ds.insert(r);
                assert!(s < n, "{name}: insert grew instead of reusing");
            }
            assert_eq!((ds.n, ds.live_len()), (n, n), "{name}");
            // Compact on a fully-live dataset is the identity renumbering.
            assert_eq!(ds.compact(), (0..n).collect::<Vec<_>>(), "{name}");
        }
    }

    #[test]
    fn compact_renumbers_and_filters_labels() {
        let mut rng = Rng::new(11);
        let mut ds = gaussian_mixture(10, 3, 2, 1.0, 0.3, &mut rng);
        let labels_before = ds.labels.clone().unwrap();
        let keep3 = ds.point(3).to_vec();
        ds.delete(0);
        ds.delete(7);
        ds.delete(9);
        let survivors = ds.compact();
        assert_eq!(survivors, vec![1, 2, 3, 4, 5, 6, 8]);
        assert_eq!((ds.n, ds.live_len()), (7, 7));
        assert_eq!(ds.point(2), &keep3[..]);
        assert_eq!(ds.labels.as_ref().unwrap()[2], labels_before[3]);
        assert_eq!(ds.labels.as_ref().unwrap().len(), 7);
    }

    #[test]
    fn insert_reuse_never_grows() {
        let mut ds = Dataset::from_rows(vec![vec![0.0], vec![1.0]]);
        assert_eq!(ds.insert_reuse(&[5.0]), None, "no free slot yet");
        ds.delete(1);
        assert_eq!(ds.insert_reuse(&[5.0]), Some(1));
        assert_eq!(ds.point(1), &[5.0]);
        assert_eq!(ds.n, 2);
        // push_row appends past the original capacity.
        assert_eq!(ds.push_row(&[7.0]), 2);
        assert_eq!((ds.n, ds.live_len()), (3, 3));
    }

    #[test]
    #[should_panic]
    fn from_flat_length_mismatch_panics() {
        let _ = Dataset::from_flat(3, 2, vec![0.0; 5]);
    }

    #[test]
    fn tau_is_min_offdiag() {
        let mut rng = Rng::new(10);
        let ds = gaussian_mixture(15, 3, 2, 0.5, 0.2, &mut rng);
        let k = Kernel::Gaussian;
        let tau = ds.tau(k);
        let mut want = f64::INFINITY;
        for i in 0..ds.n {
            for j in 0..ds.n {
                if i != j {
                    want = want.min(ds.kernel(k, i, j) as f64);
                }
            }
        }
        assert!((tau - want).abs() < 1e-12);
    }
}
