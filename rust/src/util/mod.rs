//! Self-contained utility substrate: PRNG, statistics, micro-bench harness,
//! property-test driver, CSV emission.
//!
//! These exist because the offline crate registry only carries the `xla`
//! closure (+ `anyhow`); see DESIGN.md §3 for the substitution table.

pub mod bench;
pub mod fxhash;
pub mod prop;
pub mod rng;
pub mod stats;

use std::io::Write;
use std::path::Path;

/// Write rows of f64 columns as a CSV file with a header line.
/// Used by the figure-regenerating examples (Fig. 3b/3d scatter data etc.).
pub fn write_csv<P: AsRef<Path>>(
    path: P,
    header: &[&str],
    rows: &[Vec<f64>],
) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{}", header.join(","))?;
    for row in rows {
        let cells: Vec<String> = row.iter().map(|x| format!("{x}")).collect();
        writeln!(f, "{}", cells.join(","))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    #[test]
    fn csv_round_trip() {
        let dir = std::env::temp_dir().join("kdem_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.csv");
        super::write_csv(&p, &["a", "b"], &[vec![1.0, 2.0], vec![3.5, 4.5]]).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text.lines().count(), 3);
        assert!(text.starts_with("a,b\n"));
        assert!(text.contains("3.5,4.5"));
    }
}
