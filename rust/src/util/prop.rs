//! Minimal property-testing driver (proptest is unavailable offline —
//! DESIGN.md §3).
//!
//! `forall(cases, |rng, case| ...)` runs a seeded generator/checker loop;
//! on failure it panics with the failing case index and seed so the exact
//! case reproduces with `PROP_SEED=<seed>`.

use crate::util::rng::Rng;

/// Number of cases per property (overridable via env `PROP_CASES`).
pub fn default_cases() -> usize {
    std::env::var("PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

fn base_seed() -> u64 {
    std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE)
}

/// Run `check(rng, case_index)` for `cases` seeded cases. The closure should
/// generate its own inputs from `rng` and assert its property.
pub fn forall<F: FnMut(&mut Rng, usize)>(cases: usize, mut check: F) {
    let seed = base_seed();
    for case in 0..cases {
        let mut rng = Rng::new(seed.wrapping_add(case as u64 * 0x9E37_79B9));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            check(&mut rng, case)
        }));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property failed at case {case} (PROP_SEED={seed}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut ran = 0;
        forall(16, |rng, _| {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
            ran += 1;
        });
        assert_eq!(ran, 16);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports_case() {
        forall(8, |rng, _| {
            assert!(rng.f64() < 0.0, "impossible");
        });
    }
}
