//! In-repo micro-benchmark harness (criterion is unavailable in the offline
//! registry — see DESIGN.md §3).
//!
//! Usage pattern inside a `harness = false` bench target:
//!
//! ```ignore
//! let mut b = BenchSuite::new("bench_kde");
//! b.bench("sampling_kde_query/n=4096", || { /* work */ });
//! b.finish();
//! ```
//!
//! Each case is warmed up, then timed over enough iterations to pass a
//! minimum measuring window; mean / p50 / p95 per-iteration times are
//! printed as aligned table rows so `cargo bench` output reads like the
//! paper's tables.

use std::time::{Duration, Instant};

/// One measured benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
}

/// Collects and prints benchmark cases.
pub struct BenchSuite {
    suite: String,
    results: Vec<BenchResult>,
    /// Minimum total measurement window per case.
    pub min_window: Duration,
    /// Hard cap on sample count per case.
    pub max_samples: u64,
}

impl BenchSuite {
    pub fn new(suite: &str) -> Self {
        println!("\n== {suite} ==");
        println!(
            "{:<56} {:>10} {:>12} {:>12} {:>12}",
            "case", "iters", "mean", "p50", "p95"
        );
        BenchSuite {
            suite: suite.to_string(),
            results: Vec::new(),
            min_window: Duration::from_millis(300),
            max_samples: 200,
        }
    }

    /// Time `f`, printing one row. Returns per-iteration mean in ns.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> f64 {
        // Warmup: one untimed run.
        f();
        let mut samples: Vec<f64> = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.min_window && (samples.len() as u64) < self.max_samples {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_nanos() as f64);
        }
        let mean = crate::util::stats::mean(&samples);
        let p50 = crate::util::stats::percentile(&samples, 50.0);
        let p95 = crate::util::stats::percentile(&samples, 95.0);
        println!(
            "{:<56} {:>10} {:>12} {:>12} {:>12}",
            name,
            samples.len(),
            fmt_ns(mean),
            fmt_ns(p50),
            fmt_ns(p95)
        );
        self.results.push(BenchResult {
            name: name.to_string(),
            iters: samples.len() as u64,
            mean_ns: mean,
            p50_ns: p50,
            p95_ns: p95,
        });
        mean
    }

    /// Print a free-form annotation row (e.g. KDE-query counts for Table 2).
    pub fn note(&mut self, text: &str) {
        println!("   . {text}");
    }

    pub fn finish(self) -> Vec<BenchResult> {
        println!("== {} done ({} cases) ==\n", self.suite, self.results.len());
        self.results
    }
}

/// Human-format a nanosecond quantity.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut suite = BenchSuite::new("selftest");
        suite.min_window = Duration::from_millis(5);
        let mut acc = 0u64;
        let mean = suite.bench("noop-ish", || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(mean >= 0.0);
        let results = suite.finish();
        assert_eq!(results.len(), 1);
        assert!(results[0].iters >= 1);
    }

    #[test]
    fn fmt_ns_scales() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("us"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with(" s"));
    }
}
