//! Small statistics helpers: summaries, percentiles, 1-D earth-mover
//! distance, total-variation distance.

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Percentile via nearest-rank on a *sorted copy*; p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// 1-D earth-mover distance between two equal-size multisets: the optimal
/// matching in 1-D is the sorted matching, so EMD = mean |a_(i) - b_(i)|
/// (Eq. 2 of the paper normalized by n so that `EMD <= eps` is scale-free,
/// matching Theorem 5.17's statement for n eigenvalues in [0, 2]).
pub fn emd_1d(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "EMD needs equal-size multisets");
    assert!(!a.is_empty());
    let mut sa = a.to_vec();
    let mut sb = b.to_vec();
    sa.sort_by(|x, y| x.partial_cmp(y).unwrap());
    sb.sort_by(|x, y| x.partial_cmp(y).unwrap());
    sa.iter()
        .zip(&sb)
        .map(|(x, y)| (x - y).abs())
        .sum::<f64>()
        / a.len() as f64
}

/// Total-variation distance between two discrete distributions given as
/// unnormalized weight vectors of equal length.
pub fn tv_distance(p: &[f64], q: &[f64]) -> f64 {
    assert_eq!(p.len(), q.len());
    let sp: f64 = p.iter().sum();
    let sq: f64 = q.iter().sum();
    assert!(sp > 0.0 && sq > 0.0);
    0.5 * p
        .iter()
        .zip(q)
        .map(|(a, b)| (a / sp - b / sq).abs())
        .sum::<f64>()
}

/// Relative error |got - want| / |want| (0 when both are 0).
pub fn rel_err(got: f64, want: f64) -> f64 {
    if want == 0.0 {
        if got == 0.0 {
            0.0
        } else {
            f64::INFINITY
        }
    } else {
        (got - want).abs() / want.abs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_variance_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
    }

    #[test]
    fn percentile_ordering() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }

    #[test]
    fn emd_identical_is_zero() {
        let a = [0.3, 0.7, 0.1];
        assert_eq!(emd_1d(&a, &a), 0.0);
    }

    #[test]
    fn emd_sorted_matching() {
        // {0, 1} vs {1, 0} -> zero after sorting.
        assert_eq!(emd_1d(&[0.0, 1.0], &[1.0, 0.0]), 0.0);
        // {0,0} vs {1,1} -> 1.0 mean move.
        assert!((emd_1d(&[0.0, 0.0], &[1.0, 1.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn emd_is_metric_like() {
        let a = [0.1, 0.5, 0.9];
        let b = [0.2, 0.4, 1.0];
        let c = [0.0, 0.6, 0.8];
        let (ab, bc, ac) = (emd_1d(&a, &b), emd_1d(&b, &c), emd_1d(&a, &c));
        assert!(ab >= 0.0 && bc >= 0.0);
        assert!(ac <= ab + bc + 1e-12, "triangle inequality");
    }

    #[test]
    fn tv_basics() {
        assert_eq!(tv_distance(&[1.0, 0.0], &[1.0, 0.0]), 0.0);
        assert!((tv_distance(&[1.0, 0.0], &[0.0, 1.0]) - 1.0).abs() < 1e-12);
        // Unnormalized inputs are normalized first.
        assert_eq!(tv_distance(&[2.0, 2.0], &[5.0, 5.0]), 0.0);
    }
}
