//! Deterministic, dependency-free PRNG: xoshiro256++ seeded via SplitMix64.
//!
//! Every randomized algorithm in this crate takes an explicit `&mut Rng` so
//! experiments are reproducible from a single seed recorded in
//! EXPERIMENTS.md.

/// xoshiro256++ PRNG (public-domain reference algorithm by Blackman/Vigna).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal variate from Box-Muller.
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream (for per-thread / per-shard use).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA5A5_5A5A_DEAD_BEEF)
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n). `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our purposes (bias < 2^-53).
        (self.f64() * n as f64) as usize % n
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli trial.
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (cached spare).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u = self.f64();
            if u <= f64::MIN_POSITIVE {
                continue;
            }
            let v = self.f64();
            let r = (-2.0 * u.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * v;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Exponential with rate 1.
    pub fn exponential(&mut self) -> f64 {
        -(1.0 - self.f64()).ln()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher-Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        if k * 4 >= n {
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            idx
        } else {
            // Rejection sampling with a set; fast when k << n.
            let mut seen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let i = self.below(n);
                if seen.insert(i) {
                    out.push(i);
                }
            }
            out
        }
    }

    /// Sample an index from unnormalized nonnegative weights (linear scan).
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted_index: all-zero weights");
        let mut target = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_uniformish() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "count {c} far from 10000");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s += z;
            s2 += z * z;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(13);
        for &(n, k) in &[(10usize, 10usize), (1000, 5), (100, 60)] {
            let idx = r.sample_indices(n, k);
            assert_eq!(idx.len(), k);
            let set: std::collections::HashSet<_> = idx.iter().collect();
            assert_eq!(set.len(), k);
            assert!(idx.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut r = Rng::new(17);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.weighted_index(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((2.6..3.4).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn exponential_mean_one() {
        let mut r = Rng::new(19);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exponential()).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.02, "mean {mean}");
    }
}
