//! Local clustering: Algorithm 6.1 / Theorem 6.9.
//!
//! Decide whether two vertices of a k-clusterable kernel graph lie in the
//! same cluster by comparing the endpoint distributions of length-t random
//! walks, using the CDVV14 collision-based l2 tester:
//!
//!   ||p||^2 is estimated by within-sample collisions,
//!   <p, q>  by cross-sample collisions,
//!   ||p - q||^2 = ||p||^2 + ||q||^2 - 2 <p, q>.
//!
//! Same-cluster pairs give `||p_u^t - p_w^t||^2 <= 1/(8n)` (Lemma 6.7);
//! different clusters give `>= 2/n` (disjoint support, Lemma 6.8) — the
//! tester thresholds in between.

use crate::sampling::Primitives;
use crate::util::rng::Rng;

/// Tuning knobs of the Algorithm 6.1 same-cluster tester.
#[derive(Clone, Copy, Debug)]
pub struct LocalClusterParams {
    /// Walk length t (paper: c log n / phi_in^2).
    pub walk_len: usize,
    /// Samples per distribution r (paper: O(sqrt(n k / eps) / tau^{1.5})).
    pub samples: usize,
    /// Decision threshold on the estimated ||p - q||^2 (default 0.5/n set
    /// between 1/(8n) and 2/n).
    pub threshold_scale: f64,
}

impl LocalClusterParams {
    /// Paper-shaped defaults for an n-vertex graph (log-length walks,
    /// `O(sqrt n)` samples per distribution).
    pub fn for_n(n: usize) -> Self {
        let walk_len = (3.0 * (n as f64).ln()).ceil() as usize;
        let samples = (20.0 * (n as f64).sqrt()).ceil() as usize;
        LocalClusterParams { walk_len, samples, threshold_scale: 1.0 }
    }
}

/// One same-cluster decision with its evidence and cost.
pub struct LocalClusterOutcome {
    /// The tester's verdict (distance below the threshold).
    pub same_cluster: bool,
    /// The collision-estimated squared l2 distance.
    pub distance_sq: f64,
    /// Logical KDE queries spent (cache misses).
    pub kde_queries: u64,
}

/// Unbiased collision estimator of `||p||^2` from `r` iid samples.
pub fn l2_norm_sq_estimate(samples: &[usize], n: usize) -> f64 {
    let r = samples.len();
    assert!(r >= 2);
    let mut counts = vec![0u32; n];
    for &s in samples {
        counts[s] += 1;
    }
    let pairs: f64 = counts
        .iter()
        .map(|&c| c as f64 * (c as f64 - 1.0))
        .sum();
    pairs / (r as f64 * (r as f64 - 1.0))
}

/// Unbiased estimator of `<p, q>` from r samples of each.
pub fn inner_product_estimate(a: &[usize], b: &[usize], n: usize) -> f64 {
    let mut ca = vec![0u32; n];
    let mut cb = vec![0u32; n];
    for &s in a {
        ca[s] += 1;
    }
    for &s in b {
        cb[s] += 1;
    }
    let cross: f64 = ca.iter().zip(&cb).map(|(&x, &y)| x as f64 * y as f64).sum();
    cross / (a.len() as f64 * b.len() as f64)
}

/// Algorithm 6.1: decide whether u and w share a cluster.
///
/// The `2 * samples` T-step walks run through the frontier-batched walk
/// engine ([`RandomWalker::walk_batch`](crate::sampling::RandomWalker::walk_batch)):
/// one batch advances every walker in lockstep, so each step's neighbor
/// descents coalesce into fused backend submissions and the whole query
/// costs O(T · log n) backend executions instead of the sequential
/// O(samples · T · log n) (pinned in `tests/fusion.rs`).
pub fn same_cluster(
    prims: &Primitives,
    u: usize,
    w: usize,
    params: &LocalClusterParams,
    rng: &mut Rng,
) -> LocalClusterOutcome {
    let n = prims.n();
    let before = prims.counters.queries();
    let mut starts = Vec::with_capacity(2 * params.samples);
    starts.resize(params.samples, u);
    starts.resize(2 * params.samples, w);
    let mut ends_u = prims.walker.walk_batch(&starts, params.walk_len, rng);
    let ends_w = ends_u.split_off(params.samples);
    let pp = l2_norm_sq_estimate(&ends_u, n);
    let qq = l2_norm_sq_estimate(&ends_w, n);
    let pq = inner_product_estimate(&ends_u, &ends_w, n);
    let dist_sq = (pp + qq - 2.0 * pq).max(0.0);
    LocalClusterOutcome {
        same_cluster: dist_sq <= params.threshold_scale / n as f64,
        distance_sq: dist_sq,
        kde_queries: prims.counters.queries() - before,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kde::KdeConfig;
    use crate::kernel::dataset::clusterable;
    use crate::kernel::Kernel;
    use crate::runtime::backend::CpuBackend;
    use std::sync::Arc;

    #[test]
    fn l2_estimators_unbiased_on_known_distribution() {
        // p uniform over {0,1}: ||p||^2 = 0.5.
        let mut rng = Rng::new(221);
        let trials = 300;
        let r = 50;
        let mut acc = 0.0;
        for _ in 0..trials {
            let samples: Vec<usize> = (0..r).map(|_| rng.below(2)).collect();
            acc += l2_norm_sq_estimate(&samples, 2);
        }
        let mean = acc / trials as f64;
        assert!((mean - 0.5).abs() < 0.02, "E||p||^2 = {mean}");
        // <p, q> with p = delta_0, q = uniform over {0,1}: 0.5.
        let mut acc2 = 0.0;
        for _ in 0..trials {
            let a: Vec<usize> = vec![0; r];
            let b: Vec<usize> = (0..r).map(|_| rng.below(2)).collect();
            acc2 += inner_product_estimate(&a, &b, 2);
        }
        let mean2 = acc2 / trials as f64;
        assert!((mean2 - 0.5).abs() < 0.02, "E<p,q> = {mean2}");
    }

    #[test]
    fn detects_same_and_different_clusters() {
        let mut rng = Rng::new(223);
        // Two far blobs: a (2, phi_in, phi_out)-clusterable kernel graph.
        let ds = Arc::new(clusterable(64, 4, 2, &mut rng));
        let labels = ds.labels.clone().unwrap();
        let prims = Primitives::build(
            ds,
            Kernel::Laplacian,
            &KdeConfig::exact(),
            CpuBackend::new(),
        );
        let params = LocalClusterParams::for_n(64);
        // same cluster: vertices 0 and 2 (labels alternate: i % 2)
        assert_eq!(labels[0], labels[2]);
        let same = same_cluster(&prims, 0, 2, &params, &mut rng);
        assert!(
            same.same_cluster,
            "same-cluster pair rejected (d^2 = {})",
            same.distance_sq
        );
        // different clusters: vertices 0 and 1
        assert_ne!(labels[0], labels[1]);
        let diff = same_cluster(&prims, 0, 1, &params, &mut rng);
        assert!(
            !diff.same_cluster,
            "different-cluster pair accepted (d^2 = {})",
            diff.distance_sq
        );
        // The distances should be separated by an order of magnitude.
        assert!(diff.distance_sq > 4.0 * same.distance_sq);
    }

    #[test]
    fn accuracy_over_random_pairs() {
        let mut rng = Rng::new(225);
        let ds = Arc::new(clusterable(96, 4, 3, &mut rng));
        let labels = ds.labels.clone().unwrap();
        let prims = Primitives::build(
            ds,
            Kernel::Laplacian,
            &KdeConfig::exact(),
            CpuBackend::new(),
        );
        let params = LocalClusterParams::for_n(96);
        let mut correct = 0;
        let trials = 20;
        for _ in 0..trials {
            let u = rng.below(96);
            let w = rng.below(96);
            if u == w {
                correct += 1;
                continue;
            }
            let out = same_cluster(&prims, u, w, &params, &mut rng);
            if out.same_cluster == (labels[u] == labels[w]) {
                correct += 1;
            }
        }
        assert!(
            correct >= trials - 2,
            "local clustering accuracy {correct}/{trials}"
        );
    }
}
