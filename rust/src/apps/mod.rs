//! The paper's applications (§5 linear algebra, §6 graphs), each built
//! strictly on the §4 primitives + KDE black box, with exact baselines for
//! every experiment. `docs/ALGORITHMS.md` maps every module here to its
//! paper theorem and the test that pins it.

#![warn(missing_docs)]

pub mod arboricity;
pub mod cluster_local;
pub mod cluster_spectral;
pub mod eigen_top;
pub mod lra;
pub mod resparsify;
pub mod solver;
pub mod sparsify;
pub mod spectrum;
pub mod triangles;
