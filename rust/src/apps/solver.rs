//! Approximate Laplacian system solving on the sparsifier: §5.1.1.
//!
//! Theorem 5.11: if `G'` is an eps-sparsifier of `G`, then solving
//! `L_{G'} x = b` (to machine precision, here via preconditioned CG —
//! the Theorem 5.10 solver role) gives `||x - L_G^+ b||_{L_G} <=
//! O(sqrt(eps)) ||L_G^+ b||_{L_G}`.

use crate::graph::{LaplacianOp, WGraph};
use crate::linalg::cg::{cg, CgResult};

/// Solve `L_G' x = b` on the (sparse) graph via Jacobi-preconditioned CG,
/// projecting against the all-ones null space. `b` must satisfy
/// `1^T b = 0` for consistency; we project it defensively.
pub fn solve_laplacian(g: &WGraph, b: &[f64], tol: f64, max_iters: usize) -> CgResult {
    assert_eq!(b.len(), g.n);
    let mut rhs = b.to_vec();
    let mean = rhs.iter().sum::<f64>() / g.n as f64;
    for v in rhs.iter_mut() {
        *v -= mean;
    }
    let diag = g.degrees();
    cg(&LaplacianOp(g), &rhs, Some(&diag), true, tol, max_iters)
}

/// `||x||_L = sqrt(x^T L x)` — the error norm of Theorems 5.10/5.11.
pub fn l_norm(g: &WGraph, x: &[f64]) -> f64 {
    g.laplacian_quadratic(x).max(0.0).sqrt()
}

/// End-to-end §5.1.1 quality metric: relative `L_G`-norm error of the
/// sparsifier solve against the exact solve on `G`.
pub fn solve_error_vs_exact(g_exact: &WGraph, g_sparse: &WGraph, b: &[f64]) -> f64 {
    let x_exact = solve_laplacian(g_exact, b, 1e-10, 10_000).x;
    let x_sparse = solve_laplacian(g_sparse, b, 1e-10, 10_000).x;
    let diff: Vec<f64> = x_exact
        .iter()
        .zip(&x_sparse)
        .map(|(a, b)| a - b)
        .collect();
    l_norm(g_exact, &diff) / l_norm(g_exact, &x_exact).max(1e-300)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::dataset::gaussian_mixture;
    use crate::kernel::Kernel;
    use crate::util::rng::Rng;

    fn mean_zero_vec(n: usize, rng: &mut Rng) -> Vec<f64> {
        let mut b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let m = b.iter().sum::<f64>() / n as f64;
        for v in b.iter_mut() {
            *v -= m;
        }
        b
    }

    #[test]
    fn solve_exact_laplacian_residual() {
        let mut rng = Rng::new(181);
        let ds = gaussian_mixture(24, 3, 2, 1.0, 0.5, &mut rng);
        let g = crate::graph::WGraph::complete_kernel_graph(&ds, Kernel::Laplacian);
        let b = mean_zero_vec(24, &mut rng);
        let res = solve_laplacian(&g, &b, 1e-10, 2_000);
        assert!(res.converged, "CG residual {}", res.residual);
        let mut lx = vec![0.0; 24];
        g.laplacian_matvec(&res.x, &mut lx);
        for i in 0..24 {
            assert!((lx[i] - b[i]).abs() < 1e-6, "L x != b at {i}");
        }
    }

    #[test]
    fn sparsifier_solve_close_to_exact_solve() {
        // Theorem 5.11 behaviour: error decays with sparsifier quality.
        let mut rng = Rng::new(183);
        let ds = std::sync::Arc::new(gaussian_mixture(32, 3, 2, 0.8, 0.5, &mut rng));
        let g = crate::graph::WGraph::complete_kernel_graph(&ds, Kernel::Laplacian);
        let prims = crate::sampling::Primitives::build(
            ds,
            Kernel::Laplacian,
            &crate::kde::KdeConfig::exact(),
            crate::runtime::backend::CpuBackend::new(),
        );
        let b = mean_zero_vec(32, &mut rng);
        let coarse = crate::apps::sparsify::sparsify(&prims, 800, &mut rng);
        let fine = crate::apps::sparsify::sparsify(&prims, 12_000, &mut rng);
        let e_coarse = solve_error_vs_exact(&g, &coarse.graph, &b);
        let e_fine = solve_error_vs_exact(&g, &fine.graph, &b);
        assert!(e_fine < 0.25, "fine sparsifier solve error {e_fine}");
        assert!(
            e_fine < e_coarse + 0.05,
            "error should not grow with more samples: {e_fine} vs {e_coarse}"
        );
    }
}
