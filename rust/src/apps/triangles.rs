//! Total weight of triangles (weight = product of edge weights):
//! Theorem 6.17, adapting ELRS17 to the kernel-graph query model.
//!
//! Every pair is an edge of the complete kernel graph, so a uniform edge
//! is a uniform pair. Each triangle (a, b, c) is assigned to its edge
//! (a, b) where `a ≺ b ≺ c` under the degree ordering (ties by index).
//! For a sampled pair e = (a, b) with `a ≺ b`, the assigned weight
//!
//! ```text
//! W_e = sum_{c: b ≺ c} k(a,c) k(b,c) k(a,b)
//! ```
//!
//! is estimated by weighted-neighbor sampling from `a`:
//! draw `c ~ k(a, ·)/deg(a)`, return `deg(a) · 1{b ≺ c} · k(b,c) k(a,b)`
//! — unbiased by construction. The total is `C(n,2)/|R| * sum_e Ŵ_e`.
//!
//! **Evaluation shapes.** Both entry points share one RNG discipline:
//! pooled edge `e` owns a stream forked off the caller's `rng` in pool
//! order (the uniform pair comes from that stream), and rep `j` of edge
//! `e` descends on a sub-stream forked off the edge's stream in rep
//! order. [`triangle_weight_estimate`] resolves the
//! `edge_pool · reps` neighbor descents one at a time — O(pool · reps ·
//! log n) backend dispatches cache-cold. [`triangle_weight_estimate_batched`]
//! resolves them as ONE frontier batch
//! ([`NeighborSampler::sample_batch_with_streams`](crate::sampling::NeighborSampler::sample_batch_with_streams)):
//! the descents advance in level-order lock-step, every level's cache
//! misses coalesce into fused padded backend submissions, and the whole
//! estimate costs O(log n) dispatches (≤ 10·log₂n at n = 4096, pinned in
//! `tests/fusion.rs`). Because the streams are identical, the two paths
//! produce **bit-identical** estimates from the same seed.

use crate::sampling::{NeighborSample, Primitives};
use crate::util::rng::Rng;

/// Estimate plus the §7-style cost accounting of one run.
pub struct TriangleResult {
    /// Estimated total triangle weight of the complete kernel graph.
    pub estimate: f64,
    /// Logical KDE queries spent (cache misses; Theorem 6.17's metric).
    pub kde_queries: u64,
    /// Explicit kernel evaluations spent by the estimator itself.
    pub kernel_evals: u64,
}

/// Sampling budget of the Theorem 6.17 estimator.
#[derive(Clone, Copy, Debug)]
pub struct TriangleParams {
    /// Number of uniformly sampled edges |R|.
    pub edge_pool: usize,
    /// Neighbor samples per pooled edge.
    pub reps: usize,
}

impl Default for TriangleParams {
    fn default() -> Self {
        TriangleParams { edge_pool: 256, reps: 16 }
    }
}

/// Degree ordering `a ≺ b` (ties broken by index) per §6.4.
fn precedes(deg: &[f64], a: usize, b: usize) -> bool {
    (deg[a], a) < (deg[b], b)
}

/// Theorem 6.17 estimator, sequential descents (see the module docs for
/// the shared RNG discipline — [`triangle_weight_estimate_batched`]
/// reproduces this function's result bit for bit from the same seed).
///
/// # Example
///
/// ```
/// use std::sync::Arc;
/// use kde_matrix::apps::triangles::{
///     triangle_weight_estimate, triangle_weight_estimate_batched, TriangleParams,
/// };
/// use kde_matrix::kde::KdeConfig;
/// use kde_matrix::kernel::{dataset::gaussian_mixture, Kernel};
/// use kde_matrix::runtime::CpuBackend;
/// use kde_matrix::sampling::Primitives;
/// use kde_matrix::util::rng::Rng;
///
/// let mut rng = Rng::new(5);
/// let ds = Arc::new(gaussian_mixture(24, 3, 2, 1.0, 0.5, &mut rng));
/// let prims = Primitives::build(ds, Kernel::Laplacian, &KdeConfig::exact(), CpuBackend::new());
/// let params = TriangleParams { edge_pool: 8, reps: 4 };
/// // The batched path replays the sequential path bit for bit.
/// let seq = triangle_weight_estimate(&prims, &params, &mut Rng::new(9));
/// let bat = triangle_weight_estimate_batched(&prims, &params, &mut Rng::new(9));
/// assert_eq!(seq.estimate.to_bits(), bat.estimate.to_bits());
/// assert!(seq.estimate >= 0.0);
/// ```
pub fn triangle_weight_estimate(
    prims: &Primitives,
    params: &TriangleParams,
    rng: &mut Rng,
) -> TriangleResult {
    estimate_impl(prims, params, rng, false)
}

/// Theorem 6.17 estimator, frontier-batched descents: all
/// `edge_pool · reps` weighted-neighbor draws advance in level-order
/// lock-step and resolve through fused backend submissions — O(log n)
/// dispatches for the whole estimate instead of O(pool · reps · log n) —
/// while reproducing [`triangle_weight_estimate`]'s result **bit for
/// bit** from the same seed (both pinned in `tests/fusion.rs`).
pub fn triangle_weight_estimate_batched(
    prims: &Primitives,
    params: &TriangleParams,
    rng: &mut Rng,
) -> TriangleResult {
    estimate_impl(prims, params, rng, true)
}

/// Shared estimator body. The two paths differ ONLY in how the pooled
/// descents execute (one at a time vs one frontier batch); pair draws,
/// stream forks, kernel evaluations and the accumulation order are
/// identical, which is what makes the results bit-identical.
fn estimate_impl(
    prims: &Primitives,
    params: &TriangleParams,
    rng: &mut Rng,
    batched: bool,
) -> TriangleResult {
    let ds = &prims.tree.ds;
    let kernel = prims.tree.kernel;
    let n = ds.n;
    let deg = &prims.degrees.degrees;
    let before = prims.counters.queries();
    let mut kernel_evals = 0u64;
    // Per-edge streams, uniform pairs, per-rep descent sub-streams.
    let mut edges = Vec::with_capacity(params.edge_pool);
    let mut rep_sources = Vec::with_capacity(params.edge_pool * params.reps);
    let mut rep_streams = Vec::with_capacity(params.edge_pool * params.reps);
    for _ in 0..params.edge_pool {
        let mut stream = rng.fork();
        // uniform pair (u, v), u != v; order so a ≺ b.
        let u = stream.below(n);
        let mut v = stream.below(n);
        while v == u {
            v = stream.below(n);
        }
        let (a, b) = if precedes(deg, u, v) { (u, v) } else { (v, u) };
        let k_ab = kernel.eval(ds.point(a), ds.point(b)) as f64;
        kernel_evals += 1;
        for _ in 0..params.reps {
            rep_sources.push(a);
            rep_streams.push(stream.fork());
        }
        edges.push((a, b, k_ab));
    }
    // The descents: one frontier batch, or one at a time on the very same
    // streams.
    let samples: Vec<Option<NeighborSample>> = if batched {
        prims.neighbors.sample_batch_with_streams(&rep_sources, &mut rep_streams)
    } else {
        rep_sources
            .iter()
            .zip(rep_streams.iter_mut())
            .map(|(&src, stream)| prims.neighbors.sample(src, stream))
            .collect()
    };
    // Accumulate in (edge, rep) order on both paths.
    let mut acc = 0.0f64;
    for (e, &(a, b, k_ab)) in edges.iter().enumerate() {
        let mut w_e = 0.0;
        for rep in 0..params.reps {
            let Some(s) = samples[e * params.reps + rep] else { continue };
            let c = s.neighbor;
            if c != b && precedes(deg, b, c) {
                let k_bc = kernel.eval(ds.point(b), ds.point(c)) as f64;
                kernel_evals += 1;
                w_e += deg[a] * k_bc * k_ab;
            }
        }
        acc += w_e / params.reps as f64;
    }
    let num_pairs = (n * (n - 1) / 2) as f64;
    TriangleResult {
        estimate: acc / params.edge_pool as f64 * num_pairs,
        kde_queries: prims.counters.queries() - before,
        kernel_evals,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::WGraph;
    use crate::kde::KdeConfig;
    use crate::kernel::dataset::gaussian_mixture;
    use crate::kernel::Kernel;
    use crate::runtime::backend::CpuBackend;
    use std::sync::Arc;

    fn setup(n: usize, seed: u64) -> (Arc<crate::kernel::Dataset>, Primitives, Rng) {
        let mut rng = Rng::new(seed);
        let ds = Arc::new(gaussian_mixture(n, 3, 2, 1.0, 0.5, &mut rng));
        let prims = Primitives::build(
            ds.clone(),
            Kernel::Laplacian,
            &KdeConfig::exact(),
            CpuBackend::new(),
        );
        (ds, prims, rng)
    }

    #[test]
    fn estimate_matches_exact_total() {
        let (ds, prims, mut rng) = setup(32, 251);
        let g = WGraph::complete_kernel_graph(&ds, Kernel::Laplacian);
        let exact = g.exact_triangle_weight();
        let params = TriangleParams { edge_pool: 496, reps: 64 };
        let est = triangle_weight_estimate(&prims, &params, &mut rng);
        let rel = (est.estimate - exact).abs() / exact;
        // Margin sized for the per-edge forked-stream discipline (the
        // estimator distribution is unchanged; the draws re-randomized).
        assert!(
            rel < 0.2,
            "triangle est {} vs exact {exact} (rel {rel})",
            est.estimate
        );
    }

    #[test]
    fn estimator_is_unbiased_over_runs() {
        let (ds, prims, mut rng) = setup(20, 253);
        let g = WGraph::complete_kernel_graph(&ds, Kernel::Laplacian);
        let exact = g.exact_triangle_weight();
        let params = TriangleParams { edge_pool: 64, reps: 8 };
        let runs = 40;
        let mut acc = 0.0;
        for _ in 0..runs {
            acc += triangle_weight_estimate(&prims, &params, &mut rng).estimate;
        }
        let mean = acc / runs as f64;
        assert!(
            (mean - exact).abs() < 0.1 * exact,
            "mean {mean} vs exact {exact}"
        );
    }

    #[test]
    fn cost_independent_of_n_given_pool() {
        let (_, prims, mut rng) = setup(64, 255);
        let params = TriangleParams { edge_pool: 32, reps: 4 };
        let est = triangle_weight_estimate(&prims, &params, &mut rng);
        // kernel evals <= pool * (1 + reps)
        assert!(est.kernel_evals <= 32 * 5, "evals {}", est.kernel_evals);
    }

    #[test]
    fn batched_estimate_is_bit_identical_to_sequential() {
        // The frontier-batch contract at app level: same seed, same
        // estimate, bit for bit — plus identical cost accounting (the
        // batched path issues the same logical queries and evaluations,
        // only the dispatch shape changes).
        let (_, prims, _) = setup(48, 257);
        let params = TriangleParams { edge_pool: 12, reps: 6 };
        for seed in [1u64, 77, 4242] {
            let bat = triangle_weight_estimate_batched(&prims, &params, &mut Rng::new(seed));
            let seq = triangle_weight_estimate(&prims, &params, &mut Rng::new(seed));
            assert_eq!(
                bat.estimate.to_bits(),
                seq.estimate.to_bits(),
                "seed {seed}: batched {} vs sequential {}",
                bat.estimate,
                seq.estimate
            );
            assert_eq!(bat.kernel_evals, seq.kernel_evals, "seed {seed} evals");
        }
    }

    #[test]
    fn batched_estimate_matches_exact_total() {
        // The batched path is the default evaluation shape; verify it
        // against ground truth directly too.
        let (ds, prims, mut rng) = setup(32, 259);
        let g = WGraph::complete_kernel_graph(&ds, Kernel::Laplacian);
        let exact = g.exact_triangle_weight();
        let params = TriangleParams { edge_pool: 496, reps: 64 };
        let est = triangle_weight_estimate_batched(&prims, &params, &mut rng);
        let rel = (est.estimate - exact).abs() / exact;
        assert!(
            rel < 0.2,
            "batched triangle est {} vs exact {exact} (rel {rel})",
            est.estimate
        );
    }
}
