//! Total weight of triangles (weight = product of edge weights):
//! Theorem 6.17, adapting ELRS17 to the kernel-graph query model.
//!
//! Every pair is an edge of the complete kernel graph, so a uniform edge
//! is a uniform pair. Each triangle (a, b, c) is assigned to its edge
//! (a, b) where `a ≺ b ≺ c` under the degree ordering (ties by index).
//! For a sampled pair e = (a, b) with `a ≺ b`, the assigned weight
//!
//! ```text
//! W_e = sum_{c: b ≺ c} k(a,c) k(b,c) k(a,b)
//! ```
//!
//! is estimated by weighted-neighbor sampling from `a`:
//! draw `c ~ k(a, ·)/deg(a)`, return `deg(a) · 1{b ≺ c} · k(b,c) k(a,b)`
//! — unbiased by construction. The total is `C(n,2)/|R| * sum_e Ŵ_e`.

use crate::sampling::Primitives;
use crate::util::rng::Rng;

pub struct TriangleResult {
    pub estimate: f64,
    pub kde_queries: u64,
    pub kernel_evals: u64,
}

#[derive(Clone, Copy, Debug)]
pub struct TriangleParams {
    /// Number of uniformly sampled edges |R|.
    pub edge_pool: usize,
    /// Neighbor samples per pooled edge.
    pub reps: usize,
}

impl Default for TriangleParams {
    fn default() -> Self {
        TriangleParams { edge_pool: 256, reps: 16 }
    }
}

/// Degree ordering `a ≺ b` (ties broken by index) per §6.4.
fn precedes(deg: &[f64], a: usize, b: usize) -> bool {
    (deg[a], a) < (deg[b], b)
}

/// Theorem 6.17 estimator.
pub fn triangle_weight_estimate(
    prims: &Primitives,
    params: &TriangleParams,
    rng: &mut Rng,
) -> TriangleResult {
    let ds = &prims.tree.ds;
    let kernel = prims.tree.kernel;
    let n = ds.n;
    let deg = &prims.degrees.degrees;
    let before = prims.counters.queries();
    let mut kernel_evals = 0u64;
    let mut acc = 0.0f64;
    for _ in 0..params.edge_pool {
        // uniform pair (u, v), u != v; order so a ≺ b.
        let u = rng.below(n);
        let mut v = rng.below(n);
        while v == u {
            v = rng.below(n);
        }
        let (a, b) = if precedes(deg, u, v) { (u, v) } else { (v, u) };
        let k_ab = kernel.eval(ds.point(a), ds.point(b)) as f64;
        kernel_evals += 1;
        let mut w_e = 0.0;
        for _ in 0..params.reps {
            let Some(s) = prims.neighbors.sample(a, rng) else { continue };
            let c = s.neighbor;
            if c != b && precedes(deg, b, c) {
                let k_bc = kernel.eval(ds.point(b), ds.point(c)) as f64;
                kernel_evals += 1;
                w_e += deg[a] * k_bc * k_ab;
            }
        }
        acc += w_e / params.reps as f64;
    }
    let num_pairs = (n * (n - 1) / 2) as f64;
    TriangleResult {
        estimate: acc / params.edge_pool as f64 * num_pairs,
        kde_queries: prims.counters.queries() - before,
        kernel_evals,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::WGraph;
    use crate::kde::KdeConfig;
    use crate::kernel::dataset::gaussian_mixture;
    use crate::kernel::Kernel;
    use crate::runtime::backend::CpuBackend;
    use std::sync::Arc;

    fn setup(n: usize, seed: u64) -> (Arc<crate::kernel::Dataset>, Primitives, Rng) {
        let mut rng = Rng::new(seed);
        let ds = Arc::new(gaussian_mixture(n, 3, 2, 1.0, 0.5, &mut rng));
        let prims = Primitives::build(
            ds.clone(),
            Kernel::Laplacian,
            &KdeConfig::exact(),
            CpuBackend::new(),
        );
        (ds, prims, rng)
    }

    #[test]
    fn estimate_matches_exact_total() {
        let (ds, prims, mut rng) = setup(32, 251);
        let g = WGraph::complete_kernel_graph(&ds, Kernel::Laplacian);
        let exact = g.exact_triangle_weight();
        let params = TriangleParams { edge_pool: 496, reps: 64 };
        let est = triangle_weight_estimate(&prims, &params, &mut rng);
        let rel = (est.estimate - exact).abs() / exact;
        assert!(
            rel < 0.15,
            "triangle est {} vs exact {exact} (rel {rel})",
            est.estimate
        );
    }

    #[test]
    fn estimator_is_unbiased_over_runs() {
        let (ds, prims, mut rng) = setup(20, 253);
        let g = WGraph::complete_kernel_graph(&ds, Kernel::Laplacian);
        let exact = g.exact_triangle_weight();
        let params = TriangleParams { edge_pool: 64, reps: 8 };
        let runs = 40;
        let mut acc = 0.0;
        for _ in 0..runs {
            acc += triangle_weight_estimate(&prims, &params, &mut rng).estimate;
        }
        let mean = acc / runs as f64;
        assert!(
            (mean - exact).abs() < 0.08 * exact,
            "mean {mean} vs exact {exact}"
        );
    }

    #[test]
    fn cost_independent_of_n_given_pool() {
        let (_, prims, mut rng) = setup(64, 255);
        let params = TriangleParams { edge_pool: 32, reps: 4 };
        let est = triangle_weight_estimate(&prims, &params, &mut rng);
        // kernel evals <= pool * (1 + reps)
        assert!(est.kernel_evals <= 32 * 5, "evals {}", est.kernel_evals);
    }
}
