//! Additive-error low-rank approximation of the kernel matrix:
//! Algorithm 5.15 / Corollary 5.14 (FKV over squared-row-norm samples),
//! plus the two §7 baselines — input-sparsity CountSketch (CW13, "IS")
//! and iterative SVD (block power iteration).
//!
//! The KDE algorithm touches only `n` KDE queries + `s x n` explicit kernel
//! entries for the sampled rows (`s = rows_factor * rank`, paper uses 25k);
//! both baselines must materialize all `n^2` entries — that gap is the
//! paper's Fig. 3 headline (9x fewer kernel evaluations). Row construction
//! goes through planner-chunked `KernelBackend::block_ranged` submissions
//! of at most B = 64 query rows each ("one query-batch per chunk"), so the
//! peak per-dispatch block is `B x n` instead of `s x n` while every value
//! stays bit-identical to the monolithic call.

use std::sync::Arc;

use crate::coordinator::batcher::{plan_level_fusion, FuseJob};
use crate::kde::{KdeConfig, KdeCounters};
use crate::kernel::{Dataset, Kernel};
use crate::linalg::eigen::{block_power, jacobi_eigen};
use crate::linalg::mat::Mat;
use crate::linalg::sketch::CountSketch;
use crate::runtime::backend::KernelBackend;
use crate::runtime::pjrt::{AOT_B, AOT_M};
use crate::sampling::rownorm::RowNormSampler;
use crate::util::rng::Rng;

/// A rank-k factor `V` (k x n, approximately orthonormal rows): the
/// approximation is `B = K V^T V`.
pub struct LraResult {
    /// The factor `V` itself (achieved-rank x n).
    pub v: Mat,
    /// ACHIEVED rank (`v.rows`): at most the requested rank, lower when
    /// fewer rows were sampled than the rank asked for (`s < k`) or the
    /// sampled rows' spectrum degenerates below the eigenvalue floor.
    pub rank: usize,
    /// Rows sampled by squared row norm (`s = rows_factor * rank`,
    /// clamped to `[1, n]`).
    pub sampled_rows: usize,
    /// Most query rows any single row-construction dispatch carried
    /// (bounded by the planner's B = 64 submission cap).
    pub peak_block_rows: usize,
    /// Logical KDE queries spent (cache misses; exactly n here).
    pub kde_queries: u64,
    /// Kernel evaluations performed BY THE ALGORITHM (row construction +
    /// estimator samples), not by any evaluation harness.
    pub kernel_evals: u64,
    /// f32 values the algorithm must hold at once (space accounting, §7.1).
    pub floats_stored: u64,
}

/// FKV top-k right factors from sampled, rescaled rows. The returned
/// matrix has the ACHIEVED rank as its row count: at most `k.min(r.rows)`,
/// further truncated to the eigenvalues above the 1e-12 floor — a
/// degenerate spectrum yields fewer usable directions than requested, and
/// reporting phantom all-zero rows as rank overstated it.
fn fkv_factors(r: &Mat, k: usize) -> Mat {
    // W = R R^T (s x s), exact eigendecomposition, top-k.
    let w = r.gram_rows();
    let (vals, vecs) = jacobi_eigen(&w, 100);
    let n = r.cols;
    let cap = k.min(r.rows);
    let mut achieved = 0usize;
    while achieved < cap && vals[achieved].max(0.0) > 1e-12 {
        achieved += 1;
    }
    let mut v = Mat::zeros(achieved, n);
    for j in 0..achieved {
        let lam = vals[j].max(0.0);
        let scale = 1.0 / lam.sqrt();
        // v_j = R^T q_j / sqrt(lambda_j)
        for i in 0..r.rows {
            let q = vecs[(i, j)] * scale;
            if q == 0.0 {
                continue;
            }
            let row = r.row(i);
            let dst = v.row_mut(j);
            for c in 0..n {
                dst[c] += q * row[c];
            }
        }
    }
    v
}

/// Build the rescaled sampled-row matrix `R` (`s x n`) through
/// planner-chunked [`KernelBackend::block_ranged`] submissions — one
/// query-batch of at most B = 64 rows per dispatch instead of one
/// monolithic `s x n` block call. Peak per-dispatch block memory drops
/// from `s x n` to `B x n` f32s, on PJRT each chunk is one padded
/// artifact submission, and every value is bit-identical to the
/// monolithic call (block entries are pure per-pair functions). Returns
/// `(R, peak_rows_per_dispatch)`.
fn construct_rows(
    ds: &Dataset,
    kernel: Kernel,
    picks: &[(usize, f64)],
    backend: &Arc<dyn KernelBackend>,
) -> (Mat, usize) {
    let s = picks.len();
    let n = ds.n;
    let d = ds.d;
    let flat = ds.flat();
    let mut r = Mat::zeros(s, n);
    let mut peak = 0usize;
    for sub in plan_level_fusion(&[FuseJob { rows: s, seg_rows: n }], AOT_B, AOT_M) {
        let mut queries: Vec<f32> = Vec::with_capacity(sub.rows.len() * d);
        for &(_, row) in &sub.rows {
            queries.extend_from_slice(ds.point(picks[row].0));
        }
        let ranges: Vec<(usize, usize)> = vec![(0, n); sub.rows.len()];
        let block = backend.block_ranged(kernel, &queries, flat, d, &ranges);
        peak = peak.max(sub.rows.len());
        // Rescale rows: row / sqrt(s * p_i).
        for (bi, &(_, row)) in sub.rows.iter().enumerate() {
            let scale = 1.0 / (s as f64 * picks[row].1).sqrt();
            let src = &block[bi * n..(bi + 1) * n];
            let dst = r.row_mut(row);
            for c in 0..n {
                dst[c] = src[c] as f64 * scale;
            }
        }
    }
    (r, peak)
}

/// Algorithm 5.15: KDE row-norm sampling + FKV.
///
/// `rows_factor`: rows sampled per unit of rank (paper: 25).
pub fn lra_kde(
    ds: &Arc<Dataset>,
    kernel: Kernel,
    rank: usize,
    rows_factor: usize,
    cfg: &KdeConfig,
    backend: Arc<dyn KernelBackend>,
    rng: &mut Rng,
) -> LraResult {
    let n = ds.n;
    let counters = KdeCounters::new();
    let evals_before = backend.kernel_evals();
    let rn = RowNormSampler::build(ds, kernel, cfg, backend.clone(), counters.clone());
    let s = (rows_factor * rank).clamp(1, n);
    // Sample s row indices (with replacement) by squared row norm.
    let mut picks: Vec<(usize, f64)> = Vec::with_capacity(s);
    for _ in 0..s {
        picks.push(rn.sample(rng));
    }
    // Construct the sampled rows explicitly (s x n kernel evaluations)
    // through the fused block primitive, one <= B-row query-batch per
    // planner chunk (see `construct_rows`).
    let (r, peak_block_rows) = construct_rows(ds, kernel, &picks, &backend);
    let v = fkv_factors(&r, rank);
    LraResult {
        rank: v.rows,
        sampled_rows: s,
        peak_block_rows,
        kde_queries: counters.queries(),
        kernel_evals: backend.kernel_evals() - evals_before,
        floats_stored: (s * n) as u64,
        v,
    }
}

/// Materialize the dense kernel matrix (baselines + error evaluation).
/// NOT part of the KDE algorithm's cost.
pub fn materialize_kernel_matrix(ds: &Dataset, kernel: Kernel) -> Mat {
    let n = ds.n;
    let mut k = Mat::zeros(n, n);
    for i in 0..n {
        k[(i, i)] = 1.0;
        for j in (i + 1)..n {
            let v = ds.kernel(kernel, i, j) as f64;
            k[(i, j)] = v;
            k[(j, i)] = v;
        }
    }
    k
}

/// §7 "IS" baseline: CountSketch the rows of K (s buckets), take the top-k
/// right singular directions of the sketch. Requires the full matrix.
pub fn lra_countsketch(kmat: &Mat, rank: usize, sketch_rows: usize, rng: &mut Rng) -> Mat {
    let cs = CountSketch::new(sketch_rows, kmat.rows, rng);
    let sk = cs.sketch(kmat);
    fkv_factors_from_sketch(&sk, rank)
}

fn fkv_factors_from_sketch(sk: &Mat, rank: usize) -> Mat {
    // Same achieved-rank truncation as `fkv_factors`: a degenerate sketch
    // spectrum must not report phantom all-zero factor rows.
    let w = sk.gram_rows();
    let (vals, vecs) = jacobi_eigen(&w, 100);
    let n = sk.cols;
    let cap = rank.min(sk.rows);
    let mut achieved = 0usize;
    while achieved < cap && vals[achieved].max(0.0) > 1e-12 {
        achieved += 1;
    }
    let mut v = Mat::zeros(achieved, n);
    for j in 0..achieved {
        let lam = vals[j].max(0.0);
        let scale = 1.0 / lam.sqrt();
        for i in 0..sk.rows {
            let q = vecs[(i, j)] * scale;
            if q == 0.0 {
                continue;
            }
            let row = sk.row(i);
            let dst = v.row_mut(j);
            for c in 0..n {
                dst[c] += q * row[c];
            }
        }
    }
    v
}

/// §7 "SVD" baseline: block power iteration directly on K (symmetric), so
/// the top-k eigenvectors are the optimal rank-k row space.
pub fn lra_svd(kmat: &Mat, rank: usize, iters: usize, rng: &mut Rng) -> Mat {
    let (_, vecs) = block_power(kmat, rank, iters, rng);
    let mut v = Mat::zeros(vecs.len(), kmat.cols);
    for (j, col) in vecs.iter().enumerate() {
        v.row_mut(j).copy_from_slice(col);
    }
    v
}

/// `||K - K V^T V||_F^2` evaluated exactly against the dense matrix.
pub fn lra_error(kmat: &Mat, v: &Mat) -> f64 {
    // P = K V^T  (n x k), B = P V (n x n) — compute the error without
    // materializing B: ||K - P V||_F^2 = ||K||_F^2 - 2<K, PV> + ||PV||_F^2.
    let p = kmat.matmul(&v.transpose()); // n x k
    // <K, PV> = sum_ij K_ij (PV)_ij = trace(K^T P V) = <K V^T, P>, and
    // K V^T is exactly the `p` already in hand (K symmetric) — so the
    // inner product is ||P||_F^2, with no second O(n^2 k) matmul.
    let inner: f64 = p.data.iter().map(|a| a * a).sum();
    // ||PV||_F^2 = trace(V^T P^T P V) = ||P (V V^T)^{1/2}||... compute via
    // G = V V^T (k x k): ||PV||_F^2 = trace(P^T P G)
    let g = v.gram_rows(); // k x k
    let ptp = p.transpose().matmul(&p); // k x k
    let mut pv_norm = 0.0;
    for i in 0..g.rows {
        for j in 0..g.cols {
            pv_norm += ptp[(i, j)] * g[(j, i)];
        }
    }
    (kmat.frob_norm_sq() - 2.0 * inner + pv_norm).max(0.0)
}

/// Exact best-rank-k error `||K - K_k||_F^2` via full eigendecomposition
/// (K symmetric PSD): sum of squared eigenvalues below the top k.
pub fn optimal_error(kmat: &Mat, rank: usize) -> f64 {
    let (vals, _) = jacobi_eigen(kmat, 100);
    vals.iter().skip(rank).map(|v| v * v).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::dataset::gaussian_mixture;
    use crate::runtime::backend::CpuBackend;

    fn setup(n: usize, seed: u64) -> (Arc<Dataset>, Mat, Rng) {
        let mut rng = Rng::new(seed);
        let ds = Arc::new(gaussian_mixture(n, 4, 3, 2.0, 0.4, &mut rng));
        let kmat = materialize_kernel_matrix(&ds, Kernel::Laplacian);
        (ds, kmat, rng)
    }

    #[test]
    fn lra_error_of_exact_eigenvectors_is_optimal() {
        let (_, kmat, mut rng) = setup(24, 191);
        let rank = 3;
        let v = lra_svd(&kmat, rank, 600, &mut rng);
        let got = lra_error(&kmat, &v);
        let opt = optimal_error(&kmat, rank);
        assert!(
            got <= opt * 1.05 + 1e-9,
            "block-power error {got} vs optimal {opt}"
        );
    }

    #[test]
    fn kde_lra_additive_error_bound() {
        // Corollary 5.14: err <= opt + eps ||K||_F^2 for modest eps.
        let (ds, kmat, mut rng) = setup(48, 193);
        let rank = 4;
        let r = lra_kde(
            &ds,
            Kernel::Laplacian,
            rank,
            12,
            &KdeConfig::exact(),
            CpuBackend::new(),
            &mut rng,
        );
        let err = lra_error(&kmat, &r.v);
        let opt = optimal_error(&kmat, rank);
        let frob = kmat.frob_norm_sq();
        assert!(
            err <= opt + 0.15 * frob,
            "err {err} > opt {opt} + 0.15 * {frob}"
        );
        assert_eq!(r.kde_queries, 48, "n KDE queries (Cor 5.14)");
        assert_eq!(r.sampled_rows, 48.min(12 * rank));
    }

    #[test]
    fn kde_lra_uses_fewer_evals_than_materialization() {
        // With the sampling oracle, algorithm kernel evals are
        // n * sample_size + s * n = o(n^2) once n >> 1/(tau eps^2).
        let mut rng = Rng::new(195);
        let ds = Arc::new(gaussian_mixture(256, 4, 3, 2.0, 0.4, &mut rng));
        let cfg = KdeConfig {
            kind: crate::kde::EstimatorKind::Sampling { eps: 0.5, tau: 0.3 },
            leaf_cutoff: 8,
            seed: 7,
        };
        let be = CpuBackend::new();
        let r = lra_kde(&ds, Kernel::Laplacian, 2, 8, &cfg, be, &mut rng);
        assert!(
            r.kernel_evals < (256 * 256 / 2) as u64,
            "sampled-oracle evals {} should be sub-quadratic (n^2 = {})",
            r.kernel_evals,
            256 * 256
        );
    }

    #[test]
    fn countsketch_baseline_reasonable() {
        let (_, kmat, mut rng) = setup(32, 197);
        let rank = 3;
        let v = lra_countsketch(&kmat, rank, 4 * rank + 8, &mut rng);
        let err = lra_error(&kmat, &v);
        let opt = optimal_error(&kmat, rank);
        let frob = kmat.frob_norm_sq();
        assert!(err <= opt + 0.3 * frob, "IS err {err}, opt {opt}, frob {frob}");
    }

    #[test]
    fn lra_error_matches_legacy_formula_bitwise() {
        // The fix dropped the duplicate `kv = K V^T` matmul; reusing `p`
        // must reproduce the legacy value bit for bit (kv was computed by
        // the identical matmul, so a*b == a*a bitwise).
        let (_, kmat, mut rng) = setup(32, 201);
        for rank in [1usize, 3, 5] {
            let v = lra_svd(&kmat, rank, 300, &mut rng);
            let got = lra_error(&kmat, &v);
            // Legacy formula, second matmul included.
            let p = kmat.matmul(&v.transpose());
            let kv = kmat.matmul(&v.transpose());
            let inner: f64 = kv.data.iter().zip(&p.data).map(|(a, b)| a * b).sum();
            let g = v.gram_rows();
            let ptp = p.transpose().matmul(&p);
            let mut pv_norm = 0.0;
            for i in 0..g.rows {
                for j in 0..g.cols {
                    pv_norm += ptp[(i, j)] * g[(j, i)];
                }
            }
            let legacy = (kmat.frob_norm_sq() - 2.0 * inner + pv_norm).max(0.0);
            assert_eq!(got.to_bits(), legacy.to_bits(), "rank {rank}: {got} vs {legacy}");
        }
    }

    #[test]
    fn chunked_row_construction_matches_monolithic_bitwise() {
        // `construct_rows` replaces the monolithic s x n `block` call with
        // planner-chunked `block_ranged` submissions: bit-identical rows,
        // one dispatch per <= B-row chunk, peak chunk bounded by B.
        let mut rng = Rng::new(203);
        let ds = Arc::new(gaussian_mixture(40, 4, 3, 2.0, 0.4, &mut rng));
        let s = 70usize; // > B = 64 forces two chunks
        let picks: Vec<(usize, f64)> = (0..s)
            .map(|k| ((k * 7) % 40, 0.01 + ((k % 9) as f64) / 10.0))
            .collect();
        let be: Arc<dyn KernelBackend> = CpuBackend::new();
        let calls_before = be.calls();
        let (r, peak) = construct_rows(&ds, Kernel::Laplacian, &picks, &be);
        let chunk_calls = be.calls() - calls_before;
        assert_eq!(chunk_calls, 2, "ceil(70 / 64) planner chunks");
        assert_eq!(peak, 64, "peak chunk is the B = 64 submission cap");
        // Monolithic legacy construction.
        let d = ds.d;
        let mut queries: Vec<f32> = Vec::with_capacity(s * d);
        for &(i, _) in &picks {
            queries.extend_from_slice(ds.point(i));
        }
        let block = be.block(Kernel::Laplacian, &queries, ds.flat(), d);
        for (si, &(_, p)) in picks.iter().enumerate() {
            let scale = 1.0 / (s as f64 * p).sqrt();
            for c in 0..40 {
                let want = block[si * 40 + c] as f64 * scale;
                assert_eq!(
                    r.row(si)[c].to_bits(),
                    want.to_bits(),
                    "row {si} col {c}: chunked {} vs monolithic {want}",
                    r.row(si)[c]
                );
            }
        }
    }

    #[test]
    fn fkv_reports_achieved_rank_on_degenerate_spectrum() {
        // Three rows, one an exact duplicate: the gram matrix has rank 2,
        // so asking for k = 3 must achieve 2 factor rows (not a phantom
        // all-zero third row).
        let mut r = Mat::zeros(3, 5);
        r.row_mut(0).copy_from_slice(&[1.0, 0.5, 0.0, 2.0, -1.0]);
        r.row_mut(1).copy_from_slice(&[0.0, 1.0, 3.0, -0.5, 0.25]);
        let dup: Vec<f64> = r.row(0).to_vec();
        r.row_mut(2).copy_from_slice(&dup);
        let v = fkv_factors(&r, 3);
        assert_eq!(v.rows, 2, "duplicate row must not count toward rank");
    }

    #[test]
    fn lra_kde_reports_achieved_rank_when_s_below_k() {
        // s = clamp(rows_factor * rank, 1, n) = 6 < rank = 8: the
        // requested rank is unreachable and LraResult must say so.
        let mut rng = Rng::new(205);
        let ds = Arc::new(gaussian_mixture(6, 3, 2, 2.0, 0.4, &mut rng));
        let r = lra_kde(
            &ds,
            Kernel::Laplacian,
            8,
            1,
            &KdeConfig::exact(),
            CpuBackend::new(),
            &mut rng,
        );
        assert_eq!(r.sampled_rows, 6);
        assert_eq!(r.rank, r.v.rows, "reported rank is the factor row count");
        assert!(r.rank <= 6, "rank {} cannot exceed sampled rows", r.rank);
        assert!(r.rank >= 1, "a positive-mass kernel yields at least one factor");
        assert!(r.peak_block_rows <= 64 && r.peak_block_rows >= 1);
    }

    #[test]
    fn lra_error_decreases_with_rank() {
        let (ds, kmat, mut rng) = setup(40, 199);
        let mut last = f64::INFINITY;
        for rank in [1usize, 3, 6] {
            let r = lra_kde(
                &ds,
                Kernel::Laplacian,
                rank,
                15,
                &KdeConfig::exact(),
                CpuBackend::new(),
                &mut rng,
            );
            let err = lra_error(&kmat, &r.v);
            assert!(
                err <= last * 1.05 + 1e-9,
                "rank {rank}: error {err} should not exceed previous {last}"
            );
            last = err;
        }
    }
}
