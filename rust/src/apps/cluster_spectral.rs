//! Spectral clustering on the sparsified kernel graph: §6.2 /
//! Theorems 6.12-6.13 and the §7 Nested/Rings experiments.
//!
//! Pipeline: sparsifier (Alg 5.1) -> bottom-k eigenvectors of the
//! normalized Laplacian (block power iteration on `2I - L_norm`, the
//! MM15 role) -> row-normalized spectral embedding -> k-means++ / Lloyd.

use crate::graph::{ShiftedNormLaplacianOp, WGraph};
use crate::linalg::cg::cg;
use crate::linalg::eigen::{mgs, SymOp};
use crate::linalg::mat::Mat;
use crate::util::rng::Rng;

/// `(L_norm + eps I) x` operator for inverse iteration.
struct RegNormLap<'a> {
    shifted: ShiftedNormLaplacianOp<'a>,
    eps: f64,
}

impl SymOp for RegNormLap<'_> {
    fn dim(&self) -> usize {
        self.shifted.dim()
    }
    fn apply(&self, x: &[f64], out: &mut [f64]) {
        // shifted(x) = 2x - L x  =>  L x = 2x - shifted(x)
        self.shifted.apply(x, out);
        for i in 0..x.len() {
            out[i] = (2.0 + self.eps) * x[i] - out[i];
        }
    }
}

/// Bottom-k eigenvectors of the normalized Laplacian of `g` (including the
/// trivial one), as an `n x k` embedding matrix.
///
/// Implementation: inverse subspace iteration on `(L_norm + eps I)` with CG
/// inner solves. Plain (shifted) power iteration stalls here because the
/// bottom of the Laplacian spectrum of near-disconnected cluster graphs is
/// extremely clustered; inversion blows the relevant gaps wide open.
pub fn spectral_embedding(g: &WGraph, k: usize, iters: usize, rng: &mut Rng) -> Mat {
    let n = g.n;
    let k = k.min(n);
    let op = RegNormLap {
        shifted: ShiftedNormLaplacianOp::new(g, 2.0),
        eps: 1e-3,
    };
    let mut q: Vec<Vec<f64>> = (0..(k + 1).min(n))
        .map(|_| (0..n).map(|_| rng.normal()).collect())
        .collect();
    mgs(&mut q);
    let outer = iters.clamp(4, 40);
    for _ in 0..outer {
        for col in q.iter_mut() {
            let res = cg(&op, col, None, false, 1e-8, 400);
            col.copy_from_slice(&res.x);
        }
        mgs(&mut q);
    }
    // Rayleigh-Ritz on L_norm within the subspace; sort ascending.
    let p = q.len();
    let mut buf = vec![0.0; n];
    let mut t = Mat::zeros(p, p);
    for i in 0..p {
        // L q_i = (op - eps I) q_i
        op.apply(&q[i], &mut buf);
        for (b, x) in buf.iter_mut().zip(q[i].iter()) {
            *b -= 1e-3 * x;
        }
        for j in 0..p {
            t[(j, i)] = crate::linalg::dot(&q[j], &buf);
        }
    }
    let (tvals, tvecs) = crate::linalg::jacobi_eigen(&t, 60);
    // jacobi sorts descending; bottom eigenvectors are the LAST k columns.
    let mut order: Vec<usize> = (0..p).collect();
    order.sort_by(|&a, &b| tvals[a].partial_cmp(&tvals[b]).unwrap());
    let n_keep = k;
    let mut emb = Mat::zeros(n, n_keep);
    for (out_col, &c) in order.iter().take(n_keep).enumerate() {
        for i in 0..n {
            let mut v = 0.0;
            for j in 0..p {
                v += tvecs[(j, c)] * q[j][i];
            }
            emb[(i, out_col)] = v;
        }
    }
    emb
}

/// k-means++ initialization followed by Lloyd's iterations on the rows of
/// `points`. Returns cluster labels.
pub fn kmeans(points: &Mat, k: usize, iters: usize, rng: &mut Rng) -> Vec<usize> {
    let n = points.rows;
    let d = points.cols;
    assert!(k >= 1 && n >= k);
    // k-means++ seeding
    let mut centers: Vec<Vec<f64>> = Vec::with_capacity(k);
    centers.push(points.row(rng.below(n)).to_vec());
    let mut dist_sq = vec![f64::INFINITY; n];
    while centers.len() < k {
        let last = centers.last().unwrap();
        for i in 0..n {
            let mut s = 0.0;
            let r = points.row(i);
            for j in 0..d {
                let df = r[j] - last[j];
                s += df * df;
            }
            dist_sq[i] = dist_sq[i].min(s);
        }
        let total: f64 = dist_sq.iter().sum();
        if total <= 0.0 {
            centers.push(points.row(rng.below(n)).to_vec());
            continue;
        }
        let mut target = rng.f64() * total;
        let mut pick = n - 1;
        for i in 0..n {
            target -= dist_sq[i];
            if target <= 0.0 {
                pick = i;
                break;
            }
        }
        centers.push(points.row(pick).to_vec());
    }
    // Lloyd iterations
    let mut labels = vec![0usize; n];
    for _ in 0..iters {
        let mut changed = false;
        for i in 0..n {
            let r = points.row(i);
            let mut best = (f64::INFINITY, 0usize);
            for (c, center) in centers.iter().enumerate() {
                let mut s = 0.0;
                for j in 0..d {
                    let df = r[j] - center[j];
                    s += df * df;
                }
                if s < best.0 {
                    best = (s, c);
                }
            }
            if labels[i] != best.1 {
                labels[i] = best.1;
                changed = true;
            }
        }
        // recompute centers
        let mut sums = vec![vec![0.0f64; d]; k];
        let mut counts = vec![0usize; k];
        for i in 0..n {
            counts[labels[i]] += 1;
            let r = points.row(i);
            for j in 0..d {
                sums[labels[i]][j] += r[j];
            }
        }
        for c in 0..k {
            if counts[c] > 0 {
                for j in 0..d {
                    centers[c][j] = sums[c][j] / counts[c] as f64;
                }
            }
        }
        if !changed {
            break;
        }
    }
    labels
}

/// Full spectral clustering of a (sparse or dense) weighted graph.
pub fn spectral_cluster(g: &WGraph, k: usize, rng: &mut Rng) -> Vec<usize> {
    let mut emb = spectral_embedding(g, k, 400, rng);
    // Row-normalize the embedding (standard Ng-Jordan-Weiss step).
    for i in 0..emb.rows {
        let r = emb.row_mut(i);
        let norm: f64 = r.iter().map(|x| x * x).sum::<f64>().sqrt();
        if norm > 0.0 {
            for x in r.iter_mut() {
                *x /= norm;
            }
        }
    }
    kmeans(&emb, k, 100, rng)
}

/// Permutation-maximized clustering accuracy against ground truth
/// (exhaustive over label permutations; fine for k <= 6).
pub fn clustering_accuracy(labels: &[usize], truth: &[usize], k: usize) -> f64 {
    assert_eq!(labels.len(), truth.len());
    assert!(k <= 6, "permutation search limited to k <= 6");
    let mut perm: Vec<usize> = (0..k).collect();
    let mut best = 0usize;
    permute(&mut perm, 0, &mut |p| {
        let correct = labels
            .iter()
            .zip(truth)
            .filter(|&(&l, &t)| l < k && p[l] == t)
            .count();
        best = best.max(correct);
    });
    best as f64 / labels.len() as f64
}

fn permute(arr: &mut Vec<usize>, i: usize, f: &mut impl FnMut(&[usize])) {
    if i == arr.len() {
        f(arr);
        return;
    }
    for j in i..arr.len() {
        arr.swap(i, j);
        permute(arr, i + 1, f);
        arr.swap(i, j);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::dataset::{nested, rings};
    use crate::kernel::Kernel;

    #[test]
    fn kmeans_separates_obvious_blobs() {
        let mut rng = Rng::new(231);
        let mut pts = Mat::zeros(40, 2);
        for i in 0..40 {
            let c = if i < 20 { 0.0 } else { 10.0 };
            pts[(i, 0)] = c + rng.normal() * 0.1;
            pts[(i, 1)] = c + rng.normal() * 0.1;
        }
        let labels = kmeans(&pts, 2, 50, &mut rng);
        let truth: Vec<usize> = (0..40).map(|i| usize::from(i >= 20)).collect();
        assert_eq!(clustering_accuracy(&labels, &truth, 2), 1.0);
    }

    #[test]
    fn accuracy_is_permutation_invariant() {
        let labels = vec![1, 1, 0, 0];
        let truth = vec![0, 0, 1, 1];
        assert_eq!(clustering_accuracy(&labels, &truth, 2), 1.0);
    }

    #[test]
    fn spectral_clustering_solves_nested_on_full_graph() {
        let mut rng = Rng::new(233);
        let ds = nested(160, &mut rng);
        // Bandwidth: nested needs a scale where same-cluster kernel >>
        // cross-cluster kernel; circle radius 1, use sigma ~ 0.3 => scale 3.
        let scaled = ds.scaled(3.0);
        let g = WGraph::complete_kernel_graph(&scaled, Kernel::Gaussian);
        let labels = spectral_cluster(&g, 2, &mut rng);
        let acc = clustering_accuracy(&labels, ds.labels.as_ref().unwrap(), 2);
        assert!(acc > 0.97, "nested accuracy {acc}");
    }

    #[test]
    fn spectral_clustering_solves_rings_on_full_graph() {
        let mut rng = Rng::new(235);
        let ds = rings(200, &mut rng);
        let scaled = ds.scaled(6.0);
        let g = WGraph::complete_kernel_graph(&scaled, Kernel::Gaussian);
        let labels = spectral_cluster(&g, 2, &mut rng);
        let acc = clustering_accuracy(&labels, ds.labels.as_ref().unwrap(), 2);
        assert!(acc > 0.95, "rings accuracy {acc}");
    }

    #[test]
    fn theorem_6_12_sparsifier_preserves_conductance() {
        // Cut sparsifiers preserve (k, phi_out)-clusterability.
        let mut rng = Rng::new(237);
        let ds = nested(96, &mut rng).scaled(3.0);
        let full = WGraph::complete_kernel_graph(&ds, Kernel::Gaussian);
        let prims = crate::sampling::Primitives::build(
            std::sync::Arc::new(ds.clone()),
            Kernel::Gaussian,
            &crate::kde::KdeConfig::exact(),
            crate::runtime::backend::CpuBackend::new(),
        );
        let sp = crate::apps::sparsify::sparsify(&prims, 25_000, &mut rng);
        // Conductance of the true partition is preserved within ~2x.
        let labels = ds.labels.as_ref().unwrap();
        let in_set: Vec<bool> = labels.iter().map(|&l| l == 0).collect();
        let phi_full = full.conductance(&in_set);
        let phi_sparse = sp.graph.conductance(&in_set);
        assert!(
            phi_sparse < 3.0 * phi_full + 0.05,
            "phi preserved: sparse {phi_sparse} vs full {phi_full}"
        );
    }
}
