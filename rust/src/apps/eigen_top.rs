//! Top eigenvalue / eigenvector approximation: Algorithm 5.18 /
//! Theorem 5.22.
//!
//! Step 1 subsamples a `t x t` principal submatrix (BMR21: eigenvalues are
//! preserved to additive `n/sqrt(t)`, and Lemma 5.19 gives
//! `lambda_1 >= n tau`, so `t = O(1/(eps^2 tau^2))` suffices).
//! Step 2 runs a power method on the sampled submatrix — either the
//! Remark 5.23 direct variant (materialize `K_S`, standard power method)
//! or the BIMW21-style *noisy* variant whose matvec is estimated from KDE
//! degree estimates + weighted neighbor samples, never materializing the
//! matrix.
//!
//! The returned eigenvector is sparse: supported on the `t` sampled
//! coordinates (Remark 5.23).

use std::sync::Arc;

use crate::kde::KdeConfig;
use crate::kernel::{Dataset, Kernel};
use crate::linalg::mat::{dot, normalize, Mat};
use crate::runtime::backend::KernelBackend;
use crate::sampling::Primitives;
use crate::util::rng::Rng;

/// Top-eigenpair estimate plus cost accounting of one Theorem 5.22 run.
pub struct EigenTopResult {
    /// Estimated top eigenvalue of the FULL n x n kernel matrix.
    pub lambda: f64,
    /// Sampled coordinate indices (support of the eigenvector).
    pub support: Vec<usize>,
    /// Eigenvector values on the support (unit norm).
    pub vector: Vec<f64>,
    /// Side length t of the sampled principal submatrix.
    pub submatrix_size: usize,
    /// Logical KDE queries spent (cache misses; zero for the direct
    /// variant, which never touches an oracle).
    pub kde_queries: u64,
}

/// Submatrix size Theorem 5.22 prescribes, with a practical constant.
pub fn theorem_submatrix_size(eps: f64, tau: f64, n: usize) -> usize {
    ((4.0 / (eps * eps * tau * tau)).ceil() as usize).clamp(4, n)
}

/// Remark 5.23 direct variant: materialize the t x t sampled submatrix and
/// run the standard power method. O(t^2 d) kernel work.
pub fn eigen_top_direct(
    ds: &Arc<Dataset>,
    kernel: Kernel,
    t: usize,
    iters: usize,
    rng: &mut Rng,
) -> EigenTopResult {
    let n = ds.n;
    let t = t.min(n);
    let support = rng.sample_indices(n, t);
    let sub = ds.subset(&support);
    let mut kmat = Mat::zeros(t, t);
    for i in 0..t {
        kmat[(i, i)] = 1.0;
        for j in (i + 1)..t {
            let v = sub.kernel(kernel, i, j) as f64;
            kmat[(i, j)] = v;
            kmat[(j, i)] = v;
        }
    }
    // Power method (K is PSD so no shifting needed).
    let mut v: Vec<f64> = (0..t).map(|_| rng.normal()).collect();
    normalize(&mut v);
    let mut lam = 0.0;
    for _ in 0..iters {
        let w = kmat.matvec(&v);
        lam = dot(&v, &w);
        v = w;
        if normalize(&mut v) == 0.0 {
            break;
        }
    }
    EigenTopResult {
        lambda: lam * n as f64 / t as f64, // BMR21 scaling
        support,
        vector: v,
        submatrix_size: t,
        kde_queries: 0,
    }
}

/// BIMW21-style noisy power method on the sampled submatrix: the matvec
/// `(K_S v)_i = v_i + sum_{j != i} k(i,j) v_j` is estimated as
/// `v_i + deg_i * mean_{r}( v_{j_r} )` with `j_r` drawn by weighted
/// neighbor sampling — KDE queries only, the submatrix is never formed.
///
/// The `t * matvec_samples` descents of one iteration are issued as a
/// single `sample_batch` round, so a whole noisy matvec costs O(log t)
/// backend dispatches rather than one per descent.
pub fn eigen_top_noisy(
    ds: &Arc<Dataset>,
    kernel: Kernel,
    t: usize,
    iters: usize,
    matvec_samples: usize,
    cfg: &KdeConfig,
    backend: Arc<dyn KernelBackend>,
    rng: &mut Rng,
) -> EigenTopResult {
    let n = ds.n;
    let t = t.min(n);
    let support = rng.sample_indices(n, t);
    let sub = Arc::new(ds.subset(&support));
    let prims = Primitives::build(sub, kernel, cfg, backend);
    let mut v: Vec<f64> = (0..t).map(|_| rng.normal()).collect();
    normalize(&mut v);
    let mut lam = 0.0;
    // One batched descent round per power iteration: matvec_samples
    // walkers per coordinate, all level-synchronized.
    let mut sources = Vec::with_capacity(t * matvec_samples);
    for i in 0..t {
        sources.extend(std::iter::repeat(i).take(matvec_samples));
    }
    for _ in 0..iters {
        let samples = prims.neighbors.sample_batch(&sources, rng);
        let mut w = vec![0.0; t];
        for i in 0..t {
            let deg = prims.degrees.degrees[i];
            let mut acc = 0.0;
            for s in &samples[i * matvec_samples..(i + 1) * matvec_samples] {
                if let Some(s) = s {
                    acc += v[s.neighbor];
                }
            }
            w[i] = v[i] + deg * acc / matvec_samples as f64;
        }
        lam = dot(&v, &w); // Rayleigh-style estimate with the noisy matvec
        v = w;
        if normalize(&mut v) == 0.0 {
            break;
        }
    }
    EigenTopResult {
        lambda: lam * n as f64 / t as f64,
        support,
        vector: v,
        submatrix_size: t,
        kde_queries: prims.kde_queries(),
    }
}

/// Exact top eigenvalue of the full kernel matrix (baseline, O(n^2 d)).
pub fn exact_top_eigenvalue(ds: &Dataset, kernel: Kernel, rng: &mut Rng) -> f64 {
    let kmat = crate::apps::lra::materialize_kernel_matrix(ds, kernel);
    let (vals, _) = crate::linalg::eigen::block_power(&kmat, 1, 600, rng);
    vals[0]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::dataset::gaussian_mixture;
    use crate::runtime::backend::CpuBackend;

    fn setup(n: usize, seed: u64) -> (Arc<Dataset>, Rng) {
        let mut rng = Rng::new(seed);
        // Tight-ish data: high tau, so lambda_1 ~ n * avg kernel value.
        let ds = Arc::new(gaussian_mixture(n, 3, 1, 0.0, 0.5, &mut rng));
        (ds, rng)
    }

    #[test]
    fn direct_full_sample_matches_exact() {
        let (ds, mut rng) = setup(40, 201);
        let exact = exact_top_eigenvalue(&ds, Kernel::Laplacian, &mut rng);
        let got = eigen_top_direct(&ds, Kernel::Laplacian, 40, 300, &mut rng);
        assert!(
            (got.lambda - exact).abs() < 1e-6 * exact,
            "t=n must be exact: {} vs {exact}",
            got.lambda
        );
    }

    #[test]
    fn direct_subsample_approximates() {
        let (ds, mut rng) = setup(128, 203);
        let exact = exact_top_eigenvalue(&ds, Kernel::Laplacian, &mut rng);
        let got = eigen_top_direct(&ds, Kernel::Laplacian, 48, 300, &mut rng);
        let rel = (got.lambda - exact).abs() / exact;
        assert!(rel < 0.2, "rel err {rel} (λ {}, exact {exact})", got.lambda);
        assert_eq!(got.support.len(), 48);
    }

    #[test]
    fn noisy_variant_approximates() {
        let (ds, mut rng) = setup(128, 205);
        let exact = exact_top_eigenvalue(&ds, Kernel::Laplacian, &mut rng);
        let got = eigen_top_noisy(
            &ds,
            Kernel::Laplacian,
            48,
            30,
            24,
            &KdeConfig::exact(),
            CpuBackend::new(),
            &mut rng,
        );
        let rel = (got.lambda - exact).abs() / exact;
        assert!(rel < 0.3, "rel err {rel} (λ {}, exact {exact})", got.lambda);
        assert!(got.kde_queries > 0, "noisy variant must use KDE queries");
    }

    #[test]
    fn lower_bound_lemma_5_19() {
        // lambda_1 >= n * tau when every row sums to >= n tau.
        let (ds, mut rng) = setup(64, 207);
        let tau = ds.tau(Kernel::Laplacian);
        let exact = exact_top_eigenvalue(&ds, Kernel::Laplacian, &mut rng);
        assert!(
            exact >= 64.0 * tau * 0.999,
            "λ1 {exact} < n*tau {}",
            64.0 * tau
        );
    }

    #[test]
    fn submatrix_size_formula() {
        assert_eq!(theorem_submatrix_size(1.0, 1.0, 1000), 4);
        assert!(theorem_submatrix_size(0.1, 0.5, 10_000) > 100);
        assert_eq!(theorem_submatrix_size(0.001, 0.001, 50), 50, "clamped to n");
    }
}
