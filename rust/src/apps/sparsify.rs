//! Spectral sparsification of the kernel graph: Algorithm 5.1 /
//! Theorem 5.3.
//!
//! Sample `t` edges by (approximate) squared-row-norm of the edge-vertex
//! incidence matrix H — realized as degree-sampling + neighbor-sampling —
//! and reweight each sampled edge so that `E[L_{G'}] = L_G`:
//!
//! ```text
//! q_e  = p_u q_uv + p_v q_vu          (two-sided sampling prob)
//! w_e  = k(u, v) / (t * q_e)
//! ```
//!
//! Note on the paper's Algorithm 5.1 step (d): as printed it sets
//! `w_uv = 1/(t q_e)`, which drops the `k(u,v)` factor required for
//! unbiasedness (row `h_e` of H contributes `k_e b_e b_e^T`, and the
//! importance-sampled term must be `k_e b_e b_e^T/(t q_e)`). We implement
//! the unbiased version; `sparsifier_is_unbiased` below verifies
//! `E[L'] ~ L` empirically. See DESIGN.md §3.

use crate::graph::WGraph;
use crate::kernel::{Dataset, Kernel};
use crate::sampling::Primitives;
use crate::util::rng::Rng;

/// Result of one sparsification run with full cost accounting.
pub struct SparsifyResult {
    /// The reweighted sparsifier `G'` with `E[L_{G'}] = L_G`.
    pub graph: WGraph,
    /// Edges sampled (with multiplicity) = `t`.
    pub samples: usize,
    /// Distinct edges in the sparsifier.
    pub distinct_edges: usize,
    /// Logical KDE queries spent (cache misses).
    pub kde_queries: u64,
    /// Explicit kernel evaluations spent on edge weights.
    pub kernel_evals: u64,
}

/// Number of samples Theorem 5.3 prescribes: `O(n log n / (eps^2 tau^3))`,
/// with the constant tamed for practical sizes (the paper's experiments
/// likewise pick a target edge budget directly).
pub fn theorem_sample_count(n: usize, eps: f64, tau: f64) -> usize {
    let t = (n as f64) * (n as f64).ln() / (eps * eps * tau.powi(3));
    (t.ceil() as usize).max(n)
}

/// Algorithm 5.1 over prebuilt primitives with **batched, level-fused**
/// KDE traffic: all `t` degree draws happen first, the `t` neighbor
/// descents run in level-order lock-step (`NeighborSampler::sample_batch`),
/// and the `t` reverse probabilities are resolved by one batched probe.
/// Each level's cache misses are coalesced across tree nodes into fused
/// backend submissions (`MultiLevelKde::query_points_multi`), so a whole
/// round issues O(log n) backend dispatches total — not O(t log n)
/// singleton calls, and not one dispatch per tree node touched (pinned by
/// `tests/fusion.rs`). The edge distribution and importance weights are
/// the same as [`sparsify`]'s (each walker owns a forked RNG stream; the
/// memoized oracle answers are shared), only the evaluation shape changes.
pub fn sparsify_batched(prims: &Primitives, t: usize, rng: &mut Rng) -> SparsifyResult {
    let ds = &prims.tree.ds;
    let kernel = prims.tree.kernel;
    let queries_before = prims.counters.queries();
    // (a) degree-sample all sources up front.
    let mut sources = Vec::with_capacity(t);
    let mut p_u = Vec::with_capacity(t);
    for _ in 0..t {
        let (u, p) = prims.degrees.sample(rng);
        sources.push(u);
        p_u.push(p);
    }
    // (b) all neighbor descents in one batched round.
    let samples = prims.neighbors.sample_batch(&sources, rng);
    // (c) reverse descent probabilities q_{vu}, batched.
    let mut pairs = Vec::new();
    let mut keep = Vec::new();
    for (idx, s) in samples.iter().enumerate() {
        if let Some(s) = s {
            pairs.push((s.neighbor, sources[idx]));
            keep.push(idx);
        }
    }
    let q_vu = prims.neighbors.neighbor_prob_batch(&pairs);
    // (d) exact weights, identical to the per-query path.
    let mut raw_edges: Vec<(usize, usize, f64)> = Vec::with_capacity(keep.len());
    let mut kernel_evals = 0u64;
    for (ki, &idx) in keep.iter().enumerate() {
        let u = sources[idx];
        let s = samples[idx].expect("kept samples are Some");
        let v = s.neighbor;
        let k_uv = kernel.eval(ds.point(u), ds.point(v)) as f64;
        kernel_evals += 1;
        let prob = p_u[idx] * s.prob + prims.degrees.prob(v) * q_vu[ki];
        if prob <= 0.0 {
            continue;
        }
        raw_edges.push((u, v, k_uv / (t as f64 * prob)));
    }
    let graph = WGraph::from_edges(ds.n, raw_edges);
    SparsifyResult {
        distinct_edges: graph.num_edges(),
        graph,
        samples: t,
        kde_queries: prims.counters.queries() - queries_before,
        kernel_evals,
    }
}

/// Algorithm 5.1 over prebuilt primitives. `t` = number of edge samples.
pub fn sparsify(
    prims: &Primitives,
    t: usize,
    rng: &mut Rng,
) -> SparsifyResult {
    let ds = &prims.tree.ds;
    let kernel = prims.tree.kernel;
    let queries_before = prims.counters.queries();
    let mut raw_edges: Vec<(usize, usize, f64)> = Vec::with_capacity(t);
    let mut kernel_evals = 0u64;
    for _ in 0..t {
        let Some(e) = prims.edges.sample(rng) else { continue };
        // Exact edge weight: one kernel evaluation (O(d)).
        let k_uv = kernel.eval(ds.point(e.u), ds.point(e.v)) as f64;
        kernel_evals += 1;
        if e.prob <= 0.0 {
            continue;
        }
        let w = k_uv / (t as f64 * e.prob);
        raw_edges.push((e.u, e.v, w));
    }
    let graph = WGraph::from_edges(ds.n, raw_edges);
    SparsifyResult {
        distinct_edges: graph.num_edges(),
        graph,
        samples: t,
        kde_queries: prims.counters.queries() - queries_before,
        kernel_evals,
    }
}

/// Measured spectral approximation quality of `G'` against the exact
/// kernel graph: `max |x^T L' x / x^T L x - 1|` over random probe vectors
/// plus extremal eigen-directions. (Exact oracle: O(n^2) — used by tests
/// and benches, not by the algorithm.)
pub fn spectral_error(
    ds: &Dataset,
    kernel: Kernel,
    sparsifier: &WGraph,
    probes: usize,
    rng: &mut Rng,
) -> f64 {
    let full = WGraph::complete_kernel_graph(ds, kernel);
    let n = ds.n;
    let mut worst = 0.0f64;
    for _ in 0..probes {
        let mut x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        // remove the ones-component (null space of both Laplacians)
        let mean = x.iter().sum::<f64>() / n as f64;
        for v in x.iter_mut() {
            *v -= mean;
        }
        let denom = full.laplacian_quadratic(&x);
        if denom <= 0.0 {
            continue;
        }
        let ratio = sparsifier.laplacian_quadratic(&x) / denom;
        worst = worst.max((ratio - 1.0).abs());
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kde::{KdeConfig, EstimatorKind};
    use std::sync::Arc;
    use crate::kernel::dataset::gaussian_mixture;
    use crate::runtime::backend::CpuBackend;

    fn prims(n: usize, seed: u64, cfg: KdeConfig) -> Primitives {
        let mut rng = Rng::new(seed);
        let ds = Arc::new(gaussian_mixture(n, 3, 2, 0.8, 0.5, &mut rng));
        Primitives::build(ds, Kernel::Laplacian, &cfg, CpuBackend::new())
    }

    #[test]
    fn sparsifier_is_unbiased() {
        // Average many small sparsifiers; the mean Laplacian quadratic form
        // must approach the exact one (this is the test that catches the
        // paper's Alg 5.1 step-(d) typo).
        let p = prims(24, 161, KdeConfig::exact());
        let ds = &p.tree.ds;
        let full = WGraph::complete_kernel_graph(ds, Kernel::Laplacian);
        let mut rng = Rng::new(163);
        let x: Vec<f64> = (0..24).map(|_| rng.normal()).collect();
        let want = full.laplacian_quadratic(&x);
        let runs = 60;
        let mut acc = 0.0;
        for _ in 0..runs {
            let r = sparsify(&p, 400, &mut rng);
            acc += r.graph.laplacian_quadratic(&x);
        }
        let mean = acc / runs as f64;
        assert!(
            (mean - want).abs() < 0.08 * want,
            "E[x'L'x] = {mean} vs x'Lx = {want}"
        );
    }

    #[test]
    fn sparsifier_approximates_spectrally() {
        let p = prims(48, 165, KdeConfig::exact());
        let mut rng = Rng::new(167);
        let r = sparsify(&p, 6_000, &mut rng);
        let err = spectral_error(&p.tree.ds, Kernel::Laplacian, &r.graph, 20, &mut rng);
        assert!(err < 0.35, "spectral error {err}");
        assert!(r.distinct_edges < 48 * 47 / 2, "must be sparser than complete");
    }

    #[test]
    fn sparsifier_with_sampling_oracle_still_works() {
        let cfg = KdeConfig {
            kind: EstimatorKind::Sampling { eps: 0.3, tau: 0.2 },
            leaf_cutoff: 8,
            seed: 0xEF,
        };
        let p = prims(48, 169, cfg);
        let mut rng = Rng::new(171);
        let r = sparsify(&p, 6_000, &mut rng);
        let err = spectral_error(&p.tree.ds, Kernel::Laplacian, &r.graph, 20, &mut rng);
        // Sampling oracle only changes the proposal distribution; the
        // importance weights keep the estimator consistent.
        assert!(err < 0.5, "spectral error {err} with sampling oracle");
    }

    #[test]
    fn query_accounting_scales_with_t() {
        let p = prims(32, 173, KdeConfig::exact());
        let mut rng = Rng::new(175);
        let r1 = sparsify(&p, 100, &mut rng);
        // Tree is warm now; marginal queries per extra sample are bounded
        // by 2 log n (sample descent) + log n (reverse prob).
        let r2 = sparsify(&p, 200, &mut rng);
        assert!(r1.kde_queries > 0);
        // After cache warmup, additional runs reuse answers: r2 should not
        // explode. (3 log2(32) = 15 queries/sample worst case.)
        assert!(
            r2.kde_queries <= 200 * 15,
            "queries {} exceed per-sample bound",
            r2.kde_queries
        );
        assert_eq!(r2.samples, 200);
        assert_eq!(r2.kernel_evals, 200);
    }

    #[test]
    fn theorem_count_monotone() {
        assert!(theorem_sample_count(100, 0.5, 0.1) < theorem_sample_count(100, 0.5, 0.05));
        assert!(theorem_sample_count(100, 0.5, 0.1) < theorem_sample_count(100, 0.25, 0.1));
        assert!(theorem_sample_count(100, 0.5, 0.1) >= 100);
    }
}
