//! Second-stage resparsification (§5.1, final step): reduce the Alg 5.1
//! sparsifier from O(n log n / (eps^2 tau^3)) edges to O(n log n / eps^2)
//! edges by *effective-resistance* sampling on the already-sparse graph.
//!
//! The paper invokes Lee-Sun [LS18] here; we implement the classical
//! Spielman-Srivastava scheme (the same contract, simpler machinery —
//! DESIGN.md §3): approximate all effective resistances at once via
//! Johnson-Lindenstrauss sketches of `W^{1/2} B L^+`, each sketch row
//! obtained from one Laplacian CG solve, then sample edges proportional
//! to `w_e * R_e` (their leverage scores).

use crate::graph::{LaplacianOp, WGraph};
use crate::linalg::cg::cg;
use crate::sampling::vertex::PrefixSampler;
use crate::util::rng::Rng;

/// Approximate effective resistances of every edge of `g` via `k`
/// JL projections (k ~ O(log n) for (1±eps) estimates w.h.p.).
pub fn effective_resistances(g: &WGraph, k: usize, rng: &mut Rng) -> Vec<f64> {
    let n = g.n;
    let m = g.edges.len();
    // Z has k rows; row i = L^+ (B^T W^{1/2} q_i) with q_i in {±1/sqrt(k)}^m.
    let mut z_rows: Vec<Vec<f64>> = Vec::with_capacity(k);
    let scale = 1.0 / (k as f64).sqrt();
    for _ in 0..k {
        // y = B^T W^{1/2} q: accumulate ±sqrt(w_e)/sqrt(k) at the endpoints.
        let mut y = vec![0.0f64; n];
        for &(u, v, w) in &g.edges {
            let s = if rng.bernoulli(0.5) { scale } else { -scale } * w.sqrt();
            y[u as usize] += s;
            y[v as usize] -= s;
        }
        // project out the ones component (consistency) and solve L z = y.
        let mean = y.iter().sum::<f64>() / n as f64;
        for t in y.iter_mut() {
            *t -= mean;
        }
        let diag = g.degrees();
        let res = cg(&LaplacianOp(g), &y, Some(&diag), true, 1e-8, 4 * n);
        z_rows.push(res.x);
    }
    // R_e ~ sum_i (z_i[u] - z_i[v])^2
    let mut r = Vec::with_capacity(m);
    for &(u, v, _) in &g.edges {
        let mut acc = 0.0;
        for zi in &z_rows {
            let d = zi[u as usize] - zi[v as usize];
            acc += d * d;
        }
        r.push(acc);
    }
    r
}

/// Exact effective resistance between two nodes (single CG solve; test
/// oracle).
pub fn exact_effective_resistance(g: &WGraph, u: usize, v: usize) -> f64 {
    let n = g.n;
    let mut b = vec![0.0f64; n];
    b[u] = 1.0;
    b[v] -= 1.0;
    let diag = g.degrees();
    let res = cg(&LaplacianOp(g), &b, Some(&diag), true, 1e-10, 8 * n);
    res.x[u] - res.x[v]
}

/// Spielman-Srivastava resparsification: sample `t` edges proportional to
/// `w_e R_e`, reweighted `w_e / (t p_e)`.
pub fn resparsify(g: &WGraph, t: usize, jl_dims: usize, rng: &mut Rng) -> WGraph {
    if g.edges.is_empty() {
        return g.clone();
    }
    let r = effective_resistances(g, jl_dims, rng);
    let scores: Vec<f64> = g
        .edges
        .iter()
        .zip(&r)
        .map(|(&(_, _, w), &re)| (w * re).max(1e-15))
        .collect();
    let sampler = PrefixSampler::new(&scores);
    let mut raw = Vec::with_capacity(t);
    for _ in 0..t {
        let e = sampler.sample(rng);
        let p = sampler.prob(e);
        let (u, v, w) = g.edges[e];
        raw.push((u as usize, v as usize, w / (t as f64 * p)));
    }
    WGraph::from_edges(g.n, raw)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path_graph(n: usize) -> WGraph {
        WGraph::from_edges(n, (0..n - 1).map(|i| (i, i + 1, 1.0)))
    }

    #[test]
    fn exact_resistance_on_path() {
        // Unit path: R(0, k) = k.
        let g = path_graph(6);
        for k in 1..6 {
            let r = exact_effective_resistance(&g, 0, k);
            assert!((r - k as f64).abs() < 1e-6, "R(0,{k}) = {r}");
        }
    }

    #[test]
    fn exact_resistance_parallel_edges() {
        // Two nodes joined by weight-2 edge: R = 1/2.
        let g = WGraph::from_edges(2, vec![(0, 1, 2.0)]);
        let r = exact_effective_resistance(&g, 0, 1);
        assert!((r - 0.5).abs() < 1e-8, "R = {r}");
    }

    #[test]
    fn jl_resistances_match_exact() {
        let mut rng = Rng::new(1201);
        // Random-ish connected graph.
        let mut edges = vec![];
        for i in 0..19usize {
            edges.push((i, i + 1, 0.5 + rng.f64()));
        }
        for _ in 0..30 {
            let u = rng.below(20);
            let v = rng.below(20);
            if u != v {
                edges.push((u, v, 0.2 + rng.f64()));
            }
        }
        let g = WGraph::from_edges(20, edges);
        let approx = effective_resistances(&g, 60, &mut rng);
        for (idx, &(u, v, _)) in g.edges.iter().enumerate() {
            let want = exact_effective_resistance(&g, u as usize, v as usize);
            let got = approx[idx];
            assert!(
                (got - want).abs() < 0.45 * want + 1e-6,
                "edge ({u},{v}): JL {got} vs exact {want}"
            );
        }
    }

    #[test]
    fn resparsify_preserves_quadratic_forms() {
        let mut rng = Rng::new(1203);
        // Dense-ish weighted graph -> resparsify to ~40% of edges.
        let mut edges = vec![];
        let n = 48usize;
        for u in 0..n {
            for v in (u + 1)..n {
                if rng.bernoulli(0.5) {
                    edges.push((u, v, 0.3 + rng.f64()));
                }
            }
        }
        let g = WGraph::from_edges(n, edges);
        let m0 = g.num_edges();
        let h = resparsify(&g, 4 * n * (n as f64).ln() as usize / 2, 24, &mut rng);
        // spot-check Laplacian quadratic forms
        let mut worst = 0.0f64;
        for _ in 0..15 {
            let mut x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let mean = x.iter().sum::<f64>() / n as f64;
            for v in x.iter_mut() {
                *v -= mean;
            }
            let a = g.laplacian_quadratic(&x);
            let b = h.laplacian_quadratic(&x);
            worst = worst.max((b / a - 1.0).abs());
        }
        assert!(worst < 0.5, "resparsified quadratic-form error {worst}");
        assert!(h.num_edges() <= m0, "must not densify");
    }

    #[test]
    fn two_stage_pipeline_from_kernel_graph() {
        // Alg 5.1 sparsifier -> SS resparsifier, checking the §5.1 claim
        // that the second stage reduces edges further at small extra error.
        let mut rng = Rng::new(1205);
        let ds = std::sync::Arc::new(crate::kernel::dataset::gaussian_mixture(
            40, 3, 2, 0.8, 0.5, &mut rng,
        ));
        let prims = crate::sampling::Primitives::build(
            ds.clone(),
            crate::kernel::Kernel::Laplacian,
            &crate::kde::KdeConfig::exact(),
            crate::runtime::backend::CpuBackend::new(),
        );
        let stage1 = crate::apps::sparsify::sparsify(&prims, 8_000, &mut rng);
        let stage2 = resparsify(&stage1.graph, 1_200, 24, &mut rng);
        assert!(stage2.num_edges() < stage1.graph.num_edges());
        let err = crate::apps::sparsify::spectral_error(
            &ds,
            crate::kernel::Kernel::Laplacian,
            &stage2,
            15,
            &mut rng,
        );
        assert!(err < 0.6, "two-stage spectral error {err}");
    }
}
