//! Second-stage resparsification (§5.1, final step): reduce the Alg 5.1
//! sparsifier from O(n log n / (eps^2 tau^3)) edges to O(n log n / eps^2)
//! edges by *effective-resistance* sampling on the already-sparse graph.
//!
//! The paper invokes Lee-Sun [LS18] here; we implement the classical
//! Spielman-Srivastava scheme (the same contract, simpler machinery —
//! DESIGN.md §3): approximate all effective resistances at once via
//! Johnson-Lindenstrauss sketches of `W^{1/2} B L^+`, each sketch row
//! obtained from one Laplacian CG solve, then sample edges proportional
//! to `w_e * R_e` (their leverage scores).

use std::sync::Arc;

use crate::graph::{LaplacianOp, WGraph};
use crate::kernel::{Dataset, Kernel};
use crate::linalg::cg::cg;
use crate::sampling::vertex::PrefixSampler;
use crate::util::rng::Rng;

/// Approximate effective resistances of every edge of `g` via `k`
/// JL projections (k ~ O(log n) for (1±eps) estimates w.h.p.).
pub fn effective_resistances(g: &WGraph, k: usize, rng: &mut Rng) -> Vec<f64> {
    let n = g.n;
    let m = g.edges.len();
    // Z has k rows; row i = L^+ (B^T W^{1/2} q_i) with q_i in {±1/sqrt(k)}^m.
    let mut z_rows: Vec<Vec<f64>> = Vec::with_capacity(k);
    let scale = 1.0 / (k as f64).sqrt();
    for _ in 0..k {
        // y = B^T W^{1/2} q: accumulate ±sqrt(w_e)/sqrt(k) at the endpoints.
        let mut y = vec![0.0f64; n];
        for &(u, v, w) in &g.edges {
            let s = if rng.bernoulli(0.5) { scale } else { -scale } * w.sqrt();
            y[u as usize] += s;
            y[v as usize] -= s;
        }
        // project out the ones component (consistency) and solve L z = y.
        let mean = y.iter().sum::<f64>() / n as f64;
        for t in y.iter_mut() {
            *t -= mean;
        }
        let diag = g.degrees();
        let res = cg(&LaplacianOp(g), &y, Some(&diag), true, 1e-8, 4 * n);
        z_rows.push(res.x);
    }
    // R_e ~ sum_i (z_i[u] - z_i[v])^2
    let mut r = Vec::with_capacity(m);
    for &(u, v, _) in &g.edges {
        let mut acc = 0.0;
        for zi in &z_rows {
            let d = zi[u as usize] - zi[v as usize];
            acc += d * d;
        }
        r.push(acc);
    }
    r
}

/// Exact effective resistance between two nodes (single CG solve; test
/// oracle).
pub fn exact_effective_resistance(g: &WGraph, u: usize, v: usize) -> f64 {
    let n = g.n;
    let mut b = vec![0.0f64; n];
    b[u] = 1.0;
    b[v] -= 1.0;
    let diag = g.degrees();
    let res = cg(&LaplacianOp(g), &b, Some(&diag), true, 1e-10, 8 * n);
    res.x[u] - res.x[v]
}

/// Spielman-Srivastava resparsification: sample `t` edges proportional to
/// `w_e R_e`, reweighted `w_e / (t p_e)`.
pub fn resparsify(g: &WGraph, t: usize, jl_dims: usize, rng: &mut Rng) -> WGraph {
    if g.edges.is_empty() {
        return g.clone();
    }
    let r = effective_resistances(g, jl_dims, rng);
    let scores: Vec<f64> = g
        .edges
        .iter()
        .zip(&r)
        .map(|(&(_, _, w), &re)| (w * re).max(1e-15))
        .collect();
    let sampler = PrefixSampler::new(&scores);
    let mut raw = Vec::with_capacity(t);
    for _ in 0..t {
        let e = sampler.sample(rng);
        let p = sampler.prob(e);
        let (u, v, w) = g.edges[e];
        raw.push((u as usize, v as usize, w / (t as f64 * p)));
    }
    WGraph::from_edges(g.n, raw)
}

/// One event of a dynamic point stream consumed by
/// [`MaintainedSparsifier::apply`]. Indices name fixed slots of the
/// underlying dataset; the event stream toggles their liveness.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PointEvent {
    /// The named slot becomes live (no-op if it already is).
    Insert(usize),
    /// The named slot becomes dead (no-op if it already is).
    Delete(usize),
}

/// Tuning knobs for [`MaintainedSparsifier`].
#[derive(Clone, Copy, Debug)]
pub struct MaintainedConfig {
    /// Uniform attachment degree: how many live neighbors each live point
    /// samples when it (re)enters the graph.
    pub degree: usize,
    /// Run the periodic cleanup/resparsify pass every this many events.
    pub resparsify_every: usize,
    /// Resparsify (effective-resistance resample) whenever the live edge
    /// count exceeds this after a cleanup pass.
    pub target_edges: usize,
    /// JL sketch dimensions handed to [`resparsify`].
    pub jl_dims: usize,
    /// Seed for the per-point attachment streams and the resparsify RNG.
    pub seed: u64,
}

impl Default for MaintainedConfig {
    fn default() -> Self {
        MaintainedConfig {
            degree: 4,
            resparsify_every: 256,
            target_edges: 1 << 16,
            jl_dims: 8,
            seed: 0x5EED_600D,
        }
    }
}

/// Incrementally maintained kernel-graph sparsifier over a dynamic point
/// set (the dynamic counterpart of the two-stage §5.1 pipeline).
///
/// The dataset's slots are fixed; a seeded [`PointEvent`] stream toggles
/// their liveness. Each live point `u` contributes `degree` uniformly
/// sampled edges to other live points, weighted
/// `k(u, v) * (live - 1) / degree` — an unbiased estimate of `u`'s kernel
/// row mass. Edge sampling for `u` uses a **per-point RNG stream**
/// (`seed ^ hash(u)`), so a point's attachment depends only on its own
/// slot and the live set at attachment time, never on how many events
/// other points generated. Deletions are lazy (dead endpoints are
/// filtered, not eagerly removed); every `resparsify_every` events a
/// cleanup pass drops dead edges and — when the live edge count exceeds
/// `target_edges` — resamples by effective resistance through
/// [`resparsify`], restoring the edge budget at bounded spectral cost.
///
/// `tests/dynamic.rs` pins the acceptance contract: after a long seeded
/// event script, the maintained graph's Laplacian quadratic forms match a
/// from-scratch build over the same final live set within the repo's
/// resparsify margins.
pub struct MaintainedSparsifier {
    ds: Arc<Dataset>,
    kernel: Kernel,
    cfg: MaintainedConfig,
    live: Vec<bool>,
    live_count: usize,
    edges: Vec<(u32, u32, f64)>,
    events: u64,
    resparsify_runs: u64,
    rng: Rng,
}

impl MaintainedSparsifier {
    /// Build over `ds` with slots `initial_live` live, attaching each
    /// live point through its own seeded stream.
    pub fn new(
        ds: Arc<Dataset>,
        kernel: Kernel,
        initial_live: &[usize],
        cfg: MaintainedConfig,
    ) -> Self {
        let mut live = vec![false; ds.n];
        let mut live_count = 0usize;
        for &u in initial_live {
            assert!(u < ds.n, "initial live slot {u} out of range (n = {})", ds.n);
            if !live[u] {
                live[u] = true;
                live_count += 1;
            }
        }
        let mut s = MaintainedSparsifier {
            ds,
            kernel,
            rng: Rng::new(cfg.seed ^ 0xD15C_0B91),
            cfg,
            live,
            live_count,
            edges: Vec::new(),
            events: 0,
            resparsify_runs: 0,
        };
        // Flags first, then attach: every initial point samples neighbors
        // from the full initial live set, independent of slot order.
        for u in 0..s.ds.n {
            if s.live[u] {
                s.attach(u);
            }
        }
        s
    }

    /// The per-point attachment stream for slot `u` (see the type docs).
    fn point_stream(&self, u: usize) -> Rng {
        Rng::new(self.cfg.seed ^ (u as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Sample `degree` live neighbors of `u` and push the weighted edges.
    fn attach(&mut self, u: usize) {
        let others = self.live_count.saturating_sub(1);
        if others == 0 {
            return;
        }
        let mut stream = self.point_stream(u);
        let deg = self.cfg.degree.min(others);
        let scale = others as f64 / deg as f64;
        for _ in 0..deg {
            // Rejection over slots: cheap because live points dominate
            // whenever the structure is worth maintaining.
            let v = loop {
                let c = stream.below(self.ds.n);
                if c != u && self.live[c] {
                    break c;
                }
            };
            let w = self.kernel.eval(self.ds.point(u), self.ds.point(v)) as f64 * scale;
            if w > 0.0 {
                self.edges.push((u as u32, v as u32, w));
            }
        }
    }

    /// Apply one event; returns whether it changed the live set.
    pub fn apply(&mut self, ev: PointEvent) -> bool {
        self.events += 1;
        let changed = match ev {
            PointEvent::Insert(u) => {
                assert!(u < self.ds.n, "insert slot {u} out of range");
                if self.live[u] {
                    false
                } else {
                    self.live[u] = true;
                    self.live_count += 1;
                    self.attach(u);
                    true
                }
            }
            PointEvent::Delete(u) => {
                assert!(u < self.ds.n, "delete slot {u} out of range");
                if self.live[u] {
                    self.live[u] = false;
                    self.live_count -= 1;
                    true
                } else {
                    false
                }
            }
        };
        if self.cfg.resparsify_every > 0 && self.events % self.cfg.resparsify_every as u64 == 0 {
            self.cleanup();
        }
        changed
    }

    /// Drop dead-endpoint edges; resparsify if still over budget.
    fn cleanup(&mut self) {
        self.edges
            .retain(|&(u, v, _)| self.live[u as usize] && self.live[v as usize]);
        if self.edges.len() > self.cfg.target_edges && self.live_count >= 2 {
            let g = WGraph::from_edges(
                self.ds.n,
                self.edges
                    .iter()
                    .map(|&(u, v, w)| (u as usize, v as usize, w)),
            );
            let h = resparsify(&g, self.cfg.target_edges, self.cfg.jl_dims, &mut self.rng);
            self.edges = h.edges.clone();
            self.resparsify_runs += 1;
        }
    }

    /// Current sparsifier as a graph over the dataset's slot space (dead
    /// endpoints filtered; parallel samples merged by `WGraph`).
    pub fn graph(&self) -> WGraph {
        WGraph::from_edges(
            self.ds.n,
            self.edges
                .iter()
                .filter(|&&(u, v, _)| self.live[u as usize] && self.live[v as usize])
                .map(|&(u, v, w)| (u as usize, v as usize, w)),
        )
    }

    /// Number of live slots.
    pub fn live_len(&self) -> usize {
        self.live_count
    }

    /// Whether slot `u` is currently live.
    pub fn is_live(&self, u: usize) -> bool {
        self.live[u]
    }

    /// Live slot indices, ascending (the from-scratch comparator's input).
    pub fn live_slots(&self) -> Vec<usize> {
        (0..self.ds.n).filter(|&u| self.live[u]).collect()
    }

    /// `(events applied, resparsify passes run)`.
    pub fn stats(&self) -> (u64, u64) {
        (self.events, self.resparsify_runs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path_graph(n: usize) -> WGraph {
        WGraph::from_edges(n, (0..n - 1).map(|i| (i, i + 1, 1.0)))
    }

    #[test]
    fn exact_resistance_on_path() {
        // Unit path: R(0, k) = k.
        let g = path_graph(6);
        for k in 1..6 {
            let r = exact_effective_resistance(&g, 0, k);
            assert!((r - k as f64).abs() < 1e-6, "R(0,{k}) = {r}");
        }
    }

    #[test]
    fn exact_resistance_parallel_edges() {
        // Two nodes joined by weight-2 edge: R = 1/2.
        let g = WGraph::from_edges(2, vec![(0, 1, 2.0)]);
        let r = exact_effective_resistance(&g, 0, 1);
        assert!((r - 0.5).abs() < 1e-8, "R = {r}");
    }

    #[test]
    fn jl_resistances_match_exact() {
        let mut rng = Rng::new(1201);
        // Random-ish connected graph.
        let mut edges = vec![];
        for i in 0..19usize {
            edges.push((i, i + 1, 0.5 + rng.f64()));
        }
        for _ in 0..30 {
            let u = rng.below(20);
            let v = rng.below(20);
            if u != v {
                edges.push((u, v, 0.2 + rng.f64()));
            }
        }
        let g = WGraph::from_edges(20, edges);
        let approx = effective_resistances(&g, 60, &mut rng);
        for (idx, &(u, v, _)) in g.edges.iter().enumerate() {
            let want = exact_effective_resistance(&g, u as usize, v as usize);
            let got = approx[idx];
            assert!(
                (got - want).abs() < 0.45 * want + 1e-6,
                "edge ({u},{v}): JL {got} vs exact {want}"
            );
        }
    }

    #[test]
    fn resparsify_preserves_quadratic_forms() {
        let mut rng = Rng::new(1203);
        // Dense-ish weighted graph -> resparsify to ~40% of edges.
        let mut edges = vec![];
        let n = 48usize;
        for u in 0..n {
            for v in (u + 1)..n {
                if rng.bernoulli(0.5) {
                    edges.push((u, v, 0.3 + rng.f64()));
                }
            }
        }
        let g = WGraph::from_edges(n, edges);
        let m0 = g.num_edges();
        let h = resparsify(&g, 4 * n * (n as f64).ln() as usize / 2, 24, &mut rng);
        // spot-check Laplacian quadratic forms
        let mut worst = 0.0f64;
        for _ in 0..15 {
            let mut x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let mean = x.iter().sum::<f64>() / n as f64;
            for v in x.iter_mut() {
                *v -= mean;
            }
            let a = g.laplacian_quadratic(&x);
            let b = h.laplacian_quadratic(&x);
            worst = worst.max((b / a - 1.0).abs());
        }
        assert!(worst < 0.5, "resparsified quadratic-form error {worst}");
        assert!(h.num_edges() <= m0, "must not densify");
    }

    #[test]
    fn maintained_sparsifier_tracks_the_live_set() {
        let mut rng = Rng::new(1207);
        let ds = std::sync::Arc::new(crate::kernel::dataset::gaussian_mixture(
            256, 3, 2, 1.0, 0.5, &mut rng,
        ));
        let cfg = MaintainedConfig {
            degree: 4,
            resparsify_every: 64,
            target_edges: 4096,
            jl_dims: 8,
            seed: 0xA11CE,
        };
        let initial: Vec<usize> = (0..192).collect();
        let mut m = MaintainedSparsifier::new(ds.clone(), Kernel::Laplacian, &initial, cfg);
        assert_eq!(m.live_len(), 192);

        // Event script: bring in the tail, delete every 5th original slot.
        for u in 192..256 {
            assert!(m.apply(PointEvent::Insert(u)));
        }
        for u in (0..192).step_by(5) {
            assert!(m.apply(PointEvent::Delete(u)));
        }
        // Idempotence: re-inserting a live slot / re-deleting a dead one
        // are no-ops that still count as events.
        assert!(!m.apply(PointEvent::Insert(200)));
        assert!(!m.apply(PointEvent::Delete(0)));
        let want_live = 192 + 64 - 39;
        assert_eq!(m.live_len(), want_live);
        assert_eq!(m.live_slots().len(), want_live);

        // The exported graph touches only live slots, has no self-loops,
        // and its total weight is in the same ballpark as a from-scratch
        // build over the identical final live set (both are unbiased
        // degree-4 estimates of the same kernel-row masses).
        let g = m.graph();
        assert!(g.num_edges() > 0);
        for &(u, v, w) in &g.edges {
            assert!(m.is_live(u as usize) && m.is_live(v as usize));
            assert!(u != v && w > 0.0);
        }
        let fresh = MaintainedSparsifier::new(ds, Kernel::Laplacian, &m.live_slots(), cfg);
        let gf = fresh.graph();
        let mass = |g: &WGraph| g.edges.iter().map(|&(_, _, w)| w).sum::<f64>();
        let ratio = mass(&g) / mass(&gf);
        assert!(
            (0.5..=2.0).contains(&ratio),
            "maintained vs fresh total edge mass ratio {ratio}"
        );
        let (events, _) = m.stats();
        assert_eq!(events, 64 + 39 + 2);
    }

    #[test]
    fn two_stage_pipeline_from_kernel_graph() {
        // Alg 5.1 sparsifier -> SS resparsifier, checking the §5.1 claim
        // that the second stage reduces edges further at small extra error.
        let mut rng = Rng::new(1205);
        let ds = std::sync::Arc::new(crate::kernel::dataset::gaussian_mixture(
            40, 3, 2, 0.8, 0.5, &mut rng,
        ));
        let prims = crate::sampling::Primitives::build(
            ds.clone(),
            crate::kernel::Kernel::Laplacian,
            &crate::kde::KdeConfig::exact(),
            crate::runtime::backend::CpuBackend::new(),
        );
        let stage1 = crate::apps::sparsify::sparsify(&prims, 8_000, &mut rng);
        let stage2 = resparsify(&stage1.graph, 1_200, 24, &mut rng);
        assert!(stage2.num_edges() < stage1.graph.num_edges());
        let err = crate::apps::sparsify::spectral_error(
            &ds,
            crate::kernel::Kernel::Laplacian,
            &stage2,
            15,
            &mut rng,
        );
        assert!(err < 0.6, "two-stage spectral error {err}");
    }
}
