//! Approximating the normalized-Laplacian spectrum in earth-mover
//! distance: Theorem 5.17, via the CKSV18 ApproxSpectralMoment scheme on
//! top of the random-walk primitive (Theorem 4.15).
//!
//! Pipeline:
//! 1. Spectral moments `m_l = tr(M^l)/n` of the random-walk matrix
//!    `M = A D^{-1}` are estimated by **walk collisions**: two independent
//!    walks of lengths `floor(l/2)` and `ceil(l/2)` from a uniform vertex
//!    `u` collide at `v` with probability `sum_v p_a(u,v) p_b(u,v)`;
//!    weighting a collision by `d_u/d_v` (reversibility) makes the
//!    estimator unbiased for `p_l(u, u)`.
//! 2. The eigenvalue distribution of M (support [-1, 1]) is recovered by
//!    moment matching on a grid: projected-gradient descent over the
//!    probability simplex minimizing the squared moment residuals.
//! 3. Normalized-Laplacian eigenvalues are `lambda = 1 - mu`.

use crate::sampling::Primitives;
use crate::util::rng::Rng;

/// Recovered spectrum plus cost accounting of one Theorem 5.17 run.
pub struct SpectrumResult {
    /// n recovered eigenvalues of the normalized Laplacian, in [0, 2].
    pub eigenvalues: Vec<f64>,
    /// Estimated moments of the walk-matrix spectrum (index = length l).
    pub moments: Vec<f64>,
    /// Logical KDE queries spent (cache misses).
    pub kde_queries: u64,
    /// Random walks simulated across all moment orders.
    pub walks: u64,
}

/// Parameters for the spectrum estimator.
#[derive(Clone, Copy, Debug)]
pub struct SpectrumParams {
    /// Maximum moment order L (walk length).
    pub max_moment: usize,
    /// Vertices sampled per moment.
    pub vertices: usize,
    /// Walk pairs per sampled vertex.
    pub reps: usize,
    /// Moment-matching grid size over [-1, 1].
    pub grid: usize,
    /// Projected-gradient iterations.
    pub pg_iters: usize,
}

impl Default for SpectrumParams {
    fn default() -> Self {
        SpectrumParams { max_moment: 8, vertices: 24, reps: 200, grid: 81, pg_iters: 4_000 }
    }
}

/// Euclidean projection onto the probability simplex (sort-based).
pub fn project_simplex(v: &mut [f64]) {
    let n = v.len();
    let mut u = v.to_vec();
    u.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let mut css = 0.0;
    let mut rho = 0;
    let mut theta = 0.0;
    for (i, &ui) in u.iter().enumerate() {
        css += ui;
        let t = (css - 1.0) / (i + 1) as f64;
        if ui - t > 0.0 {
            rho = i;
            theta = t;
        }
    }
    let _ = rho;
    for x in v.iter_mut() {
        *x = (*x - theta).max(0.0);
    }
    let s: f64 = v.iter().sum();
    if s > 0.0 {
        for x in v.iter_mut() {
            *x /= s;
        }
    } else {
        let uniform = 1.0 / n as f64;
        for x in v.iter_mut() {
            *x = uniform;
        }
    }
}

/// Estimate walk-matrix moments m_1..m_L by collision walks.
///
/// Per moment order, all `vertices * reps` walk pairs run as two
/// frontier-batched [`walk_batch`](crate::sampling::RandomWalker::walk_batch)
/// calls (one per half-length), so a whole moment's descents coalesce into
/// fused backend submissions instead of `2 * vertices * reps` sequential
/// walks.
pub fn estimate_moments(
    prims: &Primitives,
    params: &SpectrumParams,
    rng: &mut Rng,
) -> (Vec<f64>, u64) {
    let n = prims.n();
    let degrees = &prims.degrees.degrees;
    let mut moments = vec![0.0f64; params.max_moment + 1];
    moments[0] = 1.0;
    let mut walks = 0u64;
    for l in 1..=params.max_moment {
        let a = l / 2;
        let b = l - a;
        let mut starts = Vec::with_capacity(params.vertices * params.reps);
        for _ in 0..params.vertices {
            let u = rng.below(n);
            for _ in 0..params.reps {
                starts.push(u);
            }
        }
        let v1s = prims.walker.walk_batch(&starts, a, rng);
        let v2s = prims.walker.walk_batch(&starts, b, rng);
        walks += 2 * starts.len() as u64;
        let mut acc = 0.0;
        for ((&u, &v1), &v2) in starts.iter().zip(&v1s).zip(&v2s) {
            if v1 == v2 {
                acc += degrees[u] / degrees[v1].max(1e-300);
            }
        }
        moments[l] = acc / starts.len() as f64;
    }
    (moments, walks)
}

/// Recover a distribution over grid points in [-1, 1] matching the
/// moments, by exponentiated-gradient (mirror) descent on the simplex —
/// more stable than Euclidean projected gradient for this geometry.
pub fn match_moments(moments: &[f64], grid: usize, iters: usize) -> (Vec<f64>, Vec<f64>) {
    let g = grid;
    let mus: Vec<f64> = (0..g)
        .map(|i| -1.0 + 2.0 * i as f64 / (g - 1) as f64)
        .collect();
    // powers[l][i] = mus[i]^l
    let lmax = moments.len() - 1;
    let mut powers = vec![vec![1.0f64; g]; lmax + 1];
    for l in 1..=lmax {
        for i in 0..g {
            powers[l][i] = powers[l - 1][i] * mus[i];
        }
    }
    let mut w = vec![1.0 / g as f64; g];
    let eta = 0.2;
    for _ in 0..iters {
        // residuals r_l = sum_i w_i mu_i^l - m_l  (skip l = 0: simplex)
        let mut grad = vec![0.0f64; g];
        for l in 1..=lmax {
            let pred: f64 = (0..g).map(|i| w[i] * powers[l][i]).sum();
            let r = pred - moments[l];
            for i in 0..g {
                grad[i] += 2.0 * r * powers[l][i];
            }
        }
        let mut total = 0.0;
        for i in 0..g {
            w[i] *= (-eta * grad[i]).exp();
            total += w[i];
        }
        if total > 0.0 && total.is_finite() {
            for x in w.iter_mut() {
                *x /= total;
            }
        } else {
            for x in w.iter_mut() {
                *x = 1.0 / g as f64;
            }
        }
    }
    (mus, w)
}

/// Full Theorem 5.17 pipeline.
pub fn approximate_spectrum(
    prims: &Primitives,
    params: &SpectrumParams,
    rng: &mut Rng,
) -> SpectrumResult {
    let queries_before = prims.counters.queries();
    let (moments, walks) = estimate_moments(prims, params, rng);
    let (mus, w) = match_moments(&moments, params.grid, params.pg_iters);
    // Expand the grid distribution into n eigenvalues lambda = 1 - mu.
    let n = prims.n();
    let mut eigenvalues = Vec::with_capacity(n);
    // Largest-remainder apportionment of n points across grid weights.
    let mut alloc: Vec<(usize, f64)> = w
        .iter()
        .enumerate()
        .map(|(i, &wi)| (i, wi * n as f64))
        .collect();
    let mut counts: Vec<usize> = alloc.iter().map(|&(_, x)| x.floor() as usize).collect();
    let assigned: usize = counts.iter().sum();
    alloc.sort_by(|a, b| {
        (b.1 - b.1.floor())
            .partial_cmp(&(a.1 - a.1.floor()))
            .unwrap()
    });
    for &(i, _) in alloc.iter().take(n - assigned) {
        counts[i] += 1;
    }
    for (i, &c) in counts.iter().enumerate() {
        for _ in 0..c {
            eigenvalues.push(1.0 - mus[i]);
        }
    }
    eigenvalues.sort_by(|a, b| a.partial_cmp(b).unwrap());
    SpectrumResult {
        eigenvalues,
        moments,
        kde_queries: prims.counters.queries() - queries_before,
        walks,
    }
}

/// Exact normalized-Laplacian eigenvalues (O(n^3) Jacobi; baseline).
pub fn exact_spectrum(ds: &crate::kernel::Dataset, kernel: crate::kernel::Kernel) -> Vec<f64> {
    let g = crate::graph::WGraph::complete_kernel_graph(ds, kernel);
    let nl = g.normalized_laplacian_dense();
    let (mut vals, _) = crate::linalg::jacobi_eigen(&nl, 100);
    vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
    vals
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kde::KdeConfig;
    use crate::kernel::dataset::gaussian_mixture;
    use crate::kernel::Kernel;
    use crate::runtime::backend::CpuBackend;
    use crate::util::stats::emd_1d;
    use std::sync::Arc;

    #[test]
    fn simplex_projection_properties() {
        let mut v = vec![0.5, 2.0, -1.0, 0.3];
        project_simplex(&mut v);
        let s: f64 = v.iter().sum();
        assert!((s - 1.0).abs() < 1e-9);
        assert!(v.iter().all(|&x| x >= 0.0));
        // already-simplex input is a fixed point
        let mut p = vec![0.25, 0.25, 0.25, 0.25];
        project_simplex(&mut p);
        for x in &p {
            assert!((x - 0.25).abs() < 1e-9);
        }
    }

    #[test]
    fn moment_matching_recovers_point_mass() {
        // Distribution concentrated at mu = 0.5: moments m_l = 0.5^l.
        let moments: Vec<f64> = (0..=8).map(|l| 0.5f64.powi(l)).collect();
        let (mus, w) = match_moments(&moments, 81, 6_000);
        let mean: f64 = mus.iter().zip(&w).map(|(m, wi)| m * wi).sum();
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
        // Mass concentrated near 0.5.
        let near: f64 = mus
            .iter()
            .zip(&w)
            .filter(|(m, _)| (**m - 0.5).abs() < 0.15)
            .map(|(_, wi)| wi)
            .sum();
        assert!(near > 0.7, "mass near point {near}");
    }

    #[test]
    fn estimated_moments_match_exact_trace() {
        let mut rng = Rng::new(211);
        let ds = Arc::new(gaussian_mixture(48, 3, 2, 1.0, 0.5, &mut rng));
        let prims = Primitives::build(
            ds.clone(),
            Kernel::Laplacian,
            &KdeConfig::exact(),
            CpuBackend::new(),
        );
        let params = SpectrumParams { max_moment: 4, vertices: 48, reps: 400, ..Default::default() };
        let (moments, _) = estimate_moments(&prims, &params, &mut rng);
        // exact tr(M^l)/n via dense eigenvalues of the normalized Laplacian
        let exact = exact_spectrum(&ds, Kernel::Laplacian);
        for l in 2..=4 {
            let want: f64 =
                exact.iter().map(|&lam| (1.0 - lam).powi(l as i32)).sum::<f64>() / 48.0;
            let got = moments[l];
            assert!(
                (got - want).abs() < 0.05 + 0.3 * want.abs(),
                "moment {l}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn spectrum_emd_small() {
        let mut rng = Rng::new(213);
        let ds = Arc::new(gaussian_mixture(64, 3, 2, 1.2, 0.5, &mut rng));
        let prims = Primitives::build(
            ds.clone(),
            Kernel::Laplacian,
            &KdeConfig::exact(),
            CpuBackend::new(),
        );
        let params = SpectrumParams { vertices: 32, reps: 300, ..Default::default() };
        let got = approximate_spectrum(&prims, &params, &mut rng);
        let want = exact_spectrum(&ds, Kernel::Laplacian);
        assert_eq!(got.eigenvalues.len(), 64);
        let emd = emd_1d(&got.eigenvalues, &want);
        assert!(emd < 0.2, "EMD {emd} (Theorem 5.17 target eps)");
        for &l in &got.eigenvalues {
            assert!((-1e-9..=2.0 + 1e-9).contains(&l));
        }
    }
}
