//! Arboricity (weighted densest-subgraph density) estimation:
//! Algorithm 6.14 / Theorem 6.15.
//!
//! Sample `m` edges with probability proportional to (an upper bound on)
//! their weight via the §4 weighted-edge-sampling primitive, reweight each
//! sampled edge by `w_e / (m p_e)` so the subsampled graph preserves
//! subgraph weights in expectation, then compute the arboricity of the
//! subsample *exactly* (Goldberg flow; [Cha00]'s LP role).
//!
//! **Evaluation shapes.** Both entry points draw edge `k` from the `k`-th
//! stream forked off the caller's `rng` in draw order.
//! [`arboricity_estimate`] samples one edge at a time — O(m log n)
//! backend dispatches cache-cold. [`arboricity_estimate_batched`] draws
//! all `m` edges through the frontier-batched engine
//! ([`EdgeSampler::sample_batch`](crate::sampling::EdgeSampler::sample_batch)):
//! every descent level's cache misses coalesce into fused padded backend
//! submissions, so the whole draw costs O(log n) dispatches (≤ 10·log₂n
//! at n = 4096, pinned in `tests/fusion.rs`) — and, the streams being
//! identical, the two paths produce **bit-identical** densities from the
//! same seed.

use crate::graph::flow::{densest_subgraph, densest_subgraph_greedy};
use crate::graph::WGraph;
use crate::sampling::{EdgeSample, Primitives};
use crate::util::rng::Rng;

/// Density estimate plus cost accounting of one Algorithm 6.14 run.
pub struct ArboricityResult {
    /// Estimated maximum subgraph density (= arboricity up to the
    /// classical factor-2 relation).
    pub density: f64,
    /// Distinct edges of the reweighted subsample the offline solver ran
    /// on.
    pub subsampled_graph_edges: usize,
    /// Logical KDE queries spent (cache misses).
    pub kde_queries: u64,
    /// Members of the recovered densest set.
    pub densest_set: Vec<bool>,
}

/// Algorithm 6.14 over prebuilt primitives, sequential edge draws.
/// `m` = number of edge samples. `exact_offline`: use the flow-based
/// exact solver on the subsample (Theorem 6.15); otherwise Charikar
/// greedy (2-approx, much faster). See the module docs for the RNG
/// discipline shared with [`arboricity_estimate_batched`].
pub fn arboricity_estimate(
    prims: &Primitives,
    m: usize,
    exact_offline: bool,
    rng: &mut Rng,
) -> ArboricityResult {
    estimate_impl(prims, m, exact_offline, rng, false)
}

/// Algorithm 6.14 with the `m` edge draws resolved as ONE frontier batch
/// — O(log n) backend dispatches instead of O(m log n) — reproducing
/// [`arboricity_estimate`]'s density **bit for bit** from the same seed
/// (both pinned in `tests/fusion.rs`).
pub fn arboricity_estimate_batched(
    prims: &Primitives,
    m: usize,
    exact_offline: bool,
    rng: &mut Rng,
) -> ArboricityResult {
    estimate_impl(prims, m, exact_offline, rng, true)
}

/// Shared body: the two paths differ only in how the edge draws execute
/// (per-edge forked streams either way), so the subsampled graph — and
/// everything computed from it — is identical.
fn estimate_impl(
    prims: &Primitives,
    m: usize,
    exact_offline: bool,
    rng: &mut Rng,
    batched: bool,
) -> ArboricityResult {
    let ds = &prims.tree.ds;
    let kernel = prims.tree.kernel;
    let before = prims.counters.queries();
    let samples: Vec<Option<EdgeSample>> = if batched {
        prims.edges.sample_batch(m, rng)
    } else {
        (0..m)
            .map(|_| {
                let mut fork = rng.fork();
                prims.edges.sample(&mut fork)
            })
            .collect()
    };
    let mut raw = Vec::with_capacity(m);
    for e in samples.into_iter().flatten() {
        if e.prob <= 0.0 {
            continue;
        }
        let w = kernel.eval(ds.point(e.u), ds.point(e.v)) as f64;
        raw.push((e.u, e.v, w / (m as f64 * e.prob)));
    }
    let g = WGraph::from_edges(ds.n, raw);
    let (density, densest_set) = if exact_offline {
        densest_subgraph(g.n, &g.edges, 1e-6)
    } else {
        densest_subgraph_greedy(g.n, &g.edges)
    };
    ArboricityResult {
        density,
        subsampled_graph_edges: g.num_edges(),
        kde_queries: prims.counters.queries() - before,
        densest_set,
    }
}

/// Exact arboricity of the full kernel graph (O(n^2) edges + flow solve;
/// baseline for Theorem 6.15, the paper's `O(n^3) + O(n^2 d)` row).
pub fn arboricity_exact(g: &WGraph) -> f64 {
    densest_subgraph(g.n, &g.edges, 1e-7).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kde::KdeConfig;
    use crate::kernel::dataset::gaussian_mixture;
    use crate::kernel::Kernel;
    use crate::runtime::backend::CpuBackend;
    use std::sync::Arc;

    fn setup(n: usize, seed: u64) -> (Arc<crate::kernel::Dataset>, Primitives, Rng) {
        let mut rng = Rng::new(seed);
        // Mixture with a tight blob -> a genuinely denser subgraph.
        let ds = Arc::new(gaussian_mixture(n, 3, 2, 2.0, 0.4, &mut rng));
        let prims = Primitives::build(
            ds.clone(),
            Kernel::Laplacian,
            &KdeConfig::exact(),
            CpuBackend::new(),
        );
        (ds, prims, rng)
    }

    #[test]
    fn estimate_close_to_exact() {
        let (ds, prims, mut rng) = setup(40, 241);
        let g = WGraph::complete_kernel_graph(&ds, Kernel::Laplacian);
        let exact = arboricity_exact(&g);
        let est = arboricity_estimate(&prims, 8_000, true, &mut rng);
        let rel = (est.density - exact).abs() / exact;
        // Margin sized for the per-edge forked-stream discipline (the
        // estimator distribution is unchanged; the draws re-randomized).
        assert!(
            rel < 0.2,
            "arboricity est {} vs exact {exact} (rel {rel})",
            est.density
        );
    }

    #[test]
    fn batched_estimate_is_bit_identical_to_sequential() {
        // Same seed, same subsampled graph, same density — bit for bit —
        // through either evaluation shape.
        let (_, prims, _) = setup(40, 249);
        for seed in [3u64, 91, 2024] {
            let bat = arboricity_estimate_batched(&prims, 600, false, &mut Rng::new(seed));
            let seq = arboricity_estimate(&prims, 600, false, &mut Rng::new(seed));
            assert_eq!(
                bat.density.to_bits(),
                seq.density.to_bits(),
                "seed {seed}: batched {} vs sequential {}",
                bat.density,
                seq.density
            );
            assert_eq!(bat.subsampled_graph_edges, seq.subsampled_graph_edges);
            assert_eq!(bat.densest_set, seq.densest_set, "seed {seed} densest set");
        }
    }

    #[test]
    fn greedy_variant_lower_bounds_exact_estimate() {
        let (_, prims, mut rng) = setup(32, 243);
        let exact = arboricity_estimate(&prims, 5_000, true, &mut rng);
        let greedy = arboricity_estimate(&prims, 5_000, false, &mut rng);
        assert!(greedy.density <= exact.density * 1.1 + 1e-9);
        assert!(greedy.density >= 0.4 * exact.density, "2-approx guarantee");
    }

    #[test]
    fn subsample_much_smaller_than_complete_graph() {
        let (_, prims, mut rng) = setup(48, 245);
        let est = arboricity_estimate(&prims, 2_000, false, &mut rng);
        assert!(est.subsampled_graph_edges < 48 * 47 / 2);
        assert!(est.kde_queries > 0);
    }

    #[test]
    fn more_samples_tighter_estimate() {
        let (ds, prims, mut rng) = setup(32, 247);
        let g = WGraph::complete_kernel_graph(&ds, Kernel::Laplacian);
        let exact = arboricity_exact(&g);
        let coarse = arboricity_estimate(&prims, 400, true, &mut rng);
        let fine = arboricity_estimate(&prims, 12_000, true, &mut rng);
        let e_coarse = (coarse.density - exact).abs() / exact;
        let e_fine = (fine.density - exact).abs() / exact;
        assert!(
            e_fine <= e_coarse + 0.08,
            "fine {e_fine} should not exceed coarse {e_coarse}"
        );
    }
}
