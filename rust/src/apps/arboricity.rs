//! Arboricity (weighted densest-subgraph density) estimation:
//! Algorithm 6.14 / Theorem 6.15.
//!
//! Sample `m` edges with probability proportional to (an upper bound on)
//! their weight via the §4 weighted-edge-sampling primitive, reweight each
//! sampled edge by `w_e / (m p_e)` so the subsampled graph preserves
//! subgraph weights in expectation, then compute the arboricity of the
//! subsample *exactly* (Goldberg flow; [Cha00]'s LP role).

use crate::graph::flow::{densest_subgraph, densest_subgraph_greedy};
use crate::graph::WGraph;
use crate::sampling::Primitives;
use crate::util::rng::Rng;

pub struct ArboricityResult {
    pub density: f64,
    pub subsampled_graph_edges: usize,
    pub kde_queries: u64,
    /// Members of the recovered densest set.
    pub densest_set: Vec<bool>,
}

/// Algorithm 6.14 over prebuilt primitives. `m` = number of edge samples.
/// `exact_offline`: use the flow-based exact solver on the subsample
/// (Theorem 6.15); otherwise Charikar greedy (2-approx, much faster).
pub fn arboricity_estimate(
    prims: &Primitives,
    m: usize,
    exact_offline: bool,
    rng: &mut Rng,
) -> ArboricityResult {
    let ds = &prims.tree.ds;
    let kernel = prims.tree.kernel;
    let before = prims.counters.queries();
    let mut raw = Vec::with_capacity(m);
    for _ in 0..m {
        let Some(e) = prims.edges.sample(rng) else { continue };
        if e.prob <= 0.0 {
            continue;
        }
        let w = kernel.eval(ds.point(e.u), ds.point(e.v)) as f64;
        raw.push((e.u, e.v, w / (m as f64 * e.prob)));
    }
    let g = WGraph::from_edges(ds.n, raw);
    let (density, densest_set) = if exact_offline {
        densest_subgraph(g.n, &g.edges, 1e-6)
    } else {
        densest_subgraph_greedy(g.n, &g.edges)
    };
    ArboricityResult {
        density,
        subsampled_graph_edges: g.num_edges(),
        kde_queries: prims.counters.queries() - before,
        densest_set,
    }
}

/// Exact arboricity of the full kernel graph (O(n^2) edges + flow solve;
/// baseline for Theorem 6.15, the paper's `O(n^3) + O(n^2 d)` row).
pub fn arboricity_exact(g: &WGraph) -> f64 {
    densest_subgraph(g.n, &g.edges, 1e-7).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kde::KdeConfig;
    use crate::kernel::dataset::gaussian_mixture;
    use crate::kernel::Kernel;
    use crate::runtime::backend::CpuBackend;
    use std::sync::Arc;

    fn setup(n: usize, seed: u64) -> (Arc<crate::kernel::Dataset>, Primitives, Rng) {
        let mut rng = Rng::new(seed);
        // Mixture with a tight blob -> a genuinely denser subgraph.
        let ds = Arc::new(gaussian_mixture(n, 3, 2, 2.0, 0.4, &mut rng));
        let prims = Primitives::build(
            ds.clone(),
            Kernel::Laplacian,
            &KdeConfig::exact(),
            CpuBackend::new(),
        );
        (ds, prims, rng)
    }

    #[test]
    fn estimate_close_to_exact() {
        let (ds, prims, mut rng) = setup(40, 241);
        let g = WGraph::complete_kernel_graph(&ds, Kernel::Laplacian);
        let exact = arboricity_exact(&g);
        let est = arboricity_estimate(&prims, 8_000, true, &mut rng);
        let rel = (est.density - exact).abs() / exact;
        assert!(
            rel < 0.15,
            "arboricity est {} vs exact {exact} (rel {rel})",
            est.density
        );
    }

    #[test]
    fn greedy_variant_lower_bounds_exact_estimate() {
        let (_, prims, mut rng) = setup(32, 243);
        let exact = arboricity_estimate(&prims, 5_000, true, &mut rng);
        let greedy = arboricity_estimate(&prims, 5_000, false, &mut rng);
        assert!(greedy.density <= exact.density * 1.1 + 1e-9);
        assert!(greedy.density >= 0.4 * exact.density, "2-approx guarantee");
    }

    #[test]
    fn subsample_much_smaller_than_complete_graph() {
        let (_, prims, mut rng) = setup(48, 245);
        let est = arboricity_estimate(&prims, 2_000, false, &mut rng);
        assert!(est.subsampled_graph_edges < 48 * 47 / 2);
        assert!(est.kde_queries > 0);
    }

    #[test]
    fn more_samples_tighter_estimate() {
        let (ds, prims, mut rng) = setup(32, 247);
        let g = WGraph::complete_kernel_graph(&ds, Kernel::Laplacian);
        let exact = arboricity_exact(&g);
        let coarse = arboricity_estimate(&prims, 400, true, &mut rng);
        let fine = arboricity_estimate(&prims, 12_000, true, &mut rng);
        let e_coarse = (coarse.density - exact).abs() / exact;
        let e_fine = (fine.density - exact).abs() / exact;
        assert!(
            e_fine <= e_coarse + 0.05,
            "fine {e_fine} should not exceed coarse {e_coarse}"
        );
    }
}
