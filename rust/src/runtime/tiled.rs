//! Tiled, multi-threaded CPU kernel backend.
//!
//! The scalar [`CpuBackend`](crate::runtime::backend::CpuBackend) walks
//! every (query, data) pair with a per-pair distance loop. This backend
//! restructures the same computation three ways (EXPERIMENTS.md §Perf):
//!
//! 1. **Blocked-GEMM distance trick** — for the L2 kernels (Gaussian,
//!    exponential, rational quadratic) squared distances are computed as
//!    `||x||^2 + ||y||^2 - 2<x,y>` from precomputed row norms, so the
//!    inner loop is a pure dot product (one fma per element instead of
//!    sub + fma). The Laplacian kernel keeps a dedicated L1 tile loop —
//!    there is no norm decomposition for L1 distances.
//! 2. **Cache tiling** — data is processed in tiles of [`DTILE`] rows so a
//!    tile stays resident in L1/L2 across all query rows of a chunk, and
//!    per-tile distances land in a stack buffer that the kernel map then
//!    consumes. Batching the kernel map over the tile gives the compiler
//!    independent [`fast_exp_neg`] chains to pipeline — the scalar
//!    backend's one-libm-`expf`-per-pair serialization is the single
//!    biggest cost at moderate `d` (see the §Perf log).
//! 3. **Threading** — `std::thread::scope` workers split the query rows
//!    (or, when a call has few queries but much data, the data rows) with
//!    per-thread eval counts folded into the shared atomic counter.
//!
//! Determinism: for a fixed thread split mode, every output value is
//! accumulated in a fixed order (data tiles in order, f64 accumulator per
//! query row), so results are reproducible run-to-run and independent of
//! the worker count in the query-split path. The data-split path (b <<
//! threads) folds per-thread partial sums in chunk order, which groups the
//! same additions differently — equal up to f64 rounding.
//!
//! Numerical caveat: the norm trick computes `d(x,y)^2` by cancellation,
//! so for two *nearly identical points with huge coordinates* (norms ~1e13)
//! the result carries absolute error up to ~1e7 and the Gaussian value can
//! underflow where the scalar backend returns ~1. This case is outside the
//! PJRT padding contract this backend mirrors (FAR padding rows are only
//! ever paired with real, bandwidth-scaled queries — see
//! `tests/backend_parity.rs`); negative cancellation residue is clamped to
//! zero so `k(x, x) = 1` holds for realistic coordinates.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::kernel::{fast_exp_neg, Kernel};
use crate::runtime::backend::KernelBackend;

/// Data rows per cache tile. A tile of f32 coordinates occupies
/// `DTILE * d * 4` bytes — 32 KiB at the AOT shape d = 64, sized for L1.
const DTILE: usize = 128;

const LANES: usize = 8;

/// Tiled multi-threaded backend; see the module docs.
pub struct TiledBackend {
    threads: usize,
    evals: AtomicU64,
    calls: AtomicU64,
}

impl TiledBackend {
    /// One worker per available core.
    pub fn new() -> Arc<Self> {
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        Self::with_threads(threads)
    }

    /// Fixed worker count (1 = tiling only, no thread spawns).
    pub fn with_threads(threads: usize) -> Arc<Self> {
        assert!(threads >= 1, "need at least one worker");
        Arc::new(TiledBackend {
            threads,
            evals: AtomicU64::new(0),
            calls: AtomicU64::new(0),
        })
    }

    pub fn threads(&self) -> usize {
        self.threads
    }
}

/// 8-lane dot product (same layout trick as `kernel::sq_dist`: independent
/// partial sums so LLVM vectorizes).
#[inline]
fn dot(x: &[f32], y: &[f32]) -> f32 {
    let mut acc = [0.0f32; LANES];
    let mut xc = x.chunks_exact(LANES);
    let mut yc = y.chunks_exact(LANES);
    for (xa, ya) in (&mut xc).zip(&mut yc) {
        for l in 0..LANES {
            acc[l] += xa[l] * ya[l];
        }
    }
    let mut s: f32 = acc.iter().sum();
    for (a, b) in xc.remainder().iter().zip(yc.remainder()) {
        s += a * b;
    }
    s
}

/// 8-lane L1 distance (the Laplacian tile loop's inner kernel).
#[inline]
fn l1(x: &[f32], y: &[f32]) -> f32 {
    let mut acc = [0.0f32; LANES];
    let mut xc = x.chunks_exact(LANES);
    let mut yc = y.chunks_exact(LANES);
    for (xa, ya) in (&mut xc).zip(&mut yc) {
        for l in 0..LANES {
            acc[l] += (xa[l] - ya[l]).abs();
        }
    }
    let mut s: f32 = acc.iter().sum();
    for (a, b) in xc.remainder().iter().zip(yc.remainder()) {
        s += (a - b).abs();
    }
    s
}

/// Squared row norms of a `rows x d` buffer.
fn row_sq_norms(buf: &[f32], d: usize) -> Vec<f32> {
    buf.chunks_exact(d).map(|row| dot(row, row)).collect()
}

/// Map a tile's squared distances to kernel values. Runs over a contiguous
/// buffer so the `fast_exp_neg` chains are independent and pipeline.
#[inline]
fn map_kernel_sq(kernel: Kernel, sq: &[f32], out: &mut [f32]) {
    match kernel {
        Kernel::Gaussian => {
            for (o, &s) in out.iter_mut().zip(sq) {
                *o = fast_exp_neg(-s.max(0.0));
            }
        }
        Kernel::Exponential => {
            for (o, &s) in out.iter_mut().zip(sq) {
                *o = fast_exp_neg(-s.max(0.0).sqrt());
            }
        }
        Kernel::RationalQuadratic => {
            for (o, &s) in out.iter_mut().zip(sq) {
                *o = 1.0 / (1.0 + s.max(0.0));
            }
        }
        Kernel::Laplacian => unreachable!("Laplacian takes the L1 tile path"),
    }
}

/// KDE sums for a chunk of query rows against (a chunk of) the data.
/// `qn`/`xn` are the squared row norms matching `queries`/`data`; both are
/// empty (and unused) on the Laplacian path. Accumulates INTO `out` (one
/// f64 slot per query row), data tiles in order, so callers may feed data
/// chunks sequentially and keep a deterministic summation order.
fn sums_rows(
    kernel: Kernel,
    queries: &[f32],
    data: &[f32],
    d: usize,
    qn: &[f32],
    xn: &[f32],
    out: &mut [f64],
) {
    debug_assert_eq!(queries.len() / d, out.len());
    let mut kbuf = [0.0f32; DTILE];
    if kernel == Kernel::Laplacian {
        for tile in data.chunks(DTILE * d) {
            let rows = tile.len() / d;
            for (qi, q) in queries.chunks_exact(d).enumerate() {
                for (j, x) in tile.chunks_exact(d).enumerate() {
                    kbuf[j] = fast_exp_neg(-l1(q, x));
                }
                let mut acc = 0.0f64;
                for &k in &kbuf[..rows] {
                    acc += k as f64;
                }
                out[qi] += acc;
            }
        }
        return;
    }
    let mut sqbuf = [0.0f32; DTILE];
    for (ti, tile) in data.chunks(DTILE * d).enumerate() {
        let rows = tile.len() / d;
        let xn_t = &xn[ti * DTILE..ti * DTILE + rows];
        for (qi, q) in queries.chunks_exact(d).enumerate() {
            let qnv = qn[qi];
            for (j, x) in tile.chunks_exact(d).enumerate() {
                sqbuf[j] = qnv + xn_t[j] - 2.0 * dot(q, x);
            }
            map_kernel_sq(kernel, &sqbuf[..rows], &mut kbuf[..rows]);
            let mut acc = 0.0f64;
            for &k in &kbuf[..rows] {
                acc += k as f64;
            }
            out[qi] += acc;
        }
    }
}

/// Dense kernel block for a chunk of query rows; writes `rows x m` values
/// into `out` (row stride `m`, starting at the chunk's first row).
fn block_rows(
    kernel: Kernel,
    queries: &[f32],
    data: &[f32],
    d: usize,
    qn: &[f32],
    xn: &[f32],
    out: &mut [f32],
    m: usize,
) {
    debug_assert_eq!(queries.len() / d * m, out.len());
    if kernel == Kernel::Laplacian {
        for (ti, tile) in data.chunks(DTILE * d).enumerate() {
            let off = ti * DTILE;
            let rows = tile.len() / d;
            for (qi, q) in queries.chunks_exact(d).enumerate() {
                let dst = &mut out[qi * m + off..qi * m + off + rows];
                for (j, x) in tile.chunks_exact(d).enumerate() {
                    dst[j] = fast_exp_neg(-l1(q, x));
                }
            }
        }
        return;
    }
    let mut sqbuf = [0.0f32; DTILE];
    for (ti, tile) in data.chunks(DTILE * d).enumerate() {
        let off = ti * DTILE;
        let rows = tile.len() / d;
        let xn_t = &xn[off..off + rows];
        for (qi, q) in queries.chunks_exact(d).enumerate() {
            let qnv = qn[qi];
            for (j, x) in tile.chunks_exact(d).enumerate() {
                sqbuf[j] = qnv + xn_t[j] - 2.0 * dot(q, x);
            }
            let dst = &mut out[qi * m + off..qi * m + off + rows];
            map_kernel_sq(kernel, &sqbuf[..rows], dst);
        }
    }
}

impl KernelBackend for TiledBackend {
    fn sums(&self, kernel: Kernel, queries: &[f32], data: &[f32], d: usize) -> Vec<f64> {
        assert!(d > 0 && queries.len() % d == 0 && data.len() % d == 0);
        let b = queries.len() / d;
        let m = data.len() / d;
        self.calls.fetch_add(1, Ordering::Relaxed);
        let mut out = vec![0.0f64; b];
        if b == 0 || m == 0 {
            return out;
        }
        let l2 = kernel != Kernel::Laplacian;
        let qn = if l2 { row_sq_norms(queries, d) } else { Vec::new() };
        let xn = if l2 { row_sq_norms(data, d) } else { Vec::new() };
        let qn_s: &[f32] = &qn;
        let xn_s: &[f32] = &xn;
        let evals = &self.evals;
        if self.threads == 1 {
            sums_rows(kernel, queries, data, d, qn_s, xn_s, &mut out);
            evals.fetch_add((b * m) as u64, Ordering::Relaxed);
        } else if b >= self.threads {
            // Query split: each worker owns a disjoint slice of output
            // rows, so no reduction is needed and per-row summation order
            // is identical to the single-thread path.
            let chunk_rows = (b + self.threads - 1) / self.threads;
            std::thread::scope(|s| {
                for (ci, out_chunk) in out.chunks_mut(chunk_rows).enumerate() {
                    let lo = ci * chunk_rows;
                    let rows = out_chunk.len();
                    let q_chunk = &queries[lo * d..(lo + rows) * d];
                    let qn_chunk = if l2 { &qn_s[lo..lo + rows] } else { qn_s };
                    s.spawn(move || {
                        sums_rows(kernel, q_chunk, data, d, qn_chunk, xn_s, out_chunk);
                        evals.fetch_add((rows * m) as u64, Ordering::Relaxed);
                    });
                }
            });
        } else {
            // Few queries, much data (the KDE-sum shape for small batches):
            // split the data rows, fold per-worker partials in chunk order.
            let workers = self.threads.min((m + DTILE - 1) / DTILE).max(1);
            let mut chunk_rows = (m + workers - 1) / workers;
            chunk_rows = ((chunk_rows + DTILE - 1) / DTILE) * DTILE;
            std::thread::scope(|s| {
                let mut handles = Vec::new();
                let mut lo = 0usize;
                while lo < m {
                    let hi = (lo + chunk_rows).min(m);
                    let d_chunk = &data[lo * d..hi * d];
                    let xn_chunk: &[f32] = if l2 { &xn_s[lo..hi] } else { &[] };
                    handles.push(s.spawn(move || {
                        let mut part = vec![0.0f64; b];
                        sums_rows(kernel, queries, d_chunk, d, qn_s, xn_chunk, &mut part);
                        evals.fetch_add((b * (hi - lo)) as u64, Ordering::Relaxed);
                        part
                    }));
                    lo = hi;
                }
                for h in handles {
                    let part = h.join().expect("tiled sums worker panicked");
                    for (o, p) in out.iter_mut().zip(&part) {
                        *o += p;
                    }
                }
            });
        }
        out
    }

    fn block(&self, kernel: Kernel, queries: &[f32], data: &[f32], d: usize) -> Vec<f32> {
        assert!(d > 0 && queries.len() % d == 0 && data.len() % d == 0);
        let b = queries.len() / d;
        let m = data.len() / d;
        self.calls.fetch_add(1, Ordering::Relaxed);
        let mut out = vec![0.0f32; b * m];
        if b == 0 || m == 0 {
            return out;
        }
        let l2 = kernel != Kernel::Laplacian;
        let qn = if l2 { row_sq_norms(queries, d) } else { Vec::new() };
        let xn = if l2 { row_sq_norms(data, d) } else { Vec::new() };
        let qn_s: &[f32] = &qn;
        let xn_s: &[f32] = &xn;
        let evals = &self.evals;
        if self.threads == 1 || b == 1 {
            block_rows(kernel, queries, data, d, qn_s, xn_s, &mut out, m);
            evals.fetch_add((b * m) as u64, Ordering::Relaxed);
        } else {
            // Query split over disjoint output row ranges (the block shape
            // is row-parallel by construction; data-splitting would write
            // interleaved columns).
            let workers = self.threads.min(b);
            let chunk_rows = (b + workers - 1) / workers;
            std::thread::scope(|s| {
                for (ci, out_chunk) in out.chunks_mut(chunk_rows * m).enumerate() {
                    let lo = ci * chunk_rows;
                    let rows = out_chunk.len() / m;
                    let q_chunk = &queries[lo * d..(lo + rows) * d];
                    let qn_chunk = if l2 { &qn_s[lo..lo + rows] } else { qn_s };
                    s.spawn(move || {
                        block_rows(kernel, q_chunk, data, d, qn_chunk, xn_s, out_chunk, m);
                        evals.fetch_add((rows * m) as u64, Ordering::Relaxed);
                    });
                }
            });
        }
        out
    }

    fn kernel_evals(&self) -> u64 {
        self.evals.load(Ordering::Relaxed)
    }

    fn calls(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }

    fn name(&self) -> &'static str {
        "tiled"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::ALL_KERNELS;
    use crate::runtime::backend::CpuBackend;
    use crate::util::rng::Rng;

    fn rand_buf(rng: &mut Rng, n: usize, scale: f64) -> Vec<f32> {
        (0..n).map(|_| (rng.normal() * scale) as f32).collect()
    }

    #[test]
    fn matches_cpu_backend_smoke() {
        let mut rng = Rng::new(811);
        let (b, m, d) = (9usize, 301usize, 13usize);
        let queries = rand_buf(&mut rng, b * d, 1.5);
        let data = rand_buf(&mut rng, m * d, 1.5);
        let cpu = CpuBackend::new();
        let tiled = TiledBackend::with_threads(3);
        for k in ALL_KERNELS {
            let want = cpu.sums(k, &queries, &data, d);
            let got = tiled.sums(k, &queries, &data, d);
            for (g, w) in got.iter().zip(&want) {
                assert!(
                    (g - w).abs() < 2e-3 * (1.0 + w.abs()),
                    "{:?}: tiled {g} vs cpu {w}",
                    k
                );
            }
            let want_b = cpu.block(k, &queries, &data, d);
            let got_b = tiled.block(k, &queries, &data, d);
            for (g, w) in got_b.iter().zip(&want_b) {
                assert!(
                    (g - w).abs() < 1e-3 * (1.0 + w.abs()),
                    "{:?} block: tiled {g} vs cpu {w}",
                    k
                );
            }
        }
    }

    #[test]
    fn eval_and_call_counters() {
        let be = TiledBackend::with_threads(2);
        let q = vec![0.0f32; 3 * 2];
        let x = vec![0.5f32; 5 * 2];
        be.sums(Kernel::Gaussian, &q, &x, 2);
        assert_eq!(be.kernel_evals(), 15);
        assert_eq!(be.calls(), 1);
        be.block(Kernel::Laplacian, &q, &x, 2);
        assert_eq!(be.kernel_evals(), 30);
        assert_eq!(be.calls(), 2);
    }

    #[test]
    fn query_split_is_thread_count_invariant() {
        // With b >= threads both paths sum each output row over the data
        // tiles in the same order -> bitwise identical results.
        let mut rng = Rng::new(813);
        let (b, m, d) = (16usize, 200usize, 7usize);
        let queries = rand_buf(&mut rng, b * d, 1.0);
        let data = rand_buf(&mut rng, m * d, 1.0);
        let t1 = TiledBackend::with_threads(1);
        let t4 = TiledBackend::with_threads(4);
        for k in ALL_KERNELS {
            let a = t1.sums(k, &queries, &data, d);
            let c = t4.sums(k, &queries, &data, d);
            for (x, y) in a.iter().zip(&c) {
                assert_eq!(x.to_bits(), y.to_bits(), "{:?} nondeterministic", k);
            }
        }
    }

    #[test]
    fn empty_inputs() {
        let be = TiledBackend::with_threads(4);
        let q = vec![0.25f32; 2 * 3];
        let empty: Vec<f32> = Vec::new();
        // empty data -> zero sums, empty block
        let s = be.sums(Kernel::Gaussian, &q, &empty, 3);
        assert_eq!(s, vec![0.0, 0.0]);
        assert!(be.block(Kernel::Gaussian, &q, &empty, 3).is_empty());
        // empty queries -> empty outputs
        assert!(be.sums(Kernel::Gaussian, &empty, &q, 3).is_empty());
        assert!(be.block(Kernel::Gaussian, &empty, &q, 3).is_empty());
    }

    #[test]
    fn self_kernel_is_one_at_realistic_scale() {
        let mut rng = Rng::new(815);
        let d = 24;
        let q = rand_buf(&mut rng, d, 2.0);
        let be = TiledBackend::with_threads(1);
        for k in ALL_KERNELS {
            let v = be.block(k, &q, &q, d)[0];
            assert!((v - 1.0).abs() < 1e-4, "{:?}: k(x,x) = {v}", k);
        }
    }
}
