//! Tiled, multi-threaded CPU kernel backend.
//!
//! The scalar [`CpuBackend`](crate::runtime::backend::CpuBackend) walks
//! every (query, data) pair with a per-pair distance loop. This backend
//! restructures the same computation three ways (EXPERIMENTS.md §Perf):
//!
//! 1. **Blocked-GEMM distance trick** — for the L2 kernels (Gaussian,
//!    exponential, rational quadratic) squared distances are computed as
//!    `||x||^2 + ||y||^2 - 2<x,y>` from precomputed row norms, so the
//!    inner loop is a pure dot product (one fma per element instead of
//!    sub + fma). The Laplacian kernel keeps a dedicated L1 tile loop —
//!    there is no norm decomposition for L1 distances.
//! 2. **Cache tiling** — data is processed in tiles of `DTILE` rows so a
//!    tile stays resident in L1/L2 across all query rows of a chunk, and
//!    per-tile distances land in a stack buffer that the kernel map then
//!    consumes. Batching the kernel map over the tile keeps the
//!    `fast_exp_neg` evaluations independent — the scalar backend's
//!    one-libm-`expf`-per-pair serialization is the single biggest cost
//!    at moderate `d` (see the §Perf log).
//! 3. **Threading** — worker tasks split the query rows (or, when a call
//!    has few queries but much data, the data rows) with per-thread eval
//!    counts folded into the shared atomic counter. Tasks run on a lazily
//!    created persistent [`WorkerPool`] (`runtime::pool`) so the O(log n)
//!    small fused dispatches per descent round don't re-pay thread
//!    startup; [`TiledBackend::set_pooled`]`(false)` switches back to
//!    per-call `std::thread::scope` spawns (the A/B off-switch — both
//!    routes run the identical chunk closures, so results are
//!    `to_bits`-equal; pinned in `tests/pool.rs`).
//! 4. **Explicit SIMD** — the dot/L1 inner loops and the tile-wide kernel
//!    map dispatch through a [`MicroKernel`] function-pointer vtable
//!    selected once at construction (AVX2+FMA, NEON, or portable scalar;
//!    see `runtime::simd`), instead of relying on whatever the baseline
//!    target's autovectorizer produces.
//!
//! Determinism: for a fixed thread split mode, every output value is
//! accumulated in a fixed order (data tiles in order, f64 accumulator per
//! query row), so results are reproducible run-to-run and independent of
//! the worker count in the query-split path. The data-split path (b <<
//! threads) folds per-thread partial sums in chunk order, which groups the
//! same additions differently — equal up to f64 rounding.
//!
//! Numerical caveat: the norm trick computes `d(x,y)^2` by cancellation,
//! so for two *nearly identical points with huge coordinates* (norms ~1e13)
//! the result carries absolute error up to ~1e7 and the Gaussian value can
//! underflow where the scalar backend returns ~1. This case is outside the
//! PJRT padding contract this backend mirrors (FAR padding rows are only
//! ever paired with real, bandwidth-scaled queries — see
//! `tests/backend_parity.rs`); negative cancellation residue is clamped to
//! zero so `k(x, x) = 1` holds for realistic coordinates.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};

use crate::coordinator::metrics::PoolMetrics;
use crate::kernel::Kernel;
use crate::runtime::backend::KernelBackend;
use crate::runtime::pool::{PoolConfig, WorkerPool};
use crate::runtime::simd::{MicroKernel, SimdMode};

/// Data rows per cache tile. A tile of f32 coordinates occupies
/// `DTILE * d * 4` bytes — 32 KiB at the AOT shape d = 64, sized for L1.
const DTILE: usize = 128;

/// Tiled multi-threaded backend; see the module docs.
///
/// The inner loops (dot / L1 / kernel map) run through a [`MicroKernel`]
/// vtable chosen once at construction — AVX2+FMA or NEON when the host
/// supports them, the portable scalar path otherwise (`runtime::simd`).
pub struct TiledBackend {
    threads: usize,
    mk: &'static MicroKernel,
    evals: AtomicU64,
    calls: AtomicU64,
    /// Persistent worker pool, created lazily on the first parallel call
    /// so single-threaded and short-lived backends never spawn threads.
    pool: OnceLock<WorkerPool>,
    /// Pool execution off-switch (A/B vs per-call scoped spawns).
    pooled: AtomicBool,
}

impl TiledBackend {
    /// One worker per available core, best SIMD ISA the host supports.
    pub fn new() -> Arc<Self> {
        Self::with_threads(Self::default_threads())
    }

    /// Fixed worker count (1 = tiling only, no thread spawns), best ISA.
    pub fn with_threads(threads: usize) -> Arc<Self> {
        match Self::with_simd(threads, SimdMode::Auto) {
            Ok(be) => be,
            Err(e) => unreachable!("auto SIMD mode cannot fail: {e}"),
        }
    }

    /// Fixed worker count and explicit SIMD mode (`--simd` on the CLI).
    /// Errors when the requested ISA is not runnable on this host, so
    /// A/B benchmark runs never silently fall back.
    pub fn with_simd(threads: usize, mode: SimdMode) -> Result<Arc<Self>, String> {
        assert!(threads >= 1, "need at least one worker");
        let mk = MicroKernel::select(mode)?;
        Ok(Arc::new(TiledBackend {
            threads,
            mk,
            evals: AtomicU64::new(0),
            calls: AtomicU64::new(0),
            pool: OnceLock::new(),
            pooled: AtomicBool::new(true),
        }))
    }

    /// Worker count [`new`](Self::new) would pick.
    pub fn default_threads() -> usize {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    }

    /// Configured worker count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The microkernel vtable this backend dispatches through.
    pub fn microkernel(&self) -> &'static MicroKernel {
        self.mk
    }

    /// Route parallel chunks through the persistent pool (`true`, the
    /// default) or per-call `std::thread::scope` spawns (`false`). Both
    /// routes run the identical worker-disjoint chunk closures, so this
    /// switch never changes results — only scheduling.
    pub fn set_pooled(&self, on: bool) {
        self.pooled.store(on, Ordering::Relaxed);
    }

    /// Whether parallel chunks currently route through the pool.
    pub fn pooled(&self) -> bool {
        self.pooled.load(Ordering::Relaxed)
    }

    /// Pool occupancy counters, if the pool has been created (it is lazy:
    /// `None` until the first pooled parallel dispatch).
    pub fn pool_metrics(&self) -> Option<Arc<PoolMetrics>> {
        self.pool.get().map(|p| Arc::clone(p.metrics()))
    }

    /// The lazily created persistent pool, sized to `self.threads`.
    fn pool(&self) -> &WorkerPool {
        self.pool
            .get_or_init(|| WorkerPool::new(PoolConfig::with_workers(self.threads)))
    }

    /// Run one dispatch's worker-disjoint chunk tasks to completion —
    /// on the persistent pool, or via scoped spawns when pooling is off.
    /// Panics propagate to the caller on both routes (the `try_*`
    /// isolation boundary maps them to `BackendError::Panicked`).
    fn execute<'a>(&self, tasks: Vec<Box<dyn FnOnce() + Send + 'a>>) {
        if self.pooled.load(Ordering::Relaxed) {
            self.pool().run_scoped(tasks);
        } else {
            run_scoped_threads(tasks);
        }
    }
}

/// Per-call scoped-spawn execution: one OS thread per task, first panic
/// payload re-raised on the caller (mirrors `WorkerPool::run_scoped`).
fn run_scoped_threads(tasks: Vec<Box<dyn FnOnce() + Send + '_>>) {
    std::thread::scope(|s| {
        let handles: Vec<_> = tasks.into_iter().map(|t| s.spawn(t)).collect();
        let mut first_panic = None;
        for h in handles {
            if let Err(p) = h.join() {
                first_panic.get_or_insert(p);
            }
        }
        if let Some(p) = first_panic {
            std::panic::resume_unwind(p);
        }
    })
}

/// Squared row norms of a `rows x d` buffer.
fn row_sq_norms(mk: &MicroKernel, buf: &[f32], d: usize) -> Vec<f32> {
    buf.chunks_exact(d).map(|row| (mk.dot)(row, row)).collect()
}

/// KDE sums for a chunk of query rows against (a chunk of) the data.
/// `qn`/`xn` are the squared row norms matching `queries`/`data`; both are
/// empty (and unused) on the Laplacian path. Accumulates INTO `out` (one
/// f64 slot per query row), data tiles in order, so callers may feed data
/// chunks sequentially and keep a deterministic summation order.
#[allow(clippy::too_many_arguments)]
fn sums_rows(
    mk: &MicroKernel,
    kernel: Kernel,
    queries: &[f32],
    data: &[f32],
    d: usize,
    qn: &[f32],
    xn: &[f32],
    out: &mut [f64],
) {
    debug_assert_eq!(queries.len() / d, out.len());
    let mut kbuf = [0.0f32; DTILE];
    let mut sqbuf = [0.0f32; DTILE];
    if kernel == Kernel::Laplacian {
        // L1 distances for a whole tile land in `sqbuf` so the kernel map
        // runs lane-parallel over the tile, exactly like the L2 path.
        for tile in data.chunks(DTILE * d) {
            let rows = tile.len() / d;
            for (qi, q) in queries.chunks_exact(d).enumerate() {
                for (j, x) in tile.chunks_exact(d).enumerate() {
                    sqbuf[j] = (mk.l1)(q, x);
                }
                (mk.map_kernel_sq)(kernel, &sqbuf[..rows], &mut kbuf[..rows]);
                let mut acc = 0.0f64;
                for &k in &kbuf[..rows] {
                    acc += k as f64;
                }
                out[qi] += acc;
            }
        }
        return;
    }
    for (ti, tile) in data.chunks(DTILE * d).enumerate() {
        let rows = tile.len() / d;
        let xn_t = &xn[ti * DTILE..ti * DTILE + rows];
        for (qi, q) in queries.chunks_exact(d).enumerate() {
            let qnv = qn[qi];
            for (j, x) in tile.chunks_exact(d).enumerate() {
                sqbuf[j] = qnv + xn_t[j] - 2.0 * (mk.dot)(q, x);
            }
            (mk.map_kernel_sq)(kernel, &sqbuf[..rows], &mut kbuf[..rows]);
            let mut acc = 0.0f64;
            for &k in &kbuf[..rows] {
                acc += k as f64;
            }
            out[qi] += acc;
        }
    }
}

/// Dense kernel block for a chunk of query rows; writes `rows x m` values
/// into `out` (row stride `m`, starting at the chunk's first row).
#[allow(clippy::too_many_arguments)]
fn block_rows(
    mk: &MicroKernel,
    kernel: Kernel,
    queries: &[f32],
    data: &[f32],
    d: usize,
    qn: &[f32],
    xn: &[f32],
    out: &mut [f32],
    m: usize,
) {
    debug_assert_eq!(queries.len() / d * m, out.len());
    let mut sqbuf = [0.0f32; DTILE];
    if kernel == Kernel::Laplacian {
        for (ti, tile) in data.chunks(DTILE * d).enumerate() {
            let off = ti * DTILE;
            let rows = tile.len() / d;
            for (qi, q) in queries.chunks_exact(d).enumerate() {
                for (j, x) in tile.chunks_exact(d).enumerate() {
                    sqbuf[j] = (mk.l1)(q, x);
                }
                let dst = &mut out[qi * m + off..qi * m + off + rows];
                (mk.map_kernel_sq)(kernel, &sqbuf[..rows], dst);
            }
        }
        return;
    }
    for (ti, tile) in data.chunks(DTILE * d).enumerate() {
        let off = ti * DTILE;
        let rows = tile.len() / d;
        let xn_t = &xn[off..off + rows];
        for (qi, q) in queries.chunks_exact(d).enumerate() {
            let qnv = qn[qi];
            for (j, x) in tile.chunks_exact(d).enumerate() {
                sqbuf[j] = qnv + xn_t[j] - 2.0 * (mk.dot)(q, x);
            }
            let dst = &mut out[qi * m + off..qi * m + off + rows];
            (mk.map_kernel_sq)(kernel, &sqbuf[..rows], dst);
        }
    }
}

impl KernelBackend for TiledBackend {
    fn sums(&self, kernel: Kernel, queries: &[f32], data: &[f32], d: usize) -> Vec<f64> {
        assert!(d > 0 && queries.len() % d == 0 && data.len() % d == 0);
        let b = queries.len() / d;
        let m = data.len() / d;
        self.calls.fetch_add(1, Ordering::Relaxed);
        let mut out = vec![0.0f64; b];
        if b == 0 || m == 0 {
            return out;
        }
        let l2 = kernel != Kernel::Laplacian;
        let mk = self.mk;
        let qn = if l2 { row_sq_norms(mk, queries, d) } else { Vec::new() };
        let xn = if l2 { row_sq_norms(mk, data, d) } else { Vec::new() };
        let qn_s: &[f32] = &qn;
        let xn_s: &[f32] = &xn;
        let evals = &self.evals;
        if self.threads == 1 {
            sums_rows(mk, kernel, queries, data, d, qn_s, xn_s, &mut out);
            evals.fetch_add((b * m) as u64, Ordering::Relaxed);
        } else if b >= self.threads {
            // Query split: each worker owns a disjoint slice of output
            // rows, so no reduction is needed and per-row summation order
            // is identical to the single-thread path.
            let chunk_rows = (b + self.threads - 1) / self.threads;
            let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            for (ci, out_chunk) in out.chunks_mut(chunk_rows).enumerate() {
                let lo = ci * chunk_rows;
                let rows = out_chunk.len();
                let q_chunk = &queries[lo * d..(lo + rows) * d];
                let qn_chunk = if l2 { &qn_s[lo..lo + rows] } else { qn_s };
                tasks.push(Box::new(move || {
                    sums_rows(mk, kernel, q_chunk, data, d, qn_chunk, xn_s, out_chunk);
                    evals.fetch_add((rows * m) as u64, Ordering::Relaxed);
                }));
            }
            self.execute(tasks);
        } else {
            // Few queries, much data (the KDE-sum shape for small batches):
            // split the data rows, fold per-worker partials in chunk order
            // AFTER the batch completes — the same grouping the scoped
            // path's join-in-spawn-order fold produced.
            let workers = self.threads.min((m + DTILE - 1) / DTILE).max(1);
            let mut chunk_rows = (m + workers - 1) / workers;
            chunk_rows = ((chunk_rows + DTILE - 1) / DTILE) * DTILE;
            let nchunks = (m + chunk_rows - 1) / chunk_rows;
            let mut parts: Vec<Vec<f64>> = (0..nchunks).map(|_| vec![0.0f64; b]).collect();
            let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            for (ci, part) in parts.iter_mut().enumerate() {
                let lo = ci * chunk_rows;
                let hi = (lo + chunk_rows).min(m);
                let d_chunk = &data[lo * d..hi * d];
                let xn_chunk: &[f32] = if l2 { &xn_s[lo..hi] } else { &[] };
                tasks.push(Box::new(move || {
                    sums_rows(mk, kernel, queries, d_chunk, d, qn_s, xn_chunk, part);
                    evals.fetch_add((b * (hi - lo)) as u64, Ordering::Relaxed);
                }));
            }
            self.execute(tasks);
            for part in &parts {
                for (o, p) in out.iter_mut().zip(part) {
                    *o += p;
                }
            }
        }
        out
    }

    fn block(&self, kernel: Kernel, queries: &[f32], data: &[f32], d: usize) -> Vec<f32> {
        assert!(d > 0 && queries.len() % d == 0 && data.len() % d == 0);
        let b = queries.len() / d;
        let m = data.len() / d;
        self.calls.fetch_add(1, Ordering::Relaxed);
        let mut out = vec![0.0f32; b * m];
        if b == 0 || m == 0 {
            return out;
        }
        let l2 = kernel != Kernel::Laplacian;
        let mk = self.mk;
        let qn = if l2 { row_sq_norms(mk, queries, d) } else { Vec::new() };
        let xn = if l2 { row_sq_norms(mk, data, d) } else { Vec::new() };
        let qn_s: &[f32] = &qn;
        let xn_s: &[f32] = &xn;
        let evals = &self.evals;
        if self.threads == 1 || b == 1 {
            block_rows(mk, kernel, queries, data, d, qn_s, xn_s, &mut out, m);
            evals.fetch_add((b * m) as u64, Ordering::Relaxed);
        } else {
            // Query split over disjoint output row ranges (the block shape
            // is row-parallel by construction; data-splitting would write
            // interleaved columns).
            let workers = self.threads.min(b);
            let chunk_rows = (b + workers - 1) / workers;
            let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            for (ci, out_chunk) in out.chunks_mut(chunk_rows * m).enumerate() {
                let lo = ci * chunk_rows;
                let rows = out_chunk.len() / m;
                let q_chunk = &queries[lo * d..(lo + rows) * d];
                let qn_chunk = if l2 { &qn_s[lo..lo + rows] } else { qn_s };
                tasks.push(Box::new(move || {
                    block_rows(mk, kernel, q_chunk, data, d, qn_chunk, xn_s, out_chunk, m);
                    evals.fetch_add((rows * m) as u64, Ordering::Relaxed);
                }));
            }
            self.execute(tasks);
        }
        out
    }

    fn sums_ranged(
        &self,
        kernel: Kernel,
        queries: &[f32],
        data: &[f32],
        d: usize,
        ranges: &[(usize, usize)],
    ) -> Vec<f64> {
        assert!(d > 0 && queries.len() % d == 0 && data.len() % d == 0);
        let b = queries.len() / d;
        let m = data.len() / d;
        assert_eq!(ranges.len(), b, "one range per query row");
        for &(lo, hi) in ranges {
            assert!(lo <= hi && hi <= m, "range ({lo}, {hi}) out of bounds for m={m}");
        }
        // One dispatch for the whole fused submission.
        self.calls.fetch_add(1, Ordering::Relaxed);
        let mut out = vec![0.0f64; b];
        if b == 0 {
            return out;
        }
        let l2 = kernel != Kernel::Laplacian;
        let mk = self.mk;
        // Norms over the whole packed buffer, computed once and sliced per
        // row, so the L2 norm-trick cost matches the unfused path even when
        // many rows share a segment.
        let qn = if l2 { row_sq_norms(mk, queries, d) } else { Vec::new() };
        let xn = if l2 { row_sq_norms(mk, data, d) } else { Vec::new() };
        let qn_s: &[f32] = &qn;
        let xn_s: &[f32] = &xn;
        let evals = &self.evals;
        // Runs of consecutive rows sharing a range (a fused submission
        // keeps each node's rows adjacent) evaluate as ONE multi-row
        // sums_rows call, so a data tile stays cache-resident across the
        // whole run exactly like an unfused dispatch. Per row the walk is
        // the row's own range in DTILE chunks from its start — identical
        // for any worker count, and bit-identical to the unfused dispatch
        // except when that dispatch would take the data-split shape
        // (b < threads), whose partial-sum folding regroups the same
        // additions (module determinism note).
        let run_rows = |row0: usize, out_chunk: &mut [f64]| {
            let mut pairs = 0u64;
            let mut k = 0usize;
            while k < out_chunk.len() {
                let (lo, hi) = ranges[row0 + k];
                let mut k1 = k + 1;
                while k1 < out_chunk.len() && ranges[row0 + k1] == (lo, hi) {
                    k1 += 1;
                }
                if hi > lo {
                    pairs += ((k1 - k) * (hi - lo)) as u64;
                    let q = &queries[(row0 + k) * d..(row0 + k1) * d];
                    let qn_run = if l2 { &qn_s[row0 + k..row0 + k1] } else { qn_s };
                    let xn_run = if l2 { &xn_s[lo..hi] } else { xn_s };
                    sums_rows(
                        mk,
                        kernel,
                        q,
                        &data[lo * d..hi * d],
                        d,
                        qn_run,
                        xn_run,
                        &mut out_chunk[k..k1],
                    );
                }
                k = k1;
            }
            evals.fetch_add(pairs, Ordering::Relaxed);
        };
        if self.threads == 1 || b == 1 {
            run_rows(0, &mut out);
        } else {
            let chunk_rows = (b + self.threads - 1) / self.threads;
            let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            for (ci, out_chunk) in out.chunks_mut(chunk_rows).enumerate() {
                let run = &run_rows;
                tasks.push(Box::new(move || run(ci * chunk_rows, out_chunk)));
            }
            self.execute(tasks);
        }
        out
    }

    fn block_ranged(
        &self,
        kernel: Kernel,
        queries: &[f32],
        data: &[f32],
        d: usize,
        ranges: &[(usize, usize)],
    ) -> Vec<f32> {
        assert!(d > 0 && queries.len() % d == 0 && data.len() % d == 0);
        let b = queries.len() / d;
        let m = data.len() / d;
        assert_eq!(ranges.len(), b, "one range per query row");
        // One dispatch for the whole fused submission; per-row output
        // offsets into the ragged concatenation.
        self.calls.fetch_add(1, Ordering::Relaxed);
        let mut offsets = Vec::with_capacity(b + 1);
        let mut total = 0usize;
        offsets.push(0usize);
        for &(lo, hi) in ranges {
            assert!(lo <= hi && hi <= m, "range ({lo}, {hi}) out of bounds for m={m}");
            total += hi - lo;
            offsets.push(total);
        }
        let mut out = vec![0.0f32; total];
        if b == 0 || total == 0 {
            return out;
        }
        let l2 = kernel != Kernel::Laplacian;
        let mk = self.mk;
        let qn = if l2 { row_sq_norms(mk, queries, d) } else { Vec::new() };
        let xn = if l2 { row_sq_norms(mk, data, d) } else { Vec::new() };
        let qn_s: &[f32] = &qn;
        let xn_s: &[f32] = &xn;
        let evals = &self.evals;
        let offsets_s: &[usize] = &offsets;
        // Runs of consecutive rows sharing a range (the planner keeps a
        // chunk's rows adjacent) evaluate as ONE multi-row block_rows call
        // with the run's range length as the output row stride; each value
        // is a pure per-pair function, so the ragged block is bit-identical
        // to per-row `block` calls for any worker count.
        let run_rows = |row0: usize, row1: usize, out_chunk: &mut [f32]| {
            let base = offsets_s[row0];
            let mut pairs = 0u64;
            let mut k = row0;
            while k < row1 {
                let (lo, hi) = ranges[k];
                let mut k1 = k + 1;
                while k1 < row1 && ranges[k1] == (lo, hi) {
                    k1 += 1;
                }
                if hi > lo {
                    let m_run = hi - lo;
                    pairs += ((k1 - k) * m_run) as u64;
                    let q = &queries[k * d..k1 * d];
                    let qn_run = if l2 { &qn_s[k..k1] } else { qn_s };
                    let xn_run = if l2 { &xn_s[lo..hi] } else { xn_s };
                    let dst = &mut out_chunk[offsets_s[k] - base..offsets_s[k1] - base];
                    block_rows(mk, kernel, q, &data[lo * d..hi * d], d, qn_run, xn_run, dst, m_run);
                }
                k = k1;
            }
            evals.fetch_add(pairs, Ordering::Relaxed);
        };
        if self.threads == 1 || b == 1 {
            run_rows(0, b, &mut out);
        } else {
            // Query split over disjoint ragged output chunks.
            let chunk_rows = (b + self.threads - 1) / self.threads;
            let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            {
                let run = &run_rows;
                let mut rest: &mut [f32] = &mut out;
                let mut r0 = 0usize;
                while r0 < b {
                    let r1 = (r0 + chunk_rows).min(b);
                    let len = offsets_s[r1] - offsets_s[r0];
                    let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(len);
                    rest = tail;
                    tasks.push(Box::new(move || run(r0, r1, chunk)));
                    r0 = r1;
                }
            }
            self.execute(tasks);
        }
        out
    }

    fn kernel_evals(&self) -> u64 {
        self.evals.load(Ordering::Relaxed)
    }

    fn calls(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }

    fn name(&self) -> &'static str {
        "tiled"
    }

    fn isa(&self) -> &'static str {
        self.mk.isa.name()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::kernel::ALL_KERNELS;
    use crate::runtime::backend::CpuBackend;
    use crate::util::rng::Rng;

    fn rand_buf(rng: &mut Rng, n: usize, scale: f64) -> Vec<f32> {
        (0..n).map(|_| (rng.normal() * scale) as f32).collect()
    }

    #[test]
    fn matches_cpu_backend_smoke() {
        let mut rng = Rng::new(811);
        let (b, m, d) = (9usize, 301usize, 13usize);
        let queries = rand_buf(&mut rng, b * d, 1.5);
        let data = rand_buf(&mut rng, m * d, 1.5);
        let cpu = CpuBackend::new();
        let tiled = TiledBackend::with_threads(3);
        for k in ALL_KERNELS {
            let want = cpu.sums(k, &queries, &data, d);
            let got = tiled.sums(k, &queries, &data, d);
            for (g, w) in got.iter().zip(&want) {
                assert!(
                    (g - w).abs() < 2e-3 * (1.0 + w.abs()),
                    "{:?}: tiled {g} vs cpu {w}",
                    k
                );
            }
            let want_b = cpu.block(k, &queries, &data, d);
            let got_b = tiled.block(k, &queries, &data, d);
            for (g, w) in got_b.iter().zip(&want_b) {
                assert!(
                    (g - w).abs() < 1e-3 * (1.0 + w.abs()),
                    "{:?} block: tiled {g} vs cpu {w}",
                    k
                );
            }
        }
    }

    #[test]
    fn eval_and_call_counters() {
        let be = TiledBackend::with_threads(2);
        let q = vec![0.0f32; 3 * 2];
        let x = vec![0.5f32; 5 * 2];
        be.sums(Kernel::Gaussian, &q, &x, 2);
        assert_eq!(be.kernel_evals(), 15);
        assert_eq!(be.calls(), 1);
        be.block(Kernel::Laplacian, &q, &x, 2);
        assert_eq!(be.kernel_evals(), 30);
        assert_eq!(be.calls(), 2);
    }

    #[test]
    fn query_split_is_thread_count_invariant() {
        // With b >= threads both paths sum each output row over the data
        // tiles in the same order -> bitwise identical results.
        let mut rng = Rng::new(813);
        let (b, m, d) = (16usize, 200usize, 7usize);
        let queries = rand_buf(&mut rng, b * d, 1.0);
        let data = rand_buf(&mut rng, m * d, 1.0);
        let t1 = TiledBackend::with_threads(1);
        let t4 = TiledBackend::with_threads(4);
        for k in ALL_KERNELS {
            let a = t1.sums(k, &queries, &data, d);
            let c = t4.sums(k, &queries, &data, d);
            for (x, y) in a.iter().zip(&c) {
                assert_eq!(x.to_bits(), y.to_bits(), "{:?} nondeterministic", k);
            }
        }
    }

    #[test]
    fn empty_inputs() {
        let be = TiledBackend::with_threads(4);
        let q = vec![0.25f32; 2 * 3];
        let empty: Vec<f32> = Vec::new();
        // empty data -> zero sums, empty block
        let s = be.sums(Kernel::Gaussian, &q, &empty, 3);
        assert_eq!(s, vec![0.0, 0.0]);
        assert!(be.block(Kernel::Gaussian, &q, &empty, 3).is_empty());
        // empty queries -> empty outputs
        assert!(be.sums(Kernel::Gaussian, &empty, &q, 3).is_empty());
        assert!(be.block(Kernel::Gaussian, &empty, &q, 3).is_empty());
    }

    #[test]
    fn forced_scalar_mode_matches_auto() {
        // The vtable is the only difference between modes; sums must agree
        // within SIMD reassociation tolerance and the reported ISA must
        // reflect the forced mode.
        let mut rng = Rng::new(817);
        let (b, m, d) = (5usize, 150usize, 19usize);
        let queries = rand_buf(&mut rng, b * d, 1.0);
        let data = rand_buf(&mut rng, m * d, 1.0);
        let scalar = TiledBackend::with_simd(2, SimdMode::Scalar).unwrap();
        assert_eq!(scalar.isa(), "scalar");
        let auto = TiledBackend::with_threads(2);
        assert_eq!(auto.isa(), auto.microkernel().isa.name());
        for k in ALL_KERNELS {
            let a = scalar.sums(k, &queries, &data, d);
            let c = auto.sums(k, &queries, &data, d);
            for (x, y) in a.iter().zip(&c) {
                assert!((x - y).abs() < 1e-3 * (1.0 + y.abs()), "{:?}: {x} vs {y}", k);
            }
        }
    }

    #[test]
    fn sums_ranged_matches_unfused_subslice_bitwise() {
        // Each fused row must reproduce the unfused per-node dispatch over
        // its sub-slice bit-for-bit, and the result must be independent of
        // the worker count (rows are worker-disjoint).
        let mut rng = Rng::new(819);
        let (b, m, d) = (7usize, 300usize, 11usize);
        let queries = rand_buf(&mut rng, b * d, 1.0);
        let data = rand_buf(&mut rng, m * d, 1.0);
        // Ranges straddling DTILE boundaries, plus empty and full ranges;
        // rows 1-2 share a range so the equal-range run grouping (one
        // multi-row sums_rows call) is exercised too.
        let ranges: [(usize, usize); 7] =
            [(0, 300), (0, 128), (0, 128), (5, 5), (127, 129), (250, 300), (0, 1)];
        let t1 = TiledBackend::with_threads(1);
        let t4 = TiledBackend::with_threads(4);
        for k in ALL_KERNELS {
            let f1 = t1.sums_ranged(k, &queries, &data, d, &ranges);
            let f4 = t4.sums_ranged(k, &queries, &data, d, &ranges);
            for (q, &(lo, hi)) in ranges.iter().enumerate() {
                let want = if hi > lo {
                    t1.sums(k, &queries[q * d..(q + 1) * d], &data[lo * d..hi * d], d)[0]
                } else {
                    0.0
                };
                assert_eq!(
                    f1[q].to_bits(),
                    want.to_bits(),
                    "{:?} row {q}: fused {} vs unfused {want}",
                    k,
                    f1[q]
                );
                assert_eq!(f1[q].to_bits(), f4[q].to_bits(), "{:?} thread-dependent", k);
            }
        }
    }

    #[test]
    fn block_ranged_matches_unfused_block_bitwise() {
        // Every fused row must reproduce the per-row `block` dispatch over
        // its sub-slice bit for bit, independent of the worker count.
        let mut rng = Rng::new(821);
        let (b, m, d) = (7usize, 300usize, 11usize);
        let queries = rand_buf(&mut rng, b * d, 1.0);
        let data = rand_buf(&mut rng, m * d, 1.0);
        // Ranges straddling DTILE boundaries, plus empty/full ranges and
        // an equal-range run (rows 1-2).
        let ranges: [(usize, usize); 7] =
            [(0, 300), (0, 128), (0, 128), (5, 5), (127, 129), (250, 300), (0, 1)];
        let t1 = TiledBackend::with_threads(1);
        let t4 = TiledBackend::with_threads(4);
        for k in ALL_KERNELS {
            let f1 = t1.block_ranged(k, &queries, &data, d, &ranges);
            let f4 = t4.block_ranged(k, &queries, &data, d, &ranges);
            assert_eq!(f1.len(), f4.len());
            let mut off = 0usize;
            for (q, &(lo, hi)) in ranges.iter().enumerate() {
                if hi == lo {
                    continue;
                }
                let want = t1.block(k, &queries[q * d..(q + 1) * d], &data[lo * d..hi * d], d);
                for (j, w) in want.iter().enumerate() {
                    assert_eq!(
                        f1[off + j].to_bits(),
                        w.to_bits(),
                        "{:?} row {q} col {j}: fused {} vs block {w}",
                        k,
                        f1[off + j]
                    );
                    assert_eq!(f1[off + j].to_bits(), f4[off + j].to_bits(), "{:?} threads", k);
                }
                off += hi - lo;
            }
            assert_eq!(off, f1.len());
        }
    }

    #[test]
    fn block_ranged_counters() {
        let be = TiledBackend::with_threads(2);
        let q = vec![0.0f32; 3 * 2];
        let x = vec![0.5f32; 5 * 2];
        let out = be.block_ranged(Kernel::Gaussian, &q, &x, 2, &[(0, 5), (1, 3), (4, 4)]);
        assert_eq!(out.len(), 7);
        assert_eq!(be.calls(), 1, "a fused block submission is one dispatch");
        assert_eq!(be.kernel_evals(), 7, "pairs fold across workers");
    }

    #[test]
    fn sums_ranged_counters() {
        let be = TiledBackend::with_threads(2);
        let q = vec![0.0f32; 3 * 2];
        let x = vec![0.5f32; 5 * 2];
        be.sums_ranged(Kernel::Gaussian, &q, &x, 2, &[(0, 5), (1, 3), (4, 4)]);
        assert_eq!(be.calls(), 1, "a fused submission is one dispatch");
        assert_eq!(be.kernel_evals(), 7, "pairs fold across workers");
    }

    #[test]
    fn self_kernel_is_one_at_realistic_scale() {
        let mut rng = Rng::new(815);
        let d = 24;
        let q = rand_buf(&mut rng, d, 2.0);
        let be = TiledBackend::with_threads(1);
        for k in ALL_KERNELS {
            let v = be.block(k, &q, &q, d)[0];
            assert!((v - 1.0).abs() < 1e-4, "{:?}: k(x,x) = {v}", k);
        }
    }
}
