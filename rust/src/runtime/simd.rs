//! Explicit SIMD microkernels for the tiled CPU backend, with one-time
//! runtime dispatch.
//!
//! The tiled backend's three hot inner loops — the `dot` product behind
//! the blocked-GEMM distance trick, the `l1` distance of the Laplacian
//! path, and the tile-wide kernel map (`fast_exp_neg` evaluated over a
//! whole distance tile) — previously relied on LLVM autovectorization.
//! On the baseline `x86_64-unknown-linux-gnu` target that means SSE2:
//! 4-wide, no FMA, and a *scalar* exp per pair because the underflow
//! branch in `fast_exp_neg` defeats the vectorizer. This module provides
//! hand-written AVX2+FMA (x86_64) and NEON (aarch64) implementations plus
//! the portable scalar fallback, packaged as a [`MicroKernel`] vtable of
//! plain function pointers.
//!
//! Dispatch design: the ISA is picked **once**, at backend construction
//! ([`MicroKernel::select`] / [`MicroKernel::detect`], via
//! `is_x86_feature_detected!` on x86_64), and the chosen vtable is stored
//! on the backend. The per-tile loops call straight through the function
//! pointers — no per-tile or per-pair feature branching, and a forced
//! scalar vtable (`--simd scalar` on the CLI) gives an exact A/B of the
//! SIMD gain on identical code paths.
//!
//! Numerical contract (pinned by `tests/simd_parity.rs`):
//!
//! * `dot` / `l1` accumulate in a different order (and with FMA) than the
//!   scalar path, so results differ from the scalar implementation by
//!   reassociation roundoff only: within `4 * n * eps` of the f64
//!   reference, where `n` is the vector length and `eps = 2^-24`.
//! * `exp_neg` / `map_kernel_sq` evaluate the *same* polynomial as
//!   [`fast_exp_neg`] (coefficients shared via [`crate::kernel::fexp`]);
//!   lane results differ from the scalar routine by FMA rounding, and —
//!   near a half-ulp tie in the range reduction, where the fused multiply
//!   can round the exponent integer the other way — by at most ~128 ULPs,
//!   with both sides inside the polynomial's 5e-6 envelope. Both are
//!   within 1e-5 relative of the true `exp`. Inputs below
//!   [`fexp::UNDERFLOW`] hard-underflow to exactly `0.0` on every path
//!   (the PJRT FAR-padding contract), including inputs whose intermediate
//!   products overflow f32.
//!
//! All slice arguments of a lane implementation handle `len % lanes != 0`
//! remainders explicitly (scalar tail over the shared coefficients).
//! Every entry point debug-asserts matching input lengths, and — because
//! the vtable is a safe public API whose debug asserts compile out in
//! release — the lane loops are additionally bounded by the *minimum* of
//! the slice lengths, so a length mismatch truncates (like the scalar
//! `zip`) instead of reading or writing out of bounds.

use crate::kernel::{fast_exp_neg, fexp, Kernel};

/// Instruction set a [`MicroKernel`] was built for.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Isa {
    /// AVX2 + FMA, 8 f32 lanes (x86_64, runtime-detected).
    Avx2,
    /// NEON, 4 f32 lanes (aarch64 baseline).
    Neon,
    /// Portable Rust with 8-way manual accumulators (LLVM autovectorizes
    /// the distance loops to whatever the target baseline offers).
    Scalar,
}

impl Isa {
    /// Lower-case name used in bench JSON and reports.
    pub fn name(self) -> &'static str {
        match self {
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
            Isa::Scalar => "scalar",
        }
    }
}

/// Requested dispatch mode (`kdem --simd {auto,avx2,neon,scalar}`).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum SimdMode {
    /// Best ISA the host supports (the default).
    Auto,
    /// Force the AVX2+FMA vtable (errors on non-supporting hosts).
    Avx2,
    /// Force the NEON vtable (errors on non-aarch64 builds).
    Neon,
    /// Force the portable scalar vtable (the A/B baseline).
    Scalar,
}

impl SimdMode {
    /// Parse a `--simd` argument; `None` for unknown names.
    pub fn from_name(s: &str) -> Option<SimdMode> {
        Some(match s {
            "auto" => SimdMode::Auto,
            "avx2" => SimdMode::Avx2,
            "neon" => SimdMode::Neon,
            "scalar" => SimdMode::Scalar,
            _ => return None,
        })
    }

    /// The name [`from_name`](Self::from_name) round-trips.
    pub fn name(self) -> &'static str {
        match self {
            SimdMode::Auto => "auto",
            SimdMode::Avx2 => "avx2",
            SimdMode::Neon => "neon",
            SimdMode::Scalar => "scalar",
        }
    }
}

/// Every dispatch mode, for CLI help and round-trip tests.
pub const ALL_MODES: [SimdMode; 4] =
    [SimdMode::Auto, SimdMode::Avx2, SimdMode::Neon, SimdMode::Scalar];

/// Function-pointer vtable over the three hot inner loops. Selected once
/// at backend construction; the tile loops call through it with zero
/// per-tile branching.
pub struct MicroKernel {
    /// Instruction set these function pointers were built for.
    pub isa: Isa,
    /// `sum_i x[i] * y[i]`.
    pub dot: fn(&[f32], &[f32]) -> f32,
    /// `sum_i |x[i] - y[i]|`.
    pub l1: fn(&[f32], &[f32]) -> f32,
    /// Map a tile of distances to kernel values. For the L2 family the
    /// input holds *squared* L2 distances; for `Kernel::Laplacian` it
    /// holds L1 distances. Negative inputs (norm-trick cancellation
    /// residue) are clamped to zero before the map.
    pub map_kernel_sq: fn(Kernel, &[f32], &mut [f32]),
    /// `out[i] = exp(-max(dists[i], 0))` — the lane-parallel
    /// [`fast_exp_neg`] building block, exposed for direct A/B and ULP
    /// testing.
    pub exp_neg: fn(&[f32], &mut [f32]),
}

static SCALAR: MicroKernel = MicroKernel {
    isa: Isa::Scalar,
    dot: scalar::dot,
    l1: scalar::l1,
    map_kernel_sq: scalar::map_kernel_sq,
    exp_neg: scalar::exp_neg,
};

#[cfg(all(target_arch = "x86_64", not(miri)))]
static AVX2: MicroKernel = MicroKernel {
    isa: Isa::Avx2,
    dot: avx2::dot,
    l1: avx2::l1,
    map_kernel_sq: avx2::map_kernel_sq,
    exp_neg: avx2::exp_neg,
};

#[cfg(all(target_arch = "aarch64", not(miri)))]
static NEON: MicroKernel = MicroKernel {
    isa: Isa::Neon,
    dot: neon::dot,
    l1: neon::l1,
    map_kernel_sq: neon::map_kernel_sq,
    exp_neg: neon::exp_neg,
};

/// The AVX2 vtable, if this build targets x86_64 AND the host passes
/// runtime detection (`is_x86_feature_detected!`). Under Miri the
/// vector paths are reported unavailable — the interpreter cannot
/// execute the intrinsics — so the Miri CI leg checks the scalar
/// microkernels and the dispatch logic around them.
#[cfg(all(target_arch = "x86_64", not(miri)))]
fn avx2_kernel() -> Option<&'static MicroKernel> {
    if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
        Some(&AVX2)
    } else {
        None
    }
}

#[cfg(any(not(target_arch = "x86_64"), miri))]
fn avx2_kernel() -> Option<&'static MicroKernel> {
    None
}

/// The NEON vtable; aarch64 carries NEON in its baseline, so there is
/// nothing to runtime-detect beyond the target architecture (and, as
/// with AVX2 above, Miri reports it unavailable).
#[cfg(all(target_arch = "aarch64", not(miri)))]
fn neon_kernel() -> Option<&'static MicroKernel> {
    Some(&NEON)
}

#[cfg(any(not(target_arch = "aarch64"), miri))]
fn neon_kernel() -> Option<&'static MicroKernel> {
    None
}

impl MicroKernel {
    /// Best microkernel the host supports.
    pub fn detect() -> &'static MicroKernel {
        if let Some(mk) = avx2_kernel() {
            return mk;
        }
        if let Some(mk) = neon_kernel() {
            return mk;
        }
        &SCALAR
    }

    /// Resolve an explicit mode; errors if the host (or this build's
    /// target architecture) cannot run the requested ISA, so `--simd`
    /// A/B runs never silently fall back.
    pub fn select(mode: SimdMode) -> Result<&'static MicroKernel, String> {
        match mode {
            SimdMode::Auto => Ok(Self::detect()),
            SimdMode::Scalar => Ok(&SCALAR),
            SimdMode::Avx2 => avx2_kernel()
                .ok_or_else(|| "avx2+fma not available on this host".to_string()),
            SimdMode::Neon => neon_kernel()
                .ok_or_else(|| "neon requires an aarch64 build".to_string()),
        }
    }

    /// Every microkernel runnable on this host (scalar first). Used by
    /// the parity tests and the per-ISA bench series.
    pub fn available() -> Vec<&'static MicroKernel> {
        let mut v = vec![&SCALAR];
        v.extend(avx2_kernel());
        v.extend(neon_kernel());
        v
    }
}

/// Portable implementations. `dot`/`l1` keep the 8-way manual-accumulator
/// layout (LLVM autovectorizes it to the target baseline); the maps run
/// the shared-coefficient scalar [`fast_exp_neg`], which the compiler
/// pipelines across a tile but cannot vectorize past the underflow branch.
mod scalar {
    use super::{fast_exp_neg, Kernel};

    const LANES: usize = 8;

    pub(super) fn dot(x: &[f32], y: &[f32]) -> f32 {
        debug_assert_eq!(x.len(), y.len(), "dot: mismatched input lengths");
        let mut acc = [0.0f32; LANES];
        let mut xc = x.chunks_exact(LANES);
        let mut yc = y.chunks_exact(LANES);
        for (xa, ya) in (&mut xc).zip(&mut yc) {
            for l in 0..LANES {
                acc[l] += xa[l] * ya[l];
            }
        }
        let mut s: f32 = acc.iter().sum();
        for (a, b) in xc.remainder().iter().zip(yc.remainder()) {
            s += a * b;
        }
        s
    }

    pub(super) fn l1(x: &[f32], y: &[f32]) -> f32 {
        debug_assert_eq!(x.len(), y.len(), "l1: mismatched input lengths");
        let mut acc = [0.0f32; LANES];
        let mut xc = x.chunks_exact(LANES);
        let mut yc = y.chunks_exact(LANES);
        for (xa, ya) in (&mut xc).zip(&mut yc) {
            for l in 0..LANES {
                acc[l] += (xa[l] - ya[l]).abs();
            }
        }
        let mut s: f32 = acc.iter().sum();
        for (a, b) in xc.remainder().iter().zip(yc.remainder()) {
            s += (a - b).abs();
        }
        s
    }

    pub(super) fn exp_neg(dists: &[f32], out: &mut [f32]) {
        debug_assert_eq!(dists.len(), out.len(), "exp_neg: mismatched lengths");
        for (o, &t) in out.iter_mut().zip(dists) {
            *o = fast_exp_neg(-t.max(0.0));
        }
    }

    pub(super) fn map_kernel_sq(kernel: Kernel, dists: &[f32], out: &mut [f32]) {
        debug_assert_eq!(dists.len(), out.len(), "map_kernel_sq: mismatched lengths");
        match kernel {
            Kernel::Gaussian | Kernel::Laplacian => exp_neg(dists, out),
            Kernel::Exponential => {
                for (o, &s) in out.iter_mut().zip(dists) {
                    *o = fast_exp_neg(-s.max(0.0).sqrt());
                }
            }
            Kernel::RationalQuadratic => {
                for (o, &s) in out.iter_mut().zip(dists) {
                    *o = 1.0 / (1.0 + s.max(0.0));
                }
            }
        }
    }
}

/// AVX2 + FMA, 8 f32 lanes.
///
/// SAFETY invariant for the whole module: the safe wrappers below are only
/// reachable through the `AVX2` vtable, which `MicroKernel::select` /
/// `detect` hand out exclusively after `is_x86_feature_detected!("avx2")`
/// and `("fma")` both pass, so the `#[target_feature]` functions always
/// run on a supporting CPU.
#[cfg(all(target_arch = "x86_64", not(miri)))]
mod avx2 {
    use std::arch::x86_64::*;

    use super::{fexp, scalar, Kernel};

    pub(super) fn dot(x: &[f32], y: &[f32]) -> f32 {
        debug_assert_eq!(x.len(), y.len(), "dot: mismatched input lengths");
        // SAFETY: module invariant — AVX2+FMA verified at vtable selection.
        unsafe { dot_impl(x, y) }
    }

    pub(super) fn l1(x: &[f32], y: &[f32]) -> f32 {
        debug_assert_eq!(x.len(), y.len(), "l1: mismatched input lengths");
        // SAFETY: module invariant.
        unsafe { l1_impl(x, y) }
    }

    pub(super) fn exp_neg(dists: &[f32], out: &mut [f32]) {
        debug_assert_eq!(dists.len(), out.len(), "exp_neg: mismatched lengths");
        // SAFETY: module invariant.
        unsafe { exp_neg_impl(dists, out) }
    }

    pub(super) fn map_kernel_sq(kernel: Kernel, dists: &[f32], out: &mut [f32]) {
        debug_assert_eq!(dists.len(), out.len(), "map_kernel_sq: mismatched lengths");
        // SAFETY: module invariant.
        unsafe { map_impl(kernel, dists, out) }
    }

    /// Sum the 8 lanes of `v`.
    #[inline]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn hsum(v: __m256) -> f32 {
        let lo = _mm256_castps256_ps128(v);
        let hi = _mm256_extractf128_ps::<1>(v);
        let s = _mm_add_ps(lo, hi);
        let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_add_ss(s, _mm_shuffle_ps::<0b01>(s, s));
        _mm_cvtss_f32(s)
    }

    #[target_feature(enable = "avx2,fma")]
    unsafe fn dot_impl(x: &[f32], y: &[f32]) -> f32 {
        // min() keeps the raw-pointer loop in bounds even if the release
        // build skipped the wrapper's length debug-assert.
        let n = x.len().min(y.len());
        let xp = x.as_ptr();
        let yp = y.as_ptr();
        // Two accumulators hide the 4-cycle FMA latency at d = 64
        // (8 iterations of 8 lanes, 4 per chain).
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 16 <= n {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(xp.add(i)), _mm256_loadu_ps(yp.add(i)), acc0);
            acc1 = _mm256_fmadd_ps(
                _mm256_loadu_ps(xp.add(i + 8)),
                _mm256_loadu_ps(yp.add(i + 8)),
                acc1,
            );
            i += 16;
        }
        if i + 8 <= n {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(xp.add(i)), _mm256_loadu_ps(yp.add(i)), acc0);
            i += 8;
        }
        let mut s = hsum(_mm256_add_ps(acc0, acc1));
        // Explicit d % 8 remainder.
        while i < n {
            s += *xp.add(i) * *yp.add(i);
            i += 1;
        }
        s
    }

    #[target_feature(enable = "avx2,fma")]
    unsafe fn l1_impl(x: &[f32], y: &[f32]) -> f32 {
        let n = x.len().min(y.len());
        let xp = x.as_ptr();
        let yp = y.as_ptr();
        // Clearing the sign bit computes |a - b| without a branch.
        let absmask = _mm256_castsi256_ps(_mm256_set1_epi32(0x7FFF_FFFF));
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 16 <= n {
            let d0 = _mm256_sub_ps(_mm256_loadu_ps(xp.add(i)), _mm256_loadu_ps(yp.add(i)));
            acc0 = _mm256_add_ps(acc0, _mm256_and_ps(d0, absmask));
            let d1 =
                _mm256_sub_ps(_mm256_loadu_ps(xp.add(i + 8)), _mm256_loadu_ps(yp.add(i + 8)));
            acc1 = _mm256_add_ps(acc1, _mm256_and_ps(d1, absmask));
            i += 16;
        }
        if i + 8 <= n {
            let d0 = _mm256_sub_ps(_mm256_loadu_ps(xp.add(i)), _mm256_loadu_ps(yp.add(i)));
            acc0 = _mm256_add_ps(acc0, _mm256_and_ps(d0, absmask));
            i += 8;
        }
        let mut s = hsum(_mm256_add_ps(acc0, acc1));
        while i < n {
            s += (*xp.add(i) - *yp.add(i)).abs();
            i += 1;
        }
        s
    }

    /// `exp(-max(t, 0))` on 8 lanes — the same range reduction and
    /// polynomial as [`super::fast_exp_neg`], coefficients from
    /// [`fexp`]. The final mask zeroes every lane whose reduced input is
    /// below [`fexp::UNDERFLOW`]; that also scrubs any garbage from
    /// intermediate overflow on huge distances (FAR-padding rows), so
    /// those lanes return exactly `0.0` like the scalar routine.
    #[inline]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn exp_neg8(t: __m256) -> __m256 {
        let zero = _mm256_setzero_ps();
        let x = _mm256_sub_ps(zero, _mm256_max_ps(t, zero));
        let magic = _mm256_set1_ps(fexp::MAGIC);
        let j = _mm256_sub_ps(_mm256_fmadd_ps(x, _mm256_set1_ps(fexp::LOG2E), magic), magic);
        let f = _mm256_fnmadd_ps(j, _mm256_set1_ps(fexp::LN2_HI), x);
        let f = _mm256_fnmadd_ps(j, _mm256_set1_ps(fexp::LN2_LO), f);
        let p = _mm256_set1_ps(fexp::C5);
        let p = _mm256_fmadd_ps(p, f, _mm256_set1_ps(fexp::C4));
        let p = _mm256_fmadd_ps(p, f, _mm256_set1_ps(fexp::C3));
        let p = _mm256_fmadd_ps(p, f, _mm256_set1_ps(fexp::C2));
        let p = _mm256_fmadd_ps(p, f, _mm256_set1_ps(1.0));
        let p = _mm256_fmadd_ps(p, f, _mm256_set1_ps(1.0));
        let scale = _mm256_castsi256_ps(_mm256_slli_epi32::<23>(_mm256_add_epi32(
            _mm256_cvtps_epi32(j),
            _mm256_set1_epi32(127),
        )));
        let r = _mm256_mul_ps(scale, p);
        let live = _mm256_cmp_ps::<_CMP_GE_OQ>(x, _mm256_set1_ps(fexp::UNDERFLOW));
        _mm256_and_ps(r, live)
    }

    #[target_feature(enable = "avx2,fma")]
    unsafe fn exp_neg_impl(dists: &[f32], out: &mut [f32]) {
        let n = dists.len().min(out.len());
        let mut i = 0usize;
        while i + 8 <= n {
            let t = _mm256_loadu_ps(dists.as_ptr().add(i));
            _mm256_storeu_ps(out.as_mut_ptr().add(i), exp_neg8(t));
            i += 8;
        }
        scalar::exp_neg(&dists[i..n], &mut out[i..n]);
    }

    #[target_feature(enable = "avx2,fma")]
    unsafe fn map_impl(kernel: Kernel, dists: &[f32], out: &mut [f32]) {
        let n = dists.len().min(out.len());
        let dp = dists.as_ptr();
        let op = out.as_mut_ptr();
        let zero = _mm256_setzero_ps();
        let one = _mm256_set1_ps(1.0);
        let mut i = 0usize;
        match kernel {
            // Gaussian maps squared L2 distances, Laplacian maps L1
            // distances — the lane op is the same exp(-t).
            Kernel::Gaussian | Kernel::Laplacian => {
                while i + 8 <= n {
                    _mm256_storeu_ps(op.add(i), exp_neg8(_mm256_loadu_ps(dp.add(i))));
                    i += 8;
                }
            }
            Kernel::Exponential => {
                while i + 8 <= n {
                    let s = _mm256_max_ps(_mm256_loadu_ps(dp.add(i)), zero);
                    _mm256_storeu_ps(op.add(i), exp_neg8(_mm256_sqrt_ps(s)));
                    i += 8;
                }
            }
            Kernel::RationalQuadratic => {
                while i + 8 <= n {
                    let s = _mm256_max_ps(_mm256_loadu_ps(dp.add(i)), zero);
                    _mm256_storeu_ps(op.add(i), _mm256_div_ps(one, _mm256_add_ps(one, s)));
                    i += 8;
                }
            }
        }
        scalar::map_kernel_sq(kernel, &dists[i..n], &mut out[i..n]);
    }
}

/// NEON, 4 f32 lanes. NEON is part of the aarch64 baseline, so there is
/// nothing to runtime-detect; the `#[target_feature]` functions are always
/// safe to execute on this architecture.
#[cfg(all(target_arch = "aarch64", not(miri)))]
mod neon {
    use std::arch::aarch64::*;

    use super::{fexp, scalar, Kernel};

    pub(super) fn dot(x: &[f32], y: &[f32]) -> f32 {
        debug_assert_eq!(x.len(), y.len(), "dot: mismatched input lengths");
        // SAFETY: NEON is unconditionally available on aarch64.
        unsafe { dot_impl(x, y) }
    }

    pub(super) fn l1(x: &[f32], y: &[f32]) -> f32 {
        debug_assert_eq!(x.len(), y.len(), "l1: mismatched input lengths");
        // SAFETY: NEON is unconditionally available on aarch64.
        unsafe { l1_impl(x, y) }
    }

    pub(super) fn exp_neg(dists: &[f32], out: &mut [f32]) {
        debug_assert_eq!(dists.len(), out.len(), "exp_neg: mismatched lengths");
        // SAFETY: NEON is unconditionally available on aarch64.
        unsafe { exp_neg_impl(dists, out) }
    }

    pub(super) fn map_kernel_sq(kernel: Kernel, dists: &[f32], out: &mut [f32]) {
        debug_assert_eq!(dists.len(), out.len(), "map_kernel_sq: mismatched lengths");
        // SAFETY: NEON is unconditionally available on aarch64.
        unsafe { map_impl(kernel, dists, out) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn dot_impl(x: &[f32], y: &[f32]) -> f32 {
        // min() keeps the raw-pointer loop in bounds even if the release
        // build skipped the wrapper's length debug-assert.
        let n = x.len().min(y.len());
        let xp = x.as_ptr();
        let yp = y.as_ptr();
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i + 8 <= n {
            acc0 = vfmaq_f32(acc0, vld1q_f32(xp.add(i)), vld1q_f32(yp.add(i)));
            acc1 = vfmaq_f32(acc1, vld1q_f32(xp.add(i + 4)), vld1q_f32(yp.add(i + 4)));
            i += 8;
        }
        if i + 4 <= n {
            acc0 = vfmaq_f32(acc0, vld1q_f32(xp.add(i)), vld1q_f32(yp.add(i)));
            i += 4;
        }
        let mut s = vaddvq_f32(vaddq_f32(acc0, acc1));
        while i < n {
            s += *xp.add(i) * *yp.add(i);
            i += 1;
        }
        s
    }

    #[target_feature(enable = "neon")]
    unsafe fn l1_impl(x: &[f32], y: &[f32]) -> f32 {
        let n = x.len().min(y.len());
        let xp = x.as_ptr();
        let yp = y.as_ptr();
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i + 8 <= n {
            acc0 = vaddq_f32(acc0, vabdq_f32(vld1q_f32(xp.add(i)), vld1q_f32(yp.add(i))));
            acc1 = vaddq_f32(acc1, vabdq_f32(vld1q_f32(xp.add(i + 4)), vld1q_f32(yp.add(i + 4))));
            i += 8;
        }
        if i + 4 <= n {
            acc0 = vaddq_f32(acc0, vabdq_f32(vld1q_f32(xp.add(i)), vld1q_f32(yp.add(i))));
            i += 4;
        }
        let mut s = vaddvq_f32(vaddq_f32(acc0, acc1));
        while i < n {
            s += (*xp.add(i) - *yp.add(i)).abs();
            i += 1;
        }
        s
    }

    /// `exp(-max(t, 0))` on 4 lanes; same structure as the AVX2 version
    /// (shared coefficients, magic-constant rounding, underflow mask).
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn exp_neg4(t: float32x4_t) -> float32x4_t {
        let zero = vdupq_n_f32(0.0);
        let x = vnegq_f32(vmaxq_f32(t, zero));
        let magic = vdupq_n_f32(fexp::MAGIC);
        let j = vsubq_f32(vfmaq_f32(magic, x, vdupq_n_f32(fexp::LOG2E)), magic);
        let f = vfmsq_f32(x, j, vdupq_n_f32(fexp::LN2_HI));
        let f = vfmsq_f32(f, j, vdupq_n_f32(fexp::LN2_LO));
        let p = vdupq_n_f32(fexp::C5);
        let p = vfmaq_f32(vdupq_n_f32(fexp::C4), p, f);
        let p = vfmaq_f32(vdupq_n_f32(fexp::C3), p, f);
        let p = vfmaq_f32(vdupq_n_f32(fexp::C2), p, f);
        let p = vfmaq_f32(vdupq_n_f32(1.0), p, f);
        let p = vfmaq_f32(vdupq_n_f32(1.0), p, f);
        // j is integral and, for live lanes, in [-126, 0]: truncation
        // conversion is exact.
        let scale = vreinterpretq_f32_s32(vshlq_n_s32::<23>(vaddq_s32(
            vcvtq_s32_f32(j),
            vdupq_n_s32(127),
        )));
        let r = vmulq_f32(scale, p);
        let live = vcgeq_f32(x, vdupq_n_f32(fexp::UNDERFLOW));
        vreinterpretq_f32_u32(vandq_u32(vreinterpretq_u32_f32(r), live))
    }

    #[target_feature(enable = "neon")]
    unsafe fn exp_neg_impl(dists: &[f32], out: &mut [f32]) {
        let n = dists.len().min(out.len());
        let mut i = 0usize;
        while i + 4 <= n {
            vst1q_f32(out.as_mut_ptr().add(i), exp_neg4(vld1q_f32(dists.as_ptr().add(i))));
            i += 4;
        }
        scalar::exp_neg(&dists[i..n], &mut out[i..n]);
    }

    #[target_feature(enable = "neon")]
    unsafe fn map_impl(kernel: Kernel, dists: &[f32], out: &mut [f32]) {
        let n = dists.len().min(out.len());
        let dp = dists.as_ptr();
        let op = out.as_mut_ptr();
        let zero = vdupq_n_f32(0.0);
        let one = vdupq_n_f32(1.0);
        let mut i = 0usize;
        match kernel {
            Kernel::Gaussian | Kernel::Laplacian => {
                while i + 4 <= n {
                    vst1q_f32(op.add(i), exp_neg4(vld1q_f32(dp.add(i))));
                    i += 4;
                }
            }
            Kernel::Exponential => {
                while i + 4 <= n {
                    let s = vmaxq_f32(vld1q_f32(dp.add(i)), zero);
                    vst1q_f32(op.add(i), exp_neg4(vsqrtq_f32(s)));
                    i += 4;
                }
            }
            Kernel::RationalQuadratic => {
                while i + 4 <= n {
                    let s = vmaxq_f32(vld1q_f32(dp.add(i)), zero);
                    vst1q_f32(op.add(i), vdivq_f32(one, vaddq_f32(one, s)));
                    i += 4;
                }
            }
        }
        scalar::map_kernel_sq(kernel, &dists[i..n], &mut out[i..n]);
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::kernel::ALL_KERNELS;
    use crate::util::rng::Rng;

    #[test]
    fn detect_and_select_are_consistent() {
        let auto = MicroKernel::detect();
        assert_eq!(
            MicroKernel::select(SimdMode::Auto).unwrap().isa,
            auto.isa,
            "auto must resolve to detect()"
        );
        // Scalar is available everywhere.
        assert_eq!(MicroKernel::select(SimdMode::Scalar).unwrap().isa, Isa::Scalar);
        // Every available vtable is individually selectable by its mode.
        for mk in MicroKernel::available() {
            let mode = match mk.isa {
                Isa::Avx2 => SimdMode::Avx2,
                Isa::Neon => SimdMode::Neon,
                Isa::Scalar => SimdMode::Scalar,
            };
            assert_eq!(MicroKernel::select(mode).unwrap().isa, mk.isa);
        }
    }

    #[test]
    fn mode_names_round_trip() {
        for m in ALL_MODES {
            assert_eq!(SimdMode::from_name(m.name()), Some(m));
        }
        assert_eq!(SimdMode::from_name("sse9"), None);
    }

    #[test]
    fn every_available_microkernel_smoke() {
        // Light smoke over each host ISA; the heavy ULP/parity sweep lives
        // in tests/simd_parity.rs.
        let mut rng = Rng::new(421);
        for &d in &[1usize, 4, 8, 13, 64, 65] {
            let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let y: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let want_dot = (SCALAR.dot)(&x, &y);
            let want_l1 = (SCALAR.l1)(&x, &y);
            for mk in MicroKernel::available() {
                let got_dot = (mk.dot)(&x, &y);
                let got_l1 = (mk.l1)(&x, &y);
                assert!(
                    (got_dot - want_dot).abs() < 1e-4 * (1.0 + want_dot.abs()),
                    "{:?} dot d={d}: {got_dot} vs {want_dot}",
                    mk.isa
                );
                assert!(
                    (got_l1 - want_l1).abs() < 1e-4 * (1.0 + want_l1.abs()),
                    "{:?} l1 d={d}: {got_l1} vs {want_l1}",
                    mk.isa
                );
                for k in ALL_KERNELS {
                    let dists: Vec<f32> =
                        (0..d).map(|_| (rng.f64() * 10.0) as f32).collect();
                    let mut want = vec![0.0f32; d];
                    let mut got = vec![0.0f32; d];
                    (SCALAR.map_kernel_sq)(k, &dists, &mut want);
                    (mk.map_kernel_sq)(k, &dists, &mut got);
                    for (g, w) in got.iter().zip(&want) {
                        assert!(
                            (g - w).abs() < 1e-5 + 1e-4 * w.abs(),
                            "{:?} {:?}: {g} vs {w}",
                            mk.isa,
                            k
                        );
                    }
                }
            }
        }
    }
}
