//! Execution runtime: the `KernelBackend` contract, the pure-Rust scalar
//! CPU engine, the tiled multi-threaded CPU engine with its SIMD
//! microkernel layer, and the PJRT engine that loads the AOT HLO-text
//! artifacts produced by `python/compile/aot.py` (`make artifacts`;
//! requires the `xla` feature).
//!
//! Every backend implements the same two bulk primitives (`sums`,
//! `block`) plus the fused multi-range entry (`sums_ranged`) behind the
//! batched tree pipeline's level fusion, and reports a uniform dispatch
//! count through `calls()` — see `docs/ARCHITECTURE.md` for the
//! dispatch-counting contract shared by all backends.
#![warn(missing_docs)]

pub mod backend;
pub mod pjrt;
pub mod simd;
pub mod tiled;

pub use backend::{CpuBackend, KernelBackend};
pub use pjrt::{PjrtBackend, PjrtEngine};
pub use simd::{Isa, MicroKernel, SimdMode};
pub use tiled::TiledBackend;
