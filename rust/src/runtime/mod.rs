//! Execution runtime: the `KernelBackend` contract, the pure-Rust scalar
//! CPU engine, the tiled multi-threaded CPU engine with its SIMD
//! microkernel layer, and the PJRT engine that loads the AOT HLO-text
//! artifacts produced by `python/compile/aot.py` (`make artifacts`;
//! requires the `xla` feature).

pub mod backend;
pub mod pjrt;
pub mod simd;
pub mod tiled;

pub use backend::{CpuBackend, KernelBackend};
pub use pjrt::{PjrtBackend, PjrtEngine};
pub use simd::{Isa, MicroKernel, SimdMode};
pub use tiled::TiledBackend;
