//! Execution runtime: the `KernelBackend` contract, the pure-Rust scalar
//! CPU engine, the tiled multi-threaded CPU engine with its SIMD
//! microkernel layer, and the PJRT engine that loads the AOT HLO-text
//! artifacts produced by `python/compile/aot.py` (`make artifacts`;
//! requires the `xla` feature).
//!
//! Every backend implements the same two bulk primitives (`sums`,
//! `block`) plus the fused multi-range entry (`sums_ranged`) behind the
//! batched tree pipeline's level fusion, and reports a uniform dispatch
//! count through `calls()` — see `docs/ARCHITECTURE.md` for the
//! dispatch-counting contract shared by all backends.
//!
//! The failure model (docs/ARCHITECTURE.md §"Failure model") spans four
//! modules here: `error` defines the typed [`BackendError`] taxonomy and
//! the fallible `try_*` entry points every backend carries; `resilient`
//! composes retry-with-backoff and graceful degradation over any
//! primary/fallback backend pair; `fault` is the deterministic chaos
//! substrate that `tests/faults.rs` drives. Production code in this tree
//! must not `unwrap`/`expect` — failures travel as typed errors (the
//! clippy gate below is part of CI's `-D warnings` leg).
#![warn(missing_docs)]
#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod backend;
pub mod error;
pub mod fault;
pub mod pjrt;
pub mod pool;
pub mod resilient;
pub mod simd;
pub mod sync;
pub mod tiled;

pub use backend::{CpuBackend, KernelBackend};
pub use error::{BackendError, BackendResult};
pub use fault::{FaultInjectingBackend, FaultMode, FaultPlan};
pub use pjrt::{PjrtBackend, PjrtEngine};
pub use pool::{PoolConfig, WorkerPool};
pub use resilient::{ResilientBackend, RetryPolicy};
pub use simd::{Isa, MicroKernel, SimdMode};
pub use tiled::TiledBackend;
