//! Execution runtime: the `KernelBackend` contract, the pure-Rust CPU
//! engine, and the PJRT engine that loads the AOT HLO-text artifacts
//! produced by `python/compile/aot.py` (`make artifacts`).

pub mod backend;
pub mod pjrt;

pub use backend::{CpuBackend, KernelBackend};
pub use pjrt::{PjrtBackend, PjrtEngine};
