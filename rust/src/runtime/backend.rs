//! The kernel-evaluation backend contract shared by the pure-Rust CPU path
//! and the PJRT (AOT artifact) path.
//!
//! Every KDE estimator and every explicit row construction routes its bulk
//! kernel evaluations through a `KernelBackend`, so the same algorithm code
//! runs against either execution engine. Logical kernel-evaluation counts
//! (the paper's §7 cost metric) are tracked here.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::kernel::Kernel;
use crate::runtime::error::{catch_panic, BackendError};

/// Batched kernel evaluation engine.
///
/// Layouts: `queries` is `b x d` row-major, `data` is `m x d` row-major.
pub trait KernelBackend: Send + Sync {
    /// `out[q] = sum_j k(queries[q], data[j])` — the KDE-sum primitive.
    fn sums(&self, kernel: Kernel, queries: &[f32], data: &[f32], d: usize) -> Vec<f64>;

    /// `out[q*m + j] = k(queries[q], data[j])` — the dense block primitive.
    fn block(&self, kernel: Kernel, queries: &[f32], data: &[f32], d: usize) -> Vec<f32>;

    /// Fused multi-range KDE sums — the level-fusion primitive:
    /// `out[q] = sum_{j in ranges[q].0 .. ranges[q].1} k(queries[q], data[j])`,
    /// i.e. each query row attends only to its own contiguous row range of
    /// the shared `data` buffer. This is what lets the batched tree
    /// pipeline pack *several nodes'* query groups (each node's data
    /// packed as one segment of `data`) into a single backend dispatch;
    /// see `coordinator::batcher::plan_level_fusion` and
    /// `docs/ARCHITECTURE.md`.
    ///
    /// Contract:
    /// * `ranges.len() == queries.len() / d`; each `(lo, hi)` is in row
    ///   units with `lo <= hi <= data.len() / d`; `lo == hi` yields `0.0`.
    /// * Row `q`'s sum accumulates `data[lo*d..hi*d]` in index order with
    ///   a dedicated f64 accumulator — the same order a `sums` call uses
    ///   for that row on its per-row paths — so fused and unfused tree
    ///   evaluation memoize **bit-identical** values wherever the unfused
    ///   dispatch also walks rows in order ([`CpuBackend`] always;
    ///   `TiledBackend` except its data-split shape, `b < threads`, whose
    ///   unfused folding is itself only reproducible up to f64 rounding —
    ///   see `runtime::tiled`'s determinism note).
    /// * A backend that implements this natively counts the whole call as
    ///   ONE dispatch in [`calls`](Self::calls) (PJRT additionally counts
    ///   its padded grid executions). The provided implementation falls
    ///   back to one [`sums`](Self::sums) call per run of consecutive rows
    ///   sharing a range — correct for any backend, but without the
    ///   single-dispatch accounting.
    fn sums_ranged(
        &self,
        kernel: Kernel,
        queries: &[f32],
        data: &[f32],
        d: usize,
        ranges: &[(usize, usize)],
    ) -> Vec<f64> {
        assert!(d > 0 && queries.len() % d == 0 && data.len() % d == 0);
        let b = queries.len() / d;
        let m = data.len() / d;
        assert_eq!(ranges.len(), b, "one range per query row");
        let mut out = vec![0.0f64; b];
        let mut q0 = 0usize;
        while q0 < b {
            let (lo, hi) = ranges[q0];
            assert!(lo <= hi && hi <= m, "range ({lo}, {hi}) out of bounds for m={m}");
            let mut q1 = q0 + 1;
            while q1 < b && ranges[q1] == (lo, hi) {
                q1 += 1;
            }
            if hi > lo {
                let part = self.sums(kernel, &queries[q0 * d..q1 * d], &data[lo * d..hi * d], d);
                out[q0..q1].copy_from_slice(&part);
            }
            q0 = q1;
        }
        out
    }

    /// Fused multi-range dense block — the LRA row-construction primitive
    /// (`block`'s counterpart to [`sums_ranged`](Self::sums_ranged)):
    /// query row `q` contributes the `hi - lo` values
    /// `k(queries[q], data[j])` for `j in ranges[q].0 .. ranges[q].1`,
    /// concatenated in row order into one ragged buffer. Row `q`'s values
    /// start at `sum_{p < q} (ranges[p].1 - ranges[p].0)`.
    ///
    /// Contract:
    /// * `ranges.len() == queries.len() / d`; each `(lo, hi)` is in row
    ///   units with `lo <= hi <= data.len() / d`; `lo == hi` contributes
    ///   nothing.
    /// * Every value equals the one a plain [`block`](Self::block) call
    ///   over the row's sub-slice produces, **bit for bit** — block
    ///   entries are pure per-pair functions, so chunked LRA row
    ///   construction reproduces the monolithic `s x n` call exactly
    ///   (pinned in `apps/lra.rs` tests).
    /// * A backend that implements this natively counts the whole call as
    ///   ONE dispatch in [`calls`](Self::calls). The provided
    ///   implementation falls back to one [`block`](Self::block) call per
    ///   run of consecutive rows sharing a range — correct for any
    ///   third-party backend, without the single-dispatch accounting.
    fn block_ranged(
        &self,
        kernel: Kernel,
        queries: &[f32],
        data: &[f32],
        d: usize,
        ranges: &[(usize, usize)],
    ) -> Vec<f32> {
        assert!(d > 0 && queries.len() % d == 0 && data.len() % d == 0);
        let b = queries.len() / d;
        let m = data.len() / d;
        assert_eq!(ranges.len(), b, "one range per query row");
        let mut total = 0usize;
        for &(lo, hi) in ranges {
            assert!(lo <= hi && hi <= m, "range ({lo}, {hi}) out of bounds for m={m}");
            total += hi - lo;
        }
        let mut out = Vec::with_capacity(total);
        let mut q0 = 0usize;
        while q0 < b {
            let (lo, hi) = ranges[q0];
            let mut q1 = q0 + 1;
            while q1 < b && ranges[q1] == (lo, hi) {
                q1 += 1;
            }
            if hi > lo {
                let part =
                    self.block(kernel, &queries[q0 * d..q1 * d], &data[lo * d..hi * d], d);
                out.extend_from_slice(&part);
            }
            q0 = q1;
        }
        out
    }

    /// Fallible [`sums`](Self::sums): the provided implementation runs the
    /// infallible path behind `catch_unwind` and converts a panic into
    /// [`BackendError::Panicked`]. Backends with a native error channel
    /// (PJRT) override this to surface their real engine errors instead.
    ///
    /// Failed calls leave no partial results behind — callers (the
    /// [`resilient`](crate::runtime::resilient) wrapper, the serving
    /// path) may retry or re-issue the identical call on a fallback
    /// backend. Eval/dispatch counters may still have been bumped by the
    /// failed attempt; they are monotone cost meters, not exact ledgers.
    fn try_sums(
        &self,
        kernel: Kernel,
        queries: &[f32],
        data: &[f32],
        d: usize,
    ) -> Result<Vec<f64>, BackendError> {
        catch_panic(|| self.sums(kernel, queries, data, d))
    }

    /// Fallible [`block`](Self::block); same contract as
    /// [`try_sums`](Self::try_sums).
    fn try_block(
        &self,
        kernel: Kernel,
        queries: &[f32],
        data: &[f32],
        d: usize,
    ) -> Result<Vec<f32>, BackendError> {
        catch_panic(|| self.block(kernel, queries, data, d))
    }

    /// Fallible [`sums_ranged`](Self::sums_ranged); same contract as
    /// [`try_sums`](Self::try_sums). This is the entry the fused batched
    /// pipeline uses, so a mid-pipeline engine failure surfaces as a typed
    /// error instead of unwinding through the overlap queue.
    fn try_sums_ranged(
        &self,
        kernel: Kernel,
        queries: &[f32],
        data: &[f32],
        d: usize,
        ranges: &[(usize, usize)],
    ) -> Result<Vec<f64>, BackendError> {
        catch_panic(|| self.sums_ranged(kernel, queries, data, d, ranges))
    }

    /// Fallible [`block_ranged`](Self::block_ranged); same contract as
    /// [`try_sums`](Self::try_sums).
    fn try_block_ranged(
        &self,
        kernel: Kernel,
        queries: &[f32],
        data: &[f32],
        d: usize,
        ranges: &[(usize, usize)],
    ) -> Result<Vec<f32>, BackendError> {
        catch_panic(|| self.block_ranged(kernel, queries, data, d, ranges))
    }

    /// Logical kernel evaluations performed so far (b*m per call).
    fn kernel_evals(&self) -> u64;

    /// Backend invocations (`sums` + `block` calls) so far. This is the
    /// dispatch-count metric the batched query pipeline optimizes: a
    /// per-query path issues one call per cache miss, the level-order
    /// batched path issues one call per (node, level) group. Backends that
    /// do not track it return 0.
    fn calls(&self) -> u64 {
        0
    }

    /// Human-readable engine name for reports.
    fn name(&self) -> &'static str;

    /// Instruction set the backend's inner loops run on, for bench/report
    /// metadata: `"avx2"` / `"neon"` / `"scalar"` for the explicitly
    /// dispatched tiled backend, `"autovec"` for the scalar reference
    /// (LLVM decides), `"generic"` for engines where the question does
    /// not apply.
    fn isa(&self) -> &'static str {
        "generic"
    }
}

/// Pure-Rust reference backend. The inner loops are the crate's hottest
/// code; see EXPERIMENTS.md §Perf for the optimization log.
pub struct CpuBackend {
    evals: AtomicU64,
    calls: AtomicU64,
}

impl CpuBackend {
    /// Fresh backend with zeroed counters.
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }
}

impl Default for CpuBackend {
    fn default() -> Self {
        CpuBackend { evals: AtomicU64::new(0), calls: AtomicU64::new(0) }
    }
}

impl KernelBackend for CpuBackend {
    fn sums(&self, kernel: Kernel, queries: &[f32], data: &[f32], d: usize) -> Vec<f64> {
        assert!(d > 0 && queries.len() % d == 0 && data.len() % d == 0);
        let b = queries.len() / d;
        let m = data.len() / d;
        self.evals.fetch_add((b * m) as u64, Ordering::Relaxed);
        self.calls.fetch_add(1, Ordering::Relaxed);
        let mut out = vec![0.0f64; b];
        for (qi, q) in queries.chunks_exact(d).enumerate() {
            let mut acc = 0.0f64;
            for x in data.chunks_exact(d) {
                acc += kernel.eval(q, x) as f64;
            }
            out[qi] = acc;
        }
        out
    }

    fn block(&self, kernel: Kernel, queries: &[f32], data: &[f32], d: usize) -> Vec<f32> {
        assert!(d > 0 && queries.len() % d == 0 && data.len() % d == 0);
        let b = queries.len() / d;
        let m = data.len() / d;
        self.evals.fetch_add((b * m) as u64, Ordering::Relaxed);
        self.calls.fetch_add(1, Ordering::Relaxed);
        let mut out = vec![0.0f32; b * m];
        for (qi, q) in queries.chunks_exact(d).enumerate() {
            let row = &mut out[qi * m..(qi + 1) * m];
            for (j, x) in data.chunks_exact(d).enumerate() {
                row[j] = kernel.eval(q, x);
            }
        }
        out
    }

    fn sums_ranged(
        &self,
        kernel: Kernel,
        queries: &[f32],
        data: &[f32],
        d: usize,
        ranges: &[(usize, usize)],
    ) -> Vec<f64> {
        assert!(d > 0 && queries.len() % d == 0 && data.len() % d == 0);
        let b = queries.len() / d;
        let m = data.len() / d;
        assert_eq!(ranges.len(), b, "one range per query row");
        // One dispatch for the whole fused submission.
        self.calls.fetch_add(1, Ordering::Relaxed);
        let mut pairs = 0u64;
        let mut out = vec![0.0f64; b];
        for (qi, q) in queries.chunks_exact(d).enumerate() {
            let (lo, hi) = ranges[qi];
            assert!(lo <= hi && hi <= m, "range ({lo}, {hi}) out of bounds for m={m}");
            pairs += (hi - lo) as u64;
            // Same per-row accumulation order as `sums` over the sub-slice,
            // so fused answers are bit-identical to the unfused path.
            let mut acc = 0.0f64;
            for x in data[lo * d..hi * d].chunks_exact(d) {
                acc += kernel.eval(q, x) as f64;
            }
            out[qi] = acc;
        }
        self.evals.fetch_add(pairs, Ordering::Relaxed);
        out
    }

    fn block_ranged(
        &self,
        kernel: Kernel,
        queries: &[f32],
        data: &[f32],
        d: usize,
        ranges: &[(usize, usize)],
    ) -> Vec<f32> {
        assert!(d > 0 && queries.len() % d == 0 && data.len() % d == 0);
        let b = queries.len() / d;
        let m = data.len() / d;
        assert_eq!(ranges.len(), b, "one range per query row");
        // One dispatch for the whole fused submission.
        self.calls.fetch_add(1, Ordering::Relaxed);
        let mut pairs = 0u64;
        let mut total = 0usize;
        for &(lo, hi) in ranges {
            assert!(lo <= hi && hi <= m, "range ({lo}, {hi}) out of bounds for m={m}");
            total += hi - lo;
        }
        let mut out = Vec::with_capacity(total);
        for (qi, q) in queries.chunks_exact(d).enumerate() {
            let (lo, hi) = ranges[qi];
            pairs += (hi - lo) as u64;
            for x in data[lo * d..hi * d].chunks_exact(d) {
                out.push(kernel.eval(q, x));
            }
        }
        self.evals.fetch_add(pairs, Ordering::Relaxed);
        out
    }

    fn kernel_evals(&self) -> u64 {
        self.evals.load(Ordering::Relaxed)
    }

    fn calls(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }

    fn name(&self) -> &'static str {
        "cpu"
    }

    fn isa(&self) -> &'static str {
        "autovec"
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::kernel::ALL_KERNELS;
    use crate::util::prop::forall;

    #[test]
    fn default_try_entries_catch_panics_and_match_infallible() {
        let be = CpuBackend::new();
        let q = vec![0.0f32; 2 * 3]; // b=2, d=3
        let x = vec![0.5f32; 4 * 3]; // m=4
        let ranges = [(0usize, 4usize), (1, 3)];
        let ok = be.try_sums(Kernel::Gaussian, &q, &x, 3).expect("cpu try_sums");
        assert_eq!(ok, be.sums(Kernel::Gaussian, &q, &x, 3));
        let okr = be
            .try_sums_ranged(Kernel::Gaussian, &q, &x, 3, &ranges)
            .expect("cpu try_sums_ranged");
        assert_eq!(okr, be.sums_ranged(Kernel::Gaussian, &q, &x, 3, &ranges));
        assert!(be.try_block(Kernel::Gaussian, &q, &x, 3).is_ok());
        assert!(be.try_block_ranged(Kernel::Gaussian, &q, &x, 3, &ranges).is_ok());
        // A contract violation panics on the infallible path; the try_*
        // default converts it into a typed Panicked error.
        match be.try_sums(Kernel::Gaussian, &q, &x, 5) {
            Err(BackendError::Panicked { .. }) => {}
            other => panic!("want Panicked, got {other:?}"),
        }
        match be.try_sums_ranged(Kernel::Gaussian, &q, &x, 3, &[(0, 99), (0, 1)]) {
            Err(BackendError::Panicked { .. }) => {}
            other => panic!("want Panicked, got {other:?}"),
        }
    }

    #[test]
    fn sums_match_block_row_sums() {
        forall(16, |rng, _| {
            let d = 1 + rng.below(8);
            let b = 1 + rng.below(4);
            let m = 1 + rng.below(32);
            let queries: Vec<f32> = (0..b * d).map(|_| rng.normal() as f32).collect();
            let data: Vec<f32> = (0..m * d).map(|_| rng.normal() as f32).collect();
            let be = CpuBackend::new();
            for k in ALL_KERNELS {
                let sums = be.sums(k, &queries, &data, d);
                let block = be.block(k, &queries, &data, d);
                for q in 0..b {
                    let want: f64 = block[q * m..(q + 1) * m].iter().map(|&v| v as f64).sum();
                    assert!((sums[q] - want).abs() < 1e-4 * (1.0 + want));
                }
            }
        });
    }

    #[test]
    fn eval_counter_counts_pairs() {
        let be = CpuBackend::new();
        let q = vec![0.0f32; 3 * 2]; // b=3, d=2
        let x = vec![0.0f32; 5 * 2]; // m=5
        be.sums(Kernel::Gaussian, &q, &x, 2);
        assert_eq!(be.kernel_evals(), 15);
        assert_eq!(be.calls(), 1);
        be.block(Kernel::Gaussian, &q, &x, 2);
        assert_eq!(be.kernel_evals(), 30);
        assert_eq!(be.calls(), 2);
    }

    #[test]
    fn sums_ranged_matches_per_range_sums_bitwise() {
        forall(16, |rng, _| {
            let d = 1 + rng.below(8);
            let m = 2 + rng.below(48);
            let b = 1 + rng.below(6);
            let queries: Vec<f32> = (0..b * d).map(|_| rng.normal() as f32).collect();
            let data: Vec<f32> = (0..m * d).map(|_| rng.normal() as f32).collect();
            let ranges: Vec<(usize, usize)> = (0..b)
                .map(|_| {
                    let lo = rng.below(m);
                    let hi = lo + rng.below(m - lo + 1);
                    (lo, hi)
                })
                .collect();
            let be = CpuBackend::new();
            for k in ALL_KERNELS {
                let fused = be.sums_ranged(k, &queries, &data, d, &ranges);
                for (q, &(lo, hi)) in ranges.iter().enumerate() {
                    let want = if hi > lo {
                        be.sums(k, &queries[q * d..(q + 1) * d], &data[lo * d..hi * d], d)[0]
                    } else {
                        0.0
                    };
                    assert_eq!(
                        fused[q].to_bits(),
                        want.to_bits(),
                        "{:?} row {q} range ({lo},{hi}): fused {} vs sums {want}",
                        k,
                        fused[q]
                    );
                }
            }
        });
    }

    #[test]
    fn sums_ranged_counts_one_call_and_ranged_pairs() {
        let be = CpuBackend::new();
        let q = vec![0.0f32; 3 * 2]; // b=3, d=2
        let x = vec![0.5f32; 5 * 2]; // m=5
        let ranges = [(0usize, 5usize), (1, 3), (4, 4)];
        be.sums_ranged(Kernel::Gaussian, &q, &x, 2, &ranges);
        assert_eq!(be.calls(), 1, "a fused submission is one dispatch");
        assert_eq!(be.kernel_evals(), 5 + 2, "empty range costs nothing");
    }

    #[test]
    fn block_ranged_matches_per_row_block_bitwise() {
        forall(16, |rng, _| {
            let d = 1 + rng.below(8);
            let m = 2 + rng.below(48);
            let b = 1 + rng.below(6);
            let queries: Vec<f32> = (0..b * d).map(|_| rng.normal() as f32).collect();
            let data: Vec<f32> = (0..m * d).map(|_| rng.normal() as f32).collect();
            let ranges: Vec<(usize, usize)> = (0..b)
                .map(|_| {
                    let lo = rng.below(m);
                    let hi = lo + rng.below(m - lo + 1);
                    (lo, hi)
                })
                .collect();
            let be = CpuBackend::new();
            for k in ALL_KERNELS {
                let fused = be.block_ranged(k, &queries, &data, d, &ranges);
                let total: usize = ranges.iter().map(|&(lo, hi)| hi - lo).sum();
                assert_eq!(fused.len(), total);
                let mut off = 0usize;
                for (q, &(lo, hi)) in ranges.iter().enumerate() {
                    if hi > lo {
                        let want = be.block(
                            k,
                            &queries[q * d..(q + 1) * d],
                            &data[lo * d..hi * d],
                            d,
                        );
                        for (j, w) in want.iter().enumerate() {
                            assert_eq!(
                                fused[off + j].to_bits(),
                                w.to_bits(),
                                "{:?} row {q} col {j}",
                                k
                            );
                        }
                        off += hi - lo;
                    }
                }
            }
        });
    }

    #[test]
    fn block_ranged_counts_one_call_and_ranged_pairs() {
        let be = CpuBackend::new();
        let q = vec![0.0f32; 3 * 2]; // b=3, d=2
        let x = vec![0.5f32; 5 * 2]; // m=5
        let ranges = [(0usize, 5usize), (1, 3), (4, 4)];
        let out = be.block_ranged(Kernel::Gaussian, &q, &x, 2, &ranges);
        assert_eq!(out.len(), 5 + 2);
        assert_eq!(be.calls(), 1, "a fused block submission is one dispatch");
        assert_eq!(be.kernel_evals(), 5 + 2, "empty range costs nothing");
    }

    #[test]
    fn default_sums_ranged_impl_is_correct() {
        // A minimal backend that only provides the required methods, to
        // exercise the trait's provided `sums_ranged` (the path third-party
        // backends get for free).
        struct Minimal(CpuBackend);
        impl KernelBackend for Minimal {
            fn sums(&self, k: Kernel, q: &[f32], x: &[f32], d: usize) -> Vec<f64> {
                self.0.sums(k, q, x, d)
            }
            fn block(&self, k: Kernel, q: &[f32], x: &[f32], d: usize) -> Vec<f32> {
                self.0.block(k, q, x, d)
            }
            fn kernel_evals(&self) -> u64 {
                self.0.kernel_evals()
            }
            fn name(&self) -> &'static str {
                "minimal"
            }
        }
        let mut rng = crate::util::rng::Rng::new(271);
        let d = 3;
        let (b, m) = (5usize, 20usize);
        let queries: Vec<f32> = (0..b * d).map(|_| rng.normal() as f32).collect();
        let data: Vec<f32> = (0..m * d).map(|_| rng.normal() as f32).collect();
        // Consecutive equal ranges, a distinct range, and an empty range.
        let ranges = [(0usize, 8usize), (0, 8), (3, 20), (6, 6), (2, 9)];
        let be = Minimal(CpuBackend::default());
        let native = CpuBackend::new();
        for k in ALL_KERNELS {
            let got = be.sums_ranged(k, &queries, &data, d, &ranges);
            let want = native.sums_ranged(k, &queries, &data, d, &ranges);
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.to_bits(), w.to_bits(), "{:?}", k);
            }
            // The provided block_ranged (grouped-rows fallback) must also
            // reproduce the native ragged block bit for bit.
            let got_b = be.block_ranged(k, &queries, &data, d, &ranges);
            let want_b = native.block_ranged(k, &queries, &data, d, &ranges);
            assert_eq!(got_b.len(), want_b.len());
            for (g, w) in got_b.iter().zip(&want_b) {
                assert_eq!(g.to_bits(), w.to_bits(), "{:?} block_ranged", k);
            }
        }
    }
}
