//! The kernel-evaluation backend contract shared by the pure-Rust CPU path
//! and the PJRT (AOT artifact) path.
//!
//! Every KDE estimator and every explicit row construction routes its bulk
//! kernel evaluations through a `KernelBackend`, so the same algorithm code
//! runs against either execution engine. Logical kernel-evaluation counts
//! (the paper's §7 cost metric) are tracked here.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::kernel::Kernel;

/// Batched kernel evaluation engine.
///
/// Layouts: `queries` is `b x d` row-major, `data` is `m x d` row-major.
pub trait KernelBackend: Send + Sync {
    /// `out[q] = sum_j k(queries[q], data[j])` — the KDE-sum primitive.
    fn sums(&self, kernel: Kernel, queries: &[f32], data: &[f32], d: usize) -> Vec<f64>;

    /// `out[q*m + j] = k(queries[q], data[j])` — the dense block primitive.
    fn block(&self, kernel: Kernel, queries: &[f32], data: &[f32], d: usize) -> Vec<f32>;

    /// Logical kernel evaluations performed so far (b*m per call).
    fn kernel_evals(&self) -> u64;

    /// Backend invocations (`sums` + `block` calls) so far. This is the
    /// dispatch-count metric the batched query pipeline optimizes: a
    /// per-query path issues one call per cache miss, the level-order
    /// batched path issues one call per (node, level) group. Backends that
    /// do not track it return 0.
    fn calls(&self) -> u64 {
        0
    }

    /// Human-readable engine name for reports.
    fn name(&self) -> &'static str;

    /// Instruction set the backend's inner loops run on, for bench/report
    /// metadata: `"avx2"` / `"neon"` / `"scalar"` for the explicitly
    /// dispatched tiled backend, `"autovec"` for the scalar reference
    /// (LLVM decides), `"generic"` for engines where the question does
    /// not apply.
    fn isa(&self) -> &'static str {
        "generic"
    }
}

/// Pure-Rust reference backend. The inner loops are the crate's hottest
/// code; see EXPERIMENTS.md §Perf for the optimization log.
pub struct CpuBackend {
    evals: AtomicU64,
    calls: AtomicU64,
}

impl CpuBackend {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }
}

impl Default for CpuBackend {
    fn default() -> Self {
        CpuBackend { evals: AtomicU64::new(0), calls: AtomicU64::new(0) }
    }
}

impl KernelBackend for CpuBackend {
    fn sums(&self, kernel: Kernel, queries: &[f32], data: &[f32], d: usize) -> Vec<f64> {
        assert!(d > 0 && queries.len() % d == 0 && data.len() % d == 0);
        let b = queries.len() / d;
        let m = data.len() / d;
        self.evals.fetch_add((b * m) as u64, Ordering::Relaxed);
        self.calls.fetch_add(1, Ordering::Relaxed);
        let mut out = vec![0.0f64; b];
        for (qi, q) in queries.chunks_exact(d).enumerate() {
            let mut acc = 0.0f64;
            for x in data.chunks_exact(d) {
                acc += kernel.eval(q, x) as f64;
            }
            out[qi] = acc;
        }
        out
    }

    fn block(&self, kernel: Kernel, queries: &[f32], data: &[f32], d: usize) -> Vec<f32> {
        assert!(d > 0 && queries.len() % d == 0 && data.len() % d == 0);
        let b = queries.len() / d;
        let m = data.len() / d;
        self.evals.fetch_add((b * m) as u64, Ordering::Relaxed);
        self.calls.fetch_add(1, Ordering::Relaxed);
        let mut out = vec![0.0f32; b * m];
        for (qi, q) in queries.chunks_exact(d).enumerate() {
            let row = &mut out[qi * m..(qi + 1) * m];
            for (j, x) in data.chunks_exact(d).enumerate() {
                row[j] = kernel.eval(q, x);
            }
        }
        out
    }

    fn kernel_evals(&self) -> u64 {
        self.evals.load(Ordering::Relaxed)
    }

    fn calls(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }

    fn name(&self) -> &'static str {
        "cpu"
    }

    fn isa(&self) -> &'static str {
        "autovec"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::ALL_KERNELS;
    use crate::util::prop::forall;

    #[test]
    fn sums_match_block_row_sums() {
        forall(16, |rng, _| {
            let d = 1 + rng.below(8);
            let b = 1 + rng.below(4);
            let m = 1 + rng.below(32);
            let queries: Vec<f32> = (0..b * d).map(|_| rng.normal() as f32).collect();
            let data: Vec<f32> = (0..m * d).map(|_| rng.normal() as f32).collect();
            let be = CpuBackend::new();
            for k in ALL_KERNELS {
                let sums = be.sums(k, &queries, &data, d);
                let block = be.block(k, &queries, &data, d);
                for q in 0..b {
                    let want: f64 = block[q * m..(q + 1) * m].iter().map(|&v| v as f64).sum();
                    assert!((sums[q] - want).abs() < 1e-4 * (1.0 + want));
                }
            }
        });
    }

    #[test]
    fn eval_counter_counts_pairs() {
        let be = CpuBackend::new();
        let q = vec![0.0f32; 3 * 2]; // b=3, d=2
        let x = vec![0.0f32; 5 * 2]; // m=5
        be.sums(Kernel::Gaussian, &q, &x, 2);
        assert_eq!(be.kernel_evals(), 15);
        assert_eq!(be.calls(), 1);
        be.block(Kernel::Gaussian, &q, &x, 2);
        assert_eq!(be.kernel_evals(), 30);
        assert_eq!(be.calls(), 2);
    }
}
