//! Deterministic fault injection for chaos-testing the execution layer.
//!
//! [`FaultInjectingBackend`] wraps any [`KernelBackend`] and applies a
//! seeded [`FaultPlan`] to every dispatch: fail call #k, fail every call
//! from #k on, fail every p-th call, flip a deterministic coin per call,
//! panic instead of erroring, and/or inject latency. Because the schedule
//! is a pure function of the call index (plus the plan's own seeded RNG),
//! a chaos scenario replays identically run after run — which is what
//! lets `tests/faults.rs` pin bit-identical failover output.
//!
//! Faults fire *before* the wrapped backend is touched, so a failed call
//! leaves no partial state behind and the identical call can be retried
//! or re-issued on a fallback backend ([`crate::runtime::resilient`]).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::kernel::Kernel;
use crate::runtime::backend::KernelBackend;
use crate::runtime::error::BackendError;
use crate::util::rng::Rng;

/// How an injected fault manifests at the call site.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultMode {
    /// Return a transient [`BackendError::ExecutionFailed`] (retryable).
    Transient,
    /// Return a permanent [`BackendError::ExecutionFailed`] (fail over).
    Permanent,
    /// Panic, exercising the `catch_unwind` isolation boundaries.
    Panic,
}

/// A deterministic failure schedule over the wrapped backend's dispatches.
///
/// Call indices are 0-based and count every `sums`/`block`/`*_ranged`
/// dispatch (fallible or not) in arrival order. The individual triggers
/// compose with OR: a call faults if *any* of them matches it.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    /// Fail every call with index `>= k` (models an engine dying mid-run).
    pub fail_from: Option<u64>,
    /// Fail exactly these call indices.
    pub fail_calls: Vec<u64>,
    /// Fail every p-th call (indices p-1, 2p-1, ...). `Some(0)` never fires.
    pub fail_every: Option<u64>,
    /// Per-call failure probability from the plan's seeded coin (0 = off).
    pub fail_prob: f64,
    /// How a scheduled fault manifests.
    pub mode: FaultMode,
    /// Sleep this long at the top of every call (deadline/overload tests).
    pub latency: Option<Duration>,
    /// Seed for the `fail_prob` coin.
    pub seed: u64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            fail_from: None,
            fail_calls: Vec::new(),
            fail_every: None,
            fail_prob: 0.0,
            mode: FaultMode::Transient,
            latency: None,
            seed: 0xFA17,
        }
    }
}

impl FaultPlan {
    /// Schedule: every call with index `>= k` fails.
    pub fn fail_from(k: u64) -> Self {
        FaultPlan { fail_from: Some(k), ..FaultPlan::default() }
    }

    /// Schedule: exactly call #k fails.
    pub fn fail_call(k: u64) -> Self {
        FaultPlan { fail_calls: vec![k], ..FaultPlan::default() }
    }

    /// Schedule: every p-th call fails.
    pub fn fail_every(p: u64) -> Self {
        FaultPlan { fail_every: Some(p), ..FaultPlan::default() }
    }

    /// Schedule: no failures, only per-call latency (slow-backend model).
    pub fn latency_only(latency: Duration) -> Self {
        FaultPlan { latency: Some(latency), ..FaultPlan::default() }
    }

    /// Set how scheduled faults manifest.
    pub fn with_mode(mut self, mode: FaultMode) -> Self {
        self.mode = mode;
        self
    }

    /// Add per-call latency on top of the failure schedule.
    pub fn with_latency(mut self, latency: Duration) -> Self {
        self.latency = Some(latency);
        self
    }
}

/// A [`KernelBackend`] decorator that injects the plan's faults ahead of
/// the wrapped backend; see the module docs.
pub struct FaultInjectingBackend {
    inner: Arc<dyn KernelBackend>,
    plan: FaultPlan,
    seen: AtomicU64,
    injected: AtomicU64,
    coin: Mutex<Rng>,
}

impl FaultInjectingBackend {
    /// Wrap `inner` with the given failure schedule.
    pub fn new(inner: Arc<dyn KernelBackend>, plan: FaultPlan) -> Arc<Self> {
        let coin = Mutex::new(Rng::new(plan.seed));
        Arc::new(FaultInjectingBackend {
            inner,
            plan,
            seen: AtomicU64::new(0),
            injected: AtomicU64::new(0),
            coin,
        })
    }

    /// Dispatches that reached this wrapper so far (faulted or not).
    pub fn calls_seen(&self) -> u64 {
        self.seen.load(Ordering::Relaxed)
    }

    /// Faults injected so far.
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }

    /// Apply the schedule for the next call index: sleep, then either
    /// pass (`Ok`), fail typed, or panic, per the plan's mode.
    fn gate(&self) -> Result<(), BackendError> {
        let idx = self.seen.fetch_add(1, Ordering::Relaxed);
        if let Some(latency) = self.plan.latency {
            std::thread::sleep(latency);
        }
        let mut fault = self.plan.fail_calls.contains(&idx)
            || self.plan.fail_from.is_some_and(|k| idx >= k)
            || self.plan.fail_every.is_some_and(|p| p > 0 && (idx + 1) % p == 0);
        if !fault && self.plan.fail_prob > 0.0 {
            let mut coin = self
                .coin
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner);
            fault = coin.bernoulli(self.plan.fail_prob);
        }
        if !fault {
            return Ok(());
        }
        self.injected.fetch_add(1, Ordering::Relaxed);
        match self.plan.mode {
            FaultMode::Panic => panic!("injected fault: scheduled panic at backend call {idx}"),
            FaultMode::Transient => Err(BackendError::ExecutionFailed {
                message: format!("injected transient fault at backend call {idx}"),
                transient: true,
            }),
            FaultMode::Permanent => Err(BackendError::ExecutionFailed {
                message: format!("injected permanent fault at backend call {idx}"),
                transient: false,
            }),
        }
    }
}

impl KernelBackend for FaultInjectingBackend {
    fn sums(&self, kernel: Kernel, queries: &[f32], data: &[f32], d: usize) -> Vec<f64> {
        match self.gate() {
            Ok(()) => self.inner.sums(kernel, queries, data, d),
            Err(e) => panic!("injected fault on the infallible path: {e}"),
        }
    }

    fn block(&self, kernel: Kernel, queries: &[f32], data: &[f32], d: usize) -> Vec<f32> {
        match self.gate() {
            Ok(()) => self.inner.block(kernel, queries, data, d),
            Err(e) => panic!("injected fault on the infallible path: {e}"),
        }
    }

    fn sums_ranged(
        &self,
        kernel: Kernel,
        queries: &[f32],
        data: &[f32],
        d: usize,
        ranges: &[(usize, usize)],
    ) -> Vec<f64> {
        match self.gate() {
            Ok(()) => self.inner.sums_ranged(kernel, queries, data, d, ranges),
            Err(e) => panic!("injected fault on the infallible path: {e}"),
        }
    }

    fn block_ranged(
        &self,
        kernel: Kernel,
        queries: &[f32],
        data: &[f32],
        d: usize,
        ranges: &[(usize, usize)],
    ) -> Vec<f32> {
        match self.gate() {
            Ok(()) => self.inner.block_ranged(kernel, queries, data, d, ranges),
            Err(e) => panic!("injected fault on the infallible path: {e}"),
        }
    }

    fn try_sums(
        &self,
        kernel: Kernel,
        queries: &[f32],
        data: &[f32],
        d: usize,
    ) -> Result<Vec<f64>, BackendError> {
        self.gate()?;
        self.inner.try_sums(kernel, queries, data, d)
    }

    fn try_block(
        &self,
        kernel: Kernel,
        queries: &[f32],
        data: &[f32],
        d: usize,
    ) -> Result<Vec<f32>, BackendError> {
        self.gate()?;
        self.inner.try_block(kernel, queries, data, d)
    }

    fn try_sums_ranged(
        &self,
        kernel: Kernel,
        queries: &[f32],
        data: &[f32],
        d: usize,
        ranges: &[(usize, usize)],
    ) -> Result<Vec<f64>, BackendError> {
        self.gate()?;
        self.inner.try_sums_ranged(kernel, queries, data, d, ranges)
    }

    fn try_block_ranged(
        &self,
        kernel: Kernel,
        queries: &[f32],
        data: &[f32],
        d: usize,
        ranges: &[(usize, usize)],
    ) -> Result<Vec<f32>, BackendError> {
        self.gate()?;
        self.inner.try_block_ranged(kernel, queries, data, d, ranges)
    }

    fn kernel_evals(&self) -> u64 {
        self.inner.kernel_evals()
    }

    fn calls(&self) -> u64 {
        self.inner.calls()
    }

    fn name(&self) -> &'static str {
        "fault-injecting"
    }

    fn isa(&self) -> &'static str {
        self.inner.isa()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::runtime::backend::CpuBackend;

    fn tiny() -> (Vec<f32>, Vec<f32>) {
        (vec![0.0f32; 2 * 2], vec![0.5f32; 3 * 2])
    }

    #[test]
    fn schedule_fires_deterministically() {
        let (q, x) = tiny();
        let be = FaultInjectingBackend::new(CpuBackend::new(), FaultPlan::fail_call(1));
        assert!(be.try_sums(Kernel::Gaussian, &q, &x, 2).is_ok());
        assert!(be.try_sums(Kernel::Gaussian, &q, &x, 2).is_err());
        assert!(be.try_sums(Kernel::Gaussian, &q, &x, 2).is_ok());
        assert_eq!(be.calls_seen(), 3);
        assert_eq!(be.injected(), 1);
    }

    #[test]
    fn fail_every_period() {
        let (q, x) = tiny();
        let be = FaultInjectingBackend::new(CpuBackend::new(), FaultPlan::fail_every(3));
        let outcomes: Vec<bool> = (0..6)
            .map(|_| be.try_sums(Kernel::Gaussian, &q, &x, 2).is_ok())
            .collect();
        assert_eq!(outcomes, vec![true, true, false, true, true, false]);
    }

    #[test]
    fn fail_from_fails_everything_after_k() {
        let (q, x) = tiny();
        let be = FaultInjectingBackend::new(
            CpuBackend::new(),
            FaultPlan::fail_from(2).with_mode(FaultMode::Permanent),
        );
        assert!(be.try_sums(Kernel::Gaussian, &q, &x, 2).is_ok());
        assert!(be.try_block(Kernel::Gaussian, &q, &x, 2).is_ok());
        for _ in 0..3 {
            match be.try_sums(Kernel::Gaussian, &q, &x, 2) {
                Err(e) => assert!(!e.transient(), "permanent mode: {e}"),
                Ok(_) => panic!("call past k must fail"),
            }
        }
    }

    #[test]
    fn passing_calls_are_bit_identical_to_inner() {
        let (q, x) = tiny();
        let cpu = CpuBackend::new();
        let want = cpu.sums(Kernel::Laplacian, &q, &x, 2);
        let be = FaultInjectingBackend::new(CpuBackend::new(), FaultPlan::default());
        let got = be.try_sums(Kernel::Laplacian, &q, &x, 2).unwrap();
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.to_bits(), w.to_bits());
        }
    }

    #[test]
    fn panic_mode_panics_through_infallible_path() {
        let (q, x) = tiny();
        let be = FaultInjectingBackend::new(
            CpuBackend::new(),
            FaultPlan::fail_from(0).with_mode(FaultMode::Panic),
        );
        let err = crate::runtime::error::catch_panic(|| be.sums(Kernel::Gaussian, &q, &x, 2));
        match err {
            Err(BackendError::Panicked { message }) => {
                assert!(message.contains("injected fault"), "got: {message}")
            }
            other => panic!("want Panicked, got {other:?}"),
        }
    }
}
