//! Retry + graceful degradation over any [`KernelBackend`].
//!
//! [`ResilientBackend`] wraps a primary backend (typically PJRT) and an
//! optional fallback (typically a CPU backend) behind one composed
//! policy:
//!
//! * **Transient** errors ([`BackendError::transient`]) are retried
//!   against the primary under a bounded exponential backoff whose jitter
//!   comes from the repo's deterministic [`util::rng`](crate::util::rng)
//!   (seeded per wrapper, so a chaos run replays identically).
//! * **Permanent** errors — and transient ones that exhaust the retry
//!   budget — trip a sticky failover: this call and every later one go to
//!   the fallback. A panicking primary is caught at this boundary and
//!   treated as a permanent failure.
//! * Failed calls leave no partial results (the injection/engine layers
//!   fault before producing output), so the re-issued call computes the
//!   same values the primary would have — with a [`CpuBackend`] fallback
//!   the whole pipeline's output stays **bit-identical** to an all-CPU
//!   run, pinned in `tests/faults.rs`.
//!
//! Retry and failover counts are exported through
//! [`ResilienceMetrics`](crate::coordinator::metrics::ResilienceMetrics).
//!
//! [`CpuBackend`]: crate::runtime::backend::CpuBackend

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, PoisonError};
use std::time::Duration;

use crate::coordinator::metrics::ResilienceMetrics;
use crate::kernel::Kernel;
use crate::runtime::backend::KernelBackend;
use crate::runtime::error::{catch_panic, BackendError};
use crate::util::rng::Rng;

/// Bounded-exponential-backoff retry budget for transient failures.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Retries per submission after the first attempt (0 = fail fast).
    pub max_retries: u32,
    /// Backoff before retry #1; doubles per retry.
    pub base_backoff: Duration,
    /// Backoff ceiling after doubling.
    pub max_backoff: Duration,
    /// Seed for the jitter RNG (deterministic chaos replays).
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_retries: 2,
            base_backoff: Duration::from_millis(1),
            max_backoff: Duration::from_millis(100),
            seed: 0xBAC0FF,
        }
    }
}

impl RetryPolicy {
    /// A policy with no waiting between attempts — for tests that want
    /// retry *logic* without wall-clock cost.
    pub fn immediate(max_retries: u32) -> Self {
        RetryPolicy {
            max_retries,
            base_backoff: Duration::ZERO,
            max_backoff: Duration::ZERO,
            ..RetryPolicy::default()
        }
    }
}

/// A [`KernelBackend`] that retries transient failures and degrades to a
/// fallback backend on permanent ones; see the module docs.
pub struct ResilientBackend {
    primary: Arc<dyn KernelBackend>,
    fallback: Option<Arc<dyn KernelBackend>>,
    policy: RetryPolicy,
    jitter: Mutex<Rng>,
    failed_over: AtomicBool,
    metrics: Arc<ResilienceMetrics>,
}

impl ResilientBackend {
    /// Wrap `primary` with the given policy and optional fallback.
    pub fn new(
        primary: Arc<dyn KernelBackend>,
        fallback: Option<Arc<dyn KernelBackend>>,
        policy: RetryPolicy,
    ) -> Arc<Self> {
        let jitter = Mutex::new(Rng::new(policy.seed));
        Arc::new(ResilientBackend {
            primary,
            fallback,
            policy,
            jitter,
            failed_over: AtomicBool::new(false),
            metrics: ResilienceMetrics::new(),
        })
    }

    /// Wrap with the default policy and a fallback backend.
    pub fn with_fallback(
        primary: Arc<dyn KernelBackend>,
        fallback: Arc<dyn KernelBackend>,
    ) -> Arc<Self> {
        Self::new(primary, Some(fallback), RetryPolicy::default())
    }

    /// Shared retry/failover counters.
    pub fn metrics(&self) -> Arc<ResilienceMetrics> {
        self.metrics.clone()
    }

    /// Whether the wrapper has (stickily) degraded to the fallback.
    pub fn failed_over(&self) -> bool {
        self.failed_over.load(Ordering::Acquire)
    }

    /// Sleep the bounded-exponential backoff before retry `attempt`
    /// (1-based), jittered into `[0.5, 1.0]x` by the seeded RNG.
    fn backoff(&self, attempt: u32) {
        let doublings = (attempt - 1).min(16);
        let exp = self.policy.base_backoff.saturating_mul(1u32 << doublings);
        let capped = exp.min(self.policy.max_backoff);
        if capped.is_zero() {
            return;
        }
        let jitter = {
            let mut rng = self
                .jitter
                .lock()
                .unwrap_or_else(PoisonError::into_inner);
            0.5 + 0.5 * rng.f64()
        };
        std::thread::sleep(capped.mul_f64(jitter));
    }

    /// Run `op` under the composed retry + failover policy. Each attempt
    /// is wrapped in [`catch_panic`], so a panicking backend is handled
    /// like a permanent error instead of unwinding into the caller.
    fn run<T>(
        &self,
        op: impl Fn(&dyn KernelBackend) -> Result<T, BackendError>,
    ) -> Result<T, BackendError> {
        if !self.failed_over.load(Ordering::Acquire) {
            let mut attempt = 0u32;
            let last_err = loop {
                match catch_panic(|| op(self.primary.as_ref())).and_then(|r| r) {
                    Ok(v) => return Ok(v),
                    Err(e) => {
                        self.metrics.primary_errors.fetch_add(1, Ordering::Relaxed);
                        if e.transient() && attempt < self.policy.max_retries {
                            attempt += 1;
                            self.metrics.retries.fetch_add(1, Ordering::Relaxed);
                            self.backoff(attempt);
                            continue;
                        }
                        break e;
                    }
                }
            };
            if self.fallback.is_none() {
                return Err(last_err);
            }
            // Sticky degradation: this call and all later ones go to the
            // fallback. (Concurrent callers may each observe the trip;
            // `failovers` counts trips observed, 1 in sequential use.)
            if !self.failed_over.swap(true, Ordering::AcqRel) {
                self.metrics.failovers.fetch_add(1, Ordering::Relaxed);
            }
        }
        match &self.fallback {
            Some(fb) => {
                self.metrics.fallback_calls.fetch_add(1, Ordering::Relaxed);
                catch_panic(|| op(fb.as_ref())).and_then(|r| r)
            }
            None => Err(BackendError::permanent_failure(
                "resilient backend failed over with no fallback configured",
            )),
        }
    }
}

impl KernelBackend for ResilientBackend {
    fn sums(&self, kernel: Kernel, queries: &[f32], data: &[f32], d: usize) -> Vec<f64> {
        match self.try_sums(kernel, queries, data, d) {
            Ok(v) => v,
            Err(e) => panic!("resilient backend: primary and fallback both failed: {e}"),
        }
    }

    fn block(&self, kernel: Kernel, queries: &[f32], data: &[f32], d: usize) -> Vec<f32> {
        match self.try_block(kernel, queries, data, d) {
            Ok(v) => v,
            Err(e) => panic!("resilient backend: primary and fallback both failed: {e}"),
        }
    }

    fn sums_ranged(
        &self,
        kernel: Kernel,
        queries: &[f32],
        data: &[f32],
        d: usize,
        ranges: &[(usize, usize)],
    ) -> Vec<f64> {
        match self.try_sums_ranged(kernel, queries, data, d, ranges) {
            Ok(v) => v,
            Err(e) => panic!("resilient backend: primary and fallback both failed: {e}"),
        }
    }

    fn block_ranged(
        &self,
        kernel: Kernel,
        queries: &[f32],
        data: &[f32],
        d: usize,
        ranges: &[(usize, usize)],
    ) -> Vec<f32> {
        match self.try_block_ranged(kernel, queries, data, d, ranges) {
            Ok(v) => v,
            Err(e) => panic!("resilient backend: primary and fallback both failed: {e}"),
        }
    }

    fn try_sums(
        &self,
        kernel: Kernel,
        queries: &[f32],
        data: &[f32],
        d: usize,
    ) -> Result<Vec<f64>, BackendError> {
        self.run(|b| b.try_sums(kernel, queries, data, d))
    }

    fn try_block(
        &self,
        kernel: Kernel,
        queries: &[f32],
        data: &[f32],
        d: usize,
    ) -> Result<Vec<f32>, BackendError> {
        self.run(|b| b.try_block(kernel, queries, data, d))
    }

    fn try_sums_ranged(
        &self,
        kernel: Kernel,
        queries: &[f32],
        data: &[f32],
        d: usize,
        ranges: &[(usize, usize)],
    ) -> Result<Vec<f64>, BackendError> {
        self.run(|b| b.try_sums_ranged(kernel, queries, data, d, ranges))
    }

    fn try_block_ranged(
        &self,
        kernel: Kernel,
        queries: &[f32],
        data: &[f32],
        d: usize,
        ranges: &[(usize, usize)],
    ) -> Result<Vec<f32>, BackendError> {
        self.run(|b| b.try_block_ranged(kernel, queries, data, d, ranges))
    }

    fn kernel_evals(&self) -> u64 {
        self.primary.kernel_evals()
            + self.fallback.as_ref().map_or(0, |f| f.kernel_evals())
    }

    fn calls(&self) -> u64 {
        self.primary.calls() + self.fallback.as_ref().map_or(0, |f| f.calls())
    }

    fn name(&self) -> &'static str {
        "resilient"
    }

    fn isa(&self) -> &'static str {
        if self.failed_over() {
            self.fallback.as_ref().map_or("generic", |f| f.isa())
        } else {
            self.primary.isa()
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::runtime::backend::CpuBackend;
    use crate::runtime::fault::{FaultInjectingBackend, FaultMode, FaultPlan};

    fn tiny() -> (Vec<f32>, Vec<f32>) {
        (vec![0.0f32; 2 * 2], vec![0.5f32; 3 * 2])
    }

    #[test]
    fn transient_error_is_retried_without_failover() {
        let (q, x) = tiny();
        let primary = FaultInjectingBackend::new(CpuBackend::new(), FaultPlan::fail_call(0));
        let be = ResilientBackend::new(primary, Some(CpuBackend::new()), RetryPolicy::immediate(2));
        let want = CpuBackend::new().sums(Kernel::Gaussian, &q, &x, 2);
        let got = be.try_sums(Kernel::Gaussian, &q, &x, 2).unwrap();
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.to_bits(), w.to_bits());
        }
        assert!(!be.failed_over());
        let m = be.metrics();
        assert_eq!(m.retries.load(Ordering::Relaxed), 1);
        assert_eq!(m.failovers.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn permanent_error_fails_over_stickily() {
        let (q, x) = tiny();
        let primary = FaultInjectingBackend::new(
            CpuBackend::new(),
            FaultPlan::fail_from(0).with_mode(FaultMode::Permanent),
        );
        let be = ResilientBackend::new(
            primary.clone(),
            Some(CpuBackend::new()),
            RetryPolicy::immediate(3),
        );
        assert!(be.try_sums(Kernel::Gaussian, &q, &x, 2).is_ok());
        assert!(be.failed_over());
        let seen_after_failover = primary.calls_seen();
        assert!(be.try_sums(Kernel::Gaussian, &q, &x, 2).is_ok());
        assert_eq!(
            primary.calls_seen(),
            seen_after_failover,
            "failover is sticky: the primary is never consulted again"
        );
        let m = be.metrics();
        assert_eq!(m.failovers.load(Ordering::Relaxed), 1);
        assert_eq!(m.retries.load(Ordering::Relaxed), 0, "permanent errors skip retry");
        assert_eq!(m.fallback_calls.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn retry_budget_exhaustion_degrades() {
        let (q, x) = tiny();
        let primary = FaultInjectingBackend::new(CpuBackend::new(), FaultPlan::fail_from(0));
        let be = ResilientBackend::new(primary, Some(CpuBackend::new()), RetryPolicy::immediate(2));
        assert!(be.try_sums(Kernel::Gaussian, &q, &x, 2).is_ok());
        assert!(be.failed_over());
        assert_eq!(be.metrics().retries.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn panicking_primary_is_contained() {
        let (q, x) = tiny();
        let primary = FaultInjectingBackend::new(
            CpuBackend::new(),
            FaultPlan::fail_from(0).with_mode(FaultMode::Panic),
        );
        let be = ResilientBackend::new(primary, Some(CpuBackend::new()), RetryPolicy::immediate(2));
        let got = be.try_sums(Kernel::Gaussian, &q, &x, 2);
        assert!(got.is_ok(), "panic must be absorbed by failover: {got:?}");
        assert!(be.failed_over());
    }

    #[test]
    fn no_fallback_surfaces_the_error() {
        let (q, x) = tiny();
        let primary = FaultInjectingBackend::new(
            CpuBackend::new(),
            FaultPlan::fail_from(0).with_mode(FaultMode::Permanent),
        );
        let be = ResilientBackend::new(primary, None, RetryPolicy::immediate(1));
        match be.try_sums(Kernel::Gaussian, &q, &x, 2) {
            Err(BackendError::ExecutionFailed { transient: false, .. }) => {}
            other => panic!("want permanent ExecutionFailed, got {other:?}"),
        }
        assert!(!be.failed_over(), "nothing to fail over to");
    }
}
