//! Typed failure taxonomy for the execution layer.
//!
//! Every fallible entry point in the runtime and the serving coordinator
//! (`KernelBackend::try_*`, `KdeService::try_query`, the overlapped
//! submission queue) reports one of the [`BackendError`] variants below
//! instead of panicking. Each variant carries a **transient/permanent**
//! tag ([`BackendError::transient`]): transient failures are worth a
//! bounded retry (`runtime::resilient`), permanent ones trigger immediate
//! degradation to a fallback backend or a typed client reply.
//!
//! The infallible APIs (`sums`, `query`, ...) remain available as thin
//! wrappers that panic with the typed error's message — existing callers
//! keep their contract, new callers get a real failure channel.

use std::fmt;

/// Convenience alias for results of fallible execution-layer calls.
pub type BackendResult<T> = Result<T, BackendError>;

/// A typed failure from the execution layer (backend or serving path).
///
/// See the module docs for the transient/permanent retry semantics and
/// `docs/ARCHITECTURE.md` ("Failure model") for the end-to-end contract.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum BackendError {
    /// The execution engine reported a failure (PJRT compile/execute
    /// error, injected fault, ...). `transient` marks whether a retry of
    /// the same call can plausibly succeed.
    ExecutionFailed {
        /// Human-readable failure description (engine error chain).
        message: String,
        /// Whether a bounded retry is worthwhile.
        transient: bool,
    },
    /// Required AOT artifacts are missing or unreadable (permanent: no
    /// retry can make `manifest.json` appear mid-run).
    ArtifactMissing {
        /// What was missing, including the path looked at.
        detail: String,
    },
    /// A per-request deadline expired before the request was served. The
    /// request was dropped from the batch plan, never executed.
    Timeout,
    /// The service's bounded request queue is full; the request was
    /// rejected instead of buffered without bound (backpressure).
    Overloaded,
    /// A worker or backend panicked; the panic was caught at an isolation
    /// boundary and converted into this error instead of taking the
    /// process (or a waiting client) down.
    Panicked {
        /// The panic payload's message, when it carried one.
        message: String,
    },
    /// A request was routed to a shard index the service does not have.
    UnknownShard {
        /// The shard index the caller asked for.
        shard: usize,
        /// How many shards the service actually serves.
        shards: usize,
    },
    /// A request named a dataset the serving registry has not registered
    /// (permanent: retrying the identical request fails identically until
    /// someone registers the dataset).
    UnknownDataset {
        /// The dataset name the caller asked for.
        name: String,
    },
    /// A `try_register` named a dataset that is already registered
    /// (permanent). Re-registering a name would either be silently
    /// dropped (the idempotent `register` path) or — worse — leave
    /// clients coalescing against a stale tree; callers that mean to
    /// replace a dataset must say so through the registry's version-
    /// bumping `update`.
    AlreadyRegistered {
        /// The dataset name that was already taken.
        name: String,
    },
}

impl BackendError {
    /// Whether a bounded retry of the same call is worthwhile.
    ///
    /// * `ExecutionFailed` — per its tag (engine hiccups are transient,
    ///   structural failures are not).
    /// * `Timeout` / `Overloaded` — transient: load subsides.
    /// * `ArtifactMissing` / `Panicked` / `UnknownShard` /
    ///   `UnknownDataset` / `AlreadyRegistered` — permanent: retrying the
    ///   identical call deterministically fails again.
    pub fn transient(&self) -> bool {
        match self {
            BackendError::ExecutionFailed { transient, .. } => *transient,
            BackendError::Timeout | BackendError::Overloaded => true,
            BackendError::ArtifactMissing { .. }
            | BackendError::Panicked { .. }
            | BackendError::UnknownShard { .. }
            | BackendError::UnknownDataset { .. }
            | BackendError::AlreadyRegistered { .. } => false,
        }
    }

    /// Shorthand for a transient [`ExecutionFailed`](Self::ExecutionFailed).
    pub fn transient_failure(message: impl Into<String>) -> Self {
        BackendError::ExecutionFailed { message: message.into(), transient: true }
    }

    /// Shorthand for a permanent [`ExecutionFailed`](Self::ExecutionFailed).
    pub fn permanent_failure(message: impl Into<String>) -> Self {
        BackendError::ExecutionFailed { message: message.into(), transient: false }
    }
}

impl fmt::Display for BackendError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            BackendError::ExecutionFailed { message, transient } => {
                let kind = if *transient { "transient" } else { "permanent" };
                write!(f, "execution failed ({kind}): {message}")
            }
            BackendError::ArtifactMissing { detail } => {
                write!(f, "artifacts missing: {detail}")
            }
            BackendError::Timeout => {
                write!(f, "deadline expired before the request was served")
            }
            BackendError::Overloaded => {
                write!(f, "service overloaded: bounded request queue is full")
            }
            BackendError::Panicked { message } => {
                write!(f, "worker panicked: {message}")
            }
            BackendError::UnknownShard { shard, shards } => {
                write!(f, "unknown shard {shard} (service has {shards})")
            }
            BackendError::UnknownDataset { name } => {
                write!(f, "unknown dataset {name:?} (not registered)")
            }
            BackendError::AlreadyRegistered { name } => {
                write!(
                    f,
                    "dataset {name:?} already registered (use update to version-bump)"
                )
            }
        }
    }
}

impl std::error::Error for BackendError {}

/// Best-effort extraction of a panic payload's message.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

/// Run `f`, converting a panic into [`BackendError::Panicked`].
///
/// This is the isolation boundary the fallible default `try_*` backend
/// entry points, the batcher's workers and the overlap queue's packer
/// thread all share: a panicking computation becomes a typed error reply
/// instead of a dead thread (and, for clients waiting on a channel, a
/// hang). The closure is asserted unwind-safe — callers must tolerate
/// partially-updated internal state behind a caught panic, which every
/// call site here does (counters may over-count, memo caches keep only
/// fully-committed entries).
pub fn catch_panic<T>(f: impl FnOnce() -> T) -> BackendResult<T> {
    std::panic::catch_unwind(std::panic::AssertUnwindSafe(f))
        .map_err(|p| BackendError::Panicked { message: panic_message(p.as_ref()) })
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn transient_tags() {
        assert!(BackendError::transient_failure("x").transient());
        assert!(!BackendError::permanent_failure("x").transient());
        assert!(BackendError::Timeout.transient());
        assert!(BackendError::Overloaded.transient());
        assert!(!BackendError::ArtifactMissing { detail: "m".into() }.transient());
        assert!(!BackendError::Panicked { message: "p".into() }.transient());
        assert!(!BackendError::UnknownShard { shard: 3, shards: 1 }.transient());
        assert!(!BackendError::UnknownDataset { name: "web".into() }.transient());
        assert!(!BackendError::AlreadyRegistered { name: "web".into() }.transient());
    }

    #[test]
    fn catch_panic_converts_payloads() {
        let ok = catch_panic(|| 41 + 1);
        assert_eq!(ok, Ok(42));
        let err = catch_panic(|| -> u32 { panic!("boom {}", 7) });
        match err {
            Err(BackendError::Panicked { message }) => {
                assert!(message.contains("boom 7"), "got: {message}")
            }
            other => panic!("want Panicked, got {other:?}"),
        }
    }

    #[test]
    fn display_is_informative() {
        let e = BackendError::UnknownShard { shard: 5, shards: 2 };
        let s = format!("{e}");
        assert!(s.contains("unknown shard 5"), "got: {s}");
        assert!(format!("{}", BackendError::Overloaded).contains("overloaded"));
        assert!(format!("{}", BackendError::transient_failure("x")).contains("transient"));
        let d = format!("{}", BackendError::UnknownDataset { name: "web".into() });
        assert!(d.contains("unknown dataset") && d.contains("web"), "got: {d}");
        let a = format!("{}", BackendError::AlreadyRegistered { name: "web".into() });
        assert!(a.contains("already registered") && a.contains("web"), "got: {a}");
    }
}
