//! Persistent sharded worker pool for tile execution.
//!
//! `TiledBackend` historically paid a `std::thread::scope` spawn + join on
//! every dispatch. The batched tree pipeline makes O(log n) *small* fused
//! dispatches per descent round (ARCHITECTURE.md §Level fusion), so per-
//! dispatch thread startup is pure overhead at exactly the call shape the
//! paper's sub-quadratic bounds produce. This module keeps the workers
//! alive instead, modeled on the tuwunel database pool (SNIPPETS.md
//! Snippet 3): long-lived OS threads, one bounded queue shard per worker,
//! FIFO submit / LIFO steal, and occupancy counters surfaced through
//! [`PoolMetrics`] in `coordinator::metrics`.
//!
//! Scheduling model:
//!
//! - **Submit** round-robins tasks across shard queues and rings a
//!   generation-counter doorbell. Each worker drains its own shard FIFO
//!   (oldest first — fair across submitters) and, when its shard is
//!   empty, steals from sibling shards LIFO (newest first — the stolen
//!   task's inputs are most likely still cache-hot on the thief).
//! - **Bounded queues**: a shard at its bound runs the task inline on the
//!   submitting thread instead of queueing unboundedly — overload degrades
//!   to the caller lending itself as a worker, never to a deadlock or an
//!   unbounded queue. A submit from *inside* a pool worker also runs
//!   inline (nested-submit deadlock guard).
//! - **Scoped batches**: [`WorkerPool::run_scoped`] submits a batch of
//!   borrowing closures and blocks on a completion latch until every task
//!   has run, which is what makes the lifetime erasure below sound. A
//!   panicking task is contained on the worker (the thread survives for
//!   the next dispatch) and its payload is re-raised on the caller, so the
//!   existing `try_*` isolation boundary still maps it to
//!   [`BackendError::Panicked`](crate::runtime::error::BackendError).
//! - **Shutdown**: `Drop` flags shutdown, rings all workers, and joins
//!   them; workers drain every queued task before exiting so no submitted
//!   work is silently discarded.
//!
//! Determinism: the pool only changes *where* tasks run, never how output
//! rows are partitioned — callers hand it the same worker-disjoint chunk
//! closures the scoped path spawns, so results are `to_bits`-identical to
//! `std::thread::scope` execution (pinned in `tests/pool.rs`).
//!
//! Core pinning: opt-in (`PoolConfig::pin` or env `KDE_POOL_PIN=1`),
//! best-effort, and currently implemented only on x86_64 Linux via a raw
//! `sched_setaffinity` syscall (no libc dependency is available offline);
//! elsewhere it is a no-op. Errors are ignored — pinning is a locality
//! hint, never a correctness requirement.

use std::collections::VecDeque;

use crate::coordinator::metrics::PoolMetrics;
use crate::runtime::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use crate::runtime::sync::thread::JoinHandle;
use crate::runtime::sync::{self, Arc, Condvar, Mutex, PoisonError};

/// A unit of pool work. `'static` at the queue boundary; `run_scoped`
/// erases shorter borrows because it blocks until the batch completes.
type Task = Box<dyn FnOnce() + Send + 'static>;

thread_local! {
    /// True on pool worker threads; a submit from a worker runs inline so
    /// a task that blocks on a nested `run_scoped` latch can never wedge
    /// the pool against itself.
    static IS_POOL_WORKER: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// Construction knobs for [`WorkerPool`].
#[derive(Clone, Debug)]
pub struct PoolConfig {
    /// Worker thread count (>= 1).
    pub workers: usize,
    /// Per-shard queue bound; a full shard runs the submit inline.
    pub queue_limit: usize,
    /// Best-effort core-affinity pinning (worker i -> core i).
    pub pin: bool,
}

impl PoolConfig {
    /// Defaults: `workers` threads, 256-deep shards, pinning off unless
    /// env `KDE_POOL_PIN=1`.
    pub fn with_workers(workers: usize) -> Self {
        PoolConfig {
            workers: workers.max(1),
            queue_limit: 256,
            pin: std::env::var("KDE_POOL_PIN").map(|v| v == "1").unwrap_or(false),
        }
    }
}

/// Generation-counter doorbell: `ring` bumps the generation and wakes
/// sleepers; `wait` sleeps only while the generation still equals the one
/// the worker observed *before* scanning the queues, so a submit that
/// lands between scan and sleep is never lost.
struct Doorbell {
    gen: Mutex<u64>,
    cv: Condvar,
}

impl Doorbell {
    fn current(&self) -> u64 {
        *self.gen.lock().unwrap_or_else(PoisonError::into_inner)
    }

    fn ring(&self) {
        let mut g = self.gen.lock().unwrap_or_else(PoisonError::into_inner);
        *g = g.wrapping_add(1);
        drop(g);
        self.cv.notify_all();
    }

    fn wait(&self, seen: u64) {
        let mut g = self.gen.lock().unwrap_or_else(PoisonError::into_inner);
        // 50ms timeout backstop: shutdown and steals stay live even if a
        // wakeup is missed on an exotic platform. Under loom the backstop
        // is compiled out (a lost ring must deadlock the model, not be
        // papered over) — see `runtime::sync::wait_with_backstop`.
        while *g == seen {
            let (guard, timed_out) =
                sync::wait_with_backstop(&self.cv, g, std::time::Duration::from_millis(50));
            g = guard;
            if timed_out {
                break;
            }
        }
    }
}

/// One bounded FIFO/LIFO deque per worker.
struct Shard {
    queue: Mutex<VecDeque<Task>>,
}

struct PoolShared {
    shards: Vec<Shard>,
    doorbell: Doorbell,
    shutdown: AtomicBool,
    metrics: Arc<PoolMetrics>,
    /// Test-only kill switch: the next worker to observe it exits its
    /// loop (simulating an abrupt worker death) so the doorbell/steal
    /// liveness tests can pin that survivors keep serving every shard.
    #[cfg(test)]
    die_signal: AtomicBool,
}

impl PoolShared {
    /// Own shard FIFO first, then steal LIFO from siblings.
    fn next_task(&self, wid: usize) -> Option<Task> {
        if let Some(t) = self.shards[wid]
            .queue
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .pop_front()
        {
            self.metrics.queued.fetch_sub(1, Ordering::Relaxed);
            return Some(t);
        }
        let n = self.shards.len();
        for k in 1..n {
            let victim = (wid + k) % n;
            if let Some(t) = self.shards[victim]
                .queue
                .lock()
                .unwrap_or_else(PoisonError::into_inner)
                .pop_back()
            {
                self.metrics.queued.fetch_sub(1, Ordering::Relaxed);
                self.metrics.steals.fetch_add(1, Ordering::Relaxed);
                return Some(t);
            }
        }
        None
    }

    fn run_task(&self, task: Task) {
        PoolMetrics::gauge_inc(&self.metrics.busy, &self.metrics.busy_max);
        // Contain the panic so the worker thread survives; `run_scoped`
        // wrappers have already captured the payload for the caller.
        if std::panic::catch_unwind(std::panic::AssertUnwindSafe(task)).is_err() {
            self.metrics.task_panics.fetch_add(1, Ordering::Relaxed);
        }
        self.metrics.busy.fetch_sub(1, Ordering::Relaxed);
        self.metrics.completed.fetch_add(1, Ordering::Relaxed);
    }

    fn worker_loop(&self, wid: usize) {
        IS_POOL_WORKER.with(|f| f.set(true));
        loop {
            // Test-only worker death: exactly one worker consumes the
            // signal and returns without draining, as if it had died.
            #[cfg(test)]
            if self.die_signal.swap(false, Ordering::AcqRel) {
                return;
            }
            // Observe the doorbell generation BEFORE scanning, so a ring
            // during the scan makes the later wait return immediately.
            let gen = self.doorbell.current();
            if let Some(task) = self.next_task(wid) {
                self.run_task(task);
                continue;
            }
            if self.shutdown.load(Ordering::Acquire) {
                // Queues were empty after the shutdown flag: drained.
                return;
            }
            self.doorbell.wait(gen);
        }
    }
}

/// Persistent sharded worker pool; see the module docs.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    workers: Mutex<Vec<JoinHandle<()>>>,
    cursor: AtomicUsize,
    queue_limit: usize,
}

impl WorkerPool {
    /// Spawn `cfg.workers` long-lived workers.
    pub fn new(cfg: PoolConfig) -> Self {
        let workers = cfg.workers.max(1);
        let shared = Arc::new(PoolShared {
            shards: (0..workers)
                .map(|_| Shard {
                    queue: Mutex::new(VecDeque::new()),
                })
                .collect(),
            doorbell: Doorbell {
                gen: Mutex::new(0),
                cv: Condvar::new(),
            },
            shutdown: AtomicBool::new(false),
            metrics: PoolMetrics::new(),
            #[cfg(test)]
            die_signal: AtomicBool::new(false),
        });
        let mut handles = Vec::with_capacity(workers);
        for wid in 0..workers {
            let sh = Arc::clone(&shared);
            let pin = cfg.pin;
            let handle = sync::thread::spawn_named(&format!("kde-pool-{wid}"), move || {
                if pin {
                    pin_to_core(wid);
                }
                sh.worker_loop(wid);
            });
            match handle {
                Ok(h) => handles.push(h),
                // Spawn failure (resource exhaustion): keep going with the
                // workers we have; submit's inline fallback covers zero.
                Err(_) => break,
            }
        }
        WorkerPool {
            shared,
            workers: Mutex::new(handles),
            cursor: AtomicUsize::new(0),
            queue_limit: cfg.queue_limit.max(1),
        }
    }

    /// Live worker-thread count.
    pub fn workers(&self) -> usize {
        self.workers
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .len()
    }

    /// Occupancy/scheduling counters (shared, live).
    pub fn metrics(&self) -> &Arc<PoolMetrics> {
        &self.shared.metrics
    }

    fn enqueue(&self, task: Task) -> Result<(), Task> {
        let n = self.shared.shards.len();
        if n == 0 || self.workers() == 0 || IS_POOL_WORKER.with(|f| f.get()) {
            return Err(task);
        }
        let shard = &self.shared.shards[self.cursor.fetch_add(1, Ordering::Relaxed) % n];
        {
            let mut q = shard.queue.lock().unwrap_or_else(PoisonError::into_inner);
            if q.len() >= self.queue_limit {
                return Err(task);
            }
            q.push_back(task);
        }
        let m = &self.shared.metrics;
        PoolMetrics::gauge_inc(&m.queued, &m.queued_max);
        self.shared.doorbell.ring();
        Ok(())
    }

    /// Run a batch of borrowing closures to completion on the pool.
    ///
    /// Blocks until every task has finished (or been discarded by an
    /// unwinding worker — contained panics still count the latch down via
    /// the wrapper), then re-raises the first captured panic payload on
    /// the caller. Blocking-until-done is the soundness argument for the
    /// lifetime erasure: no erased borrow outlives this call.
    pub fn run_scoped<'a>(&self, tasks: Vec<Box<dyn FnOnce() + Send + 'a>>) {
        let n = tasks.len();
        if n == 0 {
            return;
        }
        let latch = Arc::new(ScopeLatch::new(n));
        let first_panic: Arc<Mutex<Option<Box<dyn std::any::Any + Send>>>> =
            Arc::new(Mutex::new(None));
        for task in tasks {
            let guard = CountGuard(Arc::clone(&latch));
            let panic_c = Arc::clone(&first_panic);
            let metrics = Arc::clone(&self.shared.metrics);
            let wrapped: Box<dyn FnOnce() + Send + 'a> = Box::new(move || {
                // The guard lives in the closure ENVIRONMENT: it counts the
                // latch down when the body finishes, when the body unwinds,
                // and even if the task were dropped unexecuted — the caller
                // latch can never hang.
                let _guard = guard;
                if let Err(p) = std::panic::catch_unwind(std::panic::AssertUnwindSafe(task)) {
                    // Count the containment here: the panic never reaches
                    // `run_task`'s catch (this wrapper swallows it), so
                    // this is the only place scoped panics are visible.
                    metrics.task_panics.fetch_add(1, Ordering::Relaxed);
                    panic_c
                        .lock()
                        .unwrap_or_else(PoisonError::into_inner)
                        .get_or_insert(p);
                }
            });
            // SAFETY: the erased closure only borrows data that outlives
            // this `run_scoped` call, and `latch.wait()` below does not
            // return until every wrapped closure has either run or been
            // dropped — `CountGuard` fires on all paths — so no erased
            // borrow is ever dereferenced after this frame returns.
            let wrapped: Task = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + 'a>, Box<dyn FnOnce() + Send>>(
                    wrapped,
                )
            };
            self.submit(wrapped);
        }
        latch.wait();
        let payload = first_panic
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .take();
        if let Some(p) = payload {
            std::panic::resume_unwind(p);
        }
    }

    /// Submit one `'static` task. Runs inline when the chosen shard is at
    /// its bound, when no worker threads exist, or when the caller *is* a
    /// pool worker (nested-submit deadlock guard).
    pub fn submit(&self, task: Task) {
        let m = &self.shared.metrics;
        m.submitted.fetch_add(1, Ordering::Relaxed);
        if let Err(task) = self.enqueue(task) {
            // Queue bound hit, pool-worker caller, or no shards: lend the
            // submitting thread as the worker.
            m.inline_runs.fetch_add(1, Ordering::Relaxed);
            PoolMetrics::gauge_inc(&m.busy, &m.busy_max);
            let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(task));
            m.busy.fetch_sub(1, Ordering::Relaxed);
            m.completed.fetch_add(1, Ordering::Relaxed);
            if let Err(p) = res {
                // Inline tasks run on the caller already; re-raise so raw
                // submitters see the panic (run_scoped wrappers never
                // reach this arm — they catch internally).
                std::panic::resume_unwind(p);
            }
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.doorbell.ring();
        let handles = std::mem::take(
            &mut *self.workers.lock().unwrap_or_else(PoisonError::into_inner),
        );
        for h in handles {
            // A worker that somehow died unwinding has nothing to drain;
            // ignore its panic payload here (it was already contained or
            // re-raised at the scoped boundary).
            let _ = h.join();
        }
    }
}

/// Completion latch: `wait` blocks until `count_down` has run `n` times.
struct ScopeLatch {
    remaining: Mutex<usize>,
    cv: Condvar,
}

impl ScopeLatch {
    fn new(n: usize) -> Self {
        ScopeLatch {
            remaining: Mutex::new(n),
            cv: Condvar::new(),
        }
    }

    fn count_down(&self) {
        let mut r = self.remaining.lock().unwrap_or_else(PoisonError::into_inner);
        *r = r.saturating_sub(1);
        if *r == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) {
        let mut r = self.remaining.lock().unwrap_or_else(PoisonError::into_inner);
        while *r > 0 {
            r = match self.cv.wait(r) {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
        }
    }
}

/// Counts the latch down when dropped — on normal return AND on unwind.
struct CountGuard(Arc<ScopeLatch>);

impl Drop for CountGuard {
    fn drop(&mut self) {
        self.0.count_down();
    }
}

/// Best-effort affinity pin of the current thread to `core`. Compiled
/// out under Miri (the interpreter cannot execute raw syscalls).
#[cfg(all(target_os = "linux", target_arch = "x86_64", not(miri)))]
fn pin_to_core(core: usize) {
    let mut mask = [0u64; 16]; // 1024-bit cpu_set_t
    let idx = core % (mask.len() * 64);
    mask[idx / 64] |= 1u64 << (idx % 64);
    // SAFETY: raw sched_setaffinity(0, sizeof(mask), &mask) — syscall 203
    // on x86_64 Linux (no libc crate is available offline). The kernel
    // only READS `mask`, which outlives the syscall (stack local, pointer
    // taken in the same frame); pid 0 = the calling thread, so no foreign
    // memory is touched; rcx/r11 are declared clobbered per the syscall
    // ABI and the asm is nostack. A failure returns a negative errno in
    // rax, which is deliberately ignored — pinning is a locality hint,
    // never a correctness requirement.
    unsafe {
        let mut ret: i64;
        std::arch::asm!(
            "syscall",
            inlateout("rax") 203i64 => ret,
            in("rdi") 0usize,
            in("rsi") std::mem::size_of_val(&mask),
            in("rdx") mask.as_ptr(),
            out("rcx") _,
            out("r11") _,
            options(nostack),
        );
        let _ = ret;
    }
}

/// No-op on platforms without the raw-syscall implementation (and under
/// Miri).
#[cfg(not(all(target_os = "linux", target_arch = "x86_64", not(miri))))]
fn pin_to_core(_core: usize) {}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn scoped_batch_runs_all_tasks_and_reuses_threads() {
        let pool = WorkerPool::new(PoolConfig::with_workers(4));
        let hits = AtomicU64::new(0);
        for _ in 0..50 {
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..8)
                .map(|_| {
                    let h = &hits;
                    Box::new(move || {
                        h.fetch_add(1, Ordering::Relaxed);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run_scoped(tasks);
        }
        assert_eq!(hits.load(Ordering::Relaxed), 400);
        let m = pool.metrics();
        assert_eq!(m.submitted.load(Ordering::Relaxed), 400);
        assert_eq!(m.completed.load(Ordering::Relaxed), 400);
        assert_eq!(m.busy(), 0, "gauge returns to zero");
        assert_eq!(m.queued_depth(), 0, "queues drained");
    }

    #[test]
    fn scoped_panic_reraises_on_caller_and_pool_survives() {
        let pool = WorkerPool::new(PoolConfig::with_workers(2));
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
            Box::new(|| {}),
            Box::new(|| panic!("tile worker exploded")),
            Box::new(|| {}),
        ];
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run_scoped(tasks);
        }));
        let payload = err.expect_err("panic must re-raise on the caller");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
        assert!(msg.contains("exploded"), "original payload kept: {msg}");
        // The pool must still be serviceable afterwards.
        let ok = AtomicU64::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
            .map(|_| {
                let o = &ok;
                Box::new(move || {
                    o.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(tasks);
        assert_eq!(ok.load(Ordering::Relaxed), 4);
        assert_eq!(pool.metrics().task_panics.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn drop_drains_submitted_tasks() {
        let done = Arc::new(AtomicU64::new(0));
        {
            let pool = WorkerPool::new(PoolConfig::with_workers(2));
            for _ in 0..64 {
                let d = Arc::clone(&done);
                pool.submit(Box::new(move || {
                    d.fetch_add(1, Ordering::Relaxed);
                }));
            }
            // Drop joins here.
        }
        assert_eq!(done.load(Ordering::Relaxed), 64, "drop drains the shards");
    }

    #[test]
    fn scope_latch_releases_when_every_task_panics() {
        let pool = WorkerPool::new(PoolConfig::with_workers(2));
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
            .map(|_| Box::new(|| panic!("all of them")) as Box<dyn FnOnce() + Send + '_>)
            .collect();
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run_scoped(tasks);
        }));
        assert!(err.is_err(), "first payload re-raises on the caller");
        // run_scoped returning at all proves no CountGuard was lost (the
        // latch released with every task unwinding); the pool must also
        // still serve a fresh batch afterwards.
        let hits = AtomicU64::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
            .map(|_| {
                let h = &hits;
                Box::new(move || {
                    h.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(tasks);
        assert_eq!(hits.load(Ordering::Relaxed), 4);
        assert_eq!(pool.metrics().task_panics.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn doorbell_wakes_survivor_after_worker_death() {
        let pool = WorkerPool::new(PoolConfig {
            workers: 2,
            queue_limit: 256,
            pin: false,
        });
        // Kill exactly one worker: raise the signal, then ring until a
        // worker wakes and consumes it.
        pool.shared.die_signal.store(true, Ordering::Release);
        while pool.shared.die_signal.load(Ordering::Acquire) {
            pool.shared.doorbell.ring();
            std::thread::yield_now();
        }
        // Submit round-robins across BOTH shards, so the dead worker's
        // shard fills too: the survivor must wake on the doorbell and
        // steal every orphaned task for the batch to complete at all.
        let hits = AtomicU64::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..16)
            .map(|_| {
                let h = &hits;
                Box::new(move || {
                    h.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(tasks);
        assert_eq!(hits.load(Ordering::Relaxed), 16);
        assert!(
            pool.metrics().steals.load(Ordering::Relaxed) > 0,
            "survivor stole from the dead worker's shard"
        );
    }

    #[test]
    fn overflow_runs_inline_without_deadlock() {
        // queue_limit 1 with 1 worker: most submits overflow inline on
        // this thread while the worker drains the rest.
        let pool = WorkerPool::new(PoolConfig {
            workers: 1,
            queue_limit: 1,
            pin: false,
        });
        let hits = AtomicU64::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..32)
            .map(|_| {
                let h = &hits;
                Box::new(move || {
                    h.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(tasks);
        assert_eq!(hits.load(Ordering::Relaxed), 32);
        assert!(pool.metrics().inline_runs.load(Ordering::Relaxed) > 0);
    }
}

// Model-check suite, run only by the loom CI leg:
// `RUSTFLAGS="--cfg loom" LOOM_MAX_PREEMPTIONS=3 cargo test --release --lib loom_`.
// Each model is a tiny closed protocol instance; loom explores every
// interleaving up to the preemption bound, so a lost doorbell ring or a
// leaked latch count shows up as a model DEADLOCK, deterministically —
// not as a one-in-a-million flake. Models stay within loom's default
// MAX_THREADS (main + at most 2 spawned workers).
#[cfg(all(loom, test))]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod loom_tests {
    use super::*;

    /// The generation-counter protocol itself: a producer sets a flag and
    /// rings; the consumer observes the generation BEFORE re-checking the
    /// flag. If a ring landing between the check and the sleep could be
    /// lost, the consumer would sleep forever (under loom the wait has no
    /// timeout backstop) and loom would report a deadlock.
    #[test]
    fn loom_doorbell_never_loses_a_ring() {
        loom::model(|| {
            let db = Arc::new(Doorbell {
                gen: Mutex::new(0),
                cv: Condvar::new(),
            });
            let flag = Arc::new(AtomicBool::new(false));
            let (db2, flag2) = (Arc::clone(&db), Arc::clone(&flag));
            let t = sync::thread::spawn(move || {
                flag2.store(true, Ordering::Release);
                db2.ring();
            });
            loop {
                let gen = db.current();
                if flag.load(Ordering::Acquire) {
                    break;
                }
                db.wait(gen);
            }
            t.join().unwrap();
        });
    }

    /// Submit/steal/drain/Drop: every queued task must run exactly once
    /// across every interleaving of two workers draining, stealing, and
    /// shutting down mid-stream.
    #[test]
    fn loom_pool_runs_all_submitted_tasks_across_drop() {
        loom::model(|| {
            let hits = Arc::new(AtomicUsize::new(0));
            {
                let pool = WorkerPool::new(PoolConfig {
                    workers: 2,
                    queue_limit: 4,
                    pin: false,
                });
                for _ in 0..3 {
                    let h = Arc::clone(&hits);
                    pool.submit(Box::new(move || {
                        h.fetch_add(1, Ordering::Relaxed);
                    }));
                }
                // Drop flags shutdown, rings, and joins: the drain
                // guarantee is what the assert below pins.
            }
            assert_eq!(hits.load(Ordering::Relaxed), 3);
        });
    }

    /// The scoped-batch handoff end to end: borrowed data, latch wait,
    /// lifetime-erased closures. Loom verifies the caller can never
    /// return from `run_scoped` before both borrowing tasks finished.
    #[test]
    fn loom_run_scoped_completes_borrowing_tasks() {
        loom::model(|| {
            let pool = WorkerPool::new(PoolConfig {
                workers: 1,
                queue_limit: 4,
                pin: false,
            });
            let mut a = 0u64;
            let mut b = 0u64;
            {
                let tasks: Vec<Box<dyn FnOnce() + Send + '_>> =
                    vec![Box::new(|| a += 1), Box::new(|| b += 2)];
                pool.run_scoped(tasks);
            }
            assert_eq!((a, b), (1, 2));
        });
    }

    /// The latch counts down on guard DROP, not on task run: a guard
    /// dropped unexecuted on another thread must still release the
    /// waiter in every interleaving (else: model deadlock).
    #[test]
    fn loom_scope_latch_counts_down_on_drop_without_run() {
        loom::model(|| {
            let latch = Arc::new(ScopeLatch::new(2));
            let g1 = CountGuard(Arc::clone(&latch));
            let l2 = Arc::clone(&latch);
            let t = sync::thread::spawn(move || {
                // Dropped without any task body ever running.
                drop(CountGuard(l2));
            });
            drop(g1);
            latch.wait();
            t.join().unwrap();
        });
    }
}
