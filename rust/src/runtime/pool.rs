//! Persistent sharded worker pool for tile execution.
//!
//! `TiledBackend` historically paid a `std::thread::scope` spawn + join on
//! every dispatch. The batched tree pipeline makes O(log n) *small* fused
//! dispatches per descent round (ARCHITECTURE.md §Level fusion), so per-
//! dispatch thread startup is pure overhead at exactly the call shape the
//! paper's sub-quadratic bounds produce. This module keeps the workers
//! alive instead, modeled on the tuwunel database pool (SNIPPETS.md
//! Snippet 3): long-lived OS threads, one bounded queue shard per worker,
//! FIFO submit / LIFO steal, and occupancy counters surfaced through
//! [`PoolMetrics`] in `coordinator::metrics`.
//!
//! Scheduling model:
//!
//! - **Submit** round-robins tasks across shard queues and rings a
//!   generation-counter doorbell. Each worker drains its own shard FIFO
//!   (oldest first — fair across submitters) and, when its shard is
//!   empty, steals from sibling shards LIFO (newest first — the stolen
//!   task's inputs are most likely still cache-hot on the thief).
//! - **Bounded queues**: a shard at its bound runs the task inline on the
//!   submitting thread instead of queueing unboundedly — overload degrades
//!   to the caller lending itself as a worker, never to a deadlock or an
//!   unbounded queue. A submit from *inside* a pool worker also runs
//!   inline (nested-submit deadlock guard).
//! - **Scoped batches**: [`WorkerPool::run_scoped`] submits a batch of
//!   borrowing closures and blocks on a completion latch until every task
//!   has run, which is what makes the lifetime erasure below sound. A
//!   panicking task is contained on the worker (the thread survives for
//!   the next dispatch) and its payload is re-raised on the caller, so the
//!   existing `try_*` isolation boundary still maps it to
//!   [`BackendError::Panicked`](crate::runtime::error::BackendError).
//! - **Shutdown**: `Drop` flags shutdown, rings all workers, and joins
//!   them; workers drain every queued task before exiting so no submitted
//!   work is silently discarded.
//!
//! Determinism: the pool only changes *where* tasks run, never how output
//! rows are partitioned — callers hand it the same worker-disjoint chunk
//! closures the scoped path spawns, so results are `to_bits`-identical to
//! `std::thread::scope` execution (pinned in `tests/pool.rs`).
//!
//! Core pinning: opt-in (`PoolConfig::pin` or env `KDE_POOL_PIN=1`),
//! best-effort, and currently implemented only on x86_64 Linux via a raw
//! `sched_setaffinity` syscall (no libc dependency is available offline);
//! elsewhere it is a no-op. Errors are ignored — pinning is a locality
//! hint, never a correctness requirement.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::thread::JoinHandle;

use crate::coordinator::metrics::PoolMetrics;

/// A unit of pool work. `'static` at the queue boundary; `run_scoped`
/// erases shorter borrows because it blocks until the batch completes.
type Task = Box<dyn FnOnce() + Send + 'static>;

thread_local! {
    /// True on pool worker threads; a submit from a worker runs inline so
    /// a task that blocks on a nested `run_scoped` latch can never wedge
    /// the pool against itself.
    static IS_POOL_WORKER: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// Construction knobs for [`WorkerPool`].
#[derive(Clone, Debug)]
pub struct PoolConfig {
    /// Worker thread count (>= 1).
    pub workers: usize,
    /// Per-shard queue bound; a full shard runs the submit inline.
    pub queue_limit: usize,
    /// Best-effort core-affinity pinning (worker i -> core i).
    pub pin: bool,
}

impl PoolConfig {
    /// Defaults: `workers` threads, 256-deep shards, pinning off unless
    /// env `KDE_POOL_PIN=1`.
    pub fn with_workers(workers: usize) -> Self {
        PoolConfig {
            workers: workers.max(1),
            queue_limit: 256,
            pin: std::env::var("KDE_POOL_PIN").map(|v| v == "1").unwrap_or(false),
        }
    }
}

/// Generation-counter doorbell: `ring` bumps the generation and wakes
/// sleepers; `wait` sleeps only while the generation still equals the one
/// the worker observed *before* scanning the queues, so a submit that
/// lands between scan and sleep is never lost.
struct Doorbell {
    gen: Mutex<u64>,
    cv: Condvar,
}

impl Doorbell {
    fn current(&self) -> u64 {
        *self.gen.lock().unwrap_or_else(PoisonError::into_inner)
    }

    fn ring(&self) {
        let mut g = self.gen.lock().unwrap_or_else(PoisonError::into_inner);
        *g = g.wrapping_add(1);
        drop(g);
        self.cv.notify_all();
    }

    fn wait(&self, seen: u64) {
        let mut g = self.gen.lock().unwrap_or_else(PoisonError::into_inner);
        // 50ms timeout backstop: shutdown and steals stay live even if a
        // wakeup is missed on an exotic platform.
        while *g == seen {
            let (guard, res) = match self.cv.wait_timeout(g, std::time::Duration::from_millis(50)) {
                Ok(pair) => pair,
                Err(poisoned) => poisoned.into_inner(),
            };
            g = guard;
            if res.timed_out() {
                break;
            }
        }
    }
}

/// One bounded FIFO/LIFO deque per worker.
struct Shard {
    queue: Mutex<VecDeque<Task>>,
}

struct PoolShared {
    shards: Vec<Shard>,
    doorbell: Doorbell,
    shutdown: AtomicBool,
    metrics: Arc<PoolMetrics>,
}

impl PoolShared {
    /// Own shard FIFO first, then steal LIFO from siblings.
    fn next_task(&self, wid: usize) -> Option<Task> {
        if let Some(t) = self.shards[wid]
            .queue
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .pop_front()
        {
            self.metrics.queued.fetch_sub(1, Ordering::Relaxed);
            return Some(t);
        }
        let n = self.shards.len();
        for k in 1..n {
            let victim = (wid + k) % n;
            if let Some(t) = self.shards[victim]
                .queue
                .lock()
                .unwrap_or_else(PoisonError::into_inner)
                .pop_back()
            {
                self.metrics.queued.fetch_sub(1, Ordering::Relaxed);
                self.metrics.steals.fetch_add(1, Ordering::Relaxed);
                return Some(t);
            }
        }
        None
    }

    fn run_task(&self, task: Task) {
        PoolMetrics::gauge_inc(&self.metrics.busy, &self.metrics.busy_max);
        // Contain the panic so the worker thread survives; `run_scoped`
        // wrappers have already captured the payload for the caller.
        if std::panic::catch_unwind(std::panic::AssertUnwindSafe(task)).is_err() {
            self.metrics.task_panics.fetch_add(1, Ordering::Relaxed);
        }
        self.metrics.busy.fetch_sub(1, Ordering::Relaxed);
        self.metrics.completed.fetch_add(1, Ordering::Relaxed);
    }

    fn worker_loop(&self, wid: usize) {
        IS_POOL_WORKER.with(|f| f.set(true));
        loop {
            // Observe the doorbell generation BEFORE scanning, so a ring
            // during the scan makes the later wait return immediately.
            let gen = self.doorbell.current();
            if let Some(task) = self.next_task(wid) {
                self.run_task(task);
                continue;
            }
            if self.shutdown.load(Ordering::Acquire) {
                // Queues were empty after the shutdown flag: drained.
                return;
            }
            self.doorbell.wait(gen);
        }
    }
}

/// Persistent sharded worker pool; see the module docs.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    workers: Mutex<Vec<JoinHandle<()>>>,
    cursor: AtomicUsize,
    queue_limit: usize,
}

impl WorkerPool {
    /// Spawn `cfg.workers` long-lived workers.
    pub fn new(cfg: PoolConfig) -> Self {
        let workers = cfg.workers.max(1);
        let shared = Arc::new(PoolShared {
            shards: (0..workers)
                .map(|_| Shard {
                    queue: Mutex::new(VecDeque::new()),
                })
                .collect(),
            doorbell: Doorbell {
                gen: Mutex::new(0),
                cv: Condvar::new(),
            },
            shutdown: AtomicBool::new(false),
            metrics: PoolMetrics::new(),
        });
        let mut handles = Vec::with_capacity(workers);
        for wid in 0..workers {
            let sh = Arc::clone(&shared);
            let pin = cfg.pin;
            let handle = std::thread::Builder::new()
                .name(format!("kde-pool-{wid}"))
                .spawn(move || {
                    if pin {
                        pin_to_core(wid);
                    }
                    sh.worker_loop(wid);
                });
            match handle {
                Ok(h) => handles.push(h),
                // Spawn failure (resource exhaustion): keep going with the
                // workers we have; submit's inline fallback covers zero.
                Err(_) => break,
            }
        }
        WorkerPool {
            shared,
            workers: Mutex::new(handles),
            cursor: AtomicUsize::new(0),
            queue_limit: cfg.queue_limit.max(1),
        }
    }

    /// Live worker-thread count.
    pub fn workers(&self) -> usize {
        self.workers
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .len()
    }

    /// Occupancy/scheduling counters (shared, live).
    pub fn metrics(&self) -> &Arc<PoolMetrics> {
        &self.shared.metrics
    }

    fn enqueue(&self, task: Task) -> Result<(), Task> {
        let n = self.shared.shards.len();
        if n == 0 || self.workers() == 0 || IS_POOL_WORKER.with(|f| f.get()) {
            return Err(task);
        }
        let shard = &self.shared.shards[self.cursor.fetch_add(1, Ordering::Relaxed) % n];
        {
            let mut q = shard.queue.lock().unwrap_or_else(PoisonError::into_inner);
            if q.len() >= self.queue_limit {
                return Err(task);
            }
            q.push_back(task);
        }
        let m = &self.shared.metrics;
        PoolMetrics::gauge_inc(&m.queued, &m.queued_max);
        self.shared.doorbell.ring();
        Ok(())
    }

    /// Run a batch of borrowing closures to completion on the pool.
    ///
    /// Blocks until every task has finished (or been discarded by an
    /// unwinding worker — contained panics still count the latch down via
    /// the wrapper), then re-raises the first captured panic payload on
    /// the caller. Blocking-until-done is the soundness argument for the
    /// lifetime erasure: no erased borrow outlives this call.
    pub fn run_scoped<'a>(&self, tasks: Vec<Box<dyn FnOnce() + Send + 'a>>) {
        let n = tasks.len();
        if n == 0 {
            return;
        }
        let latch = Arc::new(Latch::new(n));
        let first_panic: Arc<Mutex<Option<Box<dyn std::any::Any + Send>>>> =
            Arc::new(Mutex::new(None));
        for task in tasks {
            let guard = CountGuard(Arc::clone(&latch));
            let panic_c = Arc::clone(&first_panic);
            let wrapped: Box<dyn FnOnce() + Send + 'a> = Box::new(move || {
                // The guard lives in the closure ENVIRONMENT: it counts the
                // latch down when the body finishes, when the body unwinds,
                // and even if the task were dropped unexecuted — the caller
                // latch can never hang.
                let _guard = guard;
                if let Err(p) = std::panic::catch_unwind(std::panic::AssertUnwindSafe(task)) {
                    panic_c
                        .lock()
                        .unwrap_or_else(PoisonError::into_inner)
                        .get_or_insert(p);
                }
            });
            // SAFETY: the erased closure only borrows data that outlives
            // this `run_scoped` call, and `latch.wait()` below does not
            // return until every wrapped closure has either run or been
            // dropped — `CountGuard` fires on all paths — so no erased
            // borrow is ever dereferenced after this frame returns.
            let wrapped: Task = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + 'a>, Box<dyn FnOnce() + Send>>(
                    wrapped,
                )
            };
            self.submit(wrapped);
        }
        latch.wait();
        let payload = first_panic
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .take();
        if let Some(p) = payload {
            std::panic::resume_unwind(p);
        }
    }

    /// Submit one `'static` task. Runs inline when the chosen shard is at
    /// its bound, when no worker threads exist, or when the caller *is* a
    /// pool worker (nested-submit deadlock guard).
    pub fn submit(&self, task: Task) {
        let m = &self.shared.metrics;
        m.submitted.fetch_add(1, Ordering::Relaxed);
        if let Err(task) = self.enqueue(task) {
            // Queue bound hit, pool-worker caller, or no shards: lend the
            // submitting thread as the worker.
            m.inline_runs.fetch_add(1, Ordering::Relaxed);
            PoolMetrics::gauge_inc(&m.busy, &m.busy_max);
            let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(task));
            m.busy.fetch_sub(1, Ordering::Relaxed);
            m.completed.fetch_add(1, Ordering::Relaxed);
            if let Err(p) = res {
                // Inline tasks run on the caller already; re-raise so raw
                // submitters see the panic (run_scoped wrappers never
                // reach this arm — they catch internally).
                std::panic::resume_unwind(p);
            }
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.doorbell.ring();
        let handles = std::mem::take(
            &mut *self.workers.lock().unwrap_or_else(PoisonError::into_inner),
        );
        for h in handles {
            // A worker that somehow died unwinding has nothing to drain;
            // ignore its panic payload here (it was already contained or
            // re-raised at the scoped boundary).
            let _ = h.join();
        }
    }
}

/// Completion latch: `wait` blocks until `count_down` has run `n` times.
struct Latch {
    remaining: Mutex<usize>,
    cv: Condvar,
}

impl Latch {
    fn new(n: usize) -> Self {
        Latch {
            remaining: Mutex::new(n),
            cv: Condvar::new(),
        }
    }

    fn count_down(&self) {
        let mut r = self.remaining.lock().unwrap_or_else(PoisonError::into_inner);
        *r = r.saturating_sub(1);
        if *r == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) {
        let mut r = self.remaining.lock().unwrap_or_else(PoisonError::into_inner);
        while *r > 0 {
            r = match self.cv.wait(r) {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
        }
    }
}

/// Counts the latch down when dropped — on normal return AND on unwind.
struct CountGuard(Arc<Latch>);

impl Drop for CountGuard {
    fn drop(&mut self) {
        self.0.count_down();
    }
}

/// Best-effort affinity pin of the current thread to `core`.
#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
fn pin_to_core(core: usize) {
    // Raw sched_setaffinity(0, sizeof(mask), &mask): syscall 203 on
    // x86_64 Linux. No libc crate is available offline; the result is
    // deliberately ignored (locality hint only).
    let mut mask = [0u64; 16]; // 1024-bit cpu_set_t
    let idx = core % (mask.len() * 64);
    mask[idx / 64] |= 1u64 << (idx % 64);
    unsafe {
        let mut ret: i64;
        std::arch::asm!(
            "syscall",
            inlateout("rax") 203i64 => ret,
            in("rdi") 0usize,
            in("rsi") std::mem::size_of_val(&mask),
            in("rdx") mask.as_ptr(),
            out("rcx") _,
            out("r11") _,
            options(nostack),
        );
        let _ = ret;
    }
}

/// No-op on platforms without the raw-syscall implementation.
#[cfg(not(all(target_os = "linux", target_arch = "x86_64")))]
fn pin_to_core(_core: usize) {}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn scoped_batch_runs_all_tasks_and_reuses_threads() {
        let pool = WorkerPool::new(PoolConfig::with_workers(4));
        let hits = AtomicU64::new(0);
        for _ in 0..50 {
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..8)
                .map(|_| {
                    let h = &hits;
                    Box::new(move || {
                        h.fetch_add(1, Ordering::Relaxed);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run_scoped(tasks);
        }
        assert_eq!(hits.load(Ordering::Relaxed), 400);
        let m = pool.metrics();
        assert_eq!(m.submitted.load(Ordering::Relaxed), 400);
        assert_eq!(m.completed.load(Ordering::Relaxed), 400);
        assert_eq!(m.busy(), 0, "gauge returns to zero");
        assert_eq!(m.queued_depth(), 0, "queues drained");
    }

    #[test]
    fn scoped_panic_reraises_on_caller_and_pool_survives() {
        let pool = WorkerPool::new(PoolConfig::with_workers(2));
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
            Box::new(|| {}),
            Box::new(|| panic!("tile worker exploded")),
            Box::new(|| {}),
        ];
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run_scoped(tasks);
        }));
        let payload = err.expect_err("panic must re-raise on the caller");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
        assert!(msg.contains("exploded"), "original payload kept: {msg}");
        // The pool must still be serviceable afterwards.
        let ok = AtomicU64::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
            .map(|_| {
                let o = &ok;
                Box::new(move || {
                    o.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(tasks);
        assert_eq!(ok.load(Ordering::Relaxed), 4);
        assert_eq!(pool.metrics().task_panics.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn drop_drains_submitted_tasks() {
        let done = Arc::new(AtomicU64::new(0));
        {
            let pool = WorkerPool::new(PoolConfig::with_workers(2));
            for _ in 0..64 {
                let d = Arc::clone(&done);
                pool.submit(Box::new(move || {
                    d.fetch_add(1, Ordering::Relaxed);
                }));
            }
            // Drop joins here.
        }
        assert_eq!(done.load(Ordering::Relaxed), 64, "drop drains the shards");
    }

    #[test]
    fn overflow_runs_inline_without_deadlock() {
        // queue_limit 1 with 1 worker: most submits overflow inline on
        // this thread while the worker drains the rest.
        let pool = WorkerPool::new(PoolConfig {
            workers: 1,
            queue_limit: 1,
            pin: false,
        });
        let hits = AtomicU64::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..32)
            .map(|_| {
                let h = &hits;
                Box::new(move || {
                    h.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(tasks);
        assert_eq!(hits.load(Ordering::Relaxed), 32);
        assert!(pool.metrics().inline_runs.load(Ordering::Relaxed) > 0);
    }
}
