//! Loom-swappable synchronization facade: the single place the
//! concurrency core imports its primitives from.
//!
//! Under a normal build every item here is a zero-cost re-export of the
//! `std::sync` / `std::thread` original. Under `--cfg loom` (the
//! model-checking CI leg) the blocking primitives resolve to their
//! [`loom`](https://docs.rs/loom) twins instead, so the *same* `Doorbell`
//! / `ScopeLatch` / `OverlapSession` / store code that serves production
//! traffic is the code the model checker permutes — no shadow
//! reimplementation that could drift from the real protocol.
//!
//! The modules rebased onto this facade — `runtime/pool.rs`,
//! `coordinator/batcher.rs`, `server/store.rs`, `server/mod.rs` — must
//! not import `std::sync::Mutex` / `std::sync::Condvar` directly;
//! `scripts/check_invariants.py` enforces that as a repo invariant. The
//! `loom_*` unit suites in those modules wrap their scenarios in
//! `loom::model`, and CI runs them with `RUSTFLAGS="--cfg loom"` and
//! bounded preemptions (docs/ARCHITECTURE.md §Verification matrix).
//!
//! Deliberate deviations, all documented here because they bound what the
//! model checker can see:
//!
//! * [`Arc`] stays `std::sync::Arc` on both paths. Reference counting is
//!   not part of any modeled protocol (no code branches on strong
//!   counts), loom threads are real OS threads, and keeping one `Arc`
//!   type lets untracked shared state (metrics counters) flow through
//!   unchanged.
//! * [`wait_with_backstop`] maps to `Condvar::wait_timeout` normally but
//!   to a plain modeled `wait` under loom: wall-clock timeouts are
//!   meaningless inside a model, and modeling the backstop as a spurious
//!   wakeup would mask the lost-wakeup bugs the doorbell suite exists to
//!   catch — under loom, a missed ring is a *deadlock the checker
//!   reports*, not a 50ms hiccup.
//! * [`mpsc`] re-exports `std::sync::mpsc` normally; under loom it is a
//!   small bounded channel built on the facade's own `Mutex`/`Condvar`
//!   (std's channel blocks outside the model's knowledge, which would
//!   wedge the explorer). `recv_timeout` degrades to a plain `recv`
//!   there — the modeled suites never rely on timeouts firing.

#[cfg(not(loom))]
pub use std::sync::{Condvar, Mutex, MutexGuard};

#[cfg(loom)]
pub use loom::sync::{Condvar, Mutex, MutexGuard};

// Poison plumbing is shared: loom's lock signatures use std's
// `LockResult`/`PoisonError`, so the repo-wide
// `.lock().unwrap_or_else(PoisonError::into_inner)` recovery idiom
// compiles identically on both paths.
pub use std::sync::{LockResult, PoisonError, TryLockError};

// See the module docs: `Arc` is std on both paths, by design.
pub use std::sync::Arc;

/// Atomics: std normally, loom-instrumented under the model checker.
pub mod atomic {
    #[cfg(not(loom))]
    pub use std::sync::atomic::{fence, AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};

    #[cfg(loom)]
    pub use loom::sync::atomic::{fence, AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
}

/// Thread spawning: std normally, loom's cooperative threads under the
/// model checker.
pub mod thread {
    #[cfg(not(loom))]
    pub use std::thread::{spawn, yield_now, JoinHandle};

    #[cfg(loom)]
    pub use loom::thread::{spawn, yield_now, JoinHandle};

    /// Spawn a named thread. Thread names are a debugging affordance
    /// (panic messages, `/proc`, TSan reports); loom has no `Builder`, so
    /// under the model the name is dropped and the spawn is infallible —
    /// callers keep one code path and their spawn-failure fallbacks are
    /// still exercised by the std build.
    pub fn spawn_named<F, T>(name: &str, f: F) -> std::io::Result<JoinHandle<T>>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        #[cfg(not(loom))]
        {
            std::thread::Builder::new().name(name.to_string()).spawn(f)
        }
        #[cfg(loom)]
        {
            let _ = name;
            Ok(spawn(f))
        }
    }
}

/// Condvar wait with a wall-clock backstop: `(guard, timed_out)`.
///
/// Normal build: `Condvar::wait_timeout`, poison-recovered — the caller's
/// loop re-checks its predicate either way, so the backstop only bounds
/// how long a (theoretically impossible) missed wakeup could stall
/// shutdown or a steal. Under loom: a plain modeled `wait` that never
/// reports a timeout — if the protocol truly can miss a wakeup, the model
/// deadlocks and the checker fails the suite with the schedule that did
/// it, which is the whole point of the leg.
pub fn wait_with_backstop<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    backstop: std::time::Duration,
) -> (MutexGuard<'a, T>, bool) {
    #[cfg(not(loom))]
    {
        let (g, res) = match cv.wait_timeout(guard, backstop) {
            Ok(pair) => pair,
            Err(poisoned) => poisoned.into_inner(),
        };
        (g, res.timed_out())
    }
    #[cfg(loom)]
    {
        let _ = backstop;
        let g = match cv.wait(guard) {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        (g, false)
    }
}

#[cfg(not(loom))]
pub use std::sync::mpsc;

/// Bounded mpsc channel for the loom build, implemented on the facade's
/// own (loom-instrumented) `Mutex` + `Condvar` so the model checker can
/// permute every send/recv interleaving. API-compatible with the
/// `std::sync::mpsc` subset the rebased modules use; error types are the
/// std originals so match arms compile unchanged. `recv_timeout` never
/// times out under the model (see the module docs).
#[cfg(loom)]
pub mod mpsc {
    pub use std::sync::mpsc::{
        RecvError, RecvTimeoutError, SendError, TryRecvError, TrySendError,
    };

    use std::collections::VecDeque;
    use std::time::Duration;

    use super::{Arc, Condvar, Mutex, PoisonError};

    struct State<T> {
        buf: VecDeque<T>,
        /// `None` = "unbounded" (`channel()`); `Some(cap)` = rendezvous
        /// buffer of `sync_channel(cap)`.
        cap: Option<usize>,
        senders: usize,
        rx_alive: bool,
    }

    struct Chan<T> {
        state: Mutex<State<T>>,
        cv: Condvar,
    }

    impl<T> Chan<T> {
        fn locked(&self) -> super::MutexGuard<'_, State<T>> {
            self.state.lock().unwrap_or_else(PoisonError::into_inner)
        }
    }

    /// Sending half; `Clone` to add producers.
    pub struct SyncSender<T> {
        chan: Arc<Chan<T>>,
    }

    /// `channel()`'s sender is the same type under the model; the only
    /// behavioral difference from std (an unbounded `send` can block on
    /// the loom buffer bound) is invisible to code that is correct.
    pub type Sender<T> = SyncSender<T>;

    /// Receiving half (single consumer).
    pub struct Receiver<T> {
        chan: Arc<Chan<T>>,
    }

    /// Unbounded-in-std channel: stays unbounded here too (`cap: None`) —
    /// loom models push a handful of items, so the buffer is finite in
    /// practice and sends never block.
    pub fn channel<T>() -> (Sender<T>, Receiver<T>) {
        make(None)
    }

    /// Bounded channel; `sync_channel(0)` is modeled as capacity 1 (a
    /// true rendezvous adds nothing to the protocols under test, which
    /// all use cap >= 1).
    pub fn sync_channel<T>(cap: usize) -> (SyncSender<T>, Receiver<T>) {
        make(Some(cap.max(1)))
    }

    fn make<T>(cap: Option<usize>) -> (SyncSender<T>, Receiver<T>) {
        let chan = Arc::new(Chan {
            state: Mutex::new(State {
                buf: VecDeque::new(),
                cap,
                senders: 1,
                rx_alive: true,
            }),
            cv: Condvar::new(),
        });
        (SyncSender { chan: Arc::clone(&chan) }, Receiver { chan })
    }

    impl<T> Clone for SyncSender<T> {
        fn clone(&self) -> Self {
            self.chan.locked().senders += 1;
            SyncSender { chan: Arc::clone(&self.chan) }
        }
    }

    impl<T> Drop for SyncSender<T> {
        fn drop(&mut self) {
            let mut st = self.chan.locked();
            st.senders -= 1;
            if st.senders == 0 {
                drop(st);
                self.chan.cv.notify_all();
            }
        }
    }

    impl<T> Drop for Receiver<T> {
        fn drop(&mut self) {
            self.chan.locked().rx_alive = false;
            self.chan.cv.notify_all();
        }
    }

    impl<T> SyncSender<T> {
        /// Blocking send; errors once the receiver is gone.
        pub fn send(&self, t: T) -> Result<(), SendError<T>> {
            let mut st = self.chan.locked();
            loop {
                if !st.rx_alive {
                    return Err(SendError(t));
                }
                let full = st.cap.is_some_and(|c| st.buf.len() >= c);
                if !full {
                    st.buf.push_back(t);
                    drop(st);
                    self.chan.cv.notify_all();
                    return Ok(());
                }
                let (g, _) = super::wait_with_backstop(
                    &self.chan.cv,
                    st,
                    Duration::from_millis(50),
                );
                st = g;
            }
        }

        /// Non-blocking send.
        pub fn try_send(&self, t: T) -> Result<(), TrySendError<T>> {
            let mut st = self.chan.locked();
            if !st.rx_alive {
                return Err(TrySendError::Disconnected(t));
            }
            if st.cap.is_some_and(|c| st.buf.len() >= c) {
                return Err(TrySendError::Full(t));
            }
            st.buf.push_back(t);
            drop(st);
            self.chan.cv.notify_all();
            Ok(())
        }
    }

    impl<T> Receiver<T> {
        /// Blocking receive; errors once every sender is gone and the
        /// buffer is drained.
        pub fn recv(&self) -> Result<T, RecvError> {
            let mut st = self.chan.locked();
            loop {
                if let Some(t) = st.buf.pop_front() {
                    drop(st);
                    self.chan.cv.notify_all();
                    return Ok(t);
                }
                if st.senders == 0 {
                    return Err(RecvError);
                }
                let (g, _) = super::wait_with_backstop(
                    &self.chan.cv,
                    st,
                    Duration::from_millis(50),
                );
                st = g;
            }
        }

        /// Non-blocking receive.
        pub fn try_recv(&self) -> Result<T, TryRecvError> {
            let mut st = self.chan.locked();
            if let Some(t) = st.buf.pop_front() {
                drop(st);
                self.chan.cv.notify_all();
                return Ok(t);
            }
            if st.senders == 0 {
                return Err(TryRecvError::Disconnected);
            }
            Err(TryRecvError::Empty)
        }

        /// Under the model: a plain [`recv`](Self::recv) — timeouts never
        /// fire (no modeled suite relies on them; non-modeled code is
        /// never *run* under loom, only compiled).
        pub fn recv_timeout(&self, _timeout: Duration) -> Result<T, RecvTimeoutError> {
            self.recv().map_err(|RecvError| RecvTimeoutError::Disconnected)
        }

        /// Blocking iterator over received values, ending at disconnect.
        pub fn iter(&self) -> Iter<'_, T> {
            Iter { rx: self }
        }
    }

    /// Iterator behind [`Receiver::iter`].
    pub struct Iter<'a, T> {
        rx: &'a Receiver<T>,
    }

    impl<T> Iterator for Iter<'_, T> {
        type Item = T;

        fn next(&mut self) -> Option<T> {
            self.rx.recv().ok()
        }
    }
}

#[cfg(all(test, not(loom)))]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn wait_with_backstop_reports_timeout_on_std() {
        let m = Mutex::new(0u32);
        let cv = Condvar::new();
        let g = m.lock().unwrap();
        let (_g, timed_out) = wait_with_backstop(&cv, g, Duration::from_millis(1));
        assert!(timed_out, "nobody notifies: the backstop must fire");
    }

    #[test]
    fn spawn_named_names_the_thread() {
        let h = thread::spawn_named("kde-sync-test", || {
            std::thread::current().name().map(str::to_string)
        })
        .unwrap();
        assert_eq!(h.join().unwrap().as_deref(), Some("kde-sync-test"));
    }
}
