//! PJRT execution engine: load the HLO-text artifacts, compile once on the
//! PJRT CPU client, execute batched kernel computations from the Rust
//! request path (Python is never involved at runtime).
//!
//! Interface shapes are fixed at AOT time (B = 64 queries, M = 1024 data
//! rows, D = 64 features — `artifacts/manifest.json`). This engine handles
//! the padding/tiling:
//!
//! * queries are processed in chunks of B, padded with zero rows whose
//!   outputs are discarded;
//! * data is tiled into chunks of M; partial tiles are padded with rows at
//!   coordinate 1e6 ("far points") whose kernel mass underflows to 0.0 in
//!   f32 (verified in python/tests/test_kernel.py and here);
//! * feature dimension must be <= D; columns are zero-padded (distances
//!   are unaffected);
//! * the fused level entry (`sums_ranged`) executes the
//!   `kde_sums_ranged_*` artifacts, which take per-row `[lo, hi)` data
//!   ranges as i32 operands and mask each query row's sum to its own
//!   contiguous slice of the data input — that is what lets one B=64
//!   execution serve query groups of *several* tree nodes at once, with
//!   each node's data packed as one segment of the M-row input. Grid
//!   cells (query chunk x data tile) where every row's clamped range is
//!   empty are skipped entirely, so a well-packed level costs O(1)
//!   executions instead of one per node;
//! * the fused block entry (`block_ranged`) executes the
//!   `kde_block_ranged_*` artifacts the same way — per-row ranges, dead
//!   grid cells skipped — and scatters each row's masked (B, M) slice
//!   into the ragged output the LRA row-construction path consumes.
//!
//! The engine itself is gated behind the `xla` cargo feature because the
//! *real* `xla` crate only exists in the internal offline registry.
//! Without the feature a stub with the same API is compiled whose
//! constructors always fail with an actionable error, so callers'
//! fallback paths (every caller already handles `PjrtBackend::new`
//! failing when artifacts are missing) degrade gracefully to the
//! CPU/tiled backends. *With* the feature, the engine compiles against
//! whatever `xla` dependency the manifest provides: by default the
//! in-repo compile-only stub crate (`rust/xla-stub` — client construction
//! fails, same graceful degradation), which keeps the CI leg
//! `cargo check --features xla` type-checking this module everywhere;
//! internal builds swap the path dependency for the registry crate to get
//! the real runtime.

/// AOT query-batch rows (B) — keep in sync with python/compile/model.py.
pub const AOT_B: usize = 64;
/// AOT data-tile rows (M) — keep in sync with python/compile/model.py.
pub const AOT_M: usize = 1024;
/// AOT feature columns (D) — keep in sync with python/compile/model.py.
pub const AOT_D: usize = 64;
/// Far-point coordinate used for data padding.
pub const FAR: f32 = 1.0e6;

/// Pad a `rows x d` buffer into `target_rows x AOT_D`, filling padded
/// *rows* with `fill` and padded *columns* with 0.
#[cfg_attr(not(feature = "xla"), allow(dead_code))]
pub(crate) fn pad(
    rows_buf: &[f32],
    rows: usize,
    d: usize,
    target_rows: usize,
    fill: f32,
) -> Vec<f32> {
    let mut out = vec![0.0f32; target_rows * AOT_D];
    for r in 0..target_rows {
        if r < rows {
            let src = &rows_buf[r * d..(r + 1) * d];
            out[r * AOT_D..r * AOT_D + d].copy_from_slice(src);
        } else {
            for c in 0..AOT_D {
                out[r * AOT_D + c] = fill;
            }
        }
    }
    out
}

#[cfg(feature = "xla")]
mod engine {
    use std::collections::HashMap;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Mutex;

    use anyhow::{anyhow, Context, Result};

    use super::{pad, AOT_B, AOT_D, AOT_M, FAR};
    use crate::kernel::Kernel;
    use crate::runtime::backend::KernelBackend;
    use crate::runtime::error::BackendError;

    /// Map an engine error chain onto the typed taxonomy: missing
    /// artifacts are permanent (no retry makes `manifest.json` appear
    /// mid-run); everything else — client construction, parse/compile,
    /// execution — is tagged transient, worth one bounded retry before
    /// the resilient wrapper degrades to a CPU backend.
    fn backend_err(e: &anyhow::Error) -> BackendError {
        let message = format!("{e:#}");
        if message.contains("artifacts not built") {
            BackendError::ArtifactMissing { detail: message }
        } else {
            BackendError::ExecutionFailed { message, transient: true }
        }
    }

    /// Which artifact entry to execute.
    #[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
    enum Entry {
        Sums(Kernel),
        Block(Kernel),
        /// Per-row range-masked sums: the level-fusion artifact.
        SumsRanged(Kernel),
        /// Per-row range-masked dense block: the LRA row-construction
        /// artifact (entries outside a row's range are exactly 0.0).
        BlockRanged(Kernel),
    }

    impl Entry {
        fn file_stem(self) -> String {
            match self {
                Entry::Sums(k) => format!("kde_sums_{}", k.name()),
                Entry::Block(k) => format!("kernel_block_{}", k.name()),
                Entry::SumsRanged(k) => format!("kde_sums_ranged_{}", k.name()),
                Entry::BlockRanged(k) => format!("kde_block_ranged_{}", k.name()),
            }
        }
    }

    /// Compiled-executable cache over the PJRT CPU client.
    pub struct PjrtEngine {
        client: xla::PjRtClient,
        artifacts_dir: std::path::PathBuf,
        exes: Mutex<HashMap<Entry, xla::PjRtLoadedExecutable>>,
        /// Artifact executions so far (one per padded grid cell run).
        pub executions: AtomicU64,
    }

    // SAFETY: `PjrtEngine` is not auto-Send/Sync only because
    // `xla::PjRtClient` / `PjRtLoadedExecutable` hold raw pointers into
    // the C++ runtime. The PJRT C API contract makes both client and
    // loaded-executable handles safe to use from any thread, and our
    // usage adds its own serialization on top: every executable is
    // reached exclusively through the `Mutex`'d `exes` map, compilation
    // happens under that same lock, and `executions` is atomic. No
    // `&mut` aliasing of the C++ state is ever exposed.
    unsafe impl Send for PjrtEngine {}
    // SAFETY: see the Send argument above — shared (`&self`) access only
    // touches the client through thread-safe PJRT entry points or under
    // the `exes` lock.
    unsafe impl Sync for PjrtEngine {}

    impl PjrtEngine {
        /// Create the CPU client and point at an artifacts directory.
        pub fn new(artifacts_dir: impl Into<std::path::PathBuf>) -> Result<Self> {
            let dir = artifacts_dir.into();
            if !dir.join("manifest.json").exists() {
                return Err(anyhow!(
                    "artifacts not built: {} missing (run `make artifacts`)",
                    dir.join("manifest.json").display()
                ));
            }
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(PjrtEngine {
                client,
                artifacts_dir: dir,
                exes: Mutex::new(HashMap::new()),
                executions: AtomicU64::new(0),
            })
        }

        /// Platform name of the underlying PJRT client.
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Parse + compile `entry`'s artifact on first use; returns the
        /// cached executable afterwards. Callers hold the `exes` lock for
        /// the whole compile-and-execute, serializing executions.
        fn ensure_compiled<'a>(
            &self,
            exes: &'a mut HashMap<Entry, xla::PjRtLoadedExecutable>,
            entry: Entry,
        ) -> Result<&'a xla::PjRtLoadedExecutable> {
            match exes.entry(entry) {
                std::collections::hash_map::Entry::Occupied(o) => Ok(o.into_mut()),
                std::collections::hash_map::Entry::Vacant(v) => {
                    let path = self
                        .artifacts_dir
                        .join(format!("{}.hlo.txt", entry.file_stem()));
                    let proto = xla::HloModuleProto::from_text_file(
                        path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
                    )
                    .with_context(|| format!("parsing {}", path.display()))?;
                    let comp = xla::XlaComputation::from_proto(&proto);
                    let exe = self
                        .client
                        .compile(&comp)
                        .with_context(|| format!("compiling {}", path.display()))?;
                    Ok(v.insert(exe))
                }
            }
        }

        fn run_entry(&self, entry: Entry, queries: &[f32], data: &[f32]) -> Result<Vec<f32>> {
            debug_assert_eq!(queries.len(), AOT_B * AOT_D);
            debug_assert_eq!(data.len(), AOT_M * AOT_D);
            // A poisoned lock only means an earlier execution panicked
            // mid-call; the executable cache itself is still consistent
            // (entries are inserted fully compiled), so recover the guard.
            let mut exes = self
                .exes
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner);
            let exe = self.ensure_compiled(&mut exes, entry)?;
            let q = xla::Literal::vec1(queries).reshape(&[AOT_B as i64, AOT_D as i64])?;
            let x = xla::Literal::vec1(data).reshape(&[AOT_M as i64, AOT_D as i64])?;
            let result = exe.execute::<xla::Literal>(&[q, x])?[0][0].to_literal_sync()?;
            self.executions.fetch_add(1, Ordering::Relaxed);
            // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
            let out = result.to_tuple1()?;
            Ok(out.to_vec::<f32>()?)
        }

        /// Execute a range-masked artifact (`SumsRanged` or `BlockRanged`)
        /// on one padded (B, M) tile with per-row `[lo, hi)` ranges in
        /// tile-local row units: sums yield
        /// `out[q] = sum_{j in [lo[q], hi[q])} k(queries[q], data[j])`,
        /// blocks yield the (B, M) kernel values with entries outside a
        /// row's range masked to exactly 0.0. Padding rows get the empty
        /// range `[0, 0)` and FAR data rows sit outside every live range,
        /// so neither perturbs the output.
        fn run_entry_ranged(
            &self,
            entry: Entry,
            queries: &[f32],
            data: &[f32],
            lo: &[i32],
            hi: &[i32],
        ) -> Result<Vec<f32>> {
            debug_assert_eq!(queries.len(), AOT_B * AOT_D);
            debug_assert_eq!(data.len(), AOT_M * AOT_D);
            debug_assert_eq!(lo.len(), AOT_B);
            debug_assert_eq!(hi.len(), AOT_B);
            let mut exes = self
                .exes
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner);
            let exe = self.ensure_compiled(&mut exes, entry)?;
            let q = xla::Literal::vec1(queries).reshape(&[AOT_B as i64, AOT_D as i64])?;
            let x = xla::Literal::vec1(data).reshape(&[AOT_M as i64, AOT_D as i64])?;
            let lo_l = xla::Literal::vec1(lo);
            let hi_l = xla::Literal::vec1(hi);
            let result =
                exe.execute::<xla::Literal>(&[q, x, lo_l, hi_l])?[0][0].to_literal_sync()?;
            self.executions.fetch_add(1, Ordering::Relaxed);
            let out = result.to_tuple1()?;
            Ok(out.to_vec::<f32>()?)
        }
    }

    /// `KernelBackend` implementation over the PJRT engine, with the
    /// padding/tiling logic.
    pub struct PjrtBackend {
        engine: PjrtEngine,
        evals: AtomicU64,
        calls: AtomicU64,
    }

    impl PjrtBackend {
        /// Engine + backend over an artifacts directory; fails without a
        /// built `manifest.json` (callers degrade to the CPU backends).
        pub fn new(artifacts_dir: impl Into<std::path::PathBuf>) -> Result<std::sync::Arc<Self>> {
            Ok(std::sync::Arc::new(PjrtBackend {
                engine: PjrtEngine::new(artifacts_dir)?,
                evals: AtomicU64::new(0),
                calls: AtomicU64::new(0),
            }))
        }

        /// Artifact executions so far (one per padded (B, M) grid cell —
        /// the cost metric level fusion minimizes).
        pub fn executions(&self) -> u64 {
            self.engine.executions.load(Ordering::Relaxed)
        }
    }

    impl KernelBackend for PjrtBackend {
        fn sums(&self, kernel: Kernel, queries: &[f32], data: &[f32], d: usize) -> Vec<f64> {
            match self.try_sums(kernel, queries, data, d) {
                Ok(v) => v,
                Err(e) => panic!("PJRT execution failed: {e}"),
            }
        }

        fn try_sums(
            &self,
            kernel: Kernel,
            queries: &[f32],
            data: &[f32],
            d: usize,
        ) -> Result<Vec<f64>, BackendError> {
            assert!(d > 0 && d <= AOT_D, "feature dim {d} exceeds AOT_D {AOT_D}");
            assert!(queries.len() % d == 0 && data.len() % d == 0);
            let b = queries.len() / d;
            let m = data.len() / d;
            self.evals.fetch_add((b * m) as u64, Ordering::Relaxed);
            self.calls.fetch_add(1, Ordering::Relaxed);
            let mut out = vec![0.0f64; b];
            for (qc, qchunk) in queries.chunks(AOT_B * d).enumerate() {
                let bq = qchunk.len() / d;
                let qpad = pad(qchunk, bq, d, AOT_B, 0.0);
                for xchunk in data.chunks(AOT_M * d) {
                    let mx = xchunk.len() / d;
                    let xpad = pad(xchunk, mx, d, AOT_M, FAR);
                    let sums = self
                        .engine
                        .run_entry(Entry::Sums(kernel), &qpad, &xpad)
                        .map_err(|e| backend_err(&e))?;
                    for q in 0..bq {
                        out[qc * AOT_B + q] += sums[q] as f64;
                    }
                }
            }
            Ok(out)
        }

        fn block(&self, kernel: Kernel, queries: &[f32], data: &[f32], d: usize) -> Vec<f32> {
            match self.try_block(kernel, queries, data, d) {
                Ok(v) => v,
                Err(e) => panic!("PJRT execution failed: {e}"),
            }
        }

        fn try_block(
            &self,
            kernel: Kernel,
            queries: &[f32],
            data: &[f32],
            d: usize,
        ) -> Result<Vec<f32>, BackendError> {
            assert!(d > 0 && d <= AOT_D);
            assert!(queries.len() % d == 0 && data.len() % d == 0);
            let b = queries.len() / d;
            let m = data.len() / d;
            self.evals.fetch_add((b * m) as u64, Ordering::Relaxed);
            self.calls.fetch_add(1, Ordering::Relaxed);
            let mut out = vec![0.0f32; b * m];
            for (qc, qchunk) in queries.chunks(AOT_B * d).enumerate() {
                let bq = qchunk.len() / d;
                let qpad = pad(qchunk, bq, d, AOT_B, 0.0);
                for (xc, xchunk) in data.chunks(AOT_M * d).enumerate() {
                    let mx = xchunk.len() / d;
                    let xpad = pad(xchunk, mx, d, AOT_M, FAR);
                    let blk = self
                        .engine
                        .run_entry(Entry::Block(kernel), &qpad, &xpad)
                        .map_err(|e| backend_err(&e))?;
                    for q in 0..bq {
                        let dst_row = qc * AOT_B + q;
                        for j in 0..mx {
                            out[dst_row * m + xc * AOT_M + j] = blk[q * AOT_M + j];
                        }
                    }
                }
            }
            Ok(out)
        }

        fn sums_ranged(
            &self,
            kernel: Kernel,
            queries: &[f32],
            data: &[f32],
            d: usize,
            ranges: &[(usize, usize)],
        ) -> Vec<f64> {
            match self.try_sums_ranged(kernel, queries, data, d, ranges) {
                Ok(v) => v,
                Err(e) => panic!("PJRT execution failed: {e}"),
            }
        }

        fn try_sums_ranged(
            &self,
            kernel: Kernel,
            queries: &[f32],
            data: &[f32],
            d: usize,
            ranges: &[(usize, usize)],
        ) -> Result<Vec<f64>, BackendError> {
            assert!(d > 0 && d <= AOT_D, "feature dim {d} exceeds AOT_D {AOT_D}");
            assert!(queries.len() % d == 0 && data.len() % d == 0);
            let b = queries.len() / d;
            let m = data.len() / d;
            assert_eq!(ranges.len(), b, "one range per query row");
            let mut pairs = 0u64;
            for &(lo, hi) in ranges {
                assert!(lo <= hi && hi <= m, "range ({lo}, {hi}) out of bounds for m={m}");
                pairs += (hi - lo) as u64;
            }
            self.evals.fetch_add(pairs, Ordering::Relaxed);
            self.calls.fetch_add(1, Ordering::Relaxed);
            let mut out = vec![0.0f64; b];
            for (qc, qchunk) in queries.chunks(AOT_B * d).enumerate() {
                let bq = qchunk.len() / d;
                let qpad = pad(qchunk, bq, d, AOT_B, 0.0);
                for (xc, xchunk) in data.chunks(AOT_M * d).enumerate() {
                    let mx = xchunk.len() / d;
                    let base = xc * AOT_M;
                    // Clamp every row's range to this data tile; skip the
                    // execution entirely when no row overlaps it — that is
                    // the block-diagonal structure a packed level has.
                    let mut lo_v = [0i32; AOT_B];
                    let mut hi_v = [0i32; AOT_B];
                    let mut live = false;
                    for q in 0..bq {
                        let (lo, hi) = ranges[qc * AOT_B + q];
                        let lo_c = lo.saturating_sub(base).min(mx);
                        let hi_c = hi.saturating_sub(base).min(mx);
                        if hi_c > lo_c {
                            lo_v[q] = lo_c as i32;
                            hi_v[q] = hi_c as i32;
                            live = true;
                        }
                    }
                    if !live {
                        continue;
                    }
                    let xpad = pad(xchunk, mx, d, AOT_M, FAR);
                    let sums = self
                        .engine
                        .run_entry_ranged(Entry::SumsRanged(kernel), &qpad, &xpad, &lo_v, &hi_v)
                        .map_err(|e| backend_err(&e))?;
                    for q in 0..bq {
                        out[qc * AOT_B + q] += sums[q] as f64;
                    }
                }
            }
            Ok(out)
        }

        fn block_ranged(
            &self,
            kernel: Kernel,
            queries: &[f32],
            data: &[f32],
            d: usize,
            ranges: &[(usize, usize)],
        ) -> Vec<f32> {
            match self.try_block_ranged(kernel, queries, data, d, ranges) {
                Ok(v) => v,
                Err(e) => panic!("PJRT execution failed: {e}"),
            }
        }

        fn try_block_ranged(
            &self,
            kernel: Kernel,
            queries: &[f32],
            data: &[f32],
            d: usize,
            ranges: &[(usize, usize)],
        ) -> Result<Vec<f32>, BackendError> {
            assert!(d > 0 && d <= AOT_D, "feature dim {d} exceeds AOT_D {AOT_D}");
            assert!(queries.len() % d == 0 && data.len() % d == 0);
            let b = queries.len() / d;
            let m = data.len() / d;
            assert_eq!(ranges.len(), b, "one range per query row");
            // Per-row offsets into the ragged output concatenation.
            let mut offsets = Vec::with_capacity(b + 1);
            let mut total = 0usize;
            offsets.push(0usize);
            for &(lo, hi) in ranges {
                assert!(lo <= hi && hi <= m, "range ({lo}, {hi}) out of bounds for m={m}");
                total += hi - lo;
                offsets.push(total);
            }
            self.evals.fetch_add(total as u64, Ordering::Relaxed);
            self.calls.fetch_add(1, Ordering::Relaxed);
            let mut out = vec![0.0f32; total];
            for (qc, qchunk) in queries.chunks(AOT_B * d).enumerate() {
                let bq = qchunk.len() / d;
                let qpad = pad(qchunk, bq, d, AOT_B, 0.0);
                for (xc, xchunk) in data.chunks(AOT_M * d).enumerate() {
                    let mx = xchunk.len() / d;
                    let base = xc * AOT_M;
                    // Clamp every row's range to this data tile; skip dead
                    // grid cells entirely (the block-diagonal win).
                    let mut lo_v = [0i32; AOT_B];
                    let mut hi_v = [0i32; AOT_B];
                    let mut live = false;
                    for q in 0..bq {
                        let (lo, hi) = ranges[qc * AOT_B + q];
                        let lo_c = lo.saturating_sub(base).min(mx);
                        let hi_c = hi.saturating_sub(base).min(mx);
                        if hi_c > lo_c {
                            lo_v[q] = lo_c as i32;
                            hi_v[q] = hi_c as i32;
                            live = true;
                        }
                    }
                    if !live {
                        continue;
                    }
                    let xpad = pad(xchunk, mx, d, AOT_M, FAR);
                    let blk = self
                        .engine
                        .run_entry_ranged(Entry::BlockRanged(kernel), &qpad, &xpad, &lo_v, &hi_v)
                        .map_err(|e| backend_err(&e))?;
                    // Scatter each row's live tile-local slice into its
                    // ragged output segment.
                    for q in 0..bq {
                        let (lo_c, hi_c) = (lo_v[q] as usize, hi_v[q] as usize);
                        if hi_c <= lo_c {
                            continue;
                        }
                        let row = qc * AOT_B + q;
                        let (lo, _) = ranges[row];
                        let dst0 = offsets[row] + base + lo_c - lo;
                        for k in 0..hi_c - lo_c {
                            out[dst0 + k] = blk[q * AOT_M + lo_c + k];
                        }
                    }
                }
            }
            Ok(out)
        }

        fn kernel_evals(&self) -> u64 {
            self.evals.load(Ordering::Relaxed)
        }

        fn calls(&self) -> u64 {
            self.calls.load(Ordering::Relaxed)
        }

        fn name(&self) -> &'static str {
            "pjrt"
        }

        fn isa(&self) -> &'static str {
            "xla"
        }
    }
}

#[cfg(feature = "xla")]
pub use engine::{PjrtBackend, PjrtEngine};

#[cfg(not(feature = "xla"))]
mod stub {
    use anyhow::{anyhow, Result};

    use crate::kernel::Kernel;
    use crate::runtime::backend::KernelBackend;

    fn unavailable(dir: std::path::PathBuf) -> anyhow::Error {
        // Keep the missing-artifacts message identical to the real engine:
        // callers (and tests/pjrt_parity.rs) match on it to decide whether
        // to tell the user to build artifacts or to enable the runtime.
        if dir.join("manifest.json").exists() {
            anyhow!(
                "PJRT runtime disabled: this binary was built without the `xla` \
                 cargo feature (artifacts found at {})",
                dir.display()
            )
        } else {
            anyhow!(
                "artifacts not built: {} missing (run `make artifacts`)",
                dir.join("manifest.json").display()
            )
        }
    }

    /// Stub engine compiled when the `xla` feature is off: construction
    /// always fails, so no method past `new` is ever reachable.
    pub struct PjrtEngine {
        _private: (),
    }

    impl PjrtEngine {
        /// Always fails: this build carries no PJRT runtime.
        pub fn new(artifacts_dir: impl Into<std::path::PathBuf>) -> Result<Self> {
            Err(unavailable(artifacts_dir.into()))
        }

        /// Placeholder platform name (unreachable in practice).
        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }
    }

    /// Stub backend with the same API surface as the real one.
    pub struct PjrtBackend {
        _private: (),
    }

    impl PjrtBackend {
        /// Always fails: this build carries no PJRT runtime.
        pub fn new(artifacts_dir: impl Into<std::path::PathBuf>) -> Result<std::sync::Arc<Self>> {
            Err(unavailable(artifacts_dir.into()))
        }

        /// Artifact execution count (always 0 for the stub).
        pub fn executions(&self) -> u64 {
            0
        }
    }

    impl KernelBackend for PjrtBackend {
        fn sums(&self, _kernel: Kernel, _queries: &[f32], _data: &[f32], _d: usize) -> Vec<f64> {
            unreachable!("PjrtBackend cannot be constructed without the `xla` feature")
        }

        fn block(&self, _kernel: Kernel, _queries: &[f32], _data: &[f32], _d: usize) -> Vec<f32> {
            unreachable!("PjrtBackend cannot be constructed without the `xla` feature")
        }

        fn sums_ranged(
            &self,
            _kernel: Kernel,
            _queries: &[f32],
            _data: &[f32],
            _d: usize,
            _ranges: &[(usize, usize)],
        ) -> Vec<f64> {
            unreachable!("PjrtBackend cannot be constructed without the `xla` feature")
        }

        fn block_ranged(
            &self,
            _kernel: Kernel,
            _queries: &[f32],
            _data: &[f32],
            _d: usize,
            _ranges: &[(usize, usize)],
        ) -> Vec<f32> {
            unreachable!("PjrtBackend cannot be constructed without the `xla` feature")
        }

        fn kernel_evals(&self) -> u64 {
            0
        }

        fn name(&self) -> &'static str {
            "pjrt-disabled"
        }
    }
}

#[cfg(not(feature = "xla"))]
pub use stub::{PjrtBackend, PjrtEngine};

// PJRT integration tests live in rust/tests/pjrt_parity.rs (they need the
// artifacts built); unit tests here cover the pure padding logic.
#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn pad_zero_fill_layout() {
        // 2 rows, d=3 -> 4 rows x AOT_D
        let buf = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let out = pad(&buf, 2, 3, 4, 0.0);
        assert_eq!(out.len(), 4 * AOT_D);
        assert_eq!(&out[0..3], &[1.0, 2.0, 3.0]);
        assert_eq!(out[3], 0.0, "column padding is zero");
        assert_eq!(&out[AOT_D..AOT_D + 3], &[4.0, 5.0, 6.0]);
        assert_eq!(out[2 * AOT_D], 0.0);
    }

    #[test]
    fn pad_far_fill_rows() {
        let buf = [1.0f32, 2.0];
        let out = pad(&buf, 1, 2, 3, FAR);
        // padded rows are FAR across all AOT_D columns
        for c in 0..AOT_D {
            assert_eq!(out[AOT_D + c], FAR);
            assert_eq!(out[2 * AOT_D + c], FAR);
        }
        // real row: data then zero columns
        assert_eq!(out[0], 1.0);
        assert_eq!(out[1], 2.0);
        assert_eq!(out[2], 0.0);
    }

    #[test]
    fn backend_constructor_fails_cleanly_without_artifacts() {
        let err = match PjrtBackend::new("/nonexistent/artifacts-dir") {
            Ok(_) => panic!("must not succeed without artifacts"),
            Err(e) => format!("{e}"),
        };
        assert!(err.contains("artifacts not built"), "got: {err}");
    }
}
