//! Conjugate-gradient solver over an abstract symmetric PSD operator, with
//! optional diagonal (Jacobi) preconditioning and null-space projection.
//!
//! This is the §5.1.1 workhorse: "fast Laplacian solver" is instantiated as
//! preconditioned CG on the **sparsifier** Laplacian (Theorem 5.10's solver
//! replaced by the classical iterative method — same contract: returns x
//! with `||x - L^+ b||_L <= alpha ||L^+ b||_L`).

use crate::linalg::eigen::SymOp;
use crate::linalg::mat::{axpy, dot};

/// Outcome of a CG solve.
#[derive(Clone, Debug)]
pub struct CgResult {
    pub x: Vec<f64>,
    pub iters: usize,
    pub residual: f64,
    pub converged: bool,
}

/// Solve `A x = b` by (optionally preconditioned) CG.
///
/// * `diag_precond` — if provided, the diagonal of `A` (Jacobi M^{-1}).
/// * `project_ones` — if true, keep iterates orthogonal to the all-ones
///   vector (the Laplacian null space for connected graphs); `b` must also
///   satisfy `1^T b = 0` for the system to be consistent.
pub fn cg(
    a: &dyn SymOp,
    b: &[f64],
    diag_precond: Option<&[f64]>,
    project_ones: bool,
    tol: f64,
    max_iters: usize,
) -> CgResult {
    let n = a.dim();
    assert_eq!(b.len(), n);
    let proj = |v: &mut Vec<f64>| {
        if project_ones {
            let m: f64 = v.iter().sum::<f64>() / n as f64;
            for x in v.iter_mut() {
                *x -= m;
            }
        }
    };
    let apply_precond = |r: &[f64]| -> Vec<f64> {
        match diag_precond {
            Some(d) => r
                .iter()
                .zip(d)
                .map(|(ri, di)| if *di > 0.0 { ri / di } else { *ri })
                .collect(),
            None => r.to_vec(),
        }
    };

    let mut x = vec![0.0; n];
    let mut r = b.to_vec();
    proj(&mut r);
    let bnorm = dot(&r, &r).sqrt().max(1e-300);
    let mut z = apply_precond(&r);
    proj(&mut z);
    let mut p = z.clone();
    let mut rz = dot(&r, &z);
    let mut buf = vec![0.0; n];

    for it in 0..max_iters {
        let rnorm = dot(&r, &r).sqrt();
        if rnorm <= tol * bnorm {
            return CgResult { x, iters: it, residual: rnorm / bnorm, converged: true };
        }
        a.apply(&p, &mut buf);
        let pap = dot(&p, &buf);
        if pap <= 0.0 {
            break; // numerical breakdown / null-space direction
        }
        let alpha = rz / pap;
        axpy(&mut x, alpha, &p);
        axpy(&mut r, -alpha, &buf);
        let mut rv = r.clone();
        proj(&mut rv);
        r = rv;
        z = apply_precond(&r);
        proj(&mut z);
        let rz_new = dot(&r, &z);
        let beta = rz_new / rz;
        rz = rz_new;
        for i in 0..n {
            p[i] = z[i] + beta * p[i];
        }
    }
    let rnorm = dot(&r, &r).sqrt();
    CgResult { x, iters: max_iters, residual: rnorm / bnorm, converged: rnorm <= tol * bnorm }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::mat::Mat;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    #[test]
    fn cg_solves_spd_system() {
        forall(8, |rng, _| {
            let n = 4 + rng.below(12);
            // SPD matrix B B^T + I.
            let mut b = Mat::zeros(n, n);
            for i in 0..n {
                for j in 0..n {
                    b[(i, j)] = rng.normal();
                }
            }
            let mut a = b.matmul(&b.transpose());
            for i in 0..n {
                a[(i, i)] += 1.0;
            }
            let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let rhs = a.matvec(&xs);
            let res = cg(&a, &rhs, None, false, 1e-12, 10 * n);
            assert!(res.converged, "residual {}", res.residual);
            for i in 0..n {
                assert!((res.x[i] - xs[i]).abs() < 1e-6, "x[{i}]");
            }
        });
    }

    #[test]
    fn cg_with_jacobi_preconditioner_converges_faster_or_equal() {
        let mut rng = Rng::new(77);
        let n = 32;
        // Ill-conditioned diagonal + small coupling.
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            a[(i, i)] = 1.0 + (i as f64) * 10.0;
        }
        for i in 0..n - 1 {
            a[(i, i + 1)] = 0.1;
            a[(i + 1, i)] = 0.1;
        }
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let rhs = a.matvec(&xs);
        let diag: Vec<f64> = (0..n).map(|i| a[(i, i)]).collect();
        let plain = cg(&a, &rhs, None, false, 1e-10, 500);
        let pre = cg(&a, &rhs, Some(&diag), false, 1e-10, 500);
        assert!(plain.converged && pre.converged);
        assert!(pre.iters <= plain.iters, "pre {} vs plain {}", pre.iters, plain.iters);
    }

    #[test]
    fn cg_laplacian_with_projection() {
        // Path graph Laplacian on 4 nodes; b orthogonal to ones.
        let a = Mat::from_rows(vec![
            vec![1.0, -1.0, 0.0, 0.0],
            vec![-1.0, 2.0, -1.0, 0.0],
            vec![0.0, -1.0, 2.0, -1.0],
            vec![0.0, 0.0, -1.0, 1.0],
        ]);
        let b = vec![1.0, 0.0, 0.0, -1.0];
        let res = cg(&a, &b, None, true, 1e-12, 200);
        assert!(res.converged);
        // Check A x = b up to the null space.
        let ax = a.matvec(&res.x);
        for i in 0..4 {
            assert!((ax[i] - b[i]).abs() < 1e-8, "coord {i}: {} vs {}", ax[i], b[i]);
        }
        // Solution is mean-zero.
        let mean: f64 = res.x.iter().sum::<f64>() / 4.0;
        assert!(mean.abs() < 1e-10);
    }
}
