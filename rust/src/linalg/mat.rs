//! Dense row-major f64 matrix with the handful of operations the paper's
//! algorithms and baselines need. Deliberately small: no BLAS, no traits —
//! just the substrate.

#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: Vec<Vec<f64>>) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        assert!(rows.iter().all(|x| x.len() == c));
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            data.extend(row);
        }
        Mat { rows: r, cols: c, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// `self * x` for a vector `x`.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        let mut out = vec![0.0; self.rows];
        for i in 0..self.rows {
            let r = self.row(i);
            let mut s = 0.0;
            for j in 0..self.cols {
                s += r[j] * x[j];
            }
            out[i] = s;
        }
        out
    }

    /// `self^T * x`.
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows);
        let mut out = vec![0.0; self.cols];
        for i in 0..self.rows {
            let r = self.row(i);
            let xi = x[i];
            for j in 0..self.cols {
                out[j] += r[j] * xi;
            }
        }
        out
    }

    /// Matrix product `self * other`.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows);
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for kk in 0..self.cols {
                let a = self[(i, kk)];
                if a == 0.0 {
                    continue;
                }
                let orow = other.row(kk);
                let out_row = out.row_mut(i);
                for j in 0..other.cols {
                    out_row[j] += a * orow[j];
                }
            }
        }
        out
    }

    /// `self * self^T` (Gram of rows).
    pub fn gram_rows(&self) -> Mat {
        let mut g = Mat::zeros(self.rows, self.rows);
        for i in 0..self.rows {
            for j in i..self.rows {
                let mut s = 0.0;
                let (ri, rj) = (self.row(i), self.row(j));
                for t in 0..self.cols {
                    s += ri[t] * rj[t];
                }
                g[(i, j)] = s;
                g[(j, i)] = s;
            }
        }
        g
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    pub fn frob_norm_sq(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum()
    }

    /// `||self - other||_F^2`.
    pub fn frob_dist_sq(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum()
    }

    /// Scale in place.
    pub fn scale(&mut self, c: f64) {
        for v in &mut self.data {
            *v *= c;
        }
    }

    /// Maximum absolute entry.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0, |m, v| m.max(v.abs()))
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

// -------------------------- vector helpers --------------------------------

pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

pub fn norm(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

pub fn axpy(y: &mut [f64], alpha: f64, x: &[f64]) {
    debug_assert_eq!(y.len(), x.len());
    for i in 0..y.len() {
        y[i] += alpha * x[i];
    }
}

/// Normalize in place, returning the prior norm (no-op for zero vectors).
pub fn normalize(v: &mut [f64]) -> f64 {
    let n = norm(v);
    if n > 0.0 {
        for x in v.iter_mut() {
            *x /= n;
        }
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_identity() {
        let m = Mat::identity(3);
        assert_eq!(m.matvec(&[1.0, 2.0, 3.0]), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Mat::from_rows(vec![vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn transpose_round_trip() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn gram_matches_matmul() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0], vec![3.0, -1.0], vec![0.5, 0.0]]);
        let g = a.gram_rows();
        let want = a.matmul(&a.transpose());
        assert!(g.frob_dist_sq(&want) < 1e-20);
    }

    #[test]
    fn matvec_t_is_transpose_matvec() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        let x = [1.0, -1.0];
        assert_eq!(a.matvec_t(&x), a.transpose().matvec(&x));
    }

    #[test]
    fn frob_norms() {
        let a = Mat::from_rows(vec![vec![3.0, 4.0]]);
        assert_eq!(a.frob_norm_sq(), 25.0);
        let b = Mat::from_rows(vec![vec![0.0, 0.0]]);
        assert_eq!(a.frob_dist_sq(&b), 25.0);
    }

    #[test]
    fn normalize_unit() {
        let mut v = vec![3.0, 4.0];
        let n = normalize(&mut v);
        assert!((n - 5.0).abs() < 1e-12);
        assert!((norm(&v) - 1.0).abs() < 1e-12);
    }
}
