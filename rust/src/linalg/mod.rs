//! Dense/sparse linear-algebra substrate built from scratch (no external
//! BLAS/LAPACK): dense matrices, symmetric eigensolvers, CG, CountSketch.
//!
//! Everything downstream (sparsification quality checks, LRA baselines,
//! spectral clustering, EMD-spectrum ground truth) sits on these.

pub mod cg;
pub mod eigen;
pub mod mat;
pub mod sketch;

pub use cg::{cg, CgResult};
pub use eigen::{block_power, jacobi_eigen, SymOp};
pub use mat::{axpy, dot, norm, normalize, Mat};
pub use sketch::CountSketch;
