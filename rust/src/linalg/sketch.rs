//! Sketching baselines: CountSketch (Clarkson-Woodruff 2013 input-sparsity
//! transform) used as the **IS** baseline in the Fig. 3 low-rank
//! approximation experiments, exactly as in the paper's §7 comparison.

use crate::linalg::mat::Mat;
use crate::util::rng::Rng;

/// A CountSketch matrix `S in R^{s x n}`: each column has a single ±1 in a
/// uniformly random row. Stored implicitly as (row index, sign) per column.
#[derive(Clone, Debug)]
pub struct CountSketch {
    pub s: usize,
    pub n: usize,
    bucket: Vec<usize>,
    sign: Vec<f64>,
}

impl CountSketch {
    pub fn new(s: usize, n: usize, rng: &mut Rng) -> Self {
        assert!(s > 0);
        let bucket = (0..n).map(|_| rng.below(s)).collect();
        let sign = (0..n)
            .map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 })
            .collect();
        CountSketch { s, n, bucket, sign }
    }

    /// `S * A` for a dense `A (n x m)` in O(nnz(A)) time.
    pub fn sketch(&self, a: &Mat) -> Mat {
        assert_eq!(a.rows, self.n);
        let mut out = Mat::zeros(self.s, a.cols);
        for i in 0..a.rows {
            let b = self.bucket[i];
            let sg = self.sign[i];
            let src = a.row(i);
            let dst = out.row_mut(b);
            for j in 0..a.cols {
                dst[j] += sg * src[j];
            }
        }
        out
    }

    /// Apply to a vector.
    pub fn sketch_vec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.n);
        let mut out = vec![0.0; self.s];
        for i in 0..x.len() {
            out[self.bucket[i]] += self.sign[i] * x[i];
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::mat::dot;
    use crate::util::prop::forall;

    #[test]
    fn sketch_matches_explicit_matrix() {
        let mut rng = Rng::new(31);
        let cs = CountSketch::new(4, 10, &mut rng);
        // Build explicit S.
        let mut s_mat = Mat::zeros(4, 10);
        for j in 0..10 {
            s_mat[(cs.bucket[j], j)] = cs.sign[j];
        }
        let mut a = Mat::zeros(10, 3);
        for i in 0..10 {
            for j in 0..3 {
                a[(i, j)] = rng.normal();
            }
        }
        let fast = cs.sketch(&a);
        let slow = s_mat.matmul(&a);
        assert!(fast.frob_dist_sq(&slow) < 1e-20);
    }

    #[test]
    fn sketch_preserves_norms_in_expectation() {
        // E[||Sx||^2] = ||x||^2 for CountSketch.
        forall(4, |rng, _| {
            let n = 64;
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let want = dot(&x, &x);
            let trials = 300;
            let mut acc = 0.0;
            for _ in 0..trials {
                let cs = CountSketch::new(16, n, rng);
                let y = cs.sketch_vec(&x);
                acc += dot(&y, &y);
            }
            let got = acc / trials as f64;
            assert!(
                (got - want).abs() < 0.25 * want,
                "E||Sx||^2 = {got}, want {want}"
            );
        });
    }
}
