//! Symmetric eigensolvers: cyclic Jacobi (exact, O(n^3), the test/baseline
//! oracle) and block subspace iteration (the MM15-style "power method"
//! workhorse used by the spectral-clustering and SVD-baseline paths).

use crate::linalg::mat::{dot, normalize, Mat};
use crate::util::rng::Rng;

/// Full symmetric eigendecomposition via cyclic Jacobi rotations.
/// Returns `(eigenvalues, eigenvectors)` with eigenvalues sorted
/// descending; eigenvector `i` is the `i`-th **column** of the returned
/// matrix.
pub fn jacobi_eigen(a: &Mat, max_sweeps: usize) -> (Vec<f64>, Mat) {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut m = a.clone();
    let mut v = Mat::identity(n);
    for _ in 0..max_sweeps {
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if off.sqrt() < 1e-12 * (1.0 + m.max_abs()) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                let theta = 0.5 * (aqq - app) / apq;
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Apply rotation to rows/cols p and q of m.
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }
    let mut pairs: Vec<(f64, usize)> = (0..n).map(|i| (m[(i, i)], i)).collect();
    pairs.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let evals: Vec<f64> = pairs.iter().map(|p| p.0).collect();
    let mut evecs = Mat::zeros(n, n);
    for (new_col, &(_, old_col)) in pairs.iter().enumerate() {
        for r in 0..n {
            evecs[(r, new_col)] = v[(r, old_col)];
        }
    }
    (evals, evecs)
}

/// Abstract symmetric operator `x -> Ax` for matrix-free iteration.
pub trait SymOp {
    fn dim(&self) -> usize;
    fn apply(&self, x: &[f64], out: &mut [f64]);
}

impl SymOp for Mat {
    fn dim(&self) -> usize {
        self.rows
    }
    fn apply(&self, x: &[f64], out: &mut [f64]) {
        let y = self.matvec(x);
        out.copy_from_slice(&y);
    }
}

/// Modified Gram-Schmidt orthonormalization of the columns of `q`
/// (column-major layout: `q[j]` is column j).
pub fn mgs(q: &mut [Vec<f64>]) {
    let k = q.len();
    for j in 0..k {
        for i in 0..j {
            let (head, tail) = q.split_at_mut(j);
            let qi = &head[i];
            let qj = &mut tail[0];
            let proj = dot(qj, qi);
            for (x, y) in qj.iter_mut().zip(qi.iter()) {
                *x -= proj * y;
            }
        }
        normalize(&mut q[j]);
    }
}

/// Block subspace iteration (simultaneous power method with
/// orthonormalization) for the top-`k` eigenpairs of a symmetric PSD-ish
/// operator. This is the practical core of MM15's randomized block Krylov
/// method; convergence checked via Rayleigh-quotient stabilization.
///
/// Returns `(eigenvalues desc, eigenvectors as Vec of columns)`.
pub fn block_power(
    op: &dyn SymOp,
    k: usize,
    iters: usize,
    rng: &mut Rng,
) -> (Vec<f64>, Vec<Vec<f64>>) {
    let n = op.dim();
    let k = k.min(n);
    // Oversample the subspace: the trailing requested eigenpair converges
    // at the rate of the gap to the (p+1)-th eigenvalue, so padding with a
    // couple of extra columns sharpens eigenpair k substantially.
    let p = (k + 2).min(n);
    let mut q: Vec<Vec<f64>> = (0..p)
        .map(|_| (0..n).map(|_| rng.normal()).collect())
        .collect();
    mgs(&mut q);
    let mut buf = vec![0.0; n];
    let mut last: Vec<f64> = vec![f64::INFINITY; p];
    for it in 0..iters {
        for col in q.iter_mut() {
            op.apply(col, &mut buf);
            col.copy_from_slice(&buf);
        }
        mgs(&mut q);
        if it % 4 == 3 {
            // Rayleigh quotients for convergence check.
            let mut vals = Vec::with_capacity(p);
            for col in &q {
                op.apply(col, &mut buf);
                vals.push(dot(col, &buf));
            }
            let delta: f64 = vals
                .iter()
                .zip(&last)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f64::max);
            let scale = vals.iter().fold(1e-12, |m: f64, v| m.max(v.abs()));
            last = vals;
            if delta < 1e-10 * scale {
                break;
            }
        }
    }
    // Rayleigh-Ritz: project, solve the small eigenproblem exactly, keep
    // only the k requested eigenpairs (drop the oversampling pad).
    let mut t = Mat::zeros(p, p);
    let mut aq: Vec<Vec<f64>> = Vec::with_capacity(p);
    for col in &q {
        op.apply(col, &mut buf);
        aq.push(buf.clone());
    }
    for i in 0..p {
        for j in 0..p {
            t[(i, j)] = dot(&q[i], &aq[j]);
        }
    }
    let (tvals, tvecs) = jacobi_eigen(&t, 50);
    let mut out_vecs: Vec<Vec<f64>> = Vec::with_capacity(k);
    for c in 0..k {
        let mut v = vec![0.0; n];
        for j in 0..p {
            let w = tvecs[(j, c)];
            for i in 0..n {
                v[i] += w * q[j][i];
            }
        }
        normalize(&mut v);
        out_vecs.push(v);
    }
    (tvals[..k].to_vec(), out_vecs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    fn random_symmetric(n: usize, rng: &mut Rng) -> Mat {
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let v = rng.normal();
                a[(i, j)] = v;
                a[(j, i)] = v;
            }
        }
        a
    }

    #[test]
    fn jacobi_diagonal_matrix() {
        let mut a = Mat::zeros(3, 3);
        a[(0, 0)] = 3.0;
        a[(1, 1)] = 1.0;
        a[(2, 2)] = 2.0;
        let (vals, _) = jacobi_eigen(&a, 30);
        assert!((vals[0] - 3.0).abs() < 1e-10);
        assert!((vals[1] - 2.0).abs() < 1e-10);
        assert!((vals[2] - 1.0).abs() < 1e-10);
    }

    #[test]
    fn jacobi_known_2x2() {
        // [[2,1],[1,2]] -> eigenvalues 3, 1.
        let a = Mat::from_rows(vec![vec![2.0, 1.0], vec![1.0, 2.0]]);
        let (vals, vecs) = jacobi_eigen(&a, 30);
        assert!((vals[0] - 3.0).abs() < 1e-10);
        assert!((vals[1] - 1.0).abs() < 1e-10);
        // eigenvector for 3 is (1,1)/sqrt(2) up to sign
        let v0 = [vecs[(0, 0)], vecs[(1, 0)]];
        assert!((v0[0].abs() - std::f64::consts::FRAC_1_SQRT_2).abs() < 1e-8);
        assert!((v0[0] - v0[1]).abs() < 1e-8);
    }

    #[test]
    fn jacobi_reconstructs_matrix() {
        forall(8, |rng, _| {
            let n = 2 + rng.below(8);
            let a = random_symmetric(n, rng);
            let (vals, vecs) = jacobi_eigen(&a, 60);
            // A = V diag(vals) V^T
            let mut recon = Mat::zeros(n, n);
            for c in 0..n {
                for i in 0..n {
                    for j in 0..n {
                        recon[(i, j)] += vals[c] * vecs[(i, c)] * vecs[(j, c)];
                    }
                }
            }
            assert!(
                recon.frob_dist_sq(&a) < 1e-16 * (1.0 + a.frob_norm_sq()),
                "reconstruction error too big"
            );
        });
    }

    #[test]
    fn jacobi_eigenvectors_orthonormal() {
        let mut rng = Rng::new(23);
        let a = random_symmetric(6, &mut rng);
        let (_, vecs) = jacobi_eigen(&a, 60);
        for i in 0..6 {
            for j in 0..6 {
                let mut s = 0.0;
                for r in 0..6 {
                    s += vecs[(r, i)] * vecs[(r, j)];
                }
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((s - want).abs() < 1e-8, "({i},{j}) = {s}");
            }
        }
    }

    #[test]
    fn block_power_matches_jacobi_on_psd() {
        forall(6, |rng, _| {
            let n = 6 + rng.below(10);
            let b = random_symmetric(n, rng);
            let a = b.matmul(&b.transpose()); // PSD
            let (jvals, _) = jacobi_eigen(&a, 80);
            let (pvals, pvecs) = block_power(&a, 3, 400, rng);
            for i in 0..3 {
                assert!(
                    (pvals[i] - jvals[i]).abs() < 1e-4 * (1.0 + jvals[0]),
                    "eig {i}: {} vs {}",
                    pvals[i],
                    jvals[i]
                );
            }
            // Rayleigh quotient of returned vector equals returned value.
            let mut buf = vec![0.0; n];
            a.apply(&pvecs[0], &mut buf);
            let rq = dot(&pvecs[0], &buf);
            assert!((rq - pvals[0]).abs() < 1e-6 * (1.0 + jvals[0]));
        });
    }
}
