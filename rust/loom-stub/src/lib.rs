//! Std-backed stub of the [`loom`](https://docs.rs/loom) model checker.
//!
//! `runtime/sync.rs` resolves to this crate's re-exports under
//! `--cfg loom`. The real loom crate replaces every `std::sync` primitive
//! with an instrumented twin and runs the [`model`] closure once per
//! *possible interleaving* (bounded by `LOOM_MAX_PREEMPTIONS`), turning a
//! lost wakeup or misordered handoff into a deterministic failure with a
//! replayable schedule. That crate is a registry dependency the offline
//! container cannot fetch, so this stub keeps the same API shape over
//! plain `std`: [`model`] becomes a bounded stress loop — each iteration
//! is one concrete OS-scheduled execution — and the sync types are the
//! `std` originals. The loom CI leg (and any internal build) swaps the
//! path dependency for the registry crate of the same name, exactly like
//! `rust/xla-stub`, and the same test source is then checked
//! exhaustively.
//!
//! Only the surface `runtime/sync.rs` and the `loom_*` test suites use is
//! mirrored; anything else is deliberately absent so an accidental
//! dependency on stub-only behavior cannot creep in.

/// Iterations one [`model`] call stress-runs when the real checker is
/// unavailable. Overridable via `LOOM_STUB_ITERS` (the real crate ignores
/// that variable, so it is safe to leave set in CI).
fn stub_iters() -> usize {
    std::env::var("LOOM_STUB_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

/// Run `f` under the "model": the real crate explores every interleaving
/// of `loom` primitives; this stub re-executes the closure
/// [`stub_iters`] times so races still get many concrete chances to
/// misbehave under real OS scheduling.
pub fn model<F>(f: F)
where
    F: Fn() + Sync + Send + 'static,
{
    for _ in 0..stub_iters() {
        f();
    }
}

pub mod sync {
    //! Stub twins of `loom::sync`: the `std` originals.
    pub use std::sync::{Arc, Condvar, Mutex, MutexGuard, RwLock};

    pub mod atomic {
        //! Stub twins of `loom::sync::atomic`: the `std` originals.
        pub use std::sync::atomic::{
            fence, AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering,
        };
    }
}

pub mod thread {
    //! Stub twins of `loom::thread`: the `std` originals.
    pub use std::thread::{spawn, yield_now, JoinHandle};
}

#[cfg(test)]
mod tests {
    use super::sync::atomic::{AtomicUsize, Ordering};
    use super::sync::Arc;

    #[test]
    fn model_runs_the_closure_repeatedly() {
        let hits = Arc::new(AtomicUsize::new(0));
        let h = Arc::clone(&hits);
        super::model(move || {
            h.fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.load(Ordering::Relaxed) >= 1);
    }
}
