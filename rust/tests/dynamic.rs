//! Rebuild-equivalence property suite for the dynamic kernel-graph layer.
//!
//! The contract every test here pins: a **maintained** structure (edited
//! in place through tombstone deletes + slot-reusing inserts) must be
//! indistinguishable from a **fresh** structure built from scratch over
//! the same final point set —
//!
//! * `MultiLevelKde`: bit-identical memoized sums at every node and
//!   bit-identical neighbor samples from forked twin RNG streams, because
//!   path rebuilds replay each node's recorded RNG snapshot;
//! * edit cost: O(log n) oracle rebuilds per edit (the dispatch-count
//!   contract `edit_stats` exposes);
//! * `MaintainedSparsifier`: after a long seeded event script the
//!   maintained graph's Laplacian quadratic forms match a from-scratch
//!   build + resparsify over the identical live set within the repo's
//!   existing spectral margins.
//!
//! Failures reproduce with `PROP_SEED=<printed seed>`.

use std::sync::Arc;

use kde_matrix::apps::resparsify::{
    resparsify, MaintainedConfig, MaintainedSparsifier, PointEvent,
};
use kde_matrix::graph::WGraph;
use kde_matrix::kde::{EstimatorKind, KdeConfig, KdeCounters, MultiLevelKde};
use kde_matrix::kernel::dataset::gaussian_mixture;
use kde_matrix::kernel::{Dataset, Kernel};
use kde_matrix::runtime::backend::CpuBackend;
use kde_matrix::sampling::NeighborSampler;
use kde_matrix::util::prop::{default_cases, forall};
use kde_matrix::util::rng::Rng;

fn build_dyn(ds: Arc<Dataset>, kernel: Kernel, cfg: &KdeConfig) -> MultiLevelKde {
    MultiLevelKde::build_dynamic(ds, kernel, cfg, CpuBackend::new(), KdeCounters::new())
}

/// The tentpole property: random dataset, random seeded edit script, then
/// the maintained tree must match a fresh `build_dynamic` over its own
/// final dataset bit for bit — sums at the root and at random internal
/// nodes, and neighbor samples drawn from twin RNG streams — while
/// staying inside the O(log n) rebuilds-per-edit budget.
#[test]
fn maintained_tree_matches_fresh_rebuild_bit_for_bit() {
    // Each case builds two trees and applies up to ~32 edits; cap the
    // case count so the suite stays test-tier cheap.
    let cases = default_cases().min(24);
    forall(cases, |rng, case| {
        let n = 64 + rng.below(192);
        let d = 2 + rng.below(3);
        let mut drng = rng.fork();
        let ds = Arc::new(gaussian_mixture(n, d, 2, 1.0, 0.5, &mut drng));
        let kernel = if case % 2 == 0 { Kernel::Laplacian } else { Kernel::Gaussian };
        let cfg = KdeConfig {
            kind: if case % 3 == 0 {
                EstimatorKind::Naive
            } else {
                EstimatorKind::Sampling { eps: 0.5, tau: 0.2 }
            },
            leaf_cutoff: 8,
            seed: 0x5EED ^ case as u64,
        };
        let mut tree = build_dyn(ds, kernel, &cfg);
        let mut live: Vec<usize> = (0..n).collect();
        let edits = 8 + rng.below(24);
        let mut applied = 0u64;
        for _ in 0..edits {
            if live.len() > 2 && rng.bernoulli(0.5) {
                let k = rng.below(live.len());
                let slot = live.swap_remove(k);
                assert!(tree.delete(slot), "live slot must delete");
                applied += 1;
            } else {
                let row: Vec<f32> = (0..d).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect();
                // None only while the slot space is full (no prior delete).
                if let Some(slot) = tree.insert(&row) {
                    live.push(slot);
                    applied += 1;
                }
            }
            // Warm the memo mid-script so stale entries exist for the
            // stamp invalidation to retire.
            let p = live[rng.below(live.len())];
            let _ = tree.query_point(tree.root(), p);
        }
        live.sort_unstable();

        // Dispatch-count contract: O(log n) oracle rebuilds per edit.
        let (edit_count, rebuilds) = tree.edit_stats();
        assert_eq!(edit_count, applied);
        let depth = (n as f64).log2().ceil() as u64 + 2;
        assert!(
            rebuilds <= applied * depth,
            "rebuilds {rebuilds} > edits {applied} x depth {depth}"
        );

        // Fresh build over the SAME final dataset (tombstones included).
        let fresh = build_dyn(tree.ds.clone(), kernel, &cfg);
        let got = tree.query_points(tree.root(), &live);
        let want = fresh.query_points(fresh.root(), &live);
        for (k, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(g.to_bits(), w.to_bits(), "root sum diverged at live point {k}");
        }
        for _ in 0..4 {
            let id = rng.below(tree.num_nodes());
            let got = tree.query_points(id, &live);
            let want = fresh.query_points(id, &live);
            for (k, (g, w)) in got.iter().zip(&want).enumerate() {
                assert_eq!(g.to_bits(), w.to_bits(), "node {id} sum diverged at point {k}");
            }
        }

        // Neighbor samples from forked twin streams must agree exactly.
        let a = NeighborSampler::new(Arc::new(tree));
        let b = NeighborSampler::new(Arc::new(fresh));
        let mut sa = Rng::new(0xBEEF ^ case as u64);
        let mut sb = sa.clone();
        for _ in 0..8 {
            let src = live[sa.below(live.len())];
            let _ = sb.below(live.len()); // keep the twin streams aligned
            match (a.sample(src, &mut sa), b.sample(src, &mut sb)) {
                (Some(x), Some(y)) => {
                    assert_eq!(x.neighbor, y.neighbor, "sample diverged for source {src}");
                    assert_eq!(x.prob.to_bits(), y.prob.to_bits(), "prob diverged for {src}");
                }
                (None, None) => {}
                other => panic!("one tree sampled, the other refused: {other:?}"),
            }
        }
    });
}

/// Before any edit, a dynamic build answers bit-identically to the static
/// build of the same config — owned-buffer oracles change the memory
/// shape, never the numbers.
#[test]
fn dynamic_build_is_bit_identical_to_static_before_any_edit() {
    forall(12, |rng, case| {
        let n = 32 + rng.below(128);
        let d = 2 + rng.below(2);
        let mut drng = rng.fork();
        let ds = Arc::new(gaussian_mixture(n, d, 2, 1.2, 0.5, &mut drng));
        let kernel = if case % 2 == 0 { Kernel::Laplacian } else { Kernel::Gaussian };
        let cfg = KdeConfig {
            kind: if case % 2 == 0 {
                EstimatorKind::Sampling { eps: 0.5, tau: 0.2 }
            } else {
                EstimatorKind::Naive
            },
            leaf_cutoff: 8,
            seed: 0xD00D ^ case as u64,
        };
        let stat = MultiLevelKde::build(
            ds.clone(),
            kernel,
            &cfg,
            CpuBackend::new(),
            KdeCounters::new(),
        );
        let dynm = build_dyn(ds, kernel, &cfg);
        let pts: Vec<usize> = (0..n).collect();
        let mut ids = vec![stat.root()];
        for _ in 0..3 {
            ids.push(rng.below(stat.num_nodes()));
        }
        for id in ids {
            let s = stat.query_points(id, &pts);
            let y = dynm.query_points(id, &pts);
            for (k, (a, b)) in s.iter().zip(&y).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "node {id}, point {k}");
            }
        }
    });
}

/// Slot-space edge cases at the tree level: a capacity-1 tree, deleting
/// down to a single live point, and refilling every tombstone.
#[test]
fn dynamic_tree_edge_cases() {
    // n = 1: no neighbors to sample, delete/insert round-trips the slot.
    let ds = Arc::new(Dataset::from_flat(1, 2, vec![0.5, -0.25]));
    let mut tree = build_dyn(ds, Kernel::Laplacian, &KdeConfig::exact());
    assert!(tree.delete(0));
    assert!(!tree.delete(0), "double delete is a no-op");
    assert_eq!(tree.insert(&[1.0, 1.0]), Some(0));
    assert_eq!(tree.insert(&[2.0, 2.0]), None, "slot space is fixed");
    let sampler = NeighborSampler::new(Arc::new(tree));
    assert!(sampler.sample(0, &mut Rng::new(7)).is_none(), "n = 1 has no neighbor");

    // Delete all but one, then refill: answers match a fresh build.
    let mut rng = Rng::new(0x1CE);
    let ds = Arc::new(gaussian_mixture(32, 3, 2, 1.0, 0.5, &mut rng));
    let mut tree = build_dyn(ds, Kernel::Gaussian, &KdeConfig::exact());
    for slot in 1..32 {
        assert!(tree.delete(slot));
    }
    assert_eq!(tree.ds.live_len(), 1);
    // The sole survivor's root answer is exactly its self-term.
    let solo = tree.query_point(tree.root(), 0);
    assert!((solo - 1.0).abs() < 1e-9, "self-term only, got {solo}");
    for _ in 1..32 {
        let row: Vec<f32> = (0..3).map(|_| (rng.f64() - 0.5) as f32).collect();
        assert!(tree.insert(&row).is_some());
    }
    assert_eq!(tree.ds.live_len(), 32);
    let fresh = build_dyn(tree.ds.clone(), Kernel::Gaussian, &KdeConfig::exact());
    let pts: Vec<usize> = (0..32).collect();
    let got = tree.query_points(tree.root(), &pts);
    let want = fresh.query_points(fresh.root(), &pts);
    for (g, w) in got.iter().zip(&want) {
        assert_eq!(g.to_bits(), w.to_bits());
    }
}

/// Satellite acceptance: at n = 16384, a `MaintainedSparsifier` driven
/// through a 1000-event seeded script (with one resparsify pass) matches
/// a from-scratch attach + resparsify over the identical final live set
/// on Laplacian quadratic forms, within the margin the existing
/// `resparsify_preserves_quadratic_forms` test already grants.
#[test]
fn maintained_sparsifier_matches_scratch_rebuild_spectrally() {
    let n = 16384usize;
    let mut rng = Rng::new(0xD1A5);
    let ds = Arc::new(gaussian_mixture(n, 3, 2, 1.0, 0.5, &mut rng));
    let cfg = MaintainedConfig {
        degree: 4,
        // Exactly one cleanup/resparsify pass, at event 1000.
        resparsify_every: 1000,
        target_edges: 16_000,
        jl_dims: 6,
        seed: 0xF1D0,
    };
    let initial: Vec<usize> = (0..8192).collect();
    let mut maintained = MaintainedSparsifier::new(ds.clone(), Kernel::Laplacian, &initial, cfg);

    // Seeded script: 500 inserts from the spare tail, 500 deletes spread
    // over the initial range, interleaved deterministically.
    let mut script = Vec::with_capacity(1000);
    for k in 0..500usize {
        script.push(PointEvent::Insert(8192 + k));
        script.push(PointEvent::Delete((k * 13) % 8192));
    }
    for &ev in &script {
        maintained.apply(ev);
    }
    let (events, resparsifies) = maintained.stats();
    assert_eq!(events, 1000);
    assert_eq!(resparsifies, 1, "script must trigger exactly one resparsify");
    let live = maintained.live_slots();
    assert_eq!(live.len(), 8192 + 500 - 500);

    // From-scratch comparator over the identical live set: fresh uniform
    // attach, then the same public resparsify with a pinned stream.
    let fresh = MaintainedSparsifier::new(ds, Kernel::Laplacian, &live, cfg);
    let fresh_raw = fresh.graph();
    let fresh_sparse = resparsify(&fresh_raw, cfg.target_edges, cfg.jl_dims, &mut Rng::new(0xACE));

    let g = maintained.graph();
    assert!(g.num_edges() <= fresh_raw.num_edges(), "resparsify must not densify");
    let quad = |g: &WGraph, x: &[f64]| g.laplacian_quadratic(x);
    let mut probe_rng = Rng::new(0xB0B);
    let mut worst_vs_sparse = 0.0f64;
    let mut worst_vs_raw = 0.0f64;
    for _ in 0..8 {
        let mut x: Vec<f64> = (0..n).map(|_| probe_rng.normal()).collect();
        let mean = x.iter().sum::<f64>() / n as f64;
        for v in x.iter_mut() {
            *v -= mean;
        }
        let qm = quad(&g, &x);
        worst_vs_sparse = worst_vs_sparse.max((qm / quad(&fresh_sparse, &x) - 1.0).abs());
        worst_vs_raw = worst_vs_raw.max((qm / quad(&fresh_raw, &x) - 1.0).abs());
    }
    assert!(worst_vs_sparse < 0.5, "maintained vs scratch-resparsified: {worst_vs_sparse}");
    assert!(worst_vs_raw < 0.5, "maintained vs scratch raw attach: {worst_vs_raw}");
}
