//! PJRT-vs-CPU backend parity: the AOT artifacts (Pallas -> HLO text ->
//! PJRT CPU) must produce the same numbers as the pure-Rust backend.
//!
//! Requires `make artifacts` to have run; tests skip (with a notice) if the
//! artifacts are missing so `cargo test` stays green in a fresh checkout.

use std::sync::Arc;

use kde_matrix::kde::{KdeConfig, KdeCounters};
use kde_matrix::kde::estimators::NaiveKde;
use kde_matrix::kde::Kde;
use kde_matrix::kernel::{dataset, Kernel, ALL_KERNELS};
use kde_matrix::runtime::backend::{CpuBackend, KernelBackend};
use kde_matrix::runtime::pjrt::PjrtBackend;
use kde_matrix::util::rng::Rng;

fn pjrt() -> Option<Arc<PjrtBackend>> {
    match PjrtBackend::new("artifacts") {
        Ok(b) => Some(b),
        Err(e) => {
            eprintln!("skipping PJRT test: {e}");
            None
        }
    }
}

#[test]
fn missing_artifacts_is_a_clean_error() {
    let msg = match PjrtBackend::new("/nonexistent/artifacts") {
        Ok(_) => panic!("must not succeed without artifacts"),
        Err(e) => format!("{e}"),
    };
    assert!(
        msg.contains("artifacts not built") && msg.contains("make artifacts"),
        "error must tell the user what to run: {msg}"
    );
}

#[test]
fn sums_parity_all_kernels() {
    let Some(pjrt) = pjrt() else { return };
    let cpu = CpuBackend::new();
    let mut rng = Rng::new(301);
    for &(b, m, d) in &[(1usize, 10usize, 3usize), (5, 300, 8), (64, 1024, 64), (70, 1500, 17)] {
        let queries: Vec<f32> = (0..b * d).map(|_| rng.normal() as f32).collect();
        let data: Vec<f32> = (0..m * d).map(|_| rng.normal() as f32).collect();
        for k in ALL_KERNELS {
            let got = pjrt.sums(k, &queries, &data, d);
            let want = cpu.sums(k, &queries, &data, d);
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (g - w).abs() < 2e-3 * (1.0 + w.abs()),
                    "{:?} b={b} m={m} d={d} query {i}: pjrt {g} vs cpu {w}",
                    k
                );
            }
        }
    }
}

#[test]
fn block_parity_all_kernels() {
    let Some(pjrt) = pjrt() else { return };
    let cpu = CpuBackend::new();
    let mut rng = Rng::new(303);
    let (b, m, d) = (7usize, 200usize, 5usize);
    let queries: Vec<f32> = (0..b * d).map(|_| rng.normal() as f32).collect();
    let data: Vec<f32> = (0..m * d).map(|_| rng.normal() as f32).collect();
    for k in ALL_KERNELS {
        let got = pjrt.block(k, &queries, &data, d);
        let want = cpu.block(k, &queries, &data, d);
        assert_eq!(got.len(), want.len());
        for i in 0..got.len() {
            assert!(
                (got[i] - want[i]).abs() < 1e-4 * (1.0 + want[i].abs()),
                "{:?} entry {i}: {} vs {}",
                k,
                got[i],
                want[i]
            );
        }
    }
}

#[test]
fn padding_does_not_leak_mass() {
    // Data sizes straddling tile boundaries must give identical sums.
    let Some(pjrt) = pjrt() else { return };
    let cpu = CpuBackend::new();
    let mut rng = Rng::new(305);
    let d = 4;
    let q: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
    for m in [1usize, 1023, 1024, 1025, 2048, 3000] {
        let data: Vec<f32> = (0..m * d).map(|_| rng.normal() as f32).collect();
        let got = pjrt.sums(Kernel::Laplacian, &q, &data, d)[0];
        let want = cpu.sums(Kernel::Laplacian, &q, &data, d)[0];
        assert!(
            (got - want).abs() < 2e-3 * (1.0 + want),
            "m={m}: pjrt {got} vs cpu {want}"
        );
    }
}

#[test]
fn sums_ranged_parity_and_tile_skipping() {
    // The fused level entry: per-row ranges across B- and M-tile
    // boundaries must match the CPU reference, and grid cells with no
    // live row must not execute at all (the block-diagonal win).
    let Some(pjrt) = pjrt() else { return };
    let cpu = CpuBackend::new();
    let mut rng = Rng::new(311);
    let d = 6usize;
    let (b, m) = (70usize, 2500usize); // ceil(70/64)=2 x ceil(2500/1024)=3 grid
    let queries: Vec<f32> = (0..b * d).map(|_| rng.normal() as f32).collect();
    let data: Vec<f32> = (0..m * d).map(|_| rng.normal() as f32).collect();
    let mut ranges = Vec::with_capacity(b);
    for q in 0..b {
        let lo = (q * 37) % m;
        let hi = (lo + 1 + (q * 113) % (m - lo)).min(m);
        // A few empty ranges mixed in.
        ranges.push(if q % 9 == 0 { (lo, lo) } else { (lo, hi) });
    }
    let before = pjrt.executions();
    let got = pjrt.sums_ranged(Kernel::Gaussian, &queries, &data, d, &ranges);
    assert!(
        pjrt.executions() - before <= 6,
        "at most one execution per (query chunk, data tile) grid cell"
    );
    let want = cpu.sums_ranged(Kernel::Gaussian, &queries, &data, d, &ranges);
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert!(
            (g - w).abs() < 2e-3 * (1.0 + w.abs()),
            "ranged row {i}: pjrt {g} vs cpu {w}"
        );
    }
    // Block-diagonal skipping: rows confined to the first M-tile must not
    // execute the later tiles.
    let confined: Vec<(usize, usize)> = (0..b).map(|q| (q % 500, 500 + q % 500)).collect();
    let before = pjrt.executions();
    let _ = pjrt.sums_ranged(Kernel::Gaussian, &queries, &data, d, &confined);
    assert_eq!(
        pjrt.executions() - before,
        2,
        "only the two (query chunk, first tile) cells are live"
    );
}

#[test]
fn block_ranged_parity_and_tile_skipping() {
    // The LRA row-construction entry: ragged per-row blocks across B- and
    // M-tile boundaries must match the CPU reference, and dead grid cells
    // must not execute.
    let Some(pjrt) = pjrt() else { return };
    let cpu = CpuBackend::new();
    let mut rng = Rng::new(313);
    let d = 6usize;
    let (b, m) = (70usize, 2500usize); // ceil(70/64)=2 x ceil(2500/1024)=3 grid
    let queries: Vec<f32> = (0..b * d).map(|_| rng.normal() as f32).collect();
    let data: Vec<f32> = (0..m * d).map(|_| rng.normal() as f32).collect();
    let mut ranges = Vec::with_capacity(b);
    for q in 0..b {
        let lo = (q * 41) % m;
        let hi = (lo + 1 + (q * 97) % (m - lo)).min(m);
        ranges.push(if q % 11 == 0 { (lo, lo) } else { (lo, hi) });
    }
    let before = pjrt.executions();
    let got = pjrt.block_ranged(Kernel::Gaussian, &queries, &data, d, &ranges);
    assert!(
        pjrt.executions() - before <= 6,
        "at most one execution per (query chunk, data tile) grid cell"
    );
    let want = cpu.block_ranged(Kernel::Gaussian, &queries, &data, d, &ranges);
    assert_eq!(got.len(), want.len(), "ragged layout mismatch");
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert!(
            (g - w).abs() < 1e-4 * (1.0 + w.abs()),
            "ragged block entry {i}: pjrt {g} vs cpu {w}"
        );
    }
    // Rows confined to the first M-tile must not execute the later tiles.
    let confined: Vec<(usize, usize)> = (0..b).map(|q| (q % 500, 500 + q % 500)).collect();
    let before = pjrt.executions();
    let _ = pjrt.block_ranged(Kernel::Gaussian, &queries, &data, d, &confined);
    assert_eq!(
        pjrt.executions() - before,
        2,
        "only the two (query chunk, first tile) cells are live"
    );
}

#[test]
fn kde_estimator_runs_on_pjrt_backend() {
    // The same estimator code must run against the artifact path.
    let Some(pjrt) = pjrt() else { return };
    let mut rng = Rng::new(307);
    let ds = Arc::new(dataset::gaussian_mixture(200, 6, 2, 1.0, 0.5, &mut rng));
    let counters = KdeCounters::new();
    let kde = NaiveKde::new(
        ds.clone(),
        Kernel::Gaussian,
        0,
        200,
        pjrt.clone(),
        counters,
    );
    let got = kde.query(ds.point(3));
    let want: f64 = (0..200)
        .map(|j| Kernel::Gaussian.eval(ds.point(j), ds.point(3)) as f64)
        .sum();
    assert!(
        (got - want).abs() < 1e-3 * (1.0 + want),
        "pjrt-backed KDE {got} vs exact {want}"
    );
}

#[test]
fn full_primitives_pipeline_on_pjrt() {
    // End-to-end: primitives + sparsification running entirely on the
    // AOT artifact path.
    let Some(pjrt) = pjrt() else { return };
    let mut rng = Rng::new(309);
    let ds = Arc::new(dataset::gaussian_mixture(96, 6, 2, 0.8, 0.5, &mut rng));
    let prims = kde_matrix::sampling::Primitives::build(
        ds.clone(),
        Kernel::Laplacian,
        &KdeConfig {
            kind: kde_matrix::kde::EstimatorKind::Sampling { eps: 0.4, tau: 0.2 },
            leaf_cutoff: 16,
            seed: 0xFE,
        },
        pjrt.clone(),
    );
    let sp = kde_matrix::apps::sparsify::sparsify(&prims, 3_000, &mut rng);
    assert!(sp.distinct_edges > 0);
    let err = kde_matrix::apps::sparsify::spectral_error(
        &ds,
        Kernel::Laplacian,
        &sp.graph,
        10,
        &mut rng,
    );
    assert!(err < 0.6, "pjrt pipeline spectral error {err}");
    assert!(pjrt.executions() > 0, "pipeline must actually hit PJRT");
}
