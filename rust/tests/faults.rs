//! Chaos suite: the fault-tolerant execution layer under deterministic
//! failure schedules.
//!
//! Contracts pinned here (the failure model of docs/ARCHITECTURE.md):
//!
//! 1. **Graceful degradation is invisible in the output**: a full batched
//!    sparsifier round whose primary backend permanently fails mid-run
//!    (every call from #3 on) completes via CPU failover with results
//!    bit-identical to an all-CPU run — zero client-visible panics, zero
//!    hangs, exactly one failover.
//! 2. **Transient faults are absorbed by bounded retry**: a backend that
//!    fails every 5th call transiently never trips failover and still
//!    reproduces the clean run bit for bit.
//! 3. **Deadlines**: expired requests are answered with a typed
//!    `Timeout`, never a late answer, and the service keeps serving.
//! 4. **Backpressure**: a slow backend plus a bounded queue produces
//!    typed `Overloaded` rejections, not unbounded queueing — and every
//!    *accepted* request still gets exactly one reply.
//! 5. **Panic isolation**: a panicking backend shard yields typed
//!    `Panicked` replies (the worker pool survives, healthy shards keep
//!    serving), a panicking packer drains the overlapped submission
//!    queue cleanly, and an unwrapped failing tree dispatch surfaces as
//!    a typed error through `try_query_points_multi`.
//! 6. **Typed addressing errors**: an unknown shard is a typed
//!    `UnknownShard` reply, not a panic.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use kde_matrix::apps::sparsify::sparsify_batched;
use kde_matrix::coordinator::{try_run_double_buffered, BatcherConfig, KdeService};
use kde_matrix::kde::{Kde, KdeConfig, KdeCounters, MultiLevelKde, NaiveKde};
use kde_matrix::kernel::{dataset::gaussian_mixture, Dataset, Kernel};
use kde_matrix::runtime::backend::CpuBackend;
use kde_matrix::runtime::error::BackendError;
use kde_matrix::runtime::fault::{FaultInjectingBackend, FaultMode, FaultPlan};
use kde_matrix::runtime::resilient::{ResilientBackend, RetryPolicy};
use kde_matrix::sampling::Primitives;
use kde_matrix::util::rng::Rng;

/// Deterministic probe vector for Laplacian quadratic-form comparisons.
fn quad_probe(n: usize) -> Vec<f64> {
    (0..n).map(|i| ((i * 37) % 101) as f64 / 101.0 - 0.5).collect()
}

fn exact(ds: &Dataset, k: Kernel, y: &[f32]) -> f64 {
    (0..ds.n).map(|j| k.eval(ds.point(j), y) as f64).sum()
}

#[test]
fn sparsifier_round_fails_over_bit_identical_to_all_cpu() {
    // The acceptance pin: primary permanently dies at backend call #3
    // (mid-build), the round completes on the CPU fallback, and the
    // sparsifier is bit-identical to an all-CPU run. Failed calls leave
    // no partial state and CpuBackend is deterministic across instances,
    // so the re-issued calls compute the very same values.
    let n = 1024usize;
    let t = 32usize;
    let mut rng = Rng::new(3301);
    let ds = Arc::new(gaussian_mixture(n, 4, 3, 1.2, 0.5, &mut rng));

    let baseline = {
        let prims =
            Primitives::build(ds.clone(), Kernel::Laplacian, &KdeConfig::exact(), CpuBackend::new());
        sparsify_batched(&prims, t, &mut Rng::new(17))
    };

    let primary = FaultInjectingBackend::new(
        CpuBackend::new(),
        FaultPlan::fail_from(3).with_mode(FaultMode::Permanent),
    );
    let resilient = ResilientBackend::new(
        primary.clone(),
        Some(CpuBackend::new()),
        RetryPolicy::immediate(2),
    );
    let prims = Primitives::build(
        ds.clone(),
        Kernel::Laplacian,
        &KdeConfig::exact(),
        resilient.clone(),
    );
    let degraded = sparsify_batched(&prims, t, &mut Rng::new(17));

    assert!(resilient.failed_over(), "schedule must have tripped failover");
    assert!(primary.injected() > 0, "the fault must actually have fired");
    let m = resilient.metrics();
    assert_eq!(m.failovers.load(Ordering::Relaxed), 1, "exactly one failover");
    assert!(m.fallback_calls.load(Ordering::Relaxed) > 0);

    assert_eq!(degraded.samples, baseline.samples);
    assert_eq!(degraded.distinct_edges, baseline.distinct_edges);
    assert_eq!(degraded.kde_queries, baseline.kde_queries, "same logical query traffic");
    let x = quad_probe(n);
    assert_eq!(
        degraded.graph.laplacian_quadratic(&x).to_bits(),
        baseline.graph.laplacian_quadratic(&x).to_bits(),
        "failover run diverged from the all-CPU run"
    );
}

#[test]
fn periodic_transient_faults_are_retried_through_without_failover() {
    let n = 512usize;
    let t = 24usize;
    let mut rng = Rng::new(3401);
    let ds = Arc::new(gaussian_mixture(n, 4, 3, 1.2, 0.5, &mut rng));

    let baseline = {
        let prims =
            Primitives::build(ds.clone(), Kernel::Laplacian, &KdeConfig::exact(), CpuBackend::new());
        sparsify_batched(&prims, t, &mut Rng::new(29))
    };

    // Every 5th call fails transiently; the retry (a fresh call index)
    // passes, so the bounded budget absorbs every fault.
    let primary = FaultInjectingBackend::new(CpuBackend::new(), FaultPlan::fail_every(5));
    let resilient = ResilientBackend::new(
        primary.clone(),
        Some(CpuBackend::new()),
        RetryPolicy::immediate(2),
    );
    let prims = Primitives::build(
        ds.clone(),
        Kernel::Laplacian,
        &KdeConfig::exact(),
        resilient.clone(),
    );
    let retried = sparsify_batched(&prims, t, &mut Rng::new(29));

    assert!(!resilient.failed_over(), "transient faults must not degrade");
    assert!(primary.injected() > 0, "the schedule must actually have fired");
    let m = resilient.metrics();
    assert!(m.retries.load(Ordering::Relaxed) > 0);
    assert_eq!(m.failovers.load(Ordering::Relaxed), 0);

    assert_eq!(retried.samples, baseline.samples);
    assert_eq!(retried.distinct_edges, baseline.distinct_edges);
    let x = quad_probe(n);
    assert_eq!(
        retried.graph.laplacian_quadratic(&x).to_bits(),
        baseline.graph.laplacian_quadratic(&x).to_bits(),
        "retried run diverged from the clean run"
    );
}

#[test]
fn tree_dispatch_failure_surfaces_as_typed_error() {
    // No resilience wrapper: the fallible tree entry reports the backend
    // failure instead of unwinding through the sampling stack.
    let mut rng = Rng::new(3501);
    let ds = Arc::new(gaussian_mixture(64, 3, 2, 1.0, 0.5, &mut rng));
    let be = FaultInjectingBackend::new(
        CpuBackend::new(),
        FaultPlan::fail_from(0).with_mode(FaultMode::Permanent),
    );
    let tree = MultiLevelKde::build(
        ds,
        Kernel::Laplacian,
        &KdeConfig::exact(),
        be,
        KdeCounters::new(),
    );
    let idx = [0usize, 1, 2];
    match tree.try_query_points_multi(&[(tree.root(), &idx)]) {
        Err(BackendError::ExecutionFailed { transient: false, .. }) => {}
        other => panic!("want permanent ExecutionFailed, got {other:?}"),
    }
}

#[test]
fn expired_deadlines_get_timeout_replies_and_service_recovers() {
    let mut rng = Rng::new(3601);
    let ds = Arc::new(gaussian_mixture(32, 4, 2, 1.0, 0.5, &mut rng));
    let svc = KdeService::start(
        vec![(Kernel::Laplacian, ds.clone())],
        CpuBackend::new(),
        BatcherConfig::default(),
    );
    // A zero deadline is already expired when the router first sees it:
    // the reply is deterministically Timeout, never a late answer.
    for i in 0..6 {
        let got = svc.try_query_deadline(0, ds.point(i).to_vec(), Duration::ZERO);
        assert_eq!(got, Err(BackendError::Timeout), "request {i}");
    }
    assert!(svc.metrics.timeouts.load(Ordering::Relaxed) >= 6);
    // The service keeps serving afterwards.
    let y = ds.point(3).to_vec();
    let got = svc.try_query(0, y.clone()).expect("service healthy after timeouts");
    let want = exact(&ds, Kernel::Laplacian, &y);
    assert!((got - want).abs() < 1e-6 * (1.0 + want));
    svc.shutdown();
}

#[test]
fn overload_rejects_with_typed_error_not_unbounded_queueing() {
    let mut rng = Rng::new(3701);
    let ds = Arc::new(gaussian_mixture(64, 4, 2, 1.0, 0.5, &mut rng));
    // A slow backend (2ms per dispatch) behind a tiny bounded queue:
    // flooding the service must produce Overloaded rejections while every
    // accepted request still gets exactly one reply.
    let slow = FaultInjectingBackend::new(
        CpuBackend::new(),
        FaultPlan::latency_only(Duration::from_millis(2)),
    );
    let svc = KdeService::start(
        vec![(Kernel::Laplacian, ds.clone())],
        slow,
        BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_micros(100),
            workers: 1,
            queue_cap: 4,
        },
    );
    let mut accepted = Vec::new();
    let mut overloaded = 0u64;
    for i in 0..256 {
        match svc.try_submit(0, ds.point(i % ds.n).to_vec()) {
            Ok(rx) => accepted.push(rx),
            Err(BackendError::Overloaded) => overloaded += 1,
            Err(e) => panic!("unexpected submit error: {e:?}"),
        }
    }
    let mut answered = 0u64;
    for rx in accepted {
        // Every accepted request must be answered — an answer or a typed
        // error, never a dropped channel or a hang.
        match rx.recv_timeout(Duration::from_secs(30)).expect("accepted request got no reply") {
            Ok(_) => answered += 1,
            Err(BackendError::Overloaded) => overloaded += 1,
            Err(e) => panic!("unexpected reply: {e:?}"),
        }
    }
    assert!(overloaded > 0, "backpressure never engaged under 64x overload");
    assert!(answered > 0, "nothing was served under load");
    assert_eq!(
        svc.metrics.rejected.load(Ordering::Relaxed),
        overloaded,
        "every rejection is counted"
    );
    svc.shutdown();
}

#[test]
fn panicking_backend_shard_yields_typed_replies_and_healthy_shard_serves() {
    let mut rng = Rng::new(3801);
    let ds = Arc::new(gaussian_mixture(24, 3, 2, 1.0, 0.5, &mut rng));
    let healthy: Arc<dyn Kde> = Arc::new(NaiveKde::new(
        ds.clone(),
        Kernel::Laplacian,
        0,
        24,
        CpuBackend::new(),
        KdeCounters::new(),
    ));
    let panicking = FaultInjectingBackend::new(
        CpuBackend::new(),
        FaultPlan::fail_from(0).with_mode(FaultMode::Panic),
    );
    let broken: Arc<dyn Kde> = Arc::new(NaiveKde::new(
        ds.clone(),
        Kernel::Laplacian,
        0,
        24,
        panicking,
        KdeCounters::new(),
    ));
    let svc = KdeService::start_with_oracles(vec![healthy, broken], BatcherConfig::default());
    // The broken shard's panics are caught at the worker's isolation
    // boundary: typed replies, no hang, no process abort.
    for _ in 0..3 {
        match svc.try_query(1, ds.point(0).to_vec()) {
            Err(BackendError::Panicked { message }) => {
                assert!(message.contains("injected fault"), "got: {message}")
            }
            other => panic!("want Panicked, got {other:?}"),
        }
    }
    assert!(svc.metrics.worker_panics.load(Ordering::Relaxed) >= 3);
    // The worker pool survived: the healthy shard still answers.
    let y = ds.point(5).to_vec();
    let got = svc.try_query(0, y.clone()).expect("healthy shard must keep serving");
    let want = exact(&ds, Kernel::Laplacian, &y);
    assert!((got - want).abs() < 1e-6 * (1.0 + want));
    svc.shutdown();
}

#[test]
fn overlap_queue_packer_panic_is_contained() {
    // A panic on the packer thread becomes a typed error on the calling
    // thread; the scope join completes (no leaked blocked thread, pinned
    // by this test returning at all).
    let got = try_run_double_buffered(
        (0..64).collect::<Vec<usize>>(),
        true,
        |t| {
            if t == 7 {
                panic!("chaos: pack died at item {t}")
            }
            t
        },
        |p| Ok::<usize, BackendError>(p),
    );
    match got {
        Err(BackendError::Panicked { message }) => {
            assert!(message.contains("chaos: pack died"), "got: {message}")
        }
        other => panic!("want Panicked, got {other:?}"),
    }
}

#[test]
fn unknown_shard_is_a_typed_error() {
    let mut rng = Rng::new(3901);
    let ds = Arc::new(gaussian_mixture(8, 3, 1, 0.0, 0.3, &mut rng));
    let svc = KdeService::start(
        vec![(Kernel::Gaussian, ds)],
        CpuBackend::new(),
        BatcherConfig::default(),
    );
    match svc.try_submit(5, vec![0.0; 3]) {
        Err(BackendError::UnknownShard { shard: 5, shards: 1 }) => {}
        other => panic!("want UnknownShard, got {other:?}"),
    }
    svc.shutdown();
}
