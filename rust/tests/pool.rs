//! Concurrency battery for the persistent sharded worker pool
//! (`runtime::pool::WorkerPool`) and the pooled `TiledBackend` rebased on
//! it. Contracts pinned here:
//!
//! 1. **Soak**: >= 10k mixed `sums_ranged`/`block_ranged` submissions
//!    issued concurrently from several submitter threads against ONE
//!    pooled backend reproduce the single-thread tiled reference bit for
//!    bit (the ranged entries partition output rows worker-disjointly, so
//!    results are independent of scheduling), and stay within the
//!    established fast-exp tolerance of the scalar `CpuBackend`.
//! 2. **Off-switch**: pooled execution vs per-call `std::thread::scope`
//!    spawns (`TiledBackend::set_pooled(false)`) is `to_bits`-identical
//!    for every entry point — `sums` (query-split AND data-split shapes),
//!    `block`, `sums_ranged`, `block_ranged` and their `try_*` forms —
//!    both routes run the identical chunk closures.
//! 3. **Chaos**: a task that panics on a pool worker is contained (the
//!    worker thread survives), re-raised on the caller, and mapped to the
//!    typed `BackendError::Panicked` at the standard `catch_panic`
//!    isolation boundary; `FaultInjectingBackend` panic/transient
//!    schedules over a pooled backend yield typed errors call by call
//!    while the pool underneath stays serviceable and bit-exact.
//! 4. **Shutdown**: dropping a pool with queued work drains every task
//!    before joining (no hang, nothing discarded).
//! 5. **Metrics sanity**: `busy`/`queued` gauges return to zero at
//!    quiescence, `submitted == completed`, high-water marks and the
//!    steal counter move when the load shape forces stealing.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use kde_matrix::kernel::Kernel;
use kde_matrix::runtime::error::catch_panic;
use kde_matrix::runtime::{
    BackendError, CpuBackend, FaultInjectingBackend, FaultMode, FaultPlan, KernelBackend,
    PoolConfig, TiledBackend, WorkerPool,
};
use kde_matrix::util::rng::Rng;

fn rand_buf(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32).collect()
}

/// One soak-test call shape: a fused submission's packed queries + ranges.
struct Case {
    queries: Vec<f32>,
    ranges: Vec<(usize, usize)>,
    want_sums: Vec<f64>,
    want_block: Vec<f32>,
}

#[test]
fn soak_10k_mixed_ranged_submissions_bit_identical() {
    // 4 submitter threads x 1250 iterations x (1 sums_ranged +
    // 1 block_ranged) = 10_000 backend dispatches against one shared
    // pooled backend; every result is checked bit for bit against the
    // single-thread tiled reference computed up front.
    let (d, m) = (8usize, 160usize); // data spans two DTILE=128 tiles
    let mut rng = Rng::new(0x50a1);
    let data = Arc::new(rand_buf(&mut rng, m * d));
    let reference = TiledBackend::with_threads(1);
    let cpu = CpuBackend::new();
    let cases: Vec<Case> = (0..16)
        .map(|_| {
            let b = 4 + rng.below(8); // 4..12 query rows
            let queries = rand_buf(&mut rng, b * d);
            let ranges: Vec<(usize, usize)> = (0..b)
                .map(|_| {
                    let lo = rng.below(m);
                    let hi = lo + rng.below(m - lo + 1);
                    (lo, hi)
                })
                .collect();
            let want_sums = reference.sums_ranged(Kernel::Laplacian, &queries, &data, d, &ranges);
            let want_block = reference.block_ranged(Kernel::Laplacian, &queries, &data, d, &ranges);
            // Anchor the reference itself against the scalar CpuBackend
            // (value-level: the tiled fast-exp map is not bit-equal to
            // libm, see runtime/tiled.rs `matches_cpu_backend_smoke`).
            let cpu_sums = cpu.sums_ranged(Kernel::Laplacian, &queries, &data, d, &ranges);
            for (w, c) in want_sums.iter().zip(&cpu_sums) {
                assert!((w - c).abs() < 2e-3 * (1.0 + c.abs()), "tiled {w} vs cpu {c}");
            }
            Case { queries, ranges, want_sums, want_block }
        })
        .collect();

    let pooled = TiledBackend::with_threads(4);
    assert!(pooled.pooled(), "pool execution is the default");
    let (threads, iters) = (4usize, 1250usize);
    std::thread::scope(|s| {
        for tid in 0..threads {
            let pooled = &pooled;
            let cases = &cases;
            let data = &data;
            s.spawn(move || {
                for it in 0..iters {
                    let c = &cases[(tid * iters + it) % cases.len()];
                    let got =
                        pooled.sums_ranged(Kernel::Laplacian, &c.queries, data, d, &c.ranges);
                    for (q, (g, w)) in got.iter().zip(&c.want_sums).enumerate() {
                        assert_eq!(
                            g.to_bits(),
                            w.to_bits(),
                            "thread {tid} iter {it} row {q}: pooled {g} vs reference {w}"
                        );
                    }
                    let got =
                        pooled.block_ranged(Kernel::Laplacian, &c.queries, data, d, &c.ranges);
                    for (j, (g, w)) in got.iter().zip(&c.want_block).enumerate() {
                        assert_eq!(
                            g.to_bits(),
                            w.to_bits(),
                            "thread {tid} iter {it} value {j}: pooled {g} vs reference {w}"
                        );
                    }
                }
            });
        }
    });

    assert_eq!(pooled.calls(), (threads * iters * 2) as u64, "10k dispatches issued");
    let metrics = pooled.pool_metrics().expect("pool was exercised");
    let submitted = metrics.submitted.load(Ordering::Relaxed);
    let completed = metrics.completed.load(Ordering::Relaxed);
    assert!(submitted >= 10_000, "soak submitted {submitted} pool tasks");
    assert_eq!(submitted, completed, "every submitted task completed");
    assert_eq!(metrics.busy(), 0, "busy gauge returns to zero at quiescence");
    assert_eq!(metrics.queued_depth(), 0, "queues drained at quiescence");
    assert_eq!(metrics.task_panics.load(Ordering::Relaxed), 0);
    assert!(
        metrics.busy_max.load(Ordering::Relaxed) >= 2,
        "concurrent submitters must overlap on the pool"
    );
}

#[test]
fn pooled_matches_scoped_spawns_for_every_entry_point() {
    // The off-switch contract: set_pooled(false) routes the identical
    // worker-disjoint chunk closures through per-call scoped spawns, so
    // every entry point — infallible and try_* — is to_bits-identical.
    let d = 8usize;
    let mut rng = Rng::new(0x50a2);
    let pooled = TiledBackend::with_threads(4);
    let scoped = TiledBackend::with_threads(4);
    scoped.set_pooled(false);
    assert!(pooled.pooled() && !scoped.pooled());

    // Two shapes: b >= threads (query split) and b < threads with much
    // data (the data-split sums path, whose chunk-order partial fold must
    // also survive the rebase).
    for (b, m) in [(16usize, 200usize), (2usize, 600usize)] {
        let queries = rand_buf(&mut rng, b * d);
        let data = rand_buf(&mut rng, m * d);
        let ranges: Vec<(usize, usize)> = (0..b)
            .map(|q| {
                let lo = (q * 13) % m;
                (lo, m - (q * 7) % (m - lo))
            })
            .collect();
        for k in [Kernel::Gaussian, Kernel::Laplacian] {
            let a = pooled.sums(k, &queries, &data, d);
            let c = scoped.sums(k, &queries, &data, d);
            for (x, y) in a.iter().zip(&c) {
                assert_eq!(x.to_bits(), y.to_bits(), "{k:?} sums b={b}");
            }
            let a = pooled.block(k, &queries, &data, d);
            let c = scoped.block(k, &queries, &data, d);
            for (x, y) in a.iter().zip(&c) {
                assert_eq!(x.to_bits(), y.to_bits(), "{k:?} block b={b}");
            }
            let a = pooled.sums_ranged(k, &queries, &data, d, &ranges);
            let c = scoped.sums_ranged(k, &queries, &data, d, &ranges);
            for (x, y) in a.iter().zip(&c) {
                assert_eq!(x.to_bits(), y.to_bits(), "{k:?} sums_ranged b={b}");
            }
            let a = pooled.block_ranged(k, &queries, &data, d, &ranges);
            let c = scoped.block_ranged(k, &queries, &data, d, &ranges);
            for (x, y) in a.iter().zip(&c) {
                assert_eq!(x.to_bits(), y.to_bits(), "{k:?} block_ranged b={b}");
            }
            // try_* forms ride the same execution paths.
            let a = pooled.try_sums(k, &queries, &data, d).expect("healthy backend");
            let c = scoped.try_sums(k, &queries, &data, d).expect("healthy backend");
            for (x, y) in a.iter().zip(&c) {
                assert_eq!(x.to_bits(), y.to_bits(), "{k:?} try_sums b={b}");
            }
            let a = pooled
                .try_sums_ranged(k, &queries, &data, d, &ranges)
                .expect("healthy backend");
            let c = scoped
                .try_sums_ranged(k, &queries, &data, d, &ranges)
                .expect("healthy backend");
            for (x, y) in a.iter().zip(&c) {
                assert_eq!(x.to_bits(), y.to_bits(), "{k:?} try_sums_ranged b={b}");
            }
        }
    }

    // Toggling back re-enters the (still-live) pool with identical output.
    scoped.set_pooled(true);
    let queries = rand_buf(&mut rng, 12 * d);
    let data = rand_buf(&mut rng, 90 * d);
    let a = pooled.sums(Kernel::Laplacian, &queries, &data, d);
    let c = scoped.sums(Kernel::Laplacian, &queries, &data, d);
    for (x, y) in a.iter().zip(&c) {
        assert_eq!(x.to_bits(), y.to_bits(), "re-pooled toggle");
    }
}

#[test]
fn worker_panic_maps_to_typed_error_and_pool_stays_serviceable() {
    // A panic inside a pool task crosses run_scoped back onto the caller
    // and the standard catch_panic isolation boundary (the exact boundary
    // the KernelBackend try_* defaults use) turns it into the typed
    // BackendError::Panicked — with the pool fully serviceable after.
    let pool = WorkerPool::new(PoolConfig::with_workers(3));
    let before = pool.workers();
    let err = catch_panic(|| {
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
            Box::new(|| {}),
            Box::new(|| panic!("tile chunk exploded")),
            Box::new(|| {}),
        ];
        pool.run_scoped(tasks);
    });
    match err {
        Err(BackendError::Panicked { message }) => {
            assert!(message.contains("exploded"), "payload preserved: {message}")
        }
        other => panic!("want BackendError::Panicked, got {other:?}"),
    }
    // Containment: no worker thread died, the next batch runs clean.
    assert_eq!(pool.workers(), before, "worker threads survive contained panics");
    assert_eq!(pool.metrics().task_panics.load(Ordering::Relaxed), 1);
    let hits = AtomicU64::new(0);
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..8)
        .map(|_| {
            let h = &hits;
            Box::new(move || {
                h.fetch_add(1, Ordering::Relaxed);
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    pool.run_scoped(tasks);
    assert_eq!(hits.load(Ordering::Relaxed), 8, "pool serviceable after panic");
    assert_eq!(pool.metrics().busy(), 0);
}

#[test]
fn chaos_schedules_over_pooled_backend_yield_typed_errors() {
    // FaultInjectingBackend panic/transient schedules over a POOLED tiled
    // backend: scheduled calls surface as typed errors at the try_*
    // boundary, unscheduled calls stay bit-exact, and the pooled backend
    // underneath keeps serving across the whole storm.
    let d = 8usize;
    let mut rng = Rng::new(0x50a3);
    let queries = rand_buf(&mut rng, 8 * d);
    let data = rand_buf(&mut rng, 96 * d);
    let ranges: Vec<(usize, usize)> = (0..8).map(|q| (q * 4, 96 - q * 3)).collect();
    let tiled = TiledBackend::with_threads(4);
    let want = tiled.sums_ranged(Kernel::Laplacian, &queries, &data, d, &ranges);

    for mode in [FaultMode::Transient, FaultMode::Panic] {
        let plan = FaultPlan::fail_every(3).with_mode(mode);
        let chaos = FaultInjectingBackend::new(tiled.clone(), plan);
        let mut failures = 0u64;
        for call in 0..12u64 {
            // Panic-mode gate fires on the submitting thread; wrap the
            // dispatch in the same catch_panic boundary MultiLevelKde's
            // fallible path uses so both modes land as typed errors.
            let got = catch_panic(|| {
                chaos.try_sums_ranged(Kernel::Laplacian, &queries, &data, d, &ranges)
            })
            .and_then(|r| r);
            if (call + 1) % 3 == 0 {
                match got {
                    Err(BackendError::Panicked { message }) => {
                        assert_eq!(mode, FaultMode::Panic, "panic only in panic mode");
                        assert!(message.contains("injected fault"), "got: {message}");
                    }
                    Err(BackendError::ExecutionFailed { transient, .. }) => {
                        assert_eq!(mode, FaultMode::Transient);
                        assert!(transient, "transient schedule marks errors retryable");
                    }
                    other => panic!("call {call}: want typed error, got {other:?}"),
                }
                failures += 1;
            } else {
                let got = got.unwrap_or_else(|e| panic!("call {call} should pass: {e}"));
                for (g, w) in got.iter().zip(&want) {
                    assert_eq!(g.to_bits(), w.to_bits(), "passing calls stay bit-exact");
                }
            }
        }
        assert_eq!(failures, 4);
        assert_eq!(chaos.injected(), 4, "deterministic schedule");
    }

    // The pool below the storm never saw a fault (the gate fires before
    // the inner backend) and is still healthy.
    let metrics = tiled.pool_metrics().expect("pool was exercised");
    assert_eq!(metrics.task_panics.load(Ordering::Relaxed), 0);
    assert_eq!(metrics.busy(), 0);
    assert_eq!(metrics.queued_depth(), 0);
    let again = tiled.sums_ranged(Kernel::Laplacian, &queries, &data, d, &ranges);
    for (g, w) in again.iter().zip(&want) {
        assert_eq!(g.to_bits(), w.to_bits(), "pool healthy after the storm");
    }
}

#[test]
fn drop_with_queued_backlog_drains_every_task() {
    // Shutdown contract: Drop flags shutdown, rings the doorbell and
    // joins; workers drain every queued task before exiting. A slow head
    // task guarantees a real backlog exists at drop time.
    let done = Arc::new(AtomicU64::new(0));
    {
        let pool = WorkerPool::new(PoolConfig::with_workers(2));
        for i in 0..128u64 {
            let d = Arc::clone(&done);
            pool.submit(Box::new(move || {
                if i < 2 {
                    std::thread::sleep(std::time::Duration::from_millis(20));
                }
                d.fetch_add(1, Ordering::Relaxed);
            }));
        }
        // Drop joins here with most of the backlog still queued.
    }
    assert_eq!(done.load(Ordering::Relaxed), 128, "drop drains the shards");
}

#[test]
fn backend_drop_with_live_pool_does_not_hang() {
    // TiledBackend owns its pool through a OnceLock; dropping the backend
    // right after a dispatch must join the workers cleanly. The test's
    // completion IS the assertion (a hang trips the harness timeout).
    let mut rng = Rng::new(0x50a4);
    let queries = rand_buf(&mut rng, 8 * 4);
    let data = rand_buf(&mut rng, 64 * 4);
    for _ in 0..8 {
        let be = TiledBackend::with_threads(3);
        let s = be.sums(Kernel::Gaussian, &queries, &data, 4);
        assert_eq!(s.len(), 8);
        drop(be);
    }
}

#[test]
fn steal_counter_moves_under_skewed_load() {
    // Load shape that forces stealing: the first task (shard 0) sleeps
    // while 40 quick tasks round-robin onto all shards. Workers 1..3
    // drain their own shards FIFO, then steal shard 0's backlog LIFO —
    // the steals counter must move, and the gauges must return to zero.
    let pool = WorkerPool::new(PoolConfig::with_workers(4));
    let hits = AtomicU64::new(0);
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
    tasks.push(Box::new(|| std::thread::sleep(std::time::Duration::from_millis(100))));
    for _ in 0..40 {
        let h = &hits;
        tasks.push(Box::new(move || {
            h.fetch_add(1, Ordering::Relaxed);
        }));
    }
    pool.run_scoped(tasks);
    assert_eq!(hits.load(Ordering::Relaxed), 40);
    let m = pool.metrics();
    assert_eq!(m.submitted.load(Ordering::Relaxed), 41);
    assert_eq!(m.completed.load(Ordering::Relaxed), 41);
    assert!(m.steals() >= 1, "skewed load must trigger LIFO steals: {}", m.summary());
    assert!(m.queued_max.load(Ordering::Relaxed) >= 1, "backlog existed");
    assert_eq!(m.busy(), 0, "busy gauge zero at quiescence");
    assert_eq!(m.queued_depth(), 0, "queued gauge zero at quiescence");
}
